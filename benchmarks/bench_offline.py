"""Table 2 — offline distillation makespan: 4 prefill instances,
deadline-free; vanilla FCFS vs PLA token-max batching.  Decode side
(4 instances) is identical across systems, so the delta is prefill-side.
"""
from __future__ import annotations

from typing import Dict, List

import dataclasses

from benchmarks.common import COST, MODEL, routed_sim
from repro.core import Variant, make_policy
from repro.core.awd import AWDConfig
from repro.sim import ClusterSim, SimConfig
from repro.sim.workload import WorkloadConfig, lmsys_like_requests

N_REQ = 3000
GPU_COST = COST   # TPU launch economics (see EXPERIMENTS.md §table2 note)


def _makespan(variant: str, seed: int) -> float:
    wl = WorkloadConfig(slo_ttft=None)                # deadline-free
    reqs = lmsys_like_requests(N_REQ, rate=1e6, cfg=wl, seed=seed)
    for r in reqs:
        r.arrival = 0.0                               # full dataset at t=0
    kw = {}
    if variant == "pla_full":
        kw["awd_cfg"] = AWDConfig(deadline_free=True,
                                  min_fill_tokens=16_384)
        kw["chunk_tokens"] = 16_384  # offline: maximal C_l — "large
        # fixed-size chunks to sustain high arithmetic intensity" (§3.2b);
        # one dispatch per long minimizes serialization launch overhead

    def factory(i):
        return make_policy(Variant(variant), MODEL, threshold=256, **kw)

    sim = ClusterSim(4, factory, GPU_COST, SimConfig(router="least_loaded",
                                                     slo_ttft=None))
    sim.add_requests(reqs)
    tracker = sim.run(1e7)
    return max(r.finish_time or 0.0 for r in tracker.finished)


def run() -> List[Dict]:
    rows = []
    for name, seed in (("LMSys", 21), ("ShareGPT", 42)):
        van = _makespan("vanilla", seed)
        pla = _makespan("pla_full", seed)
        rows.append({"bench": "table2", "tag": name,
                     "vanilla_s": round(van, 1), "pla_s": round(pla, 1),
                     "improvement": round(1 - pla / van, 4),
                     "paper_improvement": 0.073 if name == "LMSys" else 0.083,
                     "mean_ms": 0.0})
    return rows
