"""Speculative decoding bench (DESIGN.md §10): multi-token commits per
dispatch on the packed mixed stream.

Scenario: one chat session decodes a long greedy stream.  The plain
baseline is the PR 3 arena-resident decode ladder — one dispatch (one
amortized weight read, one full-history KV stream) per token.  The
speculative run arms a ScriptedDraft at target acceptance ~0.7 with
k = 4: each dispatch verifies [pending, d1..d4] as ONE packed verify
segment and commits the accepted prefix plus a corrective token, so the
per-token cost of the weight read and the history stream divides by the
commit count.  Greedy acceptance is exact-match, so the spec stream is
asserted BIT-IDENTICAL to the baseline (losslessness), with zero
whole-slot gather/scatter and zero full-vocab logits rows shipped.  A
third phase samples (temperature/top-k/top-p) through the fused
on-device sampling kernel and asserts the logits stay on device there
too.  Writes BENCH_spec.json.
"""
from __future__ import annotations

import json
import os
import sys
import time
from typing import Dict, List

import numpy as np

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.serving.engine import Engine, EngineConfig  # noqa: E402
from repro.serving.draft import ScriptedDraft  # noqa: E402
from repro.serving.sampling import SamplingParams  # noqa: E402

BENCH_JSON = os.path.join(os.path.dirname(__file__), "..",
                          "BENCH_spec.json")

K = 4
BUDGET = 48          # decoded tokens per run (past the TTFT token)
ACCEPT = 0.7


def _engine(cfg, params, **kw) -> Engine:
    kw.setdefault("num_slots", 4)
    kw.setdefault("max_len", 128)
    kw.setdefault("chunk_tokens", 16)
    kw.setdefault("keep_last_logits", False)
    return Engine(cfg, params, EngineConfig(**kw))


def _kv_row_bytes(cfg) -> int:
    return (2 * cfg.num_layers * cfg.num_kv_heads * cfg.hdim
            * np.dtype(cfg.np_dtype).itemsize)


def _drive_plain(cfg, params, prompt) -> Dict:
    """PR 3 baseline: the arena-resident decode ladder, one token per
    dispatch, billed at (history + 1) KV rows each."""
    eng = _engine(cfg, params)
    kvb = _kv_row_bytes(cfg)
    eng.open_session(0)
    t0 = eng.prefill_packed([0], [prompt])[0]
    stream, cur, hbm = [t0], t0, 0.0
    wall = time.perf_counter()
    for _ in range(BUDGET):
        h = eng.history(0)
        hbm += (h + 1) * kvb          # stream the prefix, write one row
        cur = eng.decode_batch([0], [cur], steps=1)[0][0]
        stream.append(cur)
    wall = time.perf_counter() - wall
    st = eng.stats()
    disp = eng.decode_executor.dispatches
    return {
        "stream": stream,
        "row": {"dispatches": disp,
                "tokens_per_dispatch": round(BUDGET / max(disp, 1), 2),
                "hbm_bytes_per_token": round(hbm / BUDGET, 1),
                "logits_rows_shipped": st["logits_rows_shipped"],
                "arena_gathers": st["arena_gathers"],
                "arena_scatters": st["arena_scatters"],
                "wall_ms": round(1e3 * wall, 1)},
    }


def _drive_spec(cfg, params, prompt, script: List[int],
                sampling=None, fused=False) -> Dict:
    """Speculative run: ScriptedDraft proposals at target acceptance
    ~ACCEPT, verified k+1 tokens per packed dispatch.  HBM model per
    dispatch: stream the history once, write 1+k rows (rejected tails
    are truncated bookkeeping, but their rows WERE written)."""
    eng = _engine(cfg, params, fused_sampling=fused)
    kvb = _kv_row_bytes(cfg)
    draft = ScriptedDraft({0: script}, accept=ACCEPT,
                          vocab=cfg.vocab_size, seed=1)
    eng.enable_spec(draft, k=K)
    eng.open_session(0)
    if sampling is not None:
        eng.set_sampling(0, sampling)
    t0 = eng.prefill_packed([0], [prompt])[0]
    stream, cur, hbm = [t0], t0, 0.0
    wall = time.perf_counter()
    while len(stream) < 1 + BUDGET:
        h = eng.history(0)
        hbm += (h + 1 + K) * kvb
        got = eng.spec_step([(0, cur)],
                            max_new={0: 1 + BUDGET - len(stream)})[0]
        stream.extend(got)
        cur = got[-1]
    wall = time.perf_counter() - wall
    st = eng.stats()
    return {
        "stream": stream,
        "row": {"dispatches": st["spec_dispatches"],
                "tokens_per_dispatch": st["spec_tokens_per_dispatch"],
                "acceptance": st["spec_acceptance"],
                "tokens_drafted": st["tokens_drafted"],
                "tokens_accepted": st["tokens_accepted"],
                "hbm_bytes_per_token": round(hbm / BUDGET, 1),
                "logits_rows_shipped": st["logits_rows_shipped"],
                "fused_sample_steps": st["fused_sample_steps"],
                "arena_gathers": st["arena_gathers"],
                "arena_scatters": st["arena_scatters"],
                "wall_ms": round(1e3 * wall, 1)},
    }


def spec_scenario(write: bool = True) -> List[Dict]:
    import jax

    from repro.configs import get_smoke
    from repro.models import transformer as tr

    cfg = get_smoke("qwen3-4b")
    params, _ = tr.init_params(cfg, jax.random.key(0))
    rng = np.random.default_rng(9)
    prompt = rng.integers(1, cfg.vocab_size, 11)

    plain = _drive_plain(cfg, params, prompt)
    spec = _drive_spec(cfg, params, prompt, plain["stream"])
    new, old = spec["row"], plain["row"]

    # ---- §10 acceptance gates -----------------------------------------
    assert spec["stream"] == plain["stream"], \
        "speculative greedy stream diverged from the plain decode"
    assert new["tokens_per_dispatch"] > 1.8, new["tokens_per_dispatch"]
    assert new["hbm_bytes_per_token"] < old["hbm_bytes_per_token"], \
        (new["hbm_bytes_per_token"], old["hbm_bytes_per_token"])
    assert new["arena_gathers"] == 0 and new["arena_scatters"] == 0
    assert new["logits_rows_shipped"] == 0, new["logits_rows_shipped"]
    assert new["dispatches"] < old["dispatches"]

    # ---- fused on-device sampling under speculation -------------------
    sp = SamplingParams(temperature=0.8, top_k=32, top_p=0.95, seed=17)
    fused = _drive_spec(cfg, params, prompt, plain["stream"],
                        sampling=sp, fused=True)
    assert fused["row"]["logits_rows_shipped"] == 0, \
        fused["row"]["logits_rows_shipped"]
    assert fused["row"]["fused_sample_steps"] > 0
    assert len(fused["stream"]) == 1 + BUDGET

    rows = [
        {"bench": "spec_decode", "tag": "spec", "mean_ms": 0.0,
         "k": K, "target_accept": ACCEPT, **new},
        {"bench": "spec_decode", "tag": "plain", "mean_ms": 0.0, **old},
        {"bench": "spec_decode", "tag": "fused_sampled", "mean_ms": 0.0,
         **fused["row"]},
        {"bench": "spec_decode", "tag": "gain", "mean_ms": 0.0,
         "tokens_per_dispatch": new["tokens_per_dispatch"],
         "dispatch_reduction": old["dispatches"] - new["dispatches"],
         "hbm_reduction_x": round(old["hbm_bytes_per_token"]
                                  / max(new["hbm_bytes_per_token"], 1e-9),
                                  2),
         "lossless": True},
    ]
    if write:
        with open(BENCH_JSON, "w") as f:
            json.dump(rows, f, indent=1)
    for r in rows:
        print(r)
    print("BENCH_spec OK: "
          f"{new['tokens_per_dispatch']:.2f} tokens/dispatch at "
          f"acceptance {new['acceptance']:.2f}, HBM/token "
          f"{old['hbm_bytes_per_token']:.0f} -> "
          f"{new['hbm_bytes_per_token']:.0f}")
    return rows


if __name__ == "__main__":
    spec_scenario()
