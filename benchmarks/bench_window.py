"""Fig.5 — latency/throughput trade-off over the waiting window.

64-way short-prefill concurrency (paper setting), window forced to fixed
values by pinning [w_min, w_max]; AWD's adaptive point is run last.
"""
from __future__ import annotations

from typing import Dict, List

from benchmarks.common import MODEL, COST, class_stats
from repro.core import Variant, make_policy
from repro.core.awd import AWDConfig
from repro.sim import ClusterSim, SimConfig
from repro.sim.workload import WorkloadConfig, lmsys_like_requests

UNTIL = 40.0
RATE = 170.0     # above single-request-batch capacity (~66 rps): tiny
# windows saturate the instance, larger windows buy batching efficiency —
# the paper's Fig.5 trade-off


def _run(w_fixed=None):
    kw = {}
    if w_fixed is not None:
        kw["awd_cfg"] = AWDConfig(w_min=w_fixed, w_max=w_fixed,
                                  t_max=10.0, sigma=-1.0)   # pure window
    pol = make_policy(Variant("pla_full"), MODEL, threshold=256, **kw)
    sim = ClusterSim(1, lambda i: None, COST, SimConfig(router="shared"),
                     shared_policy=pol)
    wl = WorkloadConfig(first_mu=3.4, first_sigma=0.7, mean_turns=6.0,
                        slo_ttft=None)
    reqs = [r for r in lmsys_like_requests(int(RATE * UNTIL), RATE, wl,
                                           seed=11)
            if r.new_tokens < 256]
    sim.add_requests(reqs)
    tracker = sim.run(UNTIL + 30)
    return class_stats(tracker, "short", UNTIL)


def run() -> List[Dict]:
    rows = []
    for w_ms in (0.5, 2, 5, 10, 20, 50, 100):
        s = _run(w_fixed=w_ms / 1e3)
        rows.append({"bench": "fig5", "tag": f"W={w_ms}ms", **s})
    rows.append({"bench": "fig5", "tag": "W=adaptive(AWD)", **_run(None)})
    return rows
