"""Fig.6 — PLA vs vanilla + two partial ablations, RPS / mean / P90
across concurrency 1..64, temporal (1 instance) and spatial (8
instances).
"""
from __future__ import annotations

from typing import Dict, List

from benchmarks.common import class_stats, routed_sim, shared_sim
from repro.sim.workload import WorkloadConfig, closed_loop_clients

UNTIL = 30.0
VARIANTS = ("vanilla", "graph_only", "disagg_only", "pla_full")


def _run_temporal(variant: str, conc: int):
    sim = shared_sim(variant)
    sim.add_clients(closed_loop_clients(conc, WorkloadConfig(), seed=6))
    return sim.run(UNTIL)


def _run_spatial(variant: str, conc: int):
    router = "pool" if variant in ("pla_full", "disagg_only") else \
        "least_loaded"
    sim = routed_sim(variant, 8, router=router,
                     control=(variant == "pla_full"))
    sim.add_clients(closed_loop_clients(conc, WorkloadConfig(), seed=6))
    return sim.run(UNTIL)


def run() -> List[Dict]:
    rows = []
    for conc in (1, 4, 16, 64):
        for variant in VARIANTS:
            tr = _run_temporal(variant, conc)
            rows.append({"bench": "fig6-temporal",
                         "tag": f"{variant}/c{conc}",
                         **class_stats(tr, None, UNTIL)})
    for conc in (8, 32, 64, 128):
        for variant in VARIANTS:
            tr = _run_spatial(variant, conc)
            rows.append({"bench": "fig6-spatial",
                         "tag": f"{variant}/c{conc}",
                         **class_stats(tr, None, UNTIL)})
    return rows
