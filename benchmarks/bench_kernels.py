"""Per-kernel timing: Pallas (interpret on CPU — correctness-path cost)
vs the jnp oracle (XLA-compiled), with derived bandwidth estimates.
On TPU the same harness times the compiled kernels.
"""
from __future__ import annotations

import time
from typing import Dict, List

import jax
import jax.numpy as jnp

from repro.kernels import ref
from repro.kernels.decode_attn import decode_attn
from repro.kernels.flash_attn import flash_attn
from repro.kernels.ssd_scan import ssd_scan

KEY = jax.random.key(0)


def _time(fn, *args, reps=3) -> float:
    fn(*args)                                  # compile/warm
    t0 = time.perf_counter()
    for _ in range(reps):
        jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / reps * 1e6   # µs


def run() -> List[Dict]:
    rows: List[Dict] = []
    ks = jax.random.split(KEY, 8)

    b, lq, s, hq, hkv, d = 1, 128, 256, 4, 2, 64
    q = jax.random.normal(ks[0], (b, lq, hq, d))
    k = jax.random.normal(ks[1], (b, s, hkv, d))
    v = jax.random.normal(ks[2], (b, s, hkv, d))
    offs = jnp.zeros((b,), jnp.int32)
    bytes_moved = (q.size + 2 * k.size + q.size) * 4
    t_pal = _time(lambda *a: flash_attn(*a, block_q=64, block_k=64), q, k, v, offs)
    t_ref = _time(lambda *a: ref.ref_flash_attn(*a), q, k, v)
    rows.append({"bench": "kernels", "tag": "flash_attn/interp",
                 "mean_ms": t_pal / 1e3, "us": round(t_pal, 1),
                 "gbps_ref": round(bytes_moved / (t_ref * 1e-6) / 1e9, 2)})
    rows.append({"bench": "kernels", "tag": "flash_attn/ref",
                 "mean_ms": t_ref / 1e3, "us": round(t_ref, 1)})

    qd = jax.random.normal(ks[3], (4, hq, d))
    kd = jax.random.normal(ks[4], (4, 512, hkv, d))
    vd = jax.random.normal(ks[5], (4, 512, hkv, d))
    lens = jnp.full((4,), 512, jnp.int32)
    t_pal = _time(lambda *a: decode_attn(*a, block_k=128), qd, kd, vd, lens)
    t_ref = _time(ref.ref_decode_attn, qd, kd, vd, lens)
    rows.append({"bench": "kernels", "tag": "decode_attn/interp",
                 "mean_ms": t_pal / 1e3, "us": round(t_pal, 1)})
    rows.append({"bench": "kernels", "tag": "decode_attn/ref",
                 "mean_ms": t_ref / 1e3, "us": round(t_ref, 1)})

    bb, ll, nh, hd, ds = 1, 256, 4, 32, 32
    x = jax.random.normal(ks[6], (bb, ll, nh, hd))
    dt = jax.nn.softplus(jax.random.normal(ks[7], (bb, ll, nh)))
    a = -jnp.exp(jax.random.normal(ks[0], (nh,)) * 0.3)
    bm = jax.random.normal(ks[1], (bb, ll, nh, ds))
    cm = jax.random.normal(ks[2], (bb, ll, nh, ds))
    h0 = jnp.zeros((bb, nh, hd, ds))
    t_pal = _time(lambda *a_: ssd_scan(*a_, chunk=64), x, dt, a, bm, cm, h0)
    t_ref = _time(ref.ref_ssd_scan, x, dt, a, bm, cm)
    rows.append({"bench": "kernels", "tag": "ssd_scan/interp",
                 "mean_ms": t_pal / 1e3, "us": round(t_pal, 1)})
    rows.append({"bench": "kernels", "tag": "ssd_scan/ref",
                 "mean_ms": t_ref / 1e3, "us": round(t_ref, 1)})
    return rows
