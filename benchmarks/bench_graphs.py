"""§4.2 cost analysis — the TPU analogue of CUDA Graph capture: AOT
compile time per (L, B) bucket and executable-cache behaviour, measured
on the real engine with a reduced model.
"""
from __future__ import annotations

import time
from typing import Dict, List

import jax
import numpy as np

from repro.configs import get_smoke
from repro.models import transformer as tr
from repro.serving import Engine, EngineConfig


def run() -> List[Dict]:
    cfg = get_smoke("qwen3-4b")
    params, _ = tr.init_params(cfg, jax.random.key(0))
    # dense (L, B) grid capture cost is a slot/dense-baseline measurement
    eng = Engine(cfg, params, EngineConfig(num_slots=8, max_len=128,
                                           paged_kv=False))
    rows: List[Dict] = []

    cap = eng.executor.precapture(params, eng.arena.gather,
                                  lengths=(8, 16, 32), depths=(1, 2, 4))
    n = len(eng.executor.compile_times)
    rows.append({"bench": "graphs", "tag": "precapture",
                 "shapes": n, "total_s": round(cap, 2),
                 "per_graph_s": round(cap / n, 2),
                 "paper_per_graph_s": "8-12 (H200, 7-32B)",
                 "mean_ms": cap / n * 1e3})

    # steady-state dispatch: captured vs fresh-shape (miss) cost
    rng = np.random.default_rng(0)
    eng.prefill_batch([0], [rng.integers(0, cfg.vocab_size, 8)], bucket=(8, 1))
    t0 = time.perf_counter()
    for s in range(1, 6):
        eng.prefill_batch([s], [rng.integers(0, cfg.vocab_size, 8)],
                          bucket=(8, 1))
    hit = (time.perf_counter() - t0) / 5
    t0 = time.perf_counter()
    eng.prefill_batch([6], [rng.integers(0, cfg.vocab_size, 23)])  # off-grid
    miss = time.perf_counter() - t0
    rows.append({"bench": "graphs", "tag": "hit_vs_miss",
                 "hit_ms": round(hit * 1e3, 2), "miss_ms": round(miss * 1e3, 2),
                 "speedup": round(miss / hit, 1), "mean_ms": hit * 1e3})
    return rows
