"""§11 fault-tolerance acceptance bench (BENCH_faults.json, CI smoke).

Two arms:

* ``engine/failover`` — a real 4-engine smoke ServeCluster loses one
  engine mid-drain (scripted FaultPlan crash).  Queued requests
  re-route through the router, in-flight sessions re-prefill-
  reconstruct on survivors, and the acceptance bar is: ZERO lost
  requests, ``recovered_sessions > 0``, and greedy transcripts
  bit-identical to an identical fault-free cluster.
* ``sim/admission`` — the simulator under overload, admission gate on
  vs accept-everything at matched offered load: the gate must shed
  submits (``rejected > 0``) and show a STRICTLY lower violation rate
  over the admitted population.
"""
from __future__ import annotations

import json
import os
from typing import Dict, List

from benchmarks.common import COST, MODEL, THRESHOLD, class_stats
from repro.core import Variant, make_policy
from repro.core.faults import CRASH, FaultEvent, FaultInjector, FaultPlan
from repro.sim import ClusterSim, SimConfig
from repro.sim.workload import WorkloadConfig, lmsys_like_requests

BENCH_FAULTS_JSON = os.path.join(os.path.dirname(__file__), "..",
                                 "BENCH_faults.json")

N_ENGINES = 4
VICTIM = 1
N_SESSIONS = 8
DECODE_TOKENS = 6


# ------------------------------------------------------- engine failover
def _engine_failover() -> Dict:
    """Kill 1 of 4 real engines mid-drain and compare against an
    identical fault-free cluster."""
    import jax
    import numpy as np

    from repro.configs import get_smoke
    from repro.core import H200_QWEN32B
    from repro.core.routing import RoundRobinRouter
    from repro.models import transformer as tr
    from repro.serving import Engine, EngineConfig, ServeCluster
    from repro.serving.loop import ServeLoop

    cfg = get_smoke("qwen3-4b")
    params, _ = tr.init_params(cfg, jax.random.key(19))
    ecfg = EngineConfig(num_slots=8, max_len=160, chunk_tokens=16,
                        paged_kv=True, page_size=8)

    def build(faults):
        loops = []
        for _ in range(N_ENGINES):
            eng = Engine(cfg, params, ecfg)
            pol = make_policy(Variant("pla_full"), H200_QWEN32B,
                              threshold=24, chunk_tokens=16)
            loops.append(ServeLoop(eng, pol, slo_ttft=30.0))
        return ServeCluster(loops, RoundRobinRouter(), faults=faults)

    rng = np.random.default_rng(11)
    subs = [(s, rng.integers(0, cfg.vocab_size,
                             40 if s % 3 == 0 else int(rng.integers(5, 16))),
             DECODE_TOKENS)
            for s in range(N_SESSIONS)]

    baseline = build(None)
    for s, toks, d in subs:
        baseline.submit(s, toks, decode_tokens=d)
    baseline.run_until_idle(max_wall=300.0)
    want = {s: list(baseline.generated(s)) for s, _, _ in subs}

    plan = FaultPlan(events=(FaultEvent(CRASH, at=1.0, engine=VICTIM),))
    cluster = build(FaultInjector(plan))
    for s, toks, d in subs:
        cluster.submit(s, toks, decode_tokens=d)
    # let the victim reach its decode phase so the crash hits in-flight
    # sessions (not just queued requests) — the plan's crash fires on
    # the first run_until_idle tick
    for _ in range(600):
        if cluster.loops[VICTIM].active_decodes:
            break
        for lp in cluster.loops:
            if lp.has_work:
                lp.tick()
    assert cluster.loops[VICTIM].active_decodes, \
        "victim engine never reached its decode phase"
    cluster.run_until_idle(max_wall=300.0)

    rep = cluster.report()
    st = cluster.stats()
    bit_identical = all(cluster.generated(s) == want[s] for s, _, _ in subs)
    complete = all(len(cluster.generated(s)) == d + 1 for s, _, d in subs)
    return {
        "bench": "faults", "tag": "engine/failover", "mean_ms": 0.0,
        "n_submitted": N_SESSIONS,
        "n_finished": rep.n,
        "lost": N_SESSIONS - rep.n - rep.rejected - rep.abandoned,
        "crashes": st["crashes"],
        "recovered_sessions": st["recovered_sessions"],
        "rerouted_requests": st["rerouted_requests"],
        "abandoned": rep.abandoned,
        "bit_identical": int(bit_identical),
        "transcripts_complete": int(complete),
        "health": st["health"],
    }


# ----------------------------------------------------------- sim overload
def _admission_arm(admission: bool) -> Dict:
    wl = WorkloadConfig(slo_ttft=0.4)
    reqs = lmsys_like_requests(600, 150.0, wl, seed=23)
    horizon = reqs[-1].arrival

    def factory(i):
        return make_policy(Variant("pla_full"), MODEL, threshold=THRESHOLD)
    sim = ClusterSim(2, factory, COST,
                     SimConfig(router="least_loaded", mode="mix",
                               admission=admission))
    sim.add_requests(reqs)
    tracker = sim.run(horizon + 300)
    rep = tracker.report()
    s = class_stats(tracker, None, horizon)
    return {"bench": "faults",
            "tag": f"sim/admission_{'on' if admission else 'off'}",
            **s, "viol": rep.violation_rate, "rejected": rep.rejected,
            "abandoned": rep.abandoned}


def run(write: bool = True) -> List[Dict]:
    rows = [_engine_failover(),
            _admission_arm(False), _admission_arm(True)]
    off = next(r for r in rows if r["tag"] == "sim/admission_off")
    on = next(r for r in rows if r["tag"] == "sim/admission_on")
    rows.append({
        "bench": "faults", "tag": "sim/admission_gain", "mean_ms": 0.0,
        "viol_accept_everything": off["viol"],
        "viol_admission": on["viol"],
        "rejected": on["rejected"],
        "viol_cut": round(1.0 - on["viol"] / max(off["viol"], 1e-9), 3),
    })
    if write:
        with open(BENCH_FAULTS_JSON, "w") as f:
            json.dump(rows, f, indent=1)
    return rows


def _smoke() -> None:
    """CI smoke: the §11 acceptance criteria."""
    rows = run()
    for r in rows:
        print(r)
    by_tag = {r["tag"]: r for r in rows}
    eng = by_tag["engine/failover"]
    assert eng["crashes"] == 1, eng
    assert eng["lost"] == 0 and eng["abandoned"] == 0, eng
    assert eng["recovered_sessions"] > 0, eng
    assert eng["bit_identical"] == 1, eng
    assert eng["transcripts_complete"] == 1, eng
    on, off = by_tag["sim/admission_on"], by_tag["sim/admission_off"]
    assert on["rejected"] > 0 and off["rejected"] == 0, (on, off)
    assert on["viol"] < off["viol"], (on["viol"], off["viol"])
    print("fault-tolerance smoke OK")


if __name__ == "__main__":
    _smoke()
