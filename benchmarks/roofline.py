"""§Roofline — assemble the per-(arch × shape × mesh) three-term table
from the dry-run artifacts and identify the hillclimb candidates.

    compute term    = HLO_FLOPs / (chips × peak)        [per-chip cost_analysis]
    memory term     = HLO_bytes / (chips × HBM bw)
    collective term = collective_bytes / (chips × link bw)

Hardware: TPU v5e — 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link ICI.

Also home of the β_tail calibration hook: the cost model charges bucket
tail rows and decode-ladder pad rows a linear-only coefficient β_tail
(defaulting to β); :func:`fit_beta_tail` least-squares-fits it from
measured (tail_rows, step_seconds) samples on real hardware.
"""
from __future__ import annotations

import glob
import json
import os
from typing import Dict, List, Sequence, Tuple

from repro.sim.costmodel import CostModel
import dataclasses


def load_cells(report_dir: str = "reports/dryrun") -> List[Dict]:
    cells = []
    for path in sorted(glob.glob(os.path.join(report_dir, "*.json"))):
        with open(path) as f:
            cells.append(json.load(f))
    return cells


def table(cells: List[Dict]) -> List[Dict]:
    rows = []
    for c in cells:
        if c.get("skipped"):
            continue
        r = c["roofline"]
        dominant = c["bottleneck"]
        total = max(sum(r.values()), 1e-12)
        frac = r[dominant] / total
        rows.append({
            "bench": "roofline",
            "tag": f"{c['arch']}/{c['shape']}/{c['mesh']}",
            "compute_ms": round(r["compute_s"] * 1e3, 3),
            "memory_ms": round(r["memory_s"] * 1e3, 3),
            "collective_ms": round(r["collective_s"] * 1e3, 3),
            "bottleneck": dominant,
            "dominance": round(frac, 3),
            "roofline_fraction": round(c.get("roofline_fraction", 0.0), 4),
            "mem_gib": round(c["memory"]["peak_device_bytes"] / 2 ** 30, 2),
            "tpu_est_gib": round(
                c["memory"].get("tpu_estimate_bytes",
                                c["memory"]["peak_device_bytes"]) / 2 ** 30, 2),
            "mean_ms": round(sum(r.values()) * 1e3, 3),
        })
    return rows


def hillclimb_candidates(cells: List[Dict]) -> List[Dict]:
    """worst roofline fraction · most collective-bound · most
    paper-representative (decode = the short-prefill serving regime)."""
    live = [c for c in cells if not c.get("skipped")
            and c["mesh"] == "16x16"]

    def coll_frac(c):
        r = c["roofline"]
        return r["collective_s"] / max(sum(r.values()), 1e-12)

    def frac(c):
        return c.get("roofline_fraction", 0.0)

    worst = min(live, key=frac)
    most_coll = max(live, key=coll_frac)
    decode = [c for c in live if c["shape"] == "decode_32k"]
    rep = max(decode, key=lambda c: c["roofline"]["memory_s"])
    out = []
    for tag, c in (("worst-fraction", worst), ("most-collective", most_coll),
                   ("paper-representative", rep)):
        out.append({"bench": "hillclimb", "tag": tag,
                    "cell": f"{c['arch']}/{c['shape']}",
                    "roofline_fraction": round(frac(c), 4),
                    "coll_frac": round(coll_frac(c), 3), "mean_ms": 0.0})
    return out


def fit_beta_tail(samples: Sequence[Tuple[int, float]],
                  base: CostModel) -> CostModel:
    """Calibrate β_tail from measured steps (ROADMAP: 'calibrate β_tail
    against real tail-row cost on TPU').

    samples: (tail_rows, measured_step_seconds) pairs from steps whose
    ONLY varying term is the padding tail — e.g. the same packed batch
    dispatched into successive bucket rungs, or a fixed decode batch
    padded up the decode ladder.  Fits the slope of the measured-time
    residual (vs. ``base`` with a zero tail) over tail rows by least
    squares through the origin, and returns the re-parameterized model.
    Zero/negative fits clamp to 0.0 — a tail row can't cost less than
    nothing, and on hardware with free pad lanes it genuinely can cost
    ~nothing.
    """
    pts = sorted(samples)
    if len(pts) < 2:
        return base
    # the base work is identical across samples, so it cancels in the
    # deltas against the smallest-tail sample — the slope IS β_tail
    t0, s0 = pts[0]
    den = sum((t - t0) ** 2 for t, _ in pts[1:])
    if den == 0:
        return base        # one tail width only — no slope to fit
    num = sum((t - t0) * (s - s0) for t, s in pts[1:])
    return dataclasses.replace(base, beta_tail=max(num / den, 0.0))


def run() -> List[Dict]:
    cells = load_cells()
    if not cells:
        return [{"bench": "roofline", "tag": "missing",
                 "note": "run launch/dryrun first", "mean_ms": 0.0}]
    return table(cells) + hillclimb_candidates(cells)
