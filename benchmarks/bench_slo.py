"""Fig.7 — SLO violation rate (TTFT SLO = 0.4 s) under Poisson arrivals,
LMSys-like trace: PLA-Serve vs SGLang-PD (FCFS), SGLang-PD + router
(least-loaded), vanilla DP (round-robin); 1 and 8 instances.

Also the `cluster` scenario (BENCH_cluster.json, CI smoke): the §9
multi-engine spatial split — length-aware dual-queue routing + KV
handoff — against round-robin and least-loaded routers at matched
offered load, in the simulator AND on real 2-engine ServeClusters
(slot + paged arenas) proving `handoff_host_bytes == 0`.
"""
from __future__ import annotations

import json
import os
from typing import Dict, List

from benchmarks.common import (COST, MODEL, THRESHOLD, class_stats,
                               routed_sim, shared_sim)
from repro.core import Variant, make_policy
from repro.core.routing import (LeastLoadedRouter, LengthAwareRouter,
                                RoundRobinRouter)
from repro.core.scheduler import PoolPolicy
from repro.sim import ClusterSim, SimConfig
from repro.sim.workload import WorkloadConfig, lmsys_like_requests

N_REQ = 1500
BENCH_CLUSTER_JSON = os.path.join(os.path.dirname(__file__), "..",
                                  "BENCH_cluster.json")


def _run(system: str, n_inst: int, rate: float):
    wl = WorkloadConfig(slo_ttft=0.4)
    reqs = lmsys_like_requests(N_REQ, rate, wl, seed=13)
    horizon = reqs[-1].arrival
    if system == "pla":
        if n_inst == 1:
            sim = shared_sim("pla_full")
        else:
            sim = routed_sim("pla_full", n_inst, router="pool", control=True)
    elif system == "pd_fcfs":
        sim = shared_sim("vanilla") if n_inst == 1 else \
            routed_sim("vanilla", n_inst, router="round_robin")
    elif system == "pd_router":
        sim = shared_sim("vanilla") if n_inst == 1 else \
            routed_sim("vanilla", n_inst, router="least_loaded")
    else:  # vanilla_dp: decode co-resident, round-robin DP
        sim = shared_sim("vanilla", mode="mix") if n_inst == 1 else \
            routed_sim("vanilla", n_inst, router="round_robin", mode="mix")
    sim.add_requests(reqs)
    tracker = sim.run(horizon + 120)
    return class_stats(tracker, None, horizon)


def run() -> List[Dict]:
    rows = []
    for n_inst, rates in ((1, (10, 20, 30)), (8, (60, 120, 180))):
        for rate in rates:
            for system in ("pla", "pd_fcfs", "pd_router", "vanilla_dp"):
                s = _run(system, n_inst, rate)
                rows.append({"bench": "fig7",
                             "tag": f"{system}/i{n_inst}/λ{rate}", **s})
    return rows


# --------------------------------------------------------------- cluster
CLUSTER_N_INST = 4
CLUSTER_N_PREFILL = 2
CLUSTER_RATE = 80.0
CLUSTER_N_REQ = 800


def _cluster_arm(router_name: str, rate: float = CLUSTER_RATE,
                 n_req: int = CLUSTER_N_REQ) -> Dict:
    """One router policy over the SAME offered load (trace regenerated
    with the same seed — Request objects are mutated by a run)."""
    wl = WorkloadConfig(slo_ttft=0.4)
    reqs = lmsys_like_requests(n_req, rate, wl, seed=17)
    horizon = reqs[-1].arrival
    if router_name == "spatial":
        # §3.2 spatial split: CLUSTER_N_PREFILL dedicated long-prefill
        # engines, shorts AWD-batched on the rest; longs' decode phases
        # hand off to the short pool (priced device-to-device copy)
        def factory(i):
            pool = "long" if i < CLUSTER_N_PREFILL else "short"
            return PoolPolicy(MODEL, pool=pool, threshold=THRESHOLD)
        roles = ["prefill"] * CLUSTER_N_PREFILL + \
            ["decode"] * (CLUSTER_N_INST - CLUSTER_N_PREFILL)
        sim = ClusterSim(CLUSTER_N_INST, factory, COST,
                         SimConfig(mode="mix", decode_handoff=True),
                         router_obj=LengthAwareRouter(threshold=THRESHOLD),
                         roles=roles)
    else:
        # baselines: the same temporal-disaggregation engine on every
        # instance; only the ROUTER differs (fig7's DP / router arms)
        def factory(i):
            return make_policy(Variant("pla_full"), MODEL,
                               threshold=THRESHOLD)
        router = RoundRobinRouter() if router_name == "round_robin" \
            else LeastLoadedRouter()
        sim = ClusterSim(CLUSTER_N_INST, factory, COST,
                         SimConfig(mode="mix"), router_obj=router)
    sim.add_requests(reqs)
    tracker = sim.run(horizon + 300)
    s = class_stats(tracker, None, horizon)
    s["handoffs"] = sim.handoffs
    s["handoff_tokens"] = sim.handoff_tokens
    return s


def _engine_cluster(paged: bool) -> Dict:
    """Real 2-engine ServeCluster (prefill + decode roles) on the smoke
    model: longs prefill on engine 0, migrate via arena→arena handoff,
    decode on engine 1 — the counters prove no host bounce."""
    import jax
    import numpy as np

    from repro.configs import get_smoke
    from repro.core import H200_QWEN32B
    from repro.models import transformer as tr
    from repro.serving import Engine, EngineConfig, ServeCluster
    from repro.serving.loop import ServeLoop

    cfg = get_smoke("qwen3-4b")
    params, _ = tr.init_params(cfg, jax.random.key(7))
    ecfg = EngineConfig(num_slots=8, max_len=160, chunk_tokens=16,
                        paged_kv=paged, page_size=8)

    def mk(pool):
        eng = Engine(cfg, params, ecfg)
        pol = PoolPolicy(H200_QWEN32B, pool=pool, threshold=24,
                         chunk_tokens=16)
        return ServeLoop(eng, pol, slo_ttft=30.0)

    cluster = ServeCluster([mk("long"), mk("short")],
                           LengthAwareRouter(threshold=24),
                           roles=["prefill", "decode"])
    rng = np.random.default_rng(5)
    n_sessions = 6
    for s in range(n_sessions):
        n = 40 if s % 3 == 0 else int(rng.integers(4, 16))
        cluster.submit(s, rng.integers(0, cfg.vocab_size, n),
                       decode_tokens=4)
    cluster.run_until_idle(max_wall=300.0)
    rep = cluster.report()
    st = cluster.stats()
    return {
        "n": rep.n,
        "generated_ok": int(all(
            len(cluster.generated(s)) == 5 for s in range(n_sessions))),
        "migrated_sessions": st["migrated_sessions"],
        "handoff_sessions": st["handoff_sessions"],
        "handoff_tokens": st["handoff_tokens"],
        "handoff_host_bytes": st["handoff_host_bytes"],
        "router": st["router"],
    }


def cluster_scenario(write: bool = True) -> List[Dict]:
    """The BENCH_cluster.json rows: spatial dual-queue routing vs
    round-robin and least-loaded at matched offered load (fig7-style),
    plus the real-engine handoff proof on both arena families."""
    arms = {name: _cluster_arm(name)
            for name in ("round_robin", "least_loaded", "spatial")}
    rows = [{"bench": "cluster", "tag": f"sim/{name}", **s}
            for name, s in arms.items()]
    rows.append({
        "bench": "cluster", "tag": "sim/gain", "mean_ms": 0.0,
        "viol_round_robin": arms["round_robin"]["viol"],
        "viol_least_loaded": arms["least_loaded"]["viol"],
        "viol_spatial": arms["spatial"]["viol"],
        "viol_cut_vs_rr": round(
            1.0 - arms["spatial"]["viol"]
            / max(arms["round_robin"]["viol"], 1e-9), 3),
        "viol_cut_vs_ll": round(
            1.0 - arms["spatial"]["viol"]
            / max(arms["least_loaded"]["viol"], 1e-9), 3),
    })
    for paged in (False, True):
        tag = "engine/paged" if paged else "engine/slot"
        rows.append({"bench": "cluster", "tag": tag, "mean_ms": 0.0,
                     **_engine_cluster(paged)})
    if write:
        with open(BENCH_CLUSTER_JSON, "w") as f:
            json.dump(rows, f, indent=1)
    return rows


def _cluster_smoke() -> None:
    """CI smoke: the §9 acceptance criteria — at matched offered load
    the length-aware spatial router shows a STRICTLY lower SLO violation
    rate than round-robin and least-loaded, and every migrated session
    crossed engines without touching host memory."""
    rows = cluster_scenario()
    for r in rows:
        print(r)
    by_tag = {r["tag"]: r for r in rows}
    spatial = by_tag["sim/spatial"]
    assert spatial["viol"] < by_tag["sim/round_robin"]["viol"], \
        (spatial["viol"], by_tag["sim/round_robin"]["viol"])
    assert spatial["viol"] < by_tag["sim/least_loaded"]["viol"], \
        (spatial["viol"], by_tag["sim/least_loaded"]["viol"])
    assert spatial["handoffs"] > 0, spatial
    for tag in ("engine/slot", "engine/paged"):
        eng = by_tag[tag]
        assert eng["generated_ok"] == 1, eng
        assert eng["migrated_sessions"] >= 1, eng
        assert eng["handoff_sessions"] == eng["migrated_sessions"], eng
        assert eng["handoff_host_bytes"] == 0, eng
    print("cluster spatial-disaggregation smoke OK")


if __name__ == "__main__":
    import sys
    if "cluster" in sys.argv[1:]:
        _cluster_smoke()
    else:
        from benchmarks.common import emit
        emit(run(), "bench_slo")
