"""Fig.7 — SLO violation rate (TTFT SLO = 0.4 s) under Poisson arrivals,
LMSys-like trace: PLA-Serve vs SGLang-PD (FCFS), SGLang-PD + router
(least-loaded), vanilla DP (round-robin); 1 and 8 instances.
"""
from __future__ import annotations

from typing import Dict, List

from benchmarks.common import class_stats, routed_sim, shared_sim
from repro.sim.workload import WorkloadConfig, lmsys_like_requests

N_REQ = 1500


def _run(system: str, n_inst: int, rate: float):
    wl = WorkloadConfig(slo_ttft=0.4)
    reqs = lmsys_like_requests(N_REQ, rate, wl, seed=13)
    horizon = reqs[-1].arrival
    if system == "pla":
        if n_inst == 1:
            sim = shared_sim("pla_full")
        else:
            sim = routed_sim("pla_full", n_inst, router="pool", control=True)
    elif system == "pd_fcfs":
        sim = shared_sim("vanilla") if n_inst == 1 else \
            routed_sim("vanilla", n_inst, router="round_robin")
    elif system == "pd_router":
        sim = shared_sim("vanilla") if n_inst == 1 else \
            routed_sim("vanilla", n_inst, router="least_loaded")
    else:  # vanilla_dp: decode co-resident, round-robin DP
        sim = shared_sim("vanilla", mode="mix") if n_inst == 1 else \
            routed_sim("vanilla", n_inst, router="round_robin", mode="mix")
    sim.add_requests(reqs)
    tracker = sim.run(horizon + 120)
    return class_stats(tracker, None, horizon)


def run() -> List[Dict]:
    rows = []
    for n_inst, rates in ((1, (10, 20, 30)), (8, (60, 120, 180))):
        for rate in rates:
            for system in ("pla", "pd_fcfs", "pd_router", "vanilla_dp"):
                s = _run(system, n_inst, rate)
                rows.append({"bench": "fig7",
                             "tag": f"{system}/i{n_inst}/λ{rate}", **s})
    return rows
