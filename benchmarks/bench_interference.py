"""Fig.1 + Fig.3 — intra-prefill interference.

P90 TTFT of long (Fig.1) / short (Fig.3) requests under varying
concurrency of the other class, vanilla FCFS co-batching (the SGLang
behaviour the paper measures) vs isolated (dashed lines) vs LAPS
disaggregation.
"""
from __future__ import annotations

from typing import Dict, List

from benchmarks.common import class_stats, shared_sim
from repro.sim.workload import WorkloadConfig, closed_loop_clients

UNTIL = 30.0
# SGLang-like prefill admission: max_prefill_tokens ≈ 8k — one long fills
# a batch, shorts pack between them (the co-admission the paper studies)
BUDGET = 8192


def _run(variant: str, n_long: int, n_short: int, seed: int = 3):
    sim = shared_sim(variant, mem_budget_tokens=BUDGET)
    clients = []
    if n_long:
        clients += closed_loop_clients(n_long, WorkloadConfig(), seed,
                                       long_only=True)
    if n_short:
        clients += closed_loop_clients(n_short, WorkloadConfig(), seed + 1,
                                       short_only=True)
    sim.add_clients(clients)
    tracker = sim.run(UNTIL)
    return tracker


def run() -> List[Dict]:
    rows = []
    # Fig.1: long P90 vs rising short concurrency (fixed 4 long clients)
    for n_short in (0, 8, 16, 32, 64):
        for variant in ("vanilla", "pla_full"):
            tr = _run(variant, n_long=4, n_short=n_short)
            s = class_stats(tr, "long", UNTIL)
            rows.append({"bench": "fig1", "tag": f"{variant}/short{n_short}",
                         "class": "long", **s})
    # Fig.3: short P90 vs rising long concurrency (fixed 16 short clients)
    for n_long in (0, 2, 4, 8):
        for variant in ("vanilla", "pla_full"):
            tr = _run(variant, n_long=n_long, n_short=16)
            s = class_stats(tr, "short", UNTIL)
            rows.append({"bench": "fig3", "tag": f"{variant}/long{n_long}",
                         "class": "short", **s})
    return rows
