"""Fig.2 — LMSys-Chat-1M-like length distribution of the workload
generator: ~63% of first-turn prompts < 256 tokens, ~81% in later turns.

Plus the packed-vs-padded prefill comparison: the same mixed-length
batches run through the dense (L, B) bucket grid and the padding-free
packed token-bucket path on the real smoke engine, reporting useful vs.
padded tokens and compiled-shape counts.  The packed path's compile
cache grows with |token buckets|; the grid's with |L| × |B|.
"""
from __future__ import annotations

import time
from typing import Dict, List

from repro.sim.workload import length_stats, lmsys_like_requests

# the acceptance mix (7/23/61/12) plus heterogeneous follow-ups
MIXED_BATCHES = [[7, 23, 61, 12], [5, 40, 9], [16, 16, 30],
                 [61, 40], [3, 12, 7, 23]]


def _packed_vs_padded() -> List[Dict]:
    import jax
    import numpy as np

    from repro.configs import get_smoke
    from repro.models import transformer as tr
    from repro.serving import Engine, EngineConfig

    cfg = get_smoke("qwen3-4b")
    params, _ = tr.init_params(cfg, jax.random.key(0))
    rng = np.random.default_rng(0)

    # the grid arm measures the dense (L, B) baseline — pin the slot arena
    dense = Engine(cfg, params, EngineConfig(num_slots=8, max_len=128,
                                             paged_kv=False,
                                             grid_lengths=(8, 16, 32, 64),
                                             grid_depths=(1, 2, 4)))
    packed = Engine(cfg, params, EngineConfig(num_slots=8, max_len=128,
                                              packed=True,
                                              token_buckets=(64, 128, 256)))

    def run_path(eng: Engine, use_packed: bool) -> float:
        t0 = time.perf_counter()
        sess = 0
        for lens in MIXED_BATCHES:
            seqs = [rng.integers(0, cfg.vocab_size, l) for l in lens]
            ids = list(range(sess, sess + len(lens)))
            if use_packed:
                eng.prefill_packed(ids, seqs)
            else:
                bucket = eng.grid.nearest_graph(lens)
                eng.prefill_batch(ids, seqs,
                                  bucket.key if bucket else None)
            for i in ids:
                eng.close_session(i)
            sess += len(lens)
        return (time.perf_counter() - t0) * 1e3 / len(MIXED_BATCHES)

    ms_dense = run_path(dense, False)
    ms_packed = run_path(packed, True)
    ds, ps = dense.stats(), packed.stats()
    ratio = (ds["padded_tokens"] / ps["packed_padded_tokens"]
             if ps["packed_padded_tokens"] else float("inf"))
    return [
        {"bench": "packing", "tag": "grid",
         "useful_tokens": ds["useful_tokens"],
         "padded_tokens": ds["padded_tokens"],
         "efficiency": round(ds["padding_efficiency"], 3),
         "compiled_shapes": ds["captured_shapes"],
         "mean_ms": round(ms_dense, 2)},
        {"bench": "packing", "tag": "packed",
         "useful_tokens": ps["packed_useful_tokens"],
         "padded_tokens": ps["packed_padded_tokens"],
         "efficiency": round(ps["packed_padding_efficiency"], 3),
         "compiled_shapes": ps["packed_shapes"],
         "mean_ms": round(ms_packed, 2)},
        {"bench": "packing", "tag": "gain",
         "pad_reduction_x": round(ratio, 2),
         "mean_ms": 0.0},
    ]


def run() -> List[Dict]:
    reqs = lmsys_like_requests(8000, rate=100.0, seed=0)
    s = length_stats(reqs)
    rows = [{
        "bench": "fig2", "tag": "lengths",
        "first_lt256": round(s["first_lt256"], 3),
        "later_lt256": round(s["later_lt256"], 3),
        "first_median": s["first_median"],
        "later_median": s["later_median"],
        "paper_first": 0.63, "paper_later": 0.81,
        "mean_ms": 0.0,
    }]
    rows.extend(_packed_vs_padded())
    return rows
