"""Fig.2 — LMSys-Chat-1M-like length distribution of the workload
generator: ~63% of first-turn prompts < 256 tokens, ~81% in later turns.
"""
from __future__ import annotations

from typing import Dict, List

from repro.sim.workload import length_stats, lmsys_like_requests


def run() -> List[Dict]:
    reqs = lmsys_like_requests(8000, rate=100.0, seed=0)
    s = length_stats(reqs)
    return [{
        "bench": "fig2", "tag": "lengths",
        "first_lt256": round(s["first_lt256"], 3),
        "later_lt256": round(s["later_lt256"], 3),
        "first_median": s["first_median"],
        "later_median": s["later_median"],
        "paper_first": 0.63, "paper_later": 0.81,
        "mean_ms": 0.0,
    }]
