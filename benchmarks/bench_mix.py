"""Fig.8 — prefill throughput: PD disaggregation vs Mix-with-Decode,
1 and 2 instances, across concurrency.

Plus the continuous-batching scenario on the REAL smoke engine: steady
decode load + bursty short prefills, driven (a) as the unified mixed
tick (prefill segments + decode rows fused into one packed dispatch per
round) and (b) as the alternating prefill/decode loop.  Reports TTFT /
TPOT and dispatch counts, and writes BENCH_mixed.json so the perf
trajectory accumulates across PRs.
"""
from __future__ import annotations

import json
import os
import time
from typing import Dict, List

from benchmarks.common import shared_sim, routed_sim
from repro.sim.workload import WorkloadConfig, closed_loop_clients

UNTIL = 30.0
TICKS_PER_SIM_SECOND = 10          # one scheduler round ≈ 100 ms simulated
BENCH_JSON = os.path.join(os.path.dirname(__file__), "..",
                          "BENCH_mixed.json")
BENCH_DECODE_JSON = os.path.join(os.path.dirname(__file__), "..",
                                 "BENCH_decode.json")
BENCH_PREFILL_JSON = os.path.join(os.path.dirname(__file__), "..",
                                  "BENCH_prefill.json")
BENCH_WINDOW_JSON = os.path.join(os.path.dirname(__file__), "..",
                                 "BENCH_window.json")
BENCH_MULTITURN_JSON = os.path.join(os.path.dirname(__file__), "..",
                                    "BENCH_multiturn.json")
BENCH_PAGED_JSON = os.path.join(os.path.dirname(__file__), "..",
                                "BENCH_paged.json")


def _run(mode: str, n_inst: int, conc: int) -> float:
    if n_inst == 1:
        sim = shared_sim("pla_full", mode=mode)
    else:
        sim = routed_sim("pla_full", n_inst, router="pool", mode=mode)
    sim.add_clients(closed_loop_clients(conc, WorkloadConfig(), seed=8))
    sim.run(UNTIL)
    return sim.prefill_rps(UNTIL)


def _mixed_workload(cfg, seed: int = 4):
    """Steady decode load (4 sessions, 12 tokens each) + 8 rounds of
    bursty short prefills (0–3 requests of 4–20 tokens)."""
    import numpy as np
    rng = np.random.default_rng(seed)
    steady = [rng.integers(0, cfg.vocab_size, 24) for _ in range(4)]
    bursts = []
    for r in range(8):
        n = int(rng.integers(0, 4)) if r % 3 else 0   # bursty, with gaps
        bursts.append([rng.integers(0, cfg.vocab_size, int(rng.integers(4, 20)))
                       for _ in range(n)])
    return steady, bursts


def _drive(unified: bool, cfg, params, decode_budget: int = 12) -> Dict:
    """Run the mixed workload; returns dispatch/latency metrics.

    unified=True: every round is ONE engine.step_mixed (prefills +
    decode rows in one packed stream).  unified=False: the alternating
    loop — a packed prefill step, THEN a separate decode step."""
    import numpy as np

    from repro.serving import Engine, EngineConfig

    eng = Engine(cfg, params, EngineConfig(
        num_slots=16, max_len=64, packed=True, packed_max_seqs=8,
        token_buckets=(64, 128)))
    steady, bursts = _mixed_workload(cfg)
    # warm every shape both arms will hit (64/128 packed buckets, the
    # (4, 1) decode step) on throwaway sessions, so the timed region
    # measures steady-state dispatch latency, not compiles
    warm = [np.zeros(4, np.int32) for _ in range(4)]
    wf = eng.prefill_packed([90, 91, 92, 93], warm)
    eng.decode_batch([90, 91, 92, 93], [wf[s] for s in (90, 91, 92, 93)])
    for s in (90, 91, 92, 93):
        eng.close_session(s)
    firsts = eng.prefill_packed(list(range(4)), steady)
    st0 = eng.stats()
    d_base = _total_dispatches(st0)
    active = {s: decode_budget for s in range(4)}
    last = dict(firsts)
    ttfts, tpots, rounds = [], [], 0
    sess = 100
    queue = list(bursts)
    t0 = time.perf_counter()
    while active or queue:
        burst = queue.pop(0) if queue else []
        prefills = [(sess + i, toks) for i, toks in enumerate(burst)]
        sess += len(burst)
        decodes = [(s, last[s]) for s in active]
        r0 = time.perf_counter()
        if unified:
            res = eng.step_mixed(prefills, decodes)
            toks = res.tokens
            ttft = time.perf_counter() - r0
        else:
            toks = {}
            if prefills:
                toks.update(eng.prefill_packed([s for s, _ in prefills],
                                               [t for _, t in prefills]))
            # first tokens are ready after the prefill dispatch alone —
            # TTFT must not be charged for the separate decode step
            ttft = time.perf_counter() - r0
            if decodes:
                dec = eng.decode_batch([s for s, _ in decodes],
                                       [t for _, t in decodes])
                toks.update({s: d[0] for s, d in dec.items()})
        dt = time.perf_counter() - r0
        ttfts.extend([ttft] * len(prefills))
        for s, _ in prefills:          # burst requests don't decode:
            eng.close_session(s)       # recycle their arena slots
        for s in list(active):
            last[s] = toks[s]
            tpots.append(dt)
            active[s] -= 1
            if active[s] <= 0:
                del active[s]
        rounds += 1
    wall = time.perf_counter() - t0
    st = eng.stats()
    dispatches = _total_dispatches(st) - d_base
    sim_seconds = rounds / TICKS_PER_SIM_SECOND
    return {
        "dispatches": dispatches,
        "dispatches_per_sim_s": round(dispatches / sim_seconds, 2),
        "rounds": rounds,
        "decode_tokens_fused": st.get("decode_tokens_fused", 0),
        "ttft_ms": round(1e3 * sum(ttfts) / max(len(ttfts), 1), 2),
        "tpot_ms": round(1e3 * sum(tpots) / max(len(tpots), 1), 2),
        "wall_ms": round(1e3 * wall, 1),
        "compiled_shapes": st["packed_shapes"] + st["captured_shapes"]
        + st.get("decode_shapes", 0),
    }


def _total_dispatches(st: Dict) -> int:
    """Every executor's dispatches: packed + dense + the bucketed decode
    executor (decode-only steps land there since the arena path)."""
    return (st["packed_dispatches"] + st["dense_dispatches"]
            + st.get("decode_dispatches", 0))


def _continuous_batching() -> List[Dict]:
    import jax

    from repro.configs import get_smoke
    from repro.models import transformer as tr

    cfg = get_smoke("qwen3-4b")
    params, _ = tr.init_params(cfg, jax.random.key(0))
    uni = _drive(True, cfg, params)
    alt = _drive(False, cfg, params)
    rows = [
        {"bench": "mixed_cb", "tag": "unified", "mean_ms": uni["tpot_ms"],
         **uni},
        {"bench": "mixed_cb", "tag": "alternating", "mean_ms": alt["tpot_ms"],
         **alt},
        {"bench": "mixed_cb", "tag": "gain", "mean_ms": 0.0,
         "dispatch_reduction_x": round(alt["dispatches"]
                                       / max(uni["dispatches"], 1), 2),
         "fewer_dispatches_per_sim_s": alt["dispatches_per_sim_s"]
         - uni["dispatches_per_sim_s"]},
    ]
    with open(BENCH_JSON, "w") as f:
        json.dump(rows, f, indent=1)
    return rows


def _drive_decode_heavy(arena: bool, cfg, params, n_sessions: int = 6,
                        max_len: int = 64) -> Dict:
    """Decode-heavy scenario: N sessions drain staggered decode budgets
    (so the live session count passes through many distinct values)
    while occasional short prefill bursts arrive.

    arena=True: the new path — bursts fuse the decode backlog into ONE
    mixed packed dispatch and decode-only ticks run the arena-resident
    bucketed step.  arena=False: the dense-gather baseline — a separate
    (B, 1) decode dispatch every round, one compiled shape per live
    session count, whole arena slots gathered and scattered per tick."""
    import numpy as np

    from repro.serving import Engine, EngineConfig
    from repro.sim.costmodel import decode_hbm_bytes_per_token

    rng = np.random.default_rng(5)
    # slot-arena scenario by design (paged_kv pinned off): the bench
    # contrasts the bucketed SLOT decode path against the dense gather
    eng = Engine(cfg, params, EngineConfig(
        num_slots=16, max_len=max_len, packed=arena, arena_decode=arena,
        paged_kv=False, packed_max_seqs=8, token_buckets=(16, 32, 64),
        decode_buckets=(1, 2, 4, 8)))
    kv_row_bytes = (2 * cfg.num_layers * cfg.num_kv_heads * cfg.hdim
                    * np.dtype(cfg.np_dtype).itemsize)
    prompts = [rng.integers(0, cfg.vocab_size, int(rng.integers(6, 14)))
               for _ in range(n_sessions)]
    budgets = {s: 4 + 2 * s for s in range(n_sessions)}   # staggered drain

    last = {}
    for s in range(n_sessions):
        last.update(eng.prefill_batch([s], [prompts[s]]))
    base_decode = _decode_dispatches(eng, arena)
    active = dict(budgets)
    decode_tick_bytes, decode_tick_tokens = 0.0, 0
    counts_seen, rounds, burst_sess = set(), 0, 100
    t0 = time.perf_counter()
    while active:
        sessions = sorted(active)
        counts_seen.add(len(sessions))
        decodes = [(s, last[s]) for s in sessions]
        burst = [] if rounds % 4 != 1 else \
            [(burst_sess + i, rng.integers(0, cfg.vocab_size, 6))
             for i in range(2)]
        burst_sess += len(burst)
        if burst and arena:
            # unified tick: burst prefills + the whole decode backlog in
            # one packed dispatch — no separate decode step this round
            res = eng.step_mixed(burst, decodes)
            toks = res.tokens
        else:
            if burst:
                eng.prefill_batch([s for s, _ in burst],
                                  [t for _, t in burst])
            for s in sessions:   # decode-only tick: model the KV traffic
                decode_tick_bytes += decode_hbm_bytes_per_token(
                    eng.history(s), max_len, kv_row_bytes, arena=arena)
            decode_tick_tokens += len(sessions)
            dec = eng.decode_batch(sessions, [t for _, t in decodes])
            toks = {s: dec[s][0] for s in sessions}
        for s, _ in burst:
            eng.close_session(s)
        for s in sessions:
            last[s] = toks[s]
            active[s] -= 1
            if active[s] <= 0:
                del active[s]
        rounds += 1
    wall = time.perf_counter() - t0
    st = eng.stats()
    return {
        "decode_dispatches": _decode_dispatches(eng, arena) - base_decode,
        "decode_shapes": st["decode_shapes"] if arena else
        eng.executor.shapes_by_kind().get("decode", 0),
        "decode_ladder_len": len(eng.decode_executor.decode_buckets)
        if arena else None,
        "session_counts_seen": len(counts_seen),
        "hbm_bytes_per_decode_token": round(
            decode_tick_bytes / max(decode_tick_tokens, 1), 1),
        "rounds": rounds,
        "wall_ms": round(1e3 * wall, 1),
    }


def _decode_dispatches(eng, arena: bool) -> int:
    """Separate decode-step dispatches (fused rows ride a prefill
    dispatch and don't count — that's the continuous-batching saving)."""
    if arena and eng.decode_executor is not None:
        return eng.decode_executor.dispatches
    return (eng.executor.kind_hits.get("decode", 0)
            + eng.executor.kind_misses.get("decode", 0))


def decode_scenario(write: bool = True) -> List[Dict]:
    """The BENCH_decode.json rows: arena-resident bucketed decode vs the
    dense-gather baseline on the decode-heavy scenario."""
    import jax

    from repro.configs import get_smoke
    from repro.models import transformer as tr

    cfg = get_smoke("qwen3-4b")
    params, _ = tr.init_params(cfg, jax.random.key(0))
    new = _drive_decode_heavy(True, cfg, params)
    old = _drive_decode_heavy(False, cfg, params)
    rows = [
        {"bench": "decode_bucket", "tag": "arena", "mean_ms": 0.0, **new},
        {"bench": "decode_bucket", "tag": "dense", "mean_ms": 0.0, **old},
        {"bench": "decode_bucket", "tag": "gain", "mean_ms": 0.0,
         "dispatch_reduction": old["decode_dispatches"]
         - new["decode_dispatches"],
         "shape_reduction": old["decode_shapes"] - new["decode_shapes"],
         "hbm_reduction_x": round(
             old["hbm_bytes_per_decode_token"]
             / max(new["hbm_bytes_per_decode_token"], 1e-9), 2)},
    ]
    if write:
        with open(BENCH_DECODE_JSON, "w") as f:
            json.dump(rows, f, indent=1)
    return rows


def _drive_prefill_flood(arena: bool, cfg, params, rounds: int = 8,
                         max_len: int = 64) -> Dict:
    """Short-prefill flood (the paper's hot regime): every round packs
    2–3 fresh short requests plus one re-prefill of a persistent chat
    session into ONE packed tick, and a 40-token prompt advances through
    C_l = 16 chunk ticks — prefill, re-prefill, AND chunk work all on
    the packed stream.

    arena=True: the §6 path — KV reads/writes route through the slot
    map, zero whole-slot gather/scatter.  arena=False: the legacy
    gathered-cache baseline — every tick copies b_max whole (S_max,)
    arena slots out and scatters them back, O(b_max · S_max) HBM per
    step regardless of how few tokens the bucket holds."""
    import numpy as np

    from repro.serving import Engine, EngineConfig
    from repro.sim.costmodel import packed_hbm_bytes_per_step

    rng = np.random.default_rng(7)
    # slot-arena scenario by design (paged_kv pinned off): the bench
    # contrasts slot-map prefill against the whole-slot gather baseline
    eng = Engine(cfg, params, EngineConfig(
        num_slots=16, max_len=max_len, chunk_tokens=16, packed=True,
        arena_prefill=arena, paged_kv=False, packed_max_seqs=8,
        token_buckets=(32, 64)))
    px = eng.packed_executor
    kv_row_bytes = (2 * cfg.num_layers * cfg.num_kv_heads * cfg.hdim
                    * np.dtype(cfg.np_dtype).itemsize)
    hbm_bytes, steps = 0.0, 0

    def packed_tick(sessions, lists):
        """One packed dispatch, with its modeled KV traffic recorded
        BEFORE the histories advance."""
        nonlocal hbm_bytes, steps
        hists = [eng.history(s) for s in sessions]
        hbm_bytes += packed_hbm_bytes_per_step(
            [len(t) for t in lists], hists, max_len, px.max_seqs,
            kv_row_bytes, arena=arena)
        steps += 1
        return eng.prefill_packed(sessions, lists)

    # two persistent chat sessions seed re-prefill history
    for s in (0, 1):
        packed_tick([s], [rng.integers(0, cfg.vocab_size, 8)])
    t0 = time.perf_counter()
    burst_sess = 100
    for r in range(rounds):
        mix = [(0 if r % 2 else 1,
                rng.integers(0, cfg.vocab_size, 4))]     # re-prefill turn
        for i in range(2 + r % 2):                       # 2–3 fresh shorts
            mix.append((burst_sess + i,
                        rng.integers(0, cfg.vocab_size,
                                     int(rng.integers(4, 9)))))
        packed_tick([s for s, _ in mix], [t for _, t in mix])
        for s, _ in mix:
            if s >= 100:
                eng.close_session(s)                     # recycle slots
        burst_sess += len(mix)
    # one long prompt advanced in C_l chunks on the same stream
    long_toks = rng.integers(0, cfg.vocab_size, 40)
    for start in range(0, 40, 16):
        packed_tick([50], [long_toks[start:start + 16]])
    wall = time.perf_counter() - t0
    st = eng.stats()
    return {
        "packed_dispatches": st["packed_dispatches"],
        "dense_dispatches": st["dense_dispatches"],
        "arena_gathers": st["arena_gathers"],
        "arena_scatters": st["arena_scatters"],
        "hbm_bytes_per_step": round(hbm_bytes / max(steps, 1), 1),
        "steps": steps,
        "compiled_shapes": st["packed_shapes"] + st["captured_shapes"],
        "wall_ms": round(1e3 * wall, 1),
    }


def prefill_scenario(write: bool = True) -> List[Dict]:
    """The BENCH_prefill.json rows: arena-resident packed prefill (§6)
    vs the whole-slot gather/scatter baseline on a short-prefill
    flood."""
    import jax

    from repro.configs import get_smoke
    from repro.models import transformer as tr

    cfg = get_smoke("qwen3-4b")
    params, _ = tr.init_params(cfg, jax.random.key(0))
    new = _drive_prefill_flood(True, cfg, params)
    old = _drive_prefill_flood(False, cfg, params)
    rows = [
        {"bench": "prefill_arena", "tag": "arena", "mean_ms": 0.0, **new},
        {"bench": "prefill_arena", "tag": "gather", "mean_ms": 0.0, **old},
        {"bench": "prefill_arena", "tag": "gain", "mean_ms": 0.0,
         "hbm_reduction_x": round(
             old["hbm_bytes_per_step"]
             / max(new["hbm_bytes_per_step"], 1e-9), 2),
         "slot_copies_removed": old["arena_gathers"]
         + old["arena_scatters"]},
    ]
    if write:
        with open(BENCH_PREFILL_JSON, "w") as f:
            json.dump(rows, f, indent=1)
    return rows


def _drive_window(windowed: bool, cfg, params, n_sessions: int = 3,
                  max_len: int = 128) -> Dict:
    """Sliding-window scenario (DESIGN.md §7): chat sessions decode far
    past the window (cached_len ≫ window) with periodic short prefill
    bursts riding along.

    windowed=True: the rolling windowed arena — slots are window+margin
    deep, written modularly, the windowed slot-map kernels stream
    O(min(cached, window)) rows per token, zero whole-slot copies.
    windowed=False: the dense (L, B) baseline — full-depth slots, the
    window enforced by masking only, every tick gathering and
    scattering whole O(S_max) slots."""
    import numpy as np

    from repro.serving import Engine, EngineConfig
    from repro.sim.costmodel import decode_hbm_bytes_per_token

    rng = np.random.default_rng(11)
    # slot-arena scenario by design (paged_kv pinned off): the bench
    # contrasts rolling window-deep SLOTS against dense full-depth ones
    if windowed:
        ecfg = EngineConfig(num_slots=8, max_len=max_len, chunk_tokens=16,
                            paged_kv=False, packed_max_seqs=4,
                            token_buckets=(16, 32), decode_buckets=(1, 2, 4))
    else:
        ecfg = EngineConfig(num_slots=8, max_len=max_len, packed=False,
                            arena_decode=False, paged_kv=False)
    eng = Engine(cfg, params, ecfg)
    depth = eng.arena.arena[0]["k"].shape[2]   # actual slot depth
    kv_row_bytes = (2 * cfg.num_layers * cfg.num_kv_heads * cfg.hdim
                    * np.dtype(cfg.np_dtype).itemsize)
    # streamed rows per decode token: the rolling arena reads its valid
    # slot rows (≤ depth = window + margin); the dense path's masked
    # reads touch min(cached, window) rows of the whole-slot copy
    eff_window = depth if windowed else cfg.sliding_window

    prompts = [rng.integers(0, cfg.vocab_size, 8) for _ in range(n_sessions)]
    budgets = {s: 40 + 15 * s for s in range(n_sessions)}  # staggered drain
    last = {}
    for s in range(n_sessions):
        last.update(eng.prefill_batch([s], [prompts[s]]))
    active = dict(budgets)
    tick_bytes, tick_tokens, rounds, burst_sess = 0.0, 0, 0, 100
    t0 = time.perf_counter()
    while active:
        sessions = sorted(active)
        if rounds % 10 == 5:          # periodic short prefill burst
            burst = [(burst_sess, rng.integers(0, cfg.vocab_size, 6))]
            burst_sess += 1
            if windowed:
                eng.step_mixed(burst, [])
            else:
                eng.prefill_batch([s for s, _ in burst],
                                  [t for _, t in burst])
            for s, _ in burst:
                eng.close_session(s)
        for s in sessions:
            tick_bytes += decode_hbm_bytes_per_token(
                eng.history(s), max_len, kv_row_bytes, arena=windowed,
                window=eff_window)
        tick_tokens += len(sessions)
        dec = eng.decode_batch(sessions, [last[s] for s in sessions])
        for s in sessions:
            last[s] = dec[s][0]
            active[s] -= 1
            if active[s] <= 0:
                del active[s]
        rounds += 1
    wall = time.perf_counter() - t0
    st = eng.stats()
    max_cached = max(eng.history(s) for s in range(n_sessions))
    return {
        "window": cfg.sliding_window,
        "slot_depth": depth,
        "max_cached_len": max_cached,
        "hbm_bytes_per_decode_token": round(
            tick_bytes / max(tick_tokens, 1), 1),
        "arena_gathers": st["arena_gathers"],
        "arena_scatters": st["arena_scatters"],
        "decode_shapes": st.get("decode_shapes",
                                eng.executor.shapes_by_kind()
                                .get("decode", 0)),
        "compiled_shapes": st.get("packed_shapes", 0)
        + st["captured_shapes"] + st.get("decode_shapes", 0),
        "rounds": rounds,
        "wall_ms": round(1e3 * wall, 1),
    }


def window_scenario(write: bool = True) -> List[Dict]:
    """The BENCH_window.json rows: rolling windowed arena (§7) vs the
    dense full-depth baseline on long-decoding SWA sessions."""
    import jax

    from repro.configs import get_smoke
    from repro.models import transformer as tr

    cfg = get_smoke("mixtral-8x7b")            # sliding_window = 32
    params, _ = tr.init_params(cfg, jax.random.key(0))
    new = _drive_window(True, cfg, params)
    old = _drive_window(False, cfg, params)
    rows = [
        {"bench": "window_arena", "tag": "windowed", "mean_ms": 0.0, **new},
        {"bench": "window_arena", "tag": "dense", "mean_ms": 0.0, **old},
        {"bench": "window_arena", "tag": "gain", "mean_ms": 0.0,
         "hbm_reduction_x": round(
             old["hbm_bytes_per_decode_token"]
             / max(new["hbm_bytes_per_decode_token"], 1e-9), 2)},
    ]
    if write:
        with open(BENCH_WINDOW_JSON, "w") as f:
            json.dump(rows, f, indent=1)
    return rows


def _drive_multiturn(reuse: bool, cfg, params, page_size: int = 16,
                     max_len: int = 256) -> Dict:
    """Multi-turn chat on the PAGED engine (DESIGN.md §8): stateless
    API-style turns — every turn submits the FULL conversation under a
    fresh session id and closes it afterwards, so only the radix prefix
    index can carry KV across turns.

    reuse=True: the radix index maps each turn's matched prefix onto
    the pages the previous turn committed — the step prefills (and the
    model bills) only the new suffix plus the partial boundary page.
    reuse=False: the same paged kernels with the prefix cache off —
    every turn re-prefills its whole conversation."""
    import numpy as np

    from repro.data.synthetic import MultiTurnConfig, gen_multiturn_sessions
    from repro.serving import Engine, EngineConfig
    from repro.sim.costmodel import packed_hbm_bytes_per_step

    eng = Engine(cfg, params, EngineConfig(
        num_slots=16, max_len=max_len, chunk_tokens=64, packed=True,
        packed_max_seqs=8, token_buckets=(64, 256), paged_kv=True,
        page_size=page_size, prefix_cache=reuse))
    trace = gen_multiturn_sessions(MultiTurnConfig(
        vocab_size=cfg.vocab_size, num_sessions=6, system_len=48,
        suffix_lo=8, suffix_hi=32, max_turns=4, seed=11))
    kv_row_bytes = (2 * cfg.num_layers * cfg.num_kv_heads * cfg.hdim
                    * np.dtype(cfg.np_dtype).itemsize)
    prompt_tokens = prefilled = 0
    hbm_bytes = 0.0
    late_overpay = []      # turn ≥ 2: prefilled − suffix (page remainder)
    sid = 1000
    t0 = time.perf_counter()
    for u in trace:
        hit0 = eng.stats()["prefix_hit_tokens"]
        eng.open_session(sid)
        eng.step_mixed([(sid, u.tokens)], [])
        matched = eng.stats()["prefix_hit_tokens"] - hit0
        new = len(u.tokens) - matched
        prompt_tokens += len(u.tokens)
        prefilled += new
        # the §8 step streams matched pages + prefills the suffix: the
        # same O(history + new) traffic the arena model already prices
        hbm_bytes += packed_hbm_bytes_per_step(
            [new], [matched], max_len, 1, kv_row_bytes, arena=True)
        if u.turn >= 1:
            late_overpay.append(new - u.suffix)
        eng.close_session(sid)
        sid += 1
    wall = time.perf_counter() - t0
    st = eng.stats()
    return {
        "turns": len(trace),
        "prompt_tokens": prompt_tokens,
        "prefilled_tokens": prefilled,
        "prefix_hit_rate": round(st["prefix_hit_tokens"]
                                 / max(prompt_tokens, 1), 3),
        "max_turn_overpay": max(late_overpay) if late_overpay else 0,
        "page_size": page_size,
        "hbm_bytes_total": round(hbm_bytes, 1),
        "pages_evicted": st["pages_evicted"],
        "arena_gathers": st["arena_gathers"],
        "arena_scatters": st["arena_scatters"],
        "packed_dispatches": st["packed_dispatches"],
        "dense_dispatches": st["dense_dispatches"],
        "wall_ms": round(1e3 * wall, 1),
    }


def multiturn_scenario(write: bool = True) -> List[Dict]:
    """The BENCH_multiturn.json rows: radix prefix reuse on the paged
    arena vs the same paged engine re-prefilling every turn."""
    import jax

    from repro.configs import get_smoke
    from repro.models import transformer as tr

    cfg = get_smoke("qwen3-4b")
    params, _ = tr.init_params(cfg, jax.random.key(0))
    new = _drive_multiturn(True, cfg, params)
    old = _drive_multiturn(False, cfg, params)
    rows = [
        {"bench": "multiturn_paged", "tag": "reuse", "mean_ms": 0.0, **new},
        {"bench": "multiturn_paged", "tag": "noreuse", "mean_ms": 0.0,
         **old},
        {"bench": "multiturn_paged", "tag": "gain", "mean_ms": 0.0,
         "prefill_reduction_x": round(
             old["prefilled_tokens"] / max(new["prefilled_tokens"], 1), 2),
         "hbm_reduction_x": round(
             old["hbm_bytes_total"]
             / max(new["hbm_bytes_total"], 1e-9), 2)},
    ]
    if write:
        with open(BENCH_MULTITURN_JSON, "w") as f:
            json.dump(rows, f, indent=1)
    return rows


def _paged_loop(cfg, params, host_pool_bytes: int = 0):
    """Default-config PAGED serve loop (chunked long path + radix index
    + wait-for-fill) for the §12 scenarios."""
    from repro.core import H200_QWEN32B, Variant, make_policy
    from repro.serving import Engine, EngineConfig
    from repro.serving.loop import ServeLoop

    eng = Engine(cfg, params, EngineConfig(
        num_slots=6, max_len=128, page_size=8, chunk_tokens=16,
        token_buckets=(16, 32), decode_buckets=(1, 2, 4),
        host_pool_bytes=host_pool_bytes))
    pol = make_policy(Variant("pla_full"), H200_QWEN32B, threshold=32,
                      chunk_tokens=16)
    return eng, ServeLoop(eng, pol, slo_ttft=30.0)


def _drive_paged_chunk(chunk_matching: bool, cfg, params) -> Dict:
    """Long-prompt multi-turn trace for chunk-level matching (§12).

    Round 1: two long prompts share a 48-token prefix with different
    tails, submitted TOGETHER — the second is cold at submit (the first
    has not dispatched a single chunk yet), so only chunk-boundary
    re-probes can adopt the shared pages the first request indexes
    mid-trace.  Round 2: each conversation returns with 16 more tokens
    under a fresh session (stateless API style) — those hit at submit
    in both arms.  chunk_matching=False is the old submit-only probe."""
    import numpy as np

    rng = np.random.default_rng(17)
    shared = rng.integers(0, cfg.vocab_size, 48)
    tails = [rng.integers(0, cfg.vocab_size, 16) for _ in range(2)]
    eng, loop = _paged_loop(cfg, params)
    loop.chunk_matching = chunk_matching
    t0 = time.perf_counter()
    for s in range(2):
        loop.submit(s, np.concatenate([shared, tails[s]]), decode_tokens=1)
    loop.run_until_idle(max_wall=120.0)
    for s in range(2):
        loop.close_session(s)
    for s in range(2):                       # round 2: the turn comes back
        turn2 = np.concatenate([shared, tails[s],
                                rng.integers(0, cfg.vocab_size, 16)])
        loop.submit(10 + s, turn2, decode_tokens=1)
        loop.run_until_idle(max_wall=120.0)
    wall = time.perf_counter() - t0
    st = eng.stats()
    return {
        "prefix_hit_tokens": st["prefix_hit_tokens"],
        "chunk_hit_tokens": st["chunk_hit_tokens"],
        "coalesced_prefills": loop.coalesced_prefills,
        "arena_gathers": st["arena_gathers"],
        "arena_scatters": st["arena_scatters"],
        "wall_ms": round(1e3 * wall, 1),
    }


def _drive_paged_spill(spill: bool, cfg, params, n_convos: int = 5) -> Dict:
    """Eviction-pressure trace at a MATCHED device pool size: stateless
    turns over more distinct conversations than the device pool holds,
    then every conversation returns.  spill=True demotes evicted prefix
    pages to the host pool and promotes them back on the return hit;
    spill=False is drop-on-evict — the return turns re-prefill."""
    import numpy as np

    from repro.serving import Engine, EngineConfig

    rng = np.random.default_rng(23)
    # num_pages pinned BELOW the trace's working set (5 convos × 3
    # pages) so LRU eviction actually fires — the matched pool size both
    # arms share
    eng = Engine(cfg, params, EngineConfig(
        num_slots=2, max_len=64, num_pages=8, page_size=8, chunk_tokens=16,
        token_buckets=(16, 32), decode_buckets=(1, 2),
        host_pool_bytes=(64 << 20) if spill else 0))
    prompts = [rng.integers(0, cfg.vocab_size, 24) for _ in range(n_convos)]
    sid, prompt_tokens = 100, 0
    t0 = time.perf_counter()
    for _ in range(2):                       # round 2 = the returns
        for p in prompts:
            eng.open_session(sid)
            matched = eng.adopt_prefix(sid, p)
            eng.step_mixed([(sid, p[matched:])], [])
            eng.close_session(sid)
            prompt_tokens += len(p)
            sid += 1
    wall = time.perf_counter() - t0
    st = eng.stats()
    return {
        "hit_rate": round(st["prefix_hit_tokens"] / prompt_tokens, 3),
        "prefix_hit_tokens": st["prefix_hit_tokens"],
        "pages_evicted": st["pages_evicted"],
        "pages_spilled": st["pages_spilled"],
        "pages_promoted": st["pages_promoted"],
        "wall_ms": round(1e3 * wall, 1),
    }


def _drive_paged_coalesce(cfg, params, n: int = 5) -> Dict:
    """Cold-miss coalescing: N identical COLD submits arrive together;
    the wait-for-fill table parks N−1 behind the first filler, so the
    shared full-page prefix is prefilled exactly once."""
    import numpy as np

    rng = np.random.default_rng(29)
    eng, loop = _paged_loop(cfg, params)
    prompt = rng.integers(0, cfg.vocab_size, 24)
    t0 = time.perf_counter()
    for s in range(n):
        loop.submit(s, prompt, decode_tokens=1)
    loop.run_until_idle(max_wall=120.0)
    wall = time.perf_counter() - t0
    st = eng.stats()
    shared = (len(prompt) - 1) // 8 * 8      # the full-page prefix
    return {
        "submits": n,
        "coalesced_prefills": loop.coalesced_prefills,
        "prefix_hit_tokens": st["prefix_hit_tokens"],
        "shared_prefix_tokens": shared,
        # prefill rows actually written for the flood (decode rows that
        # fused into packed steps are not prefill work)
        "prefilled_tokens": st["packed_useful_tokens"]
        - st["decode_tokens_fused"],
        "wall_ms": round(1e3 * wall, 1),
    }


def paged_scenario(write: bool = True) -> List[Dict]:
    """The BENCH_paged.json rows (§12): chunk-level matching vs the
    submit-only probe, host spill tier vs drop-on-evict at a matched
    pool size, and the coalesced cold flood."""
    import jax

    from repro.configs import get_smoke
    from repro.models import transformer as tr

    cfg = get_smoke("qwen3-4b")
    params, _ = tr.init_params(cfg, jax.random.key(0))
    chunk = _drive_paged_chunk(True, cfg, params)
    submit_only = _drive_paged_chunk(False, cfg, params)
    spill = _drive_paged_spill(True, cfg, params)
    drop = _drive_paged_spill(False, cfg, params)
    coal = _drive_paged_coalesce(cfg, params)
    rows = [
        {"bench": "paged_default", "tag": "chunk_matching", "mean_ms": 0.0,
         **chunk},
        {"bench": "paged_default", "tag": "submit_only", "mean_ms": 0.0,
         **submit_only},
        {"bench": "paged_default", "tag": "spill", "mean_ms": 0.0, **spill},
        {"bench": "paged_default", "tag": "drop_on_evict", "mean_ms": 0.0,
         **drop},
        {"bench": "paged_default", "tag": "coalesce", "mean_ms": 0.0,
         **coal},
        {"bench": "paged_default", "tag": "gain", "mean_ms": 0.0,
         "chunk_extra_hit_tokens": chunk["prefix_hit_tokens"]
         - submit_only["prefix_hit_tokens"],
         "spill_hit_rate_gain": round(spill["hit_rate"] - drop["hit_rate"],
                                      3)},
    ]
    if write:
        with open(BENCH_PAGED_JSON, "w") as f:
            json.dump(rows, f, indent=1)
    return rows


def run() -> List[Dict]:
    rows = []
    for n_inst in (1, 2):
        for conc in (8, 32, 64):
            pd = _run("pd", n_inst, conc)
            mix = _run("mix", n_inst, conc)
            rows.append({"bench": "fig8", "tag": f"i{n_inst}/c{conc}",
                         "pd_rps": round(pd, 2), "mix_rps": round(mix, 2),
                         "mix_over_pd": round(mix / pd, 3) if pd else 0.0,
                         "mean_ms": 0.0})
    rows.extend(_continuous_batching())
    rows.extend(decode_scenario())
    rows.extend(prefill_scenario())
    rows.extend(window_scenario())
    rows.extend(multiturn_scenario())
    rows.extend(paged_scenario())
    return rows


def _prefill_smoke() -> None:
    """CI smoke: the short-prefill-flood acceptance criteria — zero
    whole-slot gather/scatter on the arena arm, identical dispatch
    schedule, and ≥ 5× lower modeled HBM bytes/step than the gathered
    baseline."""
    rows = prefill_scenario()
    for r in rows:
        print(r)
    new, old, gain = rows
    assert new["arena_gathers"] == 0 and new["arena_scatters"] == 0, new
    assert old["arena_gathers"] > 0 and old["arena_scatters"] > 0, old
    assert new["packed_dispatches"] == old["packed_dispatches"], (new, old)
    assert new["dense_dispatches"] == 0, new
    assert gain["hbm_reduction_x"] >= 5.0, gain
    print("packed-arena prefill smoke OK")


def _decode_smoke() -> None:
    """CI smoke: decode-heavy scenario — fewer decode dispatches, a
    compile cache bounded by the decode ladder, strictly lower modeled
    HBM bytes/token than the dense-gather baseline."""
    rows = decode_scenario()
    for r in rows:
        print(r)
    new, old = rows[0], rows[1]
    assert new["decode_dispatches"] < old["decode_dispatches"], \
        (new["decode_dispatches"], old["decode_dispatches"])
    assert new["decode_shapes"] <= new["decode_ladder_len"], rows[0]
    assert new["hbm_bytes_per_decode_token"] < \
        old["hbm_bytes_per_decode_token"], (new, old)
    print("decode-bucket smoke OK")


def _multiturn_smoke() -> None:
    """CI smoke: the §8 multi-turn acceptance criteria — every turn ≥ 2
    prefill collapses to the new-suffix cost (plus at most one partial
    page), prefix hit rate above one half, strictly fewer prefilled
    tokens and lower modeled HBM bytes than reuse-off, and zero
    whole-slot gather/scatter on the paged path."""
    rows = multiturn_scenario()
    for r in rows:
        print(r)
    new, old, gain = rows
    assert new["prefix_hit_rate"] > 0.5, new
    assert old["prefix_hit_rate"] == 0.0, old
    assert new["prefilled_tokens"] < old["prefilled_tokens"], (new, old)
    assert new["hbm_bytes_total"] < old["hbm_bytes_total"], (new, old)
    # turn ≥ 2 pays suffix + at most the partial boundary page
    assert new["max_turn_overpay"] <= new["page_size"] - 1, new
    assert new["arena_gathers"] == 0 and new["arena_scatters"] == 0, new
    assert old["arena_gathers"] == 0 and old["arena_scatters"] == 0, old
    assert new["dense_dispatches"] == 0, new
    print("multiturn-paged smoke OK")


def _window_smoke() -> None:
    """CI smoke: the sliding-window acceptance criteria — the rolling
    windowed arena keeps gather/scatter at zero, bounds its decode
    compile cache by the ladder, and models ≥2× lower HBM bytes/token
    than the dense full-depth path at cached_len ≫ window."""
    rows = window_scenario()
    for r in rows:
        print(r)
    new, old, gain = rows
    assert new["max_cached_len"] > 2 * new["window"], new
    assert new["arena_gathers"] == 0 and new["arena_scatters"] == 0, new
    assert old["arena_gathers"] > 0 and old["arena_scatters"] > 0, old
    assert new["slot_depth"] < old["slot_depth"], (new, old)
    assert gain["hbm_reduction_x"] >= 2.0, gain
    print("windowed-arena smoke OK")


def _paged_smoke() -> None:
    """CI smoke: the §12 paged-by-default acceptance criteria —
    chunk-level matching strictly increases prefix hits over the
    submit-only probe on the long-prompt trace, the spill tier strictly
    beats drop-on-evict hit rate at the same device pool size, and a
    coalesced cold flood prefills the shared prefix exactly once."""
    rows = paged_scenario()
    for r in rows:
        print(r)
    chunk, submit_only, spill, drop, coal, gain = rows
    assert chunk["prefix_hit_tokens"] > submit_only["prefix_hit_tokens"], \
        (chunk, submit_only)
    assert chunk["chunk_hit_tokens"] > 0, chunk
    assert chunk["arena_gathers"] == 0 and chunk["arena_scatters"] == 0, \
        chunk
    assert spill["hit_rate"] > drop["hit_rate"], (spill, drop)
    assert spill["pages_spilled"] > 0 and spill["pages_promoted"] > 0, spill
    assert drop["pages_spilled"] == 0 and drop["pages_promoted"] == 0, drop
    assert coal["coalesced_prefills"] == coal["submits"] - 1, coal
    # every waiter adopted the filler's pages: the shared prefix was
    # prefilled once, each of the N−1 waiters inherited it page-for-page
    assert coal["prefix_hit_tokens"] == \
        (coal["submits"] - 1) * coal["shared_prefix_tokens"], coal
    assert coal["prefilled_tokens"] == \
        coal["submits"] * 24 - coal["prefix_hit_tokens"], coal
    print("paged-default smoke OK")


if __name__ == "__main__":
    # CI smoke entries (invoke with PYTHONPATH=src:.): `prefill` runs
    # the short-prefill-flood scenario, `window` the sliding-window
    # scenario, `paged` the §12 paged-by-default one, anything else the
    # decode-heavy one — each asserting its acceptance criteria
    import sys
    if "prefill" in sys.argv[1:]:
        _prefill_smoke()
    elif "window" in sys.argv[1:]:
        _window_smoke()
    elif "multiturn" in sys.argv[1:]:
        _multiturn_smoke()
    elif "paged" in sys.argv[1:]:
        _paged_smoke()
    else:
        _decode_smoke()
