"""Fig.8 — prefill throughput: PD disaggregation vs Mix-with-Decode,
1 and 2 instances, across concurrency."""
from __future__ import annotations

from typing import Dict, List

from benchmarks.common import shared_sim, routed_sim
from repro.sim.workload import WorkloadConfig, closed_loop_clients

UNTIL = 30.0


def _run(mode: str, n_inst: int, conc: int) -> float:
    if n_inst == 1:
        sim = shared_sim("pla_full", mode=mode)
    else:
        sim = routed_sim("pla_full", n_inst, router="pool", mode=mode)
    sim.add_clients(closed_loop_clients(conc, WorkloadConfig(), seed=8))
    sim.run(UNTIL)
    return sim.prefill_rps(UNTIL)


def run() -> List[Dict]:
    rows = []
    for n_inst in (1, 2):
        for conc in (8, 32, 64):
            pd = _run("pd", n_inst, conc)
            mix = _run("mix", n_inst, conc)
            rows.append({"bench": "fig8", "tag": f"i{n_inst}/c{conc}",
                         "pd_rps": round(pd, 2), "mix_rps": round(mix, 2),
                         "mix_over_pd": round(mix / pd, 3) if pd else 0.0,
                         "mean_ms": 0.0})
    return rows
