"""Fig.8 — prefill throughput: PD disaggregation vs Mix-with-Decode,
1 and 2 instances, across concurrency.

Plus the continuous-batching scenario on the REAL smoke engine: steady
decode load + bursty short prefills, driven (a) as the unified mixed
tick (prefill segments + decode rows fused into one packed dispatch per
round) and (b) as the alternating prefill/decode loop.  Reports TTFT /
TPOT and dispatch counts, and writes BENCH_mixed.json so the perf
trajectory accumulates across PRs.
"""
from __future__ import annotations

import json
import os
import time
from typing import Dict, List

from benchmarks.common import shared_sim, routed_sim
from repro.sim.workload import WorkloadConfig, closed_loop_clients

UNTIL = 30.0
TICKS_PER_SIM_SECOND = 10          # one scheduler round ≈ 100 ms simulated
BENCH_JSON = os.path.join(os.path.dirname(__file__), "..",
                          "BENCH_mixed.json")


def _run(mode: str, n_inst: int, conc: int) -> float:
    if n_inst == 1:
        sim = shared_sim("pla_full", mode=mode)
    else:
        sim = routed_sim("pla_full", n_inst, router="pool", mode=mode)
    sim.add_clients(closed_loop_clients(conc, WorkloadConfig(), seed=8))
    sim.run(UNTIL)
    return sim.prefill_rps(UNTIL)


def _mixed_workload(cfg, seed: int = 4):
    """Steady decode load (4 sessions, 12 tokens each) + 8 rounds of
    bursty short prefills (0–3 requests of 4–20 tokens)."""
    import numpy as np
    rng = np.random.default_rng(seed)
    steady = [rng.integers(0, cfg.vocab_size, 24) for _ in range(4)]
    bursts = []
    for r in range(8):
        n = int(rng.integers(0, 4)) if r % 3 else 0   # bursty, with gaps
        bursts.append([rng.integers(0, cfg.vocab_size, int(rng.integers(4, 20)))
                       for _ in range(n)])
    return steady, bursts


def _drive(unified: bool, cfg, params, decode_budget: int = 12) -> Dict:
    """Run the mixed workload; returns dispatch/latency metrics.

    unified=True: every round is ONE engine.step_mixed (prefills +
    decode rows in one packed stream).  unified=False: the alternating
    loop — a packed prefill step, THEN a separate decode step."""
    import numpy as np

    from repro.serving import Engine, EngineConfig

    eng = Engine(cfg, params, EngineConfig(
        num_slots=16, max_len=64, packed=True, packed_max_seqs=8,
        token_buckets=(64, 128)))
    steady, bursts = _mixed_workload(cfg)
    # warm every shape both arms will hit (64/128 packed buckets, the
    # (4, 1) decode step) on throwaway sessions, so the timed region
    # measures steady-state dispatch latency, not compiles
    warm = [np.zeros(4, np.int32) for _ in range(4)]
    wf = eng.prefill_packed([90, 91, 92, 93], warm)
    eng.decode_batch([90, 91, 92, 93], [wf[s] for s in (90, 91, 92, 93)])
    for s in (90, 91, 92, 93):
        eng.close_session(s)
    firsts = eng.prefill_packed(list(range(4)), steady)
    st0 = eng.stats()
    d_base = st0["packed_dispatches"] + st0["dense_dispatches"]
    active = {s: decode_budget for s in range(4)}
    last = dict(firsts)
    ttfts, tpots, rounds = [], [], 0
    sess = 100
    queue = list(bursts)
    t0 = time.perf_counter()
    while active or queue:
        burst = queue.pop(0) if queue else []
        prefills = [(sess + i, toks) for i, toks in enumerate(burst)]
        sess += len(burst)
        decodes = [(s, last[s]) for s in active]
        r0 = time.perf_counter()
        if unified:
            res = eng.step_mixed(prefills, decodes)
            toks = res.tokens
            ttft = time.perf_counter() - r0
        else:
            toks = {}
            if prefills:
                toks.update(eng.prefill_packed([s for s, _ in prefills],
                                               [t for _, t in prefills]))
            # first tokens are ready after the prefill dispatch alone —
            # TTFT must not be charged for the separate decode step
            ttft = time.perf_counter() - r0
            if decodes:
                dec = eng.decode_batch([s for s, _ in decodes],
                                       [t for _, t in decodes])
                toks.update({s: d[0] for s, d in dec.items()})
        dt = time.perf_counter() - r0
        ttfts.extend([ttft] * len(prefills))
        for s, _ in prefills:          # burst requests don't decode:
            eng.close_session(s)       # recycle their arena slots
        for s in list(active):
            last[s] = toks[s]
            tpots.append(dt)
            active[s] -= 1
            if active[s] <= 0:
                del active[s]
        rounds += 1
    wall = time.perf_counter() - t0
    st = eng.stats()
    dispatches = st["packed_dispatches"] + st["dense_dispatches"] - d_base
    sim_seconds = rounds / TICKS_PER_SIM_SECOND
    return {
        "dispatches": dispatches,
        "dispatches_per_sim_s": round(dispatches / sim_seconds, 2),
        "rounds": rounds,
        "decode_tokens_fused": st.get("decode_tokens_fused", 0),
        "ttft_ms": round(1e3 * sum(ttfts) / max(len(ttfts), 1), 2),
        "tpot_ms": round(1e3 * sum(tpots) / max(len(tpots), 1), 2),
        "wall_ms": round(1e3 * wall, 1),
        "compiled_shapes": st["packed_shapes"] + st["captured_shapes"],
    }


def _continuous_batching() -> List[Dict]:
    import jax

    from repro.configs import get_smoke
    from repro.models import transformer as tr

    cfg = get_smoke("qwen3-4b")
    params, _ = tr.init_params(cfg, jax.random.key(0))
    uni = _drive(True, cfg, params)
    alt = _drive(False, cfg, params)
    rows = [
        {"bench": "mixed_cb", "tag": "unified", "mean_ms": uni["tpot_ms"],
         **uni},
        {"bench": "mixed_cb", "tag": "alternating", "mean_ms": alt["tpot_ms"],
         **alt},
        {"bench": "mixed_cb", "tag": "gain", "mean_ms": 0.0,
         "dispatch_reduction_x": round(alt["dispatches"]
                                       / max(uni["dispatches"], 1), 2),
         "fewer_dispatches_per_sim_s": alt["dispatches_per_sim_s"]
         - uni["dispatches_per_sim_s"]},
    ]
    with open(BENCH_JSON, "w") as f:
        json.dump(rows, f, indent=1)
    return rows


def run() -> List[Dict]:
    rows = []
    for n_inst in (1, 2):
        for conc in (8, 32, 64):
            pd = _run("pd", n_inst, conc)
            mix = _run("mix", n_inst, conc)
            rows.append({"bench": "fig8", "tag": f"i{n_inst}/c{conc}",
                         "pd_rps": round(pd, 2), "mix_rps": round(mix, 2),
                         "mix_over_pd": round(mix / pd, 3) if pd else 0.0,
                         "mean_ms": 0.0})
    rows.extend(_continuous_batching())
    return rows
