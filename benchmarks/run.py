"""Benchmark harness — one module per paper table/figure.

Prints the ``name,us_per_call,derived`` CSV contract per row, plus a
readable table per bench.  ``--only fig7`` runs a single bench.
"""
from __future__ import annotations

import argparse
import importlib
import json
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

BENCHES = [
    ("fig1+fig3", "benchmarks.bench_interference"),
    ("fig2", "benchmarks.bench_lengths"),
    ("fig5", "benchmarks.bench_window"),
    ("fig6", "benchmarks.bench_endtoend"),
    ("fig7", "benchmarks.bench_slo"),
    ("fig8", "benchmarks.bench_mix"),
    ("table2", "benchmarks.bench_offline"),
    ("graphs", "benchmarks.bench_graphs"),
    ("kernels", "benchmarks.bench_kernels"),
    ("roofline", "benchmarks.roofline"),
]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None)
    ap.add_argument("--out", default="reports/bench")
    args = ap.parse_args()

    os.makedirs(args.out, exist_ok=True)
    all_rows = []
    for name, module in BENCHES:
        if args.only and args.only not in name:
            continue
        t0 = time.perf_counter()
        mod = importlib.import_module(module)
        rows = mod.run()
        dt = time.perf_counter() - t0
        print(f"# === {name} ({module}) [{dt:.1f}s] ===")
        for row in rows:
            us = row.get("mean_ms", 0.0) * 1e3
            derived = ";".join(
                f"{k}={v}" for k, v in row.items()
                if k not in ("bench", "tag", "mean_ms"))
            print(f"{row.get('bench', name)}/{row.get('tag', '')},"
                  f"{us:.1f},{derived}")
        all_rows.extend(rows)
        with open(os.path.join(args.out, "results.json"), "w") as f:
            json.dump(all_rows, f, indent=1, default=str)
    print(f"# wrote {len(all_rows)} rows to {args.out}/results.json")


if __name__ == "__main__":
    main()
