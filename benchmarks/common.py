"""Shared helpers for the paper-figure benchmarks."""
from __future__ import annotations

import os
import sys
from typing import Dict, List, Optional

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.core import H200_QWEN32B, Variant, make_policy  # noqa: E402
from repro.core.controller import ControllerConfig, PressureController  # noqa: E402
from repro.core.scheduler import PoolPolicy  # noqa: E402
from repro.core.slo import SLOTracker, percentile  # noqa: E402
from repro.sim import ClusterSim, H200_32B, SimConfig  # noqa: E402
from repro.sim.workload import (WorkloadConfig, closed_loop_clients,  # noqa: E402
                                lmsys_like_requests)

MODEL = H200_QWEN32B
COST = H200_32B
THRESHOLD = 256.0          # operational long/short boundary (paper: <256 short)


def shared_sim(variant: str, n_instances: int = 1, mode: str = "pd",
               **policy_kw) -> ClusterSim:
    pol = make_policy(Variant(variant), MODEL, threshold=THRESHOLD,
                      **policy_kw)
    return ClusterSim(n_instances, lambda i: None, COST,
                      SimConfig(router="shared", mode=mode),
                      shared_policy=pol)


def routed_sim(variant: str, n_instances: int, router: str = "least_loaded",
               mode: str = "pd", control: bool = False) -> ClusterSim:
    if router == "pool":
        half = n_instances // 2
        def factory(i):
            return PoolPolicy(MODEL, pool="short" if i < half else "long",
                              threshold=THRESHOLD)
        ctrl = PressureController(ControllerConfig(t_cool=2.0, period=1.0)) \
            if control else None
        return ClusterSim(n_instances, factory, COST,
                          SimConfig(router="pool", mode=mode,
                                    control_period=1.0 if control else 0.0),
                          classifier=lambda r: "short"
                          if r.new_tokens < THRESHOLD else "long",
                          controller=ctrl)
    def factory(i):
        return make_policy(Variant(variant), MODEL, threshold=THRESHOLD)
    return ClusterSim(n_instances, factory, COST,
                      SimConfig(router=router, mode=mode))


def class_stats(tracker: SLOTracker, cls: Optional[str] = None,
                horizon: float = 1.0) -> Dict:
    rs = tracker.finished
    if cls == "short":
        rs = [r for r in rs if r.new_tokens < THRESHOLD]
    elif cls == "long":
        rs = [r for r in rs if r.new_tokens >= THRESHOLD]
    tt = [r.ttft() for r in rs if r.ttft() is not None]
    den = [r for r in rs if r.deadline is not None]
    viol = sum(1 for r in den
               if r.finish_time is None or r.finish_time > r.deadline)
    return {
        "n": len(rs),
        "rps": len(rs) / horizon,
        "mean_ms": 1e3 * sum(tt) / len(tt) if tt else 0.0,
        "p90_ms": 1e3 * percentile(tt, 0.9),
        "p99_ms": 1e3 * percentile(tt, 0.99),
        "viol": viol / len(den) if den else 0.0,
    }


def emit(rows: List[Dict], name: str) -> None:
    """Print the `name,us_per_call,derived` CSV contract plus the table."""
    for row in rows:
        us = row.get("mean_ms", 0.0) * 1e3
        derived = ";".join(f"{k}={v}" for k, v in row.items()
                           if k not in ("bench",))
        print(f"{name}/{row.get('tag', '')},{us:.1f},{derived}")
