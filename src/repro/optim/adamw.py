"""AdamW with fp32 master weights — ZeRO-1-style distribution.

Optimizer state (m, v, master) mirrors the parameter tree; because train
params are FSDP-sharded over the ``data`` axis (distributed.sharding
TRAIN_RULES), the optimizer state is automatically partitioned across
data-parallel workers — the ZeRO-1 property falls out of the sharding
rules rather than a separate partitioning pass.  Compute params stay in
the model dtype (bf16 at scale); masters/updates are fp32.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_ratio: float = 0.1


def lr_schedule(cfg: AdamWConfig, step: jax.Array) -> jax.Array:
    """Linear warmup then cosine decay to min_lr_ratio·lr."""
    step = step.astype(jnp.float32)
    warm = step / jnp.maximum(cfg.warmup_steps, 1)
    decay_steps = jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1)
    frac = jnp.clip((step - cfg.warmup_steps) / decay_steps, 0.0, 1.0)
    cos = cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * 0.5 * \
        (1.0 + jnp.cos(jnp.pi * frac))
    return cfg.lr * jnp.where(step < cfg.warmup_steps, warm, cos)


def adamw_init(params: Any) -> Dict:
    # copy (not view) so master never aliases the donated param buffers
    f32 = lambda p: jnp.array(p, jnp.float32, copy=True)
    return {
        "m": jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params),
        "v": jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params),
        "master": jax.tree.map(f32, params),
        "count": jnp.zeros((), jnp.int32),
    }


def global_norm(tree: Any) -> jax.Array:
    return jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                        for g in jax.tree.leaves(tree)))


def adamw_update(grads: Any, state: Dict, cfg: AdamWConfig,
                 param_dtype=jnp.float32) -> Tuple[Any, Dict, Dict]:
    """Returns (new_params_in_model_dtype, new_state, metrics)."""
    count = state["count"] + 1
    lr = lr_schedule(cfg, count)
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / (gnorm + 1e-9))
    grads = jax.tree.map(lambda g: g.astype(jnp.float32) * scale, grads)

    b1c = 1.0 - cfg.b1 ** count.astype(jnp.float32)
    b2c = 1.0 - cfg.b2 ** count.astype(jnp.float32)

    def upd(g, m, v, p):
        m = cfg.b1 * m + (1 - cfg.b1) * g
        v = cfg.b2 * v + (1 - cfg.b2) * jnp.square(g)
        mh = m / b1c
        vh = v / b2c
        step = mh / (jnp.sqrt(vh) + cfg.eps) + cfg.weight_decay * p
        return m, v, p - lr * step

    flat_g, treedef = jax.tree.flatten(grads)
    flat_m = treedef.flatten_up_to(state["m"])
    flat_v = treedef.flatten_up_to(state["v"])
    flat_p = treedef.flatten_up_to(state["master"])
    new_m, new_v, new_p = [], [], []
    for g, m, v, p in zip(flat_g, flat_m, flat_v, flat_p):
        m2, v2, p2 = upd(g, m, v, p)
        new_m.append(m2)
        new_v.append(v2)
        new_p.append(p2)
    new_state = {
        "m": jax.tree.unflatten(treedef, new_m),
        "v": jax.tree.unflatten(treedef, new_v),
        "master": jax.tree.unflatten(treedef, new_p),
        "count": count,
    }
    # force a copy when param dtype == fp32: params must not alias the
    # master buffers (both are donated by the jit'd train step)
    cast = (lambda p: p.astype(param_dtype)) if param_dtype != jnp.float32 \
        else (lambda p: jnp.copy(p))
    params = jax.tree.map(cast, new_state["master"])
    return params, new_state, {"lr": lr, "grad_norm": gnorm}
