from repro.data.synthetic import SyntheticLM, SyntheticConfig  # noqa: F401
