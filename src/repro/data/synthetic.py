"""Synthetic LM data pipeline: deterministic, seekable, checkpointable.

Sequences mix (a) Zipfian unigram noise with (b) learnable structure —
fixed-length copy/repeat motifs — so a ~100M model's loss visibly drops
within a few hundred steps (the end-to-end example's success criterion).
The iterator state is a single integer (step), making data-restart after
failure exact.

Also home to the MULTI-TURN serving trace generator (DESIGN.md §8):
seeded chat sessions drawing from a shared system-prompt pool, each turn
resubmitting the full conversation plus a fresh suffix, with
heavy-tailed (Zipf) turn counts — the workload whose TTFT the paged
arena's radix prefix reuse collapses to the new-suffix cost.  The bench
(benchmarks/bench_mix.py multiturn) and the cluster simulator consume
the SAME trace.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, List, Optional, Tuple

import numpy as np


@dataclasses.dataclass(frozen=True)
class SyntheticConfig:
    vocab_size: int
    seq_len: int
    batch: int             # per-step global batch
    accum: int = 1         # microbatch accumulation factor
    motif_len: int = 8
    motif_prob: float = 0.5
    zipf_a: float = 1.3
    seed: int = 0


class SyntheticLM:
    def __init__(self, cfg: SyntheticConfig, start_step: int = 0):
        self.cfg = cfg
        self.step = start_step

    def state(self) -> Dict:
        return {"step": self.step}

    def restore(self, state: Dict) -> None:
        self.step = int(state["step"])

    def _sample(self, rng: np.random.Generator,
                n: int) -> Tuple[np.ndarray, np.ndarray]:
        c = self.cfg
        v = c.vocab_size
        toks = rng.zipf(c.zipf_a, size=(n, c.seq_len + 1)) % (v - 1) + 1
        # inject copy motifs: x[t] = x[t - motif_len] within motif spans
        total = c.seq_len + 1
        for i in range(n):
            if rng.random() < c.motif_prob:
                start = int(rng.integers(0, total // 2))
                span = int(rng.integers(c.motif_len,
                                        max(total - start - c.motif_len, c.motif_len + 1)))
                src = toks[i, start:start + c.motif_len]
                for j in range(span):
                    pos = start + c.motif_len + j
                    if pos >= total:
                        break
                    toks[i, pos] = src[j % c.motif_len]
        return toks[:, :-1].astype(np.int32), toks[:, 1:].astype(np.int64)

    def next_batch(self) -> Dict[str, np.ndarray]:
        c = self.cfg
        rng = np.random.default_rng(c.seed * 1_000_003 + self.step)
        n = c.batch * c.accum
        x, y = self._sample(rng, n)
        self.step += 1
        return {
            "tokens": x.reshape(c.accum, c.batch, c.seq_len),
            "labels": y.reshape(c.accum, c.batch, c.seq_len).astype(np.int32),
        }

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        while True:
            yield self.next_batch()


# --------------------------------------------------------- multi-turn trace

@dataclasses.dataclass(frozen=True)
class MultiTurnConfig:
    """Stateless-API chat trace: every turn submits the FULL conversation
    (system prompt + all prior turns + the new suffix) under a fresh
    request, exactly how OpenAI-style serving frontends drive an engine —
    the shape prefix caching exists for."""
    vocab_size: int
    num_sessions: int = 8
    num_system_prompts: int = 2   # shared pool → cross-session reuse
    system_len: int = 48          # tokens per system prompt
    suffix_lo: int = 8            # fresh tokens per turn (user + reply)
    suffix_hi: int = 32
    max_turns: int = 6
    zipf_a: float = 1.7           # heavy-tailed turn counts: most
    #                               sessions are short, a few run long
    turn_gap: float = 0.05        # s between a session's turns
    session_gap: float = 0.02     # s between session starts
    seed: int = 0


@dataclasses.dataclass(frozen=True)
class TurnSpec:
    """One submitted turn of a multi-turn session."""
    session: int
    turn: int                 # 0-based within the session
    tokens: np.ndarray        # (n,) int32 — the FULL conversation so far
    suffix: int               # fresh tokens this turn (the true new work)
    reusable_prefix: int      # len(tokens) − suffix (prior turns' pages)
    arrival: float


def gen_multiturn_sessions(cfg: MultiTurnConfig) -> List[TurnSpec]:
    """Generate the trace, ordered by arrival.

    Sessions share system prompts drawn from a fixed pool, so a FRESH
    session's first turn already has a reusable prefix whenever another
    session with the same prompt committed first; turn ≥ 2 of any
    session reuses everything but its new suffix.  ``reusable_prefix``
    is the exact oracle (ignoring eviction and page rounding — the
    consumer rounds down to page granularity)."""
    rng = np.random.default_rng(cfg.seed)
    prompts = [rng.integers(1, cfg.vocab_size,
                            cfg.system_len).astype(np.int32)
               for _ in range(cfg.num_system_prompts)]
    turns: List[TurnSpec] = []
    for s in range(cfg.num_sessions):
        conv = prompts[int(rng.integers(cfg.num_system_prompts))]
        prior = 0   # conversation tokens carried in from earlier turns
        n_turns = min(int(rng.zipf(cfg.zipf_a)), cfg.max_turns)
        start = s * cfg.session_gap
        for t in range(n_turns):
            suffix = int(rng.integers(cfg.suffix_lo, cfg.suffix_hi + 1))
            conv = np.concatenate(
                [conv, rng.integers(1, cfg.vocab_size,
                                    suffix).astype(np.int32)])
            # turn 0 still reuses the SHARED system prompt if another
            # session committed it first — the consumer's radix index
            # decides; ``reusable_prefix`` reports the within-session
            # floor every cache must reach
            turns.append(TurnSpec(session=s, turn=t, tokens=conv,
                                  suffix=suffix, reusable_prefix=prior,
                                  arrival=start + t * cfg.turn_gap))
            prior = len(conv)
    turns.sort(key=lambda u: (u.arrival, u.session))
    return turns


def multiturn_requests(cfg: MultiTurnConfig, decode_tokens: int = 0,
                       rid_base: Optional[int] = None) -> List:
    """The same trace as :func:`gen_multiturn_sessions` shaped for the
    JAX-free cluster simulator: each turn becomes a full-conversation
    ``core.request.Request`` carrying its ``reusable_prefix`` annotation
    (the sim's prefix-reuse admission converts matched pages from new
    tokens into history — sim/simulator.py)."""
    from repro.core.request import Request
    out = []
    for u in gen_multiturn_sessions(cfg):
        out.append(Request(new_tokens=len(u.tokens),
                           arrival=u.arrival,
                           session=u.session * 10_000 + u.turn,
                           decode_tokens=decode_tokens,
                           reusable_prefix=u.reusable_prefix))
    return out
