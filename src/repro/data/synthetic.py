"""Synthetic LM data pipeline: deterministic, seekable, checkpointable.

Sequences mix (a) Zipfian unigram noise with (b) learnable structure —
fixed-length copy/repeat motifs — so a ~100M model's loss visibly drops
within a few hundred steps (the end-to-end example's success criterion).
The iterator state is a single integer (step), making data-restart after
failure exact.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, Tuple

import numpy as np


@dataclasses.dataclass(frozen=True)
class SyntheticConfig:
    vocab_size: int
    seq_len: int
    batch: int             # per-step global batch
    accum: int = 1         # microbatch accumulation factor
    motif_len: int = 8
    motif_prob: float = 0.5
    zipf_a: float = 1.3
    seed: int = 0


class SyntheticLM:
    def __init__(self, cfg: SyntheticConfig, start_step: int = 0):
        self.cfg = cfg
        self.step = start_step

    def state(self) -> Dict:
        return {"step": self.step}

    def restore(self, state: Dict) -> None:
        self.step = int(state["step"])

    def _sample(self, rng: np.random.Generator,
                n: int) -> Tuple[np.ndarray, np.ndarray]:
        c = self.cfg
        v = c.vocab_size
        toks = rng.zipf(c.zipf_a, size=(n, c.seq_len + 1)) % (v - 1) + 1
        # inject copy motifs: x[t] = x[t - motif_len] within motif spans
        total = c.seq_len + 1
        for i in range(n):
            if rng.random() < c.motif_prob:
                start = int(rng.integers(0, total // 2))
                span = int(rng.integers(c.motif_len,
                                        max(total - start - c.motif_len, c.motif_len + 1)))
                src = toks[i, start:start + c.motif_len]
                for j in range(span):
                    pos = start + c.motif_len + j
                    if pos >= total:
                        break
                    toks[i, pos] = src[j % c.motif_len]
        return toks[:, :-1].astype(np.int32), toks[:, 1:].astype(np.int64)

    def next_batch(self) -> Dict[str, np.ndarray]:
        c = self.cfg
        rng = np.random.default_rng(c.seed * 1_000_003 + self.step)
        n = c.batch * c.accum
        x, y = self._sample(rng, n)
        self.step += 1
        return {
            "tokens": x.reshape(c.accum, c.batch, c.seq_len),
            "labels": y.reshape(c.accum, c.batch, c.seq_len).astype(np.int32),
        }

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        while True:
            yield self.next_batch()
