"""Flash-decode Pallas kernels: one new token attending to a KV cache.

The decode step is memory-bound (the paper's short-request regime): the
valid KV prefix is streamed HBM→VMEM once; arithmetic is a (rep × D) ·
(D × block_k) GEMV-like matmul per block.  Grid = (B, Hkv, n_kv_blocks)
with the kv axis sequential; the online-softmax state for the ``rep``
query heads of one KV group sits in VMEM scratch.

Two entry points share the kernel math:

  * :func:`decode_attn` — the batch-cache form: k/v are (B, S, Hkv, D)
    rows already gathered out of the arena (the legacy dense path);
  * :func:`decode_attn_arena` — the arena-resident form: k/v are the
    WHOLE KV arena (N_slots, S, Hkv, D) and a scalar-prefetched
    ``slot_map`` selects each batch row's slot inside the BlockSpec
    index maps, so a decode tick streams only the valid cache prefixes
    of its live sessions — no whole-slot gather/scatter round-trip, no
    O(S_max) HBM copies per generated token.  KV blocks past a row's
    valid length are clamped to the last valid block in the index map
    (a repeated block index skips the DMA) and their compute is skipped.

Layout note: q rows per program = rep (GQA group fan-out, 1–8).  On real
TPUs rows < 8 under-fill sublanes; production layout would fold multiple
KV heads per program — kept simple here and validated in interpret mode.
The arena form reads (1, block_k, 1, D) blocks straight from the arena's
native (slots, S, Hkv, D) layout, trading sublane fill for zero arena
reshuffling (a transpose would copy the whole arena and defeat the
in-place point).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels._compat import CompilerParams

NEG_INF = -1e30
LANES = 128


def _largest_divisor(n: int, cap: int) -> int:
    """Largest block size ≤ cap dividing n (arena S is never padded —
    padding would copy the whole arena)."""
    for b in range(min(cap, n), 0, -1):
        if n % b == 0:
            return b
    return 1


def _kernel(len_ref, q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
            scale: float, block_k: int, n_kv_blocks: int):
    ki = pl.program_id(2)
    kv_len = len_ref[0, 0]

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    k_start = ki * block_k

    @pl.when(k_start < kv_len)
    def _compute():
        q = q_ref[0, 0]                                        # (rep, D)
        k = k_ref[0, 0]                                        # (bk, D)
        v = v_ref[0, 0]
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale        # (rep, bk)
        kpos = k_start + jax.lax.broadcasted_iota(
            jnp.int32, s.shape, 1)
        mask = kpos < kv_len
        s = jnp.where(mask, s, NEG_INF)
        m_prev = m_ref[:, :1]
        l_prev = l_ref[:, :1]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
        p = jnp.where(mask, jnp.exp(s - m_new), 0.0)
        alpha = jnp.exp(m_prev - m_new)
        l_new = alpha * l_prev + jnp.sum(p, axis=-1, keepdims=True)
        pv = jax.lax.dot_general(
            p.astype(v.dtype), v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        acc_ref[...] = acc_ref[...] * alpha + pv
        m_ref[...] = jnp.broadcast_to(m_new, m_ref.shape)
        l_ref[...] = jnp.broadcast_to(l_new, l_ref.shape)

    @pl.when(ki == n_kv_blocks - 1)
    def _finish():
        l = l_ref[:, :1]
        l = jnp.where(l == 0.0, 1.0, l)
        o_ref[0, 0] = (acc_ref[...] / l).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_k", "interpret"))
def decode_attn(q: jax.Array, k: jax.Array, v: jax.Array,
                lengths: jax.Array, *, block_k: int = 512,
                interpret: bool = True) -> jax.Array:
    """q: (B, Hq, D); k, v: (B, S, Hkv, D); lengths: (B,).

    Returns (B, Hq, D).
    """
    b, hq, d = q.shape
    s, hkv = k.shape[1], k.shape[2]
    rep = hq // hkv
    block_k = min(block_k, s)
    s_pad = -(-s // block_k) * block_k
    kt = jnp.moveaxis(k, 2, 1)                                 # (B, Hkv, S, D)
    vt = jnp.moveaxis(v, 2, 1)
    if s_pad != s:
        kt = jnp.pad(kt, ((0, 0), (0, 0), (0, s_pad - s), (0, 0)))
        vt = jnp.pad(vt, ((0, 0), (0, 0), (0, s_pad - s), (0, 0)))
    qg = q.reshape(b, hkv, rep, d)
    nk = s_pad // block_k

    kern = functools.partial(_kernel, scale=d ** -0.5, block_k=block_k,
                             n_kv_blocks=nk)
    out = pl.pallas_call(
        kern,
        grid=(b, hkv, nk),
        in_specs=[
            pl.BlockSpec((1, 1), lambda bb, g, ki: (bb, 0)),
            pl.BlockSpec((1, 1, rep, d), lambda bb, g, ki: (bb, g, 0, 0)),
            pl.BlockSpec((1, 1, block_k, d), lambda bb, g, ki: (bb, g, ki, 0)),
            pl.BlockSpec((1, 1, block_k, d), lambda bb, g, ki: (bb, g, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, rep, d), lambda bb, g, ki: (bb, g, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, hkv, rep, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((rep, LANES), jnp.float32),
            pltpu.VMEM((rep, LANES), jnp.float32),
            pltpu.VMEM((rep, d), jnp.float32),
        ],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(lengths.reshape(b, 1).astype(jnp.int32), qg, kt, vt)
    return out.reshape(b, hq, d)


def _arena_kernel(slot_ref, len_ref, q_ref, k_ref, v_ref, o_ref,
                  m_ref, l_ref, acc_ref, *, scale: float,
                  window: Optional[int], depth: int, block_k: int,
                  n_kv_blocks: int, n_phys_blocks: int):
    del slot_ref                     # consumed by the BlockSpec index maps
    b = pl.program_id(0)
    ki = pl.program_id(2)
    kv_len = len_ref[b]
    if window is None:
        n_valid = kv_len
        k_start = ki * block_k
    else:
        # rolling arena: only the last min(kv_len, depth) slots are
        # valid, and the in-window ones form a CYCLIC contiguous range
        # starting at the oldest in-window position's slot — iteration
        # index ki walks that range's blocks (mirroring the index map),
        # so only ceil(window/block_k)+1 blocks stream per row
        n_valid = jnp.minimum(kv_len, depth)
        w_eff = jnp.minimum(window, kv_len)
        s0 = (kv_len - w_eff) % depth
        phys = (s0 // block_k + ki) % n_phys_blocks
        k_start = phys * block_k

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    @pl.when(k_start < n_valid)
    def _compute():
        q = q_ref[0, 0]                                        # (rep, D)
        k = k_ref[0, :, 0, :]                                  # (bk, D)
        v = v_ref[0, :, 0, :]
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale        # (rep, bk)
        slot = k_start + jax.lax.broadcasted_iota(
            jnp.int32, s.shape, 1)
        mask = slot < n_valid
        if window is not None:
            # rolling slot s holds the newest position < kv_len congruent
            # to s mod depth; the query sits at kv_len − 1, so keep only
            # keys inside its window (qpos − window, qpos]
            wraps = jnp.maximum(kv_len - 1 - slot, 0) // depth
            kpos = slot + wraps * depth
            mask = jnp.logical_and(mask, kpos > kv_len - 1 - window)
        s = jnp.where(mask, s, NEG_INF)
        m_prev = m_ref[:, :1]
        l_prev = l_ref[:, :1]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
        p = jnp.where(mask, jnp.exp(s - m_new), 0.0)
        alpha = jnp.exp(m_prev - m_new)
        l_new = alpha * l_prev + jnp.sum(p, axis=-1, keepdims=True)
        pv = jax.lax.dot_general(
            p.astype(v.dtype), v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        acc_ref[...] = acc_ref[...] * alpha + pv
        m_ref[...] = jnp.broadcast_to(m_new, m_ref.shape)
        l_ref[...] = jnp.broadcast_to(l_new, l_ref.shape)

    @pl.when(ki == n_kv_blocks - 1)
    def _finish():
        l = l_ref[:, :1]
        l = jnp.where(l == 0.0, 1.0, l)
        o_ref[0, 0] = (acc_ref[...] / l).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("window", "interpret"))
def decode_attn_paged(q: jax.Array, k: jax.Array, v: jax.Array,
                      page_table: jax.Array, lengths: jax.Array, *,
                      window: Optional[int] = None,
                      interpret: bool = True) -> jax.Array:
    """Paged flash decode.

    The paged generalization of :func:`decode_attn_arena`: each row's KV
    lives on fixed-size pages scattered in a shared pool and a per-row
    page table maps logical kv block → physical page, so pages can be
    SHARED between rows (prefix reuse, COW forks).

    q: (B, Hq, D); k, v: (N_pages, page_size, Hkv, D) — the FULL page
    pools, untouched; page_table: (B, P_max) physical page of each row's
    logical page i; lengths: (B,) valid cache entries (history + the new
    row, which the caller scatter-wrote before this call).

    Returns (B, Hq, D).  One kv grid block == one page: logical page ki
    holds absolute positions [ki·ps, (ki+1)·ps), so the shared
    ``_arena_kernel`` math is reused verbatim with the page-id lookup
    replacing the slot-id lookup.  Logical pages past
    ``ceil(lengths/ps)`` clamp to the last valid page (a repeated page
    index skips the DMA), so a tick streams only ``lengths[b]`` cache
    rows per sequence.

    ``window``: sliding-window width.  The page table is then a RING
    over its P_max entries (§7's rolling arena at page granularity):
    position p lives on ring page (p // ps) % P_max at offset p % ps.
    The kv grid axis shrinks to the pages the window can touch — the
    walk starts at the oldest in-window position's page and wraps
    modularly, exactly :func:`decode_attn_arena`'s windowed form with
    the page-id lookup replacing the slot-id lookup.
    """
    b, hq, d = q.shape
    ps, hkv = k.shape[1], k.shape[2]
    p_max = page_table.shape[1]
    rep = hq // hkv
    block_k = ps                   # the page IS the kv block
    nk = p_max
    nk_iter = nk if window is None else min(nk, (window - 1) // block_k + 2)
    depth = ps * p_max
    qg = q.reshape(b, hkv, rep, d)

    def kv_map(bb, g, ki, pt_ref, len_ref):
        if window is None:
            last = jnp.maximum(len_ref[bb] - 1, 0) // block_k
            return (pt_ref[bb, jnp.minimum(ki, last)], 0, g, 0)
        kvl = len_ref[bb]
        n_valid = jnp.minimum(kvl, depth)
        w_eff = jnp.minimum(window, kvl)
        s0 = (kvl - w_eff) % depth      # oldest in-window ring slot
        phys = (s0 // block_k + ki) % nk
        # pre-wraparound (kvl < depth) the walk cannot wrap, so clamping
        # to the last valid page only retargets pages the kernel skips
        last = jnp.maximum(n_valid - 1, 0) // block_k
        return (pt_ref[bb, jnp.minimum(phys, last)], 0, g, 0)

    kern = functools.partial(_arena_kernel, scale=d ** -0.5, window=window,
                             depth=depth, block_k=block_k,
                             n_kv_blocks=nk_iter, n_phys_blocks=nk)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(b, hkv, nk_iter),
        in_specs=[
            pl.BlockSpec((1, 1, rep, d), lambda bb, g, ki, *_: (bb, g, 0, 0)),
            pl.BlockSpec((1, block_k, 1, d), kv_map),
            pl.BlockSpec((1, block_k, 1, d), kv_map),
        ],
        out_specs=pl.BlockSpec((1, 1, rep, d),
                               lambda bb, g, ki, *_: (bb, g, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((rep, LANES), jnp.float32),
            pltpu.VMEM((rep, LANES), jnp.float32),
            pltpu.VMEM((rep, d), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        kern,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, hkv, rep, d), q.dtype),
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(page_table.astype(jnp.int32), lengths.astype(jnp.int32), qg, k, v)
    return out.reshape(b, hq, d)


@functools.partial(jax.jit, static_argnames=("window", "block_k",
                                             "interpret"))
def decode_attn_arena(q: jax.Array, k: jax.Array, v: jax.Array,
                      slot_map: jax.Array, lengths: jax.Array, *,
                      window: Optional[int] = None, block_k: int = 512,
                      interpret: bool = True) -> jax.Array:
    """Arena-resident flash decode.

    q: (B, Hq, D); k, v: (N_slots, S, Hkv, D) — the FULL per-layer KV
    arena, untouched; slot_map: (B,) arena slot of each batch row;
    lengths: (B,) valid cache entries (history + the new row, which the
    caller scatter-wrote before this call).

    Returns (B, Hq, D).  The arena slot axis is indexed inside the
    BlockSpec index maps via scalar prefetch, so only ``lengths[b]``
    cache rows per sequence move HBM→VMEM — never whole slots and never
    slots the batch doesn't own.

    ``window``: sliding-window width.  The arena is then a ROLLING cache
    (slot depth S = window + margin, written modularly at position % S):
    block iteration clamps to the last ceil(min(lengths, S)/block_k)
    valid blocks, slot positions are reconstructed modularly, and only
    keys inside the query's window survive the mask — O(min(cached,
    window)) HBM rows per generated token instead of O(cached).
    """
    b, hq, d = q.shape
    s, hkv = k.shape[1], k.shape[2]
    rep = hq // hkv
    block_k = _largest_divisor(s, block_k)
    nk = s // block_k
    # windowed form: the in-window slots are a cyclic contiguous range
    # of ≤ window rows, so the kv grid axis shrinks to the blocks that
    # range can touch — the walk starts at the oldest in-window slot's
    # block and wraps modularly (see kv_map/_arena_kernel)
    nk_iter = nk if window is None else min(nk, (window - 1) // block_k + 2)
    qg = q.reshape(b, hkv, rep, d)

    def kv_map(bb, g, ki, slot_ref, len_ref):
        # clamp past-the-length blocks to the last valid one: a repeated
        # block index is not re-fetched, so invalid blocks cost no DMA.
        if window is None:
            last = jnp.maximum(len_ref[bb] - 1, 0) // block_k
            return (slot_ref[bb], jnp.minimum(ki, last), g, 0)
        kvl = len_ref[bb]
        n_valid = jnp.minimum(kvl, s)
        w_eff = jnp.minimum(window, kvl)
        s0 = (kvl - w_eff) % s          # oldest in-window slot
        phys = (s0 // block_k + ki) % nk
        # pre-wraparound (kvl < s) the walk cannot wrap, so clamping to
        # the last valid block only retargets blocks the kernel skips
        last = jnp.maximum(n_valid - 1, 0) // block_k
        return (slot_ref[bb], jnp.minimum(phys, last), g, 0)

    kern = functools.partial(_arena_kernel, scale=d ** -0.5, window=window,
                             depth=s, block_k=block_k, n_kv_blocks=nk_iter,
                             n_phys_blocks=nk)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(b, hkv, nk_iter),
        in_specs=[
            pl.BlockSpec((1, 1, rep, d), lambda bb, g, ki, *_: (bb, g, 0, 0)),
            pl.BlockSpec((1, block_k, 1, d), kv_map),
            pl.BlockSpec((1, block_k, 1, d), kv_map),
        ],
        out_specs=pl.BlockSpec((1, 1, rep, d),
                               lambda bb, g, ki, *_: (bb, g, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((rep, LANES), jnp.float32),
            pltpu.VMEM((rep, LANES), jnp.float32),
            pltpu.VMEM((rep, d), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        kern,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, hkv, rep, d), q.dtype),
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(slot_map.astype(jnp.int32), lengths.astype(jnp.int32), qg, k, v)
    return out.reshape(b, hq, d)
