"""Flash-decode Pallas kernel: one new token attending to a KV cache.

The decode step is memory-bound (the paper's short-request regime): the
whole KV cache is streamed HBM→VMEM once; arithmetic is a (rep × D) ·
(D × block_k) GEMV-like matmul per block.  Grid = (B, Hkv, n_kv_blocks)
with the kv axis sequential; the online-softmax state for the ``rep``
query heads of one KV group sits in VMEM scratch.

Layout note: q rows per program = rep (GQA group fan-out, 1–8).  On real
TPUs rows < 8 under-fill sublanes; production layout would fold multiple
KV heads per program — kept simple here and validated in interpret mode.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels._compat import CompilerParams

NEG_INF = -1e30
LANES = 128


def _kernel(len_ref, q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
            scale: float, block_k: int, n_kv_blocks: int):
    ki = pl.program_id(2)
    kv_len = len_ref[0, 0]

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    k_start = ki * block_k

    @pl.when(k_start < kv_len)
    def _compute():
        q = q_ref[0, 0]                                        # (rep, D)
        k = k_ref[0, 0]                                        # (bk, D)
        v = v_ref[0, 0]
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale        # (rep, bk)
        kpos = k_start + jax.lax.broadcasted_iota(
            jnp.int32, s.shape, 1)
        mask = kpos < kv_len
        s = jnp.where(mask, s, NEG_INF)
        m_prev = m_ref[:, :1]
        l_prev = l_ref[:, :1]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
        p = jnp.where(mask, jnp.exp(s - m_new), 0.0)
        alpha = jnp.exp(m_prev - m_new)
        l_new = alpha * l_prev + jnp.sum(p, axis=-1, keepdims=True)
        pv = jax.lax.dot_general(
            p.astype(v.dtype), v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        acc_ref[...] = acc_ref[...] * alpha + pv
        m_ref[...] = jnp.broadcast_to(m_new, m_ref.shape)
        l_ref[...] = jnp.broadcast_to(l_new, l_ref.shape)

    @pl.when(ki == n_kv_blocks - 1)
    def _finish():
        l = l_ref[:, :1]
        l = jnp.where(l == 0.0, 1.0, l)
        o_ref[0, 0] = (acc_ref[...] / l).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_k", "interpret"))
def decode_attn(q: jax.Array, k: jax.Array, v: jax.Array,
                lengths: jax.Array, *, block_k: int = 512,
                interpret: bool = True) -> jax.Array:
    """q: (B, Hq, D); k, v: (B, S, Hkv, D); lengths: (B,).

    Returns (B, Hq, D).
    """
    b, hq, d = q.shape
    s, hkv = k.shape[1], k.shape[2]
    rep = hq // hkv
    block_k = min(block_k, s)
    s_pad = -(-s // block_k) * block_k
    kt = jnp.moveaxis(k, 2, 1)                                 # (B, Hkv, S, D)
    vt = jnp.moveaxis(v, 2, 1)
    if s_pad != s:
        kt = jnp.pad(kt, ((0, 0), (0, 0), (0, s_pad - s), (0, 0)))
        vt = jnp.pad(vt, ((0, 0), (0, 0), (0, s_pad - s), (0, 0)))
    qg = q.reshape(b, hkv, rep, d)
    nk = s_pad // block_k

    kern = functools.partial(_kernel, scale=d ** -0.5, block_k=block_k,
                             n_kv_blocks=nk)
    out = pl.pallas_call(
        kern,
        grid=(b, hkv, nk),
        in_specs=[
            pl.BlockSpec((1, 1), lambda bb, g, ki: (bb, 0)),
            pl.BlockSpec((1, 1, rep, d), lambda bb, g, ki: (bb, g, 0, 0)),
            pl.BlockSpec((1, 1, block_k, d), lambda bb, g, ki: (bb, g, ki, 0)),
            pl.BlockSpec((1, 1, block_k, d), lambda bb, g, ki: (bb, g, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, rep, d), lambda bb, g, ki: (bb, g, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, hkv, rep, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((rep, LANES), jnp.float32),
            pltpu.VMEM((rep, LANES), jnp.float32),
            pltpu.VMEM((rep, d), jnp.float32),
        ],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(lengths.reshape(b, 1).astype(jnp.int32), qg, kt, vt)
    return out.reshape(b, hq, d)
