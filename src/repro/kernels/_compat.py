"""Version shims for the Pallas TPU API surface.

jax 0.5 renamed ``pltpu.TPUCompilerParams`` to ``pltpu.CompilerParams``;
kernels import the alias from here so one tree runs on both.
"""
from __future__ import annotations

from jax.experimental.pallas import tpu as pltpu

CompilerParams = getattr(pltpu, "CompilerParams", None) \
    or pltpu.TPUCompilerParams
