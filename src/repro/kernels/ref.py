"""Pure-jnp oracles for every Pallas kernel (the correctness contract).

Each ``ref_*`` function computes the same math as its kernel with plain
jnp ops in fp32, used by tests (`assert_allclose`) and as the XLA
fallback path on non-TPU backends.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp


def ref_flash_attn(q: jax.Array, k: jax.Array, v: jax.Array, *,
                   q_offsets: Optional[jax.Array] = None,
                   kv_lengths: Optional[jax.Array] = None,
                   window: Optional[int] = None,
                   causal: bool = True) -> jax.Array:
    """Oracle for kernels.flash_attn (prefill and re-prefill attention).

    q: (B, Lq, Hq, D); k, v: (B, S, Hkv, D) — S may exceed Lq (KV cache).
    q_offsets: (B,) absolute position of each batch row's first query
    token (re-prefill history length); None = 0.
    kv_lengths: (B,) valid KV entries (None = all S valid).
    """
    b, lq, hq, d = q.shape
    s, hkv = k.shape[1], k.shape[2]
    rep = hq // hkv
    if q_offsets is None:
        q_offsets = jnp.zeros((b,), jnp.int32)
    qpos = q_offsets[:, None] + jnp.arange(lq)[None, :]          # (B, Lq)
    kpos = jnp.arange(s)[None, None, :]                          # (1, 1, S)
    mask = jnp.ones((b, lq, s), bool)
    if causal:
        mask = mask & (kpos <= qpos[:, :, None])
    if window is not None:
        mask = mask & (kpos > qpos[:, :, None] - window)
    if kv_lengths is not None:
        mask = mask & (kpos < kv_lengths[:, None, None])
    qg = q.reshape(b, lq, hkv, rep, d).astype(jnp.float32)
    scores = jnp.einsum("blgrd,bsgd->bglrs", qg, k.astype(jnp.float32))
    scores = scores / jnp.sqrt(d).astype(jnp.float32)
    scores = jnp.where(mask[:, None, :, None, :], scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bglrs,bsgd->blgrd", probs, v.astype(jnp.float32))
    return out.reshape(b, lq, hq, d).astype(q.dtype)


def ref_ragged_prefill(q: jax.Array, k: jax.Array, v: jax.Array,
                       cu_seqlens: jax.Array,
                       q_offsets: Optional[jax.Array] = None,
                       kv_lengths: Optional[jax.Array] = None, *,
                       causal: bool = True) -> jax.Array:
    """Oracle for kernels.ragged_prefill (packed padding-free prefill).

    q: (T, Hq, D) flat packed queries — sequence i owns rows
    [cu_seqlens[i], cu_seqlens[i+1]); k, v: (B, S, Hkv, D) per-sequence
    KV caches.  q_offsets: (B,) history length (absolute position of
    each sequence's first query row); kv_lengths: (B,) valid KV entries.
    Rows beyond cu_seqlens[-1] produce zeros.  Fully traceable (cu may
    be a traced array), so it doubles as the XLA fallback path.
    """
    t, hq, d = q.shape
    b, s, hkv = k.shape[0], k.shape[1], k.shape[2]
    rep = hq // hkv
    if q_offsets is None:
        q_offsets = jnp.zeros((b,), jnp.int32)
    if kv_lengths is None:
        kv_lengths = jnp.full((b,), s, jnp.int32)
    rows = jnp.arange(t)
    seg = jnp.sum(rows[:, None] >= cu_seqlens[None, 1:], axis=1)  # (T,)
    valid_row = rows < cu_seqlens[-1]
    segc = jnp.clip(seg, 0, b - 1)
    qpos = q_offsets[segc] + rows - cu_seqlens[segc]             # (T,)
    kpos = jnp.arange(s)
    mask = (segc[:, None, None] == jnp.arange(b)[None, :, None])  # (T,B,S)
    mask = mask & valid_row[:, None, None]
    mask = mask & (kpos[None, None, :] < kv_lengths[None, :, None])
    if causal:
        mask = mask & (kpos[None, None, :] <= qpos[:, None, None])
    qg = q.reshape(t, hkv, rep, d).astype(jnp.float32)
    scores = jnp.einsum("tgrd,bsgd->tgrbs", qg, k.astype(jnp.float32))
    scores = scores / jnp.sqrt(d).astype(jnp.float32)
    scores = jnp.where(mask[:, None, None, :, :], scores, -1e30)
    flat = scores.reshape(t, hkv, rep, b * s)
    probs = jax.nn.softmax(flat, axis=-1).reshape(t, hkv, rep, b, s)
    out = jnp.einsum("tgrbs,bsgd->tgrd", probs, v.astype(jnp.float32))
    out = out * valid_row[:, None, None, None]   # no-sequence rows → 0
    return out.reshape(t, hq, d).astype(q.dtype)


def _rolling_kpos(kv_lengths: jax.Array, depth: int):
    """Absolute position held by each rolling-arena slot.

    Slot s of a depth-D rolling cache holds the newest position < kv_len
    congruent to s mod D: kpos = s + D·⌊max(kv_len−1−s, 0)/D⌋.  Returns
    (kpos (B, D), valid (B, D)) — valid is s < min(kv_len, D).
    """
    slots = jnp.arange(depth)[None, :]                           # (1, D)
    kvl = kv_lengths[:, None]                                    # (B, 1)
    wraps = jnp.maximum(kvl - 1 - slots, 0) // depth
    kpos = slots + wraps * depth
    valid = slots < jnp.minimum(kvl, depth)
    return kpos, valid


def ref_ragged_prefill_rolling(q: jax.Array, k: jax.Array, v: jax.Array,
                               cu_seqlens: jax.Array,
                               q_offsets: jax.Array,
                               kv_lengths: jax.Array, *, window: int,
                               causal: bool = True) -> jax.Array:
    """Windowed oracle over a ROLLING (modular) per-sequence cache.

    q: (T, Hq, D) flat packed stream; k, v: (B, D_slot, Hkv, D) — the
    gathered rolling cache rows, slot s holding the newest position
    congruent to s mod D_slot.  Each query row attends only keys whose
    reconstructed absolute position lies in (qpos − window, qpos].
    """
    t, hq, d = q.shape
    b, s_depth, hkv = k.shape[0], k.shape[1], k.shape[2]
    rep = hq // hkv
    rows = jnp.arange(t)
    seg = jnp.sum(rows[:, None] >= cu_seqlens[None, 1:], axis=1)  # (T,)
    valid_row = rows < cu_seqlens[-1]
    segc = jnp.clip(seg, 0, b - 1)
    qpos = q_offsets[segc] + rows - cu_seqlens[segc]             # (T,)
    kpos, kvalid = _rolling_kpos(kv_lengths, s_depth)            # (B, D)
    mask = (segc[:, None, None] == jnp.arange(b)[None, :, None])  # (T,B,D)
    mask = mask & valid_row[:, None, None]
    mask = mask & kvalid[None, :, :]
    if causal:
        mask = mask & (kpos[None, :, :] <= qpos[:, None, None])
    mask = mask & (kpos[None, :, :] > qpos[:, None, None] - window)
    qg = q.reshape(t, hkv, rep, d).astype(jnp.float32)
    scores = jnp.einsum("tgrd,bsgd->tgrbs", qg, k.astype(jnp.float32))
    scores = scores / jnp.sqrt(d).astype(jnp.float32)
    scores = jnp.where(mask[:, None, None, :, :], scores, -1e30)
    flat = scores.reshape(t, hkv, rep, b * s_depth)
    probs = jax.nn.softmax(flat, axis=-1).reshape(t, hkv, rep, b, s_depth)
    out = jnp.einsum("tgrbs,bsgd->tgrd", probs, v.astype(jnp.float32))
    out = out * valid_row[:, None, None, None]   # no-sequence rows → 0
    return out.reshape(t, hq, d).astype(q.dtype)


def ref_ragged_prefill_arena(q: jax.Array, k: jax.Array, v: jax.Array,
                             slot_map: jax.Array, cu_seqlens: jax.Array,
                             q_offsets: Optional[jax.Array] = None,
                             kv_lengths: Optional[jax.Array] = None, *,
                             causal: bool = True,
                             window: Optional[int] = None) -> jax.Array:
    """Oracle for kernels.ragged_prefill_arena (arena-resident packed
    prefill).

    q: (T, Hq, D) flat packed stream; k, v: (N_slots, S_max, Hkv, D)
    full arenas; slot_map: (B,) arena slot per segment.  The gather here
    is the ORACLE's convenience — the kernel indexes the slot axis in
    place.  Doubles as the XLA fallback off-TPU.  ``window`` selects the
    rolling-cache form (slots written modularly at position % depth).
    """
    if window is not None:
        b = slot_map.shape[0]
        if q_offsets is None:
            q_offsets = jnp.zeros((b,), jnp.int32)
        if kv_lengths is None:
            kv_lengths = jnp.full((b,), k.shape[1], jnp.int32)
        return ref_ragged_prefill_rolling(
            q, k[slot_map], v[slot_map], cu_seqlens, q_offsets, kv_lengths,
            window=window, causal=causal)
    return ref_ragged_prefill(q, k[slot_map], v[slot_map], cu_seqlens,
                              q_offsets=q_offsets, kv_lengths=kv_lengths,
                              causal=causal)


def ref_ragged_prefill_paged(q: jax.Array, k: jax.Array, v: jax.Array,
                             page_table: jax.Array, cu_seqlens: jax.Array,
                             q_offsets: Optional[jax.Array] = None,
                             kv_lengths: Optional[jax.Array] = None, *,
                             causal: bool = True,
                             window: Optional[int] = None) -> jax.Array:
    """Oracle for kernels.ragged_prefill_paged (paged packed prefill).

    q: (T, Hq, D) flat packed stream; k, v: (N_pages, page_size, Hkv, D)
    full page pools; page_table: (B, P_max) physical page per logical
    page.  The gather here — materializing each segment's logical
    (P_max·ps)-deep cache from its pages — is the ORACLE's convenience;
    the kernel reads pages in place through the table.  Doubles as the
    XLA fallback off-TPU.  ``window`` selects the ring-table form: the
    gathered pages form a depth-(P_max·ps) rolling cache (position p on
    ring page (p // ps) % P_max at offset p % ps), so the rolling
    oracle applies verbatim.
    """
    b, p_max = page_table.shape
    ps, hkv, d = k.shape[1], k.shape[2], k.shape[3]
    kg = k[page_table].reshape(b, p_max * ps, hkv, d)
    vg = v[page_table].reshape(b, p_max * ps, hkv, d)
    if window is not None:
        if q_offsets is None:
            q_offsets = jnp.zeros((b,), jnp.int32)
        if kv_lengths is None:
            kv_lengths = jnp.full((b,), p_max * ps, jnp.int32)
        return ref_ragged_prefill_rolling(
            q, kg, vg, cu_seqlens, q_offsets, kv_lengths,
            window=window, causal=causal)
    return ref_ragged_prefill(q, kg, vg, cu_seqlens, q_offsets=q_offsets,
                              kv_lengths=kv_lengths, causal=causal)


def ref_decode_attn_paged(q: jax.Array, k: jax.Array, v: jax.Array,
                          page_table: jax.Array, lengths: jax.Array, *,
                          window: Optional[int] = None) -> jax.Array:
    """Oracle for kernels.decode_attn_paged (paged flash decode).

    q: (B, Hq, D); k, v: (N_pages, page_size, Hkv, D) full page pools;
    page_table: (B, P_max); lengths: (B,) valid KV entries.  Gathers
    each row's pages into a contiguous logical cache and delegates —
    to the rolling oracle when ``window`` selects the ring-table form.
    """
    b, p_max = page_table.shape
    ps, hkv, d = k.shape[1], k.shape[2], k.shape[3]
    kg = k[page_table].reshape(b, p_max * ps, hkv, d)
    vg = v[page_table].reshape(b, p_max * ps, hkv, d)
    if window is not None:
        return ref_decode_attn_rolling(q, kg, vg, lengths, window=window)
    return ref_decode_attn(q, kg, vg, lengths)


def ref_decode_attn(q: jax.Array, k: jax.Array, v: jax.Array,
                    lengths: jax.Array) -> jax.Array:
    """Oracle for kernels.decode_attn (single-token flash decode).

    q: (B, Hq, D); k, v: (B, S, Hkv, D); lengths: (B,) valid KV entries.
    """
    b, hq, d = q.shape
    s, hkv = k.shape[1], k.shape[2]
    rep = hq // hkv
    valid = jnp.arange(s)[None, :] < lengths[:, None]            # (B, S)
    qg = q.reshape(b, hkv, rep, d).astype(jnp.float32)
    scores = jnp.einsum("bgrd,bsgd->bgrs", qg, k.astype(jnp.float32))
    scores = scores / jnp.sqrt(d).astype(jnp.float32)
    scores = jnp.where(valid[:, None, None, :], scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bgrs,bsgd->bgrd", probs, v.astype(jnp.float32))
    return out.reshape(b, hq, d).astype(q.dtype)


def ref_decode_attn_rolling(q: jax.Array, k: jax.Array, v: jax.Array,
                            lengths: jax.Array, *,
                            window: int) -> jax.Array:
    """Windowed decode oracle over a ROLLING per-row cache.

    q: (B, Hq, D); k, v: (B, D_slot, Hkv, D) rolling cache rows;
    lengths: (B,) total cached entries (history + the new row).  The
    query at position lengths − 1 attends keys whose reconstructed
    absolute position lies in (qpos − window, qpos].
    """
    b, hq, d = q.shape
    s_depth, hkv = k.shape[1], k.shape[2]
    rep = hq // hkv
    kpos, valid = _rolling_kpos(lengths, s_depth)                # (B, D)
    qpos = (lengths - 1)[:, None]
    valid = valid & (kpos > qpos - window)                       # (B, D)
    qg = q.reshape(b, hkv, rep, d).astype(jnp.float32)
    scores = jnp.einsum("bgrd,bsgd->bgrs", qg, k.astype(jnp.float32))
    scores = scores / jnp.sqrt(d).astype(jnp.float32)
    scores = jnp.where(valid[:, None, None, :], scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bgrs,bsgd->bgrd", probs, v.astype(jnp.float32))
    return out.reshape(b, hq, d).astype(q.dtype)


def ref_decode_attn_arena(q: jax.Array, k: jax.Array, v: jax.Array,
                          slot_map: jax.Array, lengths: jax.Array, *,
                          window: Optional[int] = None) -> jax.Array:
    """Oracle for kernels.decode_attn_arena (arena-resident decode).

    q: (B, Hq, D); k, v: (N_slots, S, Hkv, D) full arenas; slot_map: (B,)
    arena slot per batch row; lengths: (B,) valid KV entries.  The
    gather here is the ORACLE's convenience — the kernel indexes the
    slot axis in place.  Doubles as the XLA fallback off-TPU.  ``window``
    selects the rolling-cache form (slots written at position % depth).
    """
    if window is not None:
        return ref_decode_attn_rolling(q, k[slot_map], v[slot_map], lengths,
                                       window=window)
    return ref_decode_attn(q, k[slot_map], v[slot_map], lengths)


def ref_fused_sample(logits, temp, top_k, top_p, bias_ids, bias_vals,
                     u, draft):
    """Oracle for kernels.sampling.fused_sample (fused on-device
    sampling).  logits: (R, V); temp/top_p/u: (R,) float32;
    top_k/draft: (R,) int32 (top_k == 0 → off, top_p >= 1 → off);
    bias_ids/bias_vals: (R, MAX_BIAS).  Returns (token (R,) int32,
    p_draft (R,) float32, alt (R,) int32).  Delegates to the kernel
    module's shared-core vmap so both paths run ONE copy of the math
    (imported lazily — ref must stay importable without Pallas)."""
    from repro.kernels.sampling import fused_sample_reference
    return fused_sample_reference(logits, temp, top_k, top_p, bias_ids,
                                  bias_vals, u, draft)


def ref_ssd_scan(x: jax.Array, dt: jax.Array, a: jax.Array, bmat: jax.Array,
                 cmat: jax.Array,
                 init_state: Optional[jax.Array] = None):
    """Oracle for kernels.ssd_scan: sequential SSD recurrence.

    x: (B, L, NH, HD); dt: (B, L, NH); a: (NH,) negative;
    bmat, cmat: (B, L, NH, DS).  Returns (y, final_state (B,NH,HD,DS)).
    """
    b, l, nh, hd = x.shape
    ds = bmat.shape[-1]
    f32 = jnp.float32
    if init_state is None:
        init_state = jnp.zeros((b, nh, hd, ds), f32)

    def step(h, ins):
        xt, dtt, bt, ct = ins                                    # (B,NH,HD) etc
        da = jnp.exp(dtt * a[None, :])                           # (B,NH)
        h = da[..., None, None] * h + jnp.einsum(
            "bh,bhp,bhd->bhpd", dtt, xt, bt)
        y = jnp.einsum("bhpd,bhd->bhp", h, ct)
        return h, y

    ins = tuple(jnp.moveaxis(t.astype(f32), 1, 0) for t in (x, dt, bmat, cmat))
    state, ys = jax.lax.scan(step, init_state, ins)
    return jnp.moveaxis(ys, 0, 1).astype(x.dtype), state
