"""Ragged (padding-free) flash attention for packed prefill batches.

The packed short-prefill path concatenates every request's new tokens
into ONE flat token stream of a bucketed total length T — no per-request
length padding, no (L, B) shape cross-product.  This kernel is the
attention core of that path:

  * queries arrive flat: ``q (T, Hq, D)``; sequence i owns the rows
    ``[cu_seqlens[i], cu_seqlens[i+1])`` of the stream;
  * KV stays per-sequence: ``k/v (B, S, Hkv, D)`` — the gathered arena
    rows with this step's new KV already written at positions
    ``[q_offsets[i], q_offsets[i] + len_i)``;
  * ``q_offsets (B,)`` is the re-prefill history length (absolute
    position of each sequence's first new token), ``kv_lengths (B,)``
    the total valid cache entries (history + new);
  * grid = (Hq, n_q_blocks, B, n_kv_blocks) with the (B, kv) axes
    sequential so the online-softmax accumulator for one q block scans
    every sequence's cache in VMEM scratch;
  * cu_seqlens / q_offsets / kv_lengths ride scalar prefetch (SMEM), so
    block skipping is decided before any VMEM traffic: a (q_block, seq)
    pair is skipped unless the q block intersects the sequence's row
    range, and kv blocks past the causal frontier or the valid cache
    length are skipped like the dense kernel's.

Rows of the flat stream beyond ``cu_seqlens[-1]`` (bucket tail padding)
belong to no sequence: they accumulate nothing and produce zeros.
Masking at sequence boundaries is exact — a q block straddling two
sequences contributes each row only to its own sequence's softmax.

Two entry points share the kernel math:

  * :func:`ragged_prefill_attn` — the batch-cache form: k/v are
    (B, S, Hkv, D) rows already gathered out of the arena;
  * :func:`ragged_prefill_arena` — the arena-resident form: k/v are the
    WHOLE KV arena (N_slots, S_max, Hkv, D) and a scalar-prefetched
    ``slot_map (B,)`` routes each segment's KV blocks through its arena
    slot inside the BlockSpec index maps.  KV blocks past a segment's
    valid length clamp to the last valid block (a repeated block index
    skips the DMA), so a packed prefill / mixed / chunk tick streams
    only the valid cache prefixes of its live sessions — no whole-slot
    gather before the step and no scatter after it, killing the
    O(b_max · S_max) HBM round-trip of the gathered path.  Blocks read
    (1, block_k, 1, D) straight from the arena's native layout — a
    transpose would copy the arena and defeat the in-place point.

Decode segments (continuous batching) need no special path: a length-1
segment with ``q_offsets[i] = H`` and ``kv_lengths[i] = H + 1`` attends
over exactly ``H + 1`` keys — the causal frontier check caps the kv
scan at ``offset + 1`` blocks for that row, and kv blocks past the
valid cache length are skipped before any VMEM traffic, so a decode
row costs O(H) kv reads, not O(S_max).

GQA reads the kv head as h // rep in the index maps, same as the dense
kernel; accumulation is fp32 via ``preferred_element_type``.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels._compat import CompilerParams
from repro.kernels.decode_attn import _largest_divisor

NEG_INF = -1e30
LANES = 128


def _kernel(cu_ref, off_ref, len_ref, q_ref, k_ref, v_ref, o_ref,
            m_ref, l_ref, acc_ref, *, scale: float, causal: bool,
            block_q: int, block_k: int, n_seqs: int, n_kv_blocks: int):
    qi = pl.program_id(1)
    b = pl.program_id(2)
    ki = pl.program_id(3)

    @pl.when(jnp.logical_and(b == 0, ki == 0))
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    seg_start = cu_ref[b]
    seg_end = cu_ref[b + 1]
    offset = off_ref[b]
    kv_len = len_ref[b]

    q_start = qi * block_q                 # flat row of this q block
    k_start = ki * block_k

    # block-level skip: q block must own rows of sequence b, the kv
    # block must hold valid cache entries, and (causal) must not lie
    # entirely after the block's last query position
    run = jnp.logical_and(q_start < seg_end, q_start + block_q > seg_start)
    run = jnp.logical_and(run, k_start < kv_len)
    if causal:
        last_row = jnp.minimum(seg_end, q_start + block_q) - 1
        max_qpos = offset + last_row - seg_start
        run = jnp.logical_and(run, k_start <= max_qpos)

    @pl.when(run)
    def _compute():
        q = q_ref[0]                                           # (bq, D)
        k = k_ref[0, 0]                                        # (bk, D)
        v = v_ref[0, 0]
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale        # (bq, bk)
        rows = q_start + jax.lax.broadcasted_iota(
            jnp.int32, (block_q, block_k), 0)                  # flat row ids
        kpos = k_start + jax.lax.broadcasted_iota(
            jnp.int32, (block_q, block_k), 1)
        mine = jnp.logical_and(rows >= seg_start, rows < seg_end)
        qpos = offset + rows - seg_start
        mask = jnp.logical_and(mine, kpos < kv_len)
        if causal:
            mask = jnp.logical_and(mask, kpos <= qpos)
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_ref[:, :1]                                  # (bq, 1)
        l_prev = l_ref[:, :1]
        m_cur = jnp.max(s, axis=-1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(s - m_new)
        p = jnp.where(mask, p, 0.0)
        alpha = jnp.exp(m_prev - m_new)                        # (bq, 1)
        l_new = alpha * l_prev + jnp.sum(p, axis=-1, keepdims=True)
        pv = jax.lax.dot_general(
            p.astype(v.dtype), v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)                # (bq, D)
        acc_ref[...] = acc_ref[...] * alpha + pv
        m_ref[...] = jnp.broadcast_to(m_new, m_ref.shape)
        l_ref[...] = jnp.broadcast_to(l_new, l_ref.shape)

    @pl.when(jnp.logical_and(b == n_seqs - 1, ki == n_kv_blocks - 1))
    def _finish():
        l = l_ref[:, :1]
        l = jnp.where(l == 0.0, 1.0, l)     # rows owned by no sequence
        o_ref[0] = (acc_ref[...] / l).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "block_q", "block_k", "interpret"))
def ragged_prefill_attn(q: jax.Array, k: jax.Array, v: jax.Array,
                        cu_seqlens: jax.Array,
                        q_offsets: Optional[jax.Array] = None,
                        kv_lengths: Optional[jax.Array] = None, *,
                        causal: bool = True,
                        block_q: int = 128, block_k: int = 128,
                        interpret: bool = True) -> jax.Array:
    """q: (T, Hq, D) packed stream; k, v: (B, S, Hkv, D).  Returns
    (T, Hq, D) with zeros on rows past ``cu_seqlens[-1]``.

    cu_seqlens: (B+1,) int32 row offsets of each sequence in the stream;
    q_offsets: (B,) history length per sequence (re-prefill);
    kv_lengths: (B,) valid KV entries per sequence (defaults to S).
    """
    t, hq, d = q.shape
    b, s, hkv = k.shape[0], k.shape[1], k.shape[2]
    rep = hq // hkv
    if q_offsets is None:
        q_offsets = jnp.zeros((b,), jnp.int32)
    if kv_lengths is None:
        kv_lengths = jnp.full((b,), s, jnp.int32)

    block_q = min(block_q, max(t, 1))
    block_k = min(block_k, s)
    t_pad = -(-t // block_q) * block_q
    s_pad = -(-s // block_k) * block_k
    qt = jnp.moveaxis(q, 1, 0)                                 # (Hq, T, D)
    kt = jnp.moveaxis(k, 2, 1)                                 # (B, Hkv, S, D)
    vt = jnp.moveaxis(v, 2, 1)
    if t_pad != t:
        qt = jnp.pad(qt, ((0, 0), (0, t_pad - t), (0, 0)))
    if s_pad != s:
        kt = jnp.pad(kt, ((0, 0), (0, 0), (0, s_pad - s), (0, 0)))
        vt = jnp.pad(vt, ((0, 0), (0, 0), (0, s_pad - s), (0, 0)))
    nq, nk = t_pad // block_q, s_pad // block_k

    kern = functools.partial(
        _kernel, scale=d ** -0.5, causal=causal,
        block_q=block_q, block_k=block_k, n_seqs=b, n_kv_blocks=nk)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,
        grid=(hq, nq, b, nk),
        in_specs=[
            pl.BlockSpec((1, block_q, d), lambda h, qi, bb, ki, *_: (h, qi, 0)),
            pl.BlockSpec((1, 1, block_k, d),
                         lambda h, qi, bb, ki, *_: (bb, h // rep, ki, 0)),
            pl.BlockSpec((1, 1, block_k, d),
                         lambda h, qi, bb, ki, *_: (bb, h // rep, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, d),
                               lambda h, qi, bb, ki, *_: (h, qi, 0)),
        scratch_shapes=[
            pltpu.VMEM((block_q, LANES), jnp.float32),
            pltpu.VMEM((block_q, LANES), jnp.float32),
            pltpu.VMEM((block_q, d), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        kern,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((hq, t_pad, d), q.dtype),
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel",
                                 "arbitrary", "arbitrary")),
        interpret=interpret,
    )(cu_seqlens.astype(jnp.int32), q_offsets.astype(jnp.int32),
      kv_lengths.astype(jnp.int32), qt, kt, vt)
    return jnp.moveaxis(out[:, :t], 0, 1)


def _arena_kernel(slot_ref, cu_ref, off_ref, len_ref, q_ref, k_ref, v_ref,
                  o_ref, m_ref, l_ref, acc_ref, *, scale: float, causal: bool,
                  window: Optional[int], depth: int,
                  block_q: int, block_k: int, n_seqs: int, n_kv_blocks: int):
    del slot_ref                     # consumed by the BlockSpec index maps
    qi = pl.program_id(1)
    b = pl.program_id(2)
    ki = pl.program_id(3)

    @pl.when(jnp.logical_and(b == 0, ki == 0))
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    seg_start = cu_ref[b]
    seg_end = cu_ref[b + 1]
    offset = off_ref[b]
    kv_len = len_ref[b]
    # rolling arenas hold the last min(kv_len, depth) positions; the
    # full-depth form has depth == S_max so n_valid == kv_len always
    n_valid = jnp.minimum(kv_len, depth) if window is not None else kv_len

    q_start = qi * block_q                 # flat row of this q block
    k_start = ki * block_k

    # block-level skip, identical to the gathered kernel's: the q block
    # must own rows of segment b, the kv block must hold valid cache
    # entries (clamped blocks re-read the last valid one and are skipped
    # here), and causally it must not lie past the block's last query.
    # The causal refinement assumes slot index == absolute position, so
    # it only applies to the non-rolling form.
    run = jnp.logical_and(q_start < seg_end, q_start + block_q > seg_start)
    run = jnp.logical_and(run, k_start < n_valid)
    if causal and window is None:
        last_row = jnp.minimum(seg_end, q_start + block_q) - 1
        max_qpos = offset + last_row - seg_start
        run = jnp.logical_and(run, k_start <= max_qpos)

    @pl.when(run)
    def _compute():
        q = q_ref[0]                                           # (bq, D)
        k = k_ref[0, :, 0, :]                                  # (bk, D)
        v = v_ref[0, :, 0, :]
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale        # (bq, bk)
        rows = q_start + jax.lax.broadcasted_iota(
            jnp.int32, (block_q, block_k), 0)                  # flat row ids
        slot = k_start + jax.lax.broadcasted_iota(
            jnp.int32, (block_q, block_k), 1)
        mine = jnp.logical_and(rows >= seg_start, rows < seg_end)
        qpos = offset + rows - seg_start
        if window is None:
            kpos = slot                    # full-depth: slot == position
        else:
            # rolling slot s holds the newest position < kv_len congruent
            # to s mod depth: kpos = s + depth·⌊(kv_len−1−s)/depth⌋
            wraps = jnp.maximum(kv_len - 1 - slot, 0) // depth
            kpos = slot + wraps * depth
        mask = jnp.logical_and(mine, slot < n_valid)
        if causal:
            mask = jnp.logical_and(mask, kpos <= qpos)
        if window is not None:
            mask = jnp.logical_and(mask, kpos > qpos - window)
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_ref[:, :1]                                  # (bq, 1)
        l_prev = l_ref[:, :1]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
        p = jnp.exp(s - m_new)
        p = jnp.where(mask, p, 0.0)
        alpha = jnp.exp(m_prev - m_new)                        # (bq, 1)
        l_new = alpha * l_prev + jnp.sum(p, axis=-1, keepdims=True)
        pv = jax.lax.dot_general(
            p.astype(v.dtype), v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)                # (bq, D)
        acc_ref[...] = acc_ref[...] * alpha + pv
        m_ref[...] = jnp.broadcast_to(m_new, m_ref.shape)
        l_ref[...] = jnp.broadcast_to(l_new, l_ref.shape)

    @pl.when(jnp.logical_and(b == n_seqs - 1, ki == n_kv_blocks - 1))
    def _finish():
        l = l_ref[:, :1]
        l = jnp.where(l == 0.0, 1.0, l)     # rows owned by no segment
        o_ref[0] = (acc_ref[...] / l).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "window", "block_q", "interpret"))
def ragged_prefill_paged(q: jax.Array, k: jax.Array, v: jax.Array,
                         page_table: jax.Array, cu_seqlens: jax.Array,
                         q_offsets: Optional[jax.Array] = None,
                         kv_lengths: Optional[jax.Array] = None, *,
                         causal: bool = True, window: Optional[int] = None,
                         block_q: int = 128,
                         interpret: bool = True) -> jax.Array:
    """Paged ragged prefill flash attention.

    The paged generalization of :func:`ragged_prefill_arena`: instead of
    one contiguous arena slot per segment, each segment's KV lives on a
    list of fixed-size PAGES scattered anywhere in a shared pool, and a
    per-segment page table maps logical kv block → physical page.  Pages
    can therefore be SHARED between segments (radix-tree prefix reuse,
    COW forks) — the kernel neither knows nor cares: it reads whatever
    page the table names.

    q: (T, Hq, D) packed flat stream; k, v: (N_pages, page_size, Hkv, D)
    — the FULL page pools with this step's new KV already scatter-written
    at each token's (page, offset); page_table: (B, P_max) int32 physical
    page of each segment's logical page i (entries past the valid length
    may point anywhere live — they are clamped in the index map and never
    computed on); cu_seqlens: (B+1,) flat row offsets; q_offsets: (B,)
    history length per segment; kv_lengths: (B,) valid cache entries
    (history + new).

    Returns (T, Hq, D) with zeros on rows past ``cu_seqlens[-1]``.  One
    kv grid block == one page (block_k = page_size): logical page ki of
    segment b holds absolute positions [ki·ps, (ki+1)·ps), so the shared
    ``_arena_kernel`` math is reused verbatim with the page-id lookup
    replacing the slot-id lookup in the BlockSpec index map.  Pages past
    ``ceil(kv_lengths[b]/ps)`` clamp to the last valid page (a repeated
    block index skips the DMA), so a step streams only the valid pages
    of the segments it serves.

    ``window``: sliding-window width.  The page table is then a RING
    over its P_max entries (§7's rolling arena at page granularity):
    position p lives on logical ring page (p // ps) % P_max at offset
    p % ps, so the ring holds the last min(kv_lengths, ps·P_max)
    positions.  The shared ``_arena_kernel`` rolling math reconstructs
    each slot's absolute position modularly with depth = ps·P_max and
    masks to (qpos − window, qpos] — identical to
    :func:`ragged_prefill_arena`'s windowed form with the page-id
    lookup replacing the slot-id lookup.
    """
    t, hq, d = q.shape
    ps, hkv = k.shape[1], k.shape[2]
    b, p_max = page_table.shape
    rep = hq // hkv
    if q_offsets is None:
        q_offsets = jnp.zeros((b,), jnp.int32)
    if kv_lengths is None:
        kv_lengths = jnp.full((b,), ps * p_max, jnp.int32)

    block_q = min(block_q, max(t, 1))
    block_k = ps                   # the page IS the kv block
    t_pad = -(-t // block_q) * block_q
    qt = jnp.moveaxis(q, 1, 0)                                 # (Hq, T, D)
    if t_pad != t:
        qt = jnp.pad(qt, ((0, 0), (0, t_pad - t), (0, 0)))
    nq, nk = t_pad // block_q, p_max

    def kv_map(h, qi, bb, ki, pt_ref, cu_ref, off_ref, len_ref):
        # clamp past-the-length logical pages to the last valid one: a
        # repeated physical page is not re-fetched, so invalid pages
        # cost no DMA.  Ring tables have every page valid once
        # kv_len ≥ ps·P_max.
        n_valid = jnp.minimum(len_ref[bb], ps * p_max) \
            if window is not None else len_ref[bb]
        last = jnp.maximum(n_valid - 1, 0) // block_k
        return (pt_ref[bb, jnp.minimum(ki, last)], 0, h // rep, 0)

    kern = functools.partial(
        _arena_kernel, scale=d ** -0.5, causal=causal, window=window,
        depth=ps * p_max, block_q=block_q, block_k=block_k, n_seqs=b,
        n_kv_blocks=nk)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=4,
        grid=(hq, nq, b, nk),
        in_specs=[
            pl.BlockSpec((1, block_q, d), lambda h, qi, bb, ki, *_: (h, qi, 0)),
            pl.BlockSpec((1, block_k, 1, d), kv_map),
            pl.BlockSpec((1, block_k, 1, d), kv_map),
        ],
        out_specs=pl.BlockSpec((1, block_q, d),
                               lambda h, qi, bb, ki, *_: (h, qi, 0)),
        scratch_shapes=[
            pltpu.VMEM((block_q, LANES), jnp.float32),
            pltpu.VMEM((block_q, LANES), jnp.float32),
            pltpu.VMEM((block_q, d), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        kern,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((hq, t_pad, d), q.dtype),
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel",
                                 "arbitrary", "arbitrary")),
        interpret=interpret,
    )(page_table.astype(jnp.int32), cu_seqlens.astype(jnp.int32),
      q_offsets.astype(jnp.int32), kv_lengths.astype(jnp.int32), qt, k, v)
    return jnp.moveaxis(out[:, :t], 0, 1)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "window", "block_q", "block_k", "interpret"))
def ragged_prefill_arena(q: jax.Array, k: jax.Array, v: jax.Array,
                         slot_map: jax.Array, cu_seqlens: jax.Array,
                         q_offsets: Optional[jax.Array] = None,
                         kv_lengths: Optional[jax.Array] = None, *,
                         causal: bool = True, window: Optional[int] = None,
                         block_q: int = 128, block_k: int = 128,
                         interpret: bool = True) -> jax.Array:
    """Arena-resident ragged prefill flash attention.

    q: (T, Hq, D) packed flat stream; k, v: (N_slots, S_max, Hkv, D) —
    the FULL per-layer KV arenas with this step's new KV already
    scatter-written at each token's (slot, position); slot_map: (B,)
    arena slot of each segment (pad segments point at any live slot —
    they own no stream rows, so the block is fetched at most once and
    never computed on); cu_seqlens: (B+1,) flat row offsets;
    q_offsets: (B,) history length per segment; kv_lengths: (B,) valid
    cache entries (history + new).

    Returns (T, Hq, D) with zeros on rows past ``cu_seqlens[-1]``.  The
    arena slot axis is indexed inside the BlockSpec index maps via
    scalar prefetch and kv blocks past ``kv_lengths[b]`` clamp to the
    last valid block, so one packed step streams only the valid cache
    prefixes of the segments it serves — never whole slots and never
    slots the step doesn't own.

    ``window``: sliding-window width.  The arena is then a ROLLING
    cache: its slot depth D (= k.shape[1]) is window + margin deep and
    holds the last min(kv_lengths, D) positions, written modularly at
    position % D by the layer.  KV block iteration clamps to the last
    ceil(min(kv_len, D)/block_k) valid blocks of the slot, the kernel
    reconstructs each slot's absolute position modularly, and the mask
    keeps only keys inside (qpos − window, qpos] — so a step streams
    O(min(cached, window) + margin) cache rows per segment, not
    O(S_max).  (The decode kernel tightens its grid to the window's
    own blocks; here a segment's queries span up to the whole valid
    range, so every valid block stays on the grid.)
    """
    t, hq, d = q.shape
    s, hkv = k.shape[1], k.shape[2]
    b = slot_map.shape[0]
    rep = hq // hkv
    if q_offsets is None:
        q_offsets = jnp.zeros((b,), jnp.int32)
    if kv_lengths is None:
        kv_lengths = jnp.full((b,), s, jnp.int32)

    block_q = min(block_q, max(t, 1))
    # the arena's S axis is never padded (padding would copy the arena)
    block_k = _largest_divisor(s, block_k)
    t_pad = -(-t // block_q) * block_q
    qt = jnp.moveaxis(q, 1, 0)                                 # (Hq, T, D)
    if t_pad != t:
        qt = jnp.pad(qt, ((0, 0), (0, t_pad - t), (0, 0)))
    nq, nk = t_pad // block_q, s // block_k

    def kv_map(h, qi, bb, ki, slot_ref, cu_ref, off_ref, len_ref):
        # clamp past-the-length blocks to the last valid one: a repeated
        # block index is not re-fetched, so invalid blocks cost no DMA.
        # Rolling arenas have every slot row valid once kv_len ≥ depth.
        n_valid = jnp.minimum(len_ref[bb], s) if window is not None \
            else len_ref[bb]
        last = jnp.maximum(n_valid - 1, 0) // block_k
        return (slot_ref[bb], jnp.minimum(ki, last), h // rep, 0)

    kern = functools.partial(
        _arena_kernel, scale=d ** -0.5, causal=causal, window=window,
        depth=s, block_q=block_q, block_k=block_k, n_seqs=b, n_kv_blocks=nk)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=4,
        grid=(hq, nq, b, nk),
        in_specs=[
            pl.BlockSpec((1, block_q, d), lambda h, qi, bb, ki, *_: (h, qi, 0)),
            pl.BlockSpec((1, block_k, 1, d), kv_map),
            pl.BlockSpec((1, block_k, 1, d), kv_map),
        ],
        out_specs=pl.BlockSpec((1, block_q, d),
                               lambda h, qi, bb, ki, *_: (h, qi, 0)),
        scratch_shapes=[
            pltpu.VMEM((block_q, LANES), jnp.float32),
            pltpu.VMEM((block_q, LANES), jnp.float32),
            pltpu.VMEM((block_q, d), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        kern,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((hq, t_pad, d), q.dtype),
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel",
                                 "arbitrary", "arbitrary")),
        interpret=interpret,
    )(slot_map.astype(jnp.int32), cu_seqlens.astype(jnp.int32),
      q_offsets.astype(jnp.int32), kv_lengths.astype(jnp.int32), qt, k, v)
    return jnp.moveaxis(out[:, :t], 0, 1)
