"""Flash attention Pallas kernel for prefill AND re-prefill.

TPU-native design (HBM→VMEM→MXU):
  * grid = (B, Hq, n_q_blocks, n_kv_blocks); the kv axis is sequential
    ("arbitrary") so the online-softmax accumulator lives in VMEM scratch.
  * blocks are MXU-aligned: block_q × head_dim and block_k × head_dim
    tiles, fp32 accumulation via ``preferred_element_type``.
  * re-prefill = same kernel with per-request ``q_offsets`` (history
    length): query absolute positions are offset + arange, so causal
    masking over a KV cache longer than the query block is exact.
  * GQA without KV duplication: the kv-head index is derived from the
    q-head grid index (h // rep) in the BlockSpec index maps.
  * causal / sliding-window block skipping: fully-masked kv blocks are
    skipped via ``pl.when`` (no MXU work, no VMEM traffic beyond the
    prefetch the pipeline already issued).

Scratch m/l are kept as (block_q, 128) lane-replicated tiles — the TPU
layout for per-row scalars.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels._compat import CompilerParams

NEG_INF = -1e30
LANES = 128


def _kernel(off_ref, len_ref, q_ref, k_ref, v_ref, o_ref,
            m_ref, l_ref, acc_ref, *, scale: float, causal: bool,
            window: Optional[int], block_q: int, block_k: int,
            n_kv_blocks: int):
    ki = pl.program_id(3)
    qi = pl.program_id(2)
    offset = off_ref[0, 0]
    kv_len = len_ref[0, 0]

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q_start = offset + qi * block_q
    k_start = ki * block_k

    # block-level skip: entire kv block after the last query position,
    # or entirely before the sliding window of the first query position
    run = k_start <= q_start + block_q - 1 if causal else True
    if window is not None:
        run = jnp.logical_and(run, k_start + block_k - 1 > q_start - window)
    run = jnp.logical_and(run, k_start < kv_len)

    @pl.when(run)
    def _compute():
        q = q_ref[0, 0]                                        # (bq, D)
        k = k_ref[0, 0]                                        # (bk, D)
        v = v_ref[0, 0]
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale        # (bq, bk)
        qpos = q_start + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 0)
        kpos = k_start + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 1)
        mask = kpos < kv_len
        if causal:
            mask = jnp.logical_and(mask, kpos <= qpos)
        if window is not None:
            mask = jnp.logical_and(mask, kpos > qpos - window)
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_ref[:, :1]                                  # (bq, 1)
        l_prev = l_ref[:, :1]
        m_cur = jnp.max(s, axis=-1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(s - m_new)
        p = jnp.where(mask, p, 0.0)
        alpha = jnp.exp(m_prev - m_new)                        # (bq, 1)
        l_new = alpha * l_prev + jnp.sum(p, axis=-1, keepdims=True)
        pv = jax.lax.dot_general(
            p.astype(v.dtype), v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)                # (bq, D)
        acc_ref[...] = acc_ref[...] * alpha + pv
        m_ref[...] = jnp.broadcast_to(m_new, m_ref.shape)
        l_ref[...] = jnp.broadcast_to(l_new, l_ref.shape)

    @pl.when(ki == n_kv_blocks - 1)
    def _finish():
        l = l_ref[:, :1]
        l = jnp.where(l == 0.0, 1.0, l)                        # fully-masked rows
        o_ref[0, 0] = (acc_ref[...] / l).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "window", "block_q", "block_k", "interpret"))
def flash_attn(q: jax.Array, k: jax.Array, v: jax.Array,
               q_offsets: Optional[jax.Array] = None,
               kv_lengths: Optional[jax.Array] = None, *,
               causal: bool = True, window: Optional[int] = None,
               block_q: int = 128, block_k: int = 128,
               interpret: bool = True) -> jax.Array:
    """q: (B, Lq, Hq, D); k, v: (B, S, Hkv, D).  Returns (B, Lq, Hq, D).

    q_offsets: (B,) int32 history length per request (re-prefill);
    kv_lengths: (B,) valid KV entries (defaults to S).
    """
    b, lq, hq, d = q.shape
    s, hkv = k.shape[1], k.shape[2]
    rep = hq // hkv
    if q_offsets is None:
        q_offsets = jnp.zeros((b,), jnp.int32)
    if kv_lengths is None:
        kv_lengths = jnp.full((b,), s, jnp.int32)

    block_q = min(block_q, max(lq, 1))
    block_k = min(block_k, s)
    lq_pad = -(-lq // block_q) * block_q
    s_pad = -(-s // block_k) * block_k
    qt = jnp.moveaxis(q, 2, 1)                                 # (B, Hq, Lq, D)
    kt = jnp.moveaxis(k, 2, 1)
    vt = jnp.moveaxis(v, 2, 1)
    if lq_pad != lq:
        qt = jnp.pad(qt, ((0, 0), (0, 0), (0, lq_pad - lq), (0, 0)))
    if s_pad != s:
        kt = jnp.pad(kt, ((0, 0), (0, 0), (0, s_pad - s), (0, 0)))
        vt = jnp.pad(vt, ((0, 0), (0, 0), (0, s_pad - s), (0, 0)))
    nq, nk = lq_pad // block_q, s_pad // block_k

    grid = (b, hq, nq, nk)
    kern = functools.partial(
        _kernel, scale=d ** -0.5, causal=causal, window=window,
        block_q=block_q, block_k=block_k, n_kv_blocks=nk)
    out = pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1), lambda bb, h, qi, ki: (bb, 0)),
            pl.BlockSpec((1, 1), lambda bb, h, qi, ki: (bb, 0)),
            pl.BlockSpec((1, 1, block_q, d), lambda bb, h, qi, ki: (bb, h, qi, 0)),
            pl.BlockSpec((1, 1, block_k, d),
                         lambda bb, h, qi, ki: (bb, h // rep, ki, 0)),
            pl.BlockSpec((1, 1, block_k, d),
                         lambda bb, h, qi, ki: (bb, h // rep, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, d),
                               lambda bb, h, qi, ki: (bb, h, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((b, hq, lq_pad, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, LANES), jnp.float32),
            pltpu.VMEM((block_q, LANES), jnp.float32),
            pltpu.VMEM((block_q, d), jnp.float32),
        ],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(q_offsets.reshape(b, 1).astype(jnp.int32),
      kv_lengths.reshape(b, 1).astype(jnp.int32), qt, kt, vt)
    return jnp.moveaxis(out[:, :, :lq], 1, 2)
