"""Chunked SSD (Mamba2) scan as a Pallas kernel.

The SSD hot loop is the compute core of the mamba2/jamba architectures:
per (batch, head) it alternates a quadratic intra-chunk block (two
(Q×Q)·(Q×HD) matmuls on the MXU) with an O(HD×DS) state update.  Grid =
(B, NH, n_chunks); the chunk axis is sequential and the recurrent state
(HD × DS fp32, e.g. 64×128 = 32 KiB) lives in VMEM scratch — the whole
recurrence never leaves VMEM.

Per-row scalars (dt, cumulative decay) are handled as (Q, 1)-shaped
columns, lane-broadcast where needed.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels._compat import CompilerParams


def _kernel(a_ref, x_ref, dt_ref, b_ref, c_ref, h0_ref, y_ref, hout_ref,
            state_ref, *, chunk: int, n_chunks: int):
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _init():
        state_ref[...] = h0_ref[0, 0].astype(jnp.float32)

    a = a_ref[0, 0]                                            # scalar (<0)
    x = x_ref[0, 0].astype(jnp.float32)                        # (Q, HD)
    dt = dt_ref[0, 0].astype(jnp.float32)                      # (Q, 1)... stored (1,Q)
    dt = dt.reshape(chunk, 1)
    bmat = b_ref[0, 0].astype(jnp.float32)                     # (Q, DS)
    cmat = c_ref[0, 0].astype(jnp.float32)                     # (Q, DS)

    la = dt * a                                                # (Q, 1) log-decay
    cum = jnp.cumsum(la, axis=0)                               # (Q, 1)
    # intra-chunk: M[t,s] = exp(cum_t - cum_s) * (C_t·B_s) * dt_s for s<=t
    decay = jnp.exp(cum - cum.reshape(1, chunk))               # (Q, Q)
    tri = (jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
           >= jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1))
    cb = jax.lax.dot_general(cmat, bmat, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)  # (Q, Q)
    m = jnp.where(tri, decay * cb * dt.reshape(1, chunk), 0.0)
    y = jax.lax.dot_general(m, x, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)   # (Q, HD)
    # inter-chunk: y += (C_t * exp(cum_t)) @ state^T
    cdecay = cmat * jnp.exp(cum)                               # (Q, DS)
    y = y + jax.lax.dot_general(cdecay, state_ref[...],
                                (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
    y_ref[0, 0] = y.astype(y_ref.dtype)
    # state update: h' = exp(cum_Q) h + X^T (w ⊙ B),  w_s = exp(cum_Q-cum_s)·dt_s
    w = jnp.exp(cum[chunk - 1] - cum) * dt                     # (Q, 1)
    dstate = jax.lax.dot_general(x, w * bmat, (((0,), (0,)), ((), ())),
                                 preferred_element_type=jnp.float32)  # (HD, DS)
    state_ref[...] = jnp.exp(cum[chunk - 1, 0]) * state_ref[...] + dstate

    @pl.when(ci == n_chunks - 1)
    def _finish():
        hout_ref[0, 0] = state_ref[...]


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_scan(x: jax.Array, dt: jax.Array, a: jax.Array, bmat: jax.Array,
             cmat: jax.Array, init_state: jax.Array, *, chunk: int = 128,
             interpret: bool = True):
    """Chunked SSD scan.

    x: (B, L, NH, HD); dt: (B, L, NH) post-softplus; a: (NH,) negative;
    bmat, cmat: (B, L, NH, DS); init_state: (B, NH, HD, DS) fp32.
    Returns (y (B, L, NH, HD), final_state (B, NH, HD, DS)).
    """
    b, l, nh, hd = x.shape
    ds = bmat.shape[-1]
    chunk = min(chunk, l)
    l_pad = -(-l // chunk) * chunk
    xt = jnp.moveaxis(x, 2, 1)                                 # (B, NH, L, HD)
    dtt = jnp.moveaxis(dt, 2, 1)                               # (B, NH, L)
    bt = jnp.moveaxis(bmat, 2, 1)
    ct = jnp.moveaxis(cmat, 2, 1)
    if l_pad != l:  # dt=0 padding is an exact identity for the state
        xt = jnp.pad(xt, ((0, 0), (0, 0), (0, l_pad - l), (0, 0)))
        dtt = jnp.pad(dtt, ((0, 0), (0, 0), (0, l_pad - l)))
        bt = jnp.pad(bt, ((0, 0), (0, 0), (0, l_pad - l), (0, 0)))
        ct = jnp.pad(ct, ((0, 0), (0, 0), (0, l_pad - l), (0, 0)))
    nc = l_pad // chunk

    kern = functools.partial(_kernel, chunk=chunk, n_chunks=nc)
    y, hout = pl.pallas_call(
        kern,
        grid=(b, nh, nc),
        in_specs=[
            pl.BlockSpec((1, 1), lambda bb, h, ci: (h, 0)),
            pl.BlockSpec((1, 1, chunk, hd), lambda bb, h, ci: (bb, h, ci, 0)),
            pl.BlockSpec((1, 1, chunk), lambda bb, h, ci: (bb, h, ci)),
            pl.BlockSpec((1, 1, chunk, ds), lambda bb, h, ci: (bb, h, ci, 0)),
            pl.BlockSpec((1, 1, chunk, ds), lambda bb, h, ci: (bb, h, ci, 0)),
            pl.BlockSpec((1, 1, hd, ds), lambda bb, h, ci: (bb, h, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, chunk, hd), lambda bb, h, ci: (bb, h, ci, 0)),
            pl.BlockSpec((1, 1, hd, ds), lambda bb, h, ci: (bb, h, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, nh, l_pad, hd), x.dtype),
            jax.ShapeDtypeStruct((b, nh, hd, ds), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((hd, ds), jnp.float32)],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(a.reshape(nh, 1).astype(jnp.float32), xt, dtt, bt, ct, init_state)
    return jnp.moveaxis(y[:, :, :l], 1, 2), hout
