"""Jit'd dispatch wrappers for the Pallas kernels.

``use_pallas()`` decides the execution path:
  * TPU backend → compiled Pallas kernels (the production path);
  * CPU/GPU → interpret-mode Pallas (tests) or the jnp oracle (fast path).

The serving engine and model layers call these wrappers, never the
kernels directly, so the whole system runs identically on this CPU
container and on a real pod.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels import ref as ref_mod
from repro.kernels.decode_attn import decode_attn as _decode_pallas
from repro.kernels.decode_attn import decode_attn_arena as _decode_arena_pallas
from repro.kernels.decode_attn import decode_attn_paged as _decode_paged_pallas
from repro.kernels.flash_attn import flash_attn as _flash_pallas
from repro.kernels.ragged_prefill import ragged_prefill_attn as _ragged_pallas
from repro.kernels.ragged_prefill import \
    ragged_prefill_arena as _ragged_arena_pallas
from repro.kernels.ragged_prefill import \
    ragged_prefill_paged as _ragged_paged_pallas
from repro.kernels.sampling import MAX_BIAS  # noqa: F401  (re-export)
from repro.kernels.sampling import fused_sample as _fused_sample_pallas
from repro.kernels.ssd_scan import ssd_scan as _ssd_pallas

_FORCE: Optional[str] = None  # None=auto, "pallas", "ref"


def set_backend(mode: Optional[str]) -> None:
    """mode: None (auto), 'pallas' (interpret off-TPU), or 'ref'."""
    global _FORCE
    _FORCE = mode


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _use_pallas() -> bool:
    if _FORCE == "pallas":
        return True
    if _FORCE == "ref":
        return False
    return _on_tpu()


def mha(q, k, v, q_offsets=None, kv_lengths=None, *, causal=True,
        window=None, block_q=128, block_k=128):
    """Prefill / re-prefill attention.  See kernels.flash_attn."""
    if _use_pallas():
        return _flash_pallas(q, k, v, q_offsets, kv_lengths, causal=causal,
                             window=window, block_q=block_q, block_k=block_k,
                             interpret=not _on_tpu())
    return ref_mod.ref_flash_attn(q, k, v, q_offsets=q_offsets,
                                  kv_lengths=kv_lengths, window=window,
                                  causal=causal)


def ragged_mha(q, k, v, cu_seqlens, q_offsets=None, kv_lengths=None, *,
               causal=True, block_q=128, block_k=128):
    """Packed padding-free prefill attention.  q: (T, Hq, D) flat stream;
    k, v: (B, S, Hkv, D).  See kernels.ragged_prefill."""
    if _use_pallas():
        return _ragged_pallas(q, k, v, cu_seqlens, q_offsets, kv_lengths,
                              causal=causal, block_q=block_q,
                              block_k=block_k, interpret=not _on_tpu())
    return ref_mod.ref_ragged_prefill(q, k, v, cu_seqlens,
                                      q_offsets=q_offsets,
                                      kv_lengths=kv_lengths, causal=causal)


def ragged_mha_arena(q, k, v, slot_map, cu_seqlens, q_offsets=None,
                     kv_lengths=None, *, causal=True, window=None,
                     block_q=128, block_k=128):
    """Arena-resident packed prefill attention.  q: (T, Hq, D) flat
    stream; k, v: (N_slots, S_max, Hkv, D) full arenas; slot_map: (B,)
    arena slot per segment.  ``window`` selects the rolling
    (window-deep, modularly written) arena form.  See
    kernels.ragged_prefill."""
    if _use_pallas():
        return _ragged_arena_pallas(q, k, v, slot_map, cu_seqlens,
                                    q_offsets, kv_lengths, causal=causal,
                                    window=window, block_q=block_q,
                                    block_k=block_k,
                                    interpret=not _on_tpu())
    return ref_mod.ref_ragged_prefill_arena(q, k, v, slot_map, cu_seqlens,
                                            q_offsets=q_offsets,
                                            kv_lengths=kv_lengths,
                                            causal=causal, window=window)


def ragged_mha_paged(q, k, v, page_table, cu_seqlens, q_offsets=None,
                     kv_lengths=None, *, causal=True, window=None,
                     block_q=128):
    """Paged packed prefill attention.  q: (T, Hq, D) flat stream;
    k, v: (N_pages, page_size, Hkv, D) full page pools; page_table:
    (B, P_max) physical page per logical kv block — pages may be shared
    between segments (prefix reuse, COW forks).  ``window`` selects the
    ring-table (rolling at page granularity) form.  See
    kernels.ragged_prefill.ragged_prefill_paged."""
    if _use_pallas():
        return _ragged_paged_pallas(q, k, v, page_table, cu_seqlens,
                                    q_offsets, kv_lengths, causal=causal,
                                    window=window, block_q=block_q,
                                    interpret=not _on_tpu())
    return ref_mod.ref_ragged_prefill_paged(q, k, v, page_table, cu_seqlens,
                                            q_offsets=q_offsets,
                                            kv_lengths=kv_lengths,
                                            causal=causal, window=window)


def decode(q, k, v, lengths, *, block_k=512):
    """Single-token flash decode.  q: (B, Hq, D)."""
    if _use_pallas():
        return _decode_pallas(q, k, v, lengths, block_k=block_k,
                              interpret=not _on_tpu())
    return ref_mod.ref_decode_attn(q, k, v, lengths)


def decode_arena(q, k, v, slot_map, lengths, *, window=None, block_k=512):
    """Arena-resident single-token flash decode.  q: (B, Hq, D);
    k, v: (N_slots, S, Hkv, D) full arenas; slot_map/lengths: (B,).
    ``window`` selects the rolling (window-deep, modularly written)
    arena form.  See kernels.decode_attn.decode_attn_arena."""
    if _use_pallas():
        return _decode_arena_pallas(q, k, v, slot_map, lengths,
                                    window=window, block_k=block_k,
                                    interpret=not _on_tpu())
    return ref_mod.ref_decode_attn_arena(q, k, v, slot_map, lengths,
                                         window=window)


def decode_paged(q, k, v, page_table, lengths, *, window=None):
    """Paged single-token flash decode.  q: (B, Hq, D); k, v:
    (N_pages, page_size, Hkv, D) full page pools; page_table: (B, P_max);
    lengths: (B,).  ``window`` selects the ring-table (rolling at page
    granularity) form.  See kernels.decode_attn.decode_attn_paged."""
    if _use_pallas():
        return _decode_paged_pallas(q, k, v, page_table, lengths,
                                    window=window, interpret=not _on_tpu())
    return ref_mod.ref_decode_attn_paged(q, k, v, page_table, lengths,
                                         window=window)


def fused_sample(logits, temp, top_k, top_p, bias_ids, bias_vals, u, draft):
    """Fused on-device sampling: bias → temperature → exact top-k →
    tie-inclusive top-p → inverse-CDF draw, plus the speculative
    accept/resample outputs.  logits: (R, V); returns (token (R,) int32,
    p_draft (R,) float32, alt (R,) int32) — full-vocab rows never reach
    host.  See kernels.sampling."""
    if _use_pallas():
        return _fused_sample_pallas(logits, temp, top_k, top_p, bias_ids,
                                    bias_vals, u, draft,
                                    interpret=not _on_tpu())
    return ref_mod.ref_fused_sample(logits, temp, top_k, top_p, bias_ids,
                                    bias_vals, u, draft)


def ssd(x, dt, a, bmat, cmat, init_state, *, chunk=128):
    """Chunked SSD scan.  See kernels.ssd_scan."""
    if _use_pallas():
        return _ssd_pallas(x, dt, a, bmat, cmat, init_state, chunk=chunk,
                           interpret=not _on_tpu())
    return ref_mod.ref_ssd_scan(x, dt, a, bmat, cmat, init_state=init_state)
