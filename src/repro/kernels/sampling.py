"""Fused on-device sampling kernel (DESIGN.md §10).

One grid row per logits row: apply additive logit bias, temperature,
EXACT top-k (kth-value threshold, ties kept) and tie-inclusive top-p
truncation, then draw the token by inverse CDF from ONE uniform — plus
the speculative-decoding outputs: the filtered probability of a draft
token (the accept test ``u_acc < p_draft``) and the residual resample
token with the draft zeroed out (the reject commit).  Greedy rows
(``temp <= 0``) short-circuit to the biased argmax.

Only the (R,) token ids leave the device — never the (R, V) logits —
which closes the last host round-trip the fused greedy slice (PR 5)
left open for non-greedy sessions.

Key derivation: uniforms are drawn HOST-side from each session's
replayable ``np.random.Generator`` (seeded from ``SamplingParams.seed``
or the session id) and shipped as (R,) scalars.  Host and device
sampling therefore consume the SAME uniform stream in the same order —
``serving/sampling.py`` is the bit-level oracle, and a session can hop
between fused and host paths mid-stream without forking its rng.

Exactness over a sort-free kernel: both truncations reduce to a value
threshold, and float32 ordering equals int32 ordering of the monotone
key ``bits >= 0 ? bits : bits ^ 0x7fffffff``, so the kth largest value
(top-k) and the minimal kept probability (top-p) are found by a 31-step
binary descent over key bits — O(V log) elementwise work, no sort, no
scatter, and bit-identical thresholds to ``np.partition`` on host.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels._compat import CompilerParams

NEG = np.float32(-1e30)
LANES = 128       # output lane width: scalars broadcast across one tile
MAX_BIAS = 8      # logit-bias entries per row (engine falls back past it)
_SIGN_LOW = np.int32(0x7FFFFFFF)


def _float_key(x: jax.Array) -> jax.Array:
    """Monotone int32 key: x < y  ⟺  key(x) < key(y) (float32, no NaN).
    Positives keep their bits; negatives flip the low 31 so larger
    magnitude sorts lower.  Lets value thresholds be searched bitwise."""
    bits = jax.lax.bitcast_convert_type(x, jnp.int32)
    return jnp.where(bits >= 0, bits, bits ^ _SIGN_LOW)


def _kth_key(keys: jax.Array, valid: jax.Array, k: jax.Array) -> jax.Array:
    """Max nonnegative T with ``count(valid & keys >= T) >= k`` — the
    kth largest key among ``valid`` when that key is >= 0.  Greedy MSB
    descent: claim each bit iff enough keys still clear the raised bar."""

    def body(i, t):
        cand = t | (np.int32(1) << (30 - i))
        cnt = jnp.sum(jnp.where(valid & (keys >= cand), 1, 0))
        return jnp.where(cnt >= k, cand, t)

    return jax.lax.fori_loop(0, 31, body, np.int32(0))


def _topk_keep(scaled: jax.Array, k: jax.Array) -> jax.Array:
    """Boolean keep-mask of the k largest entries of ``scaled``, TIES
    INCLUDED — exactly ``scaled >= np.partition(scaled, -k)[-k]``.  The
    kth value may be negative, where int32 keys are negative too, so the
    descent runs on whichever side of zero holds the kth key: all of
    ``key & 0x7fffffff`` preserves order WITHIN the negatives."""
    key = _float_key(scaled)
    nonneg = key >= 0
    cnt_nn = jnp.sum(nonneg.astype(jnp.int32))
    t_nn = _kth_key(key, nonneg, k)
    low = key & _SIGN_LOW
    t_ng = _kth_key(low, ~nonneg, k - cnt_nn)
    keep_nn = nonneg & (key >= t_nn)
    keep_ng = nonneg | ((low >= t_ng) & ~nonneg)
    return jnp.where(cnt_nn >= k, keep_nn, keep_ng)


def _topp_theta(probs: jax.Array, top_p: jax.Array) -> jax.Array:
    """Minimal probability theta with strictly-greater mass
    ``G(theta) = sum(probs > theta) < top_p``; keeping ``probs >=
    theta`` is then the tie-inclusive nucleus (equal-prob tokens live or
    die together), matching ``serving.sampling.filtered_probs``.  Probs
    are nonnegative so their bitcasts ARE their keys; descend from the
    MSB, leaving a bit clear iff the predicate already holds with every
    lower bit filled (the minimal-K invariant)."""
    keys = jax.lax.bitcast_convert_type(probs, jnp.int32)

    def body(i, kacc):
        bit = np.int32(1) << (30 - i)
        trial = kacc | (bit - 1)
        mass = jnp.sum(jnp.where(keys > trial, probs, np.float32(0.0)))
        return jnp.where(mass < top_p, kacc, kacc | bit)

    kmin = jax.lax.fori_loop(0, 31, body, np.int32(0))
    return jax.lax.bitcast_convert_type(kmin, jnp.float32)


def _inv_cdf(probs: jax.Array, u: jax.Array) -> jax.Array:
    """Inverse-CDF draw: count of cumulative masses <= u (== host
    ``searchsorted(cumsum, u, side='right')``), clamped into range."""
    v = probs.shape[-1]
    cdf = jnp.cumsum(probs, axis=-1)
    idx = jnp.sum((cdf <= u).astype(jnp.int32))
    return jnp.minimum(idx, v - 1).astype(jnp.int32)


def _sample_core(biased, iota, temp, top_k, top_p, u, draft):
    """Shared math for the kernel body and the jnp oracle.

    biased: (1, V) float32 logits with bias applied; iota: (1, V) int32
    column ids; scalars: temp/top_p/u float32, top_k/draft int32
    (top_k == 0 → off, top_p >= 1 → off).  Returns scalar
    (token int32, p_draft float32, alt int32).  Mirrors
    ``serving.sampling.filtered_probs`` op for op so thresholds agree
    bit-for-bit; only reduction summation order may differ.
    """
    v = biased.shape[-1]
    gtok = jnp.argmax(biased).astype(jnp.int32)

    scaled = biased / jnp.maximum(temp, np.float32(1e-6))
    do_k = (top_k > 0) & (top_k < v)
    keep = _topk_keep(scaled, top_k) | ~do_k
    scaled = jnp.where(keep, scaled, NEG)

    probs = jnp.exp(scaled - jnp.max(scaled))
    probs = probs / jnp.sum(probs)
    do_p = (top_p > np.float32(0.0)) & (top_p < np.float32(1.0))
    keep = (probs >= _topp_theta(probs, top_p)) | ~do_p
    scaled = jnp.where(keep, scaled, NEG)

    probs = jnp.exp(scaled - jnp.max(scaled))
    probs = probs / jnp.sum(probs)
    stok = _inv_cdf(probs, u)

    dcol = iota == jnp.clip(draft, 0, v - 1)
    p_d = jnp.sum(jnp.where(dcol, probs, np.float32(0.0)))
    # residual distribution for a deterministic (point-mass) draft:
    # p with the draft zeroed, renormalized — the exact reject commit
    resid = jnp.where(dcol, np.float32(0.0), probs)
    mass = jnp.sum(resid)
    salt = _inv_cdf(resid / jnp.maximum(mass, np.float32(1e-30)), u)
    salt = jnp.where(mass > 0, salt, stok)

    greedy = temp <= np.float32(0.0)
    token = jnp.where(greedy, gtok, stok)
    p_draft = jnp.where(greedy, (gtok == draft).astype(jnp.float32), p_d)
    alt = jnp.where(greedy, gtok, salt)
    return token, p_draft, alt


def _bias_row(row, iota, bias_ids, bias_vals):
    """Additive logit bias from up to MAX_BIAS (id, val) pairs; id < 0
    is an empty entry.  Out-of-range ids match no column — the host
    path ignores them the same way."""
    for j in range(MAX_BIAS):
        row = jnp.where(iota == bias_ids[j], row + bias_vals[j], row)
    return row


def _fused_sample_kernel(temp_ref, topk_ref, topp_ref, u_ref, draft_ref,
                         bids_ref, bvals_ref, logits_ref,
                         tok_ref, pd_ref, alt_ref):
    r = pl.program_id(0)
    row = logits_ref[...].astype(jnp.float32)               # (1, V)
    v = row.shape[-1]
    iota = jax.lax.broadcasted_iota(jnp.int32, (1, v), 1)
    for j in range(MAX_BIAS):
        row = jnp.where(iota == bids_ref[r, j], row + bvals_ref[r, j], row)
    token, p_draft, alt = _sample_core(
        row, iota, temp_ref[r], topk_ref[r], topp_ref[r],
        u_ref[r], draft_ref[r])
    tok_ref[...] = jnp.full((1, LANES), token, jnp.int32)
    pd_ref[...] = jnp.full((1, LANES), p_draft, jnp.float32)
    alt_ref[...] = jnp.full((1, LANES), alt, jnp.int32)


@functools.partial(jax.jit, static_argnames=("interpret",))
def fused_sample(logits, temp, top_k, top_p, bias_ids, bias_vals, u,
                 draft, *, interpret: bool = False):
    """Sample R rows on device.  logits: (R, V); temp/top_p/u: (R,)
    float32; top_k/draft: (R,) int32; bias_ids/bias_vals: (R, MAX_BIAS).
    Returns (token (R,) int32, p_draft (R,) float32, alt (R,) int32);
    only these (R,)-sized results ever cross to host."""
    r, v = logits.shape
    outs = [jax.ShapeDtypeStruct((r, LANES), jnp.int32),
            jax.ShapeDtypeStruct((r, LANES), jnp.float32),
            jax.ShapeDtypeStruct((r, LANES), jnp.int32)]
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=7,
        grid=(r,),
        in_specs=[pl.BlockSpec((1, v), lambda i, *_: (i, 0))],
        out_specs=[pl.BlockSpec((1, LANES), lambda i, *_: (i, 0))] * 3,
    )
    tok, p_draft, alt = pl.pallas_call(
        _fused_sample_kernel,
        grid_spec=grid_spec,
        out_shape=outs,
        compiler_params=CompilerParams(dimension_semantics=("arbitrary",)),
        interpret=interpret,
    )(jnp.asarray(temp, jnp.float32), jnp.asarray(top_k, jnp.int32),
      jnp.asarray(top_p, jnp.float32), jnp.asarray(u, jnp.float32),
      jnp.asarray(draft, jnp.int32), jnp.asarray(bias_ids, jnp.int32),
      jnp.asarray(bias_vals, jnp.float32),
      jnp.asarray(logits, jnp.float32))
    return tok[:, 0], p_draft[:, 0], alt[:, 0]


@jax.jit
def fused_sample_reference(logits, temp, top_k, top_p, bias_ids,
                           bias_vals, u, draft):
    """jnp oracle: the same shared core vmapped over rows (the XLA
    fallback path off-TPU; also what `kernels.ref.ref_fused_sample`
    re-exports)."""
    v = logits.shape[-1]
    iota = jax.lax.broadcasted_iota(jnp.int32, (1, v), 1)

    def row_fn(row, t, k, p, uu, d, bi, bv):
        biased = _bias_row(row[None, :].astype(jnp.float32), iota, bi, bv)
        return _sample_core(biased, iota, t, k, p, uu, d)

    return jax.vmap(row_fn)(
        jnp.asarray(logits, jnp.float32), jnp.asarray(temp, jnp.float32),
        jnp.asarray(top_k, jnp.int32), jnp.asarray(top_p, jnp.float32),
        jnp.asarray(u, jnp.float32), jnp.asarray(draft, jnp.int32),
        jnp.asarray(bias_ids, jnp.int32), jnp.asarray(bias_vals, jnp.float32))
