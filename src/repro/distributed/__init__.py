from repro.distributed.sharding import (  # noqa: F401
    ShardingRules,
    use_rules,
    current_rules,
    spec_for,
    constrain,
    tree_shardings,
    TRAIN_RULES,
    SERVE_RULES,
)
