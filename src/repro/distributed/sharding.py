"""Logical-axis sharding rules (MaxText-style).

Model code annotates tensors with *logical* axis names ("batch", "seq",
"embed", "heads", ...).  A :class:`ShardingRules` context maps logical
names to mesh axes; :func:`constrain` applies
``jax.lax.with_sharding_constraint`` when a mesh is active and silently
no-ops on a single host device (tests, smoke runs).

Divisibility guard: a logical→mesh mapping is dropped per-tensor when the
dimension size is not divisible by the mesh-axis size (e.g. 8 KV heads on
a 16-way model axis), so one rule table serves every architecture.
"""
from __future__ import annotations

import contextlib
import dataclasses
import math
import threading
from typing import Any, Dict, Optional, Sequence, Tuple, Union

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

AxisVal = Union[None, str, Tuple[str, ...]]


# Rule tables: logical axis -> mesh axis (or tuple). "pod" present only on
# multi-pod meshes; mesh_axis_size() treats missing axes as 1.
TRAIN_RULES: Dict[str, AxisVal] = {
    "batch": ("pod", "data"),
    "seq": None,
    "embed": "data",            # FSDP: weight in-dim sharded over data
    "expert_embed": "data",     # MoE expert weight FSDP (hillclimb: None)
    "embed_act": None,          # activations keep d_model unsharded
    "heads": "model",
    "kv_heads": "model",
    "head_dim": None,
    "mlp": "model",
    "vocab": "model",
    "experts": "model",
    "expert_mlp": "model",
    "ssm_inner": "model",
    "ssm_heads": "model",
    "ssm_state": None,
    "conv_ch": "model",
    "cache_seq": None,
    "opt_shard": "data",        # ZeRO-1 extra partition for optimizer state
}

SERVE_RULES: Dict[str, AxisVal] = {
    "batch": "data",
    "seq": None,
    "embed": None,              # weights TP-only, replicated over data
    "expert_embed": None,
    "embed_act": None,
    "heads": "model",
    "kv_heads": "model",
    "head_dim": None,
    "mlp": "model",
    "vocab": "model",
    "experts": "model",
    "expert_mlp": "model",
    "ssm_inner": "model",
    "ssm_heads": "model",
    "ssm_state": None,
    "conv_ch": "model",
    "cache_seq": "model",       # flash-decode: KV seq sharded over model
    "opt_shard": None,
}


@dataclasses.dataclass
class ShardingRules:
    mesh: Optional[Mesh]
    rules: Dict[str, AxisVal]

    def axis_size(self, axis: AxisVal) -> int:
        if axis is None or self.mesh is None:
            return 1
        names = (axis,) if isinstance(axis, str) else axis
        n = 1
        for a in names:
            n *= self.mesh.shape.get(a, 1)
        return n

    def mesh_axes(self, logical: Optional[str]) -> AxisVal:
        if logical is None:
            return None
        val = self.rules.get(logical)
        if val is None or self.mesh is None:
            return None
        names = (val,) if isinstance(val, str) else tuple(val)
        names = tuple(a for a in names if a in self.mesh.shape)
        if not names:
            return None
        return names[0] if len(names) == 1 else names


_local = threading.local()


def current_rules() -> Optional[ShardingRules]:
    return getattr(_local, "rules", None)


@contextlib.contextmanager
def use_rules(rules: Optional[ShardingRules]):
    prev = current_rules()
    _local.rules = rules
    try:
        yield rules
    finally:
        _local.rules = prev


def _dim_spec(rules: ShardingRules, dim: int, logical: Optional[str]) -> AxisVal:
    axes = rules.mesh_axes(logical)
    if axes is None:
        return None
    if dim % rules.axis_size(axes) != 0:
        return None  # divisibility guard: drop mapping
    return axes


def spec_for(shape: Sequence[int], logical_axes: Sequence[Optional[str]],
             rules: Optional[ShardingRules] = None) -> P:
    rules = rules or current_rules()
    if rules is None or rules.mesh is None:
        return P()
    assert len(shape) == len(logical_axes), (shape, logical_axes)
    used: set = set()
    parts = []
    for dim, name in zip(shape, logical_axes):
        ax = _dim_spec(rules, dim, name)
        # a mesh axis may appear at most once in a PartitionSpec
        if ax is not None:
            flat = (ax,) if isinstance(ax, str) else ax
            if any(a in used for a in flat):
                ax = None
            else:
                used.update(flat)
        parts.append(ax)
    return P(*parts)


def constrain(x: jax.Array, *logical_axes: Optional[str]) -> jax.Array:
    """with_sharding_constraint under the active rules; no-op without mesh."""
    rules = current_rules()
    if rules is None or rules.mesh is None:
        return x
    spec = spec_for(x.shape, logical_axes, rules)
    return jax.lax.with_sharding_constraint(x, NamedSharding(rules.mesh, spec))


def tree_shardings(params: Any, axes_tree: Any,
                   rules: ShardingRules) -> Any:
    """NamedSharding tree for a param tree + parallel logical-axes tree."""
    if rules.mesh is None:
        return jax.tree.map(lambda _: None, params)

    def one(leaf, axes):
        if axes is None:
            return NamedSharding(rules.mesh, P())
        return NamedSharding(rules.mesh, spec_for(leaf.shape, axes, rules))

    return jax.tree.map(one, params, axes_tree,
                        is_leaf=lambda x: x is None)


def constrain_tree(tree: Any, axes_tree: Any) -> Any:
    """Apply ``constrain`` leaf-wise (axes_tree: tuples of logical names).

    Used on scan/loop carries (gradient accumulators, KV-cache carries):
    XLA's sharding propagation can lose loop-carried shardings and fall
    back to replication — re-constraining each iteration pins them.
    """
    rules = current_rules()
    if rules is None or rules.mesh is None:
        return tree

    def one(leaf, axes):
        if axes is None or not hasattr(leaf, "shape"):
            return leaf
        return constrain(leaf, *axes)

    return jax.tree.map(one, tree, axes_tree,
                        is_leaf=lambda x: x is None)


def logical_sharding(shape: Sequence[int], logical_axes: Sequence[Optional[str]],
                     rules: ShardingRules) -> Optional[NamedSharding]:
    if rules.mesh is None:
        return None
    return NamedSharding(rules.mesh, spec_for(shape, logical_axes, rules))


def pad_to_multiple(n: int, m: int) -> int:
    return int(math.ceil(n / m) * m)
