from repro.sim.costmodel import CostModel, H200_32B, H200_14B, H200_7B  # noqa: F401
from repro.sim.simulator import ClusterSim, SimConfig  # noqa: F401
from repro.sim.workload import (WorkloadConfig, lmsys_like_requests,  # noqa: F401
                                closed_loop_clients, length_stats)
