"""Discrete-event cluster simulator.

Drives the *same* policy objects (core.scheduler) the real engine uses,
under a calibrated cost model, to reproduce the paper's experiments at
H200-cluster scale on this CPU-only container.  Supports:

  * shared-queue (temporal disaggregation, N ≥ 1 instances pulling from
    one policy) and routed (per-instance policies + router) topologies;
  * routers: round_robin, least_loaded (SGLang-router-like), pool
    (PLA spatial: classify → pool → least-loaded member);
  * Algorithm 2 controller with live instance migration between pools;
  * MIX mode (decode sessions co-resident with prefill — Fig.8);
  * closed-loop clients (Fig.1/3/6) and open-loop traces (Fig.7);
  * fault injection: instance failure/join and straggler slowdown.
"""
from __future__ import annotations

import dataclasses
import heapq
import itertools
from typing import Callable, Dict, List, Optional, Sequence, Tuple

from repro.core.buckets import DEFAULT_DECODE_BUCKETS, DecodeBucketLadder
from repro.core.controller import (InstanceStats, Migration,
                                   PressureController)
from repro.core.request import Batch, Request
from repro.core.routing import EngineView, Router
from repro.core.scheduler import BasePolicy, ChunkWork, PoolPolicy
from repro.core.slo import SLOTracker
from repro.sim.costmodel import CostModel


@dataclasses.dataclass
class SimConfig:
    mode: str = "pd"              # "pd" (prefill-only instance) | "mix"
    router: str = "shared"        # shared | round_robin | least_loaded | pool
    control_period: float = 0.0   # >0 enables the pressure controller
    slo_ttft: Optional[float] = 0.4
    seed: int = 0
    max_events: int = 5_000_000
    # decode-only ticks price through the arena-resident decode ladder
    # (DESIGN.md §5), mirroring the real engine's DecodeBucketExecutor;
    # overflow falls back to the dense per-count pricing like the engine
    decode_buckets: Tuple[int, ...] = DEFAULT_DECODE_BUCKETS
    # packed prefill / mixed / chunk ticks run arena-resident (§6):
    # O(history + new) KV rows per step.  arena_prefill=False mirrors
    # the legacy engine — every packed step pays the whole-slot
    # gather/scatter round-trip of 2 · packed_seqs · arena_s_max rows
    arena_prefill: bool = True
    packed_seqs: int = 16          # gathered cache rows (b_max)
    arena_s_max: int = 256         # arena slot depth S_max
    # sliding-window width (DESIGN.md §7): mirrors the engine's rolling
    # windowed arena — decode ticks bill γ_r on min(cached, window)
    # rows per session, exactly the windowed kernel's HBM stream.
    # (CostModel.window applies the same clamp to prefill pricing.)
    window: Optional[int] = None
    # paged KV arena with radix prefix reuse (DESIGN.md §8): with
    # prefix_reuse on, admission converts each request's annotated
    # ``reusable_prefix`` — rounded DOWN to page granularity, capped so
    # at least one new token survives (the engine's match cap) — from
    # new tokens into history: the turn is billed suffix-prefill +
    # history reads, exactly what the paged engine executes.
    # (CostModel.page_size separately prices the page-table walk.)
    page_size: Optional[int] = None
    prefix_reuse: bool = False
    # §12 host-tier page spill: capacity (in pages) of the host pool
    # that catches device-evicted prefix pages.  Requests annotate the
    # host-resident part of their reusable prefix (Request.host_prefix);
    # with a pool those tokens stay adoptable but bill
    # CostModel.swap_in_time for the PCIe promotion, without one they
    # were dropped at eviction and fall out of the adoptable prefix.
    host_pool_pages: int = 0
    # §9 spatial disaggregation: when a prefill-role instance finishes a
    # request with decode budget, the session's KV hands off (device-to-
    # device, priced by CostModel.handoff_time) to the least-decode-
    # loaded non-prefill instance instead of decoding in place —
    # mirroring ServeCluster._maybe_migrate on the real engines.
    decode_handoff: bool = False
    # §10 speculative decoding: decode-only ticks become verify steps.
    # Each session's segment carries 1 + spec_k stream tokens, priced by
    # CostModel.spec_step_time (one amortized weight read for the whole
    # dispatch), and commits the EXPECTED 1 + round(spec_accept·spec_k)
    # tokens.  Fused decode rows inside mixed ticks stay 1-token in the
    # model (conservative: the real engine speculates there too).
    speculative: bool = False
    spec_k: int = 4
    spec_accept: float = 0.7
    # §11 fault tolerance: when an instance fails, its in-flight decode
    # sessions are recovered by PRICED re-prefill reconstruction on a
    # survivor (a synthetic recovery request of the session's full
    # cached context — mirroring ServeCluster's recovery path) instead
    # of being silently dropped as they used to be.
    recovery: bool = True
    # §11 SLO-aware admission control: reject an arrival whose
    # CostModel-predicted TTFT already violates its deadline (fail-fast
    # beats a guaranteed violation).  Off = accept everything.
    admission: bool = False


class _Instance:
    def __init__(self, idx: int, policy: Optional[BasePolicy],
                 speed: float = 1.0):
        self.idx = idx
        self.policy = policy          # None in shared mode
        self.speed = speed
        self.busy = False
        self.alive = True
        self.busy_time = 0.0
        self.busy_mark = 0.0          # busy_time at last control period
        # (tokens remaining, cached context length) per in-flight session:
        # decode pricing follows the ACTUAL cached lengths, which grow by
        # one with every generated token
        self.decode_sessions: List[Tuple[int, int]] = []
        self.recent_dev: List[float] = []
        self.prefill_done = 0
        self.current = None

    def advance_decodes(self, m: int = 1) -> None:
        """Every in-flight session emitted ``m`` tokens (1 plain, up to
        1 + k speculative): budgets shrink, cached contexts grow — a
        session with fewer than m tokens left just finishes."""
        self.decode_sessions = [(r - m, h + m)
                                for r, h in self.decode_sessions if r > m]

    @property
    def decode_ctx_lens(self) -> List[int]:
        return [h for _, h in self.decode_sessions]


class ClusterSim:
    def __init__(self, n_instances: int,
                 policy_factory: Callable[[int], BasePolicy],
                 cost: CostModel, cfg: Optional[SimConfig] = None,
                 shared_policy: Optional[BasePolicy] = None,
                 classifier: Optional[Callable[[Request], str]] = None,
                 controller: Optional[PressureController] = None,
                 pools: Optional[Dict[int, str]] = None,
                 router_obj: Optional[Router] = None,
                 roles: Optional[Sequence[str]] = None):
        self.cfg = cfg or SimConfig()
        self.cost = cost
        self.shared = shared_policy
        self.classifier = classifier
        self.controller = controller
        # router_obj: a core.routing Router drives placement over live
        # EngineView snapshots — the SAME object the real ServeCluster
        # uses, so policies tuned here drop into serving unchanged.
        # Takes precedence over the cfg.router string dispatch.
        self.router_obj = router_obj
        self.instances = [
            _Instance(i, None if shared_policy is not None else policy_factory(i))
            for i in range(n_instances)]
        # instance roles for the router + decode handoff ("prefill" =
        # long-prefill pool).  Default derives from PoolPolicy pools.
        if roles is not None:
            self.roles = list(roles)
        else:
            self.roles = [
                {"long": "prefill", "short": "decode"}.get(
                    getattr(i.policy, "pool", None) or "", "general")
                for i in self.instances]
        self.pools = pools or {}
        self.handoffs = 0
        self.handoff_tokens = 0
        self.swapped_pages = 0        # §12 host→device prefix promotions
        # §11: optional FaultInjector (set by apply_faults) + counters
        self.faults = None
        self.handoff_retries = 0
        self.recovered_sessions = 0
        self._decode_ladder = DecodeBucketLadder(self.cfg.decode_buckets)
        self.tracker = SLOTracker(self.cfg.slo_ttft)
        self._events: List[Tuple[float, int, str, object]] = []
        self._seq = itertools.count()
        self._rr = 0
        self.now = 0.0
        self.clients: List = []
        self._client_busy: Dict[int, bool] = {}

    # ------------------------------------------------------------ events
    def _push(self, t: float, kind: str, data=None) -> None:
        heapq.heappush(self._events, (t, next(self._seq), kind, data))

    def add_requests(self, requests: Sequence[Request]) -> None:
        for r in requests:
            self._admit_prefix(r)
            self._push(r.arrival, "arrival", r)

    def _admit_prefix(self, r: Request) -> None:
        """§8 prefix-reuse admission: shift the page-aligned part of the
        request's reusable prefix from new tokens into history.

        §12 host tier: the part of the prefix annotated host-resident
        (``host_prefix``) only survives eviction when the sim has a
        host pool — it is then billed one PCIe promotion
        (:meth:`CostModel.swap_in_time`) before the suffix prefill can
        start; without a pool those pages were dropped at eviction, so
        they fall out of the adoptable prefix and get re-prefilled."""
        if not (self.cfg.prefix_reuse and self.cfg.page_size
                and r.reusable_prefix > 0):
            return
        ps = self.cfg.page_size
        host = max(0, min(r.host_prefix, r.reusable_prefix))
        if self.cfg.host_pool_pages <= 0:
            r.reusable_prefix -= host
            host = 0
        else:
            kept = min(host, self.cfg.host_pool_pages * ps)
            r.reusable_prefix -= host - kept   # aged out of the pool too
            host = kept
        shift = min(r.reusable_prefix // ps * ps,
                    max(r.new_tokens - 1, 0))
        r.new_tokens -= shift
        r.history_tokens += shift
        if host > 0 and shift > 0:
            pages = -(-min(host, shift) // ps)
            r.swap_time = self.cost.swap_in_time(pages * ps)
            self.swapped_pages += pages

    def add_clients(self, clients, start: float = 0.0,
                    think_time: float = 0.0) -> None:
        self.clients = list(clients)
        self.think = think_time
        for cid in range(len(self.clients)):
            self._push(start, "client", cid)

    def inject_failure(self, t: float, instance: int) -> None:
        self._push(t, "fail", instance)

    def apply_faults(self, plan) -> None:
        """Map a core.faults.FaultPlan onto the simulator: crash events
        schedule instance failures at their ``at`` time (seconds here,
        ticks on the real cluster); transient handoff events are served
        by an injector consulted on the §9 handoff path (retried with
        backoff).  Dispatch/stall faults are engine-loop seams with no
        sim analogue — the sim's "dispatch" IS the priced service — so
        they are ignored."""
        from repro.core.faults import CRASH, FaultInjector
        for ev in plan.events:
            if ev.kind == CRASH and 0 <= ev.engine < len(self.instances):
                self.inject_failure(ev.at, ev.engine)
        self.faults = FaultInjector(plan)

    def inject_join(self, t: float, instance_speed: Tuple[int, float]) -> None:
        self._push(t, "join", instance_speed)

    def set_straggler(self, instance: int, speed: float) -> None:
        self.instances[instance].speed = speed

    # ------------------------------------------------------------ routing
    def _views(self) -> List[EngineView]:
        return [EngineView(engine_id=i.idx,
                           role=(self.roles[i.idx]
                                 if i.idx < len(self.roles) else "general"),
                           alive=i.alive,
                           queue_len=i.policy.queue_len(),
                           backlog_tokens=i.policy.backlog_tokens(),
                           active_decodes=len(i.decode_sessions))
                for i in self.instances if i.alive and i.policy is not None]

    def _route(self, r: Request) -> Optional[_Instance]:
        alive = [i for i in self.instances if i.alive]
        if not alive:
            return None
        if self.router_obj is not None:
            return self.instances[self.router_obj.route(r, self._views())]
        if self.cfg.router == "round_robin":
            self._rr = (self._rr + 1) % len(alive)
            return alive[self._rr]
        if self.cfg.router == "least_loaded":
            return min(alive, key=lambda i: i.policy.backlog_tokens())
        if self.cfg.router == "pool":
            cls = self.classifier(r) if self.classifier else "short"
            members = [i for i in alive
                       if getattr(i.policy, "pool", None) == cls]
            if not members:
                members = alive
            return min(members, key=lambda i: i.policy.backlog_tokens())
        return None  # shared

    # ---------------------------------------------------------- admission
    def _admit(self, r: Request, policy: BasePolicy,
               inst: Optional[_Instance] = None) -> bool:
        """§11 SLO-aware admission gate (mirrors ServeLoop's): reject
        when the predicted TTFT — queue wait ahead plus own service —
        already violates the deadline.  Recovery re-prefills are never
        rejected (shedding one loses a session, not just a turn)."""
        if not self.cfg.admission or r.recovery:
            return True
        ddl = r.deadline if r.deadline is not None else (
            None if self.cfg.slo_ttft is None
            else r.arrival + self.cfg.slo_ttft)
        if ddl is None:
            return True
        eta = self.now + self.cost.predicted_ttft(
            r.new_tokens, r.history_tokens, policy.queue_len(),
            policy.backlog_tokens(),
            len(inst.decode_sessions) if inst is not None else 0)
        if eta <= ddl:
            return True
        r.rejected = True
        self.tracker.note_rejected()
        return False

    # ------------------------------------------------------------- engine
    def _decode_tick_time(self, ctx_lens: List[int],
                          spec: bool = True) -> float:
        """One decode-only tick, mirroring the real engine's routing:
        on-ladder counts run the arena-resident bucketed step billed on
        actual cached lengths (window-clamped for SWA configs — the §7
        rolling arena streams min(cached, window) rows); ladder overflow
        falls back to the dense gather path's per-count pricing (the
        engine does exactly this).  ``spec=False`` forces plain pricing
        for ticks that only commit one token per session (the leftover
        decode step alongside a mixed tick) — verify-row cost is only
        paid where the multi-token commit happens."""
        if self.cfg.window is not None:
            ctx_lens = [min(h, self.cfg.window) for h in ctx_lens]
        if self.cfg.speculative and spec:
            # §10: the tick is one packed verify dispatch — (1+k)-token
            # segments, one amortized weight read, host draft cost
            return self.cost.spec_step_time(ctx_lens, self.cfg.spec_k)
        bucket = self._decode_ladder.bucket_for(len(ctx_lens))
        if bucket is None:
            return self.cost.decode_step_time(len(ctx_lens))
        return self.cost.decode_bucket_time(ctx_lens, bucket)

    def _spec_commit(self) -> int:
        """Tokens one decode-only tick commits per session: the expected
        speculative prefix 1 + round(α·k), or 1 when not speculating."""
        if not self.cfg.speculative:
            return 1
        return 1 + int(round(self.cfg.spec_accept * self.cfg.spec_k))

    def _try(self, inst: _Instance) -> None:
        if inst.busy or not inst.alive:
            return
        policy = self.shared if self.shared is not None else inst.policy
        if self.cfg.mode == "mix":
            # continuous batching: the policy reserves packed-stream rows
            # for the decode backlog (and shrinks the AWD window)
            policy.note_decode_backlog(len(inst.decode_sessions))
        work, wake = policy.next_work(self.now)
        if work is None:
            # MIX: run a decode-only step if sessions are active — priced
            # as one arena-resident bucketed tick over actual contexts
            if self.cfg.mode == "mix" and inst.decode_sessions:
                dt = self._decode_tick_time(inst.decode_ctx_lens) \
                    * inst.speed
                inst.busy = True
                inst.current = "decode"
                self._push(self.now + dt, "done", (inst.idx, "decode"))
            elif wake is not None and wake > self.now:
                self._push(wake, "try", inst.idx)
            return
        if self.cfg.mode == "mix":
            # clamp the reserved fusion room to the actual backlog before
            # pricing — packed_batch_time / chunk_time charge each fused
            # decode row.  Chunks fuse too (the serve loop routes C_l
            # chunks through the packed stream) when the policy is packed.
            if isinstance(work, Batch) and work.is_packed:
                work.decode_tokens = min(work.decode_tokens,
                                         len(inst.decode_sessions))
            elif isinstance(work, ChunkWork):
                ladder = getattr(getattr(policy, "awd", None), "ladder", None)
                if ladder is not None:
                    # mirror the real loop exactly: fit_decodes respects
                    # BOTH the row room and the token-bucket room (an
                    # off-ladder chunk fuses nothing and runs dense)
                    from repro.core.buckets import fit_decodes
                    n_fit, bucket = fit_decodes(
                        work.chunk_tokens, 1, len(inst.decode_sessions),
                        ladder)
                    work.decode_tokens = n_fit if bucket is not None else 0
        if isinstance(work, ChunkWork):
            # packed engines route every on-ladder C_l chunk through a
            # captured token-bucket shape (engine.prefill_long) — price
            # the graph launch in every mode, not just MIX
            ladder = getattr(getattr(policy, "awd", None), "ladder", None)
            work.uses_graph = (ladder is not None and
                               ladder.bucket_for(work.chunk_tokens)
                               is not None)
        # §6 routing: packed/mixed/chunk ticks are arena-resident (no
        # slot copies); the legacy config bills the gather/scatter
        # round-trip the slot-map kernel eliminated
        gather_rows = 0
        if not self.cfg.arena_prefill and (
                (isinstance(work, Batch) and work.is_packed)
                or (isinstance(work, ChunkWork) and work.uses_graph)):
            gather_rows = 2 * self.cfg.packed_seqs * self.cfg.arena_s_max
        service = self.cost.work_time(work, gather_rows=gather_rows) \
            * inst.speed
        # §12: host→device page promotion gates the suffix prefill —
        # billed once, on the request's first dispatch
        if isinstance(work, Batch):
            service += sum(r.swap_time for r in work.requests
                           if r.dispatch_time is None) * inst.speed
        elif isinstance(work, ChunkWork) and work.req.dispatch_time is None:
            service += work.req.swap_time * inst.speed
        if self.cfg.mode == "mix" and inst.decode_sessions:
            # decode tokens fused into a packed step already paid inside
            # the work's pricing (they share the weight read); sessions
            # beyond the fusion room pay the separate alternating step
            fused = getattr(work, "decode_tokens", 0) \
                if isinstance(work, (Batch, ChunkWork)) else 0
            if isinstance(work, Batch) and not work.is_packed:
                fused = 0
            leftover = len(inst.decode_sessions) - fused
            if leftover > 0:
                # sessions beyond the fusion room advance in a separate
                # bucketed decode tick, billed on their cached contexts
                service += self._decode_tick_time(
                    inst.decode_ctx_lens[fused:], spec=False) * inst.speed
            inst.advance_decodes()
        if isinstance(work, Batch):
            for r in work.requests:
                if r.dispatch_time is None:
                    r.dispatch_time = self.now
                r.instance = inst.idx
        elif isinstance(work, ChunkWork):
            if work.req.dispatch_time is None:
                work.req.dispatch_time = self.now
            work.req.instance = inst.idx
        inst.busy = True
        inst.current = work
        self._push(self.now + service, "done", (inst.idx, work))

    def _finish(self, inst: _Instance, work) -> None:
        inst.busy = False
        inst.current = None
        if work == "decode":
            inst.advance_decodes(self._spec_commit())
            return
        policy = self.shared if self.shared is not None else inst.policy
        policy.on_complete(work, self.now)
        if isinstance(work, Batch):
            for r in work.requests:
                r.finish_time = self.now
                self.tracker.record(r)
                self._after_request(inst, r)
        elif isinstance(work, ChunkWork) and work.is_last:
            work.req.finish_time = self.now
            self.tracker.record(work.req)
            self._after_request(inst, work.req)

    def _role(self, inst: _Instance) -> str:
        return self.roles[inst.idx] if inst.idx < len(self.roles) \
            else "general"

    def _after_request(self, inst: _Instance, r: Request) -> None:
        inst.prefill_done += 1
        if r.deadline is not None:
            inst.recent_dev.append(max(0.0, (r.finish_time or 0.0) - r.deadline))
        if self.cfg.mode == "mix" and r.decode_tokens > 0:
            if self.cfg.decode_handoff and self._role(inst) == "prefill" \
                    and any(i.alive and self._role(i) != "prefill"
                            for i in self.instances):
                # §9 spatial split: the prefilled session decodes on a
                # decode instance — its KV crosses engine→engine after
                # the (priced) device-to-device copy; the destination is
                # picked when the copy lands (load may have shifted)
                delay = self.cost.handoff_time(r.total_context)
                self.handoffs += 1
                self.handoff_tokens += r.total_context
                self._push(self.now + delay, "handoff",
                           (r.decode_tokens, r.total_context,
                            inst.idx, 0))
            else:
                inst.decode_sessions.append((r.decode_tokens,
                                             r.total_context))
        if 0 <= r.session < len(self.clients) and \
                self._client_busy.get(r.session, False):
            self._client_busy[r.session] = False
            self._push(self.now + self.think, "client", r.session)

    # ---------------------------------------------------------- controller
    def _instance_stats(self, inst: _Instance, period: float) -> InstanceStats:
        util = (inst.busy_time - inst.busy_mark) / max(period, 1e-9)
        inst.busy_mark = inst.busy_time
        dev = sum(inst.recent_dev) / len(inst.recent_dev) \
            if inst.recent_dev else 0.0
        # clip: structurally unmeetable deadlines (a 20k-token prefill vs
        # a 0.4 s TTFT SLO) must not dominate pool pressure, or the
        # controller starves the healthy pool chasing lost causes
        dev = min(dev, 1.0)
        inst.recent_dev = []
        backlog = inst.policy.backlog_tokens() / 16_384 if inst.policy else 0.0
        return InstanceStats(inst.idx, backlog, dev, min(util, 1.0))

    def _control(self) -> None:
        period = self.cfg.control_period
        alive = [i for i in self.instances if i.alive and i.policy is not None]
        shorts = [self._instance_stats(i, period) for i in alive
                  if getattr(i.policy, "pool", None) == "short"]
        longs = [self._instance_stats(i, period) for i in alive
                 if getattr(i.policy, "pool", None) == "long"]
        if self.controller is not None and shorts and longs:
            mig: Optional[Migration] = self.controller.step(
                shorts, longs, self.now)
            if mig is not None:
                inst = self.instances[mig.instance]
                if isinstance(inst.policy, PoolPolicy):
                    inst.policy.pool = mig.dst_pool
        self._push(self.now + period, "control")

    # --------------------------------------------------------------- run
    def run(self, until: float = float("inf")) -> SLOTracker:
        if self.cfg.control_period > 0:
            self._push(self.cfg.control_period, "control")
        events = 0
        busy_since: Dict[int, float] = {}
        while self._events and events < self.cfg.max_events:
            t, _, kind, data = heapq.heappop(self._events)
            if t > until:
                break
            self.now = t
            events += 1
            if kind == "arrival":
                r: Request = data
                if self.shared is not None:
                    if self._admit(r, self.shared):
                        self.shared.enqueue(r, t)
                        for inst in self.instances:
                            self._try(inst)
                else:
                    inst = self._route(r)
                    if inst is not None and \
                            self._admit(r, inst.policy, inst):
                        inst.policy.enqueue(r, t)
                        self._try(inst)
            elif kind == "client":
                # enqueue synchronously: the next turn must be visible to
                # any same-timestamp "try" of a freed instance, otherwise
                # the instance grabs a long chunk before the arrival lands
                cid: int = data
                if cid < len(self.clients):
                    r = self.clients[cid](t)
                    if r is not None:
                        r.arrival = t
                        r.session = cid
                        self._client_busy[cid] = True
                        admitted = False
                        if self.shared is not None:
                            if self._admit(r, self.shared):
                                admitted = True
                                self.shared.enqueue(r, t)
                                for inst in self.instances:
                                    self._try(inst)
                        else:
                            inst = self._route(r)
                            if inst is not None and \
                                    self._admit(r, inst.policy, inst):
                                admitted = True
                                inst.policy.enqueue(r, t)
                                self._try(inst)
                        if not admitted:
                            # rejected/unroutable: the closed-loop client
                            # thinks and moves on instead of hanging
                            self._client_busy[cid] = False
                            self._push(self.now + self.think, "client",
                                       cid)
            elif kind == "try":
                self._try(self.instances[data])
            elif kind == "handoff":
                # the migrated session's KV has landed: attach its decode
                # to the least decode-loaded non-prefill instance
                budget, ctx, src, attempt = data
                if self.faults is not None and \
                        self.faults.handoff_fails(src, self.now):
                    # §11 transient handoff failure: retry with
                    # exponential backoff, or keep the session on the
                    # source after max attempts (it decodes in place)
                    self.handoff_retries += 1
                    self.tracker.note_retried()
                    if attempt + 1 >= 3:
                        if 0 <= src < len(self.instances) and \
                                self.instances[src].alive:
                            self.instances[src].decode_sessions.append(
                                (budget, ctx))
                            self._try(self.instances[src])
                    else:
                        backoff = self.cost.handoff_launch * \
                            (2 ** (attempt + 1))
                        self._push(self.now + backoff, "handoff",
                                   (budget, ctx, src, attempt + 1))
                    continue
                cands = [i for i in self.instances
                         if i.alive and self._role(i) != "prefill"]
                dst = min(cands, key=lambda i: (len(i.decode_sessions),
                                                i.idx)) if cands else None
                if dst is not None:
                    dst.decode_sessions.append((budget, ctx))
                    self._try(dst)
            elif kind == "done":
                idx, work = data
                inst = self.instances[idx]
                if not inst.alive or inst.current is not work:
                    continue  # stale completion from a failed instance
                self._finish(inst, work)
                # defer the idle re-check behind same-timestamp client
                # releases pushed by _finish (closed-loop next turns)
                self._push(self.now, "try", inst.idx)
            elif kind == "fail":
                inst = self.instances[data]
                inst.alive = False
                # in-flight work dies with the node: the request is
                # re-submitted (re-prefill from cached/replicated state).
                # A ChunkWork's request ALSO still sits in the policy
                # queue (it only leaves at the last chunk's on_complete),
                # so the drain below must skip anything re-pushed here —
                # a double arrival dispatches the request twice and
                # double-records it.
                repushed = set()
                if isinstance(inst.current, Batch):
                    for r in inst.current.requests:
                        r.dispatch_time = None
                        repushed.add(r.rid)
                        self._push(self.now, "arrival", r)
                elif isinstance(inst.current, ChunkWork):
                    inst.current.req.dispatch_time = None
                    repushed.add(inst.current.req.rid)
                    self._push(self.now, "arrival", inst.current.req)
                inst.current, inst.busy = None, False
                # queued requests are re-routed to surviving instances
                if inst.policy is not None:
                    for r in inst.policy.drain():
                        if r.rid in repushed:
                            continue
                        r.dispatch_time = None
                        self.tracker.note_retried()
                        self._push(self.now, "arrival", r)
                # §11: in-flight decode sessions are recovered by PRICED
                # re-prefill reconstruction — a synthetic recovery
                # request replays the session's full cached context on a
                # survivor (billed as a normal prefill of ctx tokens),
                # then its remaining decode budget re-attaches there.
                # Mirrors ServeCluster._recover_session; previously the
                # sessions were silently dropped.
                if self.cfg.recovery and \
                        any(i.alive for i in self.instances):
                    for budget, ctx in inst.decode_sessions:
                        rr = Request(new_tokens=max(ctx, 1),
                                     arrival=self.now, deadline=None,
                                     session=-1, decode_tokens=budget,
                                     recovery=True)
                        self.recovered_sessions += 1
                        self._push(self.now, "arrival", rr)
                inst.decode_sessions = []
            elif kind == "join":
                idx, speed = data
                while len(self.instances) <= idx:
                    self.instances.append(_Instance(len(self.instances), None))
                self.instances[idx].alive = True
                self.instances[idx].speed = speed
            elif kind == "control":
                self._control()
            # busy-time accounting
            for inst in self.instances:
                if inst.busy and inst.idx not in busy_since:
                    busy_since[inst.idx] = t
                elif not inst.busy and inst.idx in busy_since:
                    inst.busy_time += t - busy_since.pop(inst.idx)
        return self.tracker

    # ------------------------------------------------------------ metrics
    def prefill_rps(self, horizon: float) -> float:
        return sum(i.prefill_done for i in self.instances) / max(horizon, 1e-9)
