"""Ground-truth service-time model for the discrete-event simulator.

Roofline (max) form of §2.1's T(L,H) at batch level:

  T(batch) = launch + max( T_comp(batch), T_mem(batch) )
  T_comp   = Σ_i [ α·L'_i·(L'_i + 2H_i) + β·L'_i ]          (MXU/tensor-core)
  T_mem    = weight_read + Σ_i [ w_tok·L'_i + γ_r·H_i ]     (HBM)

where L'_i is the *padded* length when the batch runs as a captured
graph.  The max() is the whole §2.1 story: a batch is memory-bound
(weight-read-dominated) until its total compute crosses the weight-read
floor — so batching/padding short re-prefills is nearly free up to the
boundary, and the AWD waiting window buys weight-read amortization,
while long prefills sit firmly on the compute side.  Launch overhead
(scheduler dispatch + kernel launches) drops to graph_launch for
captured shapes.

Calibrated for H200 + Qwen2.5-32B/14B/7B (bf16): weight_read = bytes /
4.8 TB/s; α/β scaled by parameter count.  The single-request restriction
of this model is what core.boundary fits at runtime.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence

from repro.core.request import Batch
from repro.core.scheduler import ChunkWork


@dataclasses.dataclass(frozen=True)
class CostModel:
    alpha: float            # s/token² attention compute
    beta: float             # s/token linear compute
    w_tok: float            # s/token KV write
    gamma_r: float          # s/history-token KV read (re-prefill)
    weight_read: float      # s per batch step (weights HBM read)
    launch: float = 2.0e-3  # unbatched kernel-launch + dispatch overhead
    graph_launch: float = 3.0e-4   # captured graph / AOT executable launch
    graph_lookup: float = 5.0e-5   # §4.2 per-step graph lookup/selection
    decode_step: Optional[float] = None   # defaults to weight_read
    decode_per_seq: float = 1.0e-4
    # s/token for linear-only tail/pad rows (packed bucket tails, decode
    # ladder pad rows).  Calibratable against real tail-row cost — see
    # benchmarks.roofline.fit_beta_tail; None falls back to β.
    beta_tail: Optional[float] = None
    # sliding-window width (DESIGN.md §7): attention compute and cached
    # KV reads clamp history to min(h, window) — the windowed ragged
    # kernels stream O(min(cached, window)) rows per token, so the
    # model must bill the same.  None = full attention.
    window: Optional[int] = None
    # paged KV arena (DESIGN.md §8): page_size set bills the page-table
    # walk — page_lookup per logical KV block touched (the scalar-
    # prefetched indirection the paged kernels add over the slot map).
    # Prefix hits need NO extra term: the sim's admission converts
    # matched pages from new tokens into history, and history already
    # bills γ_r reads only (no prefill FLOPs, no KV writes) — exactly
    # what the suffix-only step executes.
    page_size: Optional[int] = None
    page_lookup: float = 2.0e-7    # s per page-table entry walked
    # §9 arena→arena KV handoff (spatial disaggregation): migrating a
    # session's cached KV between engines is a device-to-device copy —
    # ~0.26 MB/token for a 32B bf16 config over an NVLink-class fabric
    # (~0.9 TB/s) plus a fixed launch.  Billed by ClusterSim when
    # decode_handoff moves a prefilled session to a decode instance.
    handoff_per_token: float = 2.9e-7
    handoff_launch: float = 5.0e-4
    # §12 host-tier page spill: promoting an evicted prefix page back
    # from the host pool is a host→device copy over PCIe-class
    # bandwidth — roughly an order of magnitude slower per token than
    # the NVLink handoff path, but still far cheaper than re-prefilling
    # the page (α·l² compute + writes).  Billed per token restored.
    swap_beta: float = 3.0e-6
    swap_launch: float = 1.5e-4
    # §10 speculative decoding: host-side draft proposal cost per draft
    # token (n-gram table lookups — tiny next to a dispatch; a
    # small-model draft would calibrate this much higher)
    draft_per_token: float = 2.0e-5

    # ------------------------------------------------------------ pieces
    def handoff_time(self, ctx: int) -> float:
        """Migrate ``ctx`` cached tokens engine→engine (§9)."""
        return self.handoff_launch + self.handoff_per_token * max(ctx, 0)

    def swap_in_time(self, tokens: int) -> float:
        """Promote ``tokens`` spilled KV tokens host→device (§12).
        Zero when nothing is promoted — the launch is only paid when a
        copy actually crosses PCIe."""
        if tokens <= 0:
            return 0.0
        return self.swap_launch + self.swap_beta * tokens

    def predicted_wait(self, queue_len: int, backlog_tokens: int,
                       active_decodes: int = 0,
                       batch_hint: int = 8) -> float:
        """Admission-control queue-wait estimate (§11): how long the
        work already queued ahead keeps the engine busy.  The backlog
        drains as packed steps of roughly ``batch_hint`` requests each —
        one weight read + launch per step (the AWD amortization), linear
        compute / KV writes per queued token under the roofline max, and
        the resident decode backlog stealing decode_per_seq per step.
        Deliberately coarse: the gate needs a monotone, conservative
        ordering of "how doomed is this submit", not a simulation."""
        if queue_len <= 0 and backlog_tokens <= 0:
            return 0.0
        steps = -(-max(queue_len, 1) // max(batch_hint, 1))
        comp = self.beta * backlog_tokens
        mem = steps * self.weight_read + self.w_tok * backlog_tokens
        return (steps * self.graph_launch + max(comp, mem)
                + steps * self.decode_per_seq * max(active_decodes, 0))

    def predicted_ttft(self, l: int, h: int, queue_len: int,
                       backlog_tokens: int,
                       active_decodes: int = 0) -> float:
        """Predicted TTFT for a submit arriving NOW: queue wait ahead of
        it plus its own single-request service time.  The §11 admission
        gate rejects when ``now + predicted_ttft > deadline`` — a
        guaranteed violation is cheaper refused than served late."""
        return (self.predicted_wait(queue_len, backlog_tokens,
                                    active_decodes)
                + self.single(l, h))

    @property
    def tail_coef(self) -> float:
        """Linear cost of one tail/pad row (β_tail, falling back to β)."""
        return self.beta if self.beta_tail is None else self.beta_tail

    def _h_eff(self, h: int) -> int:
        """Attended history: full, or window-clamped for SWA configs."""
        return h if self.window is None else min(h, self.window)

    def _page_walk(self, ctx: int) -> float:
        """Page-table indirection for one segment attending over ``ctx``
        tokens: one prefetched lookup per logical KV block (0 when the
        arena is slot-mapped, i.e. page_size is None)."""
        if self.page_size is None or ctx <= 0:
            return 0.0
        return self.page_lookup * (-(-ctx // self.page_size))

    def comp_time(self, l: int, h: int = 0, padded: Optional[int] = None) -> float:
        lp = padded if padded is not None else l
        return self.alpha * lp * (lp + 2 * self._h_eff(h)) + self.beta * lp

    def mem_time(self, l: int, h: int = 0, padded: Optional[int] = None) -> float:
        lp = padded if padded is not None else l
        return self.w_tok * lp + self.gamma_r * self._h_eff(h)

    def single(self, l: int, h: int = 0) -> float:
        """Single-request service time (what runtime fitting samples)."""
        return self.launch + max(self.comp_time(l, h),
                                 self.weight_read + self.mem_time(l, h))

    # ------------------------------------------------------------- batch
    def packed_batch_time(self, batch: Batch, gather_rows: int = 0) -> float:
        """Token-bucket pricing for packed / mixed steps.

        A packed batch executes RAW per-request tokens (no per-request
        padding) plus the bucket tail — tail rows run the linear stack
        and a junk KV write but no useful attention, so they cost
        β + w_tok each.  Fused decode rows (continuous batching) ride
        the SAME dispatch: they share the per-step weight read and add
        only their linear work plus the per-sequence decode overhead —
        the saving vs. a separate decode step is exactly one weight
        read + launch.  The stream runs as ONE fused kernel, so the
        roofline max() overlap survives even for heterogeneous mixes
        (unlike co-batched separate kernels, §2.2).

        The arena-resident step (§6) moves O(history + new) KV rows —
        exactly the mem term above.  ``gather_rows`` bills the LEGACY
        gathered-cache path: the whole-slot copies (2 · b_max · S_max
        rows per step, gather out + scatter back) that the slot-map
        kernel eliminated, at γ_r per row; 0 on the arena path."""
        fixed = self.graph_launch + self.graph_lookup
        comp = sum(self.comp_time(r.new_tokens, r.history_tokens)
                   for r in batch.requests)
        mem = self.weight_read + sum(
            self.mem_time(r.new_tokens, r.history_tokens)
            for r in batch.requests)
        tail = max(0, (batch.token_bucket or 0) - batch.stream_tokens)
        comp += self.tail_coef * tail
        mem += self.w_tok * tail + self.gamma_r * gather_rows
        fused = batch.decode_tokens * (self.beta + self.w_tok
                                       + self.decode_per_seq)
        # §8: one page-table walk per logical KV block each segment
        # attends over (prefix-hit pages included — they are read)
        fixed += sum(self._page_walk(self._h_eff(r.history_tokens)
                                     + r.new_tokens)
                     for r in batch.requests)
        return fixed + max(comp, mem) + fused

    def batch_time(self, batch: Batch, long_threshold: float = 256.0,
                   gather_rows: int = 0) -> float:
        if batch.is_packed:
            return self.packed_batch_time(batch, gather_rows)
        if batch.uses_graph:
            fixed = self.graph_launch + self.graph_lookup
            pad = batch.bucket_len
        else:
            fixed = self.launch
            pad = None
        comp = sum(self.comp_time(r.new_tokens, r.history_tokens, pad)
                   for r in batch.requests)
        mem = self.weight_read + sum(
            self.mem_time(r.new_tokens, r.history_tokens, pad)
            for r in batch.requests)
        # §2.1/§2.2 compute–memory contention: a homogeneous batch overlaps
        # its compute and memory phases (roofline max); mixing compute-bound
        # long GEMMs with memory-bound short KV traffic destroys the
        # overlap — the mixed batch pays comp + mem serially.
        kinds = {r.new_tokens >= long_threshold for r in batch.requests}
        if len(kinds) > 1:
            return fixed + comp + mem
        return fixed + max(comp, mem)

    def chunk_time(self, w: ChunkWork, gather_rows: int = 0) -> float:
        """One long-prefill chunk: C_l new tokens on top of
        (done + history) context.  A chunk riding a captured token-bucket
        shape (uses_graph) pays the graph launch, not the eager one;
        fused decode rows share the step's weight read — same pricing as
        :meth:`packed_batch_time`'s fusion term.  ``gather_rows`` bills
        the legacy whole-slot gather/scatter (γ_r per copied row) that
        the arena-resident step (§6) eliminated; 0 on the arena path."""
        h = w.done_tokens + w.req.history_tokens
        fixed = self.graph_launch + self.graph_lookup if w.uses_graph \
            else self.launch
        fixed += self._page_walk(self._h_eff(h) + w.chunk_tokens)
        fused = w.decode_tokens * (self.beta + self.w_tok
                                   + self.decode_per_seq)
        return fixed + max(
            self.comp_time(w.chunk_tokens, h),
            self.weight_read + self.mem_time(w.chunk_tokens, h)
            + self.gamma_r * gather_rows) + fused

    def decode_step_time(self, n_active: int) -> float:
        """Legacy decode pricing: per-step weight read + per-seq launch
        overhead, blind to context lengths.  Kept for callers without
        length bookkeeping; prefer :meth:`decode_bucket_time`."""
        base = self.decode_step if self.decode_step is not None \
            else self.weight_read
        return base + self.decode_per_seq * n_active

    def decode_bucket_time(self, cached_lens: Sequence[int],
                           bucket: Optional[int] = None) -> float:
        """Arena-resident bucketed decode tick (DESIGN.md §5).

        Billed on ACTUAL cached lengths: one weight read per BUCKETED
        step (not per session count — the captured executable amortizes
        it across the rung), γ_r per cached token streamed in place,
        one new KV row written (w_tok) per session, β linear per live
        row and β_tail per ladder pad row.  The dense-gather path this
        replaces moved O(S_max) arena rows per session per token; here
        HBM traffic follows the valid prefixes only."""
        n = len(cached_lens)
        if n == 0:
            return 0.0
        b = bucket if bucket is not None else n
        comp = self.beta * n + self.tail_coef * max(0, b - n)
        mem = self.weight_read + sum(self.gamma_r * self._h_eff(h)
                                     + self.w_tok for h in cached_lens)
        walk = sum(self._page_walk(self._h_eff(h) + 1)
                   for h in cached_lens)
        return self.graph_launch + self.graph_lookup + walk \
            + max(comp, mem) + self.decode_per_seq * n

    def spec_step_time(self, cached_lens: Sequence[int], k: int,
                       bucket: Optional[int] = None) -> float:
        """One speculative verify tick (DESIGN.md §10): every session's
        segment carries 1 + k stream tokens (pending + drafts), so the
        linear work and KV writes scale like a (1+k)-token packed row
        per session — but the weight read is still paid ONCE for the
        whole dispatch.  That amortization is the speculative win: a
        tick that commits 1 + α·k tokens costs far less than 1 + α·k
        plain decode ticks, each of which re-reads the weights.  Draft
        proposal adds draft_per_token per proposed token (host-side)."""
        n = len(cached_lens)
        if n == 0:
            return 0.0
        rows = n * (1 + k)
        b = bucket if bucket is not None else rows
        comp = self.beta * rows + self.tail_coef * max(0, b - rows) \
            + self.alpha * sum((1 + k) * ((1 + k) + 2 * self._h_eff(h))
                               for h in cached_lens)
        mem = self.weight_read + sum(
            self.gamma_r * self._h_eff(h) + self.w_tok * (1 + k)
            for h in cached_lens)
        walk = sum(self._page_walk(self._h_eff(h) + 1 + k)
                   for h in cached_lens)
        return self.graph_launch + self.graph_lookup + walk \
            + max(comp, mem) + self.decode_per_seq * n \
            + self.draft_per_token * k * n

    def work_time(self, work, gather_rows: int = 0) -> float:
        if isinstance(work, ChunkWork):
            return self.chunk_time(work, gather_rows)
        return self.batch_time(work, gather_rows=gather_rows)


def decode_hbm_bytes_per_token(cached_len: int, s_max: int,
                               kv_row_bytes: float, *, arena: bool,
                               window: Optional[int] = None) -> float:
    """Modeled KV HBM traffic to generate ONE token for one session.

    arena=False (dense gather/scatter): the session's whole (S_max,)
    arena slot is gathered out, attention reads the valid prefix, and
    the whole slot is scattered back — 2·S_max slot-copy rows plus the
    attended prefix and the new row.  arena=True (in-place): only the
    valid prefix is streamed and one new row is written.

    ``window``: sliding-window width — the attended prefix clamps to
    min(cached, window) on BOTH paths (§7): the windowed kernel streams
    only in-window rows, and the dense step's masked reads still touch
    only the window's rows of the gathered copy.  The dense path keeps
    paying the 2·S_max whole-slot round-trip regardless — that copy is
    blind to the mask, which is exactly the traffic the rolling arena
    retires.

    kv_row_bytes: bytes of one cached token's K+V across all layers
    (2 · layers · Hkv · D · dtype_bytes).  Pure arithmetic so the
    benchmark, the simulator, and the docs all quote the same number.
    """
    attended = cached_len if window is None else min(cached_len, window)
    if arena:
        return kv_row_bytes * (attended + 1)
    return kv_row_bytes * (2 * s_max + attended + 1)


def packed_hbm_bytes_per_step(new_tokens: Sequence[int],
                              histories: Sequence[int], s_max: int,
                              n_rows: int, kv_row_bytes: float, *,
                              arena: bool,
                              window: Optional[int] = None) -> float:
    """Modeled KV HBM traffic of ONE packed prefill / mixed / chunk step
    (the prefill sibling of :func:`decode_hbm_bytes_per_token`).

    Every step reads each segment's attended prefix (history + new) and
    writes its new rows.  arena=False (legacy gathered-cache path): the
    step ALSO copies ``n_rows`` whole (S_max,) arena slots out before
    the dispatch and scatters them back after — 2 · n_rows · S_max
    slot-copy rows regardless of how few tokens the bucket holds, the
    exact O(b_max · S_max) round-trip the slot-map kernel (§6) kills.
    arena=True: only the O(history + new) rows move.

    kv_row_bytes: bytes of one cached token's K+V across all layers
    (2 · layers · Hkv · D · dtype_bytes).  Pure arithmetic so the
    benchmark, the simulator, and the docs all quote the same number.

    ``window``: sliding-window width — each segment's attended read
    clamps to min(history, window) + new on both paths (§7), while the
    dense path's whole-slot round-trip stays 2 · n_rows · s_max.
    """
    def _h(h: int) -> int:
        return h if window is None else min(h, window)

    useful = sum(_h(h) + l for h, l in zip(histories, new_tokens))  # reads
    useful += sum(new_tokens)                                       # writes
    if arena:
        return kv_row_bytes * useful
    return kv_row_bytes * (useful + 2 * n_rows * s_max)


def _scaled(params_b: float) -> CostModel:
    """Calibration scaled by parameter count (H200 SXM, bf16, 4.8 TB/s).

    γ_r is the *physical* KV re-read: ~0.26 MB per history token (32B:
    64L × 8KV × 128D × 2B × K+V) / 4.8 TB/s ≈ 5.4e-8 s — re-prefill
    memory-boundness comes from the per-step weight read, which dominates
    short batches exactly as §2.1 argues."""
    # α = 4·d_attn·layers / peak ≈ 4·5120·64 / 990e12 ≈ 1.3e-9 s per
    # (token × context) pair; β = 2N/peak ≈ 6.5e-5 s/token (32B).
    s = params_b / 32.0
    return CostModel(
        alpha=1.3e-9 * s, beta=6.5e-5 * s, w_tok=2.0e-6 * s,
        gamma_r=5.4e-8 * s, weight_read=0.013 * s,
    )


H200_32B = _scaled(32.0)
H200_14B = _scaled(14.0)
H200_7B = _scaled(7.0)
