"""Workload generation: LMSys-Chat-1M-like multi-turn conversations.

Calibrated to Fig.2 of the paper: ~63% of first-turn prompts < 256
tokens, rising to ~81% in subsequent turns (re-prefills exclude the
system prompt and carry only the new user message).  Long-context
requests (> 1K tokens) form the heavy tail.

Two client models:
  * open-loop Poisson arrivals (Fig.7's λ-driven SLO experiments);
  * closed-loop concurrency-C clients (Fig.1/3/6's "concurrency level"
    axis): each client submits its next turn as soon as the previous one
    finishes.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Callable, List, Optional, Sequence

import numpy as np

from repro.core.request import Request


@dataclasses.dataclass
class WorkloadConfig:
    # first-turn prompt lengths: lognormal, ~63% < 256
    first_mu: float = math.log(150.0)
    first_sigma: float = 1.3
    # later-turn (re-prefill) new-token lengths: lognormal, ~81% < 256
    later_mu: float = math.log(80.0)
    later_sigma: float = 1.1
    # assistant responses (grow the history)
    resp_mu: float = math.log(200.0)
    resp_sigma: float = 0.8
    mean_turns: float = 3.5          # geometric
    max_len: int = 32_768
    slo_ttft: Optional[float] = 0.4  # s (paper §4.1); None = deadline-free
    decode_mu: float = math.log(150.0)
    decode_sigma: float = 0.9


def _ln(rng: np.random.Generator, mu: float, sigma: float, max_len: int) -> int:
    return int(min(max(rng.lognormal(mu, sigma), 1.0), max_len))


class SessionSampler:
    """Stateful per-session turn generator."""

    def __init__(self, cfg: WorkloadConfig, rng: np.random.Generator,
                 session_id: int):
        self.cfg = cfg
        self.rng = rng
        self.session = session_id
        self.turn = 0
        self.history = 0
        self.n_turns = 1 + rng.geometric(1.0 / cfg.mean_turns)

    def done(self) -> bool:
        return self.turn >= self.n_turns

    def next_request(self, now: float) -> Request:
        c = self.cfg
        if self.turn == 0:
            l = _ln(self.rng, c.first_mu, c.first_sigma, c.max_len)
            h = 0
        else:
            l = _ln(self.rng, c.later_mu, c.later_sigma, c.max_len)
            h = self.history
        dec = _ln(self.rng, c.decode_mu, c.decode_sigma, c.max_len)
        r = Request(new_tokens=l, history_tokens=h, arrival=now,
                    deadline=(now + c.slo_ttft) if c.slo_ttft else None,
                    session=self.session, decode_tokens=dec)
        self.history = h + l + dec
        self.turn += 1
        return r


def lmsys_like_requests(n: int, rate: float, cfg: Optional[WorkloadConfig] = None,
                        seed: int = 0) -> List[Request]:
    """Open-loop: n requests, Poisson(rate) arrivals, stationary turn mix."""
    cfg = cfg or WorkloadConfig()
    rng = np.random.default_rng(seed)
    out: List[Request] = []
    t = 0.0
    sessions: List[SessionSampler] = []
    sid = 0
    while len(out) < n:
        t += rng.exponential(1.0 / rate)
        # continue an existing session w.p. proportional to remaining turns
        live = [s for s in sessions if not s.done()]
        if live and rng.random() < 0.7:
            s = rng.choice(live)
        else:
            s = SessionSampler(cfg, rng, sid)
            sid += 1
            sessions.append(s)
        out.append(s.next_request(t))
    return out


def closed_loop_clients(concurrency: int, cfg: Optional[WorkloadConfig] = None,
                        seed: int = 0, think_time: float = 0.0,
                        long_only: bool = False, short_only: bool = False,
                        long_min: int = 1024, short_max: int = 64):
    """Closed-loop client factories for the simulator (Fig.1/3 style).

    Returns a list of ``next_request(now) -> Request | None`` callables,
    one per client; each produces its next turn when called (the sim
    calls it when the previous request finishes + think_time).
    ``long_only`` / ``short_only`` clamp lengths to reproduce the paper's
    interference experiments (>1K vs <64 tokens).
    """
    cfg = cfg or WorkloadConfig()

    def make_client(i: int) -> Callable[[float], Optional[Request]]:
        rng = np.random.default_rng(seed * 7919 + i)
        state = {"s": SessionSampler(cfg, rng, i)}

        def next_request(now: float) -> Optional[Request]:
            if state["s"].done():
                state["s"] = SessionSampler(cfg, rng, i + 100_000)
            r = state["s"].next_request(now)
            if long_only:
                r.new_tokens = max(r.new_tokens, long_min) + \
                    int(rng.integers(0, 3 * long_min))
            elif short_only:
                r.new_tokens = 1 + int(rng.integers(0, short_max))
            return r

        return next_request

    return [make_client(i) for i in range(concurrency)]


def length_stats(requests: Sequence[Request]) -> dict:
    """Fig.2 reproduction: fraction of prompts < 256 by turn position."""
    first = [r.new_tokens for r in requests if not r.is_reprefill]
    later = [r.new_tokens for r in requests if r.is_reprefill]

    def frac_below(xs, k):
        return sum(1 for x in xs if x < k) / len(xs) if xs else 0.0

    return {
        "n_first": len(first), "n_later": len(later),
        "first_lt256": frac_below(first, 256),
        "later_lt256": frac_below(later, 256),
        "first_gt1k": frac_below(first, 10 ** 9) - frac_below(first, 1024),
        "later_gt1k": frac_below(later, 10 ** 9) - frac_below(later, 1024),
        "first_median": float(np.median(first)) if first else 0.0,
        "later_median": float(np.median(later)) if later else 0.0,
    }
