"""Checkpoint/restart: fault tolerance for training and serving.

Trees are flattened to path-keyed npz archives plus a JSON metadata
sidecar (step, data-iterator state, rng seed).  Writes are atomic
(tmp + rename) so a node failure mid-write never corrupts the latest
checkpoint — restart resumes from the newest complete step directory.

At pod scale each host would write its own shard of the (already
FSDP-sharded) state; here the single-host form keeps the same layout.
"""
from __future__ import annotations

import json
import os
import re
import shutil
from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np


def _flatten(tree: Any) -> Dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                       for k in path)
        flat[key] = np.asarray(leaf)
    return flat


def _unflatten(example: Any, flat: Dict[str, np.ndarray]) -> Any:
    leaves = []
    for path, leaf in jax.tree_util.tree_flatten_with_path(example)[0]:
        key = "/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                       for k in path)
        arr = flat[key]
        leaves.append(arr.astype(leaf.dtype) if hasattr(leaf, "dtype") else arr)
    treedef = jax.tree_util.tree_structure(example)
    return jax.tree_util.tree_unflatten(treedef, leaves)


def save_checkpoint(ckpt_dir: str, step: int, params: Any,
                    opt_state: Optional[Any] = None,
                    meta: Optional[Dict] = None) -> str:
    tmp = os.path.join(ckpt_dir, f".tmp_step_{step}")
    final = os.path.join(ckpt_dir, f"step_{step}")
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp, exist_ok=True)
    np.savez(os.path.join(tmp, "params.npz"), **_flatten(params))
    if opt_state is not None:
        np.savez(os.path.join(tmp, "opt.npz"), **_flatten(opt_state))
    with open(os.path.join(tmp, "meta.json"), "w") as f:
        json.dump({"step": step, **(meta or {})}, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    return final


def latest_step(ckpt_dir: str) -> Optional[int]:
    if not os.path.isdir(ckpt_dir):
        return None
    steps = []
    for name in os.listdir(ckpt_dir):
        m = re.fullmatch(r"step_(\d+)", name)
        if m and os.path.exists(os.path.join(ckpt_dir, name, "meta.json")):
            steps.append(int(m.group(1)))
    return max(steps) if steps else None


def load_checkpoint(ckpt_dir: str, step: int, params_example: Any,
                    opt_example: Optional[Any] = None
                    ) -> Tuple[Any, Optional[Any], Dict]:
    d = os.path.join(ckpt_dir, f"step_{step}")
    with np.load(os.path.join(d, "params.npz")) as z:
        params = _unflatten(params_example, dict(z))
    opt_state = None
    if opt_example is not None and os.path.exists(os.path.join(d, "opt.npz")):
        with np.load(os.path.join(d, "opt.npz")) as z:
            opt_state = _unflatten(opt_example, dict(z))
    with open(os.path.join(d, "meta.json")) as f:
        meta = json.load(f)
    return params, opt_state, meta
