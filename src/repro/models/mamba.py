"""Mamba2 (SSD — state-space duality) mixer.

Implements the chunked SSD algorithm for sequence mode (train / prefill /
re-prefill) and the O(1) recurrent step for decode.  The per-layer
recurrent cache is ``(ssm_state, conv_state)``:

  ssm_state:  (B, nheads, head_dim, d_state)   fp32
  conv_state: (B, conv_width-1, conv_channels) activation dtype

Jamba's mamba layers reuse this block (SSD form substituted for Mamba-1;
see DESIGN.md §Hardware-adaptation).
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.distributed.sharding import constrain
from repro.models.layers import ParamBuilder, rms_norm


def conv_channels(cfg) -> int:
    return cfg.ssm_d_inner + 2 * cfg.ssm_n_groups * cfg.ssm_state_size


def init_mamba(pb: ParamBuilder, cfg) -> None:
    di, ds, nh = cfg.ssm_d_inner, cfg.ssm_state_size, cfg.ssm_nheads
    g, w = cfg.ssm_n_groups, cfg.ssm_conv_width
    proj_out = 2 * di + 2 * g * ds + nh          # z, x, B, C, dt
    pb.dense("in_proj", (cfg.d_model, proj_out), ("embed", "ssm_inner"))
    pb.dense("conv_w", (w, conv_channels(cfg)), (None, "conv_ch"), scale=w ** -0.5)
    pb.zeros("conv_b", (conv_channels(cfg),), ("conv_ch",))
    pb.zeros("dt_bias", (nh,), ("ssm_heads",))
    pb.const("A_log", jnp.log(jnp.linspace(1.0, 16.0, nh)), ("ssm_heads",))
    pb.ones("D", (nh,), ("ssm_heads",))
    pb.ones("norm", (di,), (None,))
    pb.dense("out_proj", (di, cfg.d_model), ("ssm_inner", "embed"))


def _causal_conv_seq(xbc: jax.Array, w: jax.Array, b: jax.Array,
                     conv_state: Optional[jax.Array],
                     valid_len: Optional[jax.Array] = None
                     ) -> Tuple[jax.Array, jax.Array]:
    """Depthwise causal conv.  xbc: (B, L, C); w: (W, C); returns (y, new_state).

    valid_len (B,): per-row count of real (non-padded) tokens — the new
    conv state is taken from each row's true end so right-padding in
    bucketized batches cannot corrupt the recurrent state."""
    width = w.shape[0]
    if conv_state is None:
        pad = jnp.zeros((xbc.shape[0], width - 1, xbc.shape[2]), xbc.dtype)
    else:
        pad = conv_state.astype(xbc.dtype)
    full = jnp.concatenate([pad, xbc], axis=1)                 # (B, W-1+L, C)
    # windowed sum: y[t] = sum_j w[j] * full[t+j]
    y = sum(full[:, j:j + xbc.shape[1], :] * w[j][None, None, :]
            for j in range(width))
    y = jax.nn.silu(y + b[None, None, :])
    if valid_len is None:
        new_state = full[:, full.shape[1] - (width - 1):, :]
    else:
        # token t sits at absolute row (W-1)+t in `full`; the last W-1
        # real inputs of row i are rows valid_len[i] .. valid_len[i]+W-2
        idx = valid_len[:, None] + jnp.arange(width - 1)[None, :]
        new_state = jnp.take_along_axis(full, idx[:, :, None], axis=1)
    return y, new_state


def _causal_conv_step(xbc: jax.Array, w: jax.Array, b: jax.Array,
                      conv_state: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """One-token conv step.  xbc: (B, 1, C); conv_state: (B, W-1, C)."""
    window = jnp.concatenate([conv_state.astype(xbc.dtype), xbc], axis=1)  # (B, W, C)
    y = jnp.einsum("bwc,wc->bc", window, w)[:, None, :]
    y = jax.nn.silu(y + b[None, None, :])
    return y, window[:, 1:, :]


def _split_proj(cfg, proj: jax.Array):
    di, ds, nh = cfg.ssm_d_inner, cfg.ssm_state_size, cfg.ssm_nheads
    g = cfg.ssm_n_groups
    z, x, bmat, cmat, dt = jnp.split(
        proj, [di, 2 * di, 2 * di + g * ds, 2 * di + 2 * g * ds], axis=-1)
    return z, x, bmat, cmat, dt


def _heads(cfg, x: jax.Array) -> jax.Array:
    b, l, _ = x.shape
    return x.reshape(b, l, cfg.ssm_nheads, cfg.ssm_head_dim)


def _group_view(cfg, m: jax.Array) -> jax.Array:
    b, l, _ = m.shape
    return m.reshape(b, l, cfg.ssm_n_groups, cfg.ssm_state_size)


def ssd_chunked(xh: jax.Array, dt: jax.Array, a: jax.Array, bg: jax.Array,
                cg: jax.Array, chunk: int,
                init_state: Optional[jax.Array] = None
                ) -> Tuple[jax.Array, jax.Array]:
    """Chunked SSD scan, group-factored.

    xh: (B, L, NH, HD); dt: (B, L, NH) (post-softplus); a: (NH,) negative;
    bg, cg: (B, L, G, DS) — B/C stay in GROUP form (never repeated to
    heads: the naive head-expanded layout costs G→NH (e.g. 16×) extra HBM
    on jamba).  fp32 casts happen per-chunk inside the scan body, so the
    full-sequence fp32 copies never materialize either.

    Returns (y (B,L,NH,HD) in xh.dtype, state (B,NH,HD,DS) fp32).
    """
    b, l, nh, hd = xh.shape
    g = bg.shape[2]
    ds = bg.shape[-1]
    hpg = nh // g
    q = min(chunk, l)
    orig_l = l
    if l % q != 0:
        # zero-pad to a chunk multiple: dt=0 ⇒ decay=1 and zero input
        # contribution, so padded steps are exact identities for the state.
        pad = q - l % q
        xh = jnp.pad(xh, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        bg = jnp.pad(bg, ((0, 0), (0, pad), (0, 0), (0, 0)))
        cg = jnp.pad(cg, ((0, 0), (0, pad), (0, 0), (0, 0)))
        l = l + pad
    nc = l // q
    f32 = jnp.float32
    xc = jnp.moveaxis(xh.reshape(b, nc, q, g, hpg, hd), 1, 0)
    dtc = jnp.moveaxis(dt.reshape(b, nc, q, g, hpg), 1, 0)
    bc = jnp.moveaxis(bg.reshape(b, nc, q, g, ds), 1, 0)
    cc = jnp.moveaxis(cg.reshape(b, nc, q, g, ds), 1, 0)
    ag = a.reshape(g, hpg).astype(f32)

    if init_state is None:
        init_state = jnp.zeros((b, g, hpg, hd, ds), f32)
    else:
        init_state = init_state.reshape(b, g, hpg, hd, ds)

    tri = jnp.tril(jnp.ones((q, q), bool))

    def body(h, xs):
        xq, dq, bq, cq = xs                                    # chunk slices
        xq = xq.astype(f32)
        dq = dq.astype(f32)
        bq = bq.astype(f32)
        cq = cq.astype(f32)
        cum = jnp.cumsum(dq * ag[None, None], axis=1)          # (b,q,g,hpg)
        # intra-chunk: M[t,s,g,h] = exp(cum_t - cum_s)·(C_t·B_s)_g·dt_s.
        # Mask the log-deltas BEFORE exp: for s > t the delta is positive
        # and exp can overflow — where(tri, exp(..), 0) then produces
        # inf·0 = NaN gradients through the unselected branch.
        logm = cum[:, :, None] - cum[:, None, :, :]            # (b,t,s,g,hpg)
        logm = jnp.where(tri[None, :, :, None, None], logm, -jnp.inf)
        decay = jnp.exp(logm)
        cb = jnp.einsum("btgd,bsgd->btsg", cq, bq)             # (b,t,s,g)
        m = decay * cb[..., None] * dq[:, None]                # (b,t,s,g,hpg)
        y = jnp.einsum("btsgh,bsghp->btghp", m, xq)
        # inter-chunk: carried state
        y = y + jnp.einsum("btgd,btgh,bghpd->btghp",
                           cq, jnp.exp(cum), h)
        # state update
        w = jnp.exp(cum[:, -1:] - cum) * dq                    # (b,s,g,hpg)
        dstate = jnp.einsum("bsgh,bsghp,bsgd->bghpd", w, xq, bq)
        h = jnp.exp(cum[:, -1])[..., None, None] * h + dstate
        return h, y.astype(xh.dtype)

    state, ys = jax.lax.scan(body, init_state, (xc, dtc, bc, cc))
    y = jnp.moveaxis(ys, 0, 1).reshape(b, l, nh, hd)[:, :orig_l]
    return y, state.reshape(b, nh, hd, ds)


def ssd_step(xh: jax.Array, dt: jax.Array, a: jax.Array, bg: jax.Array,
             cg: jax.Array, state: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """One decode step.  xh: (B,1,NH,HD); bg, cg: (B,1,G,DS);
    state: (B,NH,HD,DS) fp32."""
    f32 = jnp.float32
    b = xh.shape[0]
    nh, hd = xh.shape[2], xh.shape[3]
    g, ds = bg.shape[2], bg.shape[3]
    hpg = nh // g
    x0 = xh[:, 0].astype(f32).reshape(b, g, hpg, hd)
    d0 = dt[:, 0].astype(f32).reshape(b, g, hpg)
    b0 = bg[:, 0].astype(f32)                                  # (b,g,ds)
    c0 = cg[:, 0].astype(f32)
    ag = a.reshape(g, hpg).astype(f32)
    st = state.reshape(b, g, hpg, hd, ds)
    da = jnp.exp(d0 * ag[None])                                # (b,g,hpg)
    new = da[..., None, None] * st + jnp.einsum(
        "bgh,bghp,bgd->bghpd", d0, x0, b0)
    y = jnp.einsum("bghpd,bgd->bghp", new, c0)
    return (y.reshape(b, 1, nh, hd).astype(xh.dtype),
            new.reshape(b, nh, hd, ds))


def mamba_layer(p: Dict, x: jax.Array, *, cfg,
                cache: Optional[Tuple[jax.Array, jax.Array]] = None,
                decode: bool = False,
                valid_len: Optional[jax.Array] = None,
                ) -> Tuple[jax.Array, Optional[Tuple[jax.Array, jax.Array]]]:
    """Full Mamba2 block.  x: (B, L, d_model).

    cache = (ssm_state, conv_state) carries recurrent state across turns
    (re-prefill) and steps (decode).  Returns (y, new_cache) — new_cache is
    None when called without a cache (pure training forward).

    valid_len (B,): real token count per row.  Padded positions get
    dt = 0, which makes the SSD step an exact identity (decay exp(0)=1,
    zero input contribution), so bucketized right-padding is safe.
    """
    a = -jnp.exp(p["A_log"].astype(jnp.float32))
    proj = x @ p["in_proj"]
    z, xs, bmat, cmat, dt_raw = _split_proj(cfg, proj)
    xbc = jnp.concatenate([xs, bmat, cmat], axis=-1)
    xbc = constrain(xbc, "batch", "seq", "conv_ch")

    ssm_state = conv_state = None
    if cache is not None:
        ssm_state, conv_state = cache

    if decode:
        xbc, conv_state = _causal_conv_step(xbc, p["conv_w"], p["conv_b"], conv_state)
    else:
        xbc, conv_state = _causal_conv_seq(xbc, p["conv_w"], p["conv_b"],
                                           conv_state, valid_len)

    di, ds, g = cfg.ssm_d_inner, cfg.ssm_state_size, cfg.ssm_n_groups
    xs, bmat, cmat = jnp.split(xbc, [di, di + g * ds], axis=-1)
    xh = _heads(cfg, xs)
    bg = _group_view(cfg, bmat)
    cg = _group_view(cfg, cmat)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"].astype(jnp.float32))
    if valid_len is not None and not decode:
        keep = jnp.arange(x.shape[1])[None, :] < valid_len[:, None]
        dt = jnp.where(keep[:, :, None], dt, 0.0)
    xh = constrain(xh, "batch", "seq", "ssm_heads", None)

    if decode:
        y, ssm_state = ssd_step(xh, dt, a, bg, cg, ssm_state)
    else:
        y, ssm_state = ssd_chunked(xh, dt, a, bg, cg, cfg.ssm_chunk,
                                   init_state=ssm_state)

    y = y + p["D"].astype(y.dtype)[None, None, :, None] * xh
    y = y.reshape(x.shape[0], x.shape[1], di)
    # gated norm: silu stays in model dtype (the f32 promotion costs a
    # 2 GiB/device transient at 32k prefill; rms_norm is f32 internally)
    y = rms_norm(y * jax.nn.silu(z), p["norm"], cfg.norm_eps)
    out = y @ p["out_proj"]
    out = constrain(out, "batch", "seq", "embed_act")
    new_cache = (ssm_state, conv_state) if cache is not None else None
    return out, new_cache


def init_mamba_cache(cfg, batch: int, dtype=jnp.float32):
    ssm = jnp.zeros((batch, cfg.ssm_nheads, cfg.ssm_head_dim, cfg.ssm_state_size),
                    jnp.float32)
    conv = jnp.zeros((batch, cfg.ssm_conv_width - 1, conv_channels(cfg)), dtype)
    return ssm, conv


# --------------------------------------------------- arena-resident serving


def packed_arena_mamba_layer(p: Dict, x: jax.Array, *, cfg,
                             slot_map: jax.Array,
                             cache: Dict[str, jax.Array],
                             seg_rows: jax.Array, seg_pos: jax.Array,
                             valid_row: jax.Array, seg_lens: jax.Array,
                             ) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """Mamba2 mixer over a packed flat stream with an SSM STATE ARENA
    (DESIGN.md §7): the per-slot recurrent state is read at ``slot_map``
    and stepped IN PLACE — the hybrid/SSM model rides the same
    forward_packed_arena layer scan as attention instead of forcing the
    whole model onto the dense (L, B) path.

    x: (T, d) flat stream; cache: {"ssm": (N_slots(+1), NH, HD, DS),
    "conv": (N_slots(+1), W-1, C)} — the slot-axis state arenas for this
    layer; slot_map: (B,) arena slot per segment (pad segments point at
    the arena's SCRATCH slot, so their junk updates never touch live
    state); seg_rows/seg_pos: (T,) each flat token's (segment row, local
    index) — tail rows carry seg_rows == B and are dropped; valid_row:
    (T,) bool; seg_lens: (B,) new tokens per segment (0 for pads, which
    makes their SSD update an exact identity).

    The SSD scan itself is sequential per segment, so the flat stream is
    bridged to a dense (B, T, d) view for the scan and flattened back —
    the bridge touches activations only; the O(S_max) KV-slot copies the
    flat stream exists to avoid have no SSM analogue (recurrent state is
    O(1) per slot, and it moves exactly once per step here).

    Returns (out (T, d) flat, updated state arenas).
    """
    t, d = x.shape
    b = slot_map.shape[0]
    # flat → dense bridge: invalid rows scatter out of bounds and drop
    dense = jnp.zeros((b, t, d), x.dtype)
    dest_rows = jnp.where(valid_row, seg_rows, b)
    dense = dense.at[dest_rows, seg_pos].set(x, mode="drop")

    ssm0 = jnp.take(cache["ssm"], slot_map, axis=0)       # (B, NH, HD, DS)
    conv0 = jnp.take(cache["conv"], slot_map, axis=0)     # (B, W-1, C)
    y, (ssm1, conv1) = mamba_layer(p, dense, cfg=cfg, cache=(ssm0, conv0),
                                   decode=False, valid_len=seg_lens)

    out = y[jnp.clip(dest_rows, 0, b - 1), seg_pos]
    out = jnp.where(valid_row[:, None], out, 0.0).astype(x.dtype)
    # live slots are distinct (one session per segment); every pad row
    # targets the scratch slot, and its update is an identity anyway
    new_cache = {
        "ssm": cache["ssm"].at[slot_map].set(ssm1.astype(cache["ssm"].dtype)),
        "conv": cache["conv"].at[slot_map].set(
            conv1.astype(cache["conv"].dtype)),
    }
    return out, new_cache


def arena_decode_mamba_layer(p: Dict, x: jax.Array, *, cfg,
                             slot_map: jax.Array,
                             cache: Dict[str, jax.Array],
                             ) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """One arena-resident decode tick through a Mamba2 mixer: every
    row's recurrent state is read at ``slot_map``, stepped once (O(1)
    per token), and written back in place.  x: (B, d); pad rows point at
    the scratch slot.  Returns (out (B, d), updated state arenas)."""
    ssm0 = jnp.take(cache["ssm"], slot_map, axis=0)
    conv0 = jnp.take(cache["conv"], slot_map, axis=0)
    y, (ssm1, conv1) = mamba_layer(p, x[:, None, :], cfg=cfg,
                                   cache=(ssm0, conv0), decode=True)
    new_cache = {
        "ssm": cache["ssm"].at[slot_map].set(ssm1.astype(cache["ssm"].dtype)),
        "conv": cache["conv"].at[slot_map].set(
            conv1.astype(cache["conv"].dtype)),
    }
    return y[:, 0], new_cache
