"""Core transformer layers: RMSNorm, RoPE, GQA attention, SwiGLU MLP.

Pure-functional JAX; parameters are plain nested dicts built through
:class:`ParamBuilder`, which records a parallel tree of logical sharding
axes consumed by ``distributed.sharding``.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.distributed.sharding import constrain

# ----------------------------------------------------------------- params


class ParamBuilder:
    """Builds a param dict + a parallel logical-axes dict.

    ``abstract=True`` records ShapeDtypeStructs instead of materializing
    arrays — used by the dry-run to get (shapes, axes) with zero
    allocation and zero tracing.
    """

    def __init__(self, key: Optional[jax.Array], dtype=jnp.float32,
                 abstract: bool = False):
        self._key = key
        self.dtype = dtype
        self.abstract = abstract
        self.params: Dict = {}
        self.axes: Dict = {}

    def _split(self) -> Optional[jax.Array]:
        if self.abstract:
            return None
        self._key, k = jax.random.split(self._key)
        return k

    def sub(self, name: str) -> "ParamBuilder":
        child = ParamBuilder(self._split(), self.dtype, self.abstract)
        self.params[name] = child.params
        self.axes[name] = child.axes
        return child

    def _store(self, name, shape, axes, make):
        if self.abstract:
            self.params[name] = jax.ShapeDtypeStruct(tuple(shape), self.dtype)
        else:
            self.params[name] = make()
        self.axes[name] = axes

    def dense(self, name: str, shape: Tuple[int, ...], axes: Tuple[Optional[str], ...],
              scale: Optional[float] = None):
        fan_in = shape[0] if len(shape) > 1 else shape[-1]
        scale = scale if scale is not None else fan_in ** -0.5
        self._store(name, shape, axes, lambda: (
            scale * jax.random.normal(self._split(), shape)).astype(self.dtype))

    def zeros(self, name: str, shape, axes):
        self._store(name, shape, axes, lambda: jnp.zeros(shape, self.dtype))

    def ones(self, name: str, shape, axes):
        self._store(name, shape, axes, lambda: jnp.ones(shape, self.dtype))

    def const(self, name: str, value, axes):
        self._store(name, jnp.shape(value), axes,
                    lambda: value.astype(self.dtype))


# ------------------------------------------------------------------ norms


def rms_norm(x: jax.Array, weight: jax.Array, eps: float = 1e-6) -> jax.Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    x = x * jax.lax.rsqrt(var + eps)
    return (x * weight.astype(jnp.float32)).astype(dt)


# ------------------------------------------------------------------- rope


def rope_freqs(head_dim: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (B, L, H, D); positions: (B, L) absolute token positions."""
    d = x.shape[-1]
    freqs = rope_freqs(d, theta)                              # (D/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (B, L, D/2)
    cos = jnp.cos(angles)[:, :, None, :]
    sin = jnp.sin(angles)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# -------------------------------------------------------------- attention


def attention_mask(q_positions: jax.Array, kv_len: int, *, causal: bool,
                   window: Optional[int], kv_valid_len: Optional[jax.Array]) -> jax.Array:
    """Boolean mask (B, Lq, S): True = attend.

    q_positions: (B, Lq) absolute positions of query tokens; cache slot s
    holds absolute position s, so the causal condition ``s <= qp`` also
    excludes unwritten (junk) slots for ragged cached batches.
    kv_valid_len: (B,) valid-entry count — only needed for non-causal
    (encoder) padded batches.
    """
    kv_pos = jnp.arange(kv_len)[None, None, :]                # (1,1,S)
    qp = q_positions[:, :, None]                              # (B,Lq,1)
    mask = jnp.ones(qp.shape[:2] + (kv_len,), dtype=bool)
    if causal:
        mask = mask & (kv_pos <= qp)
    if window is not None:
        mask = mask & (kv_pos > qp - window)
    if kv_valid_len is not None:
        mask = mask & (kv_pos < kv_valid_len[:, None, None])
    return mask


def rolling_mask(q_positions: jax.Array, window: int) -> jax.Array:
    """Mask for a rolling (modular) SWA cache: slot s valid iff
    s < min(pos+1, window).  Decode-oriented (every valid slot is past)."""
    slots = jnp.arange(window)[None, None, :]
    limit = jnp.minimum(q_positions[:, :, None] + 1, window)
    return slots < limit


def gqa_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                  mask: jax.Array) -> jax.Array:
    """Grouped-query attention without materializing repeated KV.

    q: (B, Lq, Hq, D); k,v: (B, S, Hkv, D); mask: (B, Lq, S) bool.
    Returns (B, Lq, Hq, D).  Softmax in fp32.
    """
    b, lq, hq, d = q.shape
    hkv = k.shape[2]
    rep = hq // hkv
    qg = q.reshape(b, lq, hkv, rep, d)
    scores = jnp.einsum("blgrd,bsgd->bglrs", qg.astype(jnp.float32),
                        k.astype(jnp.float32)) / jnp.sqrt(d).astype(jnp.float32)
    scores = jnp.where(mask[:, None, :, None, :], scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bglrs,bsgd->blgrd", probs, v.astype(jnp.float32))
    return out.reshape(b, lq, hq, d).astype(q.dtype)


ATTN_Q_CHUNK = 1024


def attention_core(q: jax.Array, keys: jax.Array, vals: jax.Array,
                   q_positions: jax.Array, *, causal: bool,
                   window: Optional[int],
                   kv_valid_len: Optional[jax.Array],
                   mask_override: Optional[jax.Array] = None,
                   q_chunk: int = ATTN_Q_CHUNK) -> jax.Array:
    """Attention with q-chunking for long sequences (XLA-level flash):
    the (Lq × S) score matrix is never materialized beyond one q-chunk —
    essential for 32k+ prefills, where full scores are O(10 GB)/device.
    The chunk body is checkpointed so train backward recomputes scores.
    """
    b, lq, hq, d = q.shape
    s = keys.shape[1]
    if mask_override is not None or lq <= q_chunk:
        if mask_override is None:
            mask_override = attention_mask(q_positions, s, causal=causal,
                                           window=window,
                                           kv_valid_len=kv_valid_len)
        return gqa_attention(q, keys, vals, mask_override)

    pad = (-lq) % q_chunk
    if pad:
        q = jnp.pad(q, ((0, 0), (0, pad), (0, 0), (0, 0)))
        q_positions = jnp.pad(q_positions, ((0, 0), (0, pad)))
    nc = q.shape[1] // q_chunk
    qs = q.reshape(b, nc, q_chunk, hq, d).swapaxes(0, 1)      # (nc, B, qc, H, D)
    ps = q_positions.reshape(b, nc, q_chunk).swapaxes(0, 1)

    @jax.checkpoint
    def chunk(carry, xs):
        qc, pc = xs
        mask = attention_mask(pc, s, causal=causal, window=window,
                              kv_valid_len=kv_valid_len)
        return carry, gqa_attention(qc, keys, vals, mask)

    _, out = jax.lax.scan(chunk, (), (qs, ps))
    out = out.swapaxes(0, 1).reshape(b, nc * q_chunk, hq, d)
    return out[:, :lq]


def attention_layer(p: Dict, x: jax.Array, *, cfg, positions: jax.Array,
                    kv: Optional[Tuple[jax.Array, jax.Array]] = None,
                    kv_valid_len: Optional[jax.Array] = None,
                    cache_write_fn=None,
                    mask_override: Optional[jax.Array] = None,
                    dense_cache_write: bool = False,
                    ) -> Tuple[jax.Array, Optional[Tuple]]:
    """One attention mixer.

    Without a cache (train / first prefill): self-attention over x.
    With ``kv=(K, V)`` cache arrays of shape (B, S, Hkv, D): new tokens are
    written at ``positions`` (re-prefill / decode) and attention runs over
    the full cache.

    Returns (output, updated_kv or None).
    """
    b, l, _ = x.shape
    hd = cfg.hdim
    q = x @ p["wq"]
    k = x @ p["wk"]
    v = x @ p["wv"]
    if cfg.qkv_bias:
        q = q + p["bq"]
        k = k + p["bk"]
        v = v + p["bv"]
    q = q.reshape(b, l, cfg.num_heads, hd)
    k = k.reshape(b, l, cfg.num_kv_heads, hd)
    v = v.reshape(b, l, cfg.num_kv_heads, hd)
    if cfg.qk_norm:
        q = rms_norm(q, p["q_norm"], cfg.norm_eps)
        k = rms_norm(k, p["k_norm"], cfg.norm_eps)
    if cfg.causal:  # encoder-only models use absolute (no) rope here
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    q = constrain(q, "batch", "seq", "heads", "head_dim")

    updated = None
    if kv is None:
        keys, vals = k, v
    elif dense_cache_write:
        # fresh full prefill covering the whole cache (L == S): the
        # "write" is a pure layout change (batch-sharded compute KV →
        # cache sharding), avoiding the scatter XLA can only partition
        # by full rematerialization.  SWA rolling caches (S == window < L)
        # keep the last `window` tokens — position p lands in slot
        # p % window, and the tail slice is exactly slot-aligned.
        s_cache = kv[0].shape[1]
        assert s_cache == l or l % s_cache == 0, (kv[0].shape, l)
        ck = constrain(k[:, l - s_cache:].astype(kv[0].dtype),
                       "batch", "cache_seq", "kv_heads", "head_dim")
        cv = constrain(v[:, l - s_cache:].astype(kv[1].dtype),
                       "batch", "cache_seq", "kv_heads", "head_dim")
        updated = (ck, cv)
        keys, vals = k, v
    else:
        ck, cv = kv
        if cache_write_fn is None:
            cache_write_fn = write_kv_cache
        ck = cache_write_fn(ck, k, positions)
        cv = cache_write_fn(cv, v, positions)
        updated = (ck, cv)
        keys, vals = ck, cv

    out = attention_core(q, keys, vals, positions, causal=cfg.causal,
                         window=cfg.sliding_window,
                         kv_valid_len=kv_valid_len if kv is not None else None,
                         mask_override=mask_override)
    out = out.reshape(b, l, cfg.num_heads * hd)
    out = out @ p["wo"]
    return constrain(out, "batch", "seq", "embed_act"), updated


def packed_attention_layer(p: Dict, x: jax.Array, *, cfg,
                           positions: jax.Array, seg_ids: jax.Array,
                           cu_seqlens: jax.Array, q_offsets: jax.Array,
                           kv_lengths: jax.Array,
                           kv: Tuple[jax.Array, jax.Array],
                           ) -> Tuple[jax.Array, Tuple]:
    """Attention over a packed flat token stream (padding-free prefill).

    x: (T, d) — the concatenated new tokens of every sequence in the
    batch; sequence i owns rows [cu_seqlens[i], cu_seqlens[i+1]).
    positions: (T,) absolute position of each token in ITS sequence
    (history offset + local index); seg_ids: (T,) cache row each token's
    KV is written to; kv: (K, V) caches of shape (B, S, Hkv, D).

    New KV is scatter-written at (seg_ids, positions), then the ragged
    kernel attends each row to its own sequence's cache only.  Returns
    (out (T, d), updated (K, V)).
    """
    from repro.kernels import ops as kernel_ops

    t = x.shape[0]
    hd = cfg.hdim
    q = x @ p["wq"]
    k = x @ p["wk"]
    v = x @ p["wv"]
    if cfg.qkv_bias:
        q = q + p["bq"]
        k = k + p["bk"]
        v = v + p["bv"]
    q = q.reshape(t, cfg.num_heads, hd)
    k = k.reshape(t, cfg.num_kv_heads, hd)
    v = v.reshape(t, cfg.num_kv_heads, hd)
    if cfg.qk_norm:
        q = rms_norm(q, p["q_norm"], cfg.norm_eps)
        k = rms_norm(k, p["k_norm"], cfg.norm_eps)
    q = apply_rope(q[None], positions[None], cfg.rope_theta)[0]
    k = apply_rope(k[None], positions[None], cfg.rope_theta)[0]

    ck = kv[0].at[seg_ids, positions].set(k.astype(kv[0].dtype))
    cv = kv[1].at[seg_ids, positions].set(v.astype(kv[1].dtype))

    out = kernel_ops.ragged_mha(q, ck, cv, cu_seqlens, q_offsets, kv_lengths,
                                causal=cfg.causal)
    out = out.reshape(t, cfg.num_heads * hd) @ p["wo"]
    return out, (ck, cv)


def packed_arena_attention_layer(p: Dict, x: jax.Array, *, cfg,
                                 positions: jax.Array, seg_slots: jax.Array,
                                 slot_map: jax.Array,
                                 cu_seqlens: jax.Array, q_offsets: jax.Array,
                                 kv_lengths: jax.Array,
                                 kv: Tuple[jax.Array, jax.Array],
                                 window: Optional[int] = None,
                                 ) -> Tuple[jax.Array, Tuple]:
    """Attention over a packed flat stream, arena-resident (DESIGN.md §6).

    x: (T, d) — the concatenated new tokens of every segment in the
    step; kv: (K, V) FULL arena buffers of shape (N_slots, S_max, Hkv,
    D); positions: (T,) absolute position of each token in ITS sequence
    (tail rows park at S_max − 1); seg_slots: (T,) arena slot each
    token's KV is written to (tail rows reuse a live slot but write at
    the park position — the scratch row, never live data); slot_map:
    (B,) arena slot per segment for the kernel's KV routing.

    The new KV rows are scatter-written at (seg_slots, positions) —
    O(T) rows, in place under buffer donation — and the arena-resident
    ragged kernel attends each stream row to its own segment's valid
    cache prefix only.  No whole slots are gathered or scattered.
    Returns (out (T, d), updated (K, V) arenas).

    ``window``: sliding-window width (DESIGN.md §7).  The arena slot is
    then window-deep (depth = window + margin < S_max) and the new KV
    rows are ROLLING (modular) writes at position % depth — the
    wraparound overwrites exactly the positions that fell out of every
    query's window, provided depth ≥ window + segment_len − 1 (the
    packing layer enforces segment_len ≤ margin + 1).  Tail rows must
    then park in a dedicated SCRATCH slot (there is no spare row in a
    rolling slot — every row cycles live).  The kernel masks each query
    to (qpos − window, qpos], streaming O(min(cached, window)) rows.
    """
    from repro.kernels import ops as kernel_ops

    t = x.shape[0]
    hd = cfg.hdim
    q = x @ p["wq"]
    k = x @ p["wk"]
    v = x @ p["wv"]
    if cfg.qkv_bias:
        q = q + p["bq"]
        k = k + p["bk"]
        v = v + p["bv"]
    q = q.reshape(t, cfg.num_heads, hd)
    k = k.reshape(t, cfg.num_kv_heads, hd)
    v = v.reshape(t, cfg.num_kv_heads, hd)
    if cfg.qk_norm:
        q = rms_norm(q, p["q_norm"], cfg.norm_eps)
        k = rms_norm(k, p["k_norm"], cfg.norm_eps)
    q = apply_rope(q[None], positions[None], cfg.rope_theta)[0]
    k = apply_rope(k[None], positions[None], cfg.rope_theta)[0]

    write_pos = positions if window is None else positions % kv[0].shape[1]
    ck = kv[0].at[seg_slots, write_pos].set(k.astype(kv[0].dtype))
    cv = kv[1].at[seg_slots, write_pos].set(v.astype(kv[1].dtype))

    out = kernel_ops.ragged_mha_arena(q, ck, cv, slot_map, cu_seqlens,
                                      q_offsets, kv_lengths,
                                      causal=cfg.causal, window=window)
    out = out.reshape(t, cfg.num_heads * hd) @ p["wo"]
    return out, (ck, cv)


def packed_paged_attention_layer(p: Dict, x: jax.Array, *, cfg,
                                 positions: jax.Array,
                                 token_pages: jax.Array,
                                 token_offs: jax.Array,
                                 page_table: jax.Array,
                                 cu_seqlens: jax.Array, q_offsets: jax.Array,
                                 kv_lengths: jax.Array,
                                 kv: Tuple[jax.Array, jax.Array],
                                 window: Optional[int] = None,
                                 ) -> Tuple[jax.Array, Tuple]:
    """Attention over a packed flat stream, PAGED (DESIGN.md §8).

    The paged sibling of :func:`packed_arena_attention_layer`: kv are
    (K, V) page POOLS of shape (N_pages, page_size, Hkv, D) and each
    segment's cache is the ordered page list in its row of
    ``page_table`` (B, P_max) — so pages can be shared across segments
    (radix prefix reuse, COW forks).  positions: (T,) absolute position
    of each token in ITS sequence (rope + causal masking);
    token_pages/token_offs: (T,) physical (page, offset) each token's
    new KV is scatter-written to — pad/tail rows target the reserved
    scratch page at offset page_size − 1, never a live page.

    ``window``: sliding-window width (DESIGN.md §12).  The page table
    is then a RING over its P_max entries: position p lives on ring
    page (p // ps) % P_max — the engine computes token_pages through
    that ring, so the write below is already modular and only the
    kernel mask changes here.

    The write is O(T) rows in place under donation; the paged ragged
    kernel then attends each stream row through its segment's page
    table.  Returns (out (T, d), updated (K, V) pools).
    """
    from repro.kernels import ops as kernel_ops

    t = x.shape[0]
    hd = cfg.hdim
    q = x @ p["wq"]
    k = x @ p["wk"]
    v = x @ p["wv"]
    if cfg.qkv_bias:
        q = q + p["bq"]
        k = k + p["bk"]
        v = v + p["bv"]
    q = q.reshape(t, cfg.num_heads, hd)
    k = k.reshape(t, cfg.num_kv_heads, hd)
    v = v.reshape(t, cfg.num_kv_heads, hd)
    if cfg.qk_norm:
        q = rms_norm(q, p["q_norm"], cfg.norm_eps)
        k = rms_norm(k, p["k_norm"], cfg.norm_eps)
    q = apply_rope(q[None], positions[None], cfg.rope_theta)[0]
    k = apply_rope(k[None], positions[None], cfg.rope_theta)[0]

    ck = kv[0].at[token_pages, token_offs].set(k.astype(kv[0].dtype))
    cv = kv[1].at[token_pages, token_offs].set(v.astype(kv[1].dtype))

    out = kernel_ops.ragged_mha_paged(q, ck, cv, page_table, cu_seqlens,
                                      q_offsets, kv_lengths,
                                      causal=cfg.causal, window=window)
    out = out.reshape(t, cfg.num_heads * hd) @ p["wo"]
    return out, (ck, cv)


def paged_decode_layer(p: Dict, x: jax.Array, *, cfg,
                       positions: jax.Array,
                       write_pages: jax.Array, write_offs: jax.Array,
                       page_table: jax.Array, kv_lengths: jax.Array,
                       kv: Tuple[jax.Array, jax.Array],
                       window: Optional[int] = None,
                       ) -> Tuple[jax.Array, Tuple]:
    """Attention for one PAGED decode tick (DESIGN.md §8).

    The paged sibling of :func:`arena_decode_layer`: kv are (K, V) page
    pools (N_pages, page_size, Hkv, D) and each row's cache is its page
    list in ``page_table`` (B, P_max).  positions: (B,) absolute
    position of the new token (rope); write_pages/write_offs: (B,)
    physical (page, offset) its KV lands in — pad rows target the
    scratch page at offset page_size − 1; kv_lengths: (B,) valid cache
    entries including the new row.  ``window`` selects the ring-table
    form (DESIGN.md §12) — write_pages already walk the ring, computed
    by the engine.  Returns (out (B, d), updated pools).
    """
    from repro.kernels import ops as kernel_ops

    b = x.shape[0]
    hd = cfg.hdim
    q = x @ p["wq"]
    k = x @ p["wk"]
    v = x @ p["wv"]
    if cfg.qkv_bias:
        q = q + p["bq"]
        k = k + p["bk"]
        v = v + p["bv"]
    q = q.reshape(b, cfg.num_heads, hd)
    k = k.reshape(b, cfg.num_kv_heads, hd)
    v = v.reshape(b, cfg.num_kv_heads, hd)
    if cfg.qk_norm:
        q = rms_norm(q, p["q_norm"], cfg.norm_eps)
        k = rms_norm(k, p["k_norm"], cfg.norm_eps)
    q = apply_rope(q[:, None], positions[:, None], cfg.rope_theta)[:, 0]
    k = apply_rope(k[:, None], positions[:, None], cfg.rope_theta)[:, 0]

    ck = kv[0].at[write_pages, write_offs].set(k.astype(kv[0].dtype))
    cv = kv[1].at[write_pages, write_offs].set(v.astype(kv[1].dtype))

    out = kernel_ops.decode_paged(q, ck, cv, page_table, kv_lengths,
                                  window=window)
    out = out.reshape(b, cfg.num_heads * hd) @ p["wo"]
    return out, (ck, cv)


def arena_decode_layer(p: Dict, x: jax.Array, *, cfg,
                       slot_map: jax.Array, positions: jax.Array,
                       kv_lengths: jax.Array,
                       kv: Tuple[jax.Array, jax.Array],
                       window: Optional[int] = None,
                       ) -> Tuple[jax.Array, Tuple]:
    """Attention for one arena-resident decode tick.

    x: (B, d) — ONE new token per batch row; kv: (K, V) FULL arena
    buffers of shape (N_slots, S, Hkv, D); slot_map: (B,) arena slot of
    each row; positions: (B,) absolute write position of the new token
    (its cached history length; pad rows park at S-1); kv_lengths: (B,)
    valid cache entries including the new row.

    The single new KV row is scatter-written at (slot_map, positions) —
    O(B) rows, in place under buffer donation — and the arena-resident
    kernel attends each row over its own valid prefix only.  No whole
    slots are gathered or scattered.  Returns (out (B, d), updated
    (K, V) arenas).

    ``window``: sliding-window width (DESIGN.md §7).  The arena slot is
    then a window-deep ROLLING cache written modularly at position %
    depth (pad rows must point at the scratch slot — every row of a
    rolling slot cycles live), and the kernel streams O(min(cached,
    window)) rows per generated token.
    """
    from repro.kernels import ops as kernel_ops

    b = x.shape[0]
    hd = cfg.hdim
    q = x @ p["wq"]
    k = x @ p["wk"]
    v = x @ p["wv"]
    if cfg.qkv_bias:
        q = q + p["bq"]
        k = k + p["bk"]
        v = v + p["bv"]
    q = q.reshape(b, cfg.num_heads, hd)
    k = k.reshape(b, cfg.num_kv_heads, hd)
    v = v.reshape(b, cfg.num_kv_heads, hd)
    if cfg.qk_norm:
        q = rms_norm(q, p["q_norm"], cfg.norm_eps)
        k = rms_norm(k, p["k_norm"], cfg.norm_eps)
    q = apply_rope(q[:, None], positions[:, None], cfg.rope_theta)[:, 0]
    k = apply_rope(k[:, None], positions[:, None], cfg.rope_theta)[:, 0]

    write_pos = positions if window is None else positions % kv[0].shape[1]
    ck = kv[0].at[slot_map, write_pos].set(k.astype(kv[0].dtype))
    cv = kv[1].at[slot_map, write_pos].set(v.astype(kv[1].dtype))

    out = kernel_ops.decode_arena(q, ck, cv, slot_map, kv_lengths,
                                  window=window)
    out = out.reshape(b, cfg.num_heads * hd) @ p["wo"]
    return out, (ck, cv)


def write_kv_cache(cache: jax.Array, new: jax.Array, positions: jax.Array) -> jax.Array:
    """Scatter new KV rows into the cache at per-token absolute positions.

    cache: (B, S, Hkv, D); new: (B, L, Hkv, D); positions: (B, L).
    """
    def one(c, n, pos):
        return c.at[pos].set(n.astype(c.dtype))
    return jax.vmap(one)(cache, new, positions)


def init_attention(pb: ParamBuilder, cfg) -> None:
    hd = cfg.hdim
    qd, kvd = cfg.num_heads * hd, cfg.num_kv_heads * hd
    pb.dense("wq", (cfg.d_model, qd), ("embed", "heads"))
    pb.dense("wk", (cfg.d_model, kvd), ("embed", "kv_heads"))
    pb.dense("wv", (cfg.d_model, kvd), ("embed", "kv_heads"))
    pb.dense("wo", (qd, cfg.d_model), ("heads", "embed"))
    if cfg.qkv_bias:
        pb.zeros("bq", (qd,), ("heads",))
        pb.zeros("bk", (kvd,), ("kv_heads",))
        pb.zeros("bv", (kvd,), ("kv_heads",))
    if cfg.qk_norm:
        pb.ones("q_norm", (hd,), (None,))
        pb.ones("k_norm", (hd,), (None,))


# ------------------------------------------------------------------- mlp


def swiglu(p: Dict, x: jax.Array) -> jax.Array:
    h = jax.nn.silu(x @ p["wg"]) * (x @ p["wi"])
    h = constrain(h, "batch", "seq", "mlp")
    return constrain(h @ p["wo"], "batch", "seq", "embed_act")


def init_mlp(pb: ParamBuilder, d_model: int, d_ff: int) -> None:
    pb.dense("wg", (d_model, d_ff), ("embed", "mlp"))
    pb.dense("wi", (d_model, d_ff), ("embed", "mlp"))
    pb.dense("wo", (d_ff, d_model), ("mlp", "embed"))
