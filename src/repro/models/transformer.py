"""Full model assembly for every assigned architecture family.

Layers are stacked in *pattern groups*: the layer pattern of period ``p``
(dense: 1, jamba hybrid: 8) is unrolled inside a ``jax.lax.scan`` body and
parameters are stacked over the ``G = num_layers / p`` groups.  This keeps
HLO size O(pattern) instead of O(num_layers) — essential for dry-run
compile times at 32–64 layers — and gives the remat boundary used in
training (checkpoint per scan body).

Caches (KV for attention layers, (ssm, conv) state for mamba layers) are
pytrees stacked the same way, scanned through as xs/ys.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.distributed.sharding import constrain, constrain_tree
from repro.models import mamba as mamba_mod
from repro.models.config import ModelConfig
from repro.models.layers import (ParamBuilder, arena_decode_layer,
                                 attention_layer, init_attention, init_mlp,
                                 packed_arena_attention_layer,
                                 packed_attention_layer, packed_paged_attention_layer,
                                 paged_decode_layer, rms_norm, swiglu,
                                 write_kv_cache)
from repro.models.moe import init_moe, moe_dense_reference, moe_layer


def pattern_period(cfg: ModelConfig) -> int:
    if cfg.family == "hybrid":
        import math
        return math.lcm(cfg.attn_layer_period, cfg.moe_layer_period)
    return 1


def num_groups(cfg: ModelConfig) -> int:
    p = pattern_period(cfg)
    assert cfg.num_layers % p == 0, (cfg.num_layers, p)
    return cfg.num_layers // p


# ------------------------------------------------------------------- init


def _init_one_layer(key, cfg: ModelConfig, j: int, abstract: bool = False):
    pb = ParamBuilder(key, cfg.np_dtype, abstract)
    pb.ones("ln1", (cfg.d_model,), (None,))
    mx = pb.sub("mixer")
    if cfg.layer_kind(j) == "attn":
        init_attention(mx, cfg)
    else:
        mamba_mod.init_mamba(mx, cfg)
    if cfg.family == "ssm":
        # mamba2 arch: no separate FFN (the block already mixes channels)
        pass
    else:
        pb.ones("ln2", (cfg.d_model,), (None,))
        ff = pb.sub("ffn")
        if cfg.layer_is_moe(j):
            init_moe(ff, cfg.d_model, cfg.moe_d_ff or cfg.d_ff, cfg.num_experts)
        else:
            init_mlp(ff, cfg.d_model, cfg.d_ff)
    return pb.params, pb.axes


def init_params(cfg: ModelConfig, key=None,
                abstract: bool = False) -> Tuple[Dict, Dict]:
    """Returns (params, logical_axes) with pattern-stacked blocks.

    abstract=True: ShapeDtypeStruct leaves, no allocation (dry-run)."""
    p = pattern_period(cfg)
    g = num_groups(cfg)
    if abstract:
        keys = [None] * (2 + p * g)
    else:
        keys = list(jax.random.split(key, 2 + p * g))
    pb = ParamBuilder(keys[0], cfg.np_dtype, abstract)
    pb.dense("embed", (cfg.padded_vocab, cfg.d_model), ("vocab", "embed"),
             scale=0.02)
    blocks, blocks_axes = [], []
    ki = 2
    for j in range(p):
        per_group = []
        axes_j = None
        for _ in range(g):
            lp, la = _init_one_layer(keys[ki], cfg, j, abstract)
            per_group.append(lp)
            axes_j = la
            ki += 1
        if abstract:
            stacked = jax.tree.map(
                lambda s: jax.ShapeDtypeStruct((g,) + s.shape, s.dtype),
                per_group[0])
        else:
            stacked = jax.tree.map(lambda *xs: jnp.stack(xs, axis=0),
                                   *per_group)
        blocks.append(stacked)
        # leading scan dim is unsharded
        blocks_axes.append(jax.tree.map(
            lambda ax: (None,) + tuple(ax),
            axes_j, is_leaf=lambda x: isinstance(x, tuple)))
    pb.params["blocks"] = blocks
    pb.axes["blocks"] = blocks_axes
    pb.ones("final_norm", (cfg.d_model,), (None,))
    pb.dense("lm_head", (cfg.d_model, cfg.padded_vocab), ("embed", "vocab"),
             scale=cfg.d_model ** -0.5)
    return pb.params, pb.axes


def param_axes(cfg: ModelConfig) -> Dict:
    """Logical-axes tree without materializing params."""
    return init_params(cfg, abstract=True)[1]


def param_shapes(cfg: ModelConfig):
    return init_params(cfg, abstract=True)[0]


# ------------------------------------------------------------------ cache


def init_cache(cfg: ModelConfig, batch: int, max_len: int,
               dtype=None, swa_depth: Optional[int] = None) -> List[Any]:
    """Per-pattern-position cache, stacked over groups.

    attn position: {"k": (G,B,S,Hkv,D), "v": ...}
    ssm  position: {"ssm": (G,B,nh,hd,ds), "conv": (G,B,W-1,C)}

    swa_depth: attention-slot depth for sliding-window configs.  None
    keeps the legacy window-deep rolling cache (min(max_len, window));
    the serving arena passes window + margin (the §7 rolling arena,
    margin absorbing one step's writes before wraparound could alias)
    or max_len (the dense baseline, which masks the window instead of
    rolling).  Always capped at max_len.
    """
    dtype = dtype or cfg.np_dtype
    p = pattern_period(cfg)
    g = num_groups(cfg)
    caches: List[Any] = []
    for j in range(p):
        if cfg.layer_kind(j) == "attn":
            s = max_len
            if cfg.sliding_window is not None:
                s = min(max_len, swa_depth if swa_depth is not None
                        else cfg.sliding_window)
            # k and v must be DISTINCT buffers: donating an aliased pair
            # trips "attempt to donate the same buffer twice" in XLA
            shape = (g, batch, s, cfg.num_kv_heads, cfg.hdim)
            caches.append({"k": jnp.zeros(shape, dtype),
                           "v": jnp.zeros(shape, dtype)})
        else:
            ssm, conv = mamba_mod.init_mamba_cache(cfg, batch, dtype)
            caches.append({"ssm": jnp.broadcast_to(ssm, (g,) + ssm.shape),
                           "conv": jnp.broadcast_to(conv, (g,) + conv.shape)})
    return caches


def cache_logical_axes(cfg: ModelConfig) -> List[Any]:
    """Logical axes for the cache pytree (serve rules shard KV seq)."""
    p = pattern_period(cfg)
    out: List[Any] = []
    for j in range(p):
        if cfg.layer_kind(j) == "attn":
            ax = (None, "batch", "cache_seq", "kv_heads", "head_dim")
            out.append({"k": ax, "v": ax})
        else:
            out.append({"ssm": (None, "batch", "ssm_heads", None, None),
                        "conv": (None, "batch", None, "conv_ch")})
    return out


# ---------------------------------------------------------------- forward


def _block(cfg: ModelConfig, j: int, lp: Dict, x: jax.Array, cache, *,
           positions, seq_valid_len, kv_valid_len, decode: bool,
           rolling: bool, dense_write: bool = False):
    """One pattern-position layer. Returns (x, new_cache, aux)."""
    aux = jnp.zeros((), jnp.float32)
    h = rms_norm(x, lp["ln1"], cfg.norm_eps)
    if cfg.layer_kind(j) == "attn":
        kv = (cache["k"], cache["v"]) if cache is not None else None
        if rolling and kv is not None:
            window = kv[0].shape[1]
            wfn = functools.partial(_rolling_write, window=window)
            from repro.models.layers import rolling_mask
            mix, upd = attention_layer(
                lp["mixer"], h, cfg=cfg, positions=positions, kv=kv,
                kv_valid_len=None, cache_write_fn=wfn,
                mask_override=rolling_mask(positions, window))
        else:
            mix, upd = attention_layer(
                lp["mixer"], h, cfg=cfg, positions=positions, kv=kv,
                kv_valid_len=kv_valid_len, dense_cache_write=dense_write)
        new_cache = {"k": upd[0], "v": upd[1]} if upd is not None else None
    else:
        cc = (cache["ssm"], cache["conv"]) if cache is not None else None
        mix, upd = mamba_mod.mamba_layer(lp["mixer"], h, cfg=cfg, cache=cc,
                                         decode=decode,
                                         valid_len=seq_valid_len)
        new_cache = {"ssm": upd[0], "conv": upd[1]} if upd is not None else None
    x = x + mix
    if cfg.family != "ssm":
        x, a = _ffn(cfg, j, lp, x)
        aux = aux + a
    return x, new_cache, aux


def _ffn(cfg: ModelConfig, j: int, lp: Dict, x: jax.Array
         ) -> Tuple[jax.Array, jax.Array]:
    """Post-mixer FFN residual for one layer.  x: (B, L, d)."""
    aux = jnp.zeros((), jnp.float32)
    h = rms_norm(x, lp["ln2"], cfg.norm_eps)
    if cfg.layer_is_moe(j):
        if cfg.num_experts <= 8 and h.shape[0] * h.shape[1] <= 4096:
            y, aux = moe_dense_reference(lp["ffn"], h,
                                         top_k=cfg.num_experts_per_tok)
        else:
            y, aux = moe_layer(lp["ffn"], h, top_k=cfg.num_experts_per_tok)
    else:
        y = swiglu(lp["ffn"], h)
    return x + y, aux


def _rolling_write(cache, new, positions, *, window):
    return write_kv_cache(cache, new, positions % window)


def forward(params: Dict, cfg: ModelConfig, *,
            tokens: Optional[jax.Array] = None,
            embeds: Optional[jax.Array] = None,
            positions: Optional[jax.Array] = None,
            caches: Optional[List[Any]] = None,
            kv_valid_len: Optional[jax.Array] = None,
            seq_valid_len: Optional[jax.Array] = None,
            rolling: bool = False,
            remat: bool = False,
            logits_slice: Optional[str] = None,
            dense_cache_write: bool = False,
            ) -> Tuple[jax.Array, Optional[List[Any]], jax.Array]:
    """Unified forward.

    tokens: (B, L) int32 — or embeds: (B, L, d) for stub frontends.
    positions: (B, L) absolute positions (defaults arange).
    caches: from :func:`init_cache`; when given, attention writes new KV at
      ``positions`` and mamba layers thread their state (decode inferred
      from L == 1).  Caches ride the layer-scan CARRY and are updated with
      dynamic_update_index_in_dim — in-place under buffer donation, so the
      serving steps never hold two full cache copies.
    dense_cache_write: fresh full prefill covering the entire cache
      (L == S): KV "write" becomes a pure resharding copy.
    logits_slice: None → full (B, L, V) logits; "last" → (B, V) of final
      position only (decode/prefill TTFT path — avoids the full-vocab
      matmul over L).
    Returns (logits, new_caches, moe_aux_loss).
    """
    if embeds is None:
        x = jnp.take(params["embed"], tokens, axis=0)
    else:
        x = embeds.astype(params["embed"].dtype)
    b, l = x.shape[:2]
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(l)[None, :], (b, l))
    x = constrain(x, "batch", "seq", "embed_act")
    decode = l == 1 and caches is not None

    p = pattern_period(cfg)
    has_cache = caches is not None
    cache_axes = cache_logical_axes(cfg) if has_cache else None

    def body(carry, xs):
        if has_cache:
            x, aux, cs_all, g = carry
        else:
            x, aux = carry
            cs_all = None
        lps = xs
        for j in range(p):
            if cs_all is not None:
                cache_j = jax.tree.map(
                    lambda a: jax.lax.dynamic_index_in_dim(
                        a, g, 0, keepdims=False), cs_all[j])
            else:
                cache_j = None
            blk = functools.partial(
                _block, cfg, j, positions=positions,
                seq_valid_len=seq_valid_len, kv_valid_len=kv_valid_len,
                decode=decode, rolling=rolling,
                dense_write=dense_cache_write)
            if remat and p > 1:
                # nested per-layer remat: with a multi-layer pattern body
                # (jamba p=8) a single body-level checkpoint would hold all
                # 8 layers' residuals live during the block's backward
                blk = jax.checkpoint(blk, prevent_cse=False)
            x, nc, a = blk(lps[j], x, cache_j)
            aux = aux + a
            if cs_all is not None:
                upd = jax.tree.map(
                    lambda full, u: jax.lax.dynamic_update_index_in_dim(
                        full, u.astype(full.dtype), g, 0),
                    cs_all[j], nc)
                # pin the loop-carried cache sharding: XLA's propagation
                # through while-carries can decay to replicated (→ tens
                # of GiB of KV rematerialized per device)
                cs_all[j] = constrain_tree(upd, cache_axes[j])
        if has_cache:
            return (x, aux, cs_all, g + 1), None
        return (x, aux), None

    if remat:
        body = jax.checkpoint(body, prevent_cse=False)

    zero = jnp.zeros((), jnp.float32)
    if has_cache:
        carry0 = (x, zero, list(caches), jnp.zeros((), jnp.int32))
        (x, aux, new_caches, _), _ = jax.lax.scan(body, carry0,
                                                  params["blocks"])
    else:
        (x, aux), _ = jax.lax.scan(body, (x, zero), params["blocks"])
        new_caches = None

    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    vpad = cfg.padded_vocab - cfg.vocab_size
    if logits_slice == "last":
        x = x[:, -1]
        logits = x @ params["lm_head"]
        logits = constrain(logits, "batch", "vocab")
    else:
        logits = x @ params["lm_head"]
        logits = constrain(logits, "batch", "seq", "vocab")
    if vpad:
        # mask padded vocabulary columns (argmax/softmax safety)
        neg = jnp.concatenate(
            [jnp.zeros((cfg.vocab_size,), logits.dtype),
             jnp.full((vpad,), -1e9, logits.dtype)])
        logits = logits + neg
    return logits, new_caches, aux


# ---------------------------------------------------------------- packed


def _lm_head_logits(params: Dict, cfg: ModelConfig,
                    x: jax.Array) -> jax.Array:
    """Final-norm'd (B, d) rows → (B, V) logits with the padded-vocab
    columns masked (argmax/softmax safety).  ONE implementation shared
    by every serving step that emits one logit row per sequence — the
    packed, packed-arena, and arena-decode paths must never diverge
    here, they are parity-tested against each other."""
    logits = x @ params["lm_head"]
    logits = constrain(logits, "batch", "vocab")
    vpad = cfg.padded_vocab - cfg.vocab_size
    if vpad:
        neg = jnp.concatenate(
            [jnp.zeros((cfg.vocab_size,), logits.dtype),
             jnp.full((vpad,), -1e9, logits.dtype)])
        logits = logits + neg
    return logits


def _scan_serving_stack(params: Dict, cfg: ModelConfig, tokens: jax.Array,
                        caches: List[Any], mix_fn
                        ) -> Tuple[jax.Array, List[Any]]:
    """Shared layer-scan scaffold for the flat-stream serving steps
    (packed prefill, arena packed prefill, arena decode): embed →
    per-group {norm → mix_fn → FFN → cache writeback} → final norm.

    mix_fn(j, layer_params, h, cache_j) → (mix, new_cache_dict) supplies
    the mixer variant for pattern position j — attention (full or
    windowed) returning {"k", "v"}, or an SSM block returning
    {"ssm", "conv"}; everything else — including the cache
    constrain_tree pinning — is identical across the paths and lives
    exactly once.  Returns (final-normed activations, new caches)."""
    x = jnp.take(params["embed"], tokens, axis=0)
    p = pattern_period(cfg)
    cache_axes = cache_logical_axes(cfg)

    def body(carry, lps):
        x, aux, cs_all, g = carry
        for j in range(p):
            cache_j = jax.tree.map(
                lambda a: jax.lax.dynamic_index_in_dim(
                    a, g, 0, keepdims=False), cs_all[j])
            h = rms_norm(x, lps[j]["ln1"], cfg.norm_eps)
            mix, nc = mix_fn(j, lps[j]["mixer"], h, cache_j)
            x = x + mix
            if cfg.family != "ssm":
                x2, a = _ffn(cfg, j, lps[j], x[None])
                x = x2[0]
                aux = aux + a
            full = jax.tree.map(
                lambda fa, u: jax.lax.dynamic_update_index_in_dim(
                    fa, u.astype(fa.dtype), g, 0), cs_all[j], nc)
            cs_all[j] = constrain_tree(full, cache_axes[j])
        return (x, aux, cs_all, g + 1), None

    zero = jnp.zeros((), jnp.float32)
    carry0 = (x, zero, list(caches), jnp.zeros((), jnp.int32))
    (x, _, new_caches, _), _ = jax.lax.scan(body, carry0, params["blocks"])
    return rms_norm(x, params["final_norm"], cfg.norm_eps), new_caches


# ------------------------------------------------- capability descriptor


@dataclasses.dataclass(frozen=True)
class LayerCapability:
    """Arena capability of ONE pattern position (DESIGN.md §7).

    kind: "attn" (full-attention KV slot), "attn_window" (rolling
    window-deep KV slot + windowed kernel), or "ssm" (recurrent-state
    slot stepped in place).  window is the sliding-window width for
    attn_window positions, None otherwise.
    """
    kind: str
    window: Optional[int] = None


@dataclasses.dataclass(frozen=True)
class ArenaCapability:
    """Per-layer arena-residency descriptor of a model config.

    Replaces the old boolean ``supports_packed`` fallback matrix: every
    CAUSAL architecture is arena-resident (packed prefill + bucketed
    decode through the slot-map kernels), each pattern position routed
    by its :class:`LayerCapability`.  The dense (L, B) grid survives
    only as an explicitly requested measurement baseline and for
    encoder-only models (no serving decode loop at all).
    """
    layers: Tuple[LayerCapability, ...]   # one per pattern position
    causal: bool

    @property
    def packed_ok(self) -> bool:
        """Arena-resident packed prefill + decode are available."""
        return self.causal

    @property
    def pure_attn(self) -> bool:
        """Every mixer is full attention — the only configs the LEGACY
        gathered-cache packed path (forward_packed) can also run."""
        return all(c.kind == "attn" for c in self.layers)

    @property
    def has_window(self) -> bool:
        return any(c.kind == "attn_window" for c in self.layers)

    @property
    def has_ssm(self) -> bool:
        return any(c.kind == "ssm" for c in self.layers)

    @property
    def window(self) -> Optional[int]:
        for c in self.layers:
            if c.kind == "attn_window":
                return c.window
        return None

    @property
    def needs_scratch_slot(self) -> bool:
        """Rolling KV slots have no spare park row (every row cycles
        live) and SSM state has no park position at all — pads must
        target a dedicated scratch slot instead of aliasing a live one."""
        return self.has_window or self.has_ssm


def arena_capability(cfg: ModelConfig) -> ArenaCapability:
    """Per-layer capability descriptor — the §7 routing contract."""
    layers = []
    for j in range(pattern_period(cfg)):
        if cfg.layer_kind(j) != "attn":
            layers.append(LayerCapability("ssm"))
        elif cfg.sliding_window is not None:
            layers.append(LayerCapability("attn_window",
                                          window=cfg.sliding_window))
        else:
            layers.append(LayerCapability("attn"))
    return ArenaCapability(layers=tuple(layers), causal=cfg.causal)


def supports_packed(cfg: ModelConfig) -> bool:
    """LEGACY predicate for the gathered-cache packed path
    (:func:`forward_packed`), which needs pure-attention mixers with a
    full cache.  Arena routing uses :func:`arena_capability` instead —
    SSM and sliding-window configs are arena-resident there."""
    cap = arena_capability(cfg)
    return cap.causal and cap.pure_attn


def forward_packed(params: Dict, cfg: ModelConfig, *,
                   tokens: jax.Array,
                   positions: jax.Array,
                   seg_ids: jax.Array,
                   cu_seqlens: jax.Array,
                   q_offsets: jax.Array,
                   kv_lengths: jax.Array,
                   caches: List[Any],
                   last_idx: jax.Array,
                   ) -> Tuple[jax.Array, List[Any]]:
    """Padding-free forward over a packed flat token stream — the
    continuous-batching step: prefill AND decode segments side by side.

    tokens/positions/seg_ids: (T,) — the concatenation of every
    sequence's new tokens, each token carrying its absolute position
    (history offset + local index) and its cache row; sequence i owns
    rows [cu_seqlens[i], cu_seqlens[i+1]) of the stream.  Rows past
    cu_seqlens[-1] are bucket tail padding (parked positions, junk row).

    A decode segment is simply length 1 with ``q_offsets[i] = H`` (its
    full cached context) and ``kv_lengths[i] = H + 1``: the scatter in
    :func:`packed_attention_layer` appends its KV at position H and the
    ragged kernel attends it over H + 1 keys — identical math to the
    dense decode step, inside the same dispatch as the prefills.

    caches: from :func:`init_cache` with batch = B cache rows.
    last_idx: (B,) flat index of each sequence's final token — ONE logit
    gathered per segment (prefill TTFT and decode next-token alike).
    Returns (last_logits (B, V), new_caches).

    One compiled shape serves EVERY mix of segment kinds and lengths
    summing under the token bucket T — the compile-cache key space is
    |T buckets|, not |lengths| × |depths|, and prefill/decode mixes
    don't multiply it.
    """
    assert supports_packed(cfg), cfg.name

    def mix_fn(j, lp, h, cache_j):
        mix, upd = packed_attention_layer(
            lp, h, cfg=cfg, positions=positions, seg_ids=seg_ids,
            cu_seqlens=cu_seqlens, q_offsets=q_offsets,
            kv_lengths=kv_lengths, kv=(cache_j["k"], cache_j["v"]))
        return mix, {"k": upd[0], "v": upd[1]}

    x, new_caches = _scan_serving_stack(params, cfg, tokens, caches, mix_fn)
    x_last = jnp.take(x, last_idx, axis=0)                     # (B, d)
    return _lm_head_logits(params, cfg, x_last), new_caches


# ------------------------------------------------- arena packed prefill


def forward_packed_arena(params: Dict, cfg: ModelConfig, *,
                         tokens: jax.Array,
                         positions: jax.Array,
                         seg_slots: jax.Array,
                         slot_map: jax.Array,
                         cu_seqlens: jax.Array,
                         q_offsets: jax.Array,
                         kv_lengths: jax.Array,
                         arena: List[Any],
                         last_idx: jax.Array,
                         ) -> Tuple[jax.Array, List[Any]]:
    """Arena-resident packed forward: the :func:`forward_packed` step
    with the KV arena read and written IN PLACE (DESIGN.md §6).

    Same flat-stream contract as :func:`forward_packed` — prefill,
    chunk, and decode segments side by side, one logit gathered per
    segment via ``last_idx`` — but the cache argument is the KVArena
    pytree itself (per pattern position {"k"/"v": (G, N_slots, S_max,
    Hkv, D)}), not a gathered (B, S, Hkv, D) batch.  ``seg_slots (T,)``
    carries each token's arena slot (tail rows reuse a live slot but
    park at S_max − 1, the scratch row); ``slot_map (B,)`` routes each
    segment's KV reads through the kernel's scalar-prefetched index
    maps.  Each layer scatter-writes ONLY the step's new KV rows, so
    per-step HBM traffic is O(history + new) — not the O(b_max · S_max)
    whole-slot gather/scatter of the batch-cache path.  Under buffer
    donation the arena updates in place; the caller swaps the returned
    pytree back into the KVArena.

    Heterogeneous stacks ride the SAME layer scan (DESIGN.md §7): each
    pattern position routes by its :class:`LayerCapability` — full
    attention slots, windowed ROLLING slots (window-deep arena, modular
    writes, O(min(cached, window)) reads), or SSM state slots stepped in
    place at ``slot_map`` (pad segments point at the arena's scratch
    slot).  Returns (last_logits (B, V), new_arena).
    """
    cap = arena_capability(cfg)
    assert cap.packed_ok, cfg.name
    b = slot_map.shape[0]
    if cap.has_ssm:
        # flat → (segment row, local index) bridge for the SSM scan;
        # computed once, shared by every ssm pattern position
        t = tokens.shape[0]
        rows = jnp.arange(t)
        seg = jnp.sum(rows[:, None] >= cu_seqlens[None, 1:], axis=1)
        valid_row = rows < cu_seqlens[-1]
        seg_rows = jnp.clip(seg, 0, b - 1)
        seg_pos = rows - cu_seqlens[seg_rows]
        seg_lens = cu_seqlens[1:] - cu_seqlens[:-1]

    def mix_fn(j, lp, h, cache_j):
        kind = cap.layers[j].kind
        if kind == "ssm":
            return mamba_mod.packed_arena_mamba_layer(
                lp, h, cfg=cfg, slot_map=slot_map, cache=cache_j,
                seg_rows=seg_rows, seg_pos=seg_pos, valid_row=valid_row,
                seg_lens=seg_lens)
        mix, upd = packed_arena_attention_layer(
            lp, h, cfg=cfg, positions=positions, seg_slots=seg_slots,
            slot_map=slot_map, cu_seqlens=cu_seqlens, q_offsets=q_offsets,
            kv_lengths=kv_lengths, kv=(cache_j["k"], cache_j["v"]),
            window=cap.layers[j].window)
        return mix, {"k": upd[0], "v": upd[1]}

    x, new_arena = _scan_serving_stack(params, cfg, tokens, arena, mix_fn)
    x_last = jnp.take(x, last_idx, axis=0)                     # (B, d)
    return _lm_head_logits(params, cfg, x_last), new_arena


# ------------------------------------------------------- paged serving


def forward_packed_paged(params: Dict, cfg: ModelConfig, *,
                         tokens: jax.Array,
                         positions: jax.Array,
                         token_pages: jax.Array,
                         token_offs: jax.Array,
                         page_table: jax.Array,
                         cu_seqlens: jax.Array,
                         q_offsets: jax.Array,
                         kv_lengths: jax.Array,
                         arena: List[Any],
                         last_idx: jax.Array,
                         state_map: Optional[jax.Array] = None,
                         ) -> Tuple[jax.Array, List[Any]]:
    """Paged packed forward: :func:`forward_packed_arena` with the
    per-segment arena SLOT generalized to a per-block PAGE TABLE
    (DESIGN.md §8).

    Same flat-stream contract — prefill, chunk, and decode segments side
    by side, one logit per segment via ``last_idx`` — but the cache is a
    page POOL (per pattern position {"k"/"v": (G, N_pages + 1,
    page_size, Hkv, D)}) and each segment's logical cache is the ordered
    page list in its row of ``page_table (B, P_max)``.  Pages may be
    SHARED between segments (radix prefix reuse, COW forks): sharing is
    read-only by construction — writes land via ``token_pages`` /
    ``token_offs (T,)``, which the PagedKVArena only ever points at
    exclusively-owned pages (pad/tail rows park on the reserved scratch
    page at offset page_size − 1).

    Heterogeneous stacks ride the same scan (DESIGN.md §12): windowed
    positions treat ``page_table`` as a RING (the engine computes
    token_pages through it, the kernel masks to the window); SSM
    positions hold their per-session recurrent state on a STATE PAGE —
    the pool's page axis doubles as the state-slot axis (per ssm
    position {"ssm": (G, N_pages + 1, NH, HD, DS), "conv": ...}) and
    ``state_map (B,)`` names each segment's state page (pads point at
    the scratch page).  Returns (last_logits (B, V), new_pool).
    """
    cap = arena_capability(cfg)
    assert cap.packed_ok, cfg.name
    b = page_table.shape[0]
    if cap.has_ssm:
        assert state_map is not None, "paged SSM needs a state_map"
        # flat → (segment row, local index) bridge for the SSM scan;
        # computed once, shared by every ssm pattern position
        t = tokens.shape[0]
        rows = jnp.arange(t)
        seg = jnp.sum(rows[:, None] >= cu_seqlens[None, 1:], axis=1)
        valid_row = rows < cu_seqlens[-1]
        seg_rows = jnp.clip(seg, 0, b - 1)
        seg_pos = rows - cu_seqlens[seg_rows]
        seg_lens = cu_seqlens[1:] - cu_seqlens[:-1]

    def mix_fn(j, lp, h, cache_j):
        kind = cap.layers[j].kind
        if kind == "ssm":
            return mamba_mod.packed_arena_mamba_layer(
                lp, h, cfg=cfg, slot_map=state_map, cache=cache_j,
                seg_rows=seg_rows, seg_pos=seg_pos, valid_row=valid_row,
                seg_lens=seg_lens)
        mix, upd = packed_paged_attention_layer(
            lp, h, cfg=cfg, positions=positions, token_pages=token_pages,
            token_offs=token_offs, page_table=page_table,
            cu_seqlens=cu_seqlens, q_offsets=q_offsets,
            kv_lengths=kv_lengths, kv=(cache_j["k"], cache_j["v"]),
            window=cap.layers[j].window)
        return mix, {"k": upd[0], "v": upd[1]}

    x, new_arena = _scan_serving_stack(params, cfg, tokens, arena, mix_fn)
    x_last = jnp.take(x, last_idx, axis=0)                     # (B, d)
    return _lm_head_logits(params, cfg, x_last), new_arena


def forward_packed_verify_arena(params: Dict, cfg: ModelConfig, *,
                                tokens: jax.Array,
                                positions: jax.Array,
                                seg_slots: jax.Array,
                                slot_map: jax.Array,
                                cu_seqlens: jax.Array,
                                q_offsets: jax.Array,
                                kv_lengths: jax.Array,
                                arena: List[Any],
                                gather_idx: jax.Array,
                                ) -> Tuple[jax.Array, List[Any]]:
    """Speculative verification step (DESIGN.md §10): the UNCHANGED
    :func:`forward_packed_arena` dispatch, gathering L logits per
    segment instead of one.

    Verification is already the packed mixed step's shape — each decode
    session becomes a length-L re-prefill segment ``[t0, d_1..d_L-1]``
    scored against its arena history — so no new transformer or kernel
    code runs here: ``last_idx`` accepts any flat row-index vector, and
    ``gather_idx (B, L)`` simply names every row of every segment (pad
    segments point at row 0; their logits are discarded).  Row j of a
    segment scores position ``history + j + 1``, i.e. the draft d_{j+1}
    — acceptance walks that (B, L, V) block on host or in the fused
    sampling kernel.  Returns (logits (B, L, V), new_arena).
    """
    b, l = gather_idx.shape
    logits, new_arena = forward_packed_arena(
        params, cfg, tokens=tokens, positions=positions,
        seg_slots=seg_slots, slot_map=slot_map, cu_seqlens=cu_seqlens,
        q_offsets=q_offsets, kv_lengths=kv_lengths, arena=arena,
        last_idx=gather_idx.reshape(-1))
    return logits.reshape(b, l, -1), new_arena


def forward_packed_verify_paged(params: Dict, cfg: ModelConfig, *,
                                tokens: jax.Array,
                                positions: jax.Array,
                                token_pages: jax.Array,
                                token_offs: jax.Array,
                                page_table: jax.Array,
                                cu_seqlens: jax.Array,
                                q_offsets: jax.Array,
                                kv_lengths: jax.Array,
                                arena: List[Any],
                                gather_idx: jax.Array,
                                state_map: Optional[jax.Array] = None,
                                ) -> Tuple[jax.Array, List[Any]]:
    """Paged speculative verification: :func:`forward_packed_paged`
    gathering L logits per segment via ``gather_idx (B, L)`` (see
    :func:`forward_packed_verify_arena`).
    Returns (logits (B, L, V), new_pool)."""
    b, l = gather_idx.shape
    logits, new_arena = forward_packed_paged(
        params, cfg, tokens=tokens, positions=positions,
        token_pages=token_pages, token_offs=token_offs,
        page_table=page_table, cu_seqlens=cu_seqlens,
        q_offsets=q_offsets, kv_lengths=kv_lengths, arena=arena,
        last_idx=gather_idx.reshape(-1), state_map=state_map)
    return logits.reshape(b, l, -1), new_arena


def forward_decode_paged(params: Dict, cfg: ModelConfig, *,
                         tokens: jax.Array,
                         positions: jax.Array,
                         write_pages: jax.Array,
                         write_offs: jax.Array,
                         page_table: jax.Array,
                         kv_lengths: jax.Array,
                         arena: List[Any],
                         state_map: Optional[jax.Array] = None,
                         ) -> Tuple[jax.Array, List[Any]]:
    """One PAGED decode tick: :func:`forward_decode_arena` with the
    per-row slot generalized to a page table (DESIGN.md §8).

    tokens: (B,) last sampled token per row; positions: (B,) absolute
    position of the new token (rope + kv_lengths − 1);
    write_pages/write_offs: (B,) physical (page, offset) its KV lands in
    (pad rows park on the scratch page at offset page_size − 1);
    page_table: (B, P_max); kv_lengths: (B,) valid entries INCLUDING the
    new row.  Heterogeneous stacks route per layer (DESIGN.md §12):
    windowed positions walk the ring table, SSM positions step the
    per-session state page named by ``state_map (B,)`` in place (pads
    point at the scratch page).  Returns (logits, new_pool).
    """
    cap = arena_capability(cfg)
    assert cap.packed_ok, cfg.name

    def mix_fn(j, lp, h, cache_j):
        kind = cap.layers[j].kind
        if kind == "ssm":
            return mamba_mod.arena_decode_mamba_layer(
                lp, h, cfg=cfg, slot_map=state_map, cache=cache_j)
        mix, upd = paged_decode_layer(
            lp, h, cfg=cfg, positions=positions, write_pages=write_pages,
            write_offs=write_offs, page_table=page_table,
            kv_lengths=kv_lengths, kv=(cache_j["k"], cache_j["v"]),
            window=cap.layers[j].window)
        return mix, {"k": upd[0], "v": upd[1]}

    x, new_arena = _scan_serving_stack(params, cfg, tokens, arena, mix_fn)
    return _lm_head_logits(params, cfg, x), new_arena


# ------------------------------------------------------- arena decode


def forward_decode_arena(params: Dict, cfg: ModelConfig, *,
                         tokens: jax.Array,
                         slot_map: jax.Array,
                         write_pos: jax.Array,
                         kv_lengths: jax.Array,
                         arena: List[Any],
                         ) -> Tuple[jax.Array, List[Any]]:
    """One arena-resident decode tick: B sessions advance one token each
    against the KV arena IN PLACE.

    tokens: (B,) int32 — last sampled token per row; slot_map: (B,)
    arena slot each row owns; write_pos: (B,) absolute position of the
    new token (the row's cached history; pad rows park at S_max − 1);
    kv_lengths: (B,) valid cache entries INCLUDING the new row
    (history + 1; pad rows 1).

    arena: the KVArena pytree itself — per pattern position
    {"k"/"v": (G, N_slots, S_max, Hkv, D)}.  Each layer scatter-writes
    the single new KV row at (slot, write_pos) and the arena-resident
    kernel streams only valid cache prefixes, so per-token HBM traffic
    is O(cached_len) — not the O(S_max) whole-slot gather + scatter of
    the dense path.  Under buffer donation the arena updates in place;
    the caller swaps the returned pytree back into the KVArena.

    Returns (logits (B, V), new_arena).  B is a decode-ladder bucket,
    so the compiled-shape space is O(|ladder|), not O(#session-counts).

    Heterogeneous stacks ride the same scan (DESIGN.md §7): windowed
    positions write the new row modularly into the rolling slot and
    stream O(min(cached, window)); SSM positions step their per-slot
    recurrent state in place (pad rows point at the scratch slot).
    """
    cap = arena_capability(cfg)
    assert cap.packed_ok, cfg.name

    def mix_fn(j, lp, h, cache_j):
        kind = cap.layers[j].kind
        if kind == "ssm":
            return mamba_mod.arena_decode_mamba_layer(
                lp, h, cfg=cfg, slot_map=slot_map, cache=cache_j)
        mix, upd = arena_decode_layer(
            lp, h, cfg=cfg, slot_map=slot_map, positions=write_pos,
            kv_lengths=kv_lengths, kv=(cache_j["k"], cache_j["v"]),
            window=cap.layers[j].window)
        return mix, {"k": upd[0], "v": upd[1]}

    x, new_arena = _scan_serving_stack(params, cfg, tokens, arena, mix_fn)
    return _lm_head_logits(params, cfg, x), new_arena
