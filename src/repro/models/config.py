"""Unified model configuration covering every assigned architecture family.

One dataclass describes dense / MoE / VLM-backbone / SSM / audio-encoder /
hybrid models.  ``family`` selects the block layout; per-layer kind is
resolved by :meth:`ModelConfig.layer_kind`.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                      # dense | moe | vlm | ssm | audio | hybrid
    num_layers: int
    d_model: int
    num_heads: int                   # query heads (0 for attn-free)
    num_kv_heads: int
    d_ff: int
    vocab_size: int

    head_dim: Optional[int] = None   # default d_model // num_heads
    # --- attention options -------------------------------------------------
    qk_norm: bool = False            # per-head RMSNorm on q,k (qwen3)
    qkv_bias: bool = False           # bias on qkv projections (qwen2.5)
    sliding_window: Optional[int] = None   # SWA width (mixtral)
    causal: bool = True              # False for encoder-only (hubert)
    rope_theta: float = 1_000_000.0
    # --- MoE ----------------------------------------------------------------
    num_experts: int = 0
    num_experts_per_tok: int = 0
    moe_d_ff: Optional[int] = None   # per-expert FFN width (defaults d_ff)
    # --- SSM (Mamba2 / SSD) -------------------------------------------------
    ssm_state_size: int = 0
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    ssm_conv_width: int = 4
    ssm_n_groups: int = 1
    ssm_chunk: int = 128
    # --- hybrid layout (jamba) ----------------------------------------------
    attn_layer_period: int = 1       # attention every k-th layer (jamba: 8)
    attn_layer_offset: int = 0
    moe_layer_period: int = 1        # MoE every k-th layer (jamba: 2)
    moe_layer_offset: int = 1
    # --- modality frontend (stub per assignment) ----------------------------
    frontend: Optional[str] = None   # "vision" | "audio" | None
    frontend_tokens: int = 0         # patches/frames contributed by frontend
    # --- numerics -----------------------------------------------------------
    dtype: str = "bfloat16"
    norm_eps: float = 1e-6
    max_seq_len: int = 131_072
    tie_embeddings: bool = False
    vocab_pad_to: int = 256      # pad embedding/head tables so the vocab
    # dim divides the model axis (else logits replicate: e.g. mamba2's
    # 50280 on a 16-way axis cost 3 GiB/device of fp32 logits)

    # ------------------------------------------------------------------ API
    @property
    def padded_vocab(self) -> int:
        p = self.vocab_pad_to
        return (self.vocab_size + p - 1) // p * p

    @property
    def hdim(self) -> int:
        if self.head_dim is not None:
            return self.head_dim
        return self.d_model // max(self.num_heads, 1)

    @property
    def ssm_d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_nheads(self) -> int:
        return self.ssm_d_inner // self.ssm_head_dim

    @property
    def is_encoder_only(self) -> bool:
        return not self.causal

    @property
    def is_attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def supports_long_context(self) -> bool:
        """Sub-quadratic decode: SSM, hybrid, or sliding-window attention."""
        return self.family in ("ssm", "hybrid") or self.sliding_window is not None

    @property
    def activated_params_ratio(self) -> float:
        """Fraction of FFN params active per token (MoE top-k / E)."""
        if self.num_experts > 0:
            return self.num_experts_per_tok / self.num_experts
        return 1.0

    def layer_kind(self, i: int) -> str:
        """'attn' or 'ssm' for mixer of layer i."""
        if self.family == "ssm":
            return "ssm"
        if self.family == "hybrid":
            if i % self.attn_layer_period == self.attn_layer_offset:
                return "attn"
            return "ssm"
        return "attn"

    def layer_is_moe(self, i: int) -> bool:
        if self.num_experts == 0:
            return False
        if self.family == "hybrid":
            return i % self.moe_layer_period == self.moe_layer_offset
        return True

    @property
    def np_dtype(self):
        return jnp.dtype(self.dtype)

    # --------------------------------------------------------- param counts
    def param_count(self) -> int:
        """Analytic parameter count (embeddings + blocks + head)."""
        d, v = self.d_model, self.vocab_size
        total = v * d                                   # embed
        if not self.tie_embeddings and not self.is_encoder_only:
            total += v * d                              # lm head
        if self.is_encoder_only:
            total += d * v                              # ctc-style head
        for i in range(self.num_layers):
            total += 2 * d                              # pre-norms
            if self.layer_kind(i) == "attn":
                hd = self.hdim
                qd = self.num_heads * hd
                kvd = self.num_kv_heads * hd
                total += d * qd + 2 * d * kvd + qd * d  # qkvo
                if self.qkv_bias:
                    total += qd + 2 * kvd
                if self.qk_norm:
                    total += 2 * hd
            else:
                di, ds, nh = self.ssm_d_inner, self.ssm_state_size, self.ssm_nheads
                g = self.ssm_n_groups
                proj_in = 2 * di + 2 * g * ds + nh
                total += d * proj_in + proj_in          # in_proj (+dt bias folded)
                total += self.ssm_conv_width * (di + 2 * g * ds)
                total += 2 * nh + di                    # A_log, D, gated-norm
                total += di * d                         # out_proj
            if self.layer_is_moe(i):
                e, ff = self.num_experts, (self.moe_d_ff or self.d_ff)
                total += d * e                          # router
                total += e * (3 * d * ff)               # gate/up/down per expert
            elif self.layer_kind(i) == "attn" or self.family in ("ssm",):
                # ssm-family mamba2 blocks have no separate FFN; dense blocks do
                if self.family != "ssm":
                    total += 3 * d * self.d_ff
        total += d                                      # final norm
        return total

    def active_param_count(self) -> int:
        """Params touched per token (MoE: only top-k experts)."""
        if self.num_experts == 0:
            return self.param_count()
        full = self.param_count()
        e, k, ff = self.num_experts, self.num_experts_per_tok, (self.moe_d_ff or self.d_ff)
        n_moe_layers = sum(1 for i in range(self.num_layers) if self.layer_is_moe(i))
        inactive = n_moe_layers * (e - k) * 3 * self.d_model * ff
        return full - inactive

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    """One assigned input-shape cell."""
    name: str                 # train_4k | prefill_32k | decode_32k | long_500k
    seq_len: int
    global_batch: int
    kind: str                 # train | prefill | decode

    @property
    def tokens(self) -> int:
        return self.seq_len * self.global_batch


SHAPES: Tuple[ShapeSpec, ...] = (
    ShapeSpec("train_4k", 4_096, 256, "train"),
    ShapeSpec("prefill_32k", 32_768, 32, "prefill"),
    ShapeSpec("decode_32k", 32_768, 128, "decode"),
    ShapeSpec("long_500k", 524_288, 1, "decode"),
)


def shape_by_name(name: str) -> ShapeSpec:
    for s in SHAPES:
        if s.name == name:
            return s
    raise KeyError(name)


def cell_supported(cfg: ModelConfig, shape: ShapeSpec) -> Tuple[bool, str]:
    """Whether (arch, shape) is a runnable cell; reason when skipped."""
    if shape.kind == "decode" and cfg.is_encoder_only:
        return False, "encoder-only arch has no decode step"
    if shape.name == "long_500k" and not cfg.supports_long_context:
        return False, "full quadratic attention cannot serve 500k context"
    return True, ""
