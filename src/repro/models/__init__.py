from repro.models.config import ModelConfig, ShapeSpec, SHAPES, shape_by_name, cell_supported  # noqa: F401
