"""Mixture-of-Experts layer: top-k routing with sort-based dropless-ish
capacity dispatch (TPU-friendly static shapes, FLOPs ∝ active experts).

Two execution forms:
  * ``moe_dense_reference`` — weighs *all* experts per token; O(E) FLOPs.
    Used as the oracle in tests and for tiny smoke configs.
  * ``moe_layer`` — capacity-based dispatch: tokens are sorted by expert,
    packed into an (E, C, d) buffer, run through a grouped einsum, and
    combined.  FLOPs scale with top-k, not E.  Tokens overflowing the
    capacity C are dropped (their gate weight contributes nothing), as in
    Switch/GShard; tests use capacity_factor high enough for zero drops.

Serving note (DESIGN.md §7): both forms are token-independent, so the
arena-resident packed stream feeds them the flat (1, T, d) view
directly — a jamba-style hybrid step runs its MoE FFNs over the packed
stream with no per-segment unflattening (only the SSM mixers need the
dense bridge, and only for their sequential scan).  Routing therefore
sees the true token mix of the step, exactly like the dense path.
"""
from __future__ import annotations

import math
from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.distributed.sharding import constrain
from repro.models.layers import ParamBuilder


def init_moe(pb: ParamBuilder, d_model: int, d_ff: int, num_experts: int) -> None:
    # expert weights get their own FSDP logical axis ("expert_embed"):
    # the hillclimb can replicate them over data (killing per-layer
    # all-gathers) without touching dense-layer FSDP
    pb.dense("router", (d_model, num_experts), ("embed", None))
    pb.dense("wg", (num_experts, d_model, d_ff),
             ("experts", "expert_embed", "expert_mlp"))
    pb.dense("wi", (num_experts, d_model, d_ff),
             ("experts", "expert_embed", "expert_mlp"))
    pb.dense("wo", (num_experts, d_ff, d_model),
             ("experts", "expert_mlp", "expert_embed"))


def _routing(p: Dict, x2d: jax.Array, top_k: int):
    """Router logits -> (weights (T,k), experts (T,k), aux load-balance loss)."""
    logits = (x2d @ p["router"]).astype(jnp.float32)          # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    weights, experts = jax.lax.top_k(probs, top_k)            # (T, k)
    weights = weights / jnp.clip(weights.sum(-1, keepdims=True), 1e-9)
    # Switch-style aux loss: E * sum_e f_e * P_e
    e = logits.shape[-1]
    me = jnp.mean(probs, axis=0)                              # mean router prob
    onehot = jax.nn.one_hot(experts[:, 0], e)                 # top-1 assignment
    ce = jnp.mean(onehot, axis=0)                             # fraction dispatched
    aux = e * jnp.sum(me * ce)
    return weights, experts, aux


def moe_dense_reference(p: Dict, x: jax.Array, *, top_k: int) -> Tuple[jax.Array, jax.Array]:
    """Oracle: run every expert, combine with top-k gate weights."""
    b, l, d = x.shape
    x2d = x.reshape(-1, d)
    weights, experts, aux = _routing(p, x2d, top_k)
    h = jnp.einsum("td,edf->tef", x2d, p["wg"])
    g = jax.nn.silu(h) * jnp.einsum("td,edf->tef", x2d, p["wi"])
    y_all = jnp.einsum("tef,efd->ted", g, p["wo"])            # (T, E, d)
    e = p["router"].shape[-1]
    gates = jnp.zeros((x2d.shape[0], e), jnp.float32)
    gates = jax.vmap(lambda g_, e_, w_: g_.at[e_].add(w_))(gates, experts, weights)
    y = jnp.einsum("te,ted->td", gates, y_all.astype(jnp.float32))
    return y.reshape(b, l, d).astype(x.dtype), aux


def _data_shards(t: int) -> int:
    """Number of batch (data×pod) shards the token dim is split over —
    dispatch is kept LOCAL per shard so the sort/scatter never crosses
    devices (expert-parallel reality; also what XLA partitions cleanly)."""
    from repro.distributed.sharding import current_rules
    rules = current_rules()
    if rules is None or rules.mesh is None:
        return 1
    s = rules.axis_size(rules.rules.get("batch"))
    return s if s > 1 and t % s == 0 else 1


def moe_layer(p: Dict, x: jax.Array, *, top_k: int,
              capacity_factor: float = 1.25) -> Tuple[jax.Array, jax.Array]:
    """Capacity-dispatch MoE with shard-local routing.  x: (B, L, d).

    Tokens are viewed as (S, T/S, d) with S = batch-shard count; each
    shard sorts and packs its own tokens into an (E, C_local, d) buffer
    (vmap'd scatter → scatter with a sharded batch dim — no cross-shard
    rematerialization).  Expert einsums carry the shard dim; expert
    weights shard over 'experts' (E % axis == 0) or 'expert_mlp'.
    """
    b, l, d = x.shape
    e = p["router"].shape[-1]
    x2d = x.reshape(-1, d)
    t = x2d.shape[0]
    weights, experts, aux = _routing(p, x2d, top_k)

    s = _data_shards(t)
    tl = t // s                                                # tokens/shard
    cap = max(1, int(math.ceil(tl * top_k / e * capacity_factor)))

    x3 = constrain(x2d.reshape(s, tl, d), "batch", None, "embed_act")
    w3 = weights.reshape(s, tl, top_k)
    e3 = experts.reshape(s, tl, top_k)

    def dispatch_local(xs, ws, es):
        """One shard: (tl, d), (tl, k), (tl, k) → packed buffer + combine
        metadata."""
        flat_expert = es.reshape(-1)                           # (tl*k,)
        flat_weight = ws.reshape(-1)
        flat_token = jnp.repeat(jnp.arange(tl), top_k)
        order = jnp.argsort(flat_expert, stable=True)
        sorted_expert = flat_expert[order]
        sorted_token = flat_token[order]
        sorted_weight = flat_weight[order]
        group_start = jnp.searchsorted(sorted_expert, jnp.arange(e),
                                       side="left")
        ranks = jnp.arange(tl * top_k) - group_start[sorted_expert]
        keep = ranks < cap
        dest = jnp.where(keep, sorted_expert * cap + ranks, e * cap)
        buf = jnp.zeros((e * cap + 1, d), xs.dtype)
        buf = buf.at[dest].set(xs[sorted_token])
        return buf[: e * cap], dest, sorted_token, sorted_weight * keep

    buf, dest, s_tok, s_w = jax.vmap(dispatch_local)(x3, w3, e3)
    dispatched = buf.reshape(s, e, cap, d)
    dispatched = constrain(dispatched, "batch", "experts", None, "embed_act")

    h = jnp.einsum("secd,edf->secf", dispatched, p["wg"])
    g = jax.nn.silu(h) * jnp.einsum("secd,edf->secf", dispatched, p["wi"])
    g = constrain(g, "batch", "experts", None, "expert_mlp")
    y_exp = jnp.einsum("secf,efd->secd", g, p["wo"])           # (S,E,C,d)
    y_exp = constrain(y_exp, "batch", "experts", None, "embed_act")

    def combine_local(y_e, dest, s_tok, s_w):
        # combine in model dtype: top-k ≤ 8 additions per token — bf16
        # accumulation is fine and halves the (T·k, d) contrib transient
        y_flat = jnp.concatenate(
            [y_e.reshape(e * cap, d), jnp.zeros((1, d), y_e.dtype)], axis=0)
        contrib = y_flat[dest] * s_w[:, None].astype(y_flat.dtype)
        return jnp.zeros((tl, d), y_e.dtype).at[s_tok].add(contrib)

    y = jax.vmap(combine_local)(y_exp, dest, s_tok, s_w)
    y = constrain(y, "batch", None, "embed_act")
    return y.reshape(b, l, d).astype(x.dtype), aux
