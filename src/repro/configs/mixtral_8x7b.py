"""mixtral-8x7b [moe] — 8 experts top-2, SWA(4096).  [arXiv:2401.04088; hf]

SWA makes decode sub-quadratic (rolling-window KV), so the long_500k cell
is runnable for this arch (DESIGN.md §5).
"""
from repro.models.config import ModelConfig

FULL = ModelConfig(
    name="mixtral-8x7b", family="moe",
    num_layers=32, d_model=4096, num_heads=32, num_kv_heads=8,
    d_ff=14_336, vocab_size=32_000, head_dim=128,
    num_experts=8, num_experts_per_tok=2,
    sliding_window=4096, rope_theta=1_000_000.0,
)


def smoke() -> ModelConfig:
    return FULL.replace(num_layers=2, d_model=64, num_heads=4, num_kv_heads=2,
                        d_ff=128, vocab_size=256, head_dim=16,
                        num_experts=4, num_experts_per_tok=2,
                        sliding_window=32, dtype="float32")
