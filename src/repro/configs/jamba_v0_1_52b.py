"""jamba-v0.1-52b [hybrid] — Mamba+attention 1:7 interleave, MoE 16e top-2.
[arXiv:2403.19887; hf]

Layer pattern period 8: one attention layer (offset 4) per 7 mamba
layers; MoE FFN on every other layer.  Mamba layers use our SSD (Mamba2)
block — see DESIGN.md §Hardware-adaptation for the substitution note.
long_500k decode: only the 4 attention layers hold a 500k KV cache
(seq-sharded); mamba layers are O(1) state.
"""
from repro.models.config import ModelConfig

FULL = ModelConfig(
    name="jamba-v0.1-52b", family="hybrid",
    num_layers=32, d_model=4096, num_heads=32, num_kv_heads=8,
    d_ff=14_336, vocab_size=65_536, head_dim=128,
    num_experts=16, num_experts_per_tok=2,
    attn_layer_period=8, attn_layer_offset=4,
    moe_layer_period=2, moe_layer_offset=1,
    ssm_state_size=128, ssm_head_dim=64, ssm_expand=2,
    ssm_conv_width=4, ssm_n_groups=8, ssm_chunk=128,
    rope_theta=10_000.0,
)


def smoke() -> ModelConfig:
    return FULL.replace(num_layers=4, d_model=64, num_heads=4, num_kv_heads=2,
                        d_ff=128, vocab_size=256, head_dim=16,
                        num_experts=4, num_experts_per_tok=2,
                        attn_layer_period=4, attn_layer_offset=2,
                        moe_layer_period=2, moe_layer_offset=1,
                        ssm_state_size=16, ssm_head_dim=8, ssm_n_groups=2,
                        ssm_chunk=8, dtype="float32")
