"""hubert-xlarge [audio] — encoder-only (w2v2 arch), frame-level head.
[arXiv:2106.07447; unverified]

Frontend (CNN feature extractor) is a stub per the assignment:
``input_specs()`` supplies precomputed frame embeddings.  Encoder-only ⇒
no decode shapes (DESIGN.md §5).
"""
from repro.models.config import ModelConfig

FULL = ModelConfig(
    name="hubert-xlarge", family="audio",
    num_layers=48, d_model=1280, num_heads=16, num_kv_heads=16,
    d_ff=5120, vocab_size=504, head_dim=80,
    causal=False, frontend="audio",
)


def smoke() -> ModelConfig:
    return FULL.replace(num_layers=2, d_model=64, num_heads=4, num_kv_heads=4,
                        d_ff=128, vocab_size=64, head_dim=16, dtype="float32")
