"""phi-3-vision-4.2b [vlm] — phi3-mini backbone + CLIP frontend (STUB).
[hf:microsoft/Phi-3-vision-128k-instruct; hf]

Per the assignment, the modality frontend is a stub: ``input_specs()``
provides precomputed patch embeddings of width d_model.
"""
from repro.models.config import ModelConfig

FULL = ModelConfig(
    name="phi-3-vision-4.2b", family="vlm",
    num_layers=32, d_model=3072, num_heads=32, num_kv_heads=32,
    d_ff=8192, vocab_size=32_064, head_dim=96,
    frontend="vision", frontend_tokens=1024,
    rope_theta=10_000.0,
)


def smoke() -> ModelConfig:
    return FULL.replace(num_layers=2, d_model=64, num_heads=4, num_kv_heads=4,
                        d_ff=128, vocab_size=256, head_dim=16,
                        frontend_tokens=8, dtype="float32")
