"""qwen2.5-14b [dense] — GQA, QKV bias.  [hf:Qwen/Qwen2.5-0.5B; hf]"""
from repro.models.config import ModelConfig

FULL = ModelConfig(
    name="qwen2.5-14b", family="dense",
    num_layers=48, d_model=5120, num_heads=40, num_kv_heads=8,
    d_ff=13_824, vocab_size=152_064, head_dim=128,
    qkv_bias=True, rope_theta=1_000_000.0,
)


def smoke() -> ModelConfig:
    return FULL.replace(num_layers=2, d_model=64, num_heads=4, num_kv_heads=2,
                        d_ff=128, vocab_size=256, head_dim=16, dtype="float32")
