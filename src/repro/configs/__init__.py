"""Architecture registry: one module per assigned architecture.

Each module defines ``FULL`` (the exact published config) and ``smoke()``
(a reduced same-family config for CPU tests).  Select with
``--arch <id>`` in launchers.
"""
from __future__ import annotations

import importlib
from typing import Dict, List

from repro.models.config import ModelConfig

_MODULES = {
    "qwen3-4b": "qwen3_4b",
    "stablelm-1.6b": "stablelm_1_6b",
    "qwen2.5-14b": "qwen2_5_14b",
    "minitron-8b": "minitron_8b",
    "mixtral-8x7b": "mixtral_8x7b",
    "qwen3-moe-30b-a3b": "qwen3_moe_30b_a3b",
    "phi-3-vision-4.2b": "phi3_vision_4_2b",
    "mamba2-2.7b": "mamba2_2_7b",
    "hubert-xlarge": "hubert_xlarge",
    "jamba-v0.1-52b": "jamba_v0_1_52b",
    # the paper's own serving model (Qwen2.5-32B, §4)
    "qwen2.5-32b": "qwen2_5_32b",
}

ASSIGNED: List[str] = [k for k in _MODULES if k != "qwen2.5-32b"]


def get_config(name: str) -> ModelConfig:
    mod = importlib.import_module(f"repro.configs.{_MODULES[name]}")
    return mod.FULL


def get_smoke(name: str) -> ModelConfig:
    mod = importlib.import_module(f"repro.configs.{_MODULES[name]}")
    return mod.smoke()


def all_configs() -> Dict[str, ModelConfig]:
    return {k: get_config(k) for k in _MODULES}
