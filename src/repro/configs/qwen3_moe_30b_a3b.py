"""qwen3-moe-30b-a3b [moe] — 128 experts top-8, qk_norm.  [hf:Qwen/Qwen3-30B-A3B; hf]

d_ff=768 is the per-expert (moe) FFN width; every layer is MoE.
"""
from repro.models.config import ModelConfig

FULL = ModelConfig(
    name="qwen3-moe-30b-a3b", family="moe",
    num_layers=48, d_model=2048, num_heads=32, num_kv_heads=4,
    d_ff=768, vocab_size=151_936, head_dim=128,
    num_experts=128, num_experts_per_tok=8, moe_d_ff=768,
    qk_norm=True, rope_theta=1_000_000.0,
)


def smoke() -> ModelConfig:
    return FULL.replace(num_layers=2, d_model=64, num_heads=4, num_kv_heads=2,
                        d_ff=32, moe_d_ff=32, vocab_size=256, head_dim=16,
                        num_experts=8, num_experts_per_tok=2, dtype="float32")
