"""mamba2-2.7b [ssm] — SSD (state-space duality), attention-free.
[arXiv:2405.21060; unverified]

d_inner = 2*d_model = 5120, 80 ssd heads of dim 64, state 128.
long_500k decode is O(1)-state (DESIGN.md §5).
"""
from repro.models.config import ModelConfig

FULL = ModelConfig(
    name="mamba2-2.7b", family="ssm",
    num_layers=64, d_model=2560, num_heads=0, num_kv_heads=0,
    d_ff=0, vocab_size=50_280,
    ssm_state_size=128, ssm_head_dim=64, ssm_expand=2,
    ssm_conv_width=4, ssm_n_groups=1, ssm_chunk=128,
)


def smoke() -> ModelConfig:
    return FULL.replace(num_layers=2, d_model=64, vocab_size=256,
                        ssm_state_size=16, ssm_head_dim=8, ssm_chunk=8,
                        dtype="float32")
