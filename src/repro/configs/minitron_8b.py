"""minitron-8b [dense] — pruned nemotron, 256k vocab.  [arXiv:2407.14679; hf]"""
from repro.models.config import ModelConfig

FULL = ModelConfig(
    name="minitron-8b", family="dense",
    num_layers=32, d_model=4096, num_heads=32, num_kv_heads=8,
    d_ff=16_384, vocab_size=256_000, head_dim=128,
    rope_theta=10_000.0,
)


def smoke() -> ModelConfig:
    return FULL.replace(num_layers=2, d_model=64, num_heads=4, num_kv_heads=2,
                        d_ff=128, vocab_size=512, head_dim=16, dtype="float32")
