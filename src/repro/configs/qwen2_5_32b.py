"""qwen2.5-32b [dense] — the paper's own serving model (§4, Fig.1/6).
[hf:Qwen/Qwen2.5-32B; hf]"""
from repro.models.config import ModelConfig

FULL = ModelConfig(
    name="qwen2.5-32b", family="dense",
    num_layers=64, d_model=5120, num_heads=40, num_kv_heads=8,
    d_ff=27_648, vocab_size=152_064, head_dim=128,
    qkv_bias=True, rope_theta=1_000_000.0,
)


def smoke() -> ModelConfig:
    return FULL.replace(num_layers=2, d_model=64, num_heads=4, num_kv_heads=2,
                        d_ff=128, vocab_size=256, head_dim=16, dtype="float32")
