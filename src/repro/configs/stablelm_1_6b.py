"""stablelm-1.6b [dense] — MHA (kv == q heads).  [hf:stabilityai/stablelm-2-1_6b; unverified]"""
from repro.models.config import ModelConfig

FULL = ModelConfig(
    name="stablelm-1.6b", family="dense",
    num_layers=24, d_model=2048, num_heads=32, num_kv_heads=32,
    d_ff=5632, vocab_size=100_352, head_dim=64,
    rope_theta=10_000.0,
)


def smoke() -> ModelConfig:
    return FULL.replace(num_layers=2, d_model=64, num_heads=4, num_kv_heads=4,
                        d_ff=128, vocab_size=256, head_dim=16, dtype="float32")
