"""Draft proposers for speculative decoding (DESIGN.md §10).

A draft proposes up to ``k`` continuation tokens per decode session;
the target model verifies every session's ``[last_token, d_1..d_k]``
segment as ONE packed mixed dispatch through the unchanged §6 arena
kernels and commits the accepted prefix (engine.spec_step).  Drafts are
free to be wrong — a rejected tail costs one arena truncate — and free
to be short: fewer than ``k`` proposals just means fewer rows to
verify.

Protocol (duck-typed, see :class:`DraftProposer`):

* ``propose(session, last_token, k)`` → up to ``k`` token ids expected
  AFTER ``last_token``.  ``last_token`` is the pending input of the
  next tick (its KV is not cached yet — the decode convention).
* ``observe(session, tokens, prompt=False)`` — tokens whose KV the
  target engine just cached (the prompt at prefill time, then each
  step's consumed inputs: the previous pending token plus the accepted
  drafts).  The engine calls this from ``spec_step``; the serve loop
  feeds prompts.
* ``forget(session)`` — session closed / slot reused.

Three implementations:

* :class:`NGramDraft` — self-speculation: proposes the continuation
  that followed the most recent earlier occurrence of the current
  suffix n-gram.  Zero model cost, deterministic, great on repetitive
  streams (and the lossless property makes it free to be wrong).
* :class:`ScriptedDraft` — test/bench oracle: proposes a known token
  stream with seeded per-POSITION corruption at rate ``1 − accept``,
  so benches dial an exact acceptance rate α deterministically.
* :class:`SmallModelDraft` — a small target-architecture model run
  greedily through its OWN Engine (sharing all the executor/arena
  machinery), kept in sync via ``observe`` and rolled back with the
  same ``truncate`` primitive the big engine uses.
"""
from __future__ import annotations

from typing import Dict, List, Sequence

import numpy as np


class DraftProposer:
    """Interface base (and the null draft: proposes nothing)."""

    def propose(self, session: int, last_token: int, k: int) -> List[int]:
        return []

    def observe(self, session: int, tokens: Sequence[int],
                prompt: bool = False) -> None:
        pass

    def forget(self, session: int) -> None:
        pass


class NGramDraft(DraftProposer):
    """Suffix n-gram self-draft over the session's own token history.

    To propose after ``last_token``: find the most recent EARLIER
    occurrence of the longest matching suffix (length ≤ n, ≥ 1 token)
    of ``history + [last_token]`` and return the tokens that followed
    it.  Keeps its own per-session history — the slot arena stores KV,
    not token ids.
    """

    def __init__(self, n: int = 3, min_match: int = 1):
        assert n >= 1 and 1 <= min_match <= n
        self.n = n
        self.min_match = min_match
        self._hist: Dict[int, List[int]] = {}

    def observe(self, session: int, tokens: Sequence[int],
                prompt: bool = False) -> None:
        self._hist.setdefault(session, []).extend(int(t) for t in tokens)

    def forget(self, session: int) -> None:
        self._hist.pop(session, None)

    def propose(self, session: int, last_token: int, k: int) -> List[int]:
        h = self._hist.get(session, []) + [int(last_token)]
        for n in range(min(self.n, len(h) - 1), self.min_match - 1, -1):
            pat = h[-n:]
            for i in range(len(h) - n - 1, -1, -1):
                if h[i:i + n] == pat:
                    cont = h[i + n:i + n + k]
                    if cont:
                        return cont
                    break       # the only longer continuation is the suffix
        return []


class ScriptedDraft(DraftProposer):
    """Oracle draft for deterministic tests and benches.

    ``scripts[session]`` is the full expected generated stream INCLUDING
    the first (TTFT) token.  A per-session cursor counts stream tokens
    whose KV the engine has cached (observe of non-prompt tokens), so
    ``last_token == script[cursor]`` and proposals continue from
    ``cursor + 1``.  Each scripted POSITION is independently corrupted
    with probability ``1 − accept`` under a seed derived from
    (seed, session, position) — deterministic across re-proposals, so a
    run realizes acceptance rate α = ``accept`` exactly per position.
    """

    def __init__(self, scripts: Dict[int, Sequence[int]],
                 accept: float = 1.0, vocab: int = 32_000, seed: int = 0):
        self.scripts = {s: [int(t) for t in toks]
                        for s, toks in scripts.items()}
        self.accept = accept
        self.vocab = vocab
        self.seed = seed
        self._cursor: Dict[int, int] = {}

    def observe(self, session: int, tokens: Sequence[int],
                prompt: bool = False) -> None:
        if prompt:
            return              # the prompt is not part of the script
        self._cursor[session] = self._cursor.get(session, 0) + len(tokens)

    def forget(self, session: int) -> None:
        self._cursor.pop(session, None)

    def _corrupt(self, session: int, pos: int, tok: int) -> int:
        rng = np.random.default_rng((self.seed, session, pos))
        if rng.random() < self.accept:
            return tok
        return (tok + 1 + int(rng.integers(self.vocab - 1))) % self.vocab

    def propose(self, session: int, last_token: int, k: int) -> List[int]:
        script = self.scripts.get(session)
        if script is None:
            return []
        start = self._cursor.get(session, 0) + 1   # after the pending token
        out = []
        for j in range(start, min(start + k, len(script))):
            out.append(self._corrupt(session, j, script[j]))
        return out


class SmallModelDraft(DraftProposer):
    """A small model drafting through its own Engine.

    The draft engine mirrors each target session: prompts prefill,
    consumed inputs re-prefill as suffix extensions, and ``propose``
    decodes ``k`` tokens greedily — then immediately truncates its arena
    back, because only the accepted prefix (reported via ``observe``)
    may stay cached.  All the §6 packed/arena machinery is reused
    as-is; this is the "small-model draft sharing the executor
    machinery" of ISSUE 8.
    """

    def __init__(self, engine):
        self.engine = engine
        self._open: Dict[int, bool] = {}
        self._pending: Dict[int, List[int]] = {}   # observed, not yet cached

    def observe(self, session: int, tokens: Sequence[int],
                prompt: bool = False) -> None:
        self._open.setdefault(session, True)
        self._pending.setdefault(session, []).extend(int(t) for t in tokens)

    def forget(self, session: int) -> None:
        if self._open.pop(session, None):
            self.engine.close_session(session)
        self._pending.pop(session, None)

    def _sync(self, session: int) -> None:
        toks = self._pending.get(session)
        if toks:
            self.engine.prefill_packed([session], [np.asarray(toks)])
            self._pending[session] = []

    def propose(self, session: int, last_token: int, k: int) -> List[int]:
        self._sync(session)
        h = self.engine.history(session)
        if h + k + 1 > self.engine.ecfg.max_len - 2:
            return []
        out = self.engine.decode_batch([session], [int(last_token)], steps=k)
        # roll the draft's own arena back: only tokens the TARGET accepts
        # (reported via observe) may stay cached
        self.engine.arena.truncate(session, h)
        return [int(t) for t in out.get(session, [])]


__all__ = ["DraftProposer", "NGramDraft", "ScriptedDraft", "SmallModelDraft"]
