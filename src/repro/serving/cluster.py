"""Multi-engine spatial disaggregation: the ServeCluster (DESIGN.md §9).

Owns N independent ``Engine``/``ServeLoop`` pairs and a pluggable
:class:`~repro.core.routing.Router`.  Three mechanisms reproduce the
paper's fig7/fig8 multi-instance mode on real JAX engines:

* **Length-aware routing** — every fresh session is placed by the
  router over live :class:`EngineView` snapshots; later turns follow the
  session's home engine (its KV lives there).
* **Arena→arena KV handoff** — a session prefilled on a prefill-role
  engine migrates to a decode-role engine before generating:
  ``Engine.export_session`` → ``Engine.import_session`` moves slot rows
  or page lists as DEVICE arrays (``handoff_host_bytes == 0`` is the
  no-host-bounce proof), the loop-side decode bookkeeping moves with
  it, and the source slot frees for the next long prefill.
* **Deflection** — a short that spilled onto an idle prefill engine is
  bounced back to the router (``ServeLoop.withdraw`` + re-route with
  ``exclude={engine}``) if long work lands behind it before it
  dispatches — Load-Aware Prefill Deflection's admission control.

The cluster drives all loops round-robin through ``ServeLoop.tick``, so
one thread interleaves every engine — the same unified-tick semantics
as a single loop, summed over instances.  The JAX-free mirror is
``sim.simulator.ClusterSim`` with ``router_obj`` + ``decode_handoff``.
"""
from __future__ import annotations

import time
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.faults import FaultInjector
from repro.core.request import Request
from repro.core.routing import EngineView, LengthAwareRouter, RouteRequest, \
    Router
from repro.core.slo import SLOReport, SLOTracker
from repro.serving.loop import PendingRequest, ServeLoop
from repro.serving.sampling import SamplingParams
from repro.sim.costmodel import CostModel, H200_32B


class ServeCluster:
    """N serve loops behind one submit() + a routing/migration brain."""

    def __init__(self, loops: Sequence[ServeLoop], router: Router,
                 roles: Optional[Sequence[str]] = None,
                 migrate_decodes: Optional[bool] = None,
                 deflect_backlog_tokens: Optional[int] = None,
                 faults: Optional[FaultInjector] = None,
                 cost: Optional[CostModel] = None,
                 max_handoff_attempts: int = 3,
                 degrade_ticks: int = 8):
        assert loops, "a cluster needs at least one engine"
        self.loops: List[ServeLoop] = list(loops)
        self.router = router
        self.roles: List[str] = (list(roles) if roles is not None
                                 else ["general"] * len(self.loops))
        assert len(self.roles) == len(self.loops)
        pagedness = {lp.engine._paged for lp in self.loops}
        assert len(pagedness) == 1, \
            "mixed slot/paged clusters cannot hand sessions off"
        spatial = (any(r == "prefill" for r in self.roles)
                   and any(r != "prefill" for r in self.roles))
        # migrate by default exactly when the cluster HAS a spatial
        # split and its engines support handoff.  The ORIGINAL flag
        # value is kept: None (auto) applies the §11 cost/benefit gate,
        # True forces the old always-migrate behaviour, False disables.
        self._migrate_override = migrate_decodes
        self.migrate = (spatial and all(lp.engine.can_handoff
                                        for lp in self.loops)
                        if migrate_decodes is None else migrate_decodes)
        self.deflect_tokens = deflect_backlog_tokens
        self._home: Dict[int, int] = {}            # session → engine
        self._deflectable: Dict[int, Tuple[int, int]] = {}  # rid → (eng, sess)
        self.deflections = 0
        self.migrated_sessions = 0
        # ---- §11 fault tolerance -------------------------------------
        self.faults = faults
        self.cost = cost if cost is not None else H200_32B
        self.max_handoff_attempts = max_handoff_attempts
        self.degrade_ticks = degrade_ticks
        self.health: List[str] = ["healthy"] * len(self.loops)
        self._tick = 0                             # cluster tick index
        self._submit_seq = 0                       # cluster submit ordinal
        # submit-stall buffer: (release_tick, was_fresh, withdrawn req)
        self._stalled: List[Tuple[float, bool, PendingRequest]] = []
        # transient-handoff backoff: session → (attempts, retry_tick)
        self._handoff_backoff: Dict[int, Tuple[int, int]] = {}
        self._no_migrate: set = set()              # gave up: stay home
        self._degraded_until: Dict[int, int] = {}  # engine → heal tick
        self.crashes = 0
        self.recovered_sessions = 0
        self.rerouted_requests = 0
        self.handoff_retries = 0
        self.handoff_giveups = 0
        self.stalled_requests = 0
        for i, lp in enumerate(self.loops):
            lp.engine_id = i
            if faults is not None:
                lp.faults = faults

    # ------------------------------------------------------------- state
    def views(self) -> List[EngineView]:
        out = []
        for i, lp in enumerate(self.loops):
            eng = lp.engine
            free = (eng.arena.free_pages if eng._paged
                    else eng.arena.free_slots)
            health = self.health[i]
            if health != "dead" and \
                    self._degraded_until.get(i, 0) > self._tick:
                health = "degraded"
            out.append(EngineView(
                engine_id=i, role=self.roles[i],
                alive=health != "dead", health=health,
                queue_len=lp.policy.queue_len(),
                backlog_tokens=lp.policy.backlog_tokens(),
                active_decodes=len(lp.active_decodes),
                free_slots=free))
        return out

    def alive_engines(self) -> List[int]:
        return [i for i, h in enumerate(self.health) if h != "dead"]

    def engine_of(self, session: int) -> Optional[int]:
        return self._home.get(session)

    def generated(self, session: int) -> List[int]:
        home = self._home.get(session)
        if home is None:
            return []
        return self.loops[home].generated.get(session, [])

    # ------------------------------------------------------------ intake
    def submit(self, session: int, tokens: np.ndarray,
               decode_tokens: int = 0,
               deadline: Optional[float] = None,
               sampling: Optional[SamplingParams] = None) -> Request:
        """Route one turn.  A session's first turn is placed by the
        router; later turns pin to the home engine (that is where the
        cached KV lives — cross-engine reuse is exactly what
        migration/handoff is for, not re-routing)."""
        idx = self._submit_seq
        self._submit_seq += 1
        eid = self._home.get(session)
        fresh = eid is None
        if not fresh and self.health[eid] == "dead":
            # the home engine died and nothing of the session survived
            # to recover (else kill_engine re-homed it) — route fresh
            fresh = True
        meta = RouteRequest(new_tokens=len(tokens),
                            decode_tokens=decode_tokens, session=session)
        if fresh:
            eid = self.router.route(meta, self.views())
            self._home[session] = eid
        r = self.loops[eid].submit(session, tokens,
                                   decode_tokens=decode_tokens,
                                   deadline=deadline, sampling=sampling)
        if r.rejected:
            # §11 admission gate shed it — nothing landed on the engine
            if fresh:
                self._home.pop(session, None)
            return r
        # §11 injected submit stall: accepted, then withheld — pulled
        # back out of the loop and buffered until the release tick, when
        # it re-routes (original arrival preserved: the stall is charged
        # to the request's TTFT, not forgiven)
        stall = self.faults.submit_stall(idx) if self.faults is not None \
            else None
        if stall is not None:
            w = self.loops[eid].withdraw(r.rid)
            if w is not None:
                if fresh:
                    self._home.pop(session, None)
                self.stalled_requests += 1
                self._stalled.append((self._tick + stall, fresh, w))
                return r
        # a fresh SHORT parked on a prefill-role engine (spillover) is
        # a deflection candidate until it dispatches
        if (fresh and self.deflect_tokens is not None
                and self.roles[eid] == "prefill"
                and isinstance(self.router, LengthAwareRouter)
                and not self.router.is_long(meta)):
            self._deflectable[r.rid] = (eid, session)
        return r

    def close_session(self, session: int) -> None:
        home = self._home.pop(session, None)
        # purge deflection candidates for the closed session NOW — a
        # stale rid must not linger until a later sweep happens to
        # notice it is gone
        self._deflectable = {rid: (e, s)
                             for rid, (e, s) in self._deflectable.items()
                             if s != session}
        if home is not None and self.health[home] != "dead":
            self.loops[home].close_session(session)

    # --------------------------------------------------------- deflection
    def _maybe_deflect(self) -> None:
        """Bounce spilled shorts off prefill engines that turned busy.

        A deflected request leaves the bouncing engine exactly as if it
        had never been submitted there (ServeLoop.withdraw) and goes
        back through the router with that engine excluded; its original
        arrival timestamp is preserved so TTFT/SLO accounting charges
        the detour to the request, not to the clock."""
        if self.deflect_tokens is None or not self._deflectable:
            return
        for rid, (eid, _sess) in list(self._deflectable.items()):
            lp = self.loops[eid]
            pr = lp._tokens.get(rid)
            if pr is None or pr.req.dispatch_time is not None:
                self._deflectable.pop(rid, None)   # served or gone
                continue
            if lp.policy.backlog_tokens() - pr.req.new_tokens \
                    <= self.deflect_tokens:
                continue                           # engine still quiet
            w = lp.withdraw(rid)
            self._deflectable.pop(rid, None)
            if w is None:
                continue
            session = w.req.session
            self._home.pop(session, None)
            tokens = w.prompt if w.prompt is not None else w.tokens
            meta = RouteRequest(new_tokens=len(tokens),
                                decode_tokens=w.decode_tokens,
                                session=session)
            new_eid = self.router.route(meta, self.views(),
                                        exclude=frozenset({eid}))
            self._home[session] = new_eid
            r2 = self.loops[new_eid].submit(
                session, tokens, decode_tokens=w.decode_tokens,
                deadline=w.req.deadline, sampling=w.sampling)
            r2.arrival = w.req.arrival
            self.deflections += 1

    # ---------------------------------------------------------- migration
    def _migratable(self, lp: ServeLoop, session: int) -> bool:
        # only sessions that are PURELY decoding move: no queued turn
        # (its prefill belongs where it was routed) — and the engine
        # pair must support handoff at all
        return not any(p.req.session == session
                       for p in lp._tokens.values())

    def _maybe_migrate(self) -> None:
        """Move decode-phase sessions off prefill-role engines.

        In spatial mode a prefill engine exists to run long prefills
        back to back; a session that finished its prefill there would
        otherwise pin a slot and steal tick time for its decode steps.
        Export → import moves its KV device-to-device to the least
        decode-loaded non-prefill engine, the loop bookkeeping follows,
        and the source slot frees."""
        if not self.migrate:
            return
        dsts = [i for i, role in enumerate(self.roles)
                if role != "prefill" and self.health[i] != "dead"]
        if not dsts:
            return
        for src, lp in enumerate(self.loops):
            if self.roles[src] != "prefill" or self.health[src] == "dead":
                continue
            for session in list(lp.active_decodes):
                if session in self._no_migrate:
                    continue
                attempts, retry_at = self._handoff_backoff.get(
                    session, (0, 0))
                if retry_at > self._tick:
                    continue                 # still backing off
                if not self._migratable(lp, session):
                    continue
                if not self._worth_migrating(lp, session):
                    continue
                dst = min(dsts, key=lambda i: (
                    len(self.loops[i].active_decodes),
                    self.loops[i].policy.backlog_tokens(), i))
                if self.faults is not None and \
                        self.faults.handoff_fails(src, self._tick):
                    # §11 transient export/import failure: retry with
                    # exponential backoff; after max attempts keep the
                    # session home (decoding in place beats flapping)
                    self._on_handoff_failure(src, session, attempts)
                    continue
                self._handoff_backoff.pop(session, None)
                self._migrate_session(src, dst, session)

    def _worth_migrating(self, lp: ServeLoop, session: int) -> bool:
        """§11 cost/benefit gate (replaces the greedy always-migrate
        trigger): moving the session pays CostModel.handoff_time for its
        cached context; each decode token it would otherwise run on the
        prefill engine costs roughly one fused stream row (β + w_tok +
        decode_per_seq) of tick time stolen from long chunks.  Migrate
        only when the remaining budget's saving beats the copy —
        ``migrate_decodes=True`` restores the old unconditional move."""
        if self._migrate_override is True:
            return True
        remaining = lp.active_decodes.get(session, 0)
        gain = remaining * (self.cost.beta + self.cost.w_tok
                            + self.cost.decode_per_seq)
        return gain > self.cost.handoff_time(lp.engine.history(session))

    def _on_handoff_failure(self, src: int, session: int,
                            attempts: int) -> None:
        attempts += 1
        self.handoff_retries += 1
        self._degraded_until[src] = self._tick + self.degrade_ticks
        if attempts >= self.max_handoff_attempts:
            self._no_migrate.add(session)
            self._handoff_backoff.pop(session, None)
            self.handoff_giveups += 1
        else:
            self._handoff_backoff[session] = (
                attempts, self._tick + 2 ** attempts)

    def _migrate_session(self, src: int, dst: int, session: int) -> None:
        a, b = self.loops[src], self.loops[dst]
        payload = a.engine.export_session(session)
        b.engine.import_session(session, payload)
        # decode bookkeeping moves with the KV
        b.active_decodes[session] = a.active_decodes.pop(session)
        for d_src, d_dst in ((a.last_token, b.last_token),
                             (a.generated, b.generated),
                             (a.first_tokens, b.first_tokens),
                             (a._last_emit, b._last_emit),
                             (a._cache_tokens, b._cache_tokens),
                             (a._cache_pending, b._cache_pending)):
            if session in d_src:
                d_dst[session] = d_src.pop(session)
        if session in a._session_pending:
            b._session_pending[session] = a._session_pending.pop(session)
        a.engine.close_session(session)
        self._home[session] = dst
        self.migrated_sessions += 1

    # ---------------------------------------------------------- failover
    def kill_engine(self, eid: int) -> None:
        """§11 engine death.  Evacuate everything the dead engine held,
        then refuse it forever: queued requests withdraw and re-route
        through the router (dead engine excluded via its view), and
        in-flight sessions are re-prefill-reconstructed on survivors
        from the loop's recovery transcript — greedy sessions continue
        bit-identically to a fault-free run.  With no survivors the
        queued requests are recorded as abandoned, never silently lost."""
        if self.health[eid] == "dead":
            return
        lp = self.loops[eid]
        self.health[eid] = "dead"
        self.crashes += 1
        self._deflectable = {rid: (e, s)
                             for rid, (e, s) in self._deflectable.items()
                             if e != eid}
        survivors = [i for i in self.alive_engines() if i != eid]
        # 1) pull every queued (or mid-chunk) request back out
        queued: List[PendingRequest] = []
        for rid, pr in list(lp._tokens.items()):
            w = lp.withdraw(rid)
            if w is None:
                # already dispatching (a long mid-chunk): its partial KV
                # died with the arena — restart the turn from scratch
                lp.policy.purge(lambda q, _rid=rid: q.rid == _rid)
                lp._tokens.pop(rid, None)
                lp._outstanding -= 1
                pr.req.dispatch_time = None
                w = pr
            queued.append(w)
        # 2) recover sessions with committed cache on a survivor
        for session in [s for s, h in list(self._home.items()) if h == eid]:
            if not survivors or not lp._cache_tokens.get(session):
                self._home.pop(session, None)
                continue
            self._recover_session(eid, session)
        # 3) re-route the evacuated requests (recovered sessions pin to
        # their new home — their cache lives there now)
        for w in queued:
            if not survivors:
                lp.tracker.note_abandoned(w.req)
                continue
            session = w.req.session
            home = self._home.get(session)
            if home is None or self.health[home] == "dead":
                tokens = w.prompt if w.prompt is not None else w.tokens
                meta = RouteRequest(new_tokens=len(tokens),
                                    decode_tokens=w.decode_tokens,
                                    session=session)
                home = self.router.route(meta, self.views())
                self._home[session] = home
            self._resubmit(home, w)
            self.rerouted_requests += 1
            self.loops[home].tracker.note_retried()
        # 4) scrub the dead loop so has_work goes quiet, and make any
        # future dispatch attempt on the dead engine an error
        lp.policy.drain()
        lp._tokens.clear()
        lp._outstanding = 0
        lp.active_decodes.clear()
        lp.engine.mark_dead()

    def _resubmit(self, eid: int, w: PendingRequest) -> Request:
        tokens = w.prompt if w.prompt is not None else w.tokens
        r2 = self.loops[eid].submit(
            w.req.session, tokens, decode_tokens=w.decode_tokens,
            deadline=w.req.deadline, sampling=w.sampling)
        r2.arrival = w.req.arrival     # the detour stays on its TTFT bill
        return r2

    def _recover_session(self, src: int, session: int) -> None:
        """Re-prefill reconstruction (§11): replay the dead engine's
        exact cache token sequence on a router-chosen survivor and
        resume decoding from the recorded pending token."""
        lp = self.loops[src]
        cache = list(lp._cache_tokens.get(session, []))
        budget = lp.active_decodes.get(session, 0)
        meta = RouteRequest(new_tokens=len(cache), decode_tokens=budget,
                            session=session)
        dst = self.router.route(meta, self.views())
        self.loops[dst].restore_session(
            session, cache,
            pending=lp._cache_pending.get(session),
            generated=list(lp.generated.get(session, [])),
            budget=budget,
            sampling=lp.engine.sampling.get(session),
            first_token=lp.first_tokens.get(session))
        self._home[session] = dst
        self.recovered_sessions += 1

    def _release_stalled(self) -> None:
        if not self._stalled:
            return
        due = [s for s in self._stalled if s[0] <= self._tick]
        if not due:
            return
        self._stalled = [s for s in self._stalled if s[0] > self._tick]
        for _, fresh, w in due:
            session = w.req.session
            eid = self._home.get(session)
            if eid is None or self.health[eid] == "dead":
                tokens = w.prompt if w.prompt is not None else w.tokens
                meta = RouteRequest(new_tokens=len(tokens),
                                    decode_tokens=w.decode_tokens,
                                    session=session)
                eid = self.router.route(meta, self.views())
                self._home[session] = eid
            self._resubmit(eid, w)
            self.loops[eid].tracker.note_retried()

    # --------------------------------------------------------------- run
    @property
    def has_work(self) -> bool:
        return bool(self._stalled) or any(
            lp.has_work for i, lp in enumerate(self.loops)
            if self.health[i] != "dead")

    def run_until_idle(self, max_wall: float = 60.0) -> None:
        """Interleave every live loop's unified tick until the whole
        cluster drains.  Per tick: fire matured fault-plan events
        (engine crashes), release stalled submits, deflect, tick, then
        migrate (a prefill that just finished starts decoding elsewhere
        next tick).  If ``max_wall`` expires first every still-queued
        request — including buffered stalls — is recorded as abandoned
        rather than silently dropped."""
        clock = self.loops[0].clock
        start = clock()
        while self.has_work and clock() - start < max_wall:
            self._tick += 1
            if self.faults is not None:
                for eid in self.faults.crashes_due(self._tick):
                    if 0 <= eid < len(self.loops) and \
                            len(self.alive_engines()) > 1:
                        self.kill_engine(eid)
            self._release_stalled()
            self._maybe_deflect()
            did_any = False
            for i, lp in enumerate(self.loops):
                if self.health[i] == "dead" or not lp.has_work:
                    continue
                did, _ = lp.tick()
                did_any = did_any or did
            self._maybe_migrate()
            if not did_any:
                time.sleep(0.0005)
        if self.has_work:      # max_wall expired with work still queued
            for i, lp in enumerate(self.loops):
                if self.health[i] != "dead" and lp._outstanding > 0:
                    lp.abandon_pending()
            for _, _, w in self._stalled:
                self.loops[0].tracker.note_abandoned(w.req)
            self._stalled = []

    # ------------------------------------------------------------ reports
    def report(self, horizon: Optional[float] = None) -> SLOReport:
        return SLOTracker.merged(
            [lp.tracker for lp in self.loops]).report(horizon)

    def stats(self) -> Dict:
        per_engine = [lp.engine.stats() for lp in self.loops]
        merged = SLOTracker.merged([lp.tracker for lp in self.loops])
        return {
            "engines": len(self.loops),
            "roles": list(self.roles),
            "router": self.router.name,
            "health": list(self.health),
            "deflections": self.deflections,
            "migrated_sessions": self.migrated_sessions,
            # §11 fault tolerance + admission control
            "crashes": self.crashes,
            "recovered_sessions": self.recovered_sessions,
            "rerouted_requests": self.rerouted_requests,
            "handoff_retries": self.handoff_retries,
            "handoff_giveups": self.handoff_giveups,
            "stalled_requests": self.stalled_requests,
            "dispatch_faults": sum(lp.dispatch_faults for lp in self.loops),
            "rejected": merged.rejected,
            "retried": merged.retried,
            "abandoned": merged.abandoned,
            "handoff_sessions": sum(s["handoff_sessions"]
                                    for s in per_engine),
            "handoff_tokens": sum(s["handoff_tokens"] for s in per_engine),
            "handoff_host_bytes": sum(s["handoff_host_bytes"]
                                      for s in per_engine),
            "tokens_drafted": sum(s["tokens_drafted"] for s in per_engine),
            "tokens_accepted": sum(s["tokens_accepted"]
                                   for s in per_engine),
            "spec_dispatches": sum(s["spec_dispatches"]
                                   for s in per_engine),
            "per_engine": per_engine,
        }
