"""Multi-engine spatial disaggregation: the ServeCluster (DESIGN.md §9).

Owns N independent ``Engine``/``ServeLoop`` pairs and a pluggable
:class:`~repro.core.routing.Router`.  Three mechanisms reproduce the
paper's fig7/fig8 multi-instance mode on real JAX engines:

* **Length-aware routing** — every fresh session is placed by the
  router over live :class:`EngineView` snapshots; later turns follow the
  session's home engine (its KV lives there).
* **Arena→arena KV handoff** — a session prefilled on a prefill-role
  engine migrates to a decode-role engine before generating:
  ``Engine.export_session`` → ``Engine.import_session`` moves slot rows
  or page lists as DEVICE arrays (``handoff_host_bytes == 0`` is the
  no-host-bounce proof), the loop-side decode bookkeeping moves with
  it, and the source slot frees for the next long prefill.
* **Deflection** — a short that spilled onto an idle prefill engine is
  bounced back to the router (``ServeLoop.withdraw`` + re-route with
  ``exclude={engine}``) if long work lands behind it before it
  dispatches — Load-Aware Prefill Deflection's admission control.

The cluster drives all loops round-robin through ``ServeLoop.tick``, so
one thread interleaves every engine — the same unified-tick semantics
as a single loop, summed over instances.  The JAX-free mirror is
``sim.simulator.ClusterSim`` with ``router_obj`` + ``decode_handoff``.
"""
from __future__ import annotations

import time
from typing import Dict, List, Optional, Sequence

import numpy as np

from repro.core.request import Request
from repro.core.routing import EngineView, LengthAwareRouter, RouteRequest, \
    Router
from repro.core.slo import SLOReport, SLOTracker
from repro.serving.loop import ServeLoop
from repro.serving.sampling import SamplingParams


class ServeCluster:
    """N serve loops behind one submit() + a routing/migration brain."""

    def __init__(self, loops: Sequence[ServeLoop], router: Router,
                 roles: Optional[Sequence[str]] = None,
                 migrate_decodes: Optional[bool] = None,
                 deflect_backlog_tokens: Optional[int] = None):
        assert loops, "a cluster needs at least one engine"
        self.loops: List[ServeLoop] = list(loops)
        self.router = router
        self.roles: List[str] = (list(roles) if roles is not None
                                 else ["general"] * len(self.loops))
        assert len(self.roles) == len(self.loops)
        pagedness = {lp.engine._paged for lp in self.loops}
        assert len(pagedness) == 1, \
            "mixed slot/paged clusters cannot hand sessions off"
        spatial = (any(r == "prefill" for r in self.roles)
                   and any(r != "prefill" for r in self.roles))
        # migrate by default exactly when the cluster HAS a spatial
        # split and its engines support handoff
        self.migrate = (spatial and all(lp.engine.can_handoff
                                        for lp in self.loops)
                        if migrate_decodes is None else migrate_decodes)
        self.deflect_tokens = deflect_backlog_tokens
        self._home: Dict[int, int] = {}            # session → engine
        self._deflectable: Dict[int, int] = {}     # rid → engine
        self.deflections = 0
        self.migrated_sessions = 0

    # ------------------------------------------------------------- state
    def views(self) -> List[EngineView]:
        out = []
        for i, lp in enumerate(self.loops):
            eng = lp.engine
            free = (eng.arena.free_pages if eng._paged
                    else eng.arena.free_slots)
            out.append(EngineView(
                engine_id=i, role=self.roles[i],
                queue_len=lp.policy.queue_len(),
                backlog_tokens=lp.policy.backlog_tokens(),
                active_decodes=len(lp.active_decodes),
                free_slots=free))
        return out

    def engine_of(self, session: int) -> Optional[int]:
        return self._home.get(session)

    def generated(self, session: int) -> List[int]:
        home = self._home.get(session)
        if home is None:
            return []
        return self.loops[home].generated.get(session, [])

    # ------------------------------------------------------------ intake
    def submit(self, session: int, tokens: np.ndarray,
               decode_tokens: int = 0,
               deadline: Optional[float] = None,
               sampling: Optional[SamplingParams] = None) -> Request:
        """Route one turn.  A session's first turn is placed by the
        router; later turns pin to the home engine (that is where the
        cached KV lives — cross-engine reuse is exactly what
        migration/handoff is for, not re-routing)."""
        eid = self._home.get(session)
        fresh = eid is None
        meta = RouteRequest(new_tokens=len(tokens),
                            decode_tokens=decode_tokens, session=session)
        if fresh:
            eid = self.router.route(meta, self.views())
            self._home[session] = eid
        r = self.loops[eid].submit(session, tokens,
                                   decode_tokens=decode_tokens,
                                   deadline=deadline, sampling=sampling)
        # a fresh SHORT parked on a prefill-role engine (spillover) is
        # a deflection candidate until it dispatches
        if (fresh and self.deflect_tokens is not None
                and self.roles[eid] == "prefill"
                and isinstance(self.router, LengthAwareRouter)
                and not self.router.is_long(meta)):
            self._deflectable[r.rid] = eid
        return r

    def close_session(self, session: int) -> None:
        home = self._home.pop(session, None)
        if home is not None:
            self.loops[home].close_session(session)

    # --------------------------------------------------------- deflection
    def _maybe_deflect(self) -> None:
        """Bounce spilled shorts off prefill engines that turned busy.

        A deflected request leaves the bouncing engine exactly as if it
        had never been submitted there (ServeLoop.withdraw) and goes
        back through the router with that engine excluded; its original
        arrival timestamp is preserved so TTFT/SLO accounting charges
        the detour to the request, not to the clock."""
        if self.deflect_tokens is None or not self._deflectable:
            return
        for rid, eid in list(self._deflectable.items()):
            lp = self.loops[eid]
            pr = lp._tokens.get(rid)
            if pr is None or pr.req.dispatch_time is not None:
                self._deflectable.pop(rid, None)   # served or gone
                continue
            if lp.policy.backlog_tokens() - pr.req.new_tokens \
                    <= self.deflect_tokens:
                continue                           # engine still quiet
            w = lp.withdraw(rid)
            self._deflectable.pop(rid, None)
            if w is None:
                continue
            session = w.req.session
            self._home.pop(session, None)
            tokens = w.prompt if w.prompt is not None else w.tokens
            meta = RouteRequest(new_tokens=len(tokens),
                                decode_tokens=w.decode_tokens,
                                session=session)
            new_eid = self.router.route(meta, self.views(),
                                        exclude=frozenset({eid}))
            self._home[session] = new_eid
            r2 = self.loops[new_eid].submit(
                session, tokens, decode_tokens=w.decode_tokens,
                deadline=w.req.deadline, sampling=w.sampling)
            r2.arrival = w.req.arrival
            self.deflections += 1

    # ---------------------------------------------------------- migration
    def _migratable(self, lp: ServeLoop, session: int) -> bool:
        # only sessions that are PURELY decoding move: no queued turn
        # (its prefill belongs where it was routed) — and the engine
        # pair must support handoff at all
        return not any(p.req.session == session
                       for p in lp._tokens.values())

    def _maybe_migrate(self) -> None:
        """Move decode-phase sessions off prefill-role engines.

        In spatial mode a prefill engine exists to run long prefills
        back to back; a session that finished its prefill there would
        otherwise pin a slot and steal tick time for its decode steps.
        Export → import moves its KV device-to-device to the least
        decode-loaded non-prefill engine, the loop bookkeeping follows,
        and the source slot frees."""
        if not self.migrate:
            return
        dsts = [i for i, role in enumerate(self.roles) if role != "prefill"]
        if not dsts:
            return
        for src, lp in enumerate(self.loops):
            if self.roles[src] != "prefill":
                continue
            for session in list(lp.active_decodes):
                if not self._migratable(lp, session):
                    continue
                dst = min(dsts, key=lambda i: (
                    len(self.loops[i].active_decodes),
                    self.loops[i].policy.backlog_tokens(), i))
                self._migrate_session(src, dst, session)

    def _migrate_session(self, src: int, dst: int, session: int) -> None:
        a, b = self.loops[src], self.loops[dst]
        payload = a.engine.export_session(session)
        b.engine.import_session(session, payload)
        # decode bookkeeping moves with the KV
        b.active_decodes[session] = a.active_decodes.pop(session)
        for d_src, d_dst in ((a.last_token, b.last_token),
                             (a.generated, b.generated),
                             (a.first_tokens, b.first_tokens),
                             (a._last_emit, b._last_emit)):
            if session in d_src:
                d_dst[session] = d_src.pop(session)
        if session in a._session_pending:
            b._session_pending[session] = a._session_pending.pop(session)
        a.engine.close_session(session)
        self._home[session] = dst
        self.migrated_sessions += 1

    # --------------------------------------------------------------- run
    @property
    def has_work(self) -> bool:
        return any(lp.has_work for lp in self.loops)

    def run_until_idle(self, max_wall: float = 60.0) -> None:
        """Interleave every loop's unified tick until the whole cluster
        drains (or max_wall elapses).  Deflection runs before the ticks
        (bounce while still queued), migration after (a prefill that
        just finished starts decoding elsewhere next tick)."""
        clock = self.loops[0].clock
        start = clock()
        while self.has_work and clock() - start < max_wall:
            self._maybe_deflect()
            did_any = False
            for lp in self.loops:
                if not lp.has_work:
                    continue
                did, _ = lp.tick()
                did_any = did_any or did
            self._maybe_migrate()
            if not did_any:
                time.sleep(0.0005)

    # ------------------------------------------------------------ reports
    def report(self, horizon: Optional[float] = None) -> SLOReport:
        return SLOTracker.merged(
            [lp.tracker for lp in self.loops]).report(horizon)

    def stats(self) -> Dict:
        per_engine = [lp.engine.stats() for lp in self.loops]
        return {
            "engines": len(self.loops),
            "roles": list(self.roles),
            "router": self.router.name,
            "deflections": self.deflections,
            "migrated_sessions": self.migrated_sessions,
            "handoff_sessions": sum(s["handoff_sessions"]
                                    for s in per_engine),
            "handoff_tokens": sum(s["handoff_tokens"] for s in per_engine),
            "handoff_host_bytes": sum(s["handoff_host_bytes"]
                                      for s in per_engine),
            "tokens_drafted": sum(s["tokens_drafted"] for s in per_engine),
            "tokens_accepted": sum(s["tokens_accepted"]
                                   for s in per_engine),
            "spec_dispatches": sum(s["spec_dispatches"]
                                   for s in per_engine),
            "per_engine": per_engine,
        }
