"""Single-instance serving engine: real JAX execution of the LAPS design.

Composes the substrate — KVArena (slots) + BucketExecutor (captured
shapes) + models.transformer — under the paper's scheduling primitives:

  * short-prefill batches on the packed token-bucket stream —
    arena-resident by default (DESIGN.md §6): KV reads and writes route
    through a slot map inside the kernel, zero whole-slot
    gather/scatter — with the dense (L, B) bucket grid kept for SSM/SWA
    architectures, pinned graph buckets, and off-ladder batches (§3.1);
  * re-prefill: new tokens written on top of the session's cached
    history (positions carry the offset);
  * long prefills advanced in fixed chunks C_l (§3.2);
  * decode steps batched across sessions;
  * runtime (T, L, H) samples feed core.boundary.fit — the engine
    re-estimates L_m live, exactly the paper's "fitting at runtime".

Runs identically with smoke configs on this CPU container and (with a
mesh + serve sharding rules) on a TPU pod slice.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import boundary as boundary_mod
from repro.kernels import ops as kernel_ops
from repro.core.buckets import (DEFAULT_DECODE_BUCKETS, DEFAULT_TOKEN_BUCKETS,
                                BucketGrid)
from repro.models import transformer as tr
from repro.models.config import ModelConfig
from repro.serving import packing
from repro.serving import sampling as sampling_mod
from repro.serving.executor import (BucketExecutor, DecodeBucketExecutor,
                                    PackedBucketExecutor)
from repro.serving.kvcache import KVArena, PagedKVArena
from repro.serving.sampling import SamplingParams


@dataclasses.dataclass
class MixedStepResult:
    """Outcome of one continuous-batching tick (engine.step_mixed)."""
    tokens: Dict[int, int]        # session → sampled next token
    fused: bool                   # True = ONE packed dispatch served all
    bucket: Optional[int] = None  # token bucket used (fused path)
    n_prefill: int = 0            # prefill + chunk segments
    n_decode: int = 0             # fused decode segments
    # speculative ticks (DESIGN.md §10) commit SEVERAL tokens per decode
    # session in one dispatch; ``committed[s]`` is the full emitted list
    # (``tokens[s]`` stays the LAST of them for non-spec callers)
    committed: Optional[Dict[int, List[int]]] = None


@dataclasses.dataclass
class SessionExport:
    """Device-resident snapshot of one session's cached context for
    arena→arena KV handoff (DESIGN.md §9).

    ``kv`` stays on device end to end: slot arenas export per-leaf
    ``(G, length, Hkv, D)`` slices, paged arenas ``(G, n_pages,
    page_size, Hkv, D)`` page gathers.  ``Engine.import_session`` counts
    the bytes of any HOST array that crosses it into
    ``handoff_host_bytes`` — the proof counter benches assert == 0."""

    length: int
    kv: Any
    paged: bool
    token_ids: Optional[List[int]] = None   # paged: committed ids
    sampling: Optional[SamplingParams] = None
    rng: Optional[np.random.Generator] = None
    last_logits: Optional[np.ndarray] = None


@dataclasses.dataclass
class EngineConfig:
    num_slots: int = 16
    max_len: int = 256
    chunk_tokens: int = 64           # C_l
    grid_lengths: Tuple[int, ...] = (8, 16, 32, 64)
    grid_depths: Tuple[int, ...] = (1, 2, 4, 8)
    pad_token: int = 0
    measure: bool = True             # collect boundary-fit samples
    # padding-free packed serving is the DEFAULT for every causal
    # architecture (DESIGN.md §7); packed=False is the explicitly
    # requested dense (L, B) measurement baseline
    packed: bool = True
    token_buckets: Tuple[int, ...] = DEFAULT_TOKEN_BUCKETS
    packed_max_seqs: Optional[int] = None  # None → min(num_slots, 16)
    arena_decode: bool = True        # in-place bucketed decode (§5)
    decode_buckets: Tuple[int, ...] = DEFAULT_DECODE_BUCKETS
    arena_prefill: bool = True       # in-place packed prefill (§6)
    # keep a host copy of every step's last logits row per session
    # (parity harnesses, sampling introspection).  False lets all-greedy
    # steps take their token from the executor's on-device argmax and
    # skip the full-vocab logits transfer entirely (fused greedy slice)
    keep_last_logits: bool = True
    # ---- paged KV arena (DESIGN.md §8/§12) ----------------------------
    # paged_kv replaces the per-session slot arena with a shared page
    # pool + per-session page tables: radix-tree prefix reuse maps a
    # repeated prompt prefix onto existing pages (only the new suffix is
    # prefilled) and COW forks share pages between branches.  The
    # DEFAULT for every packed_ok config: sliding-window layers walk a
    # ring page table (§7 rolling at page granularity), hybrid SSM
    # layers step per-session state pages from the same pool.  Requires
    # the packed + arena paths (a paged pool has no dense gather
    # fallback, like §7 rolling); paged_kv=False keeps the slot arena
    # as the explicit measurement baseline
    paged_kv: bool = True
    page_size: int = 16
    num_pages: Optional[int] = None  # None → num_slots·max_len/page_size
    prefix_cache: bool = True        # radix prefix index on/off
    # host spill tier (§12): >0 demotes LRU index-only pages to a
    # bounded host-side pool instead of dropping them on eviction;
    # prefix matches promote spilled pages back to device.  0 = off
    host_pool_bytes: int = 0
    # ---- fused on-device sampling (DESIGN.md §10) ---------------------
    # route non-greedy rows through the fused sampling kernel (bias +
    # temperature + top-k/top-p + the inverse-CDF draw on device, host
    # uniforms shipped in): only the (R,) sampled ids cross to host.
    # Takes effect with keep_last_logits=False (a kept host logits copy
    # forces the transfer anyway); a session with more than
    # kernels.sampling.MAX_BIAS bias entries drops its step back to the
    # host sampler
    fused_sampling: bool = False


class Engine:
    def __init__(self, cfg: ModelConfig, params, ecfg: Optional[EngineConfig] = None):
        self.cfg = cfg
        self.params = params
        self.ecfg = ecfg or EngineConfig()
        cap = tr.arena_capability(cfg)
        self.capability = cap
        # ---- arena layout (DESIGN.md §7) ------------------------------
        # Rolling mode: sliding-window configs serve from window-deep
        # rolling slots (depth = window + margin, margin = the largest
        # packed bucket so one step's writes can never wrap onto a row
        # still inside any query's window).  It requires BOTH in-place
        # paths — a rolling slot cannot be gathered into the dense
        # (L, B) step, whose writes are absolute.  Otherwise SWA slots
        # are FULL depth and the dense path masks the window instead.
        self._rolling = bool(
            cap.packed_ok and cap.has_window and self.ecfg.packed
            and self.ecfg.arena_prefill and self.ecfg.arena_decode)
        swa_depth: Optional[int] = None
        # no-alias margin: the most new rows ONE segment may write into
        # a rolling slot per step.  C_l bounds it — step_mixed splits
        # any longer segment into C_l-sized packed chunks — which keeps
        # the rolling depth near the window instead of near the bucket
        self._seg_margin = self.ecfg.chunk_tokens
        if cap.has_window:
            if self._rolling:
                swa_depth = min(self.ecfg.max_len,
                                cap.window + self._seg_margin)
            else:
                swa_depth = self.ecfg.max_len
        # rolling KV slots and SSM state have no spare park row — pads
        # target a dedicated scratch slot instead of aliasing a live one
        scratch = bool(cap.packed_ok and cap.needs_scratch_slot
                       and (self.ecfg.packed or self.ecfg.arena_decode))
        self._paged = bool(self.ecfg.paged_kv)
        if self._paged:
            if not cap.packed_ok:
                raise ValueError(
                    f"{cfg.name}: paged_kv needs a causal decoder stack "
                    "(encoder-only models have no serving cache) — set "
                    "paged_kv=False for the dense baseline")
            if not (self.ecfg.packed and self.ecfg.arena_prefill
                    and self.ecfg.arena_decode):
                raise ValueError(
                    "paged_kv requires the packed + arena execution paths "
                    "(packed=True, arena_prefill=True, arena_decode=True): "
                    "a paged pool has no dense gather fallback — set "
                    "paged_kv=False to pin the slot/dense baseline")
            num_pages = self.ecfg.num_pages or (
                self.ecfg.num_slots * self.ecfg.max_len
                // self.ecfg.page_size)
            # sliding-window configs get a RING page table (§12): the §7
            # rolling arena at page granularity, ⌈(window + margin)/ps⌉
            # logical blocks with margin = chunk_tokens so one step's
            # writes never wrap onto rows still inside any query window
            ring_pages = None
            if cap.has_window:
                depth = min(self.ecfg.max_len,
                            cap.window + self._seg_margin)
                ring_pages = -(-depth // self.ecfg.page_size)
            self.arena = PagedKVArena(
                cfg, num_pages, self.ecfg.page_size, self.ecfg.max_len,
                prefix_cache=self.ecfg.prefix_cache,
                ring_pages=ring_pages, state_slots=cap.has_ssm,
                host_pool_bytes=self.ecfg.host_pool_bytes)
        else:
            self.arena = KVArena(cfg, self.ecfg.num_slots, self.ecfg.max_len,
                                 swa_depth=swa_depth, scratch_slot=scratch)
        # dense gather/scatter is a valid fallback everywhere EXCEPT on
        # rolling arenas (absolute-position writes don't fit a rolling
        # slot) and paged pools (pages are scattered, shared, and have
        # no whole-sequence row to gather) — there, oversized work is
        # split across packed steps
        self._dense_ok = not (self._rolling or self._paged)
        self.executor = BucketExecutor(cfg)
        self.packed_executor: Optional[PackedBucketExecutor] = None
        if self.ecfg.packed and cap.packed_ok and (
                cap.pure_attn or self.ecfg.arena_prefill):
            max_seqs = self.ecfg.packed_max_seqs or min(self.ecfg.num_slots,
                                                        16)
            self.packed_executor = PackedBucketExecutor(
                cfg, token_buckets=self.ecfg.token_buckets,
                max_seqs=min(max_seqs, self.ecfg.num_slots))
        self.decode_executor: Optional[DecodeBucketExecutor] = None
        if self.ecfg.arena_decode and cap.packed_ok and not (
                cap.has_window and not self._rolling):
            self.decode_executor = DecodeBucketExecutor(
                cfg, decode_buckets=self.ecfg.decode_buckets,
                max_seqs=self.ecfg.num_slots)
        self.grid = BucketGrid(self.ecfg.grid_lengths, self.ecfg.grid_depths,
                               mem_budget_tokens=self.ecfg.num_slots
                               * self.ecfg.max_len)
        self.samples: List[Tuple[float, float, float]] = []  # (T, L, H)
        self.fitted: Optional[boundary_mod.TotalFit] = None
        # last-step logits per session (parity harness + sampling hooks)
        self.last_logits: Dict[int, np.ndarray] = {}
        # per-session sampling options (greedy argmax when absent)
        self.sampling: Dict[int, SamplingParams] = {}
        self._rngs: Dict[int, np.random.Generator] = {}
        # dense-dispatch accounting by (kind, cause): "requested" =
        # the config asked for the dense baseline (packed off, a pinned
        # (L, B) bucket, arena paths disabled); "forced" = the packed
        # path exists but this step fell off it (off-ladder total,
        # over-depth batch, ladder overflow)
        self.dense_causes: Dict[Tuple[str, str], int] = {}
        # fused-greedy counters: steps that took tokens from the
        # on-device argmax without shipping full-vocab logits to host
        self.fused_greedy_steps = 0
        self.logits_rows_shipped = 0
        # §9 arena→arena handoff proof counters: sessions imported from
        # a peer engine, tokens of KV that crossed, and the bytes of any
        # HOST array among the crossing leaves (must stay 0 — the copy
        # is device-to-device)
        self.handoff_sessions = 0
        self.handoff_tokens = 0
        self.handoff_host_bytes = 0
        # §10 speculative decoding: a draft proposer attached via
        # enable_spec turns decode segments into length-(k+1) "verify"
        # segments on the SAME packed stream; counters prove the
        # multi-token commits (benches assert tokens/dispatch)
        self.draft: Optional[Any] = None     # serving.draft.DraftProposer
        self.spec_k = 0
        self.tokens_drafted = 0
        self.tokens_accepted = 0
        self.spec_dispatches = 0
        self.spec_committed = 0
        self._spec_by_session: Dict[int, List[int]] = {}  # s → [drafted, accepted]
        # non-greedy steps served by the fused sampling kernel (no
        # full-vocab logits transfer)
        self.fused_sample_steps = 0
        # §11 failure model: a crashed engine must never be dispatched
        # to again — the cluster marks it dead after evacuation and
        # every compute entry point refuses (host-side bookkeeping like
        # history()/sampling reads stays readable: that state survives
        # a device loss in the serving process).
        self.dead = False

    def mark_dead(self) -> None:
        self.dead = True

    def _check_alive(self) -> None:
        if self.dead:
            raise RuntimeError("engine is dead: dispatch refused (§11)")

    # ------------------------------------------------------------ session
    def open_session(self, session: int) -> None:
        if self._paged:
            self.arena.open(session)
        else:
            self.arena.alloc(session)

    def close_session(self, session: int) -> None:
        self.arena.free(session)
        self.last_logits.pop(session, None)
        self.sampling.pop(session, None)
        self._rngs.pop(session, None)
        if self.draft is not None:
            self.draft.forget(session)

    def history(self, session: int) -> int:
        return self.arena.length(session)

    def probe_prefix(self, tokens: Sequence[int]) -> int:
        """Tokens of ``tokens`` a FRESH session would inherit from the
        radix prefix index instead of prefilling (0 on slot arenas or
        with the prefix cache off).  The serve loop uses this to
        classify requests by their true suffix cost."""
        fn = getattr(self.arena, "probe_prefix", None)
        return int(fn(tokens)) if fn is not None else 0

    def adopt_prefix(self, session: int, tokens: Sequence[int]) -> int:
        """Map the longest indexed prefix of ``tokens`` onto existing
        pages for fresh session ``session`` NOW (instead of at dispatch
        inside ``step_mixed``), returning the adopted token count.  The
        serve loop uses this so its queued suffix, the request's billed
        length, and the chunker's slicing all agree exactly — the
        adopted pages are refcount-pinned while the request waits.  0 on
        slot arenas or with the prefix cache off."""
        if not self._paged or self.arena.length(session) != 0:
            return 0
        return self.arena.match_prefix(session, tokens)

    def fork_session(self, parent: int, child: int) -> None:
        """COW-fork ``parent``'s cached context into fresh session
        ``child`` (n-best / tool-use branches).  Paged arenas only —
        both branches share every page until one writes into the
        partial boundary page, which then copies on demand."""
        assert self._paged, "fork_session requires paged_kv=True"
        self.arena.fork(parent, child)

    # ------------------------------------------------------------ handoff
    @property
    def can_handoff(self) -> bool:
        """Arena→arena session handoff is defined for pure-attention,
        non-rolling layouts: every cache leaf is a k/v tensor with the
        sequence on one contiguous axis.  Rolling SWA slots write
        modularly and SSM state is not a token sequence — migrating
        those needs a layout-aware repack (ROADMAP)."""
        return self.capability.pure_attn and not self._rolling

    def export_session(self, session: int) -> SessionExport:
        """Handoff source (DESIGN.md §9): snapshot the session's cached
        KV as DEVICE arrays — slot rows sliced or page rows gathered,
        never copied through host — plus the sampling state a decode on
        the destination needs (params, the replayable rng, last
        logits).  The source keeps the session; the cluster closes it
        after a successful import."""
        self._check_alive()
        assert self.can_handoff, \
            "KV handoff requires a pure-attention, non-rolling arena"
        h = self.history(session)
        if self._paged:
            kv = self.arena.export_pages(session)
            ids = list(self.arena._tokens.get(session, []))
        else:
            kv = self.arena.export_slot(session)
            ids = None
        return SessionExport(length=h, kv=kv, paged=self._paged,
                             token_ids=ids,
                             sampling=self.sampling.get(session),
                             rng=self._rngs.get(session),
                             last_logits=self.last_logits.get(session))

    def import_session(self, session: int, payload: SessionExport) -> None:
        """Handoff destination: write the exported KV into this arena
        (fresh slot or fresh pages) with device-to-device copies and
        restore the sampling state.  Any host array among the KV leaves
        is counted into ``handoff_host_bytes`` — benches assert it
        stays 0."""
        self._check_alive()
        assert self.can_handoff, \
            "KV handoff requires a pure-attention, non-rolling arena"
        assert payload.paged == self._paged, \
            "handoff between arena families (slot vs paged) not supported"
        assert self.history(session) == 0, \
            f"import into non-empty session {session}"
        if payload.kv is not None:
            for leaf in jax.tree.leaves(payload.kv):
                if not isinstance(leaf, jax.Array):
                    self.handoff_host_bytes += int(
                        getattr(leaf, "nbytes", 0))
        if self._paged:
            # handoff dedupe (§12): probe the DESTINATION's radix index
            # first — prefix pages this pool already holds are adopted
            # in place and only the suffix of the exported payload is
            # copied in (import_session slices past the matched pages)
            toks = payload.token_ids or []
            if toks and self.ecfg.prefix_cache:
                self.arena.match_prefix(session, toks)
            self.arena.import_session(session, toks, payload.kv,
                                      payload.length)
        else:
            if session in self.arena._session_slot:
                self.arena.free(session)
            self.arena.import_slot(session, payload.kv, payload.length)
        if payload.sampling is not None:
            self.sampling[session] = payload.sampling
            if payload.rng is not None:
                self._rngs[session] = payload.rng
        if payload.last_logits is not None:
            self.last_logits[session] = payload.last_logits
        self.handoff_sessions += 1
        self.handoff_tokens += payload.length

    # ------------------------------------------------ speculative decode
    @property
    def can_spec(self) -> bool:
        """Speculative verify/rollback is defined exactly where
        ``arena.truncate`` is: pure-attention, non-rolling layouts
        (mirrors :attr:`can_handoff`).  A rolling SWA slot writes
        modularly — a rejected tail has already overwritten window
        history — and SSM state folds every token irreversibly into the
        recurrence; both need layout-aware rollback (ROADMAP)."""
        return self.capability.pure_attn and not self._rolling

    def enable_spec(self, draft: Any, k: int = 4) -> None:
        """Attach a draft proposer (serving.draft): decode sessions now
        advance through length-(k+1) ``verify`` segments on the packed
        mixed stream (DESIGN.md §10) — up to k accepted drafts plus one
        corrective/bonus token per dispatch, rejected tails rolled back
        via ``arena.truncate``.  Greedy sessions stay bit-identical to
        plain decode; sampled sessions commit by rejection sampling,
        which preserves the target distribution."""
        assert self.can_spec, \
            "speculative decoding needs a pure-attention, non-rolling arena"
        assert self.packed_executor is not None and self.ecfg.arena_prefill, \
            "speculative decoding rides the packed arena stream"
        assert k >= 1, k
        self.draft = draft
        self.spec_k = int(k)

    def disable_spec(self) -> None:
        self.draft = None
        self.spec_k = 0

    def _spec_ready(self) -> bool:
        return (self.draft is not None and self.spec_k > 0
                and self.packed_executor is not None
                and self.ecfg.arena_prefill and self.can_spec)

    @property
    def spec_enabled(self) -> bool:
        """True when decode ticks will actually run speculative verify
        segments — the serve loop reads this to size its stream-token
        reservations (1 + k per fused session instead of 1)."""
        return self._spec_ready()

    def _plan_spec(self, decodes: Sequence[Tuple[int, int]],
                   max_new: Optional[Dict[int, int]]
                   ) -> Dict[int, List[int]]:
        """Ask the draft for up to k tokens per eligible decode session.
        A session sits the tick out (plain 1-token decode segment) when
        its k+1 verify rows would overflow the arena, its remaining
        token budget cannot cover even one accepted draft, or the
        proposer has nothing to say."""
        spec: Dict[int, List[int]] = {}
        lim = self.ecfg.max_len - 2
        for s, tok in decodes:
            h = self.arena.length(s)
            budget = self.spec_k + 1
            if max_new is not None:
                budget = min(budget, int(max_new.get(s, budget)))
            if h <= 0 or budget < 2 or h + self.spec_k + 1 > lim:
                continue
            d = self.draft.propose(s, int(tok), self.spec_k)
            d = [int(x) for x in list(d)[:min(self.spec_k, budget - 1)]]
            if d:
                spec[s] = d
        return spec

    def spec_step(self, decodes: Sequence[Tuple[int, int]],
                  max_new: Optional[Dict[int, int]] = None
                  ) -> Dict[int, List[int]]:
        """One speculative decode tick: every eligible session's
        ``[pending, draft_1..draft_k]`` verify segment fused into ONE
        packed dispatch, 1..k+1 tokens committed each.  ``max_new``
        caps a session's emitted tokens (its last max_new gap).
        Returns {session: emitted tokens}."""
        res = self.step_mixed([], decodes, max_new=max_new)
        if res.committed is not None:
            return res.committed
        return {s: [res.tokens[s]] for s, _ in decodes}

    def _spec_draws(self, session: int, m: int
                    ) -> Tuple[np.ndarray, np.ndarray]:
        """(u_acc, u_samp) uniforms for one verify walk, drawn as
        interleaved pairs j = 0..m from the session's replayable rng —
        row j's accept test is ``u_acc[j] < p_j(draft)``, its reject
        resample (or the row-m bonus draw) consumes ``u_samp[j]``.  The
        2(m+1) draws happen up front whatever prefix is accepted, so
        the per-step rng consumption is deterministic.  Greedy sessions
        draw nothing (accept = exact id match)."""
        rng = self._rngs.get(session)
        if rng is None:
            return np.zeros(m + 1), np.zeros(m + 1)
        u = np.asarray([rng.random() for _ in range(2 * (m + 1))])
        return u[0::2], u[1::2]

    # ----------------------------------------------------------- sampling
    def set_sampling(self, session: int,
                     params: Optional[SamplingParams]) -> None:
        """Attach per-session sampling options (None → greedy argmax).
        Every path that emits a token for the session — prefill TTFT,
        fused mixed-step rows, arena/dense decode — samples under them.
        Greedy sessions WITH a logit bias keep their params (the bias
        applies before argmax); only fully-default options are dropped
        back to the vectorized argmax row."""
        if params is None or params.is_default:
            self.sampling.pop(session, None)
            self._rngs.pop(session, None)
            return
        self.sampling[session] = params
        if params.is_greedy:
            self._rngs.pop(session, None)
        else:
            self._rngs[session] = sampling_mod.make_rng(session, params)

    def _sample_rows(self, sessions: Sequence[int],
                     logits: np.ndarray) -> np.ndarray:
        """One token per (session, logits row) under its options."""
        return sampling_mod.sample_batch(logits, sessions, self.sampling,
                                         self._rngs)

    def _tokens_from_step(self, sessions: Sequence[int], logits_dev,
                          ids_dev) -> Tuple[np.ndarray, Optional[np.ndarray]]:
        """Sample one token per session from an arena step's outputs.

        The executors return the on-device greedy argmax next to the
        logits.  An all-greedy step with ``keep_last_logits=False``
        takes its tokens straight from those ids — the full-vocab
        logits never cross to host (the fused-sampling greedy slice).
        Steps with sampling options (or the default logits-keeping
        config) ship the rows and sample on host as before.  Returns
        (tokens (n,), logits_np or None).
        """
        n = len(sessions)
        all_greedy = all(s not in self.sampling for s in sessions)
        if all_greedy and not self.ecfg.keep_last_logits:
            self.fused_greedy_steps += 1
            return np.asarray(ids_dev)[:n].astype(np.int64), None
        if (self.ecfg.fused_sampling and not self.ecfg.keep_last_logits
                and self._fused_bias_ok(sessions)):
            return self._fused_sample_rows(sessions, logits_dev), None
        logits_np = np.asarray(logits_dev)
        self.logits_rows_shipped += int(logits_np.shape[0])
        return self._sample_rows(sessions, logits_np[:n]), logits_np

    def _fused_bias_ok(self, sessions: Sequence[int]) -> bool:
        """The fused sampling kernel carries MAX_BIAS bias slots per
        row; a step with a heavier-biased session keeps the host path."""
        return all(len(self.sampling[s].logit_bias or ())
                   <= kernel_ops.MAX_BIAS
                   for s in sessions if s in self.sampling)

    def _fused_sample_rows(self, sessions: Sequence[int],
                           logits_dev) -> np.ndarray:
        """Sample one token per live row through the fused on-device
        kernel (DESIGN.md §10): bias + temperature + top-k/top-p + the
        inverse-CDF draw all happen on device; host-drawn uniforms go
        in, (R,) token ids come out, and the full-vocab logits never
        cross.  Consumes ONE uniform per non-greedy row — the same rng
        protocol as the host sampler, so a session can hop between
        paths mid-stream."""
        r = int(logits_dev.shape[0])
        n = len(sessions)
        temp = np.zeros(r, np.float32)
        topk = np.zeros(r, np.int32)
        topp = np.ones(r, np.float32)
        u = np.zeros(r, np.float32)
        draft = np.full(r, -1, np.int32)
        bias_ids = np.full((r, kernel_ops.MAX_BIAS), -1, np.int32)
        bias_vals = np.zeros((r, kernel_ops.MAX_BIAS), np.float32)
        for i, s in enumerate(sessions):
            sp = self.sampling.get(s)
            if sp is None:
                continue
            temp[i] = max(float(sp.temperature), 0.0)
            topk[i] = int(sp.top_k or 0)
            topp[i] = float(sp.top_p) if sp.top_p is not None else 1.0
            for j, (t, v) in enumerate(sp.logit_bias or ()):
                bias_ids[i, j] = int(t)
                bias_vals[i, j] = float(v)
            if not sp.is_greedy:
                u[i] = float(self._rngs[s].random())
        tok, _, _ = kernel_ops.fused_sample(logits_dev, temp, topk, topp,
                                            bias_ids, bias_vals, u, draft)
        self.fused_sample_steps += 1
        return np.asarray(tok)[:n].astype(np.int64)

    def _note_dense(self, kind: str, cause: str) -> None:
        key = (kind, cause)
        self.dense_causes[key] = self.dense_causes.get(key, 0) + 1

    # ------------------------------------------------- bucketized prefill
    def prefill_batch(self, sessions: Sequence[int],
                      token_lists: Sequence[np.ndarray],
                      bucket: Optional[Tuple[int, int]] = None
                      ) -> Dict[int, int]:
        """Short-prefill / re-prefill batch.

        With a packed executor and no pinned (L, B) ``bucket``, the
        batch rides the packed token-bucket stream — arena-resident by
        default (§6), zero whole-slot gather/scatter — via
        :meth:`step_mixed` (which itself falls back to the dense path
        for off-ladder totals or over-depth batches).  An explicit
        ``bucket`` pins the dense (L, B) graph path.
        Returns {session: first_sampled_token}."""
        self._check_alive()
        if self.packed_executor is not None and (
                bucket is None or not self._dense_ok):
            # a pinned (L, B) graph bucket has no meaning on paged /
            # rolling arenas (no dense gather path exists) — the batch
            # rides the packed stream instead
            return self.step_mixed(list(zip(sessions, token_lists)),
                                   []).tokens
        cause = "requested" if (bucket is not None
                                or self.packed_executor is None) else "forced"
        return self._prefill_batch_dense(sessions, token_lists, bucket,
                                         cause=cause)

    def _prefill_batch_dense(self, sessions: Sequence[int],
                             token_lists: Sequence[np.ndarray],
                             bucket: Optional[Tuple[int, int]] = None,
                             cause: str = "requested") -> Dict[int, int]:
        """Dense (L, B) grid prefill: pads to ``bucket`` when given
        (graph path), else to max length; gathers whole arena slots and
        scatters them back.  The explicitly requested measurement
        baseline (pinned grid buckets, packed=False configs) and the
        capability-forced fallback for off-ladder packed batches —
        ``cause`` records which, feeding ``stats()``."""
        assert self._dense_ok, \
            "dense gather path cannot serve a rolling windowed arena"
        assert len(sessions) == len(token_lists)
        self._note_dense("prefill", cause)
        n = len(sessions)
        lens = [len(t) for t in token_lists]
        if bucket is not None:
            pad_l, pad_b = bucket
            assert pad_l >= max(lens) and pad_b >= n, (bucket, lens, n)
        else:
            pad_l, pad_b = max(lens), n

        slots, hists = [], []
        for s in sessions:
            slots.append(self.arena.alloc(s))
            hists.append(self.arena.length(s))
        # depth padding reuses slot 0's cache row for dummy rows
        all_slots = slots + [slots[0]] * (pad_b - n)

        tokens = np.full((pad_b, pad_l), self.ecfg.pad_token, np.int32)
        positions = np.zeros((pad_b, pad_l), np.int32)
        sample_idx = np.zeros((pad_b,), np.int32)
        park = self.arena.max_len - 1
        for i, (tl, h) in enumerate(zip(token_lists, hists)):
            tokens[i, :len(tl)] = tl
            pos = h + np.arange(pad_l)
            pos[len(tl):] = park                    # junk KV → parking slot
            positions[i] = pos
            sample_idx[i] = len(tl) - 1
        positions[n:] = park                        # dummy depth rows

        caches = self.arena.gather(all_slots)
        t0 = time.perf_counter()
        last, new_caches = self.executor.prefill(
            self.params, jnp.asarray(tokens), jnp.asarray(positions),
            caches, jnp.asarray(sample_idx))
        last_np = np.asarray(last)
        toks = self._sample_rows(sessions, last_np)
        elapsed = time.perf_counter() - t0
        self.executor.note_padding(sum(lens), pad_l * pad_b)
        # write back only the real rows
        self.arena.scatter(slots, jax.tree.map(
            lambda a: a[:, :n], new_caches))
        out: Dict[int, int] = {}
        for i, s in enumerate(sessions):
            self.arena.set_length(s, hists[i] + lens[i])
            out[s] = int(toks[i])
            self.last_logits[s] = last_np[i]
        if self.ecfg.measure and n:
            per = elapsed / n
            for l, h in zip(lens, hists):
                self.samples.append((per, float(l), float(h)))
        return out

    # ------------------------------------------------------ packed prefill
    def prefill_packed(self, sessions: Sequence[int],
                       token_lists: Sequence[np.ndarray],
                       token_bucket: Optional[int] = None
                       ) -> Dict[int, int]:
        """Padding-free packed prefill / re-prefill.

        Every request's new tokens are concatenated into ONE flat stream
        bucketed on TOTAL tokens; per-sequence KV is scatter-written to
        the arena rows.  The only padding is the bucket tail, and the
        compiled-shape space grows with |token_buckets| instead of the
        dense grid's |L| × |B|.  Falls back to the dense path when the
        packed executor is absent or the batch is off-ladder.
        Returns {session: first_sampled_token}."""
        assert len(sessions) == len(token_lists)
        res = self.step_mixed(list(zip(sessions, token_lists)), [],
                              token_bucket=token_bucket)
        return res.tokens

    # ------------------------------------------------- continuous batching
    def step_mixed(self, prefills: Sequence[Tuple[int, np.ndarray]],
                   decodes: Sequence[Tuple[int, int]],
                   token_bucket: Optional[int] = None,
                   max_new: Optional[Dict[int, int]] = None
                   ) -> MixedStepResult:
        """One continuous-batching tick: short prefills, long-prefill
        chunks, and single-token decode segments fused into ONE packed
        flat stream — one dispatch instead of a prefill step plus a
        decode step (DESIGN.md §4).

        prefills: (session, new_tokens) — fresh prefill, re-prefill, or a
        C_l chunk (the session's cached length is the history offset).
        decodes: (session, last_token) — in-flight sessions advancing one
        token each; their segment attends over ``history + 1`` keys.

        Falls back to the alternating dense path (prefill batch then
        decode batch — up to two dispatches) when the packed executor is
        absent, the mix overflows ``max_seqs``, or the total is
        off-ladder.  Returns a :class:`MixedStepResult`."""
        prefills, decodes = list(prefills), list(decodes)
        self._check_alive()
        n_p, n_d = len(prefills), len(decodes)
        assert n_p + n_d > 0, "empty mixed step"
        sess_all = [s for s, _ in prefills] + [s for s, _ in decodes]
        assert len(set(sess_all)) == len(sess_all), \
            f"session appears twice in one step: {sess_all}"
        if self._paged:
            # radix prefix adoption (§8): a FRESH session's prompt maps
            # its longest indexed prefix onto existing pages BEFORE the
            # bucket is chosen, so the step only prefills (and the
            # ladder only prices) the new suffix.  The matched pages
            # become the segment's history offset below.
            rewritten = []
            for s, toks in prefills:
                toks = np.asarray(toks, np.int32)
                if self.arena.length(s) == 0:
                    matched = self.arena.match_prefix(s, toks)
                    if matched:
                        toks = toks[matched:]
                rewritten.append((s, toks))
            prefills = rewritten
        lens = [len(t) for _, t in prefills]
        # §10 speculative planning: with a draft attached, each eligible
        # decode session's segment grows from 1 token to 1 + k (pending
        # + drafts) — the ladder prices the true verify stream
        spec: Dict[int, List[int]] = {}
        if decodes and self._spec_ready():
            spec = self._plan_spec(decodes, max_new)
        spec_len = 1 + self.spec_k
        total = sum(lens) + sum(spec_len if s in spec else 1
                                for s, _ in decodes)
        px = self.packed_executor
        bucket = None
        # px.max_seqs already accounts for the scratch pad row that
        # bucket tails park in on rolling/SSM arenas, so a fully fused
        # tick still runs as one packed step.  Rolling slots add the
        # no-alias constraint: no segment may write more than the
        # margin in one step (a pinned oversized token_bucket must not
        # bypass it) — longer segments go through the split path.
        fits = px is not None and n_p + n_d <= px.max_seqs
        if fits and self._rolling and lens and max(lens) > self._seg_margin:
            fits = False
        if fits:
            bucket = token_bucket or px.bucket_for(total)
            if bucket is not None and bucket < total:
                bucket = None
        if bucket is None and spec:
            # speculative lengths pushed the tick off the ladder — this
            # dispatch drops back to plain 1-token decode segments
            spec = {}
            total = sum(lens) + n_d
            if fits:
                bucket = token_bucket or px.bucket_for(total)
                if bucket is not None and bucket < total:
                    bucket = None
        if bucket is None:
            if not self._dense_ok:
                # rolling windowed arenas have no dense escape hatch:
                # off-ladder / over-depth work is SPLIT across packed
                # steps instead (every piece stays arena-resident)
                return self._step_split(prefills, decodes)
            out: Dict[int, int] = {}
            if prefills:
                out.update(self._prefill_batch_dense(
                    [s for s, _ in prefills], [t for _, t in prefills],
                    cause="forced" if px is not None else "requested"))
            if decodes:
                dec = self.decode_batch([s for s, _ in decodes],
                                        [t for _, t in decodes])
                out.update({s: toks[0] for s, toks in dec.items()})
            return MixedStepResult(tokens=out, fused=False,
                                   n_prefill=n_p, n_decode=n_d)

        segments: List[packing.SegmentSpec] = []
        for s, toks in prefills:
            # arena.length is 0 for not-yet-allocated sessions; the slot
            # itself is claimed once, inside _run_packed
            segments.append(packing.SegmentSpec(
                s, np.asarray(toks, np.int32), self.arena.length(s),
                kind="prefill"))
        for s, tok in decodes:
            if self._paged:
                assert self.arena.length(s) > 0, \
                    f"decode session {s} has no cached context"
            else:
                assert self.arena.slot_of(s) is not None, \
                    f"decode session {s} has no cache slot"
            if s in spec:
                # uniform verify length 1 + k (short proposals pad with
                # pad_token rows — written KV past the commit is rolled
                # back anyway) so every spec dispatch shares one
                # (bucket, L) compiled shape
                d = spec[s]
                toks = np.asarray(
                    [tok] + d + [self.ecfg.pad_token]
                    * (self.spec_k - len(d)), np.int32)
                segments.append(packing.SegmentSpec(
                    s, toks, self.arena.length(s), kind="verify"))
            else:
                segments.append(packing.SegmentSpec(
                    s, np.asarray([tok], np.int32), self.arena.length(s),
                    kind="decode"))
        if spec:
            return self._run_spec(segments, bucket,
                                  {s: len(d) for s, d in spec.items()})
        return self._run_packed(segments, bucket)

    def _step_split(self, prefills: Sequence[Tuple[int, np.ndarray]],
                    decodes: Sequence[Tuple[int, int]]) -> MixedStepResult:
        """Serve an off-ladder / over-depth mix WITHOUT the dense path:
        prefills advance in C_l-sized packed chunks and the decode
        backlog drains in ladder-top groups — every piece stays
        arena-resident.  The rolling windowed arena (§7) requires this
        (a rolling slot cannot be gathered into the dense step); the
        chunk size also re-establishes the no-alias margin for any
        caller-supplied segment length."""
        px = self.packed_executor
        c = min(self._seg_margin, px.ladder.max_tokens)
        out: Dict[int, int] = {}
        for s, toks in prefills:
            toks = np.asarray(toks)
            for start in range(0, len(toks), c):
                res = self.step_mixed([(s, toks[start:start + c])], [])
                out[s] = res.tokens[s]
        if decodes:
            dx = self.decode_executor
            m = dx.ladder.max_seqs if dx is not None else 1
            decodes = list(decodes)
            for i in range(0, len(decodes), m):
                grp = decodes[i:i + m]
                dec = self.decode_batch([s for s, _ in grp],
                                        [t for _, t in grp])
                out.update({s: v[0] for s, v in dec.items()})
        return MixedStepResult(tokens=out, fused=False,
                               n_prefill=len(prefills),
                               n_decode=len(decodes))

    def _run_packed(self, segments: List[packing.SegmentSpec],
                    bucket: int) -> MixedStepResult:
        """Dispatch an assembled segment list as one packed stream.

        Arena-resident by default (§6): the step reads cached history
        and writes new KV rows directly in the arena through the slot
        map — zero whole-slot gather/scatter.  ``arena_prefill=False``
        keeps the legacy gathered-cache dispatch (the measurement
        baseline)."""
        if self._paged:
            return self._run_packed_paged(segments, bucket)
        px = self.packed_executor
        n = len(segments)
        slots = [self.arena.alloc(seg.session) for seg in segments]
        b_max = px.stream_rows
        # dummy cache rows (and tail-padding KV writes) reuse slot 0,
        # confined to the scratch row at S_max − 1 by their positions —
        # except on rolling/SSM arenas, where pads own the scratch SLOT
        # (a rolling slot has no spare row; state has no park position)
        pad_slot = self.arena.scratch if self.arena.scratch is not None \
            else slots[0]
        all_slots = slots + [pad_slot] * (b_max - n)
        stream = packing.assemble_mixed_stream(
            segments, bucket, b_max, park_position=self.arena.max_len - 1,
            pad_token=self.ecfg.pad_token)
        sessions = [seg.session for seg in segments]

        if self.ecfg.arena_prefill:
            slot_map = np.asarray(all_slots, np.int32)
            seg_slots = slot_map[stream.seg_ids]   # per-token arena slot
            t0 = time.perf_counter()
            last, ids, new_arena = px.mixed_step_arena(
                self.params, jnp.asarray(stream.tokens),
                jnp.asarray(stream.positions), jnp.asarray(seg_slots),
                jnp.asarray(slot_map), jnp.asarray(stream.cu_seqlens),
                jnp.asarray(stream.q_offsets),
                jnp.asarray(stream.kv_lengths), self.arena.arena,
                jnp.asarray(stream.last_idx), n_decode=stream.decode_tokens)

            def writeback():
                self.arena.replace(new_arena)
        else:
            ids = None
            caches = self.arena.gather(all_slots)
            t0 = time.perf_counter()
            last, new_caches = px.mixed_step(
                self.params, jnp.asarray(stream.tokens),
                jnp.asarray(stream.positions), jnp.asarray(stream.seg_ids),
                jnp.asarray(stream.cu_seqlens),
                jnp.asarray(stream.q_offsets),
                jnp.asarray(stream.kv_lengths), caches,
                jnp.asarray(stream.last_idx), n_decode=stream.decode_tokens)

            def writeback():
                self.arena.scatter(slots, jax.tree.map(
                    lambda a: a[:, :n], new_caches))
        if ids is not None:
            toks, last_np = self._tokens_from_step(sessions, last, ids)
        else:
            last_np = np.asarray(last)
            self.logits_rows_shipped += int(last_np.shape[0])
            toks = self._sample_rows(sessions, last_np)
        elapsed = time.perf_counter() - t0
        px.note_padding(stream.total_tokens, bucket)
        writeback()
        out: Dict[int, int] = {}
        for i, seg in enumerate(segments):
            self.arena.set_length(seg.session, seg.history + seg.length)
            if self.draft is not None:
                if seg.kind == "decode":
                    # keep the draft's view of the cached stream in sync
                    # on non-speculative ticks too
                    self.draft.observe(seg.session, [int(seg.tokens[0])])
                else:
                    # prompt/chunk tokens seed the draft's history
                    self.draft.observe(seg.session,
                                       [int(t) for t in seg.tokens],
                                       prompt=True)
            out[seg.session] = int(toks[i])
            if last_np is not None:
                self.last_logits[seg.session] = last_np[i]
        if self.ecfg.measure:
            # only prefill work feeds the (T, L, H) boundary fit — decode
            # rows are priced by the decode model, not T(L, H)
            pre = [seg for seg in segments if seg.kind != "decode"]
            if pre:
                per = elapsed / len(pre)
                for seg in pre:
                    self.samples.append((per, float(seg.length),
                                         float(seg.history)))
        n_d = stream.decode_tokens
        return MixedStepResult(tokens=out, fused=True, bucket=bucket,
                               n_prefill=n - n_d, n_decode=n_d)

    def _run_packed_paged(self, segments: List[packing.SegmentSpec],
                          bucket: int) -> MixedStepResult:
        """Paged dispatch of an assembled segment list (DESIGN.md §8).

        Per segment, ``prepare_extend`` makes the write range
        exclusively owned (COW-copying a fork-shared boundary page,
        allocating tail pages); the step then writes each stream row's
        KV at its (page, offset) and reads every segment's FULL logical
        context — matched prefix pages included — through its page-table
        row.  Tail rows and dummy sequences park on the reserved scratch
        page at offset page_size − 1 (the §6 pad invariant at page
        granularity).  ``commit`` records the written token ids and
        indexes newly-full pages for cross-session reuse."""
        px = self.packed_executor
        ar = self.arena
        ps = ar.page_size
        n = len(segments)
        b_max = px.stream_rows
        stream = packing.assemble_mixed_stream(
            segments, bucket, b_max, park_position=ar.max_len - 1,
            pad_token=self.ecfg.pad_token)
        sessions = [seg.session for seg in segments]

        ring = ar.ring_pages
        page_table = np.full((b_max, ar.max_pages_per_seq), ar.scratch,
                             np.int32)
        token_pages = np.full(bucket, ar.scratch, np.int32)
        token_offs = np.full(bucket, ps - 1, np.int32)
        state_map = np.full(b_max, ar.scratch, np.int32)
        cu = stream.cu_seqlens
        for i, seg in enumerate(segments):
            pages = ar.prepare_extend(seg.session, seg.length)
            page_table[i, :len(pages)] = pages
            pos = stream.positions[cu[i]:cu[i + 1]]
            pt = np.asarray(pages, np.int32)
            # ring tables (§12): position p lives on ring page
            # (p // ps) % n_ring — the host-side half of the §7 rolling
            # reconstruction; the kernel recovers kpos from the slot
            pidx = pos // ps if ring is None else (pos // ps) % ring
            token_pages[cu[i]:cu[i + 1]] = pt[pidx]
            token_offs[cu[i]:cu[i + 1]] = pos % ps
            if ar.state_slots:
                state_map[i] = ar.state_pages[seg.session]

        t0 = time.perf_counter()
        last, ids, new_arena = px.mixed_step_paged(
            self.params, jnp.asarray(stream.tokens),
            jnp.asarray(stream.positions), jnp.asarray(token_pages),
            jnp.asarray(token_offs), jnp.asarray(page_table),
            jnp.asarray(stream.cu_seqlens), jnp.asarray(stream.q_offsets),
            jnp.asarray(stream.kv_lengths), ar.arena,
            jnp.asarray(stream.last_idx), jnp.asarray(state_map),
            n_decode=stream.decode_tokens)
        toks, last_np = self._tokens_from_step(sessions, last, ids)
        elapsed = time.perf_counter() - t0
        px.note_padding(stream.total_tokens, bucket)
        ar.replace(new_arena)
        out: Dict[int, int] = {}
        for i, seg in enumerate(segments):
            ar.commit(seg.session, seg.tokens)
            if self.draft is not None:
                if seg.kind == "decode":
                    self.draft.observe(seg.session, [int(seg.tokens[0])])
                else:
                    self.draft.observe(seg.session,
                                       [int(t) for t in seg.tokens],
                                       prompt=True)
            out[seg.session] = int(toks[i])
            if last_np is not None:
                self.last_logits[seg.session] = last_np[i]
        if self.ecfg.measure:
            pre = [seg for seg in segments if seg.kind != "decode"]
            if pre:
                per = elapsed / len(pre)
                for seg in pre:
                    self.samples.append((per, float(seg.length),
                                         float(seg.history)))
        n_d = stream.decode_tokens
        return MixedStepResult(tokens=out, fused=True, bucket=bucket,
                               n_prefill=n - n_d, n_decode=n_d)

    # ------------------------------------------- speculative verify step
    def _run_spec(self, segments: List[packing.SegmentSpec], bucket: int,
                  n_drafts: Dict[int, int]) -> MixedStepResult:
        """Dispatch a mixed stream carrying ``verify`` segments
        (DESIGN.md §10).

        The SAME packed arena step runs — a verify segment is
        mechanically a length-(k+1) re-prefill — but every verify row's
        output is gathered back ((B, L) on-device argmax ids for fused
        greedy steps, (R,) fused-kernel samples, or (B, L, V) host rows)
        so acceptance can walk each session's drafts: row j scores the
        token AFTER inputs [pending, d_1..d_j], so accepted drafts and
        the corrective/bonus token commit together, 1..k+1 per session
        per dispatch.  Accepted prefixes stay in place; rejected tails
        roll back via ``arena.truncate`` (slot: length bookkeeping;
        paged: page release + radix de-index)."""
        px = self.packed_executor
        n = len(segments)
        L = 1 + self.spec_k
        b_max = px.stream_rows
        stream = packing.assemble_mixed_stream(
            segments, bucket, b_max, park_position=self.arena.max_len - 1,
            pad_token=self.ecfg.pad_token)
        sessions = [seg.session for seg in segments]
        # gather row i: a verify segment reads ALL its L rows back;
        # other kinds repeat their last row (their token is column 0)
        gather = np.zeros((b_max, L), np.int32)
        cu = stream.cu_seqlens
        for i, seg in enumerate(segments):
            if seg.kind == "verify":
                gather[i] = cu[i] + np.arange(L, dtype=np.int32)
            else:
                gather[i] = stream.last_idx[i]

        if self._paged:
            ar = self.arena
            ps = ar.page_size
            ring = ar.ring_pages
            page_table = np.full((b_max, ar.max_pages_per_seq), ar.scratch,
                                 np.int32)
            token_pages = np.full(bucket, ar.scratch, np.int32)
            token_offs = np.full(bucket, ps - 1, np.int32)
            state_map = np.full(b_max, ar.scratch, np.int32)
            for i, seg in enumerate(segments):
                pages = ar.prepare_extend(seg.session, seg.length)
                page_table[i, :len(pages)] = pages
                pos = stream.positions[cu[i]:cu[i + 1]]
                pt = np.asarray(pages, np.int32)
                pidx = pos // ps if ring is None else (pos // ps) % ring
                token_pages[cu[i]:cu[i + 1]] = pt[pidx]
                token_offs[cu[i]:cu[i + 1]] = pos % ps
                if ar.state_slots:
                    state_map[i] = ar.state_pages[seg.session]
            t0 = time.perf_counter()
            logits, ids, new_arena = px.verify_step_paged(
                self.params, jnp.asarray(stream.tokens),
                jnp.asarray(stream.positions), jnp.asarray(token_pages),
                jnp.asarray(token_offs), jnp.asarray(page_table),
                jnp.asarray(stream.cu_seqlens),
                jnp.asarray(stream.q_offsets),
                jnp.asarray(stream.kv_lengths), ar.arena,
                jnp.asarray(gather), jnp.asarray(state_map))
        else:
            slots = [self.arena.alloc(seg.session) for seg in segments]
            pad_slot = self.arena.scratch if self.arena.scratch is not None \
                else slots[0]
            all_slots = slots + [pad_slot] * (b_max - n)
            slot_map = np.asarray(all_slots, np.int32)
            seg_slots = slot_map[stream.seg_ids]
            t0 = time.perf_counter()
            logits, ids, new_arena = px.verify_step_arena(
                self.params, jnp.asarray(stream.tokens),
                jnp.asarray(stream.positions), jnp.asarray(seg_slots),
                jnp.asarray(slot_map), jnp.asarray(stream.cu_seqlens),
                jnp.asarray(stream.q_offsets),
                jnp.asarray(stream.kv_lengths), self.arena.arena,
                jnp.asarray(gather))

        # interleaved uniforms per verify session, drawn up front so the
        # fused kernel and the host oracle consume one rng stream layout
        draws = {seg.session: self._spec_draws(seg.session,
                                               n_drafts[seg.session])
                 for seg in segments if seg.kind == "verify"}
        all_greedy = all(s not in self.sampling for s in sessions)
        logits_np = None
        frows = None            # fused-kernel (tok, p_draft, alt) rows
        if all_greedy and not self.ecfg.keep_last_logits:
            self.fused_greedy_steps += 1
            ids_np = np.asarray(ids)
        elif (self.ecfg.fused_sampling and not self.ecfg.keep_last_logits
                and self._fused_bias_ok(sessions)):
            frows = self._fused_verify_rows(segments, n_drafts, logits, L,
                                            draws)
            ids_np = np.asarray(ids)
        else:
            logits_np = np.asarray(logits)
            self.logits_rows_shipped += int(logits_np.shape[0]
                                            * logits_np.shape[1])
            ids_np = np.asarray(ids)
        elapsed = time.perf_counter() - t0
        px.note_padding(stream.total_tokens, bucket)
        self.arena.replace(new_arena)

        committed: Dict[int, List[int]] = {}
        out: Dict[int, int] = {}
        n_verify = 0
        for i, seg in enumerate(segments):
            s = seg.session
            if seg.kind != "verify":
                if logits_np is not None:
                    row = logits_np[i, 0]
                    sp = self.sampling.get(s)
                    if sp is None or sp.is_default:
                        tok = int(np.argmax(row))
                    else:
                        tok = int(sampling_mod.sample_token(
                            row, sp, self._rngs.get(s)))
                    self.last_logits[s] = row
                elif frows is not None:
                    tok = int(frows[0][i * L])
                else:
                    tok = int(ids_np[i, 0])
                if self._paged:
                    self.arena.commit(s, [int(t) for t in seg.tokens])
                else:
                    self.arena.set_length(s, seg.history + seg.length)
                if self.draft is not None:
                    if seg.kind == "decode":
                        self.draft.observe(s, [int(seg.tokens[0])])
                    else:
                        self.draft.observe(s, [int(t) for t in seg.tokens],
                                           prompt=True)
                committed[s] = [tok]
                out[s] = tok
                continue
            # ---- verify segment: walk the drafts ----------------------
            m = n_drafts[s]
            d = [int(t) for t in seg.tokens[1:1 + m]]
            if logits_np is not None:
                tok_r, pd_r, alt_r = self._host_verify_row(
                    s, logits_np[i], d, draws[s][1])
            elif frows is not None:
                base = i * L
                tok_r = [int(frows[0][base + j]) for j in range(m + 1)]
                pd_r = [float(frows[1][base + j]) for j in range(m + 1)]
                alt_r = [int(frows[2][base + j]) for j in range(m + 1)]
            else:
                ids_row = ids_np[i]
                tok_r = [int(ids_row[j]) for j in range(m + 1)]
                pd_r = [1.0 if (j < m and tok_r[j] == d[j]) else 0.0
                        for j in range(m + 1)]
                alt_r = list(tok_r)
            u_acc = draws[s][0]
            emitted: List[int] = []
            for j in range(m):
                if u_acc[j] < pd_r[j]:
                    emitted.append(d[j])     # draft accepted
                else:
                    emitted.append(alt_r[j])  # corrective token; stop
                    break
            else:
                emitted.append(tok_r[m])     # all accepted → bonus token
            c = len(emitted)
            if self._paged:
                # the radix index must only ever see tokens whose KV is
                # REAL: pending + accepted drafts.  commit advances the
                # length to h + c; truncate then releases the
                # over-allocated tail pages the verify write touched
                self.arena.commit(s, [int(t) for t in seg.tokens[:c]])
                self.arena.truncate(s, seg.history + c)
            else:
                self.arena.set_length(s, seg.history + seg.length)
                self.arena.truncate(s, seg.history + c)
            if logits_np is not None:
                self.last_logits[s] = logits_np[i, c - 1]
            if self.draft is not None:
                self.draft.observe(s, [int(t) for t in seg.tokens[:c]])
            self.tokens_drafted += m
            self.tokens_accepted += c - 1
            self.spec_committed += c
            acc = self._spec_by_session.setdefault(s, [0, 0])
            acc[0] += m
            acc[1] += c - 1
            n_verify += 1
            committed[s] = emitted
            out[s] = emitted[-1]
        if self.ecfg.measure:
            pre = [seg for seg in segments
                   if seg.kind not in ("decode", "verify")]
            if pre:
                per = elapsed / len(pre)
                for seg in pre:
                    self.samples.append((per, float(seg.length),
                                         float(seg.history)))
        if n_verify:
            self.spec_dispatches += 1
        n_dec = sum(1 for seg in segments
                    if seg.kind in ("decode", "verify"))
        return MixedStepResult(tokens=out, fused=True, bucket=bucket,
                               n_prefill=n - n_dec, n_decode=n_dec,
                               committed=committed)

    def _host_verify_row(self, session: int, logits_row: np.ndarray,
                         d: List[int], u_samp: np.ndarray
                         ) -> Tuple[List[int], List[float], List[int]]:
        """Per verify row j, the triple the fused kernel returns —
        (plain sample, p(draft_j), residual resample with the draft
        zeroed) — computed by the host oracle sampler over the
        session's filtered distribution."""
        m = len(d)
        sp = self.sampling.get(session)
        tok_r: List[int] = []
        pd_r: List[float] = []
        alt_r: List[int] = []
        for j in range(m + 1):
            row = logits_row[j]
            if sp is None or sp.is_greedy:
                t = (int(sampling_mod.sample_token(row, sp))
                     if sp is not None else int(np.argmax(row)))
                tok_r.append(t)
                pd_r.append(1.0 if (j < m and t == d[j]) else 0.0)
                alt_r.append(t)
                continue
            probs = sampling_mod.filtered_probs(row, sp)
            v = probs.shape[0]
            u = float(u_samp[j])
            tok_r.append(sampling_mod.sample_from_probs(probs, u))
            in_range = j < m and 0 <= d[j] < v
            pd_r.append(float(probs[d[j]]) if in_range else 0.0)
            if in_range and probs[d[j]] < 1.0:
                resid = probs.copy()
                resid[d[j]] = 0.0
                alt_r.append(sampling_mod.sample_from_probs(
                    resid / resid.sum(), u))
            else:
                alt_r.append(tok_r[-1])
        return tok_r, pd_r, alt_r

    def _fused_verify_rows(self, segments: List[packing.SegmentSpec],
                           n_drafts: Dict[int, int], logits_dev, L: int,
                           draws: Dict[int, Tuple[np.ndarray, np.ndarray]]
                           ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
        """Run the fused sampling kernel over EVERY flat gathered row
        ((b_max·L, V) logits reshaped on device): each row's plain
        sample, p(draft) and residual resample come back as (R,)
        scalars — the full-vocab logits never cross to host even for
        sampled speculative sessions.  Non-verify segments use column 0
        (their repeated last row); pad rows run greedy into the void."""
        b_max = int(logits_dev.shape[0])
        r = b_max * L
        temp = np.zeros(r, np.float32)
        topk = np.zeros(r, np.int32)
        topp = np.ones(r, np.float32)
        u = np.zeros(r, np.float32)
        draft = np.full(r, -1, np.int32)
        bias_ids = np.full((r, kernel_ops.MAX_BIAS), -1, np.int32)
        bias_vals = np.zeros((r, kernel_ops.MAX_BIAS), np.float32)
        for i, seg in enumerate(segments):
            s = seg.session
            sp = self.sampling.get(s)
            verify = seg.kind == "verify"
            m = n_drafts.get(s, 0)
            for j in range(L if verify else 1):
                rr = i * L + j
                if sp is not None:
                    temp[rr] = max(float(sp.temperature), 0.0)
                    topk[rr] = int(sp.top_k or 0)
                    topp[rr] = (float(sp.top_p)
                                if sp.top_p is not None else 1.0)
                    for jj, (t, v) in enumerate(sp.logit_bias or ()):
                        bias_ids[rr, jj] = int(t)
                        bias_vals[rr, jj] = float(v)
                if verify:
                    if j <= m:
                        u[rr] = float(draws[s][1][j])
                    if j < m:
                        draft[rr] = int(seg.tokens[1 + j])
                elif sp is not None and not sp.is_greedy:
                    u[rr] = float(self._rngs[s].random())
        tok, p_d, alt = kernel_ops.fused_sample(
            jnp.reshape(logits_dev, (r, -1)), temp, topk, topp,
            bias_ids, bias_vals, u, draft)
        self.fused_sample_steps += 1
        return np.asarray(tok), np.asarray(p_d), np.asarray(alt)

    # ------------------------------------------------------ long prefill
    def prefill_long(self, session: int, token_list: np.ndarray) -> int:
        """Chunked long prefill (C_l per step).  Returns first token.

        Each chunk rides the packed token-bucket stream when available
        (a re-prefill segment whose history is the tokens already done),
        so a chunk can share a step with short requests and decode rows
        instead of running the dense path solo; off-ladder chunks fall
        back to the dense path inside ``prefill_packed``.

        CHUNK-LEVEL prefix matching (§12): on paged arenas the radix
        index is re-probed at every chunk boundary — a long prompt whose
        cached prefix extends past the first chunk adopts the already-
        indexed pages mid-request and only prefills the truly-cold
        tail, instead of re-prefilling tokens the pool already holds."""
        c = self.ecfg.chunk_tokens
        arr = np.asarray(token_list)
        tok = None
        i = 0
        while i < len(arr):
            if self._paged and self.arena.index is not None:
                adopted = self.arena.match_extend(
                    session, [int(t) for t in arr[i:]])
                i += adopted
            chunk = arr[i:i + c]
            res = self.prefill_packed([session], [np.asarray(chunk)])
            tok = res[session]
            i += len(chunk)
        return tok

    # ------------------------------------------------------------- decode
    def decode_batch(self, sessions: Sequence[int],
                     tokens: Sequence[int], steps: int = 1
                     ) -> Dict[int, List[int]]:
        """Decode ``steps`` tokens for each session (per-session sampling
        options apply; greedy argmax by default).

        Routed through the arena-resident bucketed path when available:
        the batch axis pads to a decode-ladder rung (compile cache keyed
        on the BUCKET, not the session count) and the KV arena is read
        in place — no whole-slot gather/scatter.  Falls back to the
        dense gather path for non-attention architectures or ticks that
        overflow the ladder."""
        self._check_alive()
        dx = self.decode_executor
        bucket = dx.bucket_for(len(sessions)) if dx is not None else None
        if bucket is None:
            if not self._dense_ok:
                # rolling arenas: ladder overflow splits into ladder-top
                # groups, every tick staying arena-resident
                m = dx.ladder.max_seqs
                out: Dict[int, List[int]] = {}
                sessions, tokens = list(sessions), list(tokens)
                for i in range(0, len(sessions), m):
                    out.update(self.decode_batch(sessions[i:i + m],
                                                 tokens[i:i + m], steps))
                return out
            return self._decode_batch_dense(
                sessions, tokens, steps,
                cause="requested" if dx is None else "forced")
        if self._paged:
            return self._decode_batch_paged(sessions, tokens, steps, bucket)

        n = len(sessions)
        slots = [self.arena.slot_of(s) for s in sessions]
        assert all(sl is not None for sl in slots), \
            f"decode session without a cache slot: {list(sessions)}"
        park = self.arena.max_len - 1
        cur = np.asarray(tokens, np.int32)
        out: Dict[int, List[int]] = {s: [] for s in sessions}
        for _ in range(steps):
            hists = [self.arena.length(s) for s in sessions]
            rows = packing.pad_decode_rows(
                slots, hists, cur, bucket, park_position=park,
                pad_token=self.ecfg.pad_token, pad_slot=self.arena.scratch)
            logits, ids, new_arena = dx.decode(
                self.params, jnp.asarray(rows.tokens),
                jnp.asarray(rows.slot_map), jnp.asarray(rows.write_pos),
                jnp.asarray(rows.kv_lengths), self.arena.arena)
            self.arena.replace(new_arena)
            dx.note_padding(n, bucket)
            if self.draft is not None:
                for i, s in enumerate(sessions):
                    self.draft.observe(s, [int(cur[i])])
            toks, logits_np = self._tokens_from_step(sessions, logits, ids)
            cur = toks.astype(np.int32)
            for i, s in enumerate(sessions):
                self.arena.set_length(s, hists[i] + 1)
                out[s].append(int(cur[i]))
                if logits_np is not None:
                    self.last_logits[s] = logits_np[i]
        return out

    def _decode_batch_paged(self, sessions: Sequence[int],
                            tokens: Sequence[int], steps: int,
                            bucket: int) -> Dict[int, List[int]]:
        """Paged decode tick (DESIGN.md §8): each row writes its new KV
        at (page, offset) from ``prepare_extend(1)`` — COW-copying a
        fork-shared boundary page first — and attends over its full
        logical context through its page-table row.  Ladder pad rows
        park on the scratch page at offset page_size − 1 and attend over
        one garbage key (output discarded)."""
        dx = self.decode_executor
        ar = self.arena
        ps = ar.page_size
        n = len(sessions)
        cur = np.asarray(tokens, np.int32)
        out: Dict[int, List[int]] = {s: [] for s in sessions}
        for _ in range(steps):
            hists = [ar.length(s) for s in sessions]
            assert all(h > 0 for h in hists), \
                f"paged decode on an empty session: {list(sessions)}"
            tok = np.full(bucket, self.ecfg.pad_token, np.int32)
            tok[:n] = cur
            ring = ar.ring_pages
            positions = np.full(bucket, ar.max_len - 1, np.int32)
            write_pages = np.full(bucket, ar.scratch, np.int32)
            write_offs = np.full(bucket, ps - 1, np.int32)
            page_table = np.full((bucket, ar.max_pages_per_seq),
                                 ar.scratch, np.int32)
            kv_lengths = np.ones(bucket, np.int32)
            state_map = np.full(bucket, ar.scratch, np.int32)
            for i, (s, h) in enumerate(zip(sessions, hists)):
                pages = ar.prepare_extend(s, 1)
                page_table[i, :len(pages)] = pages
                positions[i] = h
                pidx = h // ps if ring is None else (h // ps) % ring
                write_pages[i] = pages[pidx]
                write_offs[i] = h % ps
                kv_lengths[i] = h + 1
                if ar.state_slots:
                    state_map[i] = ar.state_pages[s]
            logits, ids, new_arena = dx.decode_paged(
                self.params, jnp.asarray(tok), jnp.asarray(positions),
                jnp.asarray(write_pages), jnp.asarray(write_offs),
                jnp.asarray(page_table), jnp.asarray(kv_lengths), ar.arena,
                jnp.asarray(state_map))
            ar.replace(new_arena)
            dx.note_padding(n, bucket)
            # the KV written this tick belongs to the INPUT token — the
            # radix index must see the ids whose keys occupy the pages
            for i, s in enumerate(sessions):
                ar.commit(s, [int(cur[i])])
                if self.draft is not None:
                    self.draft.observe(s, [int(cur[i])])
            toks, logits_np = self._tokens_from_step(sessions, logits, ids)
            cur = toks.astype(np.int32)
            for i, s in enumerate(sessions):
                out[s].append(int(cur[i]))
                if logits_np is not None:
                    self.last_logits[s] = logits_np[i]
        return out

    def _decode_batch_dense(self, sessions: Sequence[int],
                            tokens: Sequence[int], steps: int = 1,
                            cause: str = "requested"
                            ) -> Dict[int, List[int]]:
        """Dense fallback: gather whole arena slots, run the (B, 1)
        decode step, scatter the slots back — O(S_max) HBM per token
        and one compiled shape per session count.  ``cause`` records
        whether the config requested it or the ladder forced it."""
        assert self._dense_ok, \
            "dense gather path cannot serve a rolling windowed arena"
        n = len(sessions)
        slots = [self.arena.slot_of(s) for s in sessions]
        cur = np.asarray(tokens, np.int32)
        out: Dict[int, List[int]] = {s: [] for s in sessions}
        for _ in range(steps):
            self._note_dense("decode", cause)
            hists = [self.arena.length(s) for s in sessions]
            positions = np.asarray(hists, np.int32)[:, None]
            caches = self.arena.gather(slots)
            logits, new_caches = self.executor.decode(
                self.params, jnp.asarray(cur[:, None]),
                jnp.asarray(positions), caches)
            self.arena.scatter(slots, new_caches)
            self.executor.note_padding(n, n)
            logits_np = np.asarray(logits)
            if self.draft is not None:
                for i, s in enumerate(sessions):
                    self.draft.observe(s, [int(cur[i])])
            cur = self._sample_rows(sessions, logits_np).astype(np.int32)
            for i, s in enumerate(sessions):
                self.arena.set_length(s, hists[i] + 1)
                out[s].append(int(cur[i]))
                self.last_logits[s] = logits_np[i]
        return out

    # ------------------------------------------------------ runtime fit
    def fit_boundary(self) -> Optional[boundary_mod.TotalFit]:
        if len(self.samples) >= 8:
            self.fitted = boundary_mod.fit_total(self.samples)
        return self.fitted

    def classification_threshold(self, history: int = 0) -> float:
        if self.fitted is not None:
            return self.fitted.boundary(history)
        return boundary_mod.H200_QWEN32B.boundary(history)

    # ------------------------------------------------------------- stats
    def stats(self) -> Dict:
        out = {
            "graph_hit_rate": self.executor.hit_rate,
            "captured_shapes": len(self.executor.compile_times),
            "capture_seconds": self.executor.capture_cost(),
            "free_slots": (self.arena.free_pages if self._paged
                           else self.arena.free_slots),
            "fit_samples": len(self.samples),
            "useful_tokens": self.executor.useful_tokens,
            "padded_tokens": self.executor.padded_tokens,
            "padding_efficiency": self.executor.padding_efficiency,
            "hit_rate_by_kind": self.executor.hit_rate_by_kind,
            # whole-slot copy proof: the §5/§6 arena paths keep both at 0
            "arena_gathers": self.arena.gather_calls,
            "arena_scatters": self.arena.scatter_calls,
            # §8/§12 paged-arena proof counters (0 on slot arenas)
            "prefix_hit_tokens": getattr(self.arena, "prefix_hit_tokens", 0),
            "chunk_hit_tokens": getattr(self.arena, "chunk_hit_tokens", 0),
            "pages_cow_forked": getattr(self.arena, "pages_cow_forked", 0),
            "pages_evicted": getattr(self.arena, "pages_evicted", 0),
            # §12 host spill tier
            "pages_spilled": getattr(self.arena, "pages_spilled", 0),
            "pages_promoted": getattr(self.arena, "pages_promoted", 0),
            "host_pool_pages": getattr(self.arena, "host_pool_pages", 0),
            "host_pages_dropped": getattr(self.arena, "host_pages_dropped",
                                          0),
            # §12 hybrid boundary-state checkpoints + handoff dedupe
            "state_checkpoints": getattr(self.arena, "state_checkpoints", 0),
            "handoff_pages_deduped": getattr(self.arena,
                                             "handoff_pages_deduped", 0),
            # §9 arena→arena handoff proof counters
            "handoff_sessions": self.handoff_sessions,
            "handoff_tokens": self.handoff_tokens,
            "handoff_host_bytes": self.handoff_host_bytes,
        }
        if self._paged:
            out["free_pages"] = self.arena.free_pages
            out["radix_pages"] = (len(self.arena.index.pages())
                                  if self.arena.index is not None else 0)
        if self.decode_executor is not None:
            dx = self.decode_executor
            out.update({
                "decode_shapes": len(dx.compile_times),
                "decode_dispatches": dx.dispatches,
                "decode_hit_rate": dx.hit_rate,
                "decode_useful_rows": dx.useful_tokens,
                "decode_pad_rows": dx.padded_tokens,
                "decode_padding_efficiency": dx.padding_efficiency,
            })
        if self.packed_executor is not None:
            px = self.packed_executor
            out.update({
                "packed_shapes": len(px.compile_times),
                "packed_hit_rate": px.hit_rate,
                "packed_useful_tokens": px.useful_tokens,
                "packed_padded_tokens": px.padded_tokens,
                "packed_padding_efficiency": px.padding_efficiency,
                "packed_dispatches": px.dispatches,
                "packed_shapes_by_kind": px.shapes_by_kind(),
                "mixed_steps": px.mixed_steps,
                "decode_tokens_fused": px.decode_tokens_fused,
            })
        out["dense_dispatches"] = self.executor.dispatches
        # per-kind dense causes: "requested" = the config pinned the
        # dense baseline (explicit (L, B) bucket, packed/arena paths
        # off); "forced" = a capability/ladder miss pushed an otherwise
        # packed step onto the dense path.  Hit-rate readers use this to
        # separate baseline measurement runs from real fallbacks.
        by_cause: Dict[str, Dict[str, int]] = {}
        for (kind, cause), count in self.dense_causes.items():
            by_cause.setdefault(kind, {}).setdefault(cause, 0)
            by_cause[kind][cause] += count
        out["dense_dispatches_by_cause"] = by_cause
        out["fused_greedy_steps"] = self.fused_greedy_steps
        out["fused_sample_steps"] = self.fused_sample_steps
        out["logits_rows_shipped"] = self.logits_rows_shipped
        # §10 speculative decoding counters: drafted vs accepted tokens,
        # verify dispatches, total commits, and per-session acceptance
        out["tokens_drafted"] = self.tokens_drafted
        out["tokens_accepted"] = self.tokens_accepted
        out["spec_dispatches"] = self.spec_dispatches
        out["spec_committed"] = self.spec_committed
        out["spec_acceptance"] = (self.tokens_accepted
                                  / max(1, self.tokens_drafted))
        out["spec_tokens_per_dispatch"] = (self.spec_committed
                                           / max(1, self.spec_dispatches))
        out["spec_by_session"] = {
            s: {"drafted": v[0], "accepted": v[1],
                "acceptance": v[1] / max(1, v[0])}
            for s, v in self._spec_by_session.items()}
        return out
