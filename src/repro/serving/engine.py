"""Single-instance serving engine: real JAX execution of the LAPS design.

Composes the substrate — KVArena (slots) + BucketExecutor (captured
shapes) + models.transformer — under the paper's scheduling primitives:

  * short-prefill batches padded to the (L, B) bucket grid, executed as
    one captured step (§3.1);
  * re-prefill: new tokens written on top of the session's cached
    history (positions carry the offset);
  * long prefills advanced in fixed chunks C_l (§3.2);
  * decode steps batched across sessions;
  * runtime (T, L, H) samples feed core.boundary.fit — the engine
    re-estimates L_m live, exactly the paper's "fitting at runtime".

Runs identically with smoke configs on this CPU container and (with a
mesh + serve sharding rules) on a TPU pod slice.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import boundary as boundary_mod
from repro.core.buckets import DEFAULT_TOKEN_BUCKETS, BucketGrid
from repro.models import transformer as tr
from repro.models.config import ModelConfig
from repro.serving.executor import BucketExecutor, PackedBucketExecutor
from repro.serving.kvcache import KVArena


@dataclasses.dataclass
class EngineConfig:
    num_slots: int = 16
    max_len: int = 256
    chunk_tokens: int = 64           # C_l
    grid_lengths: Tuple[int, ...] = (8, 16, 32, 64)
    grid_depths: Tuple[int, ...] = (1, 2, 4, 8)
    pad_token: int = 0
    measure: bool = True             # collect boundary-fit samples
    packed: bool = False             # padding-free packed prefill path
    token_buckets: Tuple[int, ...] = DEFAULT_TOKEN_BUCKETS
    packed_max_seqs: Optional[int] = None  # None → min(num_slots, 16)


class Engine:
    def __init__(self, cfg: ModelConfig, params, ecfg: Optional[EngineConfig] = None):
        self.cfg = cfg
        self.params = params
        self.ecfg = ecfg or EngineConfig()
        self.arena = KVArena(cfg, self.ecfg.num_slots, self.ecfg.max_len)
        self.executor = BucketExecutor(cfg)
        self.packed_executor: Optional[PackedBucketExecutor] = None
        if self.ecfg.packed and tr.supports_packed(cfg):
            max_seqs = self.ecfg.packed_max_seqs or min(self.ecfg.num_slots,
                                                        16)
            self.packed_executor = PackedBucketExecutor(
                cfg, token_buckets=self.ecfg.token_buckets,
                max_seqs=min(max_seqs, self.ecfg.num_slots))
        self.grid = BucketGrid(self.ecfg.grid_lengths, self.ecfg.grid_depths,
                               mem_budget_tokens=self.ecfg.num_slots
                               * self.ecfg.max_len)
        self.samples: List[Tuple[float, float, float]] = []  # (T, L, H)
        self.fitted: Optional[boundary_mod.TotalFit] = None

    # ------------------------------------------------------------ session
    def open_session(self, session: int) -> None:
        self.arena.alloc(session)

    def close_session(self, session: int) -> None:
        self.arena.free(session)

    def history(self, session: int) -> int:
        return self.arena.length(session)

    # ------------------------------------------------- bucketized prefill
    def prefill_batch(self, sessions: Sequence[int],
                      token_lists: Sequence[np.ndarray],
                      bucket: Optional[Tuple[int, int]] = None
                      ) -> Dict[int, int]:
        """Short-prefill / re-prefill batch.  Pads to ``bucket`` (L, B)
        when given (graph path), else to max length (standard path).
        Returns {session: first_sampled_token}."""
        assert len(sessions) == len(token_lists)
        n = len(sessions)
        lens = [len(t) for t in token_lists]
        if bucket is not None:
            pad_l, pad_b = bucket
            assert pad_l >= max(lens) and pad_b >= n, (bucket, lens, n)
        else:
            pad_l, pad_b = max(lens), n

        slots, hists = [], []
        for s in sessions:
            slots.append(self.arena.alloc(s))
            hists.append(self.arena.length(s))
        # depth padding reuses slot 0's cache row for dummy rows
        all_slots = slots + [slots[0]] * (pad_b - n)

        tokens = np.full((pad_b, pad_l), self.ecfg.pad_token, np.int32)
        positions = np.zeros((pad_b, pad_l), np.int32)
        sample_idx = np.zeros((pad_b,), np.int32)
        park = self.arena.max_len - 1
        for i, (tl, h) in enumerate(zip(token_lists, hists)):
            tokens[i, :len(tl)] = tl
            pos = h + np.arange(pad_l)
            pos[len(tl):] = park                    # junk KV → parking slot
            positions[i] = pos
            sample_idx[i] = len(tl) - 1
        positions[n:] = park                        # dummy depth rows

        caches = self.arena.gather(all_slots)
        t0 = time.perf_counter()
        last, new_caches = self.executor.prefill(
            self.params, jnp.asarray(tokens), jnp.asarray(positions),
            caches, jnp.asarray(sample_idx))
        toks = np.asarray(jnp.argmax(last, axis=-1))
        elapsed = time.perf_counter() - t0
        self.executor.note_padding(sum(lens), pad_l * pad_b)
        # write back only the real rows
        self.arena.scatter(slots, jax.tree.map(
            lambda a: a[:, :n], new_caches))
        out: Dict[int, int] = {}
        for i, s in enumerate(sessions):
            self.arena.set_length(s, hists[i] + lens[i])
            out[s] = int(toks[i])
        if self.ecfg.measure and n:
            per = elapsed / n
            for l, h in zip(lens, hists):
                self.samples.append((per, float(l), float(h)))
        return out

    # ------------------------------------------------------ packed prefill
    def prefill_packed(self, sessions: Sequence[int],
                       token_lists: Sequence[np.ndarray],
                       token_bucket: Optional[int] = None
                       ) -> Dict[int, int]:
        """Padding-free packed prefill / re-prefill.

        Every request's new tokens are concatenated into ONE flat stream
        bucketed on TOTAL tokens; per-sequence KV is scatter-written to
        the arena rows.  The only padding is the bucket tail, and the
        compiled-shape space grows with |token_buckets| instead of the
        dense grid's |L| × |B|.  Falls back to the dense path when the
        packed executor is absent or the batch is off-ladder.
        Returns {session: first_sampled_token}."""
        assert len(sessions) == len(token_lists)
        n = len(sessions)
        lens = [len(t) for t in token_lists]
        total = sum(lens)
        px = self.packed_executor
        if px is None or n > px.max_seqs:
            return self.prefill_batch(sessions, token_lists)
        bucket = token_bucket or px.bucket_for(total)
        if bucket is None or bucket < total:
            return self.prefill_batch(sessions, token_lists)

        slots, hists = [], []
        for s in sessions:
            slots.append(self.arena.alloc(s))
            hists.append(self.arena.length(s))
        b_max = px.max_seqs
        # dummy cache rows (and tail-padding KV writes) reuse slot 0
        all_slots = slots + [slots[0]] * (b_max - n)
        park = self.arena.max_len - 1

        tokens = np.full(bucket, self.ecfg.pad_token, np.int32)
        positions = np.full(bucket, park, np.int32)       # tail → parking
        seg_ids = np.full(bucket, n if n < b_max else 0, np.int32)
        cu = np.full(b_max + 1, total, np.int32)
        cu[0] = 0
        off = np.zeros(b_max, np.int32)
        kvl = np.zeros(b_max, np.int32)
        last_idx = np.zeros(b_max, np.int32)
        o = 0
        for i, (tl, h) in enumerate(zip(token_lists, hists)):
            l = len(tl)
            tokens[o:o + l] = tl
            positions[o:o + l] = h + np.arange(l)
            seg_ids[o:o + l] = i
            cu[i + 1] = o + l
            off[i] = h
            kvl[i] = h + l
            last_idx[i] = o + l - 1
            o += l

        caches = self.arena.gather(all_slots)
        t0 = time.perf_counter()
        last, new_caches = px.prefill_packed(
            self.params, jnp.asarray(tokens), jnp.asarray(positions),
            jnp.asarray(seg_ids), jnp.asarray(cu), jnp.asarray(off),
            jnp.asarray(kvl), caches, jnp.asarray(last_idx))
        toks = np.asarray(jnp.argmax(last, axis=-1))
        elapsed = time.perf_counter() - t0
        px.note_padding(total, bucket)
        self.arena.scatter(slots, jax.tree.map(
            lambda a: a[:, :n], new_caches))
        out: Dict[int, int] = {}
        for i, s in enumerate(sessions):
            self.arena.set_length(s, hists[i] + lens[i])
            out[s] = int(toks[i])
        if self.ecfg.measure and n:
            per = elapsed / n
            for l, h in zip(lens, hists):
                self.samples.append((per, float(l), float(h)))
        return out

    # ------------------------------------------------------ long prefill
    def prefill_long(self, session: int, token_list: np.ndarray) -> int:
        """Chunked long prefill (C_l per step).  Returns first token."""
        c = self.ecfg.chunk_tokens
        tok = None
        for start in range(0, len(token_list), c):
            chunk = token_list[start:start + c]
            res = self.prefill_batch([session], [np.asarray(chunk)])
            tok = res[session]
        return tok

    # ------------------------------------------------------------- decode
    def decode_batch(self, sessions: Sequence[int],
                     tokens: Sequence[int], steps: int = 1
                     ) -> Dict[int, List[int]]:
        """Greedy decode ``steps`` tokens for each session."""
        n = len(sessions)
        slots = [self.arena.slot_of(s) for s in sessions]
        cur = np.asarray(tokens, np.int32)
        out: Dict[int, List[int]] = {s: [] for s in sessions}
        for _ in range(steps):
            hists = [self.arena.length(s) for s in sessions]
            positions = np.asarray(hists, np.int32)[:, None]
            caches = self.arena.gather(slots)
            logits, new_caches = self.executor.decode(
                self.params, jnp.asarray(cur[:, None]),
                jnp.asarray(positions), caches)
            self.arena.scatter(slots, new_caches)
            cur = np.asarray(jnp.argmax(logits, axis=-1), np.int32)
            for i, s in enumerate(sessions):
                self.arena.set_length(s, hists[i] + 1)
                out[s].append(int(cur[i]))
        return out

    # ------------------------------------------------------ runtime fit
    def fit_boundary(self) -> Optional[boundary_mod.TotalFit]:
        if len(self.samples) >= 8:
            self.fitted = boundary_mod.fit_total(self.samples)
        return self.fitted

    def classification_threshold(self, history: int = 0) -> float:
        if self.fitted is not None:
            return self.fitted.boundary(history)
        return boundary_mod.H200_QWEN32B.boundary(history)

    # ------------------------------------------------------------- stats
    def stats(self) -> Dict:
        out = {
            "graph_hit_rate": self.executor.hit_rate,
            "captured_shapes": len(self.executor.compile_times),
            "capture_seconds": self.executor.capture_cost(),
            "free_slots": self.arena.free_slots,
            "fit_samples": len(self.samples),
            "useful_tokens": self.executor.useful_tokens,
            "padded_tokens": self.executor.padded_tokens,
            "padding_efficiency": self.executor.padding_efficiency,
        }
        if self.packed_executor is not None:
            px = self.packed_executor
            out.update({
                "packed_shapes": len(px.compile_times),
                "packed_hit_rate": px.hit_rate,
                "packed_useful_tokens": px.useful_tokens,
                "packed_padded_tokens": px.padded_tokens,
                "packed_padding_efficiency": px.padding_efficiency,
            })
        return out
