"""Mixed-stream assembly for continuous batching (DESIGN.md §4).

One scheduler tick produces ONE flat (T,) token stream holding three
segment kinds side by side:

  * ``prefill`` — a short request's new tokens (history 0 or a
    re-prefill offset);
  * ``chunk``   — one C_l slice of a long prefill (history = tokens
    already prefilled), so a long chunk shares the step with shorts
    instead of running the dense path solo;
  * ``decode``  — ONE token of an in-flight session (history = its
    full cached context), attending over ``history + 1`` keys through
    the ragged kernel's offset prefetch;
  * ``verify``  — a speculative session's ``[pending, d_1..d_{L-1}]``
    draft segment (DESIGN.md §10): a length-L re-prefill whose logits
    are ALL gathered back so acceptance can walk the drafts.

Mechanically a decode segment is a length-1 re-prefill, so the packed
executor serves every mix with the SAME compiled shape — prefill and
decode share one dispatch, which is the continuous-batching point.

This module is pure numpy (no JAX) so the assembly invariants — bucket
never exceeded, segments never split, per-session token order kept,
``cu_seqlens`` consistent — are property-testable in microseconds.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence

import numpy as np

from repro.core.buckets import fit_decodes  # noqa: F401
# fit_decodes lives in core.buckets (pure ladder arithmetic shared with
# the JAX-free simulator) and is re-exported here for the serving side

SEGMENT_KINDS = ("prefill", "chunk", "decode", "verify")


@dataclasses.dataclass(frozen=True)
class SegmentSpec:
    """One sequence's slice of the mixed stream."""
    session: int
    tokens: np.ndarray        # (len,) int32 new tokens (decode: length 1)
    history: int              # cached KV tokens before this step
    kind: str = "prefill"     # prefill | chunk | decode | verify

    def __post_init__(self):
        assert self.kind in SEGMENT_KINDS, self.kind
        assert len(self.tokens) >= 1, "empty segment"
        if self.kind == "decode":
            assert len(self.tokens) == 1, "decode segments carry ONE token"
        # a "verify" segment is [pending token, draft_1..draft_{L-1}] —
        # mechanically a length-L re-prefill whose logits are ALL read
        # back (speculative verification, DESIGN.md §10); any length ≥ 1

    @property
    def length(self) -> int:
        return len(self.tokens)


@dataclasses.dataclass
class MixedStream:
    """The assembled flat stream — exactly the packed executor's inputs.

    Row layout per DESIGN.md §3: sequence i owns rows
    [cu_seqlens[i], cu_seqlens[i+1]); rows past cu_seqlens[n_seqs] are
    bucket tail (parked positions, duplicate cache row).  All arrays are
    statically shaped on (bucket, b_max) so every mix of segment kinds
    reuses one compiled executable.
    """
    tokens: np.ndarray        # (bucket,) int32
    positions: np.ndarray     # (bucket,) int32 absolute positions
    seg_ids: np.ndarray       # (bucket,) int32 local cache-row index
    cu_seqlens: np.ndarray    # (b_max + 1,) int32
    q_offsets: np.ndarray     # (b_max,) int32 history offsets
    kv_lengths: np.ndarray    # (b_max,) int32 valid cache entries
    last_idx: np.ndarray      # (b_max,) int32 flat index of final token
    segments: List[SegmentSpec]
    bucket: int

    @property
    def n_seqs(self) -> int:
        return len(self.segments)

    @property
    def total_tokens(self) -> int:
        return int(sum(s.length for s in self.segments))

    @property
    def decode_tokens(self) -> int:
        return sum(s.length for s in self.segments if s.kind == "decode")

    @property
    def prefill_tokens(self) -> int:
        return sum(s.length for s in self.segments
                   if s.kind not in ("decode", "verify"))

    @property
    def verify_tokens(self) -> int:
        return sum(s.length for s in self.segments if s.kind == "verify")

    @property
    def tail_tokens(self) -> int:
        return self.bucket - self.total_tokens


def assemble_mixed_stream(segments: Sequence[SegmentSpec], bucket: int,
                          b_max: int, park_position: int,
                          pad_token: int = 0) -> MixedStream:
    """Concatenate segments into one statically shaped packed stream.

    park_position: the arena's junk KV slot (max_len - 1) — tail rows
    and the dummy-sequence rows write there so padding never corrupts a
    live cache entry.
    """
    n = len(segments)
    assert 0 < n <= b_max, (n, b_max)
    total = sum(s.length for s in segments)
    assert total <= bucket, (total, bucket)

    tokens = np.full(bucket, pad_token, np.int32)
    positions = np.full(bucket, park_position, np.int32)
    # tail rows write their junk KV into a DUPLICATE cache row (index n
    # when a dummy row exists, else row 0) at the parked position
    seg_ids = np.full(bucket, n if n < b_max else 0, np.int32)
    cu = np.full(b_max + 1, total, np.int32)
    cu[0] = 0
    off = np.zeros(b_max, np.int32)
    kvl = np.zeros(b_max, np.int32)
    last_idx = np.zeros(b_max, np.int32)

    o = 0
    for i, seg in enumerate(segments):
        l = seg.length
        tokens[o:o + l] = seg.tokens
        positions[o:o + l] = seg.history + np.arange(l)
        seg_ids[o:o + l] = i
        cu[i + 1] = o + l
        off[i] = seg.history
        kvl[i] = seg.history + l
        last_idx[i] = o + l - 1
        o += l

    return MixedStream(tokens=tokens, positions=positions, seg_ids=seg_ids,
                       cu_seqlens=cu, q_offsets=off, kv_lengths=kvl,
                       last_idx=last_idx, segments=list(segments),
                       bucket=bucket)


@dataclasses.dataclass
class DecodeRows:
    """One arena-resident decode tick's padded row arrays — exactly the
    DecodeBucketExecutor's inputs.  Rows [0, n) are the live sessions in
    submission order; rows [n, bucket) are ladder padding that writes
    junk KV at the park position of row 0's slot and attends over one
    garbage key (output discarded)."""
    tokens: np.ndarray        # (bucket,) int32 last sampled token per row
    slot_map: np.ndarray      # (bucket,) int32 arena slot per row
    write_pos: np.ndarray     # (bucket,) int32 new-KV position (pad: park)
    kv_lengths: np.ndarray    # (bucket,) int32 valid entries (pad: 1)
    n: int                    # live rows
    bucket: int

    @property
    def pad_rows(self) -> int:
        return self.bucket - self.n


def pad_decode_rows(slots: Sequence[int], histories: Sequence[int],
                    tokens: Sequence[int], bucket: int,
                    park_position: int, pad_token: int = 0,
                    pad_slot: Optional[int] = None) -> DecodeRows:
    """Pad one decode tick's rows to the ladder ``bucket``.

    The live rows keep their submission order and exact values — the
    bucket choice never drops or reorders sessions (property-tested).
    Pad rows reuse slot 0's arena row but write at ``park_position``
    (the arena's designated junk slot), so padding never corrupts a
    live cache entry.  ``pad_slot`` overrides the slot pad rows target:
    rolling windowed arenas and SSM state arenas (DESIGN.md §7) pass
    their dedicated scratch slot — a rolling slot has no spare park row
    and recurrent state has no park position, so aliasing a live slot
    is not an option there.
    """
    n = len(slots)
    assert 0 < n <= bucket, (n, bucket)
    assert len(histories) == n and len(tokens) == n
    tok = np.full(bucket, pad_token, np.int32)
    tok[:n] = tokens
    sm = np.full(bucket, slots[0] if pad_slot is None else pad_slot,
                 np.int32)
    sm[:n] = slots
    wp = np.full(bucket, park_position, np.int32)
    wp[:n] = histories
    kl = np.ones(bucket, np.int32)
    kl[:n] = np.asarray(histories, np.int32) + 1
    return DecodeRows(tokens=tok, slot_map=sm, write_pos=wp, kv_lengths=kl,
                      n=n, bucket=bucket)


__all__ = ["SegmentSpec", "MixedStream", "assemble_mixed_stream",
           "DecodeRows", "pad_decode_rows", "fit_decodes", "SEGMENT_KINDS"]
