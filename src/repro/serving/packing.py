"""Mixed-stream assembly for continuous batching (DESIGN.md §4).

One scheduler tick produces ONE flat (T,) token stream holding three
segment kinds side by side:

  * ``prefill`` — a short request's new tokens (history 0 or a
    re-prefill offset);
  * ``chunk``   — one C_l slice of a long prefill (history = tokens
    already prefilled), so a long chunk shares the step with shorts
    instead of running the dense path solo;
  * ``decode``  — ONE token of an in-flight session (history = its
    full cached context), attending over ``history + 1`` keys through
    the ragged kernel's offset prefetch.

Mechanically a decode segment is a length-1 re-prefill, so the packed
executor serves every mix with the SAME compiled shape — prefill and
decode share one dispatch, which is the continuous-batching point.

This module is pure numpy (no JAX) so the assembly invariants — bucket
never exceeded, segments never split, per-session token order kept,
``cu_seqlens`` consistent — are property-testable in microseconds.
"""
from __future__ import annotations

import dataclasses
from typing import List, Sequence

import numpy as np

from repro.core.buckets import fit_decodes  # noqa: F401
# fit_decodes lives in core.buckets (pure ladder arithmetic shared with
# the JAX-free simulator) and is re-exported here for the serving side

SEGMENT_KINDS = ("prefill", "chunk", "decode")


@dataclasses.dataclass(frozen=True)
class SegmentSpec:
    """One sequence's slice of the mixed stream."""
    session: int
    tokens: np.ndarray        # (len,) int32 new tokens (decode: length 1)
    history: int              # cached KV tokens before this step
    kind: str = "prefill"     # prefill | chunk | decode

    def __post_init__(self):
        assert self.kind in SEGMENT_KINDS, self.kind
        assert len(self.tokens) >= 1, "empty segment"
        if self.kind == "decode":
            assert len(self.tokens) == 1, "decode segments carry ONE token"

    @property
    def length(self) -> int:
        return len(self.tokens)


@dataclasses.dataclass
class MixedStream:
    """The assembled flat stream — exactly the packed executor's inputs.

    Row layout per DESIGN.md §3: sequence i owns rows
    [cu_seqlens[i], cu_seqlens[i+1]); rows past cu_seqlens[n_seqs] are
    bucket tail (parked positions, duplicate cache row).  All arrays are
    statically shaped on (bucket, b_max) so every mix of segment kinds
    reuses one compiled executable.
    """
    tokens: np.ndarray        # (bucket,) int32
    positions: np.ndarray     # (bucket,) int32 absolute positions
    seg_ids: np.ndarray       # (bucket,) int32 local cache-row index
    cu_seqlens: np.ndarray    # (b_max + 1,) int32
    q_offsets: np.ndarray     # (b_max,) int32 history offsets
    kv_lengths: np.ndarray    # (b_max,) int32 valid cache entries
    last_idx: np.ndarray      # (b_max,) int32 flat index of final token
    segments: List[SegmentSpec]
    bucket: int

    @property
    def n_seqs(self) -> int:
        return len(self.segments)

    @property
    def total_tokens(self) -> int:
        return int(sum(s.length for s in self.segments))

    @property
    def decode_tokens(self) -> int:
        return sum(s.length for s in self.segments if s.kind == "decode")

    @property
    def prefill_tokens(self) -> int:
        return sum(s.length for s in self.segments if s.kind != "decode")

    @property
    def tail_tokens(self) -> int:
        return self.bucket - self.total_tokens


def assemble_mixed_stream(segments: Sequence[SegmentSpec], bucket: int,
                          b_max: int, park_position: int,
                          pad_token: int = 0) -> MixedStream:
    """Concatenate segments into one statically shaped packed stream.

    park_position: the arena's junk KV slot (max_len - 1) — tail rows
    and the dummy-sequence rows write there so padding never corrupts a
    live cache entry.
    """
    n = len(segments)
    assert 0 < n <= b_max, (n, b_max)
    total = sum(s.length for s in segments)
    assert total <= bucket, (total, bucket)

    tokens = np.full(bucket, pad_token, np.int32)
    positions = np.full(bucket, park_position, np.int32)
    # tail rows write their junk KV into a DUPLICATE cache row (index n
    # when a dummy row exists, else row 0) at the parked position
    seg_ids = np.full(bucket, n if n < b_max else 0, np.int32)
    cu = np.full(b_max + 1, total, np.int32)
    cu[0] = 0
    off = np.zeros(b_max, np.int32)
    kvl = np.zeros(b_max, np.int32)
    last_idx = np.zeros(b_max, np.int32)

    o = 0
    for i, seg in enumerate(segments):
        l = seg.length
        tokens[o:o + l] = seg.tokens
        positions[o:o + l] = seg.history + np.arange(l)
        seg_ids[o:o + l] = i
        cu[i + 1] = o + l
        off[i] = seg.history
        kvl[i] = seg.history + l
        last_idx[i] = o + l - 1
        o += l

    return MixedStream(tokens=tokens, positions=positions, seg_ids=seg_ids,
                       cu_seqlens=cu, q_offsets=off, kv_lengths=kvl,
                       last_idx=last_idx, segments=list(segments),
                       bucket=bucket)


__all__ = ["SegmentSpec", "MixedStream", "assemble_mixed_stream",
           "fit_decodes", "SEGMENT_KINDS"]
