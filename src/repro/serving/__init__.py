from repro.serving.kvcache import KVArena  # noqa: F401
from repro.serving.packing import (SegmentSpec, MixedStream,  # noqa: F401
                                   assemble_mixed_stream, fit_decodes)
from repro.serving.executor import (BucketExecutor,  # noqa: F401
                                    PackedBucketExecutor)
from repro.serving.engine import (Engine, EngineConfig,  # noqa: F401
                                  MixedStepResult)
