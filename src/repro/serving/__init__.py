from repro.serving.kvcache import KVArena  # noqa: F401
from repro.serving.packing import (SegmentSpec, MixedStream,  # noqa: F401
                                   assemble_mixed_stream, fit_decodes,
                                   DecodeRows, pad_decode_rows)
from repro.serving.executor import (BucketExecutor,  # noqa: F401
                                    DecodeBucketExecutor,
                                    PackedBucketExecutor)
from repro.serving.sampling import SamplingParams, GREEDY  # noqa: F401
from repro.serving.draft import (DraftProposer, NGramDraft,  # noqa: F401
                                 ScriptedDraft, SmallModelDraft)
from repro.serving.engine import (Engine, EngineConfig,  # noqa: F401
                                  MixedStepResult, SessionExport)
from repro.serving.loop import PendingRequest, ServeLoop  # noqa: F401
from repro.serving.cluster import ServeCluster  # noqa: F401
