"""Slot-arena KV/state cache.

TPU-friendly dense layout: one preallocated arena per layer-pattern
position with a leading slot dimension —

  attention:  k/v  (G, slots, S_max, Hkv, D)
  mamba:      ssm  (G, slots, NH, HD, DS) fp32, conv (G, slots, W-1, C)

Sessions own slots; a prefill batch is assembled by gathering its slot
rows and written back by scatter.  Statically shaped throughout (S_max
fixed), so every bucketized step compiles once — the paged-KV pointer
chasing of GPU systems is replaced by whole-slot gathers, which XLA
turns into efficient dynamic-slice DMAs.

Decode-only ticks skip even the gather: the arena-resident decode path
(DESIGN.md §5) hands the arena pytree itself to the executor, the
kernel indexes the slot axis through a scalar-prefetched slot map, and
:meth:`KVArena.replace` swaps the (donated, in-place) result back —
per-token HBM traffic is O(cached_len), not O(S_max) slot copies.
Packed prefill / mixed / chunk ticks do the same (DESIGN.md §6): the
whole-slot gather/scatter survives only as the dense fallback for
SSM/SWA architectures and off-ladder batches, and the
``gather_calls`` / ``scatter_calls`` counters prove the hot paths
never touch it.
"""
from __future__ import annotations

from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp

from repro.models import transformer as tr
from repro.models.config import ModelConfig


class KVArena:
    def __init__(self, cfg: ModelConfig, num_slots: int, max_len: int,
                 dtype=None, swa_depth: Optional[int] = None,
                 scratch_slot: bool = False):
        self.cfg = cfg
        self.num_slots = num_slots
        self.max_len = max_len
        # swa_depth: attention-slot depth for sliding-window configs —
        # the §7 rolling arena passes window + margin; the dense
        # baseline passes max_len (window masked, not rolled); None
        # keeps the legacy min(max_len, window) clamp
        self.swa_depth = swa_depth
        # scratch_slot: allocate ONE extra slot that sessions can never
        # claim — rolling KV slots have no spare park row and SSM state
        # has no park position, so pad rows/segments target this slot
        # instead of aliasing a live one (DESIGN.md §7)
        self.scratch: Optional[int] = num_slots if scratch_slot else None
        alloc_slots = num_slots + (1 if scratch_slot else 0)
        # build per-slot cache then add the slot axis via the batch dim:
        # init_cache already produces (G, B, ...) — treat B as slots
        self.arena = tr.init_cache(cfg, alloc_slots, max_len, dtype,
                                   swa_depth=swa_depth)
        self._free: List[int] = list(range(num_slots))
        self._session_slot: Dict[int, int] = {}
        self.lengths: Dict[int, int] = {}          # session -> tokens cached
        # whole-slot copy counters: the arena-resident paths (decode §5,
        # packed prefill §6/§7) must keep these at ZERO on their hot
        # ticks — the acceptance proof that no O(S_max) round-trips
        # survive
        self.gather_calls = 0
        self.scatter_calls = 0

    # ----------------------------------------------------------- slots
    def alloc(self, session: int) -> int:
        if session in self._session_slot:
            return self._session_slot[session]
        if not self._free:
            raise RuntimeError("KV arena exhausted")
        slot = self._free.pop()
        self._session_slot[session] = slot
        self.lengths[session] = 0
        return slot

    def free(self, session: int) -> None:
        slot = self._session_slot.pop(session, None)
        if slot is not None:
            self._free.append(slot)
            self.lengths.pop(session, None)

    def slot_of(self, session: int) -> Optional[int]:
        return self._session_slot.get(session)

    def length(self, session: int) -> int:
        return self.lengths.get(session, 0)

    def set_length(self, session: int, n: int) -> None:
        if n > self.max_len - 2:
            raise RuntimeError(
                f"session {session} overflows arena ({n} > {self.max_len - 2})")
        self.lengths[session] = n

    @property
    def free_slots(self) -> int:
        return len(self._free)

    # ---------------------------------------------------------- gather
    def gather(self, slots: List[int]) -> Any:
        self.gather_calls += 1
        idx = jnp.asarray(slots, jnp.int32)
        return jax.tree.map(lambda a: jnp.take(a, idx, axis=1), self.arena)

    def scatter(self, slots: List[int], batch_cache: Any) -> None:
        self.scatter_calls += 1
        idx = jnp.asarray(slots, jnp.int32)
        self.arena = jax.tree.map(
            lambda a, b: a.at[:, idx].set(b.astype(a.dtype)),
            self.arena, batch_cache)

    # ------------------------------------------------------- in-place use
    def replace(self, new_arena: Any) -> None:
        """Swap in the arena pytree returned by an arena-resident step.

        The arena-resident decode path reads the arena IN PLACE (the
        kernel indexes the slot axis through a slot map) and returns the
        updated buffers — under donation the same memory, just a new
        handle.  No gather/scatter bookkeeping happens here; lengths are
        advanced by the engine per session."""
        self.arena = new_arena
