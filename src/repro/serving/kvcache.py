"""Slot-arena KV/state cache.

TPU-friendly dense layout: one preallocated arena per layer-pattern
position with a leading slot dimension —

  attention:  k/v  (G, slots, S_max, Hkv, D)
  mamba:      ssm  (G, slots, NH, HD, DS) fp32, conv (G, slots, W-1, C)

Sessions own slots; a prefill batch is assembled by gathering its slot
rows and written back by scatter.  Statically shaped throughout (S_max
fixed), so every bucketized step compiles once — the paged-KV pointer
chasing of GPU systems is replaced by whole-slot gathers, which XLA
turns into efficient dynamic-slice DMAs.

Decode-only ticks skip even the gather: the arena-resident decode path
(DESIGN.md §5) hands the arena pytree itself to the executor, the
kernel indexes the slot axis through a scalar-prefetched slot map, and
:meth:`KVArena.replace` swaps the (donated, in-place) result back —
per-token HBM traffic is O(cached_len), not O(S_max) slot copies.
Packed prefill / mixed / chunk ticks do the same (DESIGN.md §6): the
whole-slot gather/scatter survives only as the dense fallback for
SSM/SWA architectures and off-ladder batches, and the
``gather_calls`` / ``scatter_calls`` counters prove the hot paths
never touch it.
"""
from __future__ import annotations

from collections import OrderedDict
from typing import Any, Dict, Iterable, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.models import transformer as tr
from repro.models.config import ModelConfig


class KVArena:
    def __init__(self, cfg: ModelConfig, num_slots: int, max_len: int,
                 dtype=None, swa_depth: Optional[int] = None,
                 scratch_slot: bool = False):
        self.cfg = cfg
        self.num_slots = num_slots
        self.max_len = max_len
        # swa_depth: attention-slot depth for sliding-window configs —
        # the §7 rolling arena passes window + margin; the dense
        # baseline passes max_len (window masked, not rolled); None
        # keeps the legacy min(max_len, window) clamp
        self.swa_depth = swa_depth
        # scratch_slot: allocate ONE extra slot that sessions can never
        # claim — rolling KV slots have no spare park row and SSM state
        # has no park position, so pad rows/segments target this slot
        # instead of aliasing a live one (DESIGN.md §7)
        self.scratch: Optional[int] = num_slots if scratch_slot else None
        alloc_slots = num_slots + (1 if scratch_slot else 0)
        # build per-slot cache then add the slot axis via the batch dim:
        # init_cache already produces (G, B, ...) — treat B as slots
        self.arena = tr.init_cache(cfg, alloc_slots, max_len, dtype,
                                   swa_depth=swa_depth)
        self._free: List[int] = list(range(num_slots))
        self._session_slot: Dict[int, int] = {}
        self.lengths: Dict[int, int] = {}          # session -> tokens cached
        # whole-slot copy counters: the arena-resident paths (decode §5,
        # packed prefill §6/§7) must keep these at ZERO on their hot
        # ticks — the acceptance proof that no O(S_max) round-trips
        # survive
        self.gather_calls = 0
        self.scatter_calls = 0

    # ----------------------------------------------------------- slots
    def alloc(self, session: int) -> int:
        if session in self._session_slot:
            return self._session_slot[session]
        if not self._free:
            raise RuntimeError("KV arena exhausted")
        slot = self._free.pop()
        self._session_slot[session] = slot
        self.lengths[session] = 0
        return slot

    def free(self, session: int) -> None:
        slot = self._session_slot.pop(session, None)
        if slot is not None:
            self._free.append(slot)
            self.lengths.pop(session, None)

    def slot_of(self, session: int) -> Optional[int]:
        return self._session_slot.get(session)

    def length(self, session: int) -> int:
        return self.lengths.get(session, 0)

    def set_length(self, session: int, n: int) -> None:
        if n > self.max_len - 2:
            raise RuntimeError(
                f"session {session} overflows arena ({n} > {self.max_len - 2})")
        self.lengths[session] = n

    def truncate(self, session: int, n: int) -> None:
        """Speculative rollback (DESIGN.md §10): drop cached rows past
        ``n``.  The slot layout needs no data movement — rows beyond the
        valid length are unreachable by invariant (attention masks to
        kv_length, the next append overwrites them in place) — so
        truncate is pure length bookkeeping here; the paged arena's
        version releases pages and de-indexes the radix suffix."""
        h = self.lengths.get(session, 0)
        if not 0 <= n <= h:
            raise ValueError(
                f"truncate session {session} to {n} outside [0, {h}]")
        self.lengths[session] = n

    @property
    def free_slots(self) -> int:
        return len(self._free)

    # ---------------------------------------------------------- gather
    def gather(self, slots: List[int]) -> Any:
        self.gather_calls += 1
        idx = jnp.asarray(slots, jnp.int32)
        return jax.tree.map(lambda a: jnp.take(a, idx, axis=1), self.arena)

    def scatter(self, slots: List[int], batch_cache: Any) -> None:
        self.scatter_calls += 1
        idx = jnp.asarray(slots, jnp.int32)
        self.arena = jax.tree.map(
            lambda a, b: a.at[:, idx].set(b.astype(a.dtype)),
            self.arena, batch_cache)

    # ------------------------------------------------------- in-place use
    def replace(self, new_arena: Any) -> None:
        """Swap in the arena pytree returned by an arena-resident step.

        The arena-resident decode path reads the arena IN PLACE (the
        kernel indexes the slot axis through a slot map) and returns the
        updated buffers — under donation the same memory, just a new
        handle.  No gather/scatter bookkeeping happens here; lengths are
        advanced by the engine per session."""
        self.arena = new_arena

    # ----------------------------------------------------------- handoff
    def export_slot(self, session: int) -> Any:
        """Handoff source (DESIGN.md §9): slice the session's cached rows
        as DEVICE arrays — one dynamic-slice per leaf, no host transfer.
        Only valid for pure-attention, non-rolling layouts (seq axis 2)."""
        slot = self._session_slot[session]
        h = self.lengths[session]
        return jax.tree.map(lambda a: a[:, slot, :h], self.arena)

    def import_slot(self, session: int, kv: Any, n_tokens: int) -> int:
        """Handoff destination: allocate a slot and device-copy the
        exported rows into it.  Returns the slot."""
        assert session not in self._session_slot, \
            f"import into live session {session}"
        slot = self.alloc(session)
        if n_tokens:
            self.arena = jax.tree.map(
                lambda a, b: a.at[:, slot, :n_tokens].set(b.astype(a.dtype)),
                self.arena, kv)
        self.set_length(session, n_tokens)
        return slot


class _RadixNode:
    """One edge of the prefix trie: a page_size-token chunk → one page.

    ``state_page`` (hybrid configs, DESIGN.md §12) optionally names a
    page holding the SSM boundary-state CHECKPOINT after this chunk —
    the recurrent state a session would hold having processed exactly
    the root→here token path.  The node owns one refcount on it."""
    __slots__ = ("children", "parent", "chunk", "page", "last_use",
                 "state_page")

    def __init__(self, parent: Optional["_RadixNode"] = None,
                 chunk: Optional[Tuple[int, ...]] = None, page: int = -1):
        self.children: Dict[Tuple[int, ...], "_RadixNode"] = {}
        self.parent = parent
        self.chunk = chunk
        self.page = page
        self.last_use = 0
        self.state_page: Optional[int] = None


class RadixPageIndex:
    """Radix/trie prefix index over page_size-token chunks.

    Maps token-id prefixes to the KV pages that hold them, at PAGE
    granularity: an edge at depth i is the tuple of token ids
    ``tokens[i·ps : (i+1)·ps]`` and names the physical page caching that
    chunk's KV.  Only FULL pages are indexed — a prefix is shareable
    exactly up to its last page boundary, which is also what makes
    sharing safe: sessions append at positions ≥ their committed length,
    so an indexed (full) page is never written again (see
    PagedKVArena.prepare_extend for the one COW exception, fork-shared
    partial pages, which by construction are never in this index).

    The index holds its own reference on every indexed page; eviction
    (LRU over leaf nodes) drops that reference so cold cached prefixes
    return to the free pool once no session holds them either.
    """

    def __init__(self, page_size: int):
        self.page_size = page_size
        self.root = _RadixNode()
        self._clock = 0
        self._n_pages = 0

    def _tick(self) -> int:
        self._clock += 1
        return self._clock

    def match(self, tokens: Sequence[int],
              touch: bool = True) -> List[int]:
        """Longest indexed prefix of ``tokens`` in full-page chunks.

        Returns the page ids caching ``tokens[:len(result)·ps]``.  Never
        matches past ``len(tokens) − 1``: the caller must keep ≥ 1 token
        of true suffix to prefill (attention needs a query row to
        produce this turn's logits).
        """
        ps = self.page_size
        limit = max(len(tokens) - 1, 0) // ps
        node, pages = self.root, []
        now = self._tick() if touch else self._clock
        for i in range(limit):
            child = node.children.get(tuple(tokens[i * ps:(i + 1) * ps]))
            if child is None:
                break
            if touch:
                child.last_use = now
            pages.append(child.page)
            node = child
        return pages

    def insert(self, tokens: Sequence[int],
               pages: Sequence[int]) -> List[int]:
        """Index every full-page chunk of ``tokens``; return the page ids
        NEWLY referenced (the caller owns refcounts).  Chunks already
        indexed keep their existing page — the duplicate stays private
        to its session."""
        ps = self.page_size
        node, newly = self.root, []
        now = self._tick()
        for i in range(len(tokens) // ps):
            chunk = tuple(tokens[i * ps:(i + 1) * ps])
            child = node.children.get(chunk)
            if child is None:
                child = _RadixNode(parent=node, chunk=chunk, page=pages[i])
                node.children[chunk] = child
                newly.append(pages[i])
                self._n_pages += 1
            child.last_use = now
            node = child
        return newly

    def pages(self) -> List[int]:
        out, stack = [], [self.root]
        while stack:
            n = stack.pop()
            if n is not self.root:
                out.append(n.page)
            stack.extend(n.children.values())
        return out

    def leaves(self) -> Iterable[_RadixNode]:
        stack = [self.root]
        while stack:
            n = stack.pop()
            if n is not self.root and not n.children:
                yield n
            stack.extend(n.children.values())

    def remove(self, node: _RadixNode) -> int:
        """Unlink a LEAF node; returns its page (caller drops the ref)."""
        assert not node.children and node.parent is not None
        del node.parent.children[node.chunk]
        self._n_pages -= 1
        return node.page

    def __len__(self) -> int:
        return self._n_pages


class PagedKVArena:
    """Paged KV cache: fixed-size pages in a shared pool + per-session
    page tables, with radix-tree prefix reuse, COW forks, and LRU
    eviction (DESIGN.md §8).

    Layout per layer-pattern position: k/v ``(G, N_pages + 1, page_size,
    Hkv, D)`` — init_cache's batch axis becomes the PAGE axis, so the
    paged kernels read ``(1, page_size, 1, D)`` blocks exactly like the
    slot kernels read arena blocks.  Page ``N_pages`` is the reserved
    SCRATCH page (the §6/§7 scratch-row/slot invariant at page
    granularity): it is never allocated, never indexed, and pad stream
    rows write at (scratch, page_size − 1).

    Sessions own ORDERED page lists (logical page i = positions
    [i·ps, (i+1)·ps)).  Pages are shared in two ways:

      * radix-tree prefix reuse — ``match_prefix`` maps a new session's
        token ids onto the pages of any previously committed identical
        prefix, so only the new suffix is prefilled;
      * COW forks — ``fork`` clones a session's table for n-best /
        tool-use branches; both branches share every page until one
        writes into the (partial) boundary page, which
        ``prepare_extend`` then copies.

    ``refcount[p]`` = #sessions whose table holds p, + 1 if the radix
    index holds p.  Append-only writes land at positions ≥ the committed
    length, so full (indexed, shareable) pages are never written; the
    only write into a shared page would be the fork-shared partial
    boundary page, and that is exactly the COW trigger.  A page returns
    to the free pool when its refcount drops to zero; when the pool runs
    dry, LRU leaf pages held only by the index are evicted
    (oversubscription: the index may cache far more prefix than live
    sessions could pin).

    Three layout extensions ride on the same pool (DESIGN.md §12):

      * ``ring_pages=n`` — RING tables for sliding-window configs: the
        session's page list is a ring of at most ``n`` logical blocks;
        position p lives on ring page ``(p // ps) % n`` (the engine
        computes the mapping host-side).  Ring pages are overwritten in
        place as the window rolls, so they are never shareable: the
        radix index is disabled, refcounts stay 1, and forks are
        rejected.
      * ``state_slots=True`` — hybrid (SSM) configs: each session gets
        one STATE page from the same pool (the SSM leaves of the arena
        pytree use the page axis as the state-slot axis).  ``commit``
        checkpoints the live state into a fresh page attached to the
        radix node whenever the committed length lands on a page
        boundary, and ``match_prefix`` clamps adoption to the deepest
        matched ancestor that carries such a checkpoint.
      * ``host_pool_bytes>0`` — host spill tier: eviction DEMOTES
        index-only LRU pages to a bounded host-side pool (one
        ``device_get`` on the victim) instead of dropping them;
        ``match_prefix`` / ``match_extend`` promote entries back into
        fresh device pages on hit.  Session-pinned pages (rc > 1) are
        never spill candidates, and state checkpoints do not survive
        demotion (a promoted page re-enters the index KV-only).

    ``cfg=None`` builds a bookkeeping-only arena (no device arrays) for
    property tests of the share/fork/evict/spill/write state machine.
    """

    def __init__(self, cfg: Optional[ModelConfig], num_pages: int,
                 page_size: int, max_len: int, dtype=None,
                 prefix_cache: bool = True,
                 ring_pages: Optional[int] = None,
                 state_slots: bool = False,
                 host_pool_bytes: int = 0):
        self.cfg = cfg
        self.num_pages = num_pages
        self.page_size = page_size
        self.max_len = max_len
        self.scratch: int = num_pages          # reserved, never allocated
        self.ring_pages = ring_pages
        self.state_slots = state_slots
        # swa_depth=page_size keeps windowed attn pages FULL page_size
        # deep (init_cache would otherwise clamp them to the window);
        # the ring table, not the page depth, carries the window
        self.arena = (tr.init_cache(cfg, num_pages + 1, page_size, dtype,
                                    swa_depth=page_size)
                      if cfg is not None else None)
        self._free: List[int] = list(range(num_pages))
        self._refcount: List[int] = [0] * num_pages
        self._pages: Dict[int, List[int]] = {}     # session -> page list
        self._tokens: Dict[int, List[int]] = {}    # session -> cached ids
        self.lengths: Dict[int, int] = {}          # session -> tokens cached
        self.state_pages: Dict[int, int] = {}      # session -> SSM state page
        if ring_pages is not None:
            prefix_cache = False               # ring pages are overwritten
        self.index: Optional[RadixPageIndex] = (
            RadixPageIndex(page_size) if prefix_cache else None)
        # host spill tier: full-chunk-path key -> device_get'd page leaves
        # (None payloads in bookkeeping mode); LRU = insertion order
        self.host_pool_bytes = host_pool_bytes
        self._host_pool: "OrderedDict[Tuple, Any]" = OrderedDict()
        self._host_bytes = 0
        if self.arena is not None:
            self._page_bytes = int(sum(
                a[:, 0].nbytes for a in jax.tree.leaves(self.arena)))
        else:
            self._page_bytes = 1               # bookkeeping: count pages
        # proof counters (engine.stats())
        self.prefix_hit_tokens = 0
        self.chunk_hit_tokens = 0
        self.pages_cow_forked = 0
        self.pages_evicted = 0
        self.pages_spilled = 0
        self.pages_promoted = 0
        self.host_pages_dropped = 0
        self.state_checkpoints = 0
        self.handoff_pages_deduped = 0
        # the paged paths never materialize whole sequences: kept for
        # stats() symmetry with KVArena and asserted == 0 by benches
        self.gather_calls = 0
        self.scatter_calls = 0

    # ---------------------------------------------------------- refcounts
    def _ref(self, page: int) -> None:
        self._refcount[page] += 1

    def _unref(self, page: int) -> None:
        rc = self._refcount[page] = self._refcount[page] - 1
        assert rc >= 0, f"page {page} refcount underflow"
        if rc == 0:
            self._free.append(page)

    def _alloc_page(self) -> int:
        if not self._free:
            self._evict(1)
        if not self._free:
            raise RuntimeError("KV page pool exhausted")
        page = self._free.pop()
        self._refcount[page] = 1
        return page

    def _evict(self, need: int) -> None:
        """LRU-evict leaf pages held ONLY by the radix index; with a
        host tier configured the victim is DEMOTED (one device_get)
        instead of dropped, and its state checkpoint (if any) is
        released — checkpoints never survive demotion."""
        if self.index is None:
            return
        freed = 0
        while freed < need:
            victim = None
            for leaf in self.index.leaves():
                if leaf.page < 0:
                    continue                   # mid-promotion placeholder
                if self._refcount[leaf.page] != 1:
                    continue                   # pinned by a live session
                if victim is None or leaf.last_use < victim.last_use:
                    victim = leaf
            if victim is None:
                return
            if self.host_pool_bytes > 0:
                self._spill(self._node_key(victim), victim.page)
            if victim.state_page is not None:
                self._unref(victim.state_page)
                victim.state_page = None
            self._unref(self.index.remove(victim))
            self.pages_evicted += 1
            freed += 1

    # ----------------------------------------------------------- host tier
    @staticmethod
    def _node_key(node: _RadixNode) -> Tuple[Tuple[int, ...], ...]:
        """Full root→node chunk path — the host-pool key (content-
        addressed, so promotion survives page-id recycling)."""
        chunks: List[Tuple[int, ...]] = []
        while node.parent is not None:
            chunks.append(node.chunk)
            node = node.parent
        return tuple(reversed(chunks))

    def _spill(self, key: Tuple, page: int) -> None:
        """Demote one page to the host pool (device_get on the victim
        only); oldest entries age out when the byte budget overflows."""
        if self.arena is not None:
            payload = jax.tree.map(lambda a: jax.device_get(a[:, page]),
                                   self.arena)
        else:
            payload = None
        if key in self._host_pool:             # refreshed content: replace
            self._host_pool.pop(key)
            self._host_bytes -= self._page_bytes
        self._host_pool[key] = payload
        self._host_bytes += self._page_bytes
        self.pages_spilled += 1
        while self._host_bytes > self.host_pool_bytes and self._host_pool:
            self._host_pool.popitem(last=False)
            self._host_bytes -= self._page_bytes
            self.host_pages_dropped += 1

    def _promote(self, key: Tuple, parent: _RadixNode,
                 chunk: Tuple[int, ...]) -> Optional[_RadixNode]:
        """Promote a host-pool entry back into a fresh device page and
        re-link it under ``parent`` in the radix index.  The node is
        linked (page = −1) BEFORE allocating so the alloc's own eviction
        sweep can neither pick it nor orphan ``parent``."""
        if key not in self._host_pool:
            return None
        payload = self._host_pool.pop(key)
        self._host_bytes -= self._page_bytes
        node = _RadixNode(parent=parent, chunk=chunk, page=-1)
        parent.children[chunk] = node
        try:
            page = self._alloc_page()          # ref owned by the index
        except RuntimeError:
            del parent.children[chunk]
            self._host_pool[key] = payload     # put it back; no pool room
            self._host_bytes += self._page_bytes
            return None
        node.page = page
        if self.arena is not None and payload is not None:
            self.arena = jax.tree.map(
                lambda a, b: a.at[:, page].set(jnp.asarray(b, a.dtype)),
                self.arena, payload)
        node.last_use = self.index._tick()
        self.index._n_pages += 1
        self.pages_promoted += 1
        return node

    # ------------------------------------------------------------ sessions
    def open(self, session: int) -> None:
        if session in self._pages:
            return
        self._pages[session] = []
        self._tokens[session] = []
        self.lengths[session] = 0
        if self.state_slots:
            # one SSM state page per session, from the same pool — the
            # SSM leaves of the arena use the page axis as state slots
            self.state_pages[session] = self._alloc_page()

    def free(self, session: int) -> None:
        pages = self._pages.pop(session, None)
        if pages is None:
            return
        for p in pages:
            self._unref(p)
        sp = self.state_pages.pop(session, None)
        if sp is not None:
            self._unref(sp)
        self._tokens.pop(session, None)
        self.lengths.pop(session, None)

    def pages_of(self, session: int) -> List[int]:
        return self._pages.get(session, [])

    def state_of(self, session: int) -> Optional[int]:
        """The session's SSM state page (None for pure-attn configs)."""
        return self.state_pages.get(session)

    def slot_of(self, session: int) -> Optional[int]:
        """KVArena-compatible accessor: for hybrid configs the 'slot'
        carrying per-session recurrent state is the state page."""
        return self.state_pages.get(session)

    def length(self, session: int) -> int:
        return self.lengths.get(session, 0)

    @property
    def free_pages(self) -> int:
        return len(self._free)

    @property
    def max_pages_per_seq(self) -> int:
        if self.ring_pages is not None:
            return self.ring_pages
        return self.max_len // self.page_size

    @property
    def host_pool_pages(self) -> int:
        return len(self._host_pool)

    # -------------------------------------------------------- prefix reuse
    def _walk(self, start: _RadixNode, start_key: Tuple,
              tokens: Sequence[int], limit: int, *, pin: bool,
              promote: bool) -> List[_RadixNode]:
        """Follow ``tokens`` chunk by chunk from ``start``, optionally
        promoting host-pool continuations.  ``pin=True`` refs every
        matched page immediately (the caller owns the refs) so a later
        promotion's eviction sweep can never free a page already
        matched this walk."""
        node, key = start, start_key
        out: List[_RadixNode] = []
        now = self.index._tick() if pin else self.index._clock
        ps = self.page_size
        for i in range(limit):
            chunk = tuple(int(t) for t in tokens[i * ps:(i + 1) * ps])
            key = key + (chunk,)
            child = node.children.get(chunk)
            if child is None and promote:
                child = self._promote(key, node, chunk)
            if child is None:
                break
            if pin:
                child.last_use = now
                self._ref(child.page)
            out.append(child)
            node = child
        return out

    def _adoptable(self, nodes: List[_RadixNode]) -> int:
        """How many matched chunks a session can actually ADOPT: all of
        them for pure-attn configs; for hybrids, only up to the deepest
        ancestor carrying an SSM boundary-state checkpoint (the
        recurrent state must be reconstructable, not just the KV)."""
        if not self.state_slots:
            return len(nodes)
        for d in range(len(nodes), 0, -1):
            if nodes[d - 1].state_page is not None:
                return d
        return 0

    def probe_prefix(self, tokens: Sequence[int]) -> int:
        """Tokens a fresh session with this prompt would NOT re-prefill
        (non-adopting; used by the serve loop for length-aware
        scheduling of the true suffix).  Counts device-resident chunks
        AND host-pool continuations — a spilled page is still a hit,
        just one ``swap_in`` away."""
        if self.index is None:
            return 0
        ps = self.page_size
        limit = max(len(tokens) - 1, 0) // ps
        nodes = self._walk(self.index.root, (), tokens, limit,
                           pin=False, promote=False)
        d = self._adoptable(nodes)
        if self.state_slots:
            return d * ps          # host entries carry no checkpoints
        key = tuple(tuple(int(t) for t in tokens[i * ps:(i + 1) * ps])
                    for i in range(d))
        while d < limit:
            key = key + (tuple(int(t)
                               for t in tokens[d * ps:(d + 1) * ps]),)
            if key not in self._host_pool:
                break
            d += 1
        return d * ps

    def match_prefix(self, session: int, tokens: Sequence[int]) -> int:
        """Map the longest indexed prefix of ``tokens`` onto existing
        pages; the session then only prefills ``tokens[matched:]``.
        Host-pool continuations are promoted back to device pages on
        the way.  For hybrid configs the match is clamped to the deepest
        ancestor with an SSM boundary-state checkpoint, and the
        checkpoint content is copied into the session's state page.

        Only valid on an EMPTY session (a turn's full conversation is
        matched once, before its first prefill).  Returns the matched
        token count (multiple of page_size, ≤ len(tokens) − 1).
        """
        self.open(session)
        assert self.lengths[session] == 0 and not self._pages[session], \
            f"match_prefix on non-empty session {session}"
        if self.index is None:
            return 0
        ps = self.page_size
        limit = max(len(tokens) - 1, 0) // ps
        nodes = self._walk(self.index.root, (), tokens, limit,
                           pin=True, promote=True)
        d = self._adoptable(nodes)
        for nd in nodes[d:]:                   # unwind the clamped tail
            self._unref(nd.page)
        nodes = nodes[:d]
        if not nodes:
            return 0
        matched = len(nodes) * ps
        self._pages[session] = [nd.page for nd in nodes]
        self._tokens[session] = [int(t) for t in tokens[:matched]]
        self.lengths[session] = matched
        self.prefix_hit_tokens += matched
        if self.state_slots:
            self._copy_page(nodes[-1].state_page,
                            self.state_pages[session])
        return matched

    def match_extend(self, session: int, tokens: Sequence[int]) -> int:
        """CHUNK-LEVEL prefix matching (DESIGN.md §12): mid-request, map
        the longest indexed continuation of the session's cached history
        onto existing pages, so a long prompt whose cached prefix
        extends past the first chunk skips already-indexed pages at
        every chunk boundary — not just at submit.

        ``tokens`` is the not-yet-cached remainder of the prompt.  Only
        valid when the session sits exactly on a page boundary (chunked
        prefill with page-aligned chunks guarantees this).  Keeps ≥ 1
        token of true suffix.  Returns the adopted token count.
        """
        if self.index is None:
            return 0
        h = self.lengths.get(session, 0)
        ps = self.page_size
        if h == 0 or h % ps:
            return 0
        toks = self._tokens[session]
        # locate the session's frontier node by CONTENT (the session may
        # hold private duplicate pages; the trie is keyed on token ids)
        node, key = self.index.root, ()
        for i in range(h // ps):
            chunk = tuple(toks[i * ps:(i + 1) * ps])
            child = node.children.get(chunk)
            if child is None:
                return 0                       # history not indexed
            key = key + (chunk,)
            node = child
        limit = max(len(tokens) - 1, 0) // ps
        nodes = self._walk(node, key, tokens, limit,
                           pin=True, promote=True)
        # hybrids: the session's live SSM state covers exactly h tokens,
        # so skipping ahead is only sound up to a boundary-state
        # checkpoint that replaces it — clamp like match_prefix
        d = self._adoptable(nodes)
        for nd in nodes[d:]:
            self._unref(nd.page)
        nodes = nodes[:d]
        if not nodes:
            return 0
        matched = len(nodes) * ps
        self._pages[session].extend(nd.page for nd in nodes)
        toks.extend(int(t) for t in tokens[:matched])
        self.lengths[session] = h + matched
        self.prefix_hit_tokens += matched
        self.chunk_hit_tokens += matched
        if self.state_slots:
            self._copy_page(nodes[-1].state_page,
                            self.state_pages[session])
        return matched

    # --------------------------------------------------------------- write
    def prepare_extend(self, session: int, n: int) -> List[int]:
        """Make positions [length, length + n) writable: COW-copy the
        fork-shared partial boundary page (the ONLY shareable page a
        write can touch — full pages are append-safe) and allocate fresh
        pages for the tail.  Returns the session's page list; every page
        overlapping the write range is exclusively owned afterwards."""
        self.open(session)
        h = self.lengths[session]
        if h + n > self.max_len - 2:
            raise RuntimeError(
                f"session {session} overflows arena "
                f"({h + n} > {self.max_len - 2})")
        ps = self.page_size
        pages = self._pages[session]
        if self.ring_pages is not None:
            # ring table (§12): allocate only until the ring is full;
            # past that, writes wrap onto existing ring pages (the
            # engine maps position p to ring slot (p // ps) % n_ring).
            # Ring pages are never shared, so no COW is ever needed.
            last = (h + n - 1) // ps
            while len(pages) <= last and len(pages) < self.ring_pages:
                pages.append(self._alloc_page())
            return pages
        if h % ps and self._refcount[pages[h // ps]] > 1:
            src = pages[h // ps]
            dst = self._alloc_page()
            self._copy_page(src, dst)
            self._unref(src)
            pages[h // ps] = dst
            self.pages_cow_forked += 1
        last = (h + n - 1) // ps
        while len(pages) <= last:
            pages.append(self._alloc_page())
        return pages

    def commit(self, session: int, token_ids: Sequence[int]) -> None:
        """Record ``token_ids`` as written at [length, length + n) (the
        step already scatter-wrote their KV via prepare_extend's pages)
        and index every newly-FULL page for cross-session reuse."""
        toks = self._tokens[session]
        toks.extend(int(t) for t in token_ids)
        self.lengths[session] += len(token_ids)
        if self.index is not None:
            ps = self.page_size
            n_full = self.lengths[session] // ps
            for p in self.index.insert(toks[:n_full * ps],
                                       self._pages[session][:n_full]):
                self._ref(p)
            if (self.state_slots and n_full > 0
                    and self.lengths[session] % ps == 0):
                self._checkpoint_state(session, toks, n_full)

    def _checkpoint_state(self, session: int, toks: List[int],
                          n_full: int) -> None:
        """SSM boundary-state checkpoint (§12): when the committed
        length lands exactly on a page boundary, the session's LIVE
        state equals the state after ``n_full`` chunks — snapshot it
        into a fresh page owned by the radix node at that depth, so a
        later session matching this prefix can adopt it.  Best-effort:
        pool pressure skips the snapshot rather than evicting live
        data for it."""
        ps = self.page_size
        try:
            cp = self._alloc_page()
        except RuntimeError:
            return
        # re-walk AFTER the alloc: its eviction sweep may have dropped
        # the very node we are about to decorate
        node: Optional[_RadixNode] = self.index.root
        for i in range(n_full):
            node = node.children.get(tuple(toks[i * ps:(i + 1) * ps]))
            if node is None:
                break
        if node is None or node is self.index.root \
                or node.state_page is not None:
            self._unref(cp)
            return
        self._copy_page(self.state_pages[session], cp)
        node.state_page = cp
        self.state_checkpoints += 1

    # ------------------------------------------------------------ rollback
    def truncate(self, session: int, n: int) -> None:
        """Speculative rollback (DESIGN.md §10): forget every cached
        token past ``n``.

        Three things unwind, in order:

        1. **Radix de-index** — the session's indexed chunk path is
           walked and suffix nodes covering chunks ≥ ``n // ps`` are
           unlinked deepest-first, including the boundary chunk whose
           page goes full → partial (an indexed page must stay
           append-only; the session will write into the partial page
           again).  A node survives when it still has children (a longer
           indexed prefix — shared, not ours to drop) or when it names a
           different physical page (a private duplicate was never
           indexed); in either case every shallower node survives too,
           and any such still-indexed boundary page keeps rc > 1 so
           ``prepare_extend``'s COW shields it from the re-extend.
        2. **Page-refcount release** — the session unrefs every page
           past ``ceil(n / ps)``; pages held by the index or by a fork
           sibling stay alive (rc > 0), exclusively-owned tails return
           to the free pool.  Pages over-allocated by a speculative
           ``prepare_extend`` (never committed) are released the same
           way even when ``n == length``.
        3. **Token trim** — ``_tokens``/``lengths`` shrink to ``n``.

        ``audit()`` holds afterwards: every unref is mirrored by a table
        or index removal.
        """
        h = self.lengths.get(session, 0)
        if not 0 <= n <= h:
            raise ValueError(
                f"truncate session {session} to {n} outside [0, {h}]")
        self.open(session)
        ps = self.page_size
        toks = self._tokens[session]
        pages = self._pages[session]
        if self.ring_pages is not None:
            # ring tables: pages hold modularly-wrapped history, so the
            # rollback is pure length bookkeeping (rows past ``n`` are
            # unreachable by the window mask and overwritten in place)
            del toks[n:]
            self.lengths[session] = n
            return
        new_full = n // ps
        keep_pages = -(-n // ps)
        if self.index is not None:
            # the session's indexed chain, chunk by chunk
            path: List[_RadixNode] = []
            node = self.index.root
            for i in range(h // ps):
                child = node.children.get(tuple(toks[i * ps:(i + 1) * ps]))
                if child is None:
                    break
                path.append(child)
                node = child
            for i in range(len(path) - 1, new_full - 1, -1):
                nd = path[i]
                if nd.children or nd.page != pages[i]:
                    break
                if nd.state_page is not None:
                    self._unref(nd.state_page)
                    nd.state_page = None
                self._unref(self.index.remove(nd))
        for p in pages[keep_pages:]:
            self._unref(p)
        del pages[keep_pages:]
        del toks[n:]
        self.lengths[session] = n

    # ---------------------------------------------------------------- fork
    def fork(self, parent: int, child: int) -> None:
        """COW-fork: the child shares every page (and the token history)
        of the parent; diverging writes copy the partial boundary page
        on demand (prepare_extend).  Hybrid configs also deep-copy the
        parent's SSM state page (recurrent state diverges immediately)."""
        assert self.ring_pages is None, \
            "ring tables cannot fork (pages are overwritten in place)"
        assert child not in self._pages, f"fork onto live session {child}"
        self.open(child)
        for p in self._pages[parent]:
            self._ref(p)
        self._pages[child] = list(self._pages[parent])
        self._tokens[child] = list(self._tokens[parent])
        self.lengths[child] = self.lengths[parent]
        if self.state_slots:
            self._copy_page(self.state_pages[parent],
                            self.state_pages[child])

    # ------------------------------------------------------------- handoff
    def export_pages(self, session: int) -> Any:
        """Handoff source (DESIGN.md §9): gather the session's page rows
        from the pool as DEVICE arrays (no host transfer)."""
        pages = self._pages.get(session, [])
        if self.arena is None or not pages:
            return None
        idx = jnp.asarray(pages, jnp.int32)
        return jax.tree.map(lambda a: jnp.take(a, idx, axis=1), self.arena)

    def import_session(self, session: int, token_ids: Sequence[int],
                       kv: Any, n_tokens: int) -> List[int]:
        """Handoff destination: allocate fresh pages, device-copy the
        exported pool rows into them, rebuild the session bookkeeping,
        and index every full page — the imported prefix becomes
        shareable here exactly as if it had been prefilled locally.

        DEDUPE (§12): the caller may ``match_prefix`` the incoming
        transcript FIRST — pages the destination's radix index already
        holds are adopted, and only the suffix of the exported payload
        (``kv`` sliced past the matched pages) is copied in.  ``kv`` is
        always the FULL export; the slicing happens here."""
        self.open(session)
        h = self.lengths[session]
        ps = self.page_size
        assert h % ps == 0, \
            f"import into session {session} off a page boundary ({h})"
        assert self._tokens[session] == [int(t) for t in token_ids[:h]], \
            f"import into session {session} with mismatched history"
        if n_tokens > self.max_len - 2:
            raise RuntimeError(
                f"imported session {session} overflows arena "
                f"({n_tokens} > {self.max_len - 2})")
        skip = h // ps
        n_pages = -(-n_tokens // ps) - skip
        pages = [self._alloc_page() for _ in range(n_pages)]
        if self.arena is not None and kv is not None and pages:
            idx = jnp.asarray(pages, jnp.int32)
            self.arena = jax.tree.map(
                lambda a, b: a.at[:, idx].set(b[:, skip:].astype(a.dtype)),
                self.arena, kv)
        self._pages[session].extend(pages)
        self._tokens[session].extend(int(t) for t in token_ids[h:n_tokens])
        self.lengths[session] = n_tokens
        if skip:
            self.handoff_pages_deduped += skip
        if self.index is not None:
            n_full = n_tokens // ps
            for p in self.index.insert(self._tokens[session][:n_full * ps],
                                       self._pages[session][:n_full]):
                self._ref(p)
        return self._pages[session]

    # ------------------------------------------------------- device arrays
    def _copy_page(self, src: int, dst: int) -> None:
        if self.arena is None:
            return
        self.arena = jax.tree.map(
            lambda a: a.at[:, dst].set(a[:, src]), self.arena)

    def replace(self, new_arena: Any) -> None:
        """Swap in the page pool returned by a paged step (donated)."""
        self.arena = new_arena

    # --------------------------------------------------------------- audit
    def audit(self) -> None:
        """Assert the refcount/free-list/scratch/host-tier invariants
        (tests)."""
        rc = [0] * self.num_pages
        for pages in self._pages.values():
            for p in pages:
                assert p != self.scratch, "scratch page in a session table"
                rc[p] += 1
        for sp in self.state_pages.values():
            assert sp != self.scratch, "scratch page as a state page"
            rc[sp] += 1
        if self.index is not None:
            stack = [self.index.root]
            while stack:
                nd = stack.pop()
                if nd is not self.index.root:
                    assert nd.page != self.scratch, \
                        "scratch page in the radix index"
                    assert nd.page >= 0, "placeholder node leaked"
                    rc[nd.page] += 1
                    if nd.state_page is not None:
                        assert nd.state_page != self.scratch
                        rc[nd.state_page] += 1
                stack.extend(nd.children.values())
        assert self._host_bytes == len(self._host_pool) * self._page_bytes, \
            "host pool byte accounting drift"
        assert self._host_bytes <= max(self.host_pool_bytes, 0) or \
            not self._host_pool, "host pool over budget"
        assert rc == self._refcount, \
            f"refcount drift: counted {rc} != tracked {self._refcount}"
        assert sorted(self._free) == sorted(set(self._free)), \
            "duplicate pages in the free list"
        for p in self._free:
            assert p != self.scratch and self._refcount[p] == 0, \
                f"free page {p} still referenced"
        for p, r in enumerate(self._refcount):
            assert (r == 0) == (p in set(self._free)), \
                f"page {p} rc={r} free-list membership mismatch"
