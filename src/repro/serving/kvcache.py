"""Slot-arena KV/state cache.

TPU-friendly dense layout: one preallocated arena per layer-pattern
position with a leading slot dimension —

  attention:  k/v  (G, slots, S_max, Hkv, D)
  mamba:      ssm  (G, slots, NH, HD, DS) fp32, conv (G, slots, W-1, C)

Sessions own slots; a prefill batch is assembled by gathering its slot
rows and written back by scatter.  Statically shaped throughout (S_max
fixed), so every bucketized step compiles once — the paged-KV pointer
chasing of GPU systems is replaced by whole-slot gathers, which XLA
turns into efficient dynamic-slice DMAs.

Decode-only ticks skip even the gather: the arena-resident decode path
(DESIGN.md §5) hands the arena pytree itself to the executor, the
kernel indexes the slot axis through a scalar-prefetched slot map, and
:meth:`KVArena.replace` swaps the (donated, in-place) result back —
per-token HBM traffic is O(cached_len), not O(S_max) slot copies.
Packed prefill / mixed / chunk ticks do the same (DESIGN.md §6): the
whole-slot gather/scatter survives only as the dense fallback for
SSM/SWA architectures and off-ladder batches, and the
``gather_calls`` / ``scatter_calls`` counters prove the hot paths
never touch it.
"""
from __future__ import annotations

from typing import Any, Dict, Iterable, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.models import transformer as tr
from repro.models.config import ModelConfig


class KVArena:
    def __init__(self, cfg: ModelConfig, num_slots: int, max_len: int,
                 dtype=None, swa_depth: Optional[int] = None,
                 scratch_slot: bool = False):
        self.cfg = cfg
        self.num_slots = num_slots
        self.max_len = max_len
        # swa_depth: attention-slot depth for sliding-window configs —
        # the §7 rolling arena passes window + margin; the dense
        # baseline passes max_len (window masked, not rolled); None
        # keeps the legacy min(max_len, window) clamp
        self.swa_depth = swa_depth
        # scratch_slot: allocate ONE extra slot that sessions can never
        # claim — rolling KV slots have no spare park row and SSM state
        # has no park position, so pad rows/segments target this slot
        # instead of aliasing a live one (DESIGN.md §7)
        self.scratch: Optional[int] = num_slots if scratch_slot else None
        alloc_slots = num_slots + (1 if scratch_slot else 0)
        # build per-slot cache then add the slot axis via the batch dim:
        # init_cache already produces (G, B, ...) — treat B as slots
        self.arena = tr.init_cache(cfg, alloc_slots, max_len, dtype,
                                   swa_depth=swa_depth)
        self._free: List[int] = list(range(num_slots))
        self._session_slot: Dict[int, int] = {}
        self.lengths: Dict[int, int] = {}          # session -> tokens cached
        # whole-slot copy counters: the arena-resident paths (decode §5,
        # packed prefill §6/§7) must keep these at ZERO on their hot
        # ticks — the acceptance proof that no O(S_max) round-trips
        # survive
        self.gather_calls = 0
        self.scatter_calls = 0

    # ----------------------------------------------------------- slots
    def alloc(self, session: int) -> int:
        if session in self._session_slot:
            return self._session_slot[session]
        if not self._free:
            raise RuntimeError("KV arena exhausted")
        slot = self._free.pop()
        self._session_slot[session] = slot
        self.lengths[session] = 0
        return slot

    def free(self, session: int) -> None:
        slot = self._session_slot.pop(session, None)
        if slot is not None:
            self._free.append(slot)
            self.lengths.pop(session, None)

    def slot_of(self, session: int) -> Optional[int]:
        return self._session_slot.get(session)

    def length(self, session: int) -> int:
        return self.lengths.get(session, 0)

    def set_length(self, session: int, n: int) -> None:
        if n > self.max_len - 2:
            raise RuntimeError(
                f"session {session} overflows arena ({n} > {self.max_len - 2})")
        self.lengths[session] = n

    def truncate(self, session: int, n: int) -> None:
        """Speculative rollback (DESIGN.md §10): drop cached rows past
        ``n``.  The slot layout needs no data movement — rows beyond the
        valid length are unreachable by invariant (attention masks to
        kv_length, the next append overwrites them in place) — so
        truncate is pure length bookkeeping here; the paged arena's
        version releases pages and de-indexes the radix suffix."""
        h = self.lengths.get(session, 0)
        if not 0 <= n <= h:
            raise ValueError(
                f"truncate session {session} to {n} outside [0, {h}]")
        self.lengths[session] = n

    @property
    def free_slots(self) -> int:
        return len(self._free)

    # ---------------------------------------------------------- gather
    def gather(self, slots: List[int]) -> Any:
        self.gather_calls += 1
        idx = jnp.asarray(slots, jnp.int32)
        return jax.tree.map(lambda a: jnp.take(a, idx, axis=1), self.arena)

    def scatter(self, slots: List[int], batch_cache: Any) -> None:
        self.scatter_calls += 1
        idx = jnp.asarray(slots, jnp.int32)
        self.arena = jax.tree.map(
            lambda a, b: a.at[:, idx].set(b.astype(a.dtype)),
            self.arena, batch_cache)

    # ------------------------------------------------------- in-place use
    def replace(self, new_arena: Any) -> None:
        """Swap in the arena pytree returned by an arena-resident step.

        The arena-resident decode path reads the arena IN PLACE (the
        kernel indexes the slot axis through a slot map) and returns the
        updated buffers — under donation the same memory, just a new
        handle.  No gather/scatter bookkeeping happens here; lengths are
        advanced by the engine per session."""
        self.arena = new_arena

    # ----------------------------------------------------------- handoff
    def export_slot(self, session: int) -> Any:
        """Handoff source (DESIGN.md §9): slice the session's cached rows
        as DEVICE arrays — one dynamic-slice per leaf, no host transfer.
        Only valid for pure-attention, non-rolling layouts (seq axis 2)."""
        slot = self._session_slot[session]
        h = self.lengths[session]
        return jax.tree.map(lambda a: a[:, slot, :h], self.arena)

    def import_slot(self, session: int, kv: Any, n_tokens: int) -> int:
        """Handoff destination: allocate a slot and device-copy the
        exported rows into it.  Returns the slot."""
        assert session not in self._session_slot, \
            f"import into live session {session}"
        slot = self.alloc(session)
        if n_tokens:
            self.arena = jax.tree.map(
                lambda a, b: a.at[:, slot, :n_tokens].set(b.astype(a.dtype)),
                self.arena, kv)
        self.set_length(session, n_tokens)
        return slot


class _RadixNode:
    """One edge of the prefix trie: a page_size-token chunk → one page."""
    __slots__ = ("children", "parent", "chunk", "page", "last_use")

    def __init__(self, parent: Optional["_RadixNode"] = None,
                 chunk: Optional[Tuple[int, ...]] = None, page: int = -1):
        self.children: Dict[Tuple[int, ...], "_RadixNode"] = {}
        self.parent = parent
        self.chunk = chunk
        self.page = page
        self.last_use = 0


class RadixPageIndex:
    """Radix/trie prefix index over page_size-token chunks.

    Maps token-id prefixes to the KV pages that hold them, at PAGE
    granularity: an edge at depth i is the tuple of token ids
    ``tokens[i·ps : (i+1)·ps]`` and names the physical page caching that
    chunk's KV.  Only FULL pages are indexed — a prefix is shareable
    exactly up to its last page boundary, which is also what makes
    sharing safe: sessions append at positions ≥ their committed length,
    so an indexed (full) page is never written again (see
    PagedKVArena.prepare_extend for the one COW exception, fork-shared
    partial pages, which by construction are never in this index).

    The index holds its own reference on every indexed page; eviction
    (LRU over leaf nodes) drops that reference so cold cached prefixes
    return to the free pool once no session holds them either.
    """

    def __init__(self, page_size: int):
        self.page_size = page_size
        self.root = _RadixNode()
        self._clock = 0
        self._n_pages = 0

    def _tick(self) -> int:
        self._clock += 1
        return self._clock

    def match(self, tokens: Sequence[int],
              touch: bool = True) -> List[int]:
        """Longest indexed prefix of ``tokens`` in full-page chunks.

        Returns the page ids caching ``tokens[:len(result)·ps]``.  Never
        matches past ``len(tokens) − 1``: the caller must keep ≥ 1 token
        of true suffix to prefill (attention needs a query row to
        produce this turn's logits).
        """
        ps = self.page_size
        limit = max(len(tokens) - 1, 0) // ps
        node, pages = self.root, []
        now = self._tick() if touch else self._clock
        for i in range(limit):
            child = node.children.get(tuple(tokens[i * ps:(i + 1) * ps]))
            if child is None:
                break
            if touch:
                child.last_use = now
            pages.append(child.page)
            node = child
        return pages

    def insert(self, tokens: Sequence[int],
               pages: Sequence[int]) -> List[int]:
        """Index every full-page chunk of ``tokens``; return the page ids
        NEWLY referenced (the caller owns refcounts).  Chunks already
        indexed keep their existing page — the duplicate stays private
        to its session."""
        ps = self.page_size
        node, newly = self.root, []
        now = self._tick()
        for i in range(len(tokens) // ps):
            chunk = tuple(tokens[i * ps:(i + 1) * ps])
            child = node.children.get(chunk)
            if child is None:
                child = _RadixNode(parent=node, chunk=chunk, page=pages[i])
                node.children[chunk] = child
                newly.append(pages[i])
                self._n_pages += 1
            child.last_use = now
            node = child
        return newly

    def pages(self) -> List[int]:
        out, stack = [], [self.root]
        while stack:
            n = stack.pop()
            if n is not self.root:
                out.append(n.page)
            stack.extend(n.children.values())
        return out

    def leaves(self) -> Iterable[_RadixNode]:
        stack = [self.root]
        while stack:
            n = stack.pop()
            if n is not self.root and not n.children:
                yield n
            stack.extend(n.children.values())

    def remove(self, node: _RadixNode) -> int:
        """Unlink a LEAF node; returns its page (caller drops the ref)."""
        assert not node.children and node.parent is not None
        del node.parent.children[node.chunk]
        self._n_pages -= 1
        return node.page

    def __len__(self) -> int:
        return self._n_pages


class PagedKVArena:
    """Paged KV cache: fixed-size pages in a shared pool + per-session
    page tables, with radix-tree prefix reuse, COW forks, and LRU
    eviction (DESIGN.md §8).

    Layout per layer-pattern position: k/v ``(G, N_pages + 1, page_size,
    Hkv, D)`` — init_cache's batch axis becomes the PAGE axis, so the
    paged kernels read ``(1, page_size, 1, D)`` blocks exactly like the
    slot kernels read arena blocks.  Page ``N_pages`` is the reserved
    SCRATCH page (the §6/§7 scratch-row/slot invariant at page
    granularity): it is never allocated, never indexed, and pad stream
    rows write at (scratch, page_size − 1).

    Sessions own ORDERED page lists (logical page i = positions
    [i·ps, (i+1)·ps)).  Pages are shared in two ways:

      * radix-tree prefix reuse — ``match_prefix`` maps a new session's
        token ids onto the pages of any previously committed identical
        prefix, so only the new suffix is prefilled;
      * COW forks — ``fork`` clones a session's table for n-best /
        tool-use branches; both branches share every page until one
        writes into the (partial) boundary page, which
        ``prepare_extend`` then copies.

    ``refcount[p]`` = #sessions whose table holds p, + 1 if the radix
    index holds p.  Append-only writes land at positions ≥ the committed
    length, so full (indexed, shareable) pages are never written; the
    only write into a shared page would be the fork-shared partial
    boundary page, and that is exactly the COW trigger.  A page returns
    to the free pool when its refcount drops to zero; when the pool runs
    dry, LRU leaf pages held only by the index are evicted
    (oversubscription: the index may cache far more prefix than live
    sessions could pin).

    ``cfg=None`` builds a bookkeeping-only arena (no device arrays) for
    property tests of the share/fork/evict/write state machine.
    """

    def __init__(self, cfg: Optional[ModelConfig], num_pages: int,
                 page_size: int, max_len: int, dtype=None,
                 prefix_cache: bool = True):
        self.cfg = cfg
        self.num_pages = num_pages
        self.page_size = page_size
        self.max_len = max_len
        self.scratch: int = num_pages          # reserved, never allocated
        self.arena = (tr.init_cache(cfg, num_pages + 1, page_size, dtype)
                      if cfg is not None else None)
        self._free: List[int] = list(range(num_pages))
        self._refcount: List[int] = [0] * num_pages
        self._pages: Dict[int, List[int]] = {}     # session -> page list
        self._tokens: Dict[int, List[int]] = {}    # session -> cached ids
        self.lengths: Dict[int, int] = {}          # session -> tokens cached
        self.index: Optional[RadixPageIndex] = (
            RadixPageIndex(page_size) if prefix_cache else None)
        # proof counters (engine.stats())
        self.prefix_hit_tokens = 0
        self.pages_cow_forked = 0
        self.pages_evicted = 0
        # the paged paths never materialize whole sequences: kept for
        # stats() symmetry with KVArena and asserted == 0 by benches
        self.gather_calls = 0
        self.scatter_calls = 0

    # ---------------------------------------------------------- refcounts
    def _ref(self, page: int) -> None:
        self._refcount[page] += 1

    def _unref(self, page: int) -> None:
        rc = self._refcount[page] = self._refcount[page] - 1
        assert rc >= 0, f"page {page} refcount underflow"
        if rc == 0:
            self._free.append(page)

    def _alloc_page(self) -> int:
        if not self._free:
            self._evict(1)
        if not self._free:
            raise RuntimeError("KV page pool exhausted")
        page = self._free.pop()
        self._refcount[page] = 1
        return page

    def _evict(self, need: int) -> None:
        """LRU-evict leaf pages held ONLY by the radix index."""
        if self.index is None:
            return
        freed = 0
        while freed < need:
            victim = None
            for leaf in self.index.leaves():
                if self._refcount[leaf.page] != 1:
                    continue                   # pinned by a live session
                if victim is None or leaf.last_use < victim.last_use:
                    victim = leaf
            if victim is None:
                return
            self._unref(self.index.remove(victim))
            self.pages_evicted += 1
            freed += 1

    # ------------------------------------------------------------ sessions
    def open(self, session: int) -> None:
        if session in self._pages:
            return
        self._pages[session] = []
        self._tokens[session] = []
        self.lengths[session] = 0

    def free(self, session: int) -> None:
        pages = self._pages.pop(session, None)
        if pages is None:
            return
        for p in pages:
            self._unref(p)
        self._tokens.pop(session, None)
        self.lengths.pop(session, None)

    def pages_of(self, session: int) -> List[int]:
        return self._pages.get(session, [])

    def length(self, session: int) -> int:
        return self.lengths.get(session, 0)

    @property
    def free_pages(self) -> int:
        return len(self._free)

    @property
    def max_pages_per_seq(self) -> int:
        return self.max_len // self.page_size

    # -------------------------------------------------------- prefix reuse
    def probe_prefix(self, tokens: Sequence[int]) -> int:
        """Tokens a fresh session with this prompt would NOT re-prefill
        (non-adopting; used by the serve loop for length-aware
        scheduling of the true suffix)."""
        if self.index is None:
            return 0
        return len(self.index.match(tokens, touch=False)) * self.page_size

    def match_prefix(self, session: int, tokens: Sequence[int]) -> int:
        """Map the longest indexed prefix of ``tokens`` onto existing
        pages; the session then only prefills ``tokens[matched:]``.

        Only valid on an EMPTY session (a turn's full conversation is
        matched once, before its first prefill).  Returns the matched
        token count (multiple of page_size, ≤ len(tokens) − 1).
        """
        self.open(session)
        assert self.lengths[session] == 0 and not self._pages[session], \
            f"match_prefix on non-empty session {session}"
        if self.index is None:
            return 0
        pages = self.index.match(tokens)
        if not pages:
            return 0
        matched = len(pages) * self.page_size
        for p in pages:
            self._ref(p)
        self._pages[session] = list(pages)
        self._tokens[session] = list(tokens[:matched])
        self.lengths[session] = matched
        self.prefix_hit_tokens += matched
        return matched

    # --------------------------------------------------------------- write
    def prepare_extend(self, session: int, n: int) -> List[int]:
        """Make positions [length, length + n) writable: COW-copy the
        fork-shared partial boundary page (the ONLY shareable page a
        write can touch — full pages are append-safe) and allocate fresh
        pages for the tail.  Returns the session's page list; every page
        overlapping the write range is exclusively owned afterwards."""
        self.open(session)
        h = self.lengths[session]
        if h + n > self.max_len - 2:
            raise RuntimeError(
                f"session {session} overflows arena "
                f"({h + n} > {self.max_len - 2})")
        ps = self.page_size
        pages = self._pages[session]
        if h % ps and self._refcount[pages[h // ps]] > 1:
            src = pages[h // ps]
            dst = self._alloc_page()
            self._copy_page(src, dst)
            self._unref(src)
            pages[h // ps] = dst
            self.pages_cow_forked += 1
        last = (h + n - 1) // ps
        while len(pages) <= last:
            pages.append(self._alloc_page())
        return pages

    def commit(self, session: int, token_ids: Sequence[int]) -> None:
        """Record ``token_ids`` as written at [length, length + n) (the
        step already scatter-wrote their KV via prepare_extend's pages)
        and index every newly-FULL page for cross-session reuse."""
        toks = self._tokens[session]
        toks.extend(int(t) for t in token_ids)
        self.lengths[session] += len(token_ids)
        if self.index is not None:
            n_full = self.lengths[session] // self.page_size
            for p in self.index.insert(toks[:n_full * self.page_size],
                                       self._pages[session][:n_full]):
                self._ref(p)

    # ------------------------------------------------------------ rollback
    def truncate(self, session: int, n: int) -> None:
        """Speculative rollback (DESIGN.md §10): forget every cached
        token past ``n``.

        Three things unwind, in order:

        1. **Radix de-index** — the session's indexed chunk path is
           walked and suffix nodes covering chunks ≥ ``n // ps`` are
           unlinked deepest-first, including the boundary chunk whose
           page goes full → partial (an indexed page must stay
           append-only; the session will write into the partial page
           again).  A node survives when it still has children (a longer
           indexed prefix — shared, not ours to drop) or when it names a
           different physical page (a private duplicate was never
           indexed); in either case every shallower node survives too,
           and any such still-indexed boundary page keeps rc > 1 so
           ``prepare_extend``'s COW shields it from the re-extend.
        2. **Page-refcount release** — the session unrefs every page
           past ``ceil(n / ps)``; pages held by the index or by a fork
           sibling stay alive (rc > 0), exclusively-owned tails return
           to the free pool.  Pages over-allocated by a speculative
           ``prepare_extend`` (never committed) are released the same
           way even when ``n == length``.
        3. **Token trim** — ``_tokens``/``lengths`` shrink to ``n``.

        ``audit()`` holds afterwards: every unref is mirrored by a table
        or index removal.
        """
        h = self.lengths.get(session, 0)
        if not 0 <= n <= h:
            raise ValueError(
                f"truncate session {session} to {n} outside [0, {h}]")
        self.open(session)
        ps = self.page_size
        toks = self._tokens[session]
        pages = self._pages[session]
        new_full = n // ps
        keep_pages = -(-n // ps)
        if self.index is not None:
            # the session's indexed chain, chunk by chunk
            path: List[_RadixNode] = []
            node = self.index.root
            for i in range(h // ps):
                child = node.children.get(tuple(toks[i * ps:(i + 1) * ps]))
                if child is None:
                    break
                path.append(child)
                node = child
            for i in range(len(path) - 1, new_full - 1, -1):
                nd = path[i]
                if nd.children or nd.page != pages[i]:
                    break
                self._unref(self.index.remove(nd))
        for p in pages[keep_pages:]:
            self._unref(p)
        del pages[keep_pages:]
        del toks[n:]
        self.lengths[session] = n

    # ---------------------------------------------------------------- fork
    def fork(self, parent: int, child: int) -> None:
        """COW-fork: the child shares every page (and the token history)
        of the parent; diverging writes copy the partial boundary page
        on demand (prepare_extend)."""
        assert child not in self._pages, f"fork onto live session {child}"
        self.open(child)
        for p in self._pages[parent]:
            self._ref(p)
        self._pages[child] = list(self._pages[parent])
        self._tokens[child] = list(self._tokens[parent])
        self.lengths[child] = self.lengths[parent]

    # ------------------------------------------------------------- handoff
    def export_pages(self, session: int) -> Any:
        """Handoff source (DESIGN.md §9): gather the session's page rows
        from the pool as DEVICE arrays (no host transfer)."""
        pages = self._pages.get(session, [])
        if self.arena is None or not pages:
            return None
        idx = jnp.asarray(pages, jnp.int32)
        return jax.tree.map(lambda a: jnp.take(a, idx, axis=1), self.arena)

    def import_session(self, session: int, token_ids: Sequence[int],
                       kv: Any, n_tokens: int) -> List[int]:
        """Handoff destination: allocate fresh pages, device-copy the
        exported pool rows into them, rebuild the session bookkeeping,
        and index every full page — the imported prefix becomes
        shareable here exactly as if it had been prefilled locally."""
        self.open(session)
        assert self.lengths[session] == 0 and not self._pages[session], \
            f"import into non-empty session {session}"
        if n_tokens > self.max_len - 2:
            raise RuntimeError(
                f"imported session {session} overflows arena "
                f"({n_tokens} > {self.max_len - 2})")
        ps = self.page_size
        n_pages = -(-n_tokens // ps)
        pages = [self._alloc_page() for _ in range(n_pages)]
        if self.arena is not None and kv is not None and pages:
            idx = jnp.asarray(pages, jnp.int32)
            self.arena = jax.tree.map(
                lambda a, b: a.at[:, idx].set(b.astype(a.dtype)),
                self.arena, kv)
        self._pages[session] = pages
        self._tokens[session] = [int(t) for t in token_ids[:n_tokens]]
        self.lengths[session] = n_tokens
        if self.index is not None:
            n_full = n_tokens // ps
            for p in self.index.insert(self._tokens[session][:n_full * ps],
                                       pages[:n_full]):
                self._ref(p)
        return pages

    # ------------------------------------------------------- device arrays
    def _copy_page(self, src: int, dst: int) -> None:
        if self.arena is None:
            return
        self.arena = jax.tree.map(
            lambda a: a.at[:, dst].set(a[:, src]), self.arena)

    def replace(self, new_arena: Any) -> None:
        """Swap in the page pool returned by a paged step (donated)."""
        self.arena = new_arena

    # --------------------------------------------------------------- audit
    def audit(self) -> None:
        """Assert the refcount/free-list/scratch invariants (tests)."""
        rc = [0] * self.num_pages
        for pages in self._pages.values():
            for p in pages:
                assert p != self.scratch, "scratch page in a session table"
                rc[p] += 1
        if self.index is not None:
            for p in self.index.pages():
                assert p != self.scratch, "scratch page in the radix index"
                rc[p] += 1
        assert rc == self._refcount, \
            f"refcount drift: counted {rc} != tracked {self._refcount}"
        assert sorted(self._free) == sorted(set(self._free)), \
            "duplicate pages in the free list"
        for p in self._free:
            assert p != self.scratch and self._refcount[p] == 0, \
                f"free page {p} still referenced"
        for p, r in enumerate(self._refcount):
            assert (r == 0) == (p in set(self._free)), \
                f"page {p} rc={r} free-list membership mismatch"
