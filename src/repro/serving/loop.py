"""Wall-clock serving loop: core policies driving the real JAX engine.

This is the production composition (launch/serve.py wraps it):

  requests → DualQueue classification → AWD short batches / chunked
  long prefills → bucketized AOT executables → KV arena → decode.

The same policy objects run in the simulator under a virtual clock; here
they schedule real JAX computations, TTFTs are real wall-clock, and the
engine's (T, L, H) samples continuously re-fit the §2.1 boundary.

Continuous batching (DESIGN.md §4): sessions submitted with
``decode_tokens > 0`` keep generating after their prefill completes.
Instead of alternating prefill and decode phases, every scheduler tick
drives ONE mixed step — the packed flat stream carries the tick's short
prefills (or the long-prefill chunk) plus one decode token for each
in-flight session, so prefill and decode share a single dispatch.  The
decode backlog is reported to the policy, which shrinks the AWD waiting
window (a stalled window stalls every session's TPOT) and reserves
stream rows for the fused decode segments.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Dict, List, Optional, Tuple

import numpy as np

from repro.core.request import Batch, Request
from repro.core.scheduler import BasePolicy, ChunkWork
from repro.core.slo import SLOTracker
from repro.serving import packing
from repro.serving.engine import Engine
from repro.serving.sampling import SamplingParams


@dataclasses.dataclass
class PendingRequest:
    req: Request
    tokens: np.ndarray            # prompt suffix past any adopted prefix
    decode_tokens: int = 0
    prompt: Optional[np.ndarray] = None   # full original prompt (deflection)
    sampling: Optional[SamplingParams] = None


class ServeLoop:
    def __init__(self, engine: Engine, policy: BasePolicy,
                 slo_ttft: Optional[float] = 0.4,
                 clock: Callable[[], float] = time.monotonic,
                 refit_every: int = 16,
                 max_queue: Optional[int] = None,
                 admission=None):
        self.engine = engine
        self.policy = policy
        self.clock = clock
        self.tracker = SLOTracker(slo_ttft)
        self.slo = slo_ttft
        # §11 admission control: a bounded intake queue plus an optional
        # CostModel-shaped estimator (anything with predicted_ttft(l, h,
        # queue_len, backlog_tokens, active_decodes)); a submit whose
        # predicted completion already violates its deadline is rejected
        # at the door.  Both default OFF — accept-everything.
        self.max_queue = max_queue
        self.admission = admission
        # §11 fault seams, wired by ServeCluster: a FaultInjector whose
        # dispatch_fails(engine_id, tick) is consulted before every
        # dispatch, plus this loop's id and a monotone tick counter
        self.faults = None
        self.engine_id = 0
        self.ticks = 0
        self.dispatch_faults = 0
        self._tokens: Dict[int, PendingRequest] = {}
        self._outstanding = 0
        self.refit_every = refit_every
        self._since_fit = 0
        self.first_tokens: Dict[int, int] = {}
        # continuous batching state: in-flight decode sessions
        self.active_decodes: Dict[int, int] = {}   # session → tokens left
        self.last_token: Dict[int, int] = {}
        self.generated: Dict[int, List[int]] = {}
        self.tpot_samples: List[float] = []        # s between decode tokens
        self.max_tpot_samples = 4096               # keep the tail only
        self._last_emit: Dict[int, float] = {}
        # tokens accepted for a session but not yet written to the engine
        # cache (queued prefills + unserved decode budgets): the history
        # estimate for a turn enqueued behind another turn of the same
        # session is engine.history + this
        self._session_pending: Dict[int, int] = {}
        # §11 recovery transcript: the EXACT token sequence the engine
        # cache holds per session (committed turn prompts + generated
        # tokens whose KV has been written), plus the one sampled-but-
        # unwritten "pending" token (its KV lands when it is fed as the
        # next decode input).  Re-prefilling _cache_tokens on a survivor
        # reproduces the crashed cache bit-for-bit; feeding the recorded
        # pending token resumes generation exactly where it stopped.
        self._cache_tokens: Dict[int, List[int]] = {}
        self._cache_pending: Dict[int, int] = {}
        # §12 cold-miss coalescing (wait-for-fill): concurrent COLD
        # submits of an identical full-page prompt prefix park behind
        # the first submit (the "filler") instead of each prefilling the
        # same pages; when the filler's prefill completes, the waiters
        # re-enter intake and adopt the freshly indexed pages.  Keyed by
        # the prompt's full-page chunk prefix.
        self._active_fills: Dict[Tuple[int, ...], int] = {}  # key → rid
        self._fill_waiters: Dict[Tuple[int, ...], List[Tuple]] = {}
        self.coalesced_prefills = 0
        # §12 chunk-level matching: re-probe the radix index at every
        # chunk boundary of a long prefill (False = the old submit-only
        # probe — kept as the measurement baseline for benches/tests)
        self.chunk_matching = True

    def _dec_pending(self, session: int, n: int) -> None:
        if n <= 0 or session not in self._session_pending:
            return
        left = self._session_pending[session] - n
        if left > 0:
            self._session_pending[session] = left
        else:
            self._session_pending.pop(session, None)

    def close_session(self, session: int) -> None:
        """Release a finished session: its engine slot and every piece
        of per-session loop state (transcripts, decode bookkeeping) —
        long-running loops must not accumulate dead sessions.  Queued
        turns for the session are purged FIRST: a later tick must never
        dispatch a prefill into the freed (or reallocated) slot."""
        for r in self.policy.purge(lambda q: q.session == session):
            self._tokens.pop(r.rid, None)
            self._outstanding -= 1
            self._finish_fill(r.rid)
        # parked waiters of the closing session vanish with it
        for key, ws in list(self._fill_waiters.items()):
            keep = [w for w in ws if w[0].session != session]
            self._outstanding -= len(ws) - len(keep)
            self._fill_waiters[key] = keep
        self.engine.close_session(session)
        self.active_decodes.pop(session, None)
        self.last_token.pop(session, None)
        self.generated.pop(session, None)
        self.first_tokens.pop(session, None)
        self._last_emit.pop(session, None)
        self._session_pending.pop(session, None)
        self._cache_tokens.pop(session, None)
        self._cache_pending.pop(session, None)

    # ------------------------------------------------------------ intake
    def submit(self, session: int, tokens: np.ndarray,
               decode_tokens: int = 0,
               deadline: Optional[float] = None,
               sampling: Optional[SamplingParams] = None) -> Request:
        """Queue one turn.  ``sampling`` attaches per-session decode
        options (temperature / top-k / top-p / logit-bias); None or
        temperature 0 without a bias is greedy.  They apply to the TTFT
        token and every generated token, on the fused mixed path and
        the bucketed decode path alike — every path ends in the same
        logits gather."""
        now = self.clock()
        ddl = deadline if deadline is not None else \
            (now + self.slo if self.slo else None)
        if self.max_queue is not None or self.admission is not None:
            r = self._admission_gate(session, tokens, now, ddl)
            if r is not None:
                return r
        # a new turn preempts any generation still running on the session
        # — including decode budgets of EARLIER turns still queued: those
        # tokens will never be generated, so the pending-token estimate
        # must forget them too
        preempted = self.active_decodes.pop(session, 0)
        # the preempted turn's sampled-but-unwritten token never reaches
        # the cache — the new turn prefills right after the committed
        # history, so the recovery transcript must forget it too
        self._cache_pending.pop(session, None)
        for p in self._tokens.values():
            if p.req.session == session and p.decode_tokens:
                preempted += p.decode_tokens
                p.decode_tokens = 0
        self._dec_pending(session, preempted)
        self.engine.open_session(session)
        self.engine.set_sampling(session, sampling)
        pending = self._session_pending.get(session, 0)
        # history ESTIMATE: cache length now plus every queued-but-unwritten
        # token of this session.  Reading engine.history alone is stale the
        # moment a second turn is submitted before the first dispatches —
        # wrong dual-queue classification and AWD billing.  The estimate is
        # refined to the exact cache length at dispatch time.
        hist = self.engine.history(session) + pending
        # paged engines with a radix prefix index: adopt the longest
        # indexed prefix of the prompt RIGHT HERE, so length-aware
        # classification, the AWD token budget, and the long-prefill
        # chunker all see (and slice) exactly the true suffix — the
        # matched pages are refcount-pinned while the request waits and
        # the prefill step only ever touches tokens past them (§8).
        # Adoption is gated on a TRULY empty session: adopting under a
        # queued prior turn would bump the arena length and corrupt the
        # queued turn's write offset.
        prompt = np.asarray(tokens)
        # §12 wait-for-fill: a COLD submit whose full-page prefix is
        # already being filled by an in-flight request parks behind that
        # filler — it re-enters intake on fill completion and adopts the
        # indexed pages instead of prefilling them a second time
        key = self._fill_key(prompt) if hist == 0 else None
        if key is not None and key in self._active_fills:
            r = Request(new_tokens=len(prompt), history_tokens=0,
                        arrival=now, deadline=ddl, session=session)
            self._fill_waiters[key].append(
                (r, prompt, decode_tokens, sampling))
            self._session_pending[session] = \
                pending + len(prompt) + decode_tokens
            self._outstanding += 1
            self.coalesced_prefills += 1
            return r
        reusable = self.engine.adopt_prefix(session, prompt) if hist == 0 \
            else 0
        tokens = prompt[reusable:]
        r = Request(new_tokens=len(tokens),
                    history_tokens=hist + reusable,
                    arrival=now, deadline=ddl,
                    session=session, reusable_prefix=reusable)
        self._tokens[r.rid] = PendingRequest(r, tokens, decode_tokens,
                                             prompt=prompt,
                                             sampling=sampling)
        self._session_pending[session] = \
            pending + len(tokens) + decode_tokens
        self.policy.enqueue(r, now)
        self._outstanding += 1
        # this request will index new full pages: register it as the
        # filler so identical cold submits park instead of duplicating
        if key is not None and key not in self._active_fills and \
                reusable < len(key):
            self._active_fills[key] = r.rid
            self._fill_waiters[key] = []
        return r

    def _fill_key(self, prompt: np.ndarray) -> Optional[Tuple[int, ...]]:
        """Coalescing key: the prompt's full-page chunk prefix (≥ 1 full
        page, keeping 1 token of true suffix).  None when the engine has
        no radix index or the prompt spans no full page."""
        eng = self.engine
        if not getattr(eng, "_paged", False) or eng.arena.index is None:
            return None
        ps = eng.arena.page_size
        n_full = max(len(prompt) - 1, 0) // ps
        if n_full == 0:
            return None
        return tuple(int(t) for t in prompt[:n_full * ps])

    def _finish_fill(self, rid: int) -> None:
        """Filler completion (or cancellation): release its parked
        waiters back through normal intake — they adopt whatever the
        radix index now holds (the full filled prefix on success, less
        on a withdrawn/abandoned filler) and queue only their true
        suffix."""
        key = next((k for k, v in self._active_fills.items() if v == rid),
                   None)
        if key is None:
            return
        del self._active_fills[key]
        waiters = self._fill_waiters.pop(key, [])
        now = self.clock()
        for r, prompt, decode_tokens, sampling in waiters:
            s = r.session
            self._dec_pending(s, len(prompt) + decode_tokens)
            self._outstanding -= 1
            pending = self._session_pending.get(s, 0)
            hist = self.engine.history(s) + pending
            reusable = self.engine.adopt_prefix(s, prompt) if hist == 0 \
                else 0
            suffix = prompt[reusable:]
            r.new_tokens = len(suffix)
            r.history_tokens = hist + reusable
            r.reusable_prefix = reusable
            self._tokens[r.rid] = PendingRequest(
                r, suffix, decode_tokens, prompt=prompt, sampling=sampling)
            self._session_pending[s] = \
                pending + len(suffix) + decode_tokens
            self.policy.enqueue(r, now)
            self._outstanding += 1

    def _admission_gate(self, session: int, tokens: np.ndarray,
                        now: float, ddl: Optional[float]
                        ) -> Optional[Request]:
        """§11 admission control, checked BEFORE any submit side effect
        (no session opened, no prefix adopted, nothing queued).  Returns
        the rejected Request (``rejected=True``, never enqueued) when the
        submit should be shed, else None.  Two triggers: a full bounded
        queue, and a predicted TTFT that already violates the deadline —
        serving a guaranteed violation only delays everyone behind it."""
        reject = False
        if self.max_queue is not None and \
                self.policy.queue_len() >= self.max_queue:
            reject = True
        elif self.admission is not None and ddl is not None:
            hist = self.engine.history(session) + \
                self._session_pending.get(session, 0)
            eta = now + self.admission.predicted_ttft(
                len(tokens), hist, self.policy.queue_len(),
                self.policy.backlog_tokens(), len(self.active_decodes))
            reject = eta > ddl
        if not reject:
            return None
        r = Request(new_tokens=len(np.asarray(tokens)),
                    history_tokens=self.engine.history(session),
                    arrival=now, deadline=ddl, session=session,
                    rejected=True)
        self.tracker.note_rejected()
        return r

    def withdraw(self, rid: int) -> Optional[PendingRequest]:
        """Deflection support (§9): pull a still-queued request back out
        of the loop — removed from the policy and every intake-side
        record as if it had never been submitted — so the cluster can
        re-route it.  Returns None when the request is unknown or has
        already dispatched (too late to bounce)."""
        pr = self._tokens.get(rid)
        if pr is None or pr.req.dispatch_time is not None:
            return None
        if not self.policy.purge(lambda q: q.rid == rid):
            return None
        self._tokens.pop(rid, None)
        self._outstanding -= 1
        session = pr.req.session
        self._dec_pending(session, len(pr.tokens) + pr.decode_tokens)
        # free the engine session when nothing else references it — the
        # withdrawn request wrote no KV (at most adopted pins, which
        # close releases)
        others = any(p.req.session == session
                     for p in self._tokens.values())
        if not others and session not in self.active_decodes and \
                self.engine.history(session) <= pr.req.reusable_prefix:
            self.engine.close_session(session)
        # a withdrawn filler releases its waiters (they adopt whatever
        # the index holds and prefill the rest themselves)
        self._finish_fill(rid)
        return pr

    # ------------------------------------------------- decode bookkeeping
    def _start_decoding(self, session: int, first_token: int,
                        budget: int, now: float) -> None:
        self.first_tokens[session] = first_token
        self.generated.setdefault(session, []).append(first_token)
        self.last_token[session] = first_token
        self._last_emit[session] = now
        # the freshly sampled TTFT token: emitted, but its KV is written
        # only when it is fed as the next decode input
        self._cache_pending[session] = first_token
        if budget > 0:
            self.active_decodes[session] = budget

    def _record_decoded(self, session: int, tokens: List[int],
                        now: float) -> None:
        """Commit the tokens one tick emitted for a session.  A
        speculative tick commits up to k+1 tokens in ONE dispatch
        (DESIGN.md §10); the tick's wall-clock gap covers that many
        inter-token intervals, so TPOT credits m samples of gap/m each —
        billing the full gap to every token would overstate TPOT m-fold,
        and billing it once would hide the speculative speedup."""
        m = len(tokens)
        if m == 0:
            return
        self.generated.setdefault(session, []).extend(tokens)
        self.last_token[session] = tokens[-1]
        # recovery transcript (§11): committing m tokens means the old
        # pending token plus the first m-1 new ones had their KV written
        # (each as a dispatch input row); the last new token becomes the
        # next pending.  Holds for plain 1-token ticks and speculative
        # multi-commits alike.
        pend = self._cache_pending.get(session)
        if pend is not None:
            seq = [pend] + tokens
            self._cache_tokens.setdefault(session, []).extend(seq[:-1])
            self._cache_pending[session] = seq[-1]
        gap = (now - self._last_emit.get(session, now)) / m
        self.tpot_samples.extend([gap] * m)
        if len(self.tpot_samples) > 2 * self.max_tpot_samples:
            self.tpot_samples = self.tpot_samples[-self.max_tpot_samples:]
        self._last_emit[session] = now
        self._dec_pending(session, m)   # these tokens' KV is now cached
        left = self.active_decodes.get(session, 0) - m
        if left > 0:
            self.active_decodes[session] = left
        else:
            self.active_decodes.pop(session, None)

    def _commit_turn(self, session: int, pr: PendingRequest) -> None:
        """Recovery transcript (§11): a turn's prompt enters the cache
        atomically when its prefill COMPLETES (last chunk included) —
        adopted prefix plus suffix, i.e. the full original prompt.
        Mid-turn partial chunks are deliberately not tracked: a crash
        mid-prefill restarts the turn from its full prompt."""
        full = pr.prompt if pr.prompt is not None else pr.tokens
        self._cache_tokens.setdefault(session, []).extend(
            int(t) for t in np.asarray(full).tolist())

    def _fusable_decodes(self, exclude: Tuple[int, ...] = ()
                         ) -> List[Tuple[int, int]]:
        return [(s, self.last_token[s]) for s in self.active_decodes
                if s not in exclude]

    def _tokens_per_decode(self) -> int:
        """Stream tokens one fused decode session costs this tick: 1 +
        spec_k when the engine runs speculative verify segments, else 1.
        Sizing the ladder/AWD reserves with this keeps verify segments
        from busting the token bucket mid-assembly."""
        return 1 + self.engine.spec_k if self.engine.spec_enabled else 1

    @staticmethod
    def _committed(res, session: int) -> List[int]:
        """Tokens a mixed step emitted for a fused decode session — the
        full speculative commit when present, the single sampled token
        otherwise."""
        if res.committed and session in res.committed:
            return list(res.committed[session])
        return [res.tokens[session]]

    # ----------------------------------------------------------- execute
    def _run_batch(self, batch: Batch) -> None:
        now = self.clock()
        sessions, token_lists = [], []
        for r in batch.requests:
            r.dispatch_time = now
            # the enqueue-time history was an estimate (a prior turn of
            # the session may still have been queued); the cache length
            # NOW is the truth the prefill writes against
            r.history_tokens = self.engine.history(r.session)
            pr = self._tokens[r.rid]
            sessions.append(r.session)
            token_lists.append(pr.tokens)
        px = self.engine.packed_executor
        if batch.is_packed:
            # the unified tick: fuse one decode token per in-flight
            # session into the prefill stream, up to the bucket's room
            fused: List[Tuple[int, int]] = []
            bucket = batch.token_bucket
            if px is not None:
                cand = self._fusable_decodes(exclude=tuple(sessions))
                n_fit, bucket = packing.fit_decodes(
                    sum(len(t) for t in token_lists), len(sessions),
                    len(cand), px.ladder, token_bucket=batch.token_bucket,
                    tokens_per_decode=self._tokens_per_decode())
                fused = cand[:n_fit]
            batch.decode_tokens = len(fused)
            res = self.engine.step_mixed(
                list(zip(sessions, token_lists)), fused,
                token_bucket=bucket,
                max_new={s: self.active_decodes[s] for s, _ in fused})
            firsts = res.tokens
            done = self.clock()
            for s, _ in fused:
                self._record_decoded(s, self._committed(res, s), done)
        else:
            bucket = None
            if batch.uses_graph:
                bucket = (batch.bucket_len, batch.bucket_depth)
            firsts = self.engine.prefill_batch(sessions, token_lists, bucket)
            done = self.clock()
        for r in batch.requests:
            r.finish_time = done
            self.tracker.record(r)
            pr = self._tokens.pop(r.rid)     # prefill served: drop prompt
            self._dec_pending(r.session, len(pr.tokens))
            self._commit_turn(r.session, pr)
            self._start_decoding(r.session, firsts[r.session],
                                 pr.decode_tokens, done)
            self._outstanding -= 1
            self._finish_fill(r.rid)         # release parked waiters

    def _run_chunk(self, work: ChunkWork) -> None:
        now = self.clock()
        r = work.req
        if r.dispatch_time is None:
            r.dispatch_time = now
            # first chunk: refine the enqueue-time history estimate to
            # the exact cache length (later chunks keep it — done chunks
            # are accounted by ChunkWork.done_tokens)
            r.history_tokens = self.engine.history(r.session)
        pr = self._tokens[r.rid]
        # §12 chunk-level matching: re-probe the radix index at this
        # chunk boundary — pages indexed since submit (another request's
        # fill that was still in flight back then) are adopted instead
        # of re-prefilled.  match_extend self-gates on page alignment
        # and keeps ≥ 1 token of true suffix, so the final chunk always
        # dispatches and produces the first-token logits.
        adopt = 0
        if self.chunk_matching and getattr(self.engine, "_paged", False) \
                and self.engine.arena.index is not None:
            rem = pr.tokens[work.done_tokens:]
            if len(rem) > 1:
                if self.engine.history(r.session) == 0:
                    # cold at submit, warm now: the first chunk gets the
                    # full-prompt match the submit-time probe missed
                    adopt = self.engine.adopt_prefix(r.session, rem)
                    # count it with the chunk-boundary hits: the submit
                    # probe missed these pages, the re-probe found them
                    self.engine.arena.chunk_hit_tokens += adopt
                else:
                    adopt = self.engine.arena.match_extend(
                        r.session, [int(t) for t in rem[:-1]])
        if adopt:
            self._dec_pending(r.session, adopt)
            work.chunk_tokens += adopt   # on_complete advances past them
            work.is_last = work.is_last or \
                (work.done_tokens + work.chunk_tokens >= len(pr.tokens))
        chunk = np.asarray(
            pr.tokens[work.done_tokens + adopt:
                      work.done_tokens + work.chunk_tokens])
        px = self.engine.packed_executor
        if px is not None:
            # a long-prefill chunk shares the packed stream with the
            # decode backlog instead of serializing against it
            cand = self._fusable_decodes(exclude=(r.session,))
            n_fit, bucket = packing.fit_decodes(
                len(chunk), 1, len(cand), px.ladder,
                tokens_per_decode=self._tokens_per_decode())
            fused = cand[:n_fit] if bucket is not None else []
            res = self.engine.step_mixed(
                [(r.session, chunk)], fused, token_bucket=bucket,
                max_new={s: self.active_decodes[s] for s, _ in fused})
            firsts = res.tokens
            done = self.clock()
            for s, _ in fused:
                self._record_decoded(s, self._committed(res, s), done)
        else:
            firsts = self.engine.prefill_batch([r.session], [chunk])
            done = self.clock()
        self._dec_pending(r.session, len(chunk))
        if work.is_last:
            r.finish_time = done
            self.tracker.record(r)
            self._tokens.pop(r.rid, None)    # all chunks served
            self._commit_turn(r.session, pr)
            self._start_decoding(r.session, firsts[r.session],
                                 pr.decode_tokens, done)
            self._outstanding -= 1
            self._finish_fill(r.rid)         # release parked waiters

    def _run_decode_only(self) -> None:
        """No prefill work this tick: advance every in-flight session in
        a single dispatch.  With a draft armed this is one speculative
        verify step — each session commits up to spec_k + 1 tokens per
        dispatch (DESIGN.md §10), capped by its remaining budget — else
        one token via the arena-resident bucketed decode path (or the
        dense gather step)."""
        sessions = list(self.active_decodes)
        if self.engine.spec_enabled:
            out = self.engine.spec_step(
                [(s, self.last_token[s]) for s in sessions],
                max_new=dict(self.active_decodes))
            done = self.clock()
            for s in sessions:
                self._record_decoded(s, out[s], done)
            return
        tokens = [self.last_token[s] for s in sessions]
        out = self.engine.decode_batch(sessions, tokens, steps=1)
        done = self.clock()
        for s in sessions:
            self._record_decoded(s, [out[s][0]], done)

    # --------------------------------------------------------------- run
    @property
    def has_work(self) -> bool:
        """True while any prefill is queued or any decode budget remains."""
        return self._outstanding > 0 or bool(self.active_decodes)

    def tick(self) -> Tuple[bool, Optional[float]]:
        """One unified scheduler tick: ask the policy for work, run it
        (or a decode-only step when the backlog is the only work), and
        periodically re-fit the §2.1 boundary.  Returns ``(did_work,
        wake_time)`` so multi-engine drivers (ServeCluster) can
        interleave many loops without nesting their drain loops."""
        now = self.clock()
        self.ticks += 1
        self.policy.note_decode_backlog(
            len(self.active_decodes),
            tokens_per_decode=self._tokens_per_decode())
        work, wake = self.policy.next_work(now)
        if work is not None and self.faults is not None and \
                self.faults.dispatch_fails(self.engine_id, self.ticks):
            # §11 injected dispatch exception: the engine never ran, so
            # the work re-enters the queue untouched.  A Batch was popped
            # by next_work — push its requests back (state intact: they
            # are still in _tokens, never dispatched).  A ChunkWork
            # retries for free: skipping on_complete leaves the chunk
            # progress unadvanced, so the same chunk is offered again.
            self.dispatch_faults += 1
            if isinstance(work, Batch) and work.requests:
                for r in work.requests:
                    self.policy.enqueue(r, now)
                self.tracker.note_retried(len(work.requests))
            else:
                self.tracker.note_retried(1)
            return True, wake
        did = True
        if isinstance(work, Batch) and work.requests:
            self._run_batch(work)
            self.policy.on_complete(work, self.clock())
        elif isinstance(work, ChunkWork):
            self._run_chunk(work)
            self.policy.on_complete(work, self.clock())
        elif self.active_decodes:
            # the decode backlog fills what would be an idle wait —
            # temporal sharing without a separate decode phase
            self._run_decode_only()
        else:
            did = False
        if self.engine.spec_dispatches:
            # mirror the engine's speculative totals into the tracker so
            # cluster-merged SLO reports carry acceptance statistics
            self.tracker.note_spec(self.engine.tokens_drafted,
                                   self.engine.tokens_accepted,
                                   self.engine.spec_dispatches,
                                   self.engine.spec_committed)
        self._since_fit += 1
        if self._since_fit >= self.refit_every:
            self._since_fit = 0
            fit = self.engine.fit_boundary()
            if fit is not None and hasattr(self.policy, "dq") and \
                    self.policy.dq.override is None:
                self.policy.dq.model = None  # fitted threshold wins
                self.policy.dq.override = fit.boundary()
        return did, wake

    def run_until_idle(self, max_wall: float = 60.0) -> None:
        """Drive the unified tick until every prefill AND every session's
        decode budget is drained.  If ``max_wall`` expires first, the
        still-queued prefills are ABANDONED — drained and recorded in the
        tracker (counter + violation accounting) instead of silently
        left behind as they used to be."""
        start = self.clock()
        while self.has_work and self.clock() - start < max_wall:
            did, wake = self.tick()
            if not did:
                now = self.clock()
                if wake is not None:
                    time.sleep(max(0.0, min(wake - now, 0.01)))
                else:
                    time.sleep(0.0005)
        if self._outstanding > 0:
            self.abandon_pending()

    def abandon_pending(self) -> int:
        """Drop every still-queued prefill, recording each as abandoned
        (§11: a timeout must never LOSE requests untracked).  In-flight
        decode budgets stay — their requests already produced a first
        token and were recorded; a later drive can resume them."""
        n = 0
        for r in self.policy.drain():
            pr = self._tokens.pop(r.rid, None)
            self._outstanding -= 1
            if pr is not None:
                self._dec_pending(r.session,
                                  len(pr.tokens) + pr.decode_tokens)
            self.tracker.note_abandoned(r)
            n += 1
        # parked wait-for-fill requests never reached the policy queue —
        # a timeout must not lose them untracked either
        for ws in self._fill_waiters.values():
            for r, prompt, decode_tokens, _ in ws:
                self._dec_pending(r.session, len(prompt) + decode_tokens)
                self._outstanding -= 1
                self.tracker.note_abandoned(r)
                n += 1
        self._fill_waiters.clear()
        self._active_fills.clear()
        return n

    # --------------------------------------------------------- recovery
    def restore_session(self, session: int, cache_tokens: List[int],
                        pending: Optional[int], generated: List[int],
                        budget: int, sampling=None,
                        first_token: Optional[int] = None) -> None:
        """Rebuild a crashed engine's session on THIS loop by re-prefill
        reconstruction (§11): replay the exact cache token sequence the
        dead arena held, then resume decoding from the recorded pending
        token.  On a paged engine the radix prefix index absorbs any
        indexed prefix, so recovery costs only the uncached suffix (§8).
        Greedy sessions continue bit-identically to a fault-free run:
        the cache contents and the pending input token are both exact.
        The reconstruction dispatches synchronously — bypassing the
        policy queue — so no queued turn can prefill against a
        half-rebuilt cache."""
        now = self.clock()
        arr = np.asarray(cache_tokens, dtype=np.int64)
        self.engine.open_session(session)
        self.engine.set_sampling(session, sampling)
        if len(arr):
            reusable = self.engine.adopt_prefix(session, arr)
            suffix = arr[reusable:]
            if len(suffix):
                # chunked re-prefill through the normal packed path; the
                # recomputed final sample is discarded — the recorded
                # pending token is the ground truth (it was already
                # emitted to the client before the crash)
                self.engine.prefill_long(session, suffix)
        self._cache_tokens[session] = list(cache_tokens)
        if generated:
            self.generated[session] = list(generated)
        if first_token is not None:
            self.first_tokens[session] = first_token
        if pending is not None:
            self.last_token[session] = pending
            self._cache_pending[session] = pending
        self._last_emit[session] = now
        if budget > 0 and pending is not None:
            self.active_decodes[session] = budget
        self.tracker.note_recovered()

    def decode(self, session: int, steps: int) -> List[int]:
        """Manual greedy continuation (legacy API).  Keeps the loop's
        per-session bookkeeping coherent: ``last_token`` / ``generated``
        advance with the engine cache, so a later unified tick fuses the
        session from the RIGHT token (not a stale one)."""
        first = self.last_token.get(session,
                                    self.first_tokens.get(session, 0))
        out = self.engine.decode_batch([session], [first], steps)
        toks = out[session]
        if session in self.last_token or session in self.generated:
            now = self.clock()
            self.generated.setdefault(session, []).extend(toks)
            self.last_token[session] = toks[-1]
            self._last_emit[session] = now
            pend = self._cache_pending.get(session)
            if pend is not None and toks:
                self._cache_tokens.setdefault(session, []).extend(
                    [pend] + toks[:-1])
                self._cache_pending[session] = toks[-1]
        return [first] + toks
