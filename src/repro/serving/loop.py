"""Wall-clock serving loop: core policies driving the real JAX engine.

This is the production composition (launch/serve.py wraps it):

  requests → DualQueue classification → AWD short batches / chunked
  long prefills → bucketized AOT executables → KV arena → decode.

The same policy objects run in the simulator under a virtual clock; here
they schedule real JAX computations, TTFTs are real wall-clock, and the
engine's (T, L, H) samples continuously re-fit the §2.1 boundary.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Dict, List, Optional

import numpy as np

from repro.core.request import Batch, Request
from repro.core.scheduler import BasePolicy, ChunkWork
from repro.core.slo import SLOTracker
from repro.serving.engine import Engine


@dataclasses.dataclass
class PendingRequest:
    req: Request
    tokens: np.ndarray
    decode_tokens: int = 0


class ServeLoop:
    def __init__(self, engine: Engine, policy: BasePolicy,
                 slo_ttft: Optional[float] = 0.4,
                 clock: Callable[[], float] = time.monotonic,
                 refit_every: int = 16):
        self.engine = engine
        self.policy = policy
        self.clock = clock
        self.tracker = SLOTracker(slo_ttft)
        self.slo = slo_ttft
        self._tokens: Dict[int, PendingRequest] = {}
        self._outstanding = 0
        self.refit_every = refit_every
        self._since_fit = 0
        self.first_tokens: Dict[int, int] = {}

    # ------------------------------------------------------------ intake
    def submit(self, session: int, tokens: np.ndarray,
               decode_tokens: int = 0,
               deadline: Optional[float] = None) -> Request:
        now = self.clock()
        self.engine.open_session(session)
        r = Request(new_tokens=len(tokens),
                    history_tokens=self.engine.history(session),
                    arrival=now,
                    deadline=deadline if deadline is not None else
                    (now + self.slo if self.slo else None),
                    session=session)
        self._tokens[r.rid] = PendingRequest(r, np.asarray(tokens),
                                             decode_tokens)
        self.policy.enqueue(r, now)
        self._outstanding += 1
        return r

    # ----------------------------------------------------------- execute
    def _run_batch(self, batch: Batch) -> None:
        now = self.clock()
        sessions, token_lists = [], []
        for r in batch.requests:
            r.dispatch_time = now
            pr = self._tokens[r.rid]
            sessions.append(r.session)
            token_lists.append(pr.tokens)
        if batch.is_packed:
            firsts = self.engine.prefill_packed(sessions, token_lists,
                                                batch.token_bucket)
        else:
            bucket = None
            if batch.uses_graph:
                bucket = (batch.bucket_len, batch.bucket_depth)
            firsts = self.engine.prefill_batch(sessions, token_lists, bucket)
        done = self.clock()
        for r in batch.requests:
            r.finish_time = done
            self.tracker.record(r)
            self.first_tokens[r.session] = firsts[r.session]
            self._outstanding -= 1

    def _run_chunk(self, work: ChunkWork) -> None:
        now = self.clock()
        r = work.req
        if r.dispatch_time is None:
            r.dispatch_time = now
        pr = self._tokens[r.rid]
        chunk = pr.tokens[work.done_tokens:work.done_tokens + work.chunk_tokens]
        firsts = self.engine.prefill_batch([r.session], [np.asarray(chunk)])
        if work.is_last:
            r.finish_time = self.clock()
            self.tracker.record(r)
            self.first_tokens[r.session] = firsts[r.session]
            self._outstanding -= 1

    # --------------------------------------------------------------- run
    def run_until_idle(self, max_wall: float = 60.0) -> None:
        start = self.clock()
        while self._outstanding > 0 and self.clock() - start < max_wall:
            now = self.clock()
            work, wake = self.policy.next_work(now)
            if isinstance(work, Batch) and work.requests:
                self._run_batch(work)
                self.policy.on_complete(work, self.clock())
            elif isinstance(work, ChunkWork):
                self._run_chunk(work)
                self.policy.on_complete(work, self.clock())
            elif wake is not None:
                time.sleep(max(0.0, min(wake - now, 0.01)))
            else:
                time.sleep(0.0005)
            self._since_fit += 1
            if self._since_fit >= self.refit_every:
                self._since_fit = 0
                fit = self.engine.fit_boundary()
                if fit is not None and hasattr(self.policy, "dq") and \
                        self.policy.dq.override is None:
                    self.policy.dq.model = None  # fitted threshold wins
                    self.policy.dq.override = fit.boundary()

    def decode(self, session: int, steps: int) -> List[int]:
        first = self.first_tokens.get(session, 0)
        out = self.engine.decode_batch([session], [first], steps)
        return [first] + out[session]
