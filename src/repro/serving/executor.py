"""Bucketized AOT-executable cache — the TPU analogue of CUDA Graph
capture (§3.1, DESIGN.md §2).

Each shape is lowered + compiled ONCE (``jax.jit(...).lower(...)
.compile()``) and re-dispatched with zero retracing afterwards.  A shape
miss costs a fresh compile — seconds, like the paper's 8–12 s per-graph
capture — which is precisely why the scheduler pads to the captured
grid.  Compile times, hit/miss statistics, and padding-efficiency
counters are recorded for the §4.2 cost analysis.

Two executors share the cache machinery:

  * :class:`BucketExecutor` — the dense (L, B) grid: every batch is
    padded to a captured (length, depth) shape, so the worst-case key
    space is |lengths| × |depths|.
  * :class:`PackedBucketExecutor` — the padding-free packed path: all
    requests are concatenated into one flat token stream bucketed on
    TOTAL tokens only, so the key space is |token buckets|.  Cache rows
    (max_seqs) and the arena S_max are fixed at construction, keeping
    every packed shape independent of the batch composition.
"""
from __future__ import annotations

import time
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.buckets import (DEFAULT_DECODE_BUCKETS, DEFAULT_TOKEN_BUCKETS,
                                DecodeBucketLadder, TokenBucketLadder)
from repro.models import transformer as tr
from repro.models.config import ModelConfig


def make_prefill_fn(cfg: ModelConfig) -> Callable:
    """(params, tokens(B,L), positions(B,L), caches, sample_idx(B,)) →
    (last_logits(B,V), new_caches).  Covers first prefill AND re-prefill
    (positions carry the history offset)."""

    def prefill_step(params, tokens, positions, caches, sample_idx):
        logits, new_caches, _ = tr.forward(
            params, cfg, tokens=tokens, positions=positions, caches=caches,
            seq_valid_len=sample_idx + 1)
        last = jnp.take_along_axis(
            logits, sample_idx[:, None, None], axis=1)[:, 0]
        return last, new_caches

    return prefill_step


def make_packed_prefill_fn(cfg: ModelConfig) -> Callable:
    """(params, tokens(T,), positions(T,), seg_ids(T,), cu_seqlens(B+1,),
    q_offsets(B,), kv_lengths(B,), caches, last_idx(B,)) →
    (last_logits(B,V), new_caches).  Padding-free packed prefill."""

    def packed_step(params, tokens, positions, seg_ids, cu_seqlens,
                    q_offsets, kv_lengths, caches, last_idx):
        return tr.forward_packed(
            params, cfg, tokens=tokens, positions=positions,
            seg_ids=seg_ids, cu_seqlens=cu_seqlens, q_offsets=q_offsets,
            kv_lengths=kv_lengths, caches=caches, last_idx=last_idx)

    return packed_step


def make_packed_arena_fn(cfg: ModelConfig) -> Callable:
    """(params, tokens(T,), positions(T,), seg_slots(T,), slot_map(B,),
    cu_seqlens(B+1,), q_offsets(B,), kv_lengths(B,), arena, last_idx(B,))
    → (last_logits(B,V), greedy_ids(B,), new_arena).  Arena-resident
    packed prefill: the KV arena is read in place (slot axis indexed
    inside the kernel) and only the step's new KV rows are written.
    ``greedy_ids`` is the on-device argmax of each row — all-greedy
    steps take their tokens from it without shipping the full-vocab
    logits to host."""

    def packed_step(params, tokens, positions, seg_slots, slot_map,
                    cu_seqlens, q_offsets, kv_lengths, arena, last_idx):
        last, new_arena = tr.forward_packed_arena(
            params, cfg, tokens=tokens, positions=positions,
            seg_slots=seg_slots, slot_map=slot_map, cu_seqlens=cu_seqlens,
            q_offsets=q_offsets, kv_lengths=kv_lengths, arena=arena,
            last_idx=last_idx)
        return last, jnp.argmax(last, axis=-1).astype(jnp.int32), new_arena

    return packed_step


def make_packed_paged_fn(cfg: ModelConfig) -> Callable:
    """(params, tokens(T,), positions(T,), token_pages(T,), token_offs(T,),
    page_table(B,P_max), cu_seqlens(B+1,), q_offsets(B,), kv_lengths(B,),
    arena, last_idx(B,), state_map(B,)) → (last_logits(B,V),
    greedy_ids(B,), new_arena).  Paged packed prefill (DESIGN.md §8/§12):
    the page pool is read in place through a per-block page table, so
    segments can SHARE pages (radix prefix reuse, COW forks) inside one
    step; SSM positions step the state page named by ``state_map``."""

    def packed_step(params, tokens, positions, token_pages, token_offs,
                    page_table, cu_seqlens, q_offsets, kv_lengths, arena,
                    last_idx, state_map):
        last, new_arena = tr.forward_packed_paged(
            params, cfg, tokens=tokens, positions=positions,
            token_pages=token_pages, token_offs=token_offs,
            page_table=page_table, cu_seqlens=cu_seqlens,
            q_offsets=q_offsets, kv_lengths=kv_lengths, arena=arena,
            last_idx=last_idx, state_map=state_map)
        return last, jnp.argmax(last, axis=-1).astype(jnp.int32), new_arena

    return packed_step


def make_packed_verify_arena_fn(cfg: ModelConfig) -> Callable:
    """(params, tokens(T,), positions(T,), seg_slots(T,), slot_map(B,),
    cu_seqlens(B+1,), q_offsets(B,), kv_lengths(B,), arena,
    gather_idx(B,L)) → (logits(B,L,V), greedy_ids(B,L), new_arena).
    Speculative verification (DESIGN.md §10): the unchanged arena
    dispatch gathering EVERY row's logits per segment instead of one.
    ``greedy_ids`` is the per-row on-device argmax — all-greedy
    acceptance walks it without shipping (B, L, V) to host."""

    def verify_step(params, tokens, positions, seg_slots, slot_map,
                    cu_seqlens, q_offsets, kv_lengths, arena, gather_idx):
        logits, new_arena = tr.forward_packed_verify_arena(
            params, cfg, tokens=tokens, positions=positions,
            seg_slots=seg_slots, slot_map=slot_map, cu_seqlens=cu_seqlens,
            q_offsets=q_offsets, kv_lengths=kv_lengths, arena=arena,
            gather_idx=gather_idx)
        return (logits, jnp.argmax(logits, axis=-1).astype(jnp.int32),
                new_arena)

    return verify_step


def make_packed_verify_paged_fn(cfg: ModelConfig) -> Callable:
    """(params, tokens(T,), positions(T,), token_pages(T,), token_offs(T,),
    page_table(B,P_max), cu_seqlens(B+1,), q_offsets(B,), kv_lengths(B,),
    arena, gather_idx(B,L), state_map(B,)) → (logits(B,L,V),
    greedy_ids(B,L), new_pool).  Paged speculative verification
    (DESIGN.md §10)."""

    def verify_step(params, tokens, positions, token_pages, token_offs,
                    page_table, cu_seqlens, q_offsets, kv_lengths, arena,
                    gather_idx, state_map):
        logits, new_arena = tr.forward_packed_verify_paged(
            params, cfg, tokens=tokens, positions=positions,
            token_pages=token_pages, token_offs=token_offs,
            page_table=page_table, cu_seqlens=cu_seqlens,
            q_offsets=q_offsets, kv_lengths=kv_lengths, arena=arena,
            gather_idx=gather_idx, state_map=state_map)
        return (logits, jnp.argmax(logits, axis=-1).astype(jnp.int32),
                new_arena)

    return verify_step


def make_paged_decode_fn(cfg: ModelConfig) -> Callable:
    """(params, tokens(B,), positions(B,), write_pages(B,), write_offs(B,),
    page_table(B,P_max), kv_lengths(B,), arena, state_map(B,)) →
    (logits(B,V), greedy_ids(B,), new_arena).  Paged decode
    (DESIGN.md §8/§12)."""

    def decode_step(params, tokens, positions, write_pages, write_offs,
                    page_table, kv_lengths, arena, state_map):
        logits, new_arena = tr.forward_decode_paged(
            params, cfg, tokens=tokens, positions=positions,
            write_pages=write_pages, write_offs=write_offs,
            page_table=page_table, kv_lengths=kv_lengths, arena=arena,
            state_map=state_map)
        return (logits, jnp.argmax(logits, axis=-1).astype(jnp.int32),
                new_arena)

    return decode_step


def make_decode_fn(cfg: ModelConfig) -> Callable:
    def decode_step(params, tokens, positions, caches):
        logits, new_caches, _ = tr.forward(
            params, cfg, tokens=tokens, positions=positions, caches=caches,
            logits_slice="last")
        return logits, new_caches

    return decode_step


def make_arena_decode_fn(cfg: ModelConfig) -> Callable:
    """(params, tokens(B,), slot_map(B,), write_pos(B,), kv_lengths(B,),
    arena) → (logits(B,V), greedy_ids(B,), new_arena).  Arena-resident
    decode: the KV arena is read in place (slot axis indexed inside the
    kernel) and only the single new KV row per session is written.
    ``greedy_ids`` is the on-device argmax per row — all-greedy ticks
    take their tokens from it without shipping full-vocab logits to
    host (the fused-sampling greedy slice)."""

    def decode_step(params, tokens, slot_map, write_pos, kv_lengths, arena):
        logits, new_arena = tr.forward_decode_arena(
            params, cfg, tokens=tokens, slot_map=slot_map,
            write_pos=write_pos, kv_lengths=kv_lengths, arena=arena)
        return (logits, jnp.argmax(logits, axis=-1).astype(jnp.int32),
                new_arena)

    return decode_step


def resolve_donation(donate_cache: Optional[bool]) -> bool:
    """Effective cache-donation flag.

    None → donate on TPU only (the conservative historical default).
    An EXPLICIT True/False is always respected: jax supports buffer
    donation on CPU too, so a caller's choice must not be silently
    overridden (the old code dropped True on CPU without a trace)."""
    if donate_cache is None:
        return jax.default_backend() == "tpu"
    return bool(donate_cache)


class _ExecutorBase:
    """Compile-once shape cache + hit/miss + padding-efficiency stats."""

    def __init__(self) -> None:
        self._compiled: Dict[Tuple, Any] = {}
        self.compile_times: Dict[Tuple, float] = {}
        self.hits = 0
        self.misses = 0
        self.useful_tokens = 0     # real prompt tokens executed
        self.total_tokens = 0      # tokens incl. bucket/grid padding
        # per-kind dispatch accounting ("prefill" / "decode" / ...):
        # the aggregate hit rate hides a cold decode path behind a warm
        # prefill path, so each kind reports its own
        self.kind_hits: Dict[str, int] = {}
        self.kind_misses: Dict[str, int] = {}

    # --------------------------------------------------------------- keys
    @staticmethod
    def _key(kind: str, *arrays) -> Tuple:
        def sig(x):
            return tuple((l.shape, str(l.dtype)) for l in jax.tree.leaves(x))
        return (kind,) + tuple(sig(a) for a in arrays)

    def _get(self, kind: str, jitted, args) -> Any:
        key = self._key(kind, *args)
        exe = self._compiled.get(key)
        if exe is None:
            self.misses += 1
            self.kind_misses[kind] = self.kind_misses.get(kind, 0) + 1
            t0 = time.perf_counter()
            exe = jitted.lower(*args).compile()
            self.compile_times[key] = time.perf_counter() - t0
            self._compiled[key] = exe
        else:
            self.hits += 1
            self.kind_hits[kind] = self.kind_hits.get(kind, 0) + 1
        return exe

    # ------------------------------------------------------------- stats
    def note_padding(self, useful: int, total: int) -> None:
        """Record one step's token accounting: ``useful`` real prompt
        tokens executed inside a shape of ``total`` tokens."""
        self.useful_tokens += int(useful)
        self.total_tokens += int(total)

    @property
    def padded_tokens(self) -> int:
        return self.total_tokens - self.useful_tokens

    @property
    def padding_efficiency(self) -> float:
        """useful / total executed tokens (1.0 = zero padding waste)."""
        return (self.useful_tokens / self.total_tokens
                if self.total_tokens else 1.0)

    def capture_cost(self) -> float:
        """Total 'graph capture' (compile) seconds — §4.2."""
        return sum(self.compile_times.values())

    @property
    def hit_rate(self) -> float:
        total = self.hits + self.misses
        return self.hits / total if total else 0.0

    @property
    def hit_rate_by_kind(self) -> Dict[str, float]:
        """Per-dispatch-kind compile-cache hit rates."""
        out: Dict[str, float] = {}
        for kind in set(self.kind_hits) | set(self.kind_misses):
            h = self.kind_hits.get(kind, 0)
            m = self.kind_misses.get(kind, 0)
            out[kind] = h / (h + m) if (h + m) else 0.0
        return out

    def shapes_by_kind(self) -> Dict[str, int]:
        """Compile-cache size per dispatch kind (key[0] is the kind)."""
        out: Dict[str, int] = {}
        for key in self.compile_times:
            out[key[0]] = out.get(key[0], 0) + 1
        return out

    @property
    def dispatches(self) -> int:
        """Total step dispatches (compile hits + misses) — the unit the
        continuous-batching benchmark counts: fusing decode into the
        packed stream shrinks this without shrinking work done."""
        return self.hits + self.misses


class BucketExecutor(_ExecutorBase):
    """The dense (L, B) bucket-grid executor (pads to captured shapes)."""

    def __init__(self, cfg: ModelConfig, donate_cache: Optional[bool] = None):
        super().__init__()
        self.cfg = cfg
        self.donate_cache = resolve_donation(donate_cache)
        self._prefill = make_prefill_fn(cfg)
        self._decode = make_decode_fn(cfg)
        donate = (3,) if self.donate_cache else ()
        self._jit_prefill = jax.jit(self._prefill, donate_argnums=donate)
        self._jit_decode = jax.jit(self._decode, donate_argnums=donate)

    # ---------------------------------------------------------- dispatch
    def prefill(self, params, tokens, positions, caches, sample_idx):
        exe = self._get("prefill", self._jit_prefill,
                        (params, tokens, positions, caches, sample_idx))
        return exe(params, tokens, positions, caches, sample_idx)

    def decode(self, params, tokens, positions, caches):
        exe = self._get("decode", self._jit_decode,
                        (params, tokens, positions, caches))
        return exe(params, tokens, positions, caches)

    def precapture(self, params, arena_gather, lengths, depths) -> float:
        """Capture the (L, B) grid at init (paper: graphs captured at
        system initialization).  Returns total capture seconds."""
        t0 = time.perf_counter()
        for b in depths:
            caches = arena_gather(list(range(b)))
            for l in lengths:
                tokens = jnp.zeros((b, l), jnp.int32)
                positions = jnp.zeros((b, l), jnp.int32)
                sample_idx = jnp.zeros((b,), jnp.int32)
                self._get("prefill", self._jit_prefill,
                          (params, tokens, positions, caches, sample_idx))
            tok1 = jnp.zeros((b, 1), jnp.int32)
            pos1 = jnp.zeros((b, 1), jnp.int32)
            self._get("decode", self._jit_decode,
                      (params, tok1, pos1, caches))
        return time.perf_counter() - t0


class PackedBucketExecutor(_ExecutorBase):
    """Padding-free packed prefill keyed on a 1-D total-token bucket.

    Every step runs one flat (T,) token stream with ``max_seqs`` cache
    rows gathered from the arena, so the compiled-shape space grows with
    |token_buckets| — not with the (length × depth) cross-product of the
    dense grid.  The only padding is the bucket tail T − Σ len_i.
    """

    def __init__(self, cfg: ModelConfig,
                 token_buckets: Tuple[int, ...] = DEFAULT_TOKEN_BUCKETS,
                 max_seqs: int = 16,
                 donate_cache: Optional[bool] = None):
        super().__init__()
        self.capability = tr.arena_capability(cfg)
        if not self.capability.packed_ok:
            raise ValueError(
                f"{cfg.name}: packed serving needs a causal decoder "
                "(encoder-only models have no serving decode loop)")
        self.cfg = cfg
        # scratch-slot arenas (rolling SWA / SSM state, DESIGN.md §7)
        # permanently reserve ONE stream row: bucket-tail tokens park
        # their junk writes in a dummy segment whose slot is the
        # scratch slot.  Folding the reservation into the ladder keeps
        # every consumer — the engine, ServeLoop's fit_decodes, AWD,
        # the simulator — agreeing on the schedulable room, so a fully
        # fused tick still dispatches as ONE packed step.
        self.reserve_pad_row = self.capability.needs_scratch_slot
        if self.reserve_pad_row:
            max_seqs = max_seqs - 1
            assert max_seqs >= 1, \
                "scratch-slot arenas need packed max_seqs >= 2"
        self.ladder = TokenBucketLadder(token_buckets, max_seqs)
        self.donate_cache = resolve_donation(donate_cache)
        # LEGACY gathered-cache form: whole arena slots copied out and
        # back around the step — pure-attention only (SSM state and
        # rolling SWA slots have no gathered equivalent), kept as the
        # measurement baseline
        self._jit_packed = None
        if self.capability.pure_attn:
            self._packed = make_packed_prefill_fn(cfg)
            self._jit_packed = jax.jit(
                self._packed,
                donate_argnums=(7,) if self.donate_cache else ())
        # arena-resident form (DESIGN.md §6/§7): the KV + state arenas
        # ride as an in-place argument (donated) instead of gathered
        # cache rows; per-layer routing from the capability descriptor
        self._packed_arena = make_packed_arena_fn(cfg)
        self._jit_packed_arena = jax.jit(
            self._packed_arena,
            donate_argnums=(8,) if self.donate_cache else ())
        # paged form (DESIGN.md §8/§12): per-block page table instead of
        # a per-segment slot — every packed_ok config (windowed layers
        # walk a ring table, SSM layers step per-session state pages)
        self._packed_paged = make_packed_paged_fn(cfg)
        self._jit_packed_paged = jax.jit(
            self._packed_paged,
            donate_argnums=(9,) if self.donate_cache else ())
        # speculative verification forms (DESIGN.md §10): the SAME
        # packed dispatch with an L-per-segment logits gather.  Their
        # compile cache is keyed on (token bucket, L) via the
        # gather_idx shape — fixed L keeps the shape space small
        self._verify_arena = make_packed_verify_arena_fn(cfg)
        self._jit_verify_arena = jax.jit(
            self._verify_arena,
            donate_argnums=(8,) if self.donate_cache else ())
        self._verify_paged = make_packed_verify_paged_fn(cfg)
        self._jit_verify_paged = jax.jit(
            self._verify_paged,
            donate_argnums=(9,) if self.donate_cache else ())
        # continuous-batching counters: a mixed step fuses decode rows
        # into the same packed stream (and the SAME compiled executable —
        # the shape key is (token bucket, max_seqs), not the segment mix)
        self.mixed_steps = 0
        self.decode_tokens_fused = 0
        # speculative counters: verify dispatches and draft rows verified
        self.verify_steps = 0
        self.verify_rows = 0

    # ------------------------------------------------------------ lookup
    @property
    def token_buckets(self) -> Tuple[int, ...]:
        return self.ladder.buckets

    @property
    def max_seqs(self) -> int:
        """Schedulable segments per step (pad-row reservation applied)."""
        return self.ladder.max_seqs

    @property
    def stream_rows(self) -> int:
        """Cache rows of the compiled stream shape: the schedulable
        segments plus the reserved scratch pad row, if any."""
        return self.ladder.max_seqs + (1 if self.reserve_pad_row else 0)

    def bucket_for(self, total_tokens: int) -> Optional[int]:
        """Smallest token bucket ≥ total_tokens (None if off-scale)."""
        return self.ladder.bucket_for(total_tokens)

    # ---------------------------------------------------------- dispatch
    def prefill_packed(self, params, tokens, positions, seg_ids, cu_seqlens,
                       q_offsets, kv_lengths, caches, last_idx):
        assert self._jit_packed is not None, \
            f"{self.cfg.name}: gathered-cache packed path is attention-only"
        args = (params, tokens, positions, seg_ids, cu_seqlens,
                q_offsets, kv_lengths, caches, last_idx)
        exe = self._get("packed_prefill", self._jit_packed, args)
        return exe(*args)

    def mixed_step(self, params, tokens, positions, seg_ids, cu_seqlens,
                   q_offsets, kv_lengths, caches, last_idx, *,
                   n_decode: int = 0):
        """One continuous-batching step: the flat stream carries prefill
        segments AND length-1 decode segments (history offsets point each
        decode row at its full cached context).

        Dispatches through the SAME compile-cache entry as a pure
        prefill of this (token bucket, max_seqs) shape — the executable
        is keyed on shapes only, so prefill, decode, and every mix in
        between share one captured step.  ``n_decode`` feeds the fusion
        counters."""
        if n_decode:
            self.mixed_steps += 1
            self.decode_tokens_fused += int(n_decode)
        return self.prefill_packed(params, tokens, positions, seg_ids,
                                   cu_seqlens, q_offsets, kv_lengths,
                                   caches, last_idx)

    def prefill_packed_arena(self, params, tokens, positions, seg_slots,
                             slot_map, cu_seqlens, q_offsets, kv_lengths,
                             arena, last_idx):
        args = (params, tokens, positions, seg_slots, slot_map, cu_seqlens,
                q_offsets, kv_lengths, arena, last_idx)
        exe = self._get("packed_arena", self._jit_packed_arena, args)
        return exe(*args)

    def mixed_step_arena(self, params, tokens, positions, seg_slots,
                         slot_map, cu_seqlens, q_offsets, kv_lengths,
                         arena, last_idx, *, n_decode: int = 0):
        """One arena-resident continuous-batching step (DESIGN.md §6):
        same flat stream and fusion semantics as :meth:`mixed_step`, but
        the KV arena is an ARGUMENT read in place — the kernel routes
        each segment's KV blocks through ``slot_map`` and the step
        writes only the new rows, so there is no whole-slot gather
        before it and no scatter after it.  The compile cache stays
        keyed on the token bucket (the arena shape is a constant); under
        donation the arena buffers update in place and the caller swaps
        the returned pytree into its KVArena."""
        if n_decode:
            self.mixed_steps += 1
            self.decode_tokens_fused += int(n_decode)
        return self.prefill_packed_arena(params, tokens, positions,
                                         seg_slots, slot_map, cu_seqlens,
                                         q_offsets, kv_lengths, arena,
                                         last_idx)

    def mixed_step_paged(self, params, tokens, positions, token_pages,
                         token_offs, page_table, cu_seqlens, q_offsets,
                         kv_lengths, arena, last_idx, state_map, *,
                         n_decode: int = 0):
        """One PAGED continuous-batching step (DESIGN.md §8): same flat
        stream and fusion semantics as :meth:`mixed_step_arena`, but the
        cache argument is the shared page POOL and each segment's KV is
        routed through its row of ``page_table`` — so segments can share
        prefix pages and a prefix-hit turn streams its full logical
        context while having prefilled only its suffix.  ``state_map``
        (B,) names each segment's SSM state page (scratch for pads /
        pure-attn configs).  The compile cache is keyed on (token
        bucket, P_max); the pool shape is a constant."""
        if n_decode:
            self.mixed_steps += 1
            self.decode_tokens_fused += int(n_decode)
        args = (params, tokens, positions, token_pages, token_offs,
                page_table, cu_seqlens, q_offsets, kv_lengths, arena,
                last_idx, state_map)
        exe = self._get("packed_paged", self._jit_packed_paged, args)
        return exe(*args)

    def verify_step_arena(self, params, tokens, positions, seg_slots,
                          slot_map, cu_seqlens, q_offsets, kv_lengths,
                          arena, gather_idx):
        """One speculative verification dispatch (DESIGN.md §10): the
        arena-resident packed step scoring every session's k-token draft
        segment at once, returning (logits (B, L, V), greedy_ids (B, L),
        new_arena).  Kernel-identical to :meth:`mixed_step_arena` — only
        the final logits gather widens from 1 to L rows per segment."""
        self.verify_steps += 1
        self.verify_rows += int(gather_idx.shape[0] * gather_idx.shape[1])
        args = (params, tokens, positions, seg_slots, slot_map, cu_seqlens,
                q_offsets, kv_lengths, arena, gather_idx)
        exe = self._get("verify_arena", self._jit_verify_arena, args)
        return exe(*args)

    def verify_step_paged(self, params, tokens, positions, token_pages,
                          token_offs, page_table, cu_seqlens, q_offsets,
                          kv_lengths, arena, gather_idx, state_map):
        """Paged speculative verification dispatch (DESIGN.md §10) —
        :meth:`verify_step_arena` over the shared page pool."""
        self.verify_steps += 1
        self.verify_rows += int(gather_idx.shape[0] * gather_idx.shape[1])
        args = (params, tokens, positions, token_pages, token_offs,
                page_table, cu_seqlens, q_offsets, kv_lengths, arena,
                gather_idx, state_map)
        exe = self._get("verify_paged", self._jit_verify_paged, args)
        return exe(*args)

    def precapture(self, params, arena_gather) -> float:
        """Compile every token bucket at init — |token_buckets| shapes
        total, vs |L|×|B| for the dense grid."""
        t0 = time.perf_counter()
        b = self.max_seqs
        caches = arena_gather(list(range(b)))
        for t in self.token_buckets:
            tokens = jnp.zeros((t,), jnp.int32)
            positions = jnp.zeros((t,), jnp.int32)
            seg_ids = jnp.zeros((t,), jnp.int32)
            cu = jnp.zeros((b + 1,), jnp.int32)
            off = jnp.zeros((b,), jnp.int32)
            kvl = jnp.zeros((b,), jnp.int32)
            last = jnp.zeros((b,), jnp.int32)
            self._get("packed_prefill", self._jit_packed,
                      (params, tokens, positions, seg_ids, cu, off, kvl,
                       caches, last))
        return time.perf_counter() - t0

    def precapture_arena(self, params, arena) -> float:
        """Compile every token bucket's arena-resident step at init —
        |token_buckets| shapes total.  Lower + compile only; the arena
        is never executed against (nor donated away)."""
        t0 = time.perf_counter()
        b = self.stream_rows
        for t in self.token_buckets:
            tokens = jnp.zeros((t,), jnp.int32)
            positions = jnp.zeros((t,), jnp.int32)
            seg_slots = jnp.zeros((t,), jnp.int32)
            slot_map = jnp.zeros((b,), jnp.int32)
            cu = jnp.zeros((b + 1,), jnp.int32)
            off = jnp.zeros((b,), jnp.int32)
            kvl = jnp.zeros((b,), jnp.int32)
            last = jnp.zeros((b,), jnp.int32)
            self._get("packed_arena", self._jit_packed_arena,
                      (params, tokens, positions, seg_slots, slot_map, cu,
                       off, kvl, arena, last))
        return time.perf_counter() - t0


class DecodeBucketExecutor(_ExecutorBase):
    """Arena-resident bucketed decode (mirrors :class:`PackedBucketExecutor`
    for the decode regime).

    A decode-only tick runs ONE executable whose batch axis is padded to
    a small decode-seqs ladder rung (default 1/2/4/8/16/32, capped at
    the arena depth), so the compile cache is keyed on the BUCKET — not
    the live session count.  N sessions draining at staggered rates
    compile at most |ladder| shapes instead of one per distinct count.

    The KV arena is an ARGUMENT, read in place: the kernel indexes the
    slot axis through a scalar-prefetched slot map and streams only
    valid cache prefixes, and the step writes back one KV row per
    session — no whole-slot gather/scatter.  Under donation the arena
    buffers update in place; the caller swaps the returned pytree into
    its KVArena.
    """

    def __init__(self, cfg: ModelConfig,
                 decode_buckets: Tuple[int, ...] = DEFAULT_DECODE_BUCKETS,
                 max_seqs: Optional[int] = None,
                 donate_cache: Optional[bool] = None):
        super().__init__()
        self.capability = tr.arena_capability(cfg)
        if not self.capability.packed_ok:
            raise ValueError(
                f"{cfg.name}: arena-resident decode needs a causal "
                "decoder (encoder-only models have no decode loop)")
        self.cfg = cfg
        self.ladder = DecodeBucketLadder(decode_buckets, max_seqs)
        self.donate_cache = resolve_donation(donate_cache)
        self._decode = make_arena_decode_fn(cfg)
        self._jit_decode = jax.jit(
            self._decode, donate_argnums=(5,) if self.donate_cache else ())
        # paged form (DESIGN.md §8/§12): every packed_ok config —
        # windowed layers walk a ring table, SSM layers step their
        # per-session state page through state_map
        self._decode_paged = make_paged_decode_fn(cfg)
        self._jit_decode_paged = jax.jit(
            self._decode_paged,
            donate_argnums=(7,) if self.donate_cache else ())

    # ------------------------------------------------------------ lookup
    @property
    def decode_buckets(self) -> Tuple[int, ...]:
        return self.ladder.buckets

    def bucket_for(self, n_seqs: int) -> Optional[int]:
        """Smallest ladder rung ≥ n_seqs (None → dense fallback)."""
        return self.ladder.bucket_for(n_seqs)

    # ---------------------------------------------------------- dispatch
    def decode(self, params, tokens, slot_map, write_pos, kv_lengths,
               arena):
        args = (params, tokens, slot_map, write_pos, kv_lengths, arena)
        exe = self._get("arena_decode", self._jit_decode, args)
        return exe(*args)

    def decode_paged(self, params, tokens, positions, write_pages,
                     write_offs, page_table, kv_lengths, arena, state_map):
        """One PAGED decode tick (DESIGN.md §8/§12): the page pool rides
        in place and each row's KV is routed through its page-table row —
        rows may share prefix pages.  ``state_map`` (B,) names each row's
        SSM state page (scratch for pads / pure-attn configs).  Compile
        cache keyed on the decode bucket × P_max."""
        args = (params, tokens, positions, write_pages, write_offs,
                page_table, kv_lengths, arena, state_map)
        exe = self._get("paged_decode", self._jit_decode_paged, args)
        return exe(*args)

    def precapture(self, params, arena) -> float:
        """Compile every decode rung at init — |ladder| shapes total, vs
        one per live session count on the dense path.  Lower + compile
        only; the arena is never executed against (nor donated away)."""
        t0 = time.perf_counter()
        for b in self.decode_buckets:
            tokens = jnp.zeros((b,), jnp.int32)
            rows = jnp.zeros((b,), jnp.int32)
            lens = jnp.ones((b,), jnp.int32)
            self._get("arena_decode", self._jit_decode,
                      (params, tokens, rows, rows, lens, arena))
        return time.perf_counter() - t0


__all__ = ["BucketExecutor", "PackedBucketExecutor", "DecodeBucketExecutor",
           "DEFAULT_TOKEN_BUCKETS", "DEFAULT_DECODE_BUCKETS",
           "make_prefill_fn", "make_packed_prefill_fn",
           "make_packed_arena_fn", "make_packed_paged_fn",
           "make_packed_verify_arena_fn", "make_packed_verify_paged_fn",
           "make_decode_fn", "make_arena_decode_fn",
           "make_paged_decode_fn", "resolve_donation"]
