"""Bucketized AOT-executable cache — the TPU analogue of CUDA Graph
capture (§3.1, DESIGN.md §2).

Each (kind, L_bucket, B_bucket) shape is lowered + compiled ONCE
(``jax.jit(...).lower(...).compile()``) and re-dispatched with zero
retracing afterwards.  A shape miss costs a fresh compile — seconds,
like the paper's 8–12 s per-graph capture — which is precisely why the
scheduler pads to the captured grid.  Compile times and hit/miss
statistics are recorded for the §4.2 cost analysis.
"""
from __future__ import annotations

import time
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import transformer as tr
from repro.models.config import ModelConfig


def make_prefill_fn(cfg: ModelConfig) -> Callable:
    """(params, tokens(B,L), positions(B,L), caches, sample_idx(B,)) →
    (last_logits(B,V), new_caches).  Covers first prefill AND re-prefill
    (positions carry the history offset)."""

    def prefill_step(params, tokens, positions, caches, sample_idx):
        logits, new_caches, _ = tr.forward(
            params, cfg, tokens=tokens, positions=positions, caches=caches,
            seq_valid_len=sample_idx + 1)
        last = jnp.take_along_axis(
            logits, sample_idx[:, None, None], axis=1)[:, 0]
        return last, new_caches

    return prefill_step


def make_decode_fn(cfg: ModelConfig) -> Callable:
    def decode_step(params, tokens, positions, caches):
        logits, new_caches, _ = tr.forward(
            params, cfg, tokens=tokens, positions=positions, caches=caches,
            logits_slice="last")
        return logits, new_caches

    return decode_step


class BucketExecutor:
    def __init__(self, cfg: ModelConfig, donate_cache: Optional[bool] = None):
        self.cfg = cfg
        self._prefill = make_prefill_fn(cfg)
        self._decode = make_decode_fn(cfg)
        if donate_cache is None:  # buffer donation: TPU yes, CPU warns
            donate_cache = jax.default_backend() == "tpu"
        self._jit_prefill = jax.jit(self._prefill,
                                    donate_argnums=(3,) if donate_cache else ())
        self._jit_decode = jax.jit(self._decode,
                                   donate_argnums=(3,) if donate_cache else ())
        self._compiled: Dict[Tuple, Any] = {}
        self.compile_times: Dict[Tuple, float] = {}
        self.hits = 0
        self.misses = 0

    # --------------------------------------------------------------- keys
    @staticmethod
    def _key(kind: str, *arrays) -> Tuple:
        def sig(x):
            return tuple((l.shape, str(l.dtype)) for l in jax.tree.leaves(x))
        return (kind,) + tuple(sig(a) for a in arrays)

    def _get(self, kind: str, jitted, args) -> Any:
        key = self._key(kind, *args)
        exe = self._compiled.get(key)
        if exe is None:
            self.misses += 1
            t0 = time.perf_counter()
            exe = jitted.lower(*args).compile()
            self.compile_times[key] = time.perf_counter() - t0
            self._compiled[key] = exe
        else:
            self.hits += 1
        return exe

    # ---------------------------------------------------------- dispatch
    def prefill(self, params, tokens, positions, caches, sample_idx):
        exe = self._get("prefill", self._jit_prefill,
                        (params, tokens, positions, caches, sample_idx))
        return exe(params, tokens, positions, caches, sample_idx)

    def decode(self, params, tokens, positions, caches):
        exe = self._get("decode", self._jit_decode,
                        (params, tokens, positions, caches))
        return exe(params, tokens, positions, caches)

    # ------------------------------------------------------------- stats
    def capture_cost(self) -> float:
        """Total 'graph capture' (compile) seconds — §4.2."""
        return sum(self.compile_times.values())

    @property
    def hit_rate(self) -> float:
        total = self.hits + self.misses
        return self.hits / total if total else 0.0

    def precapture(self, params, arena_gather, lengths, depths) -> float:
        """Capture the (L, B) grid at init (paper: graphs captured at
        system initialization).  Returns total capture seconds."""
        t0 = time.perf_counter()
        for b in depths:
            caches = arena_gather(list(range(b)))
            for l in lengths:
                tokens = jnp.zeros((b, l), jnp.int32)
                positions = jnp.zeros((b, l), jnp.int32)
                sample_idx = jnp.zeros((b,), jnp.int32)
                self._get("prefill", self._jit_prefill,
                          (params, tokens, positions, caches, sample_idx))
            tok1 = jnp.zeros((b, 1), jnp.int32)
            pos1 = jnp.zeros((b, 1), jnp.int32)
            self._get("decode", self._jit_decode,
                      (params, tok1, pos1, caches))
        return time.perf_counter() - t0
