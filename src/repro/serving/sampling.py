"""Per-session token sampling (ROADMAP PR-2 follow-up).

Every serving path ends in one `(B, V)` logits gather — prefill TTFT
tokens, fused mixed-step rows, and arena-decode rows alike.  This module
turns those rows into tokens under per-session options: greedy argmax
(the default, temperature 0), temperature scaling, top-k truncation,
top-p (nucleus) truncation, and additive logit bias.  Logit bias applies
BEFORE everything else — including greedy argmax, so a biased session
can force/ban tokens even at temperature 0.

Pure numpy on host-side logits: the sampled token feeds the NEXT step's
token stream, which is assembled on host anyway, so sampling adds no
device dispatch.  Determinism: each session owns a Generator seeded from
``SamplingParams.seed`` (or the session id), so a replayed request
stream reproduces its tokens exactly.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Sequence, Tuple, Union

import numpy as np

BiasSpec = Union[Dict[int, float], Tuple[Tuple[int, float], ...]]


@dataclasses.dataclass(frozen=True)
class SamplingParams:
    """Per-session decode options.  temperature <= 0 means greedy
    (logit_bias still applies — biased argmax)."""
    temperature: float = 0.0
    top_k: Optional[int] = None
    top_p: Optional[float] = None
    seed: Optional[int] = None
    logit_bias: Optional[BiasSpec] = None   # {token_id: additive bias}

    def __post_init__(self):
        # normalize dict → sorted tuple so params stay hashable/frozen
        if isinstance(self.logit_bias, dict):
            object.__setattr__(self, "logit_bias",
                               tuple(sorted(self.logit_bias.items())))

    @property
    def is_greedy(self) -> bool:
        return self.temperature <= 0.0

    @property
    def is_default(self) -> bool:
        """True when plain vectorized argmax already does the job."""
        return self.is_greedy and not self.logit_bias


GREEDY = SamplingParams()


def make_rng(session: int, params: SamplingParams) -> np.random.Generator:
    seed = params.seed if params.seed is not None else session
    return np.random.default_rng(seed)


def _apply_bias(logits: np.ndarray, params: SamplingParams) -> np.ndarray:
    """Additive per-token bias, IN PLACE (out-of-range ids are ignored).
    Callers pass a private float32 copy — no second allocation here."""
    if not params.logit_bias:
        return logits
    for tok, bias in params.logit_bias:
        if 0 <= int(tok) < logits.size:
            logits[int(tok)] += np.float32(bias)
    return logits


def filtered_probs(logits: np.ndarray, params: SamplingParams) -> np.ndarray:
    """The (V,) distribution a non-greedy session actually samples from:
    bias → temperature → exact top-k (kth-value threshold, ties kept) →
    tie-inclusive top-p (a token survives iff the mass of STRICTLY
    GREATER probs is < top_p) → renormalized softmax.

    float32 throughout, matching the fused on-device sampling kernel
    bit-for-bit up to summation order.  Speculative rejection sampling
    reads draft probabilities straight off this distribution.
    """
    scaled = _apply_bias(np.asarray(logits, np.float32).copy(), params)
    scaled = scaled / np.float32(max(params.temperature, 1e-6))
    if params.top_k is not None and 0 < params.top_k < scaled.size:
        kth = np.partition(scaled, -params.top_k)[-params.top_k]
        scaled = np.where(scaled < kth, -np.inf, scaled)
    if params.top_p is not None and 0.0 < params.top_p < 1.0:
        shifted = scaled - scaled.max()
        probs = np.exp(shifted)
        probs /= probs.sum()
        # strict-greater mass G(v) = Σ p_j for p_j > v, via the sorted
        # prefix: ties share one G, so equal-prob tokens live or die
        # together (the value-threshold rule the device kernel uses)
        sp = np.sort(probs)[::-1]
        cs = np.concatenate(([np.float32(0.0)], np.cumsum(sp)))
        first_le = np.searchsorted(-sp, -probs, side="left")
        scaled = np.where(cs[first_le] < params.top_p, scaled, -np.inf)
    scaled = scaled - scaled.max()
    probs = np.exp(scaled)
    probs /= probs.sum()
    return probs.astype(np.float32)


def sample_from_probs(probs: np.ndarray, u: float) -> int:
    """Inverse-CDF draw: the smallest index whose cumulative mass
    exceeds ``u``.  One uniform per draw — the same protocol the fused
    sampling kernel consumes, so host and device paths share one rng
    stream layout."""
    cdf = np.cumsum(probs)
    return int(min(np.searchsorted(cdf, u, side="right"), probs.size - 1))


def sample_token(logits: np.ndarray, params: SamplingParams,
                 rng: Optional[np.random.Generator] = None) -> int:
    """Sample one token from a (V,) logits row."""
    if params.is_greedy or rng is None:
        scaled = _apply_bias(np.asarray(logits, np.float32).copy(), params)
        return int(np.argmax(scaled))
    return sample_from_probs(filtered_probs(logits, params), rng.random())


def sample_batch(logits: np.ndarray, sessions: Sequence[int],
                 params: Dict[int, SamplingParams],
                 rngs: Dict[int, np.random.Generator]) -> np.ndarray:
    """Sample one token per row of a (n, V) logits block.

    Default rows (no per-session params) share one vectorized argmax;
    rows with options go through :func:`sample_token` — bias, then
    greedy argmax or the temperature / top-k / top-p draw from their
    session's Generator.  Row order is the caller's ``sessions`` order —
    the segment/batch layout is never reordered by sampling.
    """
    n = len(sessions)
    assert logits.shape[0] >= n, (logits.shape, n)
    out = np.argmax(logits[:n], axis=-1).astype(np.int64)
    for i, s in enumerate(sessions):
        sp = params.get(s)
        if sp is not None and not sp.is_default:
            out[i] = sample_token(logits[i], sp, rngs.get(s))
    return out


__all__ = ["SamplingParams", "GREEDY", "make_rng", "sample_token",
           "sample_batch", "filtered_probs", "sample_from_probs"]
