"""Per-session token sampling (ROADMAP PR-2 follow-up).

Every serving path ends in one `(B, V)` logits gather — prefill TTFT
tokens, fused mixed-step rows, and arena-decode rows alike.  This module
turns those rows into tokens under per-session options: greedy argmax
(the default, temperature 0), temperature scaling, and top-k truncation.

Pure numpy on host-side logits: the sampled token feeds the NEXT step's
token stream, which is assembled on host anyway, so sampling adds no
device dispatch.  Determinism: each session owns a Generator seeded from
``SamplingParams.seed`` (or the session id), so a replayed request
stream reproduces its tokens exactly.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Sequence

import numpy as np


@dataclasses.dataclass(frozen=True)
class SamplingParams:
    """Per-session decode options.  temperature <= 0 means greedy."""
    temperature: float = 0.0
    top_k: Optional[int] = None
    seed: Optional[int] = None

    @property
    def is_greedy(self) -> bool:
        return self.temperature <= 0.0


GREEDY = SamplingParams()


def make_rng(session: int, params: SamplingParams) -> np.random.Generator:
    seed = params.seed if params.seed is not None else session
    return np.random.default_rng(seed)


def sample_token(logits: np.ndarray, params: SamplingParams,
                 rng: Optional[np.random.Generator] = None) -> int:
    """Sample one token from a (V,) logits row."""
    if params.is_greedy or rng is None:
        return int(np.argmax(logits))
    scaled = logits.astype(np.float64) / params.temperature
    if params.top_k is not None and 0 < params.top_k < scaled.size:
        kth = np.partition(scaled, -params.top_k)[-params.top_k]
        scaled = np.where(scaled < kth, -np.inf, scaled)
    scaled = scaled - scaled.max()
    probs = np.exp(scaled)
    probs /= probs.sum()
    return int(rng.choice(scaled.size, p=probs))


def sample_batch(logits: np.ndarray, sessions: Sequence[int],
                 params: Dict[int, SamplingParams],
                 rngs: Dict[int, np.random.Generator]) -> np.ndarray:
    """Sample one token per row of a (n, V) logits block.

    Greedy rows (no per-session params) share one vectorized argmax;
    sampled rows draw from their session's Generator.  Row order is the
    caller's ``sessions`` order — the segment/batch layout is never
    reordered by sampling.
    """
    n = len(sessions)
    assert logits.shape[0] >= n, (logits.shape, n)
    out = np.argmax(logits[:n], axis=-1).astype(np.int64)
    for i, s in enumerate(sessions):
        sp = params.get(s)
        if sp is not None and not sp.is_greedy:
            out[i] = sample_token(logits[i], sp, rngs.get(s))
    return out


__all__ = ["SamplingParams", "GREEDY", "make_rng", "sample_token",
           "sample_batch"]
