"""Algorithm 2 — Lightweight Instance-Pressure Controller (§3.2 spatial).

Per-instance pressure ψ_k = α·q_k + β·e_k − γ·u_k from queue backlog,
SLA deviation and utilization; robust (P90) pool aggregation; single-step
hill-climb with hysteresis τ, cool-down T_cool and a minimum allocation
n_min.  Also the elastic-scaling / failure-handling point: pools may
grow or shrink between control periods (instances joining, leaving, or
dying) — the controller simply re-balances whatever is alive.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence


@dataclasses.dataclass
class InstanceStats:
    instance: int
    queue_backlog: float      # q_k — queued tokens (normalized by capacity)
    sla_deviation: float      # e_k — mean positive (TTFT − SLO) of recent reqs
    utilization: float        # u_k — busy fraction over the control period


@dataclasses.dataclass
class Migration:
    instance: int
    src_pool: str             # "short" | "long"
    dst_pool: str


@dataclasses.dataclass
class ControllerConfig:
    alpha: float = 1.0        # weight on backlog
    beta: float = 4.0         # weight on SLA deviation
    gamma: float = 0.5        # credit for utilization headroom
    tau: float = 0.25         # hysteresis
    t_cool: float = 5.0       # cool-down (s)
    n_min: int = 1            # minimum instances per pool
    quantile: float = 0.90    # robust aggregator A(·)
    period: float = 1.0       # control period Δt (s)
    min_pressure: float = 0.05  # absolute gate: multiplicative hysteresis
    # is meaningless around ≤0 pressures (an idle pool must not strip a
    # busy-but-healthy one whose utilization credit turns ψ negative)


def _p_quantile(vals: Sequence[float], q: float) -> float:
    if not vals:
        return 0.0
    s = sorted(vals)
    idx = min(int(q * len(s)), len(s) - 1)
    return s[idx]


class PressureController:
    def __init__(self, cfg: Optional[ControllerConfig] = None):
        self.cfg = cfg or ControllerConfig()
        self.t_last = float("-inf")
        self.history: List[Dict] = []

    def pressure(self, st: InstanceStats) -> float:
        c = self.cfg
        return c.alpha * st.queue_backlog + c.beta * st.sla_deviation \
            - c.gamma * st.utilization

    def pool_pressure(self, stats: Sequence[InstanceStats]) -> float:
        return _p_quantile([self.pressure(s) for s in stats],
                           self.cfg.quantile)

    def step(self, short_pool: Sequence[InstanceStats],
             long_pool: Sequence[InstanceStats],
             now: float) -> Optional[Migration]:
        """One control period.  Returns at most one migration."""
        c = self.cfg
        p_s = self.pool_pressure(short_pool)
        p_l = self.pool_pressure(long_pool)
        self.history.append({"t": now, "p_short": p_s, "p_long": p_l,
                             "n_short": len(short_pool),
                             "n_long": len(long_pool)})
        if now - self.t_last < c.t_cool:
            return None
        if p_s > max((1 + c.tau) * p_l, c.min_pressure) \
                and len(long_pool) > c.n_min:
            # migrate the least-pressured long instance to the short pool
            victim = min(long_pool, key=self.pressure)
            self.t_last = now
            return Migration(victim.instance, "long", "short")
        if p_l > max((1 + c.tau) * p_s, c.min_pressure) \
                and len(short_pool) > c.n_min:
            victim = min(short_pool, key=self.pressure)
            self.t_last = now
            return Migration(victim.instance, "short", "long")
        return None
