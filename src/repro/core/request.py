"""Request model: the unit the LAPS scheduler reasons about.

A request is one *prefill job*: either a first-turn prefill (H == 0) or a
multi-turn re-prefill (H > 0 cached history tokens, L new tokens).
Decode work is modelled separately (PD disaggregation) except in MIX
mode.
"""
from __future__ import annotations

import dataclasses
import itertools
from typing import Optional

_ids = itertools.count()


@dataclasses.dataclass
class Request:
    new_tokens: int                      # L — new prompt tokens this turn
    history_tokens: int = 0              # H — cached KV history
    arrival: float = 0.0
    deadline: Optional[float] = None     # absolute TTFT deadline (None = offline)
    session: int = -1
    decode_tokens: int = 0               # expected output length (PD sims)
    # tokens of the prompt a paged KV arena can inherit from its radix
    # prefix index (shared system prompt / earlier turn) instead of
    # prefilling — the scheduler and sim bill only the suffix past it
    reusable_prefix: int = 0
    # §12 host spill tier: how many of those reusable tokens live in the
    # HOST page pool (demoted by eviction) rather than on device.  Sims
    # with host_pool_pages > 0 bill their promotion (swap_in_time);
    # without a host tier they were dropped at eviction and are not
    # adoptable at all
    host_prefix: int = 0
    rid: int = dataclasses.field(default_factory=lambda: next(_ids))

    # fault tolerance (DESIGN.md §11): a recovery request is the synthetic
    # re-prefill that reconstructs a crashed engine's session on a
    # survivor — trackers count it as `recovered`, not as client traffic;
    # `rejected` marks a submit shed by the admission gate (never queued)
    recovery: bool = False
    rejected: bool = False

    # runtime bookkeeping (filled by scheduler/engine/sim)
    swap_time: float = 0.0               # host→device promotion delay billed
    dispatch_time: Optional[float] = None
    finish_time: Optional[float] = None
    instance: Optional[int] = None
    padded_to: Optional[int] = None      # bucket length it was padded to
    used_graph: bool = False

    @property
    def is_reprefill(self) -> bool:
        return self.history_tokens > 0

    @property
    def total_context(self) -> int:
        return self.new_tokens + self.history_tokens

    def ttft(self) -> Optional[float]:
        if self.finish_time is None:
            return None
        return self.finish_time - self.arrival

    def violated(self) -> bool:
        if self.deadline is None:
            return False
        return self.finish_time is None or self.finish_time > self.deadline

    def slack(self, now: float, service_estimate: float) -> float:
        """Time to spare if dispatched now (∞ when deadline-free)."""
        if self.deadline is None:
            return float("inf")
        return self.deadline - now - service_estimate


@dataclasses.dataclass
class Batch:
    requests: list
    bucket_len: Optional[int] = None     # padded per-request length (graph L)
    bucket_depth: Optional[int] = None   # padded batch size (graph B)
    token_bucket: Optional[int] = None   # packed path: total-token bucket T
    uses_graph: bool = False
    kind: str = "short"                  # short | long | decode | mixed
    decode_tokens: int = 0               # decode rows fused into this step
    # (continuous batching: each rides the packed stream as a length-1
    # segment, sharing the dispatch + weight read with the prefills)

    @property
    def depth(self) -> int:
        return len(self.requests)

    @property
    def tokens(self) -> int:
        return sum(r.new_tokens for r in self.requests)

    @property
    def stream_tokens(self) -> int:
        """Real rows of the packed stream: prefill + fused decode."""
        return self.tokens + self.decode_tokens

    @property
    def is_packed(self) -> bool:
        return self.token_bucket is not None

    @property
    def padded_tokens(self) -> int:
        if self.token_bucket is not None:
            return self.token_bucket
        if self.bucket_len is None or self.bucket_depth is None:
            return self.tokens
        return self.bucket_len * self.bucket_depth

    @property
    def max_history(self) -> int:
        return max((r.history_tokens for r in self.requests), default=0)
