"""Instance-level scheduling policies and serving modes (§3, §1).

Four serving modes (paper §1) plus the two partial ablation variants of
Fig.6.  Policies are pure decision objects: the discrete-event simulator
and the real engine both drive them through the same three calls —
``enqueue``, ``next_work``, ``on_complete``.

  VANILLA           SGLang-like: single FCFS queue, memory-constrained
                    continuous batching, long+short co-batched.
  GRAPH_ONLY        VANILLA batching + bucketized graph execution (ablation).
  DISAGG_ONLY       dual-queue LP/SP separation, no AWD window/graphs (ablation).
  PLA_FULL          dual queue + AWD + graph bucketization (the paper).

Long-prefill work always advances one request at a time in fixed chunks
C_l (§3.2 "long-prefill dispatch continues to advance a single request by
fixed-size chunks"), which bounds how long a ready short batch can wait
behind a long prefill in temporal disaggregation.
"""
from __future__ import annotations

import dataclasses
import enum
from collections import deque
from typing import Deque, List, Optional, Tuple

from repro.core.awd import AWDConfig, AWDScheduler
from repro.core.boundary import LatencyModel
from repro.core.buckets import BucketGrid
from repro.core.queues import DualQueue
from repro.core.request import Batch, Request


class ServingMode(str, enum.Enum):
    MIX = "mix"                       # decode co-batched with prefill
    PD_TEMPORAL = "pd_temporal"       # prefill/decode alternate on one instance
    PD_SPATIAL = "pd_spatial"         # prefill/decode on separate instances
    PREFILL_DISAGG = "prefill_disagg"  # ours: LP/SP disaggregation


class Variant(str, enum.Enum):
    VANILLA = "vanilla"
    GRAPH_ONLY = "graph_only"
    DISAGG_ONLY = "disagg_only"
    PLA_FULL = "pla_full"


@dataclasses.dataclass
class ChunkWork:
    """One long-prefill chunk advancing request `req`."""
    req: Request
    chunk_tokens: int
    done_tokens: int          # tokens already prefilled (acts as history)
    is_last: bool
    decode_tokens: int = 0    # decode rows fused into the chunk's packed
    # stream (continuous batching — the serve loop fuses the backlog
    # into chunk steps exactly as into short batches)
    uses_graph: bool = False  # chunk rides a captured token-bucket shape
    # (engine.prefill_long routes C_l chunks through the packed path)


class BasePolicy:
    """Interface: the instance asks for work whenever it goes idle."""

    def enqueue(self, r: Request, now: float) -> None:
        raise NotImplementedError

    def next_work(self, now: float):
        """Returns (Batch | ChunkWork | None, wake_time | None)."""
        raise NotImplementedError

    def on_complete(self, work, now: float) -> None:
        pass

    def note_decode_backlog(self, n: int, tokens_per_decode: int = 1) -> None:
        """Continuous batching: the serving loop reports how many
        in-flight sessions await their next decode token (each costing
        ``tokens_per_decode`` stream tokens — > 1 under speculation).
        Policies that form packed batches reserve fusion room; others
        ignore it."""
        pass

    def backlog_tokens(self) -> int:
        raise NotImplementedError

    def queue_len(self) -> int:
        raise NotImplementedError

    def drain(self) -> List[Request]:
        """Remove and return every queued request (failure re-routing)."""
        raise NotImplementedError

    def purge(self, pred) -> List[Request]:
        """Remove and return every queued request matching ``pred``
        (session close, deflection).  Concrete policies override this to
        preserve queue order and chunk progress; the fallback drains and
        re-enqueues the survivors."""
        kept: List[Request] = []
        out: List[Request] = []
        for r in self.drain():
            (out if pred(r) else kept).append(r)
        for r in kept:
            self.enqueue(r, 0.0)
        return out


class FCFSPolicy(BasePolicy):
    """Vanilla SGLang-like: memory-constrained FCFS batching; long and
    short co-batched (the interference source of §2.2).  GRAPH_ONLY adds
    bucket matching on whatever FCFS happened to batch."""

    def __init__(self, *, mem_budget_tokens: int = 16_384,
                 grid: Optional[BucketGrid] = None):
        self.queue: Deque[Request] = deque()
        self.mem_budget = mem_budget_tokens
        self.grid = grid  # non-None = GRAPH_ONLY variant

    def enqueue(self, r: Request, now: float) -> None:
        self.queue.append(r)

    def next_work(self, now: float):
        if not self.queue:
            return None, None
        batch: List[Request] = []
        tokens = 0
        seen = set()
        for r in list(self.queue):
            if batch and tokens + r.new_tokens > self.mem_budget:
                break
            if r.session >= 0 and r.session in seen:
                continue    # a session's later turn waits for its earlier
            batch.append(r)
            tokens += r.new_tokens
            seen.add(r.session)
        picked = {r.rid for r in batch}
        self.queue = deque(r for r in self.queue if r.rid not in picked)
        b = Batch(requests=batch, kind="mixed")
        if self.grid is not None:
            g = self.grid.nearest_graph([r.new_tokens for r in batch],
                                        self.mem_budget)
            if g is not None:
                b.bucket_len, b.bucket_depth = g.length, g.depth
                b.uses_graph = True
                for r in batch:
                    r.padded_to, r.used_graph = g.length, True
        return b, None

    def backlog_tokens(self) -> int:
        return sum(r.new_tokens for r in self.queue)

    def queue_len(self) -> int:
        return len(self.queue)

    def drain(self) -> List[Request]:
        out = list(self.queue)
        self.queue.clear()
        return out

    def purge(self, pred) -> List[Request]:
        out = [r for r in self.queue if pred(r)]
        if out:
            gone = {r.rid for r in out}
            self.queue = deque(r for r in self.queue if r.rid not in gone)
        return out


class TemporalDisaggPolicy(BasePolicy):
    """§3.2 temporal disaggregation on a single instance: dual queues;
    short batches formed by AWD (or plain bucketless FCFS for the
    DISAGG_ONLY ablation); long prefills advance in chunks C_l; a ready
    short batch preempts at chunk boundaries (short-priority)."""

    def __init__(self, model: LatencyModel, *, grid: Optional[BucketGrid] = None,
                 awd_cfg: Optional[AWDConfig] = None,
                 chunk_tokens: int = 2048,
                 use_awd: bool = True,
                 threshold: Optional[float] = None,
                 max_short_streak: int = 8):
        self.dq = DualQueue(model, override_threshold=threshold)
        self.grid = grid or BucketGrid()
        self.awd = AWDScheduler(self.grid, awd_cfg) if use_awd else None
        self.chunk = chunk_tokens
        self._long_progress: dict = {}   # rid -> tokens done
        # anti-starvation: under a continuous short flood, guarantee one
        # long chunk per `max_short_streak` short dispatches (bounded
        # interference: one chunk ≈ C_l·β, the paper's temporal phases)
        self.max_short_streak = max_short_streak
        self._short_streak = 0

    def enqueue(self, r: Request, now: float) -> None:
        cls = self.dq.push(r)
        if cls == "short" and self.awd is not None:
            self.awd.on_arrival(now)

    def note_decode_backlog(self, n: int, tokens_per_decode: int = 1) -> None:
        if self.awd is not None:
            self.awd.note_decode_backlog(n, tokens_per_decode)

    # ------------------------------------------------------------- short
    def _short_work(self, now: float):
        q = list(self.dq.short)
        if not q:
            return None, None
        if self.awd is not None:
            batch, wake = self.awd.decide(q, now)
            if batch is not None:
                picked = {r.rid for r in batch.requests}
                self.dq.short = deque(r for r in self.dq.short
                                      if r.rid not in picked)
            return batch, wake
        # DISAGG_ONLY: batch all queued shorts under budget, no window
        batch: List[Request] = []
        tokens = 0
        seen = set()
        for r in list(self.dq.short):
            if batch and tokens + r.new_tokens > self.grid.mem_budget:
                break
            if r.session >= 0 and r.session in seen:
                continue
            batch.append(r)
            tokens += r.new_tokens
            seen.add(r.session)
        picked = {r.rid for r in batch}
        self.dq.short = deque(r for r in self.dq.short
                              if r.rid not in picked)
        return Batch(requests=batch, kind="short"), None

    # -------------------------------------------------------------- long
    def _long_work(self) -> Optional[ChunkWork]:
        if not self.dq.long:
            return None
        r = self.dq.long[0]
        done = self._long_progress.get(r.rid, 0)
        remaining = r.new_tokens - done
        chunk = min(self.chunk, remaining)
        return ChunkWork(req=r, chunk_tokens=chunk, done_tokens=done,
                         is_last=(done + chunk >= r.new_tokens))

    def next_work(self, now: float):
        if self._short_streak >= self.max_short_streak and self.dq.long:
            self._short_streak = 0
            return self._long_work(), None
        short, wake = self._short_work(now)
        if short is not None and short.requests:
            self._short_streak += 1
            return short, None
        if self.dq.short and wake is not None:
            # shorts are accumulating inside an AWD window: hold the slot
            # (the "short phase" of temporal disaggregation) instead of
            # starting a long chunk that would outlive the window —
            # otherwise long work de-facto preempts short SLAs.
            return None, wake
        long_work = self._long_work()
        if long_work is not None:
            self._short_streak = 0
            return long_work, wake
        return None, wake

    def on_complete(self, work, now: float) -> None:
        if isinstance(work, ChunkWork):
            if work.is_last:
                self._long_progress.pop(work.req.rid, None)
                if self.dq.long and self.dq.long[0].rid == work.req.rid:
                    self.dq.long.popleft()
            else:
                self._long_progress[work.req.rid] = \
                    work.done_tokens + work.chunk_tokens
        elif isinstance(work, Batch) and self.awd is not None:
            if work.requests and work.requests[0].dispatch_time is not None:
                fin = now - work.requests[0].dispatch_time
                self.awd.observe_service(fin)

    def backlog_tokens(self) -> int:
        return self.dq.backlog_tokens("short") + self.dq.backlog_tokens("long")

    def queue_len(self) -> int:
        return len(self.dq)

    def drain(self) -> List[Request]:
        out = list(self.dq.short) + list(self.dq.long)
        self.dq.short.clear()
        self.dq.long.clear()
        self._long_progress.clear()
        return out

    def purge(self, pred) -> List[Request]:
        out = [r for r in self.dq.short if pred(r)] + \
              [r for r in self.dq.long if pred(r)]
        if out:
            gone = {r.rid for r in out}
            self.dq.short = deque(r for r in self.dq.short
                                  if r.rid not in gone)
            self.dq.long = deque(r for r in self.dq.long
                                 if r.rid not in gone)
            for r in out:
                self._long_progress.pop(r.rid, None)
        return out


class PoolPolicy(TemporalDisaggPolicy):
    """§3.2 spatial mode: instance dedicated to ONE class (mutual
    exclusion).  pool = 'short' → AWD batches only; 'long' → chunked FCFS
    only.  The spatial controller migrates instances between pools."""

    def __init__(self, model: LatencyModel, pool: str, **kw):
        super().__init__(model, **kw)
        self.pool = pool

    def next_work(self, now: float):
        if self.pool == "short":
            b, wake = self._short_work(now)
            return (b if (b is not None and b.requests) else None), wake
        lw = self._long_work()
        return lw, None


def make_policy(variant: Variant, model: LatencyModel, *,
                grid: Optional[BucketGrid] = None,
                awd_cfg: Optional[AWDConfig] = None,
                mem_budget_tokens: int = 16_384,
                chunk_tokens: int = 2048,
                threshold: Optional[float] = None) -> BasePolicy:
    grid = grid or BucketGrid(mem_budget_tokens=mem_budget_tokens)
    if variant == Variant.VANILLA:
        return FCFSPolicy(mem_budget_tokens=mem_budget_tokens)
    if variant == Variant.GRAPH_ONLY:
        return FCFSPolicy(mem_budget_tokens=mem_budget_tokens, grid=grid)
    if variant == Variant.DISAGG_ONLY:
        return TemporalDisaggPolicy(model, grid=grid, use_awd=False,
                                    chunk_tokens=chunk_tokens,
                                    threshold=threshold)
    return TemporalDisaggPolicy(model, grid=grid, awd_cfg=awd_cfg,
                                chunk_tokens=chunk_tokens,
                                threshold=threshold)
