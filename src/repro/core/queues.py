"""§3.2 — dual-queue length classification (Q_s / Q_l).

Requests are classified at arrival by prompt length against the fitted
compute/memory boundary L_m (re-prefills use the history-dependent
L_m^re-prefill).  Each class has an independent FIFO; instances in
disaggregated modes pull exclusively from one queue.
"""
from __future__ import annotations

from collections import deque
from typing import Deque, Optional

from repro.core.boundary import LatencyModel
from repro.core.request import Request


class DualQueue:
    def __init__(self, model: LatencyModel,
                 override_threshold: Optional[float] = None):
        self.model = model
        self.override = override_threshold
        self.short: Deque[Request] = deque()
        self.long: Deque[Request] = deque()
        self.n_short = 0
        self.n_long = 0

    def threshold(self, history: int) -> float:
        if self.override is not None:
            return self.override
        return self.model.boundary(history)

    def classify(self, r: Request) -> str:
        return "short" if r.new_tokens < self.threshold(r.history_tokens) \
            else "long"

    def push(self, r: Request) -> str:
        cls = self.classify(r)
        if cls == "short":
            self.short.append(r)
            self.n_short += 1
        else:
            self.long.append(r)
            self.n_long += 1
        return cls

    # ------------------------------------------------------------- stats
    def backlog_tokens(self, which: str) -> int:
        q = self.short if which == "short" else self.long
        return sum(r.new_tokens for r in q)

    def oldest_wait(self, which: str, now: float) -> float:
        q = self.short if which == "short" else self.long
        return now - q[0].arrival if q else 0.0

    def __len__(self) -> int:
        return len(self.short) + len(self.long)
