"""§2.1 — the compute/memory boundary latency model.

    T_comp(L, H) ≈ α·L·(L + 2H) + β·L
    T_mem(L, H)  ≈ γ_w·L + γ_r·H

Boundaries:
    L_m^prefill    = max(0, (γ_w − β)/α)
    L_m^re-prefill = positive root of α·L² + (2αH + β − γ_w)·L − γ_r·H = 0,
                     saturating at γ_r/(2α) for H ≫ |β−γ_w|/(2α).

Constants are fitted at runtime from (T_comp, T_mem, L, H) samples
(:func:`fit`) or taken from :data:`H200_QWEN32B` — a calibration chosen
so the prefill boundary lands in the paper's empirical 150–512-token
range (§2.1) and absolute latencies match the paper's H200/Qwen2.5-32B
setup to first order.  The roofline cross-check (:func:`roofline_boundary`)
computes the arithmetic-intensity crossing AI(L) = P_peak/B_mem.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional, Sequence, Tuple

import numpy as np


@dataclasses.dataclass(frozen=True)
class LatencyModel:
    alpha: float    # s/token² — attention quadratic compute
    beta: float     # s/token  — FFN/projection linear compute
    gamma_w: float  # s/token  — KV write + per-token weight-read share
    gamma_r: float  # s/token  — KV read per history token

    # ------------------------------------------------------------ latency
    def t_comp(self, l: float, h: float = 0.0) -> float:
        return self.alpha * l * (l + 2.0 * h) + self.beta * l

    def t_mem(self, l: float, h: float = 0.0) -> float:
        return self.gamma_w * l + self.gamma_r * h

    def total(self, l: float, h: float = 0.0) -> float:
        return self.t_comp(l, h) + self.t_mem(l, h)

    # ---------------------------------------------------------- boundaries
    def l_m_prefill(self) -> float:
        return max(0.0, (self.gamma_w - self.beta) / self.alpha)

    def l_m_reprefill(self, h: float) -> float:
        if h <= 0:
            return self.l_m_prefill()
        b = 2.0 * self.alpha * h + self.beta - self.gamma_w
        disc = b * b + 4.0 * self.alpha * self.gamma_r * h
        return max(0.0, (-b + math.sqrt(disc)) / (2.0 * self.alpha))

    def saturation(self) -> float:
        """lim_{H→∞} L_m^re-prefill = γ_r / (2α)."""
        return self.gamma_r / (2.0 * self.alpha)

    def boundary(self, h: float = 0.0,
                 clip: Tuple[float, float] = (16.0, 2048.0)) -> float:
        """Operational classification threshold (clipped fitted boundary)."""
        lm = self.l_m_reprefill(h) if h > 0 else self.l_m_prefill()
        return float(min(max(lm, clip[0]), clip[1]))

    def is_memory_bound(self, l: float, h: float = 0.0) -> bool:
        return self.t_mem(l, h) > self.t_comp(l, h)


def fit(samples: Sequence[Tuple[float, float, float, float]]) -> LatencyModel:
    """Least-squares fit of (T_comp, T_mem, L, H) runtime samples (§2.1).

    T_comp is quadratic in (L, H) with features [L(L+2H), L];
    T_mem is linear with features [L, H].  Coefficients are clamped ≥ 0.
    """
    arr = np.asarray(samples, dtype=np.float64)
    t_comp, t_mem, l, h = arr[:, 0], arr[:, 1], arr[:, 2], arr[:, 3]
    xc = np.stack([l * (l + 2.0 * h), l], axis=1)
    coef_c, *_ = np.linalg.lstsq(xc, t_comp, rcond=None)
    xm = np.stack([l, h], axis=1)
    coef_m, *_ = np.linalg.lstsq(xm, t_mem, rcond=None)
    alpha, beta = max(coef_c[0], 1e-12), max(coef_c[1], 0.0)
    gamma_w, gamma_r = max(coef_m[0], 0.0), max(coef_m[1], 0.0)
    return LatencyModel(alpha, beta, gamma_w, gamma_r)


@dataclasses.dataclass(frozen=True)
class TotalFit:
    """Fit of wall-clock totals T(L,H) ≈ F + b·L + a·L(L+2H) + c·H.

    When only end-to-end times are observable (no profiler separating
    compute from memory stations), the compute/memory boundary is the
    roofline crossing of the quadratic compute term against the fixed
    memory floor F (weight read + launch): a·L² + b_c·L = F.  We
    conservatively attribute the linear term to compute (b_c = b), which
    biases L_m slightly low — safe for classification (a borderline
    request lands in the long queue).
    """
    alpha: float
    beta_eff: float
    gamma_r: float
    fixed: float

    def l_m(self) -> float:
        a, b, f = self.alpha, self.beta_eff, self.fixed
        if a <= 0:
            return f / b if b > 0 else 0.0
        disc = b * b + 4.0 * a * f
        return (-b + math.sqrt(disc)) / (2.0 * a)

    def boundary(self, h: float = 0.0,
                 clip: Tuple[float, float] = (16.0, 2048.0)) -> float:
        return float(min(max(self.l_m(), clip[0]), clip[1]))

    def total(self, l: float, h: float = 0.0) -> float:
        return self.fixed + self.beta_eff * l + \
            self.alpha * l * (l + 2.0 * h) + self.gamma_r * h


def fit_total(samples: Sequence[Tuple[float, float, float]]) -> TotalFit:
    """Least-squares fit of (T_total, L, H) wall-clock engine samples."""
    arr = np.asarray(samples, dtype=np.float64)
    t, l, h = arr[:, 0], arr[:, 1], arr[:, 2]
    x = np.stack([np.ones_like(l), l, l * (l + 2.0 * h), h], axis=1)
    coef, *_ = np.linalg.lstsq(x, t, rcond=None)
    return TotalFit(alpha=max(coef[2], 1e-15), beta_eff=max(coef[1], 1e-12),
                    gamma_r=max(coef[3], 0.0), fixed=max(coef[0], 0.0))


def roofline_boundary(model_params: int, kv_bytes_per_token: float,
                      peak_flops: float, mem_bw: float,
                      weight_bytes: Optional[float] = None) -> float:
    """Roofline form of the boundary (§2.1): smallest L whose prefill
    arithmetic intensity reaches AI* = P_peak/B_mem.

    AI(L) ≈ 2·N·L / (W + L·kv_bytes): FLOPs grow linearly in L, bytes are
    dominated by the one-time weight read W plus per-token KV writes.
    """
    w = weight_bytes if weight_bytes is not None else 2.0 * model_params
    ai_star = peak_flops / mem_bw
    denom = 2.0 * model_params - ai_star * kv_bytes_per_token
    if denom <= 0:
        return float("inf")
    return ai_star * w / denom


# Calibration for the paper's setup (H200 SXM, Qwen2.5-32B, bf16).
# α and β are physical (4·d_attn·layers/peak ≈ 1.3e-9 s/pair; 2N/peak ≈
# 6.5e-5 s/token).  The paper's *linear* T_mem = γ_w·L form has no slot
# for the fixed per-step weight read, so a fitted γ_w lands a hair above
# β with the gap set by the weight-read amortization slope around short
# lengths; we pin γ_w = β + 300·α so the prefill boundary sits at 300
# tokens — inside the paper's empirically reported 150–512 range.
# γ_r is the physical KV re-read per history token
# (≈0.26 MB / 4.8 TB/s ≈ 5.4e-8 s): with physical constants the
# re-prefill saturation γ_r/(2α) ≈ 21 tokens sits BELOW L_m^prefill, so
# the history-dependent boundary *descends* toward saturation — the
# paper's rising-boundary narrative corresponds to fitted (coarse) γ_r
# values; both regimes are covered by the same formula and tests.
_A32, _B32 = 1.3e-9, 6.5e-5
H200_QWEN32B = LatencyModel(alpha=_A32, beta=_B32,
                            gamma_w=_B32 + 300.0 * _A32, gamma_r=5.4e-8)

_A14, _B14 = 5.7e-10, 2.8e-5
H200_QWEN14B = LatencyModel(alpha=_A14, beta=_B14,
                            gamma_w=_B14 + 280.0 * _A14, gamma_r=2.4e-8)
_A7, _B7 = 2.8e-10, 1.4e-5
H200_QWEN7B = LatencyModel(alpha=_A7, beta=_B7,
                           gamma_w=_B7 + 250.0 * _A7, gamma_r=1.2e-8)
