"""Cluster-level routing policies for spatial disaggregation (§3, fig7/fig8).

Pure decision objects, JAX-free, shared verbatim by the real multi-engine
``ServeCluster`` (serving/cluster.py) and the discrete-event cluster
simulator (sim/simulator.py): both sides build :class:`EngineView`
snapshots of their instances and ask the :class:`Router` for a placement,
so a policy tuned offline in the simulator drops into the live cluster
unchanged.

Three concrete policies reproduce the paper's fig7 comparison:

* :class:`RoundRobinRouter` — vanilla data-parallel spraying (the paper's
  DP baseline).
* :class:`LeastLoadedRouter` — SGLang-router-style backlog balancing:
  place on the engine with the smallest queued-token + active-decode load.
* :class:`LengthAwareRouter` — the paper's dual-queue SPATIAL mode: long
  prefills go only to dedicated prefill-role engines, shorts batch on the
  rest, each pool balanced least-loaded internally. An optional spillover
  lets a short ride an *idle* prefill engine under short-pool pressure;
  the cluster's deflection hook (Load-Aware Prefill Deflection) bounces it
  back through :meth:`Router.route` with ``exclude={engine_id}`` if long
  work arrives behind it before dispatch.
"""
from __future__ import annotations

import dataclasses
from typing import FrozenSet, List, Optional, Sequence


@dataclasses.dataclass
class EngineView:
    """One engine's router-visible state at routing time."""

    engine_id: int
    role: str = "general"          # "prefill" | "decode" | "general"
    alive: bool = True
    # §11 failure model: "healthy" | "degraded" | "dead".  Dead engines
    # are never routed to; degraded ones (recent transient faults) are
    # eligible only when no healthy engine is.
    health: str = "healthy"
    queue_len: int = 0             # queued requests (policy backlog)
    backlog_tokens: int = 0        # queued prefill tokens
    active_decodes: int = 0        # sessions mid-generation
    free_slots: int = 0            # free arena slots / pages


@dataclasses.dataclass
class RouteRequest:
    """The router-visible shape of one incoming turn."""

    new_tokens: int
    history_tokens: int = 0
    decode_tokens: int = 0
    session: int = -1


def _load(v: EngineView):
    """Backlog ordering: queued prefill work plus resident decode load;
    ties break on queue depth, then engine id for determinism."""
    return (v.backlog_tokens + v.active_decodes, v.queue_len, v.engine_id)


class Router:
    """route(request, cluster_state) -> engine_id.

    ``views`` is the full cluster snapshot; ``exclude`` names engines that
    must not be chosen (deflection re-routes pass the bouncing engine).
    If exclusion leaves nothing eligible the exclusion is ignored rather
    than failing — a lone overloaded engine still beats dropping work.
    """

    name = "router"

    def route(self, req, views: Sequence[EngineView],
              exclude: FrozenSet[int] = frozenset()) -> int:
        raise NotImplementedError

    @staticmethod
    def _eligible(views: Sequence[EngineView],
                  exclude: FrozenSet[int]) -> List[EngineView]:
        live = [v for v in views if v.alive and v.health != "dead"]
        out = [v for v in live if v.engine_id not in exclude]
        if not out:
            out = live
        if not out:
            raise RuntimeError("no alive engines to route to")
        # prefer fully-healthy engines; degraded ones (recent transient
        # faults, §11) only take traffic when nothing healthy is eligible
        healthy = [v for v in out if v.health == "healthy"]
        return healthy or out


class RoundRobinRouter(Router):
    """Data-parallel baseline: successive requests walk the engine list."""

    name = "round_robin"

    def __init__(self):
        self._i = -1

    def route(self, req, views, exclude=frozenset()) -> int:
        elig = self._eligible(views, exclude)
        self._i += 1
        return elig[self._i % len(elig)].engine_id


class LeastLoadedRouter(Router):
    """Backlog balancing: minimize queued tokens + active decodes."""

    name = "least_loaded"

    def route(self, req, views, exclude=frozenset()) -> int:
        return min(self._eligible(views, exclude), key=_load).engine_id


class LengthAwareRouter(Router):
    """Dual-queue spatial placement (§3): longs pinned to prefill engines.

    A request with ``new_tokens >= threshold`` is long and may only land
    on a prefill-role engine (falling back to the general pool when the
    cluster has none). Shorts go least-loaded over the non-prefill pool.
    With ``spill_tokens`` set, a short may be placed on an *idle* prefill
    engine when every short engine's backlog exceeds that bound — the
    deflection hook undoes the spill if the prefill engine becomes busy
    before the short dispatches.
    """

    name = "length_aware"

    def __init__(self, threshold: float = 256.0,
                 spill_tokens: Optional[int] = None):
        self.threshold = threshold
        self.spill_tokens = spill_tokens

    def is_long(self, req) -> bool:
        return req.new_tokens >= self.threshold

    def route(self, req, views, exclude=frozenset()) -> int:
        elig = self._eligible(views, exclude)
        prefill = [v for v in elig if v.role == "prefill"]
        rest = [v for v in elig if v.role != "prefill"]
        if self.is_long(req):
            pool = prefill or rest
            return min(pool, key=_load).engine_id
        if not rest:
            return min(prefill, key=_load).engine_id
        best = min(rest, key=_load)
        if (self.spill_tokens is not None and prefill
                and best.backlog_tokens > self.spill_tokens):
            idle = [v for v in prefill
                    if v.backlog_tokens == 0 and v.queue_len == 0]
            if idle:
                return min(idle, key=_load).engine_id
        return best.engine_id


def make_router(name: str, threshold: float = 256.0,
                spill_tokens: Optional[int] = None) -> Router:
    if name in ("round_robin", "rr"):
        return RoundRobinRouter()
    if name in ("least_loaded", "ll"):
        return LeastLoadedRouter()
    if name in ("length_aware", "spatial"):
        return LengthAwareRouter(threshold=threshold,
                                 spill_tokens=spill_tokens)
    raise ValueError(f"unknown router: {name!r}")
