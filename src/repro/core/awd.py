"""Algorithm 1 — AWD: Adaptive-Wait-Depth batching for short prefills.

Pure decision logic, shared verbatim by the discrete-event simulator
(virtual clock) and the real serving engine (wall clock).  The caller
owns the queue; AWD decides *when* to dispatch and *what* to batch.

State:
  W — waiting window, adapted from observed fill times;
  D — target depth, aligned to a captured graph shape;
  r̂ — EWMA short-request arrival rate (drives the graph window W_GR);
  Ŝ — EWMA service-time estimate (drives the SLA window W_SLA).

Dispatch triggers (any): depth(B) ≥ D · window expiry · SLA slack ≤ σ ·
head-of-line wait ≥ T_max.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence, Tuple

from repro.core.buckets import (Bucket, BucketGrid, DEFAULT_TOKEN_BUCKETS,
                                TokenBucketLadder)
from repro.core.request import Batch, Request

EPS = 1e-9


@dataclasses.dataclass
class AWDConfig:
    w_min: float = 0.001          # s
    w_max: float = 0.050          # s
    sigma: float = 0.020          # SLA slack threshold (s)
    delta: float = 0.005          # safety margin inside W_SLA (s)
    t_max: float = 0.200          # absolute head-of-line wait cap (s)
    service_estimate: float = 0.010  # initial Ŝ (s)
    rate_ewma: float = 0.2        # EWMA factor for r̂
    service_ewma: float = 0.3     # EWMA factor for Ŝ
    mem_budget_tokens: Optional[int] = None  # None → the grid's budget
    deadline_free: bool = False   # §3.2(b): token-max mode
    min_fill_tokens: int = 8_192  # deadline-free: admit when tok(B) ≥ M_s
    max_pad_ratio: float = 1.5    # graph profitability guard: run the
    # standard (unpadded) kernel when padding would inflate batch tokens
    # beyond this factor — "else use standard prefill kernel" (Alg. 1 l.10).
    # Deadline-free (offline) batches are compute-bound, where padding is
    # pure compute waste — a much tighter guard applies there.
    max_pad_ratio_offline: float = 1.1
    idle_flush: float = 0.5       # deadline-free: flush residue when the
    # queue has been stagnant this long (tail requests must not starve)
    packed: bool = False          # padding-free packed prefill: batches
    # concatenate into one flat token stream bucketed on TOTAL tokens
    # (TokenBucketLadder) instead of padding to the (L, B) grid
    token_buckets: Optional[Tuple[int, ...]] = None  # None → defaults
    packed_max_seqs: int = 16     # cache rows per packed step (B_max)
    decode_window_shrink: float = 0.25  # continuous batching: every
    # waiting decode session stalls one TPOT per tick spent filling a
    # prefill batch, so the waiting window shrinks as the decode backlog
    # grows — W_eff = W / (1 + shrink · n_decode)


class AWDScheduler:
    def __init__(self, grid: BucketGrid, cfg: Optional[AWDConfig] = None):
        self.grid = grid
        self.cfg = cfg or AWDConfig()
        self.ladder: Optional[TokenBucketLadder] = None
        if self.cfg.packed:
            self.ladder = TokenBucketLadder(
                self.cfg.token_buckets or DEFAULT_TOKEN_BUCKETS,
                self.cfg.packed_max_seqs)
        # single source of truth for the memory budget (grid's by default)
        self.mem_budget = self.cfg.mem_budget_tokens or grid.mem_budget
        self.s_hat = self.cfg.service_estimate
        self.r_hat = 0.0
        self._last_arrival: Optional[float] = None
        self._accum_since: Optional[float] = None
        # init per Algorithm 1 line 1
        self.d_target = grid.max_depth(grid.lengths[0], self.mem_budget)
        self.w = self.cfg.w_max
        self.dispatches = 0
        self.graph_hits = 0
        self.decode_backlog = 0   # active decode sessions awaiting fusion
        self.decode_tokens_per = 1   # stream tokens one fused session costs

    def note_decode_backlog(self, n: int, tokens_per_decode: int = 1) -> None:
        """Continuous batching: the loop reports how many in-flight
        sessions are waiting on their next decode token.  The backlog
        shrinks the waiting window (their TPOT stalls while we wait) and
        reserves stream rows in packed batch formation.
        ``tokens_per_decode`` is the stream cost of ONE fused session —
        1 plain, 1 + k when the engine speculates (a verify segment
        carries k draft tokens besides the pending one, DESIGN.md §10) —
        so the token reserve scales while the row reserve does not."""
        self.decode_backlog = max(0, int(n))
        self.decode_tokens_per = max(1, int(tokens_per_decode))

    # ------------------------------------------------------------ signals
    def on_arrival(self, now: float) -> None:
        if self._last_arrival is not None:
            # clamp the gap: simultaneous arrivals (batch completions
            # releasing several closed-loop clients at one timestamp)
            # must not blow the EWMA up to 1/ε
            gap = max(now - self._last_arrival, 1e-4)
            inst = min(1.0 / gap, 1e4)
            a = self.cfg.rate_ewma
            self.r_hat = (1 - a) * self.r_hat + a * inst
        self._last_arrival = now

    def observe_service(self, seconds: float) -> None:
        a = self.cfg.service_ewma
        self.s_hat = (1 - a) * self.s_hat + a * seconds

    # ------------------------------------------------------------ windows
    def w_sla(self, queue: Sequence[Request], now: float) -> float:
        """Last safe time to wait before any pending request would violate
        its deadline after one prefill step of duration Ŝ."""
        ddls = [r.deadline for r in queue if r.deadline is not None]
        if not ddls:
            return float("inf")
        return max(0.0, min(ddls) - now - self.s_hat - self.cfg.delta)

    def w_gr(self, depth: int) -> float:
        """Expected time to reach target depth D at arrival rate r̂."""
        need = max(0, self.d_target - depth)
        return need / max(self.r_hat, EPS)

    def window(self, queue: Sequence[Request], now: float, depth: int) -> float:
        w = min(self.w_sla(queue, now), self.w_gr(depth))
        w = min(max(w, self.cfg.w_min), self.cfg.w_max)
        if self.decode_backlog:
            # decode sessions stall one token per tick we spend waiting:
            # trade batch fill for TPOT as the backlog grows (applied to
            # the EFFECTIVE window, after the clamp, so pressure bites
            # even when the raw window sits at w_max)
            w = max(self.cfg.w_min,
                    w / (1.0 + self.cfg.decode_window_shrink
                         * self.decode_backlog))
        return w

    # ----------------------------------------------------------- batching
    def _select(self, queue: Sequence[Request],
                depth_cap: Optional[int] = None,
                decode_tokens: int = 0) -> List[Request]:
        """Bucket-first greedy selection (Algorithm 1 line 6): requests
        ordered by (bucket, arrival) so same-length groups cluster and
        padding to the eventual NEARESTGRAPH shape stays minimal; filled
        to target depth D under the memory budget.

        Packed mode: requests cost their RAW length (no per-request
        padding exists), order is plain FCFS (packing is composition-
        independent), and the fill target is the token-bucket ladder.
        ``decode_tokens`` active decode sessions each reserve one stream
        row AND ``decode_tokens_per`` stream tokens for continuous-
        batching fusion (clamped so at least one prefill always fits)."""
        if not queue:
            return []
        cap = depth_cap if depth_cap is not None else self.d_target
        budget = self.mem_budget
        if self.ladder is not None:
            reserve = min(decode_tokens, self.ladder.max_seqs - 1)
            cap = min(cap, self.ladder.max_seqs - reserve)
            budget = min(budget, max(1, self.ladder.max_tokens
                                     - reserve * self.decode_tokens_per))
            ordered = sorted(queue, key=lambda r: r.arrival)
        else:
            ordered = sorted(
                queue, key=lambda r: (self.grid.nearest_length(r.new_tokens)
                                      or 10 ** 9, r.arrival))
        picked: List[Request] = []
        tokens = 0
        seen_sessions = set()
        for r in ordered:
            if len(picked) >= cap:
                break
            if r.session >= 0 and r.session in seen_sessions:
                # one step per session: a second queued turn depends on
                # the first turn's KV writes, so it waits for the next
                # batch (same-stream duplicates would corrupt the cache)
                continue
            pad = self._cost(r)
            if picked and tokens + pad > budget:
                break
            picked.append(r)
            tokens += pad
            seen_sessions.add(r.session)
        return picked

    def _cost(self, r: Request) -> int:
        """Tokens a request occupies in a batch shape: its padded bucket
        length on the dense grid, its raw length on the packed ladder."""
        if self.ladder is not None:
            return r.new_tokens
        return self.grid.nearest_length(r.new_tokens) or r.new_tokens

    def _sla_urgent(self, queue: Sequence[Request], now: float) -> bool:
        return any(r.slack(now, self.s_hat) <= self.cfg.sigma for r in queue)

    # ------------------------------------------------------------- decide
    def decide(self, queue: List[Request], now: float,
               force: bool = False,
               decode_tokens: Optional[int] = None
               ) -> Tuple[Optional[Batch], Optional[float]]:
        """Returns (batch_to_dispatch | None, next_wakeup_time | None).

        The caller removes the batch's requests from the queue on dispatch.
        ``decode_tokens`` (None → the noted backlog) is the number of
        in-flight decode sessions the emitted packed batch must leave
        room for — the batch comes back with ``decode_tokens`` set to the
        fusion capacity actually reserved inside its token bucket.
        """
        if decode_tokens is None:
            decode_tokens = self.decode_backlog
        if not queue:
            self._accum_since = None
            return None, None
        if self._accum_since is None:
            self._accum_since = max(now, queue[0].arrival)

        if self.cfg.deadline_free:
            # token-max policy (§3.2b): pack to the full memory budget
            # (no depth target — offline cares about throughput only);
            # admit when tok(B) ≥ M_s, or flush the residue once the
            # queue has been stagnant for idle_flush seconds
            batch = self._select(queue, depth_cap=10 ** 9)
            tok = sum(r.new_tokens for r in batch)
            stagnant = now - self._accum_since >= self.cfg.idle_flush
            # "full" = the packer stopped on the budget while work remains
            # (real tokens can sit below min_fill forever once padding
            # hits the budget — dispatch, don't wait for the idle timer)
            full = len(batch) < len(queue)
            if tok >= self.cfg.min_fill_tokens or full or stagnant or force:
                return self._emit(batch, now), None
            return None, self._accum_since + self.cfg.idle_flush

        batch = self._select(queue, decode_tokens=decode_tokens)
        elapsed = now - self._accum_since
        w = self.window(queue, now, len(batch))
        urgent = self._sla_urgent(queue, now)
        hol = now - queue[0].arrival
        if (urgent or hol >= self.cfg.t_max) and queue:
            # SLA path: flush deadline-ordered, regardless of bucket
            batch = self._flush_select(queue)
            return self._emit(batch, now, sla_flush=True,
                              decode_tokens=decode_tokens), None
        # waiting is only rational if ≥1 more request is expected to
        # arrive inside the remaining window (napkin math: r̂·W ≥ 1)
        futile = self.r_hat * max(w - elapsed, 0.0) < 1.0
        if force or (batch and (len(batch) >= self.d_target or elapsed >= w
                                or futile)):
            return self._emit(batch, now, decode_tokens=decode_tokens), None
        wake = self._accum_since + w
        ddls = [r.deadline - self.s_hat - self.cfg.sigma
                for r in queue if r.deadline is not None]
        if ddls:
            wake = min(wake, min(ddls))
        return None, max(wake, now + EPS)

    def _flush_select(self, queue: Sequence[Request]) -> List[Request]:
        """Deadline-ordered flush packed to the memory budget — a flush
        must clear backlog, so it is NOT capped at the captured-graph
        depth (an over-deep flush simply runs the standard kernel)."""
        picked: List[Request] = []
        tokens = 0
        seen_sessions = set()
        for r in sorted(queue, key=lambda r: (r.deadline is None,
                                              r.deadline or r.arrival)):
            if r.session >= 0 and r.session in seen_sessions:
                continue          # same-session turns never share a step
            pad = self._cost(r)
            if picked and tokens + pad > self.mem_budget:
                break
            picked.append(r)
            tokens += pad
            seen_sessions.add(r.session)
        return picked

    def _emit(self, requests: List[Request], now: float,
              sla_flush: bool = False, decode_tokens: int = 0) -> Batch:
        lengths = [r.new_tokens for r in requests]
        batch = Batch(requests=list(requests), kind="short")
        real = max(sum(lengths), 1)
        ratio = self.cfg.max_pad_ratio_offline if self.cfg.deadline_free \
            else self.cfg.max_pad_ratio
        if self.ladder is not None:
            # packed path: one flat stream in the total-token bucket —
            # the profitability guard only sees the bucket tail.  Fused
            # decode rows (continuous batching) count as real tokens —
            # ``decode_tokens_per`` each when the engine speculates —
            # the bucket must cover them and they discount the tail.
            # When the full reserve busts the ladder, fuse FEWER decodes
            # rather than losing the packed path for the whole batch.
            per = self.decode_tokens_per
            fused = max(0, min(decode_tokens,
                               self.ladder.max_seqs - len(requests)))
            tb = self.ladder.bucket_for(sum(lengths) + fused * per)
            while tb is None and fused > 0:
                fused -= 1
                tb = self.ladder.bucket_for(sum(lengths) + fused * per)
            if tb is not None and len(requests) <= self.ladder.max_seqs \
                    and tb <= ratio * (real + fused * per):
                batch.token_bucket = tb
                batch.uses_graph = True
                batch.decode_tokens = fused
                if fused:
                    batch.kind = "mixed"
                self.graph_hits += 1
                for r in requests:
                    r.used_graph = True
        if not batch.uses_graph:
            # dense (L, B) grid — also the packed mode's fallback when
            # the token bucket flunks profitability (a small batch in a
            # big bucket): a captured grid shape still beats an eager
            # compile of the exact batch shape at serve time
            g = self.grid.nearest_graph(lengths, self.mem_budget)
            if g is not None and g.length * len(requests) <= ratio * real:
                batch.bucket_len, batch.bucket_depth = g.length, g.depth
                batch.uses_graph = True
                self.graph_hits += 1
                for r in requests:
                    r.padded_to, r.used_graph = g.length, True
        self.dispatches += 1
        # Algorithm 1 lines 11–15: adapt W / D from fill behaviour.
        # SLA flushes bypass the adaptation — shrinking D on a deadline
        # flush would spiral target depth (and throughput) down.
        fill = now - (self._accum_since if self._accum_since is not None else now)
        d = len(requests)
        if not sla_flush:
            if d >= self.d_target:
                # Algorithm 1 l.13: W ← clip(τ); grow D only on fast fills
                # (demand clearly supports a deeper target)
                self.w = min(max(fill, self.cfg.w_min), self.cfg.w_max)
                if fill < 0.5 * self.w or self.r_hat * self.cfg.w_max > 2 * d:
                    self.d_target = self._next_depth_up(d)
            else:
                self.d_target = max(1, self._depth_floor(d))
        self._accum_since = None
        return batch

    # depth adaptation helpers: D moves along the captured-depth grid
    def _next_depth_up(self, d: int) -> int:
        for dep in self.grid.depths:
            if dep > d:
                return dep
        return self.grid.depths[-1]

    def _depth_floor(self, d: int) -> int:
        best = self.grid.depths[0]
        for dep in self.grid.depths:
            if dep <= d:
                best = dep
        return best

    @property
    def graph_hit_rate(self) -> float:
        return self.graph_hits / self.dispatches if self.dispatches else 0.0
