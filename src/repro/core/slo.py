"""SLO accounting: TTFT percentiles, violation rates, RPS (§4 metrics)."""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Optional, Sequence

from repro.core.request import Request


def percentile(vals: Sequence[float], q: float) -> float:
    """Standard nearest-rank percentile: the ⌈q·n⌉-th smallest value.

    The previous ``int(q * n)`` index was biased one rank high — p99 over
    any sample smaller than 100 reported the maximum instead of the
    99th-percentile rank.
    """
    if not vals:
        return 0.0
    s = sorted(vals)
    idx = min(max(math.ceil(q * len(s)), 1), len(s)) - 1
    return s[idx]


@dataclasses.dataclass
class SLOReport:
    n: int
    rps: float
    mean_ttft: float
    p50_ttft: float
    p90_ttft: float
    p99_ttft: float
    violation_rate: float
    mean_queue_wait: float
    graph_hit_rate: float = 0.0
    # speculative decoding (DESIGN.md §10) — zeros when no draft is armed
    tokens_drafted: int = 0
    tokens_accepted: int = 0
    spec_dispatches: int = 0
    spec_acceptance: float = 0.0        # accepted / drafted
    spec_tokens_per_dispatch: float = 0.0
    # fault tolerance + admission control (DESIGN.md §11) — zeros on a
    # fault-free, accept-everything run
    rejected: int = 0                   # shed by the admission gate
    retried: int = 0                    # re-enqueued / re-routed attempts
    recovered_sessions: int = 0         # re-prefill-reconstructed sessions
    abandoned: int = 0                  # dropped at max_wall expiry

    def as_dict(self) -> Dict:
        return dataclasses.asdict(self)


class SLOTracker:
    """Streaming SLO aggregates plus a bounded tail of finished requests.

    Means, violation rate, graph hit rate, and the horizon are folded into
    O(1) state in :meth:`record`, so a long-lived serve loop never holds
    more than ``2 * max_finished`` Request objects. ``finished`` keeps the
    most recent requests for percentile estimation and for callers that
    inspect individual results — on runs shorter than ``max_finished`` it
    retains everything and :meth:`report` is exact, matching the old
    keep-it-all behaviour.
    """

    def __init__(self, slo_ttft: Optional[float] = None,
                 max_finished: int = 4096):
        self.slo = slo_ttft
        self.max_finished = max_finished
        self.finished: List[Request] = []
        # streaming aggregates over every request ever recorded
        self.n_recorded = 0
        self._ttft_sum = 0.0
        self._ttft_n = 0
        self._wait_sum = 0.0
        self._wait_n = 0
        self._viol = 0
        self._denom = 0
        self._graphs = 0
        self._max_finish = 0.0
        # speculative decoding totals, synced from Engine.stats() by the
        # serve loop (absolute values, not deltas — idempotent)
        self.tokens_drafted = 0
        self.tokens_accepted = 0
        self.spec_dispatches = 0
        self.spec_committed = 0
        # fault tolerance + admission control (DESIGN.md §11)
        self.rejected = 0
        self.retried = 0
        self.recovered = 0
        self.abandoned = 0

    def note_rejected(self, n: int = 1) -> None:
        """Admission gate shed ``n`` submits (fail-fast, never queued)."""
        self.rejected += n

    def note_retried(self, n: int = 1) -> None:
        """``n`` dispatch/handoff/submit attempts were re-tried."""
        self.retried += n

    def note_recovered(self, n: int = 1) -> None:
        """``n`` sessions were re-prefill-reconstructed after a crash."""
        self.recovered += n

    def note_abandoned(self, r: Optional[Request] = None) -> None:
        """A still-queued request was dropped (max_wall expiry).  It
        never finished, so a deadline it carried counts as violated —
        abandoning must not flatter the violation rate."""
        self.abandoned += 1
        if r is not None:
            ddl = r.deadline if r.deadline is not None else (
                None if self.slo is None else r.arrival + self.slo)
            if ddl is not None:
                self._denom += 1
                self._viol += 1

    def note_spec(self, drafted: int, accepted: int, dispatches: int,
                  committed: int = 0) -> None:
        """Sync the engine's speculative counters into the tracker.
        Absolute totals (one tracker per engine), so calling after every
        tick is safe; :meth:`merged` sums them across engines."""
        self.tokens_drafted = int(drafted)
        self.tokens_accepted = int(accepted)
        self.spec_dispatches = int(dispatches)
        self.spec_committed = int(committed)

    def record(self, r: Request) -> None:
        if getattr(r, "recovery", False):
            # a synthetic re-prefill reconstructing a crashed session:
            # count the recovery, but keep it out of TTFT/violation
            # stats — its "arrival" is the crash time, not a client's
            self.recovered += 1
            return
        self.n_recorded += 1
        t = r.ttft()
        if t is not None:
            self._ttft_sum += t
            self._ttft_n += 1
        if r.dispatch_time is not None:
            self._wait_sum += r.dispatch_time - r.arrival
            self._wait_n += 1
        ddl = r.deadline if r.deadline is not None else (
            None if self.slo is None else r.arrival + self.slo)
        if ddl is not None:
            self._denom += 1
            if r.finish_time is None or r.finish_time > ddl:
                self._viol += 1
        if r.used_graph:
            self._graphs += 1
        if r.finish_time is not None:
            self._max_finish = max(self._max_finish, r.finish_time)
        self.finished.append(r)
        if len(self.finished) > 2 * self.max_finished:
            del self.finished[:-self.max_finished]

    @classmethod
    def merged(cls, trackers: Sequence["SLOTracker"]) -> "SLOTracker":
        """Fold several trackers (one per cluster engine) into one view."""
        out = cls(trackers[0].slo if trackers else None,
                  max_finished=max((t.max_finished for t in trackers),
                                   default=4096))
        for t in trackers:
            out.n_recorded += t.n_recorded
            out._ttft_sum += t._ttft_sum
            out._ttft_n += t._ttft_n
            out._wait_sum += t._wait_sum
            out._wait_n += t._wait_n
            out._viol += t._viol
            out._denom += t._denom
            out._graphs += t._graphs
            out._max_finish = max(out._max_finish, t._max_finish)
            out.tokens_drafted += t.tokens_drafted
            out.tokens_accepted += t.tokens_accepted
            out.spec_dispatches += t.spec_dispatches
            out.spec_committed += t.spec_committed
            out.rejected += t.rejected
            out.retried += t.retried
            out.recovered += t.recovered
            out.abandoned += t.abandoned
            out.finished.extend(t.finished)
        if len(out.finished) > 2 * out.max_finished:
            out.finished.sort(key=lambda r: r.finish_time or 0.0)
            del out.finished[:-out.max_finished]
        return out

    def report(self, horizon: Optional[float] = None) -> SLOReport:
        ttfts = [r.ttft() for r in self.finished if r.ttft() is not None]
        if horizon is None:
            horizon = self._max_finish if self.n_recorded else 1.0
        return SLOReport(
            n=self.n_recorded,
            rps=self.n_recorded / max(horizon, 1e-9),
            mean_ttft=self._ttft_sum / self._ttft_n if self._ttft_n else 0.0,
            p50_ttft=percentile(ttfts, 0.50),
            p90_ttft=percentile(ttfts, 0.90),
            p99_ttft=percentile(ttfts, 0.99),
            violation_rate=self._viol / self._denom if self._denom else 0.0,
            mean_queue_wait=(self._wait_sum / self._wait_n
                             if self._wait_n else 0.0),
            graph_hit_rate=(self._graphs / self.n_recorded
                            if self.n_recorded else 0.0),
            tokens_drafted=self.tokens_drafted,
            tokens_accepted=self.tokens_accepted,
            spec_dispatches=self.spec_dispatches,
            spec_acceptance=(self.tokens_accepted
                             / max(1, self.tokens_drafted)),
            spec_tokens_per_dispatch=(self.spec_committed
                                      / max(1, self.spec_dispatches)),
            rejected=self.rejected,
            retried=self.retried,
            recovered_sessions=self.recovered,
            abandoned=self.abandoned,
        )
