"""SLO accounting: TTFT percentiles, violation rates, RPS (§4 metrics)."""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence

from repro.core.request import Request


def percentile(vals: Sequence[float], q: float) -> float:
    if not vals:
        return 0.0
    s = sorted(vals)
    idx = min(int(q * len(s)), len(s) - 1)
    return s[idx]


@dataclasses.dataclass
class SLOReport:
    n: int
    rps: float
    mean_ttft: float
    p50_ttft: float
    p90_ttft: float
    p99_ttft: float
    violation_rate: float
    mean_queue_wait: float
    graph_hit_rate: float = 0.0

    def as_dict(self) -> Dict:
        return dataclasses.asdict(self)


class SLOTracker:
    def __init__(self, slo_ttft: Optional[float] = None):
        self.slo = slo_ttft
        self.finished: List[Request] = []

    def record(self, r: Request) -> None:
        self.finished.append(r)

    def report(self, horizon: Optional[float] = None) -> SLOReport:
        rs = self.finished
        ttfts = [r.ttft() for r in rs if r.ttft() is not None]
        waits = [r.dispatch_time - r.arrival for r in rs
                 if r.dispatch_time is not None]
        if horizon is None:
            horizon = max((r.finish_time or 0.0) for r in rs) if rs else 1.0
        viol = 0
        denom = 0
        for r in rs:
            ddl = r.deadline if r.deadline is not None else (
                None if self.slo is None else r.arrival + self.slo)
            if ddl is None:
                continue
            denom += 1
            if r.finish_time is None or r.finish_time > ddl:
                viol += 1
        graphs = sum(1 for r in rs if r.used_graph)
        return SLOReport(
            n=len(rs),
            rps=len(rs) / max(horizon, 1e-9),
            mean_ttft=sum(ttfts) / len(ttfts) if ttfts else 0.0,
            p50_ttft=percentile(ttfts, 0.50),
            p90_ttft=percentile(ttfts, 0.90),
            p99_ttft=percentile(ttfts, 0.99),
            violation_rate=viol / denom if denom else 0.0,
            mean_queue_wait=sum(waits) / len(waits) if waits else 0.0,
            graph_hit_rate=graphs / len(rs) if rs else 0.0,
        )
