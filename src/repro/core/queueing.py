"""§2.2 — M/G/1 Pollaczek–Khinchine analysis of intra-prefill interference.

Used (a) to *predict* head-of-line blocking penalties for mixed long/short
prefill batching and (b) as an analytic oracle the discrete-event
simulator is validated against in tests.
"""
from __future__ import annotations

import dataclasses
from typing import Sequence, Tuple


@dataclasses.dataclass(frozen=True)
class ServiceClass:
    rate: float        # arrival rate λ_i (req/s)
    mean: float        # E[S_i] (s)
    second_moment: float  # E[S_i²] (s²)


def mixture(classes: Sequence[ServiceClass]) -> Tuple[float, float, float]:
    """Aggregate (λ, E[S], E[S²]) of a Poisson mixture."""
    lam = sum(c.rate for c in classes)
    if lam <= 0:
        return 0.0, 0.0, 0.0
    es = sum(c.rate * c.mean for c in classes) / lam
    es2 = sum(c.rate * c.second_moment for c in classes) / lam
    return lam, es, es2


def pk_wait(lam: float, es: float, es2: float) -> float:
    """P-K mean waiting time W = λE[S²] / (2(1−ρ)); inf when ρ ≥ 1."""
    rho = lam * es
    if rho >= 1.0:
        return float("inf")
    return lam * es2 / (2.0 * (1.0 - rho))


def mixed_wait(classes: Sequence[ServiceClass]) -> float:
    lam, es, es2 = mixture(classes)
    return pk_wait(lam, es, es2)


def hol_penalty(lam: float, p_short: float, s_long: float, s_short: float,
                rho: float) -> float:
    """ΔW_HoL = λ p(1−p) (S_ℓ − S_s)² / (2(1−ρ))  (§2.2).

    The extra waiting inflicted on *every* request by mixing two
    deterministic service classes instead of serving a homogeneous stream
    with the same mean.
    """
    if rho >= 1.0:
        return float("inf")
    return lam * p_short * (1.0 - p_short) * (s_long - s_short) ** 2 \
        / (2.0 * (1.0 - rho))


def normalized_latency(service: float, wait: float) -> float:
    """R/S = 1 + W/S — the convoy-effect metric (§2.2): identical W hurts
    short jobs more."""
    return 1.0 + wait / service


def utilization(classes: Sequence[ServiceClass]) -> float:
    return sum(c.rate * c.mean for c in classes)
