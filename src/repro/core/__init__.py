"""LAPS / PLA-Serve core: the paper's contribution.

boundary — §2.1 compute/memory boundary latency model + runtime fitting
queueing — §2.2 M/G/1 P-K interference analysis (HoL penalty)
buckets  — §3.1 power-of-two (L,B) graph grid + NEARESTGRAPH
awd      — Algorithm 1 Adaptive-Wait-Depth batching
queues   — §3.2 dual-queue LP/SP classification
scheduler— §3.2 temporal/spatial policies + serving modes + ablations
controller — Algorithm 2 instance-pressure controller
slo      — TTFT/violation metrics
faults   — §11 deterministic chaos injection (FaultPlan/FaultInjector)
"""
from repro.core.boundary import LatencyModel, fit, roofline_boundary, H200_QWEN32B  # noqa: F401
from repro.core.buckets import Bucket, BucketGrid  # noqa: F401
from repro.core.awd import AWDConfig, AWDScheduler  # noqa: F401
from repro.core.queues import DualQueue  # noqa: F401
from repro.core.controller import (ControllerConfig, InstanceStats, Migration,  # noqa: F401
                                   PressureController)
from repro.core.request import Batch, Request  # noqa: F401
from repro.core.scheduler import (ServingMode, Variant, make_policy,  # noqa: F401
                                  TemporalDisaggPolicy, FCFSPolicy, PoolPolicy,
                                  ChunkWork)
from repro.core.routing import (EngineView, LeastLoadedRouter,  # noqa: F401
                                LengthAwareRouter, RoundRobinRouter,
                                RouteRequest, Router, make_router)
from repro.core.slo import SLOTracker, SLOReport, percentile  # noqa: F401
from repro.core.faults import (FaultEvent, FaultInjector,  # noqa: F401
                               FaultPlan)
from repro.core import queueing  # noqa: F401
