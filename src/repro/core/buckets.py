"""§3.1 — the power-of-two (L, B) bucket grid for graph capture.

On GPU each bucket is a captured CUDA Graph; on TPU each bucket is an
AOT-compiled fixed-shape XLA executable (serving/executor.py).  The grid
and the NEARESTGRAPH matching logic are identical.
"""
from __future__ import annotations

import bisect
import dataclasses
from typing import List, Optional, Sequence, Tuple


DEFAULT_LENGTHS = (8, 16, 32, 64, 128, 256)
DEFAULT_DEPTHS = (1, 2, 4, 8, 16, 32, 64)
DEFAULT_TOKEN_BUCKETS = (64, 128, 256, 512)
DEFAULT_DECODE_BUCKETS = (1, 2, 4, 8, 16, 32)


@dataclasses.dataclass(frozen=True)
class Bucket:
    length: int   # padded per-request token length
    depth: int    # padded batch size

    @property
    def tokens(self) -> int:
        return self.length * self.depth

    @property
    def key(self) -> Tuple[int, int]:
        return (self.length, self.depth)


class BucketGrid:
    """The captured-shape grid H of Algorithm 1."""

    def __init__(self, lengths: Sequence[int] = DEFAULT_LENGTHS,
                 depths: Sequence[int] = DEFAULT_DEPTHS,
                 mem_budget_tokens: int = 16_384):
        self.lengths = tuple(sorted(lengths))
        self.depths = tuple(sorted(depths))
        self.mem_budget = mem_budget_tokens
        self.buckets = [Bucket(l, d) for l in self.lengths for d in self.depths
                        if l * d <= mem_budget_tokens]

    # ------------------------------------------------------------- lookup
    def nearest_length(self, l: int) -> Optional[int]:
        """Smallest captured length ≥ l (None if l exceeds the grid)."""
        i = bisect.bisect_left(self.lengths, l)
        return self.lengths[i] if i < len(self.lengths) else None

    def covers(self, l: int) -> bool:
        return l <= self.lengths[-1]

    def max_depth(self, length: int, mem_budget: Optional[int] = None) -> int:
        """Largest captured depth whose (length, depth) fits the budget —
        the target depth D of Algorithm 1."""
        budget = mem_budget if mem_budget is not None else self.mem_budget
        best = 0
        for d in self.depths:
            if length * d <= budget:
                best = d
        return best

    def nearest_graph(self, lengths: Sequence[int],
                      mem_budget: Optional[int] = None) -> Optional[Bucket]:
        """NEARESTGRAPH(B, H, M): smallest captured (L, B) covering every
        request with minimal padding; None if any request is off-grid or
        the padded batch busts the memory budget."""
        if not lengths:
            return None
        budget = mem_budget if mem_budget is not None else self.mem_budget
        lmax = max(lengths)
        bl = self.nearest_length(lmax)
        if bl is None:
            return None
        i = bisect.bisect_left(self.depths, len(lengths))
        if i >= len(self.depths):
            return None
        bd = self.depths[i]
        if bl * bd > budget:
            return None
        return Bucket(bl, bd)

    def padding_waste(self, lengths: Sequence[int]) -> float:
        """Fraction of padded tokens wasted for this batch under the grid."""
        b = self.nearest_graph(lengths)
        if b is None:
            return 0.0
        real = sum(lengths)
        return 1.0 - real / b.tokens

    def __len__(self) -> int:
        return len(self.buckets)


class TokenBucketLadder:
    """Padding-free alternative to the (L, B) grid: captured shapes are
    1-D TOTAL-token buckets over a packed flat stream.

    A batch of heterogeneous lengths [7, 61, 12] packs into one stream
    of 80 tokens and runs in the 128-bucket shape — the only padding is
    the bucket tail (48 tokens here), vs. padding every request to the
    max bucketed length under the dense grid.  The captured-shape space
    is |buckets|, not |lengths| × |depths|.
    """

    def __init__(self, buckets: Sequence[int] = DEFAULT_TOKEN_BUCKETS,
                 max_seqs: int = 16):
        assert buckets, "token ladder needs at least one bucket"
        self.buckets = tuple(sorted(buckets))
        self.max_seqs = max_seqs

    # ------------------------------------------------------------- lookup
    @property
    def max_tokens(self) -> int:
        return self.buckets[-1]

    def bucket_for(self, total_tokens: int) -> Optional[int]:
        """Smallest bucket ≥ total_tokens (None when off-scale)."""
        i = bisect.bisect_left(self.buckets, total_tokens)
        return self.buckets[i] if i < len(self.buckets) else None

    def covers(self, total_tokens: int) -> bool:
        return total_tokens <= self.buckets[-1]

    def padding_waste(self, lengths: Sequence[int]) -> float:
        """Fraction of executed tokens wasted on the bucket tail."""
        total = sum(lengths)
        b = self.bucket_for(total)
        if b is None or b == 0:
            return 0.0
        return 1.0 - total / b

    def __len__(self) -> int:
        return len(self.buckets)


class DecodeBucketLadder:
    """The decode-seqs ladder: a decode-only tick pads its BATCH axis to
    a small power-of-two rung, so the compiled-shape space for decode is
    O(log max_seqs) — not one executable per live session count (the
    §3.1 shape-cache blowup, in its decode form).

    Rungs above ``max_seqs`` (the arena depth) are dropped and the arena
    depth itself becomes the top rung — whether the configured ladder
    overshoots the arena OR stops short of it — so a full-arena decode
    tick always lands on the ladder and never falls back to the dense
    per-count path.
    """

    def __init__(self, buckets: Sequence[int] = DEFAULT_DECODE_BUCKETS,
                 max_seqs: Optional[int] = None):
        assert buckets, "decode ladder needs at least one rung"
        rungs = sorted(set(buckets))
        if max_seqs is not None:
            rungs = [r for r in rungs if r < max_seqs] + [max_seqs]
        self.buckets = tuple(rungs)

    @property
    def max_seqs(self) -> int:
        return self.buckets[-1]

    def bucket_for(self, n_seqs: int) -> Optional[int]:
        """Smallest rung ≥ n_seqs (None when the tick overflows)."""
        if n_seqs <= 0:
            return None
        i = bisect.bisect_left(self.buckets, n_seqs)
        return self.buckets[i] if i < len(self.buckets) else None

    def covers(self, n_seqs: int) -> bool:
        return 0 < n_seqs <= self.buckets[-1]

    def pad_rows(self, n_seqs: int) -> int:
        b = self.bucket_for(n_seqs)
        return b - n_seqs if b is not None else 0

    def __len__(self) -> int:
        return len(self.buckets)


def fit_decodes(prefill_tokens: int, n_prefill: int, n_decodes: int,
                ladder: TokenBucketLadder,
                token_bucket: Optional[int] = None,
                tokens_per_decode: int = 1
                ) -> Tuple[int, Optional[int]]:
    """How many decode sessions can fuse into a packed step already
    carrying ``prefill_tokens`` over ``n_prefill`` segments
    (continuous batching, DESIGN.md §4).

    Each fused session costs ``tokens_per_decode`` stream tokens — 1
    for a plain decode row, 1 + k when a speculative verify segment
    carries k draft tokens (DESIGN.md §10) — but always ONE sequence
    row, so the fit is min over the token room and the row room.
    Returns (n_fit, bucket) — bucket is the smallest ladder rung
    covering the fused total (or ``token_bucket`` when the caller
    pinned one); (0, None) when even the prefill part is off-ladder.

    Pure ladder arithmetic (no serving deps): the real engine's mixed
    step and the discrete-event simulator's pricing share this exact
    function, which is what keeps them in agreement.
    """
    row_room = max(0, ladder.max_seqs - n_prefill)
    want = min(n_decodes, row_room)
    while want >= 0:
        total = prefill_tokens + want * tokens_per_decode
        if total == 0:
            return 0, None
        bucket = token_bucket if token_bucket is not None \
            else ladder.bucket_for(total)
        if bucket is not None and total <= bucket:
            return want, bucket
        want -= 1
    return 0, None


def greedy_length_groups(lengths: Sequence[int],
                         grid: BucketGrid) -> List[List[int]]:
    """Greedy bucket-first grouping (Algorithm 1 line 6): indices grouped
    by their nearest captured length, minimizing intra-batch padding."""
    groups: dict = {}
    for idx, l in enumerate(lengths):
        key = grid.nearest_length(l) or -1
        groups.setdefault(key, []).append(idx)
    return [groups[k] for k in sorted(groups)]
