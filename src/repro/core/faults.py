"""Deterministic fault injection for chaos testing (DESIGN.md §11).

A :class:`FaultPlan` is a frozen, seed-reproducible script of failures;
a :class:`FaultInjector` consumes one plan and answers point queries from
the serving stack's fault seams:

  * ``crashes_due(tick)``      — engine deaths (``ServeCluster`` kills the
    engine, evacuates its queue through the router, and re-prefill-
    reconstructs its in-flight sessions on survivors);
  * ``handoff_fails(engine)``  — transient export/import failures on the
    §9 arena→arena handoff path (the cluster retries with exponential
    backoff and falls back to keeping the session home);
  * ``dispatch_fails(engine)`` — a dispatch attempt raises before the
    engine runs (the loop re-enqueues the work untouched);
  * ``submit_stall(index)``    — the Nth cluster submit is accepted but
    withheld for ``duration`` ticks before being routed (a slow/retried
    client connection).

Everything is driven by the plan — the injector holds NO hidden RNG
state, so replaying the same plan over the same workload reproduces the
same failure sequence exactly.  JAX-free: shared verbatim by the real
``ServeCluster`` and the discrete-event ``ClusterSim`` (where ``at`` is
simulated seconds instead of a tick index).
"""
from __future__ import annotations

import dataclasses
import random
from typing import Dict, List, Optional, Tuple

CRASH = "crash"          # engine dies at tick `at`
HANDOFF = "handoff"      # next `count` handoffs FROM `engine` fail
DISPATCH = "dispatch"    # next `count` dispatches ON `engine` raise
STALL = "stall"          # the `at`-th submit is held `duration` ticks

KINDS = (CRASH, HANDOFF, DISPATCH, STALL)


@dataclasses.dataclass(frozen=True)
class FaultEvent:
    kind: str                # one of KINDS
    at: float = 0.0          # tick (cluster) / seconds (sim); STALL: submit #
    engine: int = -1         # target engine (-1 = any, for HANDOFF/DISPATCH)
    count: int = 1           # transient kinds: consecutive failures injected
    duration: float = 0.0    # STALL: ticks/seconds the submit is withheld


@dataclasses.dataclass(frozen=True)
class FaultPlan:
    events: Tuple[FaultEvent, ...] = ()
    seed: Optional[int] = None       # provenance only (set by random())

    @classmethod
    def random(cls, seed: int, n_engines: int, horizon: float = 64.0,
               max_crashes: int = 1, p_crash: float = 0.5,
               p_handoff: float = 0.5, p_dispatch: float = 0.5,
               p_stall: float = 0.5, max_submits: int = 8) -> "FaultPlan":
        """A seed-deterministic chaos plan.  At most ``max_crashes``
        engines die (never all: at least one survivor is always left so
        recovery has somewhere to land); transient handoff/dispatch
        faults and submit stalls are sprinkled independently."""
        rng = random.Random(seed)
        events: List[FaultEvent] = []
        crashes = min(max_crashes, max(n_engines - 1, 0))
        victims = rng.sample(range(n_engines), n_engines)
        for v in victims[:crashes]:
            if rng.random() < p_crash:
                events.append(FaultEvent(
                    CRASH, at=float(rng.randrange(1, max(int(horizon), 2))),
                    engine=v))
        if rng.random() < p_handoff:
            events.append(FaultEvent(
                HANDOFF, at=float(rng.randrange(0, max(int(horizon), 1))),
                engine=rng.randrange(n_engines), count=rng.randint(1, 4)))
        if rng.random() < p_dispatch:
            events.append(FaultEvent(
                DISPATCH, at=float(rng.randrange(0, max(int(horizon), 1))),
                engine=rng.randrange(n_engines), count=rng.randint(1, 3)))
        if rng.random() < p_stall:
            events.append(FaultEvent(
                STALL, at=float(rng.randrange(0, max_submits)),
                duration=float(rng.randint(1, 6))))
        return cls(events=tuple(events), seed=seed)


class FaultInjector:
    """Consumes one :class:`FaultPlan`.  Stateful only in *which events
    already fired* — deterministic given the same query sequence."""

    def __init__(self, plan: FaultPlan):
        self.plan = plan
        self._left: Dict[int, int] = {
            i: ev.count for i, ev in enumerate(plan.events)}
        self._crashed: set = set()
        # injected-fault tally by kind (observability + test assertions)
        self.injected: Dict[str, int] = {k: 0 for k in KINDS}

    def _matches(self, ev: FaultEvent, kind: str, engine: int,
                 at: Optional[float]) -> bool:
        if ev.kind != kind:
            return False
        if ev.engine not in (-1, engine):
            return False
        return at is None or at >= ev.at

    def _consume(self, kind: str, engine: int,
                 at: Optional[float]) -> Optional[FaultEvent]:
        for i, ev in enumerate(self.plan.events):
            if self._left.get(i, 0) <= 0:
                continue
            if self._matches(ev, kind, engine, at):
                self._left[i] -= 1
                self.injected[kind] += 1
                return ev
        return None

    # ------------------------------------------------------------ queries
    def crashes_due(self, tick: float) -> List[int]:
        """Engine ids whose crash event has matured (fires once each)."""
        out = []
        for i, ev in enumerate(self.plan.events):
            if ev.kind == CRASH and i not in self._crashed and tick >= ev.at:
                self._crashed.add(i)
                self.injected[CRASH] += 1
                out.append(ev.engine)
        return out

    def handoff_fails(self, engine: int, at: Optional[float] = None) -> bool:
        """True when the next handoff FROM ``engine`` should fail
        transiently (one scripted failure consumed per call)."""
        return self._consume(HANDOFF, engine, at) is not None

    def dispatch_fails(self, engine: int, at: Optional[float] = None) -> bool:
        """True when the next dispatch on ``engine`` should raise."""
        return self._consume(DISPATCH, engine, at) is not None

    def submit_stall(self, index: int) -> Optional[float]:
        """Duration to withhold the ``index``-th submit, or None."""
        for i, ev in enumerate(self.plan.events):
            if (ev.kind == STALL and self._left.get(i, 0) > 0
                    and int(ev.at) == index):
                self._left[i] -= 1
                self.injected[STALL] += 1
                return ev.duration
        return None
