"""Fault-tolerant training loop: checkpoint every N steps, resume from
the latest complete checkpoint (params + optimizer + data-iterator
state), jit'd step with buffer donation.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Dict, List, Optional

import jax
import numpy as np

from repro.checkpoint.ckpt import (latest_step, load_checkpoint,
                                   save_checkpoint)
from repro.data.synthetic import SyntheticLM
from repro.models.config import ModelConfig
from repro.optim.adamw import AdamWConfig, adamw_init
from repro.training.train_step import make_train_step
from repro.models import transformer as tr


@dataclasses.dataclass
class TrainConfig:
    steps: int = 100
    ckpt_dir: Optional[str] = None
    ckpt_every: int = 50
    log_every: int = 10
    accum: int = 1
    remat: bool = True


class TrainLoop:
    def __init__(self, cfg: ModelConfig, opt_cfg: AdamWConfig,
                 data: SyntheticLM, tcfg: TrainConfig,
                 log_fn: Callable[[str], None] = print):
        self.cfg = cfg
        self.opt_cfg = opt_cfg
        self.data = data
        self.tcfg = tcfg
        self.log = log_fn
        self.history: List[Dict] = []
        step_fn = make_train_step(cfg, opt_cfg, accum=tcfg.accum,
                                  remat=tcfg.remat)
        self._step = jax.jit(step_fn, donate_argnums=(0, 1))

    # ------------------------------------------------------------- state
    def init_or_restore(self, key: jax.Array):
        params, _ = tr.init_params(self.cfg, key)
        opt_state = adamw_init(params)
        start = 0
        if self.tcfg.ckpt_dir:
            last = latest_step(self.tcfg.ckpt_dir)
            if last is not None:
                params, opt_state, meta = load_checkpoint(
                    self.tcfg.ckpt_dir, last, params, opt_state)
                self.data.restore(meta.get("data", {"step": last}))
                start = meta["step"]
                self.log(f"[restore] resumed from step {start}")
        return params, opt_state, start

    # --------------------------------------------------------------- run
    def run(self, key: jax.Array):
        params, opt_state, start = self.init_or_restore(key)
        t0 = time.perf_counter()
        for step in range(start, self.tcfg.steps):
            batch = self.data.next_batch()
            params, opt_state, metrics = self._step(params, opt_state, batch)
            if (step + 1) % self.tcfg.log_every == 0 or step == start:
                loss = float(metrics["loss"])
                self.history.append({"step": step + 1, "loss": loss,
                                     "lr": float(metrics["lr"])})
                dt = time.perf_counter() - t0
                self.log(f"[train] step {step + 1} loss {loss:.4f} "
                         f"lr {float(metrics['lr']):.2e} ({dt:.1f}s)")
            if self.tcfg.ckpt_dir and (step + 1) % self.tcfg.ckpt_every == 0:
                save_checkpoint(self.tcfg.ckpt_dir, step + 1, params,
                                opt_state, {"data": self.data.state()})
        if self.tcfg.ckpt_dir:
            save_checkpoint(self.tcfg.ckpt_dir, self.tcfg.steps, params,
                            opt_state, {"data": self.data.state()})
        return params, opt_state
