"""Training step: remat'd forward, microbatch gradient accumulation,
AdamW update.

The accumulation loop is a ``jax.lax.scan`` over microbatches with fp32
grad carry — the standard large-batch memory trick (activations exist
for one microbatch at a time; the layer-scan inside the model is
checkpointed).  All sharding is SPMD via the logical rules; per-pod data
parallelism, FSDP over ``data``, tensor over ``model``.
"""
from __future__ import annotations

import functools
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import transformer as tr
from repro.models.config import ModelConfig
from repro.optim.adamw import AdamWConfig, adamw_update


def cross_entropy(logits: jax.Array, labels: jax.Array,
                  mask: Optional[jax.Array] = None) -> jax.Array:
    """Mean token cross-entropy; logits (B, L, V) any dtype, fp32 math."""
    logits = logits.astype(jnp.float32)
    logz = jax.scipy.special.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = logz - gold
    if mask is None:
        return jnp.mean(nll)
    mask = mask.astype(jnp.float32)
    return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)


def make_loss_fn(cfg: ModelConfig, *, remat: bool = True,
                 aux_weight: float = 0.01) -> Callable:
    def loss_fn(params, tokens, labels, embeds=None):
        logits, _, aux = tr.forward(
            params, cfg,
            tokens=tokens if embeds is None else None,
            embeds=embeds, remat=remat)
        mask = None if cfg.is_encoder_only else (labels >= 0)
        labels = jnp.maximum(labels, 0)
        loss = cross_entropy(logits, labels, mask)
        return loss + aux_weight * aux, loss

    return loss_fn


def make_train_step(cfg: ModelConfig, opt_cfg: AdamWConfig, *,
                    accum: int = 1, remat: bool = True,
                    with_embeds: bool = False,
                    grad_dtype=jnp.float32,
                    constrain_grads: bool = True) -> Callable:
    """Returns train_step(params, opt_state, batch) → (params, opt_state,
    metrics).  batch: {"tokens": (A, B, L) or "embeds": (A, B, L, D),
    "labels": (A, B, L)} with A = accumulation steps.

    grad_dtype: accumulate gradients in bf16 to halve the per-microbatch
    FSDP gradient-reduction wire volume (§Perf hillclimb; the optimizer
    update still runs in fp32).
    constrain_grads: pin the accumulator sharding inside the micro loop
    (False = defer to after the scan — §Perf hypothesis)."""
    loss_fn = make_loss_fn(cfg, remat=remat)
    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)
    from repro.distributed.sharding import constrain_tree
    from repro.models.transformer import param_axes
    axes = param_axes(cfg)

    def train_step(params, opt_state, batch):
        def micro(carry, xs):
            g_acc, l_acc = carry
            if with_embeds:
                (tot, l), g = grad_fn(params, None, xs["labels"],
                                      embeds=xs["embeds"])
            else:
                (tot, l), g = grad_fn(params, xs["tokens"], xs["labels"])
            g_acc = jax.tree.map(
                lambda a, b: a + b.astype(grad_dtype), g_acc, g)
            # pin the accumulator's sharding to the param sharding:
            # XLA loses loop-carried shardings and would replicate the
            # full-model gradient on every device otherwise
            if constrain_grads:
                g_acc = constrain_tree(g_acc, axes)
            return (g_acc, l_acc + l), None

        zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, grad_dtype), params)
        (grads, loss_sum), _ = jax.lax.scan(micro, (zeros, jnp.zeros((), jnp.float32)),
                                            batch)
        if not constrain_grads:
            grads = constrain_tree(grads, axes)
        grads = jax.tree.map(lambda g: g / accum, grads)
        params, opt_state, om = adamw_update(grads, opt_state, opt_cfg,
                                             param_dtype=cfg.np_dtype)
        metrics = {"loss": loss_sum / accum, **om}
        return params, opt_state, metrics

    return train_step


def init_train_state(cfg: ModelConfig, key: jax.Array) -> Tuple[Any, Dict]:
    from repro.optim.adamw import adamw_init
    params, _ = tr.init_params(cfg, key)
    return params, adamw_init(params)
