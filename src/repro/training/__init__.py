from repro.training.train_step import make_train_step, cross_entropy  # noqa: F401
from repro.training.loop import TrainLoop, TrainConfig  # noqa: F401
