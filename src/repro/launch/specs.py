"""ShapeDtypeStruct input specs for every (arch × shape) dry-run cell.

Weak-type-correct, shardable, zero-allocation stand-ins for train /
prefill / decode steps.  Modality frontends are stubs per the
assignment: ``input_specs`` yields precomputed patch/frame embeddings of
the backbone width instead of token ids.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.distributed.sharding import ShardingRules, spec_for
from repro.models import transformer as tr
from repro.models.config import ModelConfig, ShapeSpec
from jax.sharding import NamedSharding


def _sds(shape, dtype, logical, rules: ShardingRules):
    sharding = None
    if rules.mesh is not None:
        sharding = NamedSharding(rules.mesh, spec_for(shape, logical, rules))
    return jax.ShapeDtypeStruct(shape, dtype, sharding=sharding)


@dataclasses.dataclass
class CellSpec:
    kind: str                      # train | prefill | decode
    args: Tuple                    # positional ShapeDtypeStructs after params
    accum: int = 1
    rolling: bool = False
    with_embeds: bool = False
    cache_len: Optional[int] = None


def cache_specs(cfg: ModelConfig, batch: int, max_len: int,
                rules: ShardingRules):
    """ShapeDtypeStructs for the cache pytree, with serve shardings."""
    shapes = jax.eval_shape(lambda: tr.init_cache(cfg, batch, max_len))
    axes = tr.cache_logical_axes(cfg)

    def attach(leaf_shapes, leaf_axes):
        return jax.tree.map(
            lambda s: _sds(s.shape, s.dtype, leaf_axes, rules), leaf_shapes)

    out = []
    for cs, ax in zip(shapes, axes):
        out.append({k: attach(v, ax[k]) for k, v in cs.items()})
    return out


def train_accum(shape: ShapeSpec, cfg: Optional[ModelConfig] = None
                ) -> Tuple[int, int]:
    """(accum_steps, microbatch) for the train shape.  The largest models
    (≥30B total params) take deeper accumulation — smaller microbatch
    activations are what keeps them inside HBM."""
    accum = 4
    if cfg is not None and cfg.param_count() > 30e9:
        accum = 8
    return accum, shape.global_batch // accum


def input_specs(cfg: ModelConfig, shape: ShapeSpec,
                rules: ShardingRules,
                accum_override: Optional[int] = None) -> CellSpec:
    b, s = shape.global_batch, shape.seq_len
    emb = cfg.frontend is not None
    if shape.kind == "train":
        a, mb = train_accum(shape, cfg)
        if accum_override:
            a, mb = accum_override, shape.global_batch // accum_override
        batch: Dict[str, Any] = {
            "labels": _sds((a, mb, s), jnp.int32, (None, "batch", "seq"), rules),
        }
        if emb:
            batch["embeds"] = _sds((a, mb, s, cfg.d_model), cfg.np_dtype,
                                   (None, "batch", "seq", "embed_act"), rules)
        else:
            batch["tokens"] = _sds((a, mb, s), jnp.int32,
                                   (None, "batch", "seq"), rules)
        return CellSpec("train", (batch,), accum=a, with_embeds=emb)

    if shape.kind == "prefill":
        if cfg.is_encoder_only:
            x = _sds((b, s, cfg.d_model), cfg.np_dtype,
                     ("batch", "seq", "embed_act"), rules)
            return CellSpec("encode", (x,), with_embeds=True)
        tokens = (_sds((b, s, cfg.d_model), cfg.np_dtype,
                       ("batch", "seq", "embed_act"), rules) if emb else
                  _sds((b, s), jnp.int32, ("batch", "seq"), rules))
        positions = _sds((b, s), jnp.int32, ("batch", "seq"), rules)
        caches = cache_specs(cfg, b, s, rules)
        sample_idx = _sds((b,), jnp.int32, ("batch",), rules)
        return CellSpec("prefill", (tokens, positions, caches, sample_idx),
                        with_embeds=emb, cache_len=s)

    # decode: one new token against a cache of seq_len
    cache_len = s
    rolling = False
    if cfg.sliding_window is not None and s > cfg.sliding_window:
        cache_len = cfg.sliding_window       # rolling-window KV (mixtral)
        rolling = True
    tokens = _sds((b, 1), jnp.int32, ("batch", None), rules)
    positions = _sds((b, 1), jnp.int32, ("batch", None), rules)
    caches = cache_specs(cfg, b, cache_len, rules)
    return CellSpec("decode", (tokens, positions, caches), rolling=rolling,
                    cache_len=cache_len)
