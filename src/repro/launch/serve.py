"""Serving launcher: real engine + LAPS scheduler under synthetic
multi-turn traffic (CLI wrapper over serving.loop.ServeLoop).

On this CPU container, use --smoke (reduced config).  On a pod, the same
entry point builds the production mesh and serve-rule shardings.

Example:
  PYTHONPATH=src python -m repro.launch.serve --arch qwen3-4b --smoke \
      --sessions 8 --turns 3 --variant pla_full
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs import get_config, get_smoke
from repro.core import H200_QWEN32B, Variant, make_policy
from repro.models import transformer as tr
from repro.serving import Engine, EngineConfig
from repro.serving.loop import ServeLoop


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-4b")
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--variant", default="pla_full",
                    choices=[v.value for v in Variant])
    ap.add_argument("--sessions", type=int, default=6)
    ap.add_argument("--turns", type=int, default=3)
    ap.add_argument("--decode-steps", type=int, default=4)
    ap.add_argument("--slo", type=float, default=2.0)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--packed", action="store_true",
                    help="packed token-bucket stream, arena-resident (§6)")
    args = ap.parse_args()

    cfg = get_smoke(args.arch) if args.smoke else get_config(args.arch)
    params, _ = tr.init_params(cfg, jax.random.key(args.seed))
    # --packed rides the full default stack (packed + paged pool, §12);
    # the plain run keeps the explicit slot/dense baseline
    engine = Engine(cfg, params, EngineConfig(
        num_slots=max(8, args.sessions), max_len=192, chunk_tokens=32,
        packed=args.packed, paged_kv=args.packed))
    awd_cfg = None
    if args.packed and engine.packed_executor is not None:
        from repro.core.awd import AWDConfig
        awd_cfg = AWDConfig(packed=True,
                            token_buckets=engine.ecfg.token_buckets,
                            packed_max_seqs=engine.packed_executor.max_seqs)
    policy = make_policy(Variant(args.variant), H200_QWEN32B, threshold=48,
                         chunk_tokens=32, awd_cfg=awd_cfg)
    if engine.packed_executor is None:
        # §3.1: capture the (L, B) executable grid at system init.  A
        # packed-arena engine skips this — the dense grid is only its
        # SSM/off-ladder fallback, and its warmup gathers would muddy
        # the zero-slot-copy proof counters (§6)
        cap = engine.executor.precapture(
            params, engine.arena.gather, lengths=(8, 16, 32, 64),
            depths=(1, 2, 4))
        print(f"[serve] captured {len(engine.executor.compile_times)} "
              f"shapes in {cap:.1f}s at init")
    if engine.decode_executor is not None and not engine._paged:
        # §5: compile every decode-ladder rung up front too, so no live
        # decode tick pays a first-rung compile.  The paged engine's
        # rungs key on bucket × P_max and compile lazily on first tick.
        dcap = engine.decode_executor.precapture(params, engine.arena.arena)
        print(f"[serve] captured {len(engine.decode_executor.compile_times)}"
              f" decode rungs in {dcap:.1f}s at init")
    if engine.packed_executor is not None and engine.ecfg.arena_prefill \
            and not engine._paged:
        # §6: compile every token bucket's arena-resident packed step —
        # the hot path for every prefill/mixed/chunk tick
        pcap = engine.packed_executor.precapture_arena(params,
                                                      engine.arena.arena)
        print(f"[serve] captured {len(engine.packed_executor.token_buckets)}"
              f" packed-arena buckets in {pcap:.1f}s at init")
    loop = ServeLoop(engine, policy, slo_ttft=args.slo)

    rng = np.random.default_rng(args.seed)
    t0 = time.perf_counter()
    for turn in range(args.turns):
        for s in range(args.sessions):
            if rng.random() < 0.2:
                n = int(rng.integers(48, 96))     # long prefill
            else:
                n = int(rng.integers(4, 32))      # short / re-prefill
            loop.submit(s, rng.integers(0, cfg.vocab_size, n))
        loop.run_until_idle(max_wall=120.0)
        for s in range(args.sessions):
            toks = loop.decode(s, args.decode_steps)
            if turn == args.turns - 1 and s == 0:
                print(f"[serve] session {s} decoded: {toks}")
    wall = time.perf_counter() - t0

    rep = loop.tracker.report(wall)
    print(f"[serve] arch={cfg.name} variant={args.variant} "
          f"requests={rep.n} wall={wall:.1f}s")
    print(f"[serve] mean TTFT {rep.mean_ttft * 1000:.1f} ms  "
          f"p90 {rep.p90_ttft * 1000:.1f} ms  viol {rep.violation_rate:.3f}  "
          f"graph-hit {rep.graph_hit_rate:.2f}")
    print(f"[serve] engine stats: {engine.stats()}")
    fit = engine.fit_boundary()
    if fit:
        print(f"[serve] fitted boundary L_m = {fit.boundary():.0f} tokens "
              f"(fixed {fit.fixed * 1000:.2f} ms, beta {fit.beta_eff * 1e3:.3f} ms/tok)")


if __name__ == "__main__":
    main()
