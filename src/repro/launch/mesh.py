"""Production mesh construction.

A FUNCTION (never a module-level constant) so importing this module
never touches jax device state.  Single pod: 16×16 = 256 chips
(TPU v5e pod slice); multi-pod: 2×16×16 = 512 chips with a leading
"pod" axis (DCN between pods, ICI within).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False) -> jax.sharding.Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    auto = (jax.sharding.AxisType.Auto,) * len(axes)
    return jax.make_mesh(shape, axes, axis_types=auto)


def make_host_mesh() -> jax.sharding.Mesh:
    """Degenerate 1×1 mesh for single-host smoke runs."""
    auto = (jax.sharding.AxisType.Auto,) * 2
    return jax.make_mesh((1, 1), ("data", "model"), axis_types=auto)
