import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# The two lines above MUST run before any jax-importing module: jax locks
# the device count at first backend init.  Everything else follows.

import argparse      # noqa: E402
import json          # noqa: E402
import re            # noqa: E402
import time          # noqa: E402
import traceback     # noqa: E402
from typing import Dict, Optional, Tuple  # noqa: E402

import jax           # noqa: E402
import jax.numpy as jnp  # noqa: E402

from repro.configs import ASSIGNED, get_config  # noqa: E402
from repro.distributed.sharding import (SERVE_RULES, TRAIN_RULES,  # noqa: E402
                                        ShardingRules, tree_shardings, use_rules)
from repro.launch.mesh import make_production_mesh  # noqa: E402
from repro.launch.specs import input_specs, train_accum  # noqa: E402
from repro.models import transformer as tr  # noqa: E402
from repro.models.config import SHAPES, cell_supported, shape_by_name  # noqa: E402
from repro.optim.adamw import AdamWConfig, adamw_init  # noqa: E402
from repro.training.train_step import make_train_step  # noqa: E402

# TPU v5e hardware constants (per chip) for the roofline terms
PEAK_FLOPS = 197e12          # bf16
HBM_BW = 819e9               # bytes/s
ICI_BW = 50e9                # bytes/s/link (≈ per-chip usable)

# HLO line shape: `%name = f32[8,1,128]{2,1,0} all-gather(...)`
COLLECTIVE_RE = re.compile(
    r"= (\w+)\[([\d,]*)\]\S*\s+(all-gather|all-reduce|reduce-scatter|"
    r"all-to-all|collective-permute)(?:-start)?\(")
DTYPE_BYTES = {"f32": 4, "bf16": 2, "f16": 2, "s32": 4, "u32": 4, "pred": 1,
               "f64": 8, "s64": 8, "u8": 1, "s8": 1, "f8e4m3fn": 1}
# wire multiplier: ring all-reduce moves ≈2× the buffer
WIRE = {"all-reduce": 2.0, "all-gather": 1.0, "reduce-scatter": 1.0,
        "all-to-all": 1.0, "collective-permute": 1.0}


_COMP_NAME_RE = re.compile(r"^(ENTRY\s+)?%?([\w.\-]+)\s*\(")
_WHILE_BODY_RE = re.compile(r"\bwhile\([^\n]*?body=%?([\w.\-]+)")


def collective_bytes(hlo: str, depth_factors) -> Dict[str, float]:
    """Sum per-device collective wire bytes from optimized HLO.

    Collectives inside while-loop bodies execute once per iteration.  HLO
    text does not expose trip counts, so we attribute structurally: the
    call graph of while bodies is walked from ENTRY, and a body at
    nesting depth d is multiplied by prod(depth_factors[:d]) — the known
    static trip counts of the step (grad-accum scan × layer scan ×
    attention-chunk scan).  Computations called once (fusions, the
    optimizer update) get factor 1.  Documented in EXPERIMENTS.md §Method.
    """
    out: Dict[str, float] = {k: 0.0 for k in WIRE}
    # split into computations: defs start at column 0
    blocks = []
    comp_idx: Dict[str, int] = {}
    entry = None
    for block in re.split(r"\n(?=\S)", hlo):
        head = block.split("\n", 1)[0]
        m = _COMP_NAME_RE.match(head)
        name = m.group(2) if m else f"_anon{len(blocks)}"
        comp_idx[name] = len(blocks)
        blocks.append((name, block))
        if head.startswith("ENTRY"):
            entry = name
    if entry is None and blocks:
        entry = max(blocks, key=lambda nb: len(nb[1]))[0]

    # while-body edges per computation
    children: Dict[str, list] = {n: _WHILE_BODY_RE.findall(b)
                                 for n, b in blocks}

    # BFS from entry assigning structural multipliers by nesting depth
    factor: Dict[str, float] = {}
    if entry is not None:
        factor[entry] = 1.0
        frontier = [(entry, 0)]
        while frontier:
            name, depth = frontier.pop()
            f = factor[name]
            trip = depth_factors[depth] if depth < len(depth_factors) else 1
            for child in children.get(name, []):
                if child in comp_idx and child not in factor:
                    factor[child] = f * trip
                    frontier.append((child, depth + 1))

    for name, block in blocks:
        f = factor.get(name, 1.0)
        for m in COLLECTIVE_RE.finditer(block):
            dtype, dims, op = m.groups()
            nbytes = DTYPE_BYTES.get(dtype, 4)
            for d in dims.split(","):
                if d:
                    nbytes *= int(d)
            out[op] += nbytes * f * WIRE[op]
    out["total"] = sum(out[k] for k in WIRE)
    return out


def build_step(cfg, cell, mesh, rules, opt_rules=None, opts=()):
    """Returns (fn, args) ready for jit(...).lower(*args).

    opt_rules: optional separate rule table for the optimizer state —
    ZeRO-1 proper: live weights may be replicated over data while
    master/m/v stay data-sharded (one gather per step instead of
    per-layer all-gathers)."""
    params_shapes, axes = tr.init_params(cfg, abstract=True)
    p_shard = tree_shardings(params_shapes, axes, rules)
    params = jax.tree.map(
        lambda s, sh: jax.ShapeDtypeStruct(s.shape, s.dtype, sharding=sh),
        params_shapes, p_shard)

    if cell.kind == "train":
        opt_shapes = jax.eval_shape(adamw_init, params_shapes)
        o_axes = {"m": axes, "v": axes, "master": axes, "count": None}
        o_shard = tree_shardings(opt_shapes, o_axes, opt_rules or rules)
        opt = jax.tree.map(
            lambda s, sh: jax.ShapeDtypeStruct(s.shape, s.dtype, sharding=sh),
            opt_shapes, o_shard)
        gdt = jnp.bfloat16 if "gradbf16" in opts else jnp.float32
        step = make_train_step(cfg, AdamWConfig(), accum=cell.accum,
                               remat=True, with_embeds=cell.with_embeds,
                               grad_dtype=gdt,
                               constrain_grads="lateconstrain" not in opts)
        return step, (params, opt) + cell.args

    if cell.kind == "encode":
        def encode_step(params, embeds):
            logits, _, _ = tr.forward(params, cfg, embeds=embeds)
            return logits
        return encode_step, (params,) + cell.args

    if cell.kind == "prefill":
        emb = cell.with_embeds

        def prefill_step(params, tokens, positions, caches, sample_idx):
            logits, new_caches, _ = tr.forward(
                params, cfg,
                tokens=None if emb else tokens,
                embeds=tokens if emb else None,
                positions=positions, caches=caches,
                logits_slice="last", dense_cache_write=True)
            return logits, new_caches
        return prefill_step, (params,) + cell.args

    rolling = cell.rolling

    def decode_step(params, tokens, positions, caches):
        logits, new_caches, _ = tr.forward(
            params, cfg, tokens=tokens, positions=positions, caches=caches,
            rolling=rolling, logits_slice="last")
        return logits, new_caches
    return decode_step, (params,) + cell.args


def _quant_wrap(fn, args, cell, opts):
    """Beyond-paper serving optimizations, applied as dry-run wrappers so
    model code stays unchanged (§Perf hillclimb):

    int8w  — weights stored int8 in HBM, dequantized at use (per-tensor
             static scale stand-in; production: per-channel scales, fused
             dequant inside the matmul/Pallas kernel);
    int8kv — KV cache stored int8, dequant on read / requant on write.
    """
    int8w = "int8w" in opts and cell.kind in ("prefill", "decode", "encode")
    int8kv = "int8kv" in opts and cell.kind in ("prefill", "decode")
    if not (int8w or int8kv):
        return fn, args
    import jax.numpy as jnp

    def deq(x):
        return (x.astype(jnp.bfloat16) / 16.0) if x.dtype == jnp.int8 else x

    def quant(x):
        return jnp.clip(x.astype(jnp.float32) * 16.0, -127, 127
                        ).astype(jnp.int8)

    def to_int8_spec(s):
        if s.dtype == jnp.dtype(jnp.bfloat16):
            return jax.ShapeDtypeStruct(s.shape, jnp.int8,
                                        sharding=s.sharding)
        return s

    args = list(args)
    cache_pos = 3                       # (params, tokens, positions, caches)
    if int8w:
        args[0] = jax.tree.map(
            lambda s: to_int8_spec(s) if len(s.shape) >= 2 else s, args[0])
    if int8kv:
        args[cache_pos] = jax.tree.map(to_int8_spec, args[cache_pos])

    def wrapped(params, *rest):
        if int8w:
            params = jax.tree.map(deq, params)
        rest = list(rest)
        if int8kv:
            rest[cache_pos - 1] = jax.tree.map(deq, rest[cache_pos - 1])
        out = fn(params, *rest)
        if int8kv and isinstance(out, tuple) and len(out) == 2:
            logits, caches = out
            caches = jax.tree.map(
                lambda x: quant(x) if x.dtype == jnp.bfloat16 else x, caches)
            return logits, caches
        return out

    return wrapped, tuple(args)


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             out_dir: Optional[str] = None, verbose: bool = True,
             opts: Tuple[str, ...] = ()) -> Dict:
    cfg = get_config(arch)
    shape = shape_by_name(shape_name)
    ok, why = cell_supported(cfg, shape)
    if not ok:
        return {"arch": arch, "shape": shape_name, "skipped": why}
    mesh = make_production_mesh(multi_pod=multi_pod)
    base = TRAIN_RULES if shape.kind == "train" else SERVE_RULES
    rules_map = dict(base)
    if multi_pod and shape.kind != "train":
        rules_map["batch"] = ("pod", "data")
    opt_rules = None
    if "moe-repl" in opts:
        # hillclimb: replicate live expert weights over the data axis
        # (killing per-layer all-gathers) but keep optimizer state
        # FSDP-sharded — ZeRO-1 proper: one params gather per step
        rules_map["expert_embed"] = None
        opt_map = dict(rules_map)
        opt_map["expert_embed"] = "data"
        opt_rules = ShardingRules(mesh=mesh, rules=opt_map)
    rules = ShardingRules(mesh=mesh, rules=rules_map)

    rec: Dict = {"arch": arch, "shape": shape_name,
                 "mesh": "2x16x16" if multi_pod else "16x16",
                 "devices": mesh.size, "opts": list(opts)}
    accum_override = None
    for o in opts:
        if o.startswith("accum"):
            accum_override = int(o[len("accum"):])
    with use_rules(rules):
        cell = input_specs(cfg, shape, rules, accum_override)
        fn, args = build_step(cfg, cell, mesh, rules, opt_rules, opts)
        fn, args = _quant_wrap(fn, args, cell, opts)
        # buffer donation mirrors production: KV caches update in place,
        # train params/opt-state are consumed by the step
        donate = {"train": (0, 1), "prefill": (3,), "decode": (3,),
                  "encode": ()}[cell.kind]
        t0 = time.perf_counter()
        with mesh:
            lowered = jax.jit(fn, donate_argnums=donate).lower(*args)
            compiled = lowered.compile()
        rec["compile_s"] = time.perf_counter() - t0

        ma = compiled.memory_analysis()
        peak = (ma.argument_size_in_bytes + ma.output_size_in_bytes
                + ma.temp_size_in_bytes - ma.alias_size_in_bytes)
        # The CPU backend legalizes every bf16 op to f32 (no native bf16
        # compute), materializing f32 copies of all bf16 temporaries —
        # roughly doubling temp bytes vs a TPU compilation.  Arguments and
        # outputs keep their true dtypes.  tpu_estimate_bytes corrects
        # temp by 2× (documented in EXPERIMENTS.md §Method).
        tpu_est = (ma.argument_size_in_bytes + ma.output_size_in_bytes
                   + ma.temp_size_in_bytes / 2 - ma.alias_size_in_bytes)
        rec["memory"] = {
            "argument_bytes": ma.argument_size_in_bytes,
            "output_bytes": ma.output_size_in_bytes,
            "temp_bytes": ma.temp_size_in_bytes,
            "alias_bytes": ma.alias_size_in_bytes,
            "peak_device_bytes": peak,
            "tpu_estimate_bytes": tpu_est,
        }
        ca = compiled.cost_analysis() or {}
        rec["cost"] = {"flops": ca.get("flops", 0.0),
                       "bytes_accessed": ca.get("bytes accessed", 0.0)}
        from repro.models.transformer import num_groups
        g = num_groups(cfg)
        chunks = max(shape.seq_len // 1024, 1)
        if shape.kind == "train":
            depth_factors = (cell.accum, g, chunks)
        elif shape.kind == "decode":
            depth_factors = (g, max(cfg.ssm_chunk and 1, 1))
        else:
            depth_factors = (g, chunks)
        rec["collectives"] = collective_bytes(compiled.as_text(),
                                              depth_factors)

        # Roofline terms — per chip, seconds per step (EXPERIMENTS.md
        # §Method documents each source):
        #  · compute: XLA's flops counter visits while bodies once, so the
        #    raw count undercounts scanned layers; MODEL_FLOPS/chips is an
        #    exact per-chip floor for useful compute — take the max.
        #  · memory: the per-step HBM working set (arguments + outputs +
        #    bf16-corrected temporaries) must move through HBM ≥ once.
        #  · collective: per-device wire bytes from the structural parse
        #    (= cluster_bytes/chips, the spec's normalization).
        tokens = shape.tokens if shape.kind != "decode" else shape.global_batch
        n_active = cfg.active_param_count()
        mf = 2.0 * n_active * tokens
        if shape.kind == "train":
            mf *= 3.0
        rec["model_flops"] = mf
        flops = max(rec["cost"]["flops"], mf / mesh.size)
        rec["roofline"] = {
            "compute_s": flops / PEAK_FLOPS,
            "memory_s": tpu_est / HBM_BW,
            "collective_s": rec["collectives"]["total"] / ICI_BW,
        }
        terms = rec["roofline"]
        rec["bottleneck"] = max(terms, key=terms.get)
        rec["mfu_ratio"] = (mf / mesh.size) / flops if flops else 0.0
        # roofline fraction: useful-compute time over the step's dominant
        # bound — the score §Perf drives up
        rec["roofline_fraction"] = (mf / mesh.size / PEAK_FLOPS) / \
            max(sum(terms.values()), 1e-12)

    if verbose:
        m = rec["memory"]["peak_device_bytes"] / 2**30
        te = rec["memory"]["tpu_estimate_bytes"] / 2**30
        r = rec["roofline"]
        print(f"[dryrun] {arch:20s} {shape_name:12s} {rec['mesh']:8s} "
              f"compile {rec['compile_s']:6.1f}s  mem/dev {m:6.2f} GiB "
              f"(tpu-est {te:5.2f})  "
              f"comp {r['compute_s']*1e3:8.2f}ms mem {r['memory_s']*1e3:8.2f}ms "
              f"coll {r['collective_s']*1e3:8.2f}ms  -> {rec['bottleneck']}")
    if out_dir:
        os.makedirs(out_dir, exist_ok=True)
        tag = f"{arch}_{shape_name}_{rec['mesh']}"
        if opts:
            tag += "_" + "-".join(opts)
        with open(os.path.join(out_dir, tag + ".json"), "w") as f:
            json.dump(rec, f, indent=1)
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--mesh", choices=["single", "multi", "both"],
                    default="both")
    ap.add_argument("--out", default="reports/dryrun")
    ap.add_argument("--opt", default="",
                    help="comma-separated: int8kv,int8w,moe-repl (§Perf)")
    args = ap.parse_args()

    archs = ASSIGNED if args.arch == "all" else [args.arch]
    shapes = [s.name for s in SHAPES] if args.shape == "all" else [args.shape]
    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]
    opts = tuple(o for o in args.opt.split(",") if o)
    failures = []
    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                try:
                    run_cell(arch, shape, mp, args.out, opts=opts)
                except Exception as e:  # noqa: BLE001
                    failures.append((arch, shape, mp, repr(e)))
                    print(f"[dryrun] FAIL {arch} {shape} multi={mp}: {e}")
                    traceback.print_exc()
    if failures:
        raise SystemExit(f"{len(failures)} dry-run cells failed: "
                         + "; ".join(str(f[:3]) for f in failures))
    print("[dryrun] ALL CELLS PASSED")


if __name__ == "__main__":
    main()
