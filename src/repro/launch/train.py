"""Training launcher: fault-tolerant loop with checkpoint/restart.

CPU container: --smoke trains a reduced config end-to-end.  On a pod the
same entry point builds the production mesh, applies TRAIN_RULES
shardings (FSDP×TP×pod-DP) and streams the sharded synthetic pipeline.

Example:
  PYTHONPATH=src python -m repro.launch.train --arch qwen3-4b --smoke \
      --steps 100 --ckpt-dir /tmp/ckpt
"""
from __future__ import annotations

import argparse

import jax

from repro.configs import get_config, get_smoke
from repro.data import SyntheticConfig, SyntheticLM
from repro.optim import AdamWConfig
from repro.training import TrainConfig, TrainLoop


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-4b")
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--accum", type=int, default=2)
    ap.add_argument("--seq-len", type=int, default=64)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_smoke(args.arch) if args.smoke else get_config(args.arch)
    data = SyntheticLM(SyntheticConfig(
        vocab_size=cfg.vocab_size, seq_len=args.seq_len, batch=args.batch,
        accum=args.accum, seed=args.seed))
    ocfg = AdamWConfig(lr=args.lr, warmup_steps=max(args.steps // 20, 1),
                       total_steps=args.steps)
    tcfg = TrainConfig(steps=args.steps, ckpt_dir=args.ckpt_dir,
                       ckpt_every=args.ckpt_every, accum=args.accum)
    loop = TrainLoop(cfg, ocfg, data, tcfg)
    loop.run(jax.random.key(args.seed))
    print(f"[train] done: {len(loop.history)} logged points, "
          f"final loss {loop.history[-1]['loss']:.4f}")


if __name__ == "__main__":
    main()
