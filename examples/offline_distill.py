"""Offline distillation (Table 2 scenario): deadline-free token-max
batching on the real engine — large shape-uniform batches, maximal graph
reuse, makespan comparison vs FCFS.

    PYTHONPATH=src python examples/offline_distill.py
"""
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax                                    # noqa: E402
import numpy as np                            # noqa: E402

from repro.configs import get_smoke           # noqa: E402
from repro.core import H200_QWEN32B, Variant, make_policy  # noqa: E402
from repro.core.awd import AWDConfig          # noqa: E402
from repro.models import transformer as tr    # noqa: E402
from repro.serving import Engine, EngineConfig  # noqa: E402
from repro.serving.loop import ServeLoop      # noqa: E402

N_PROMPTS = 24


def run(variant: str, cfg, params, prompts):
    engine = Engine(cfg, params, EngineConfig(num_slots=32, max_len=96,
                                              chunk_tokens=32))
    kw = {}
    if variant == "pla_full":
        kw["awd_cfg"] = AWDConfig(deadline_free=True, min_fill_tokens=64)
    policy = make_policy(Variant(variant), H200_QWEN32B, threshold=48, **kw)
    loop = ServeLoop(engine, policy, slo_ttft=None)
    t0 = time.perf_counter()
    for i, toks in enumerate(prompts):
        loop.submit(i, toks)
    loop.run_until_idle(max_wall=600.0)
    # distill: decode a fixed continuation per prompt
    for i in range(len(prompts)):
        loop.decode(i, 2)
    return time.perf_counter() - t0, loop.tracker.report()


def main():
    cfg = get_smoke("qwen2.5-14b")
    params, _ = tr.init_params(cfg, jax.random.key(2))
    rng = np.random.default_rng(3)
    prompts = [rng.integers(0, cfg.vocab_size, int(rng.integers(6, 20)))
               for _ in range(N_PROMPTS)]
    for variant in ("vanilla", "pla_full"):
        span, rep = run(variant, cfg, params, prompts)
        print(f"{variant:10s} makespan={span:6.1f}s requests={rep.n} "
              f"graph-hit={rep.graph_hit_rate:.2f}")


if __name__ == "__main__":
    main()
