"""Spatial disaggregation at cluster scale (simulated): 8 prefill
instances split into short/long pools, Algorithm 2 controller
re-balancing live, a node failure at t=10 s, and a straggler — the
full fault-tolerance story of DESIGN.md §7.

    PYTHONPATH=src python examples/cluster_spatial.py
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.core import H200_QWEN32B  # noqa: E402
from repro.core.controller import ControllerConfig, PressureController  # noqa: E402
from repro.core.scheduler import PoolPolicy  # noqa: E402
from repro.sim import ClusterSim, H200_32B, SimConfig  # noqa: E402
from repro.sim.workload import WorkloadConfig, closed_loop_clients  # noqa: E402

N = 8
UNTIL = 40.0


def main():
    def factory(i):
        return PoolPolicy(H200_QWEN32B, pool="short" if i < N // 2 else "long",
                          threshold=256)

    ctrl = PressureController(ControllerConfig(t_cool=2.0, period=1.0))
    sim = ClusterSim(N, factory, H200_32B,
                     SimConfig(router="pool", control_period=1.0),
                     classifier=lambda r: "short" if r.new_tokens < 256
                     else "long",
                     controller=ctrl)
    sim.add_clients(closed_loop_clients(96, WorkloadConfig(), seed=5))
    sim.set_straggler(3, speed=2.0)       # instance 3 runs at half speed
    sim.inject_failure(10.0, 7)           # instance 7 dies at t=10
    tracker = sim.run(UNTIL)
    rep = tracker.report(UNTIL)
    pools = [getattr(i.policy, "pool", "?") + ("†" if not i.alive else "")
             for i in sim.instances]
    print(f"requests={rep.n} rps={rep.rps:.1f} p90={rep.p90_ttft*1e3:.0f}ms "
          f"viol={rep.violation_rate:.3f}")
    print(f"final pools: {pools}")
    print(f"controller migrations: "
          f"{sum(1 for h in ctrl.history if h)} control periods, "
          f"last pressures short={ctrl.history[-1]['p_short']:.2f} "
          f"long={ctrl.history[-1]['p_long']:.2f}")


if __name__ == "__main__":
    main()
