"""Train a small LM end-to-end with the fault-tolerant loop: loss drops
over a few hundred steps; kill/restart resumes exactly.

    PYTHONPATH=src python examples/train_small.py [--steps 200]
"""
import argparse
import os
import sys
import tempfile

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax                                    # noqa: E402

from repro.configs import get_smoke           # noqa: E402
from repro.data import SyntheticConfig, SyntheticLM  # noqa: E402
from repro.optim import AdamWConfig           # noqa: E402
from repro.training import TrainConfig, TrainLoop  # noqa: E402


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--arch", default="qwen3-4b")
    ap.add_argument("--ckpt-dir", default=None)
    args = ap.parse_args()

    cfg = get_smoke(args.arch).replace(d_model=128, d_ff=256, num_layers=4)
    ckpt = args.ckpt_dir or tempfile.mkdtemp(prefix="laps_ckpt_")
    data = SyntheticLM(SyntheticConfig(vocab_size=cfg.vocab_size, seq_len=64,
                                       batch=8, accum=2, seed=11))
    loop = TrainLoop(
        cfg,
        AdamWConfig(lr=1e-3, warmup_steps=10, total_steps=args.steps),
        data,
        TrainConfig(steps=args.steps, ckpt_dir=ckpt, ckpt_every=50,
                    log_every=20, accum=2))
    loop.run(jax.random.key(0))
    first, last = loop.history[0]["loss"], loop.history[-1]["loss"]
    print(f"loss {first:.3f} → {last:.3f} over {args.steps} steps "
          f"(checkpoints in {ckpt})")
    assert last < first, "training failed to reduce loss"


if __name__ == "__main__":
    main()
