"""Quickstart: the full LAPS stack in ~40 lines.

Builds a reduced qwen3-family model, serves two multi-turn sessions
through the length-aware scheduler (dual queues → AWD bucketized batches
→ AOT executables → KV arena), decodes a few tokens, and prints the
runtime-fitted compute/memory boundary.

    PYTHONPATH=src python examples/quickstart.py
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax                                    # noqa: E402
import numpy as np                            # noqa: E402

from repro.configs import get_smoke           # noqa: E402
from repro.core import H200_QWEN32B, Variant, make_policy  # noqa: E402
from repro.models import transformer as tr    # noqa: E402
from repro.serving import Engine, EngineConfig  # noqa: E402
from repro.serving.loop import ServeLoop      # noqa: E402


def main():
    cfg = get_smoke("qwen3-4b")
    params, _ = tr.init_params(cfg, jax.random.key(0))
    engine = Engine(cfg, params, EngineConfig(num_slots=8, max_len=128,
                                              chunk_tokens=16))
    policy = make_policy(Variant("pla_full"), H200_QWEN32B, threshold=32,
                         chunk_tokens=16)
    loop = ServeLoop(engine, policy, slo_ttft=10.0)

    rng = np.random.default_rng(0)
    for turn in range(2):
        loop.submit(0, rng.integers(0, cfg.vocab_size, 12))   # short
        loop.submit(1, rng.integers(0, cfg.vocab_size, 48))   # long (chunked)
        loop.run_until_idle(max_wall=60.0)
        print(f"turn {turn}: session0 → {loop.decode(0, 4)}")

    rep = loop.tracker.report()
    print(f"served {rep.n} requests | mean TTFT {rep.mean_ttft*1e3:.0f} ms "
          f"| graph hit-rate {rep.graph_hit_rate:.2f}")
    fit = engine.fit_boundary()
    if fit:
        print(f"runtime-fitted boundary L_m ≈ {fit.boundary():.0f} tokens")


if __name__ == "__main__":
    main()
