"""End-to-end serving driver (deliverable b): batched multi-turn traffic
against the real engine under the paper's temporal disaggregation,
comparing PLA-full vs vanilla FCFS on the same trace.

    PYTHONPATH=src python examples/serve_multiturn.py [--sessions 8]
"""
import argparse
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax                                    # noqa: E402
import numpy as np                            # noqa: E402

from repro.configs import get_smoke           # noqa: E402
from repro.core import H200_QWEN32B, Variant, make_policy  # noqa: E402
from repro.models import transformer as tr    # noqa: E402
from repro.serving import Engine, EngineConfig  # noqa: E402
from repro.serving.loop import ServeLoop      # noqa: E402


def run_variant(variant: str, cfg, params, trace):
    engine = Engine(cfg, params, EngineConfig(num_slots=16, max_len=192,
                                              chunk_tokens=24))
    if not engine._paged:
        # dense (L, B) grid warmup — only the slot baseline dispatches it
        engine.executor.precapture(params, engine.arena.gather,
                                   lengths=(8, 16, 32), depths=(1, 2, 4))
    policy = make_policy(Variant(variant), H200_QWEN32B, threshold=32,
                         chunk_tokens=24)
    loop = ServeLoop(engine, policy, slo_ttft=5.0)
    t0 = time.perf_counter()
    for turn in trace:
        for session, toks in turn:
            loop.submit(session, toks)
        loop.run_until_idle(max_wall=300.0)
    wall = time.perf_counter() - t0
    rep = loop.tracker.report(wall)
    return rep, wall, engine.stats()


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--sessions", type=int, default=6)
    ap.add_argument("--turns", type=int, default=3)
    args = ap.parse_args()

    cfg = get_smoke("qwen3-4b")
    params, _ = tr.init_params(cfg, jax.random.key(1))
    rng = np.random.default_rng(7)
    trace = []
    for _ in range(args.turns):
        turn = []
        for s in range(args.sessions):
            n = int(rng.integers(40, 56)) if rng.random() < 0.2 \
                else int(rng.integers(4, 24))
            turn.append((s, rng.integers(0, cfg.vocab_size, n)))
        trace.append(turn)

    for variant in ("vanilla", "pla_full"):
        rep, wall, stats = run_variant(variant, cfg, params, trace)
        print(f"{variant:10s} n={rep.n:3d} wall={wall:5.1f}s "
              f"mean={rep.mean_ttft*1e3:7.1f}ms p90={rep.p90_ttft*1e3:7.1f}ms "
              f"graph-hit={stats['graph_hit_rate']:.2f}")


if __name__ == "__main__":
    main()
