"""Windowed (rolling) arena serving — DESIGN.md §7.

Three layers of proof for the sliding-window path:

  * kernel parity: the windowed arena kernels (`ragged_prefill_arena` /
    `decode_attn_arena` with ``window``) and their rolling oracles agree
    with full-history windowed attention (``ref_flash_attn(window=)``) —
    including wraparound, GQA, and interpret-mode Pallas;
  * the hypothesis no-alias property: random (window, history, new)
    mixes written modularly into a window+margin-deep slot never clobber
    a key still inside any query's window — the arena path matches the
    dense full-history oracle at 1e-5;
  * engine acceptance: with a DEFAULT EngineConfig, an SWA config runs
    a mixed prefill + chunk + decode ServeLoop-style schedule entirely
    arena-resident (KVArena.gather_calls == scatter_calls == 0) with
    greedy tokens identical to the full-forward oracle at every step.
"""
import itertools

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs import get_smoke
from repro.kernels import ops, ref
from repro.kernels.decode_attn import decode_attn_arena
from repro.kernels.ragged_prefill import ragged_prefill_arena
from repro.models import transformer as tr
from repro.serving import Engine, EngineConfig

KEY = jax.random.key(21)
TOL = dict(atol=1e-5, rtol=1e-5)


def _build_rolling_arena(rng, full_k, full_v, depth, n_slots=3, slot=1):
    """Arena slots with `slot` holding the last min(kv_len, depth)
    positions of (full_k, full_v) written modularly; other slots junk."""
    kv_len = full_k.shape[0]
    hkv, hd = full_k.shape[1], full_k.shape[2]
    ak = rng.standard_normal((n_slots, depth, hkv, hd)).astype(np.float32)
    av = rng.standard_normal((n_slots, depth, hkv, hd)).astype(np.float32)
    for p in range(max(0, kv_len - depth), kv_len):
        ak[slot, p % depth] = full_k[p]
        av[slot, p % depth] = full_v[p]
    return jnp.asarray(ak), jnp.asarray(av)


@pytest.mark.parametrize("hq,hkv", [(4, 4), (4, 2), (4, 1)])
@pytest.mark.parametrize("hist,new", [(0, 5), (12, 3), (30, 4), (45, 1)])
def test_windowed_prefill_kernel_parity(hq, hkv, hist, new):
    """Windowed arena prefill kernel == rolling oracle == full-history
    windowed attention, across GQA ratios and wraparound depths."""
    rng = np.random.default_rng(hist * 10 + new)
    window, depth, hd = 8, 16, 8
    kv_len = hist + new
    fk = rng.standard_normal((kv_len, hkv, hd)).astype(np.float32)
    fv = rng.standard_normal((kv_len, hkv, hd)).astype(np.float32)
    fq = rng.standard_normal((kv_len, hq, hd)).astype(np.float32)
    gt = ref.ref_flash_attn(jnp.asarray(fq[None, hist:]),
                            jnp.asarray(fk[None]), jnp.asarray(fv[None]),
                            q_offsets=jnp.asarray([hist], jnp.int32),
                            window=window)[0]
    ak, av = _build_rolling_arena(rng, fk, fv, depth)
    q = jnp.asarray(fq[hist:])
    cu = jnp.asarray([0, new], jnp.int32)
    off = jnp.asarray([hist], jnp.int32)
    kvl = jnp.asarray([kv_len], jnp.int32)
    sm = jnp.asarray([1], jnp.int32)
    o_ref = ref.ref_ragged_prefill_arena(q, ak, av, sm, cu, off, kvl,
                                         window=window)
    o_pal = ragged_prefill_arena(q, ak, av, sm, cu, off, kvl, window=window,
                                 block_q=2, block_k=4, interpret=True)
    np.testing.assert_allclose(np.asarray(o_ref), np.asarray(gt), **TOL)
    np.testing.assert_allclose(np.asarray(o_pal), np.asarray(gt), **TOL)


@pytest.mark.parametrize("kv_len", [1, 7, 16, 23, 40])
def test_windowed_decode_kernel_parity(kv_len):
    """Windowed arena decode kernel == rolling oracle == full-history
    windowed attention at every wraparound phase."""
    rng = np.random.default_rng(kv_len)
    window, depth, hq, hkv, hd = 8, 16, 4, 2, 8
    fk = rng.standard_normal((kv_len, hkv, hd)).astype(np.float32)
    fv = rng.standard_normal((kv_len, hkv, hd)).astype(np.float32)
    fq = rng.standard_normal((1, 1, hq, hd)).astype(np.float32)
    gt = ref.ref_flash_attn(jnp.asarray(fq), jnp.asarray(fk[None]),
                            jnp.asarray(fv[None]),
                            q_offsets=jnp.asarray([kv_len - 1], jnp.int32),
                            window=window)[:, 0]
    ak, av = _build_rolling_arena(rng, fk, fv, depth)
    q = jnp.asarray(fq[:, 0])
    sm = jnp.asarray([1], jnp.int32)
    kvl = jnp.asarray([kv_len], jnp.int32)
    d_ref = ref.ref_decode_attn_arena(q, ak, av, sm, kvl, window=window)
    d_pal = decode_attn_arena(q, ak, av, sm, kvl, window=window, block_k=4,
                              interpret=True)
    np.testing.assert_allclose(np.asarray(d_ref), np.asarray(gt), **TOL)
    np.testing.assert_allclose(np.asarray(d_pal), np.asarray(gt), **TOL)


def test_windowed_multi_segment_stream():
    """One packed stream mixing prefill, re-prefill (wrapped history),
    and decode segments over distinct rolling slots."""
    rng = np.random.default_rng(3)
    window, depth, hq, hkv, hd = 8, 16, 4, 2, 8
    segs = [(0, 4), (20, 3), (14, 1)]          # (history, new)
    n = len(segs)
    n_slots = n + 1
    ak = rng.standard_normal((n_slots, depth, hkv, hd)).astype(np.float32)
    av = rng.standard_normal((n_slots, depth, hkv, hd)).astype(np.float32)
    fulls, q_rows, gts = [], [], []
    for i, (h, l) in enumerate(segs):
        kv_len = h + l
        fk = rng.standard_normal((kv_len, hkv, hd)).astype(np.float32)
        fv = rng.standard_normal((kv_len, hkv, hd)).astype(np.float32)
        fq = rng.standard_normal((l, hq, hd)).astype(np.float32)
        for p in range(max(0, kv_len - depth), kv_len):
            ak[i + 1, p % depth] = fk[p]
            av[i + 1, p % depth] = fv[p]
        gts.append(ref.ref_flash_attn(
            jnp.asarray(fq[None]), jnp.asarray(fk[None]),
            jnp.asarray(fv[None]), q_offsets=jnp.asarray([h], jnp.int32),
            window=window)[0])
        q_rows.append(fq)
    q = jnp.asarray(np.concatenate(q_rows, axis=0))
    lens = [l for _, l in segs]
    cu = jnp.asarray(np.concatenate([[0], np.cumsum(lens)]), jnp.int32)
    off = jnp.asarray([h for h, _ in segs], jnp.int32)
    kvl = jnp.asarray([h + l for h, l in segs], jnp.int32)
    sm = jnp.asarray([1, 2, 3], jnp.int32)
    out = ragged_prefill_arena(q, jnp.asarray(ak), jnp.asarray(av), sm, cu,
                               off, kvl, window=window, block_q=2, block_k=4,
                               interpret=True)
    o = 0
    for i, l in enumerate(lens):
        np.testing.assert_allclose(np.asarray(out[o:o + l]),
                                   np.asarray(gts[i]), **TOL)
        o += l


# ------------------------------------------------- hypothesis property
# (optional locally; CI installs hypothesis and conftest fails loudly
# if it is missing there, so the property always runs in CI)

try:
    from hypothesis import given, settings, strategies as st
    _HAVE_HYPOTHESIS = True
except ImportError:                                      # pragma: no cover
    _HAVE_HYPOTHESIS = False


@pytest.mark.skipif(not _HAVE_HYPOTHESIS, reason="hypothesis not installed")
def test_rolling_writes_never_alias():
    @settings(max_examples=30, deadline=None)
    @given(window=st.integers(2, 12), hist=st.integers(0, 50),
           new=st.integers(1, 8), margin=st.integers(8, 16),
           seed=st.integers(0, 2**16))
    def prop(window, hist, new, margin, seed):
        _check_no_alias(window, hist, new, margin, seed)
    prop()


def _check_no_alias(window, hist, new, margin, seed):
    """The §7 no-alias invariant: modular writes into a slot of depth ≥
    window + margin (new ≤ margin) never overwrite a key still inside
    ANY query's window — random (window, history, new) mixes match the
    dense full-history windowed oracle at 1e-5, through wraparound."""
    rng = np.random.default_rng(seed)
    depth = window + margin
    hq = hkv = 2
    hd = 4
    kv_len = hist + new
    fk = rng.standard_normal((kv_len, hkv, hd)).astype(np.float32)
    fv = rng.standard_normal((kv_len, hkv, hd)).astype(np.float32)
    fq = rng.standard_normal((new, hq, hd)).astype(np.float32)
    # arena state BEFORE the step: last min(hist, depth) history rows
    ak = rng.standard_normal((2, depth, hkv, hd)).astype(np.float32)
    av = rng.standard_normal((2, depth, hkv, hd)).astype(np.float32)
    for p in range(max(0, hist - depth), hist):
        ak[1, p % depth] = fk[p]
        av[1, p % depth] = fv[p]
    # the step's own modular writes (what the layer does in place)
    ak = jnp.asarray(ak).at[1, (hist + np.arange(new)) % depth].set(
        fk[hist:])
    av = jnp.asarray(av).at[1, (hist + np.arange(new)) % depth].set(
        fv[hist:])
    gt = ref.ref_flash_attn(jnp.asarray(fq[None]), jnp.asarray(fk[None]),
                            jnp.asarray(fv[None]),
                            q_offsets=jnp.asarray([hist], jnp.int32),
                            window=window)[0]
    out = ref.ref_ragged_prefill_arena(
        jnp.asarray(fq), ak, av, jnp.asarray([1], jnp.int32),
        jnp.asarray([0, new], jnp.int32), jnp.asarray([hist], jnp.int32),
        jnp.asarray([kv_len], jnp.int32), window=window)
    np.testing.assert_allclose(np.asarray(out), np.asarray(gt), **TOL)


# ---------------------------------------------------- engine acceptance


def _greedy(params, cfg, seq):
    lo, _, _ = tr.forward(params, cfg,
                          tokens=jnp.asarray(seq, jnp.int32)[None])
    return int(jnp.argmax(lo[0, -1]))


def test_windowed_engine_arena_resident_default_config():
    """Acceptance (§12): with default EngineConfig flags, the SWA config
    runs mixed prefill + chunk + decode schedules on the PAGED arena
    with a RING page table — zero whole-slot gather/scatter, a
    window-deep logical footprint per session, greedy tokens identical
    to the full-forward dense oracle even with cached_len ≫ window."""
    cfg = get_smoke("mixtral-8x7b")            # sliding_window = 32
    params, _ = tr.init_params(cfg, KEY)
    rng = np.random.default_rng(5)
    eng = Engine(cfg, params, EngineConfig(
        num_slots=4, max_len=128, chunk_tokens=16,
        token_buckets=(16, 32), decode_buckets=(1, 2, 4)))
    assert eng._paged and eng._rolling
    assert eng.arena.ring_pages is not None
    depth = eng.arena.ring_pages * eng.arena.page_size
    assert depth < 128, "ring table must be window-deep, not S_max-deep"

    ctx = {}
    t1 = rng.integers(0, cfg.vocab_size, 10)
    t2 = rng.integers(0, cfg.vocab_size, 7)
    out = eng.step_mixed([(0, t1), (1, t2)], []).tokens
    ctx[0], ctx[1] = list(t1), list(t2)
    assert out[0] == _greedy(params, cfg, ctx[0])
    assert out[1] == _greedy(params, cfg, ctx[1])
    # decode both sessions past the ROLLING DEPTH (every slot row has
    # wrapped at least once), with a chunked long turn riding in
    last = dict(out)
    # enough ticks to (a) wrap every rolling slot row and (b) push the
    # cached length well past the window
    n_ticks = max(depth + 4, 2 * cfg.sliding_window + 5) - 10
    for i in range(n_ticks):
        if i == 20:                      # a C_l chunked long turn rides in
            long_toks = rng.integers(0, cfg.vocab_size, 40)
            tok = eng.prefill_long(2, long_toks)
            assert tok == _greedy(params, cfg, list(long_toks))
            eng.close_session(2)
        dec = eng.decode_batch([0, 1], [last[0], last[1]])
        for s in (0, 1):
            ctx[s].append(last[s])
            last[s] = dec[s][0]
            if i % 4 == 0 or i >= n_ticks - 3:   # keep the test fast
                assert last[s] == _greedy(params, cfg, ctx[s]), (s, i)
    assert eng.history(0) == 10 + n_ticks > depth      # wrapped
    assert eng.history(0) > 2 * cfg.sliding_window     # cached >> window
    # mid-conversation re-prefill next to a fused decode row
    t3 = rng.integers(0, cfg.vocab_size, 5)
    res = eng.step_mixed([(0, t3)], [(1, last[1])])
    assert res.fused
    assert res.tokens[0] == _greedy(params, cfg, ctx[0] + list(t3))
    ctx[1].append(last[1])
    assert res.tokens[1] == _greedy(params, cfg, ctx[1])
    # the §7 acceptance counters: every tick was arena-resident
    assert eng.arena.gather_calls == 0
    assert eng.arena.scatter_calls == 0
    assert eng.stats()["dense_dispatches"] == 0


def test_windowed_dense_baseline_stays_available():
    """packed=False requests the dense measurement baseline: full-depth
    slots, window enforced by masking, same greedy tokens — and the
    cause accounting labels every dense dispatch 'requested'."""
    cfg = get_smoke("mixtral-8x7b")
    params, _ = tr.init_params(cfg, KEY)
    rng = np.random.default_rng(9)
    eng = Engine(cfg, params, EngineConfig(num_slots=4, max_len=128,
                                           packed=False,
                                           arena_decode=False,
                                           paged_kv=False))
    assert not eng._rolling and eng.packed_executor is None
    assert eng.arena.arena[0]["k"].shape[2] == 128
    t1 = rng.integers(0, cfg.vocab_size, 10)
    out = eng.prefill_batch([0], [t1])
    ctx = list(t1)
    assert out[0] == _greedy(params, cfg, ctx)
    last = out[0]
    for i in range(40):                      # past the window
        ctx.append(last)
        last = eng.decode_batch([0], [last])[0][0]
        assert last == _greedy(params, cfg, ctx), i
    causes = eng.stats()["dense_dispatches_by_cause"]
    assert causes["prefill"] == {"requested": 1}
    assert causes["decode"] == {"requested": 40}
    assert eng.arena.gather_calls > 0


def test_windowed_split_replaces_dense_fallback():
    """Off-ladder totals on a rolling arena cannot fall back to the
    dense gather path — they split across packed chunks and ladder
    groups, staying arena-resident and token-exact."""
    cfg = get_smoke("mixtral-8x7b")
    params, _ = tr.init_params(cfg, KEY)
    rng = np.random.default_rng(13)
    eng = Engine(cfg, params, EngineConfig(
        num_slots=4, max_len=128, chunk_tokens=16,
        token_buckets=(16, 32), decode_buckets=(1, 2)))
    big = rng.integers(0, cfg.vocab_size, 50)   # > max bucket 32
    res = eng.step_mixed([(0, big)], [])
    assert res.tokens[0] == _greedy(params, cfg, list(big))
    assert eng.arena.gather_calls == 0 and eng.arena.scatter_calls == 0
    assert eng.stats()["dense_dispatches"] == 0
