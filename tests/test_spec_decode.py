"""Speculative decoding on the packed mixed stream (DESIGN.md §10).

The §10 contract, asserted end to end:

* **Lossless**: greedy acceptance is exact-match, so the speculative
  stream is BIT-IDENTICAL to the plain decode — at every draft quality
  (perfect, adversarial, n-gram) and on BOTH arena layouts (slot and
  paged).  Rejected tails roll back via ``arena.truncate`` and leave the
  paged refcount/free-list invariants intact (``audit``).
* **Distribution-preserving sampling**: non-greedy sessions commit by
  rejection sampling against the same filtered distribution the host
  sampler uses; the host-logits verify path and the fused on-device
  kernel path consume the same per-session uniform stream and must emit
  identical tokens — with the fused path shipping ZERO full-vocab
  logits rows.
* **Capability-gated**: rolling sliding-window slots cannot roll back
  (the tail already overwrote window history), so ``enable_spec``
  refuses exactly where ``can_handoff`` does.
"""
import jax
import numpy as np
import pytest

from repro.configs import get_smoke
from repro.models import transformer as tr
from repro.serving import Engine, EngineConfig
from repro.serving.draft import NGramDraft, ScriptedDraft
from repro.serving.sampling import SamplingParams

KEY = jax.random.key(7)


@pytest.fixture(scope="module")
def smoke():
    cfg = get_smoke("qwen3-4b")
    params, _ = tr.init_params(cfg, KEY)
    return cfg, params


def _engine(cfg, params, paged=False, **kw):
    kw.setdefault("num_slots", 4)
    kw.setdefault("max_len", 96)
    kw.setdefault("chunk_tokens", 16)
    kw.setdefault("keep_last_logits", False)
    return Engine(cfg, params, EngineConfig(paged_kv=paged, **kw))


def _spec_run(eng, prompt, n, sampling=None):
    eng.open_session(0)
    if sampling is not None:
        eng.set_sampling(0, sampling)
    t0 = eng.prefill_packed([0], [prompt])[0]
    out, cur = [t0], t0
    while len(out) < n:
        got = eng.spec_step([(0, cur)], max_new={0: n - len(out)})[0]
        assert 1 <= len(got) <= n - len(out)
        out.extend(got)
        cur = got[-1]
    return out[:n]


@pytest.mark.parametrize("paged", [False, True])
def test_greedy_spec_lossless(smoke, paged):
    """Greedy spec == plain decode, token for token, whatever fraction
    of the drafts is garbage — on slot AND paged arenas."""
    cfg, params = smoke
    rng = np.random.default_rng(0)
    prompt = rng.integers(1, cfg.vocab_size, 12)

    eng = _engine(cfg, params, paged)
    eng.open_session(0)
    t0 = eng.prefill_packed([0], [prompt])[0]
    base = [t0] + eng.decode_batch([0], [t0], steps=14)[0]

    for accept in (1.0, 0.0):
        eng = _engine(cfg, params, paged)
        eng.enable_spec(ScriptedDraft({0: base}, accept=accept,
                                      vocab=cfg.vocab_size, seed=3), k=4)
        got = _spec_run(eng, prompt, 15)
        assert got == base, (paged, accept)
        st = eng.stats()
        assert st["arena_gathers"] == 0 and st["arena_scatters"] == 0
        assert st["logits_rows_shipped"] == 0
        assert st["spec_dispatches"] > 0
        assert st["tokens_accepted"] <= st["tokens_drafted"]
        if accept == 1.0:
            # perfect drafts: every dispatch commits the full k+1 block
            assert st["spec_tokens_per_dispatch"] > 1.8
            assert st["spec_acceptance"] == 1.0
        if paged:
            eng.arena.audit()   # rollback kept refcounts coherent


def test_ngram_spec_lossless(smoke):
    """A real (oracle-free) proposer must still be lossless — the
    n-gram draft guesses from the observed stream, acceptance filters."""
    cfg, params = smoke
    rng = np.random.default_rng(4)
    prompt = rng.integers(1, cfg.vocab_size, 10)
    eng = _engine(cfg, params)
    eng.open_session(0)
    t0 = eng.prefill_packed([0], [prompt])[0]
    base = [t0] + eng.decode_batch([0], [t0], steps=12)[0]

    eng = _engine(cfg, params)
    eng.enable_spec(NGramDraft(n=3), k=4)
    assert _spec_run(eng, prompt, 13) == base


def test_sampled_spec_host_fused_parity(smoke):
    """Rejection sampling under temperature/top-k/top-p/bias: the
    host-logits verify path and the fused kernel path draw from one
    rng protocol and must produce the SAME stream; only the host path
    ships logits rows."""
    cfg, params = smoke
    rng = np.random.default_rng(8)
    prompt = rng.integers(1, cfg.vocab_size, 9)
    sp = SamplingParams(temperature=0.8, top_k=20, top_p=0.95, seed=7,
                        logit_bias={3: 2.0})
    streams, stats = {}, {}
    for fused in (False, True):
        eng = _engine(cfg, params, fused_sampling=fused)
        # an arbitrary (wrong) script: acceptance will be near zero, so
        # the rejection-resample arm is what parity exercises here
        script = list(np.random.default_rng(99)
                      .integers(1, cfg.vocab_size, 40))
        eng.enable_spec(ScriptedDraft({0: script}, accept=1.0,
                                      vocab=cfg.vocab_size, seed=0), k=3)
        streams[fused] = _spec_run(eng, prompt, 12, sampling=sp)
        stats[fused] = eng.stats()
    assert streams[False] == streams[True]
    assert stats[True]["logits_rows_shipped"] == 0
    assert stats[True]["fused_sample_steps"] > 0
    assert stats[False]["logits_rows_shipped"] > 0


def test_spec_capability_gating(smoke):
    """Rolling sliding-window arenas cannot truncate (the §7 slot
    writes modularly) — enable_spec must refuse, exactly like
    can_handoff."""
    cfg, params = smoke
    eng = _engine(cfg, params)
    assert eng.can_spec
    wcfg = get_smoke("mixtral-8x7b")        # sliding_window = 32
    wparams, _ = tr.init_params(wcfg, jax.random.key(1))
    weng = Engine(wcfg, wparams, EngineConfig(
        num_slots=4, max_len=96, chunk_tokens=16))
    assert not weng.can_spec
    with pytest.raises(AssertionError):
        weng.enable_spec(NGramDraft(), k=4)


def test_spec_counters_and_session_stats(smoke):
    """Engine.stats() exposes the §10 counters, per-session acceptance
    included."""
    cfg, params = smoke
    rng = np.random.default_rng(2)
    prompt = rng.integers(1, cfg.vocab_size, 8)
    eng = _engine(cfg, params)
    eng.open_session(0)
    t0 = eng.prefill_packed([0], [prompt])[0]
    base = [t0] + eng.decode_batch([0], [t0], steps=10)[0]

    eng = _engine(cfg, params)
    eng.enable_spec(ScriptedDraft({0: base}, accept=1.0,
                                  vocab=cfg.vocab_size, seed=0), k=4)
    _spec_run(eng, prompt, 11)
    st = eng.stats()
    assert st["tokens_drafted"] > 0
    assert st["tokens_accepted"] == st["tokens_drafted"]
    assert st["spec_committed"] == 10
    by = st["spec_by_session"][0]
    assert by["drafted"] == st["tokens_drafted"]
    assert by["acceptance"] == 1.0
