"""Arena-resident packed prefill (DESIGN.md §6): kernel-level parity of
the slot-map ragged flash prefill against the dense oracle (GQA/MHA/MQA,
ragged histories incl. history + new == S_max, decode segments),
engine-level parity of the arena path vs the gathered-cache packed path
and the dense oracle (logits + KV to 1e-5, interpret mode included),
zero whole-slot gather/scatter on every packed/mixed/chunk tick, and the
pad-slot aliasing regression — padded segments only ever touch the
S_max − 1 scratch row."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke
from repro.kernels import ops as kernel_ops
from repro.kernels import ref
from repro.kernels.ragged_prefill import ragged_prefill_arena
from repro.models import transformer as tr
from repro.serving import Engine, EngineConfig

KEY = jax.random.key(27)
TOL = dict(atol=1e-5, rtol=0)
TOL_INTERPRET = dict(atol=2e-5, rtol=0)


def rand(key, shape):
    return jax.random.normal(key, shape, jnp.float32)


def make_stream(lens, hists, s):
    """(cu, off, kvl) segment vectors for a packed stream."""
    b = len(lens)
    cu = np.zeros(b + 1, np.int32)
    cu[1:] = np.cumsum(lens)
    off = np.asarray(hists, np.int32)
    kvl = off + np.asarray(lens, np.int32)
    assert (kvl <= s).all()
    return jnp.asarray(cu), jnp.asarray(off), jnp.asarray(kvl)


# ----------------------------------------------------------- kernel level


@pytest.mark.parametrize("nslots,s,hq,hkv,d,bq,bk", [
    (8, 64, 8, 2, 32, 16, 16),    # GQA
    (5, 96, 4, 4, 64, 8, 32),     # MHA
    (6, 40, 8, 1, 16, 8, 32),     # MQA, block_k snapped to a divisor of S
])
def test_arena_prefill_kernel_matches_oracle(nslots, s, hq, hkv, d, bq, bk):
    ks = jax.random.split(KEY, 4)
    lens = [5, 9, 4]
    hists = [7, 0, 12]
    t = sum(lens) + 3                          # bucket tail rows
    q = rand(ks[0], (t, hq, d))
    k = rand(ks[1], (nslots, s, hkv, d))
    v = rand(ks[2], (nslots, s, hkv, d))
    slot = jax.random.permutation(ks[3], nslots)[:len(lens)]
    cu, off, kvl = make_stream(lens, hists, s)
    out = ragged_prefill_arena(q, k, v, slot, cu, off, kvl,
                               block_q=bq, block_k=bk)
    want = ref.ref_ragged_prefill_arena(q, k, v, slot, cu, off, kvl)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), atol=2e-5)
    # bucket tail rows belong to no segment and come out exactly zero
    np.testing.assert_array_equal(np.asarray(out)[sum(lens):], 0.0)


def test_arena_prefill_kernel_full_cache():
    """history + new == S_max: the deepest segment reads every valid
    block and nothing past the arena edge."""
    ks = jax.random.split(KEY, 4)
    nslots, s, hq, hkv, d = 4, 32, 4, 2, 16
    lens, hists = [6, 4], [s - 6, 0]
    t = sum(lens)
    q = rand(ks[0], (t, hq, d))
    k = rand(ks[1], (nslots, s, hkv, d))
    v = rand(ks[2], (nslots, s, hkv, d))
    slot = jnp.array([3, 0], jnp.int32)
    cu, off, kvl = make_stream(lens, hists, s)
    assert int(kvl[0]) == s
    out = ragged_prefill_arena(q, k, v, slot, cu, off, kvl,
                               block_q=8, block_k=8)
    want = ref.ref_ragged_prefill_arena(q, k, v, slot, cu, off, kvl)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), atol=2e-5)


def test_arena_prefill_kernel_decode_segments():
    """Length-1 decode segments (offset = full cached history) attend
    over exactly history + 1 keys through the slot-map index maps."""
    ks = jax.random.split(KEY, 4)
    nslots, s, hq, hkv, d = 6, 48, 8, 2, 32
    lens, hists = [7, 1, 1], [3, 20, 0]        # prefill + two decodes
    t = sum(lens) + 2
    q = rand(ks[0], (t, hq, d))
    k = rand(ks[1], (nslots, s, hkv, d))
    v = rand(ks[2], (nslots, s, hkv, d))
    slot = jnp.array([5, 1, 3], jnp.int32)
    cu, off, kvl = make_stream(lens, hists, s)
    out = ragged_prefill_arena(q, k, v, slot, cu, off, kvl,
                               block_q=4, block_k=16)
    want = ref.ref_ragged_prefill_arena(q, k, v, slot, cu, off, kvl)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), atol=2e-5)


# ----------------------------------------------------------- engine level

CONFIGS = {
    "qwen3-4b": lambda: get_smoke("qwen3-4b"),
    "mha": lambda: get_smoke("qwen3-4b").replace(name="mha-smoke",
                                                 num_kv_heads=4),
}


def build_pair(cfg):
    """(arena engine, gathered-cache packed engine) on shared params."""
    params, _ = tr.init_params(cfg, KEY)
    kw = dict(num_slots=8, max_len=128, chunk_tokens=32, packed=True,
              token_buckets=(64, 128, 256), paged_kv=False)
    eng = Engine(cfg, params, EngineConfig(**kw, arena_prefill=True))
    ora = Engine(cfg, params, EngineConfig(**kw, arena_prefill=False))
    return params, eng, ora


def assert_kv_parity(eng: Engine, ora: Engine, sessions, tol=TOL):
    for s in sessions:
        n = eng.arena.length(s)
        assert n == ora.arena.length(s), (s, n, ora.arena.length(s))
        sm, so = eng.arena.slot_of(s), ora.arena.slot_of(s)
        for cm, co in zip(eng.arena.arena, ora.arena.arena):
            for part in ("k", "v"):
                np.testing.assert_allclose(
                    np.asarray(cm[part][:, sm, :n]),
                    np.asarray(co[part][:, so, :n]),
                    err_msg=f"session {s} cache {part}", **tol)


@pytest.mark.parametrize("arch", list(CONFIGS))
def test_packed_arena_parity(arch):
    """Prefill batch, re-prefill, long chunk, and fused decode rows on
    the arena path reproduce the gathered-cache packed path token for
    token — with ZERO whole-slot gather/scatter calls."""
    cfg = CONFIGS[arch]()
    rng = np.random.default_rng(11)
    _, eng, ora = build_pair(cfg)
    seqs = [rng.integers(0, cfg.vocab_size, l) for l in (9, 5, 14)]
    f1 = eng.prefill_batch([2, 3, 4], seqs)
    f2 = ora.prefill_batch([2, 3, 4], seqs)
    assert f1 == f2
    long_toks = rng.integers(0, cfg.vocab_size, 50)
    for e in (eng, ora):
        e.prefill_batch([5], [long_toks[:32]])
    # one mixed tick: fresh prefill + long chunk + three decode rows
    t_a = rng.integers(0, cfg.vocab_size, 7)
    decodes = [(s, f1[s]) for s in (2, 3, 4)]
    r1 = eng.step_mixed([(0, t_a), (5, long_toks[32:])], decodes)
    r2 = ora.step_mixed([(0, t_a), (5, long_toks[32:])], decodes)
    assert r1.fused and r2.fused
    assert r1.tokens == r2.tokens
    for s in (0, 2, 3, 4, 5):
        np.testing.assert_allclose(eng.last_logits[s], ora.last_logits[s],
                                   err_msg=f"session {s} logits", **TOL)
    assert_kv_parity(eng, ora, (0, 2, 3, 4, 5))
    # the §6 acceptance proof: no whole-slot copies on the arena engine
    assert eng.arena.gather_calls == 0 and eng.arena.scatter_calls == 0
    assert ora.arena.gather_calls > 0 and ora.arena.scatter_calls > 0
    kinds = eng.packed_executor.shapes_by_kind()
    assert "packed_arena" in kinds and "packed_prefill" not in kinds


def test_packed_arena_parity_interpret_mode():
    """The same parity against the dense (unpacked) oracle engine with
    the arena Pallas kernel in interpret mode: slot-map index maps and
    length-clamped block fetches match the oracle."""
    cfg = CONFIGS["qwen3-4b"]()
    rng = np.random.default_rng(13)
    params, _ = tr.init_params(cfg, KEY)
    kernel_ops.set_backend("pallas")
    try:
        eng = Engine(cfg, params, EngineConfig(
            num_slots=8, max_len=128, chunk_tokens=32, packed=True,
            token_buckets=(64, 128, 256), paged_kv=False))
        ora = Engine(cfg, params, EngineConfig(num_slots=8, max_len=128,
                                               paged_kv=False))
        seqs = [rng.integers(0, cfg.vocab_size, l) for l in (7, 18)]
        f1 = eng.prefill_batch([0, 1], seqs)
        f2 = ora.prefill_batch([0, 1], seqs)
        assert f1 == f2
        # re-prefill on top of cached history, fused with a decode row
        t2 = rng.integers(0, cfg.vocab_size, 6)
        r1 = eng.step_mixed([(0, t2)], [(1, f1[1])])
        tok0 = ora.prefill_batch([0], [t2])[0]
        tok1 = ora.decode_batch([1], [f2[1]])[1][0]
        assert r1.tokens == {0: tok0, 1: tok1}
        for s in (0, 1):
            np.testing.assert_allclose(eng.last_logits[s],
                                       ora.last_logits[s], **TOL_INTERPRET)
        assert_kv_parity(eng, ora, (0, 1), tol=TOL_INTERPRET)
        assert eng.arena.gather_calls == 0
    finally:
        kernel_ops.set_backend(None)


def test_packed_ticks_run_zero_slot_copies():
    """End to end: prefill batches, chunked long prefill, mixed ticks,
    and bucketed decode on an attention model never call arena.gather /
    arena.scatter — the engine stats expose the proof counters."""
    cfg = CONFIGS["qwen3-4b"]()
    rng = np.random.default_rng(17)
    params, _ = tr.init_params(cfg, KEY)
    eng = Engine(cfg, params, EngineConfig(
        num_slots=8, max_len=128, chunk_tokens=32, packed=True,
        token_buckets=(64, 128, 256), paged_kv=False))
    f = eng.prefill_batch([0, 1], [rng.integers(0, cfg.vocab_size, 6)
                                   for _ in range(2)])
    eng.prefill_long(2, rng.integers(0, cfg.vocab_size, 80))
    eng.step_mixed([(3, rng.integers(0, cfg.vocab_size, 5))],
                   [(0, f[0]), (1, f[1])])
    eng.decode_batch([0, 1], [f[0], f[1]])
    st = eng.stats()
    assert st["arena_gathers"] == 0 and st["arena_scatters"] == 0
    assert st["dense_dispatches"] == 0
    assert st["packed_dispatches"] >= 5      # 1 + 3 chunks + 1 mixed


def test_dense_fallbacks_still_gather():
    """Off-ladder packed totals keep the dense gather path; SSM
    architectures are arena-resident by default (§7) and gather whole
    slots only when the dense baseline is explicitly requested."""
    rng = np.random.default_rng(19)
    cfg = CONFIGS["qwen3-4b"]()
    params, _ = tr.init_params(cfg, KEY)
    eng = Engine(cfg, params, EngineConfig(num_slots=8, max_len=64,
                                           packed=True, paged_kv=False,
                                           token_buckets=(16,)))
    eng.prefill_packed([0], [rng.integers(0, cfg.vocab_size, 30)])
    assert eng.packed_executor.total_tokens == 0     # off-ladder
    assert eng.executor.total_tokens == 30           # dense served it
    assert eng.arena.gather_calls == 1 and eng.arena.scatter_calls == 1
    assert eng.stats()["dense_dispatches_by_cause"]["prefill"] == \
        {"forced": 1}
    # mamba: arena-resident by default — the SSM state arena steps in
    # place, zero whole-slot copies
    mcfg = get_smoke("mamba2-2.7b")
    mparams, _ = tr.init_params(mcfg, KEY)
    meng = Engine(mcfg, mparams, EngineConfig(num_slots=4, max_len=64,
                                              packed=True, paged_kv=False))
    assert meng.packed_executor is not None
    out = meng.prefill_batch([0], [rng.integers(0, mcfg.vocab_size, 6)])
    assert 0 in out
    assert meng.arena.gather_calls == 0
    # the dense baseline survives behind an explicit request
    base = Engine(mcfg, mparams, EngineConfig(num_slots=4, max_len=64,
                                              packed=False, paged_kv=False))
    assert base.packed_executor is None
    out = base.prefill_batch([0], [rng.integers(0, mcfg.vocab_size, 6)])
    assert 0 in out
    assert base.arena.gather_calls == 1


# ------------------------------------------------- pad-slot aliasing


def snapshot(eng):
    return jax.tree.map(np.asarray, eng.arena.arena)


def changed_rows(before, after, slot):
    """Set of cache positions whose K or V rows differ for ``slot``."""
    rows = set()
    for cb, ca in zip(before, after):
        for part in ("k", "v"):
            diff = np.any(np.asarray(cb[part][:, slot])
                          != np.asarray(ca[part][:, slot]), axis=(0, 2, 3))
            rows.update(np.nonzero(diff)[0].tolist())
    return rows


@pytest.mark.parametrize("path", ["arena", "gather", "grid"])
def test_pad_segments_confined_to_scratch_row(path):
    """Regression for the pad-slot aliasing hazard: dummy rows reuse
    slots[0], so their junk KV writes MUST land on the S_max − 1 scratch
    row only — never a live cache entry — and sessions outside the batch
    must be untouched, on the arena path, the gathered packed path, and
    the dense (L, B) grid path alike."""
    cfg = CONFIGS["qwen3-4b"]()
    rng = np.random.default_rng(23)
    params, _ = tr.init_params(cfg, KEY)
    eng = Engine(cfg, params, EngineConfig(
        num_slots=8, max_len=64, packed=(path != "grid"), paged_kv=False,
        arena_prefill=(path == "arena"), token_buckets=(64, 128)))
    # a live victim session with cached history, NOT in the batch
    victim_toks = rng.integers(0, cfg.vocab_size, 10)
    eng.prefill_batch([9], [victim_toks])
    vslot = eng.arena.slot_of(9)
    before = snapshot(eng)
    toks = rng.integers(0, cfg.vocab_size, 5)
    if path == "grid":
        # explicit (L, B) bucket with depth padding: 1 request, 2 rows
        eng.prefill_batch([0], [toks], bucket=(8, 2))
    else:
        eng.prefill_batch([0], [toks])       # b_max − 1 dummy rows
    after = snapshot(eng)
    park = eng.arena.max_len - 1
    slot0 = eng.arena.slot_of(0)
    assert changed_rows(before, after, vslot) == set(), \
        "pad rows corrupted a live slot outside the batch"
    assert changed_rows(before, after, slot0) <= set(range(len(toks))) \
        | {park}, "batch slot written outside its new rows + scratch row"
    # the victim's cached prefix still decodes correctly
    n = eng.arena.length(9)
    assert n == 10
    for cb, ca in zip(before, after):
        for part in ("k", "v"):
            np.testing.assert_array_equal(cb[part][:, vslot, :n],
                                          ca[part][:, vslot, :n])
