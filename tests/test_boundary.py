"""§2.1 boundary model: analytic identities, runtime fitting recovery,
and hypothesis properties."""
import math

import numpy as np
import pytest
pytest.importorskip("hypothesis")
from hypothesis import given, strategies as st

from repro.core.boundary import (H200_QWEN32B, LatencyModel, TotalFit, fit,
                                 fit_total, roofline_boundary)

pos = st.floats(min_value=1e-9, max_value=1e-2, allow_nan=False)


def test_prefill_boundary_formula():
    m = LatencyModel(alpha=1e-7, beta=8e-5, gamma_w=1.2e-4, gamma_r=6e-5)
    lm = m.l_m_prefill()
    assert lm == pytest.approx((1.2e-4 - 8e-5) / 1e-7)
    # at the boundary, compute == memory for H=0
    assert m.t_comp(lm) == pytest.approx(m.t_mem(lm), rel=1e-6)


def test_gamma_w_below_beta_always_compute_bound():
    m = LatencyModel(alpha=1e-7, beta=1e-4, gamma_w=5e-5, gamma_r=1e-7)
    assert m.l_m_prefill() == 0.0


@given(h=st.floats(min_value=0.0, max_value=1e6))
def test_reprefill_boundary_root(h):
    m = LatencyModel(alpha=1e-7, beta=8e-5, gamma_w=1.2e-4, gamma_r=6e-5)
    lm = m.l_m_reprefill(h)
    if lm > 0:
        # the boundary is the root of T_comp(L,H) = T_mem(L,H)
        assert m.t_comp(lm, h) == pytest.approx(m.t_mem(lm, h), rel=1e-4)


@given(h1=st.floats(min_value=1.0, max_value=1e5),
       h2=st.floats(min_value=1.0, max_value=1e5))
def test_reprefill_boundary_monotone_toward_saturation(h1, h2):
    """L_m^re-prefill(H) approaches γ_r/(2α) monotonically as H grows —
    from below when L_m(0) < saturation (the paper's rising case), from
    above when physical γ_r puts saturation under L_m(0)."""
    for m in (LatencyModel(alpha=1e-7, beta=8e-5, gamma_w=1.2e-4,
                           gamma_r=2e-4),     # rising case
              LatencyModel(alpha=1e-7, beta=8e-5, gamma_w=1.2e-4,
                           gamma_r=6e-6)):    # descending case
        sat = m.saturation()
        lo, hi = min(h1, h2), max(h1, h2)
        d_lo = abs(m.l_m_reprefill(lo) - sat)
        d_hi = abs(m.l_m_reprefill(hi) - sat)
        assert d_hi <= d_lo + 1e-6


def test_reprefill_saturation():
    m = LatencyModel(alpha=1e-7, beta=8e-5, gamma_w=1.2e-4, gamma_r=6e-5)
    sat = m.saturation()
    assert sat == pytest.approx(6e-5 / 2e-7)
    assert m.l_m_reprefill(1e12) == pytest.approx(sat, rel=1e-3)


def test_fit_recovers_constants():
    true = LatencyModel(alpha=2e-7, beta=5e-5, gamma_w=9e-5, gamma_r=3e-5)
    rng = np.random.default_rng(0)
    samples = []
    for _ in range(200):
        l = float(rng.integers(1, 4096))
        h = float(rng.integers(0, 8192))
        noise = 1.0 + rng.normal(0, 0.01)
        samples.append((true.t_comp(l, h) * noise, true.t_mem(l, h) * noise,
                        l, h))
    est = fit(samples)
    assert est.alpha == pytest.approx(true.alpha, rel=0.05)
    assert est.beta == pytest.approx(true.beta, rel=0.1)
    assert est.gamma_w == pytest.approx(true.gamma_w, rel=0.05)
    assert est.gamma_r == pytest.approx(true.gamma_r, rel=0.05)
    assert est.l_m_prefill() == pytest.approx(true.l_m_prefill(), rel=0.15)


def test_fit_total_recovers_roofline_crossing():
    # ground truth: max(comp, mem) single-request model (sim.costmodel).
    # Production samples are short-dominated (Fig.2), which is what lets
    # the fit see the memory floor; the smooth model low-biases the
    # boundary across the max() kink (conservative classification).
    alpha, beta, fixed = 1.3e-9, 6.5e-5, 0.013
    rng = np.random.default_rng(1)
    samples = []
    for _ in range(400):
        l = float(min(max(rng.lognormal(np.log(80), 1.2), 1), 4096))
        h = float(rng.integers(0, 4096))
        t = max(alpha * l * (l + 2 * h) + beta * l, fixed + 2e-6 * l)
        samples.append((t * (1 + rng.normal(0, 0.02)), l, h))
    est = fit_total(samples)
    true_crossing = fixed / beta            # ≈ 200 tokens
    assert 0.4 * true_crossing < est.boundary() < 2.5 * true_crossing, est


def test_paper_calibration_in_range():
    assert 150 <= H200_QWEN32B.l_m_prefill() <= 512


def test_roofline_boundary():
    # 32B params, bf16 weights, H200: 989 TF / 4.8 TB/s
    lm = roofline_boundary(32e9, 0.26e6, 989e12, 4.8e12)
    assert 100 < lm < 600
    # more bandwidth → lower boundary
    lm2 = roofline_boundary(32e9, 0.26e6, 989e12, 9.6e12)
    assert lm2 < lm


def test_total_fit_l_m_degenerate():
    t = TotalFit(alpha=0.0, beta_eff=1e-4, gamma_r=0.0, fixed=0.013)
    assert t.l_m() == pytest.approx(130.0)
