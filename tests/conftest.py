import os
import sys

# single real device for tests/benches (dry-run sets its own flag)
os.environ.setdefault("JAX_PLATFORMS", "cpu")
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

try:  # hypothesis is optional — plain pytest runs without it
    from hypothesis import settings  # noqa: E402
except ImportError:
    settings = None
else:
    settings.register_profile("ci", max_examples=25, deadline=None)
    settings.load_profile("ci")
