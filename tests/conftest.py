import os
import sys

# single real device for tests/benches (dry-run sets its own flag)
os.environ.setdefault("JAX_PLATFORMS", "cpu")
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

try:  # hypothesis is optional — plain pytest runs without it
    from hypothesis import settings  # noqa: E402
except ImportError:
    settings = None
    # ... except in CI, where a missing install would silently skip every
    # property suite (batch assembly, AWD, queueing invariants).  Fail
    # loudly instead: ci.yml pins `hypothesis` in the install step.
    if os.environ.get("CI"):
        raise RuntimeError(
            "hypothesis is not installed but CI=1 — the property-based "
            "suites would silently skip; add `hypothesis` to the CI "
            "install (see .github/workflows/ci.yml)")
else:
    settings.register_profile("ci", max_examples=25, deadline=None)
    settings.load_profile("ci")
