"""Hybrid (attention + SSM) and pure-SSM arena serving — DESIGN.md §7.

The SSM state arena lets jamba-style hybrid stacks and mamba2 ride the
same arena-resident packed/decode paths as attention models: per-slot
recurrent state is read at the slot map and stepped IN PLACE inside the
layer scan.  Proofs here:

  * engine parity: arena (default config) vs the explicitly requested
    dense baseline (packed=False, arena_decode=False) through
    interleaved step_mixed / chunk / decode-tick schedules — logits AND
    recurrent state at 1e-5, in interpret mode too;
  * the acceptance counters: the arena arm never touches
    KVArena.gather/scatter;
  * pad-row hygiene: ladder padding and bucket tails target the scratch
    slot, so live SSM state is bit-identical to a pad-free run.
"""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs import get_smoke
from repro.kernels import ops as kernel_ops
from repro.models import transformer as tr
from repro.serving import Engine, EngineConfig

KEY = jax.random.key(17)
TOL = dict(atol=1e-5, rtol=1e-5)
TOL_INTERPRET = dict(atol=2e-5, rtol=2e-5)


def _engines(arch, **kw):
    cfg = get_smoke(arch)
    params, _ = tr.init_params(cfg, KEY)
    defaults = dict(num_slots=4, max_len=64, chunk_tokens=16,
                    token_buckets=(16, 32, 64), decode_buckets=(1, 2, 4))
    defaults.update(kw)
    eng = Engine(cfg, params, EngineConfig(**defaults))
    ora = Engine(cfg, params, EngineConfig(
        num_slots=4, max_len=64, packed=False, arena_decode=False,
        paged_kv=False))
    return cfg, params, eng, ora


def _slot_state(eng, session):
    """Recurrent-state pytree of one session's slot (ssm positions)."""
    slot = eng.arena.slot_of(session)
    out = []
    for c in eng.arena.arena:
        if "ssm" in c:
            out.append({k: np.asarray(c[k][:, slot]) for k in ("ssm",
                                                               "conv")})
    return out


def _drive_pair(cfg, eng, ora, tol):
    """Interleaved schedule on both engines; asserts tokens, logits,
    and per-session recurrent state agree at every step."""
    rng = np.random.default_rng(2)
    t1 = rng.integers(0, cfg.vocab_size, 9)
    t2 = rng.integers(0, cfg.vocab_size, 5)
    r1 = eng.step_mixed([(0, t1), (1, t2)], [])
    assert r1.fused
    r2o = ora.prefill_batch([0, 1], [t1, t2])
    assert r1.tokens == r2o
    last = dict(r1.tokens)
    for s in (0, 1):
        np.testing.assert_allclose(eng.last_logits[s], ora.last_logits[s],
                                   **tol)
    # staggered decode ticks through several bucket rungs
    active = [0, 1]
    for i in range(6):
        d1 = eng.decode_batch(active, [last[s] for s in active])
        d2 = ora.decode_batch(active, [last[s] for s in active])
        assert d1 == d2, (i, d1, d2)
        for s in active:
            last[s] = d1[s][0]
            np.testing.assert_allclose(eng.last_logits[s],
                                       ora.last_logits[s], **tol)
        if i == 3:
            active = [0]                     # session count changes rung
    # a mid-conversation turn fused with the decode backlog
    t3 = rng.integers(0, cfg.vocab_size, 6)
    r3 = eng.step_mixed([(1, t3)], [(0, last[0])])
    assert r3.fused and r3.n_decode == 1
    o3 = ora.prefill_batch([1], [t3])
    od = ora.decode_batch([0], [last[0]])
    assert r3.tokens[1] == o3[1] and r3.tokens[0] == od[0][0]
    for s in (0, 1):
        np.testing.assert_allclose(eng.last_logits[s], ora.last_logits[s],
                                   **tol)
    # chunked long prefill through the packed stream
    long_toks = rng.integers(0, cfg.vocab_size, 40)
    tok1 = eng.prefill_long(2, long_toks)
    tok2 = ora.prefill_long(2, long_toks)
    assert tok1 == tok2
    np.testing.assert_allclose(eng.last_logits[2], ora.last_logits[2], **tol)
    # recurrent state parity, slot-resident vs gathered
    for s in (0, 1, 2):
        st1, st2 = _slot_state(eng, s), _slot_state(ora, s)
        for c1, c2 in zip(st1, st2):
            np.testing.assert_allclose(c1["ssm"], c2["ssm"], **tol)
            np.testing.assert_allclose(c1["conv"], c2["conv"], **tol)
    # §7 acceptance counters: the arena arm never copied a slot
    assert eng.arena.gather_calls == 0 and eng.arena.scatter_calls == 0
    assert eng.stats()["dense_dispatches"] == 0
    assert ora.arena.gather_calls > 0 and ora.arena.scatter_calls > 0


@pytest.mark.parametrize("arch", ["jamba-v0.1-52b", "mamba2-2.7b"])
def test_hybrid_arena_matches_dense(arch):
    cfg, params, eng, ora = _engines(arch)
    _drive_pair(cfg, eng, ora, TOL)


def test_hybrid_arena_interpret_mode():
    """Same parity with the Pallas kernels in interpret mode (the attn
    positions of the hybrid stack route through the slot-map kernel)."""
    kernel_ops.set_backend("pallas")
    try:
        cfg, params, eng, ora = _engines("jamba-v0.1-52b")
        _drive_pair(cfg, eng, ora, TOL_INTERPRET)
    finally:
        kernel_ops.set_backend(None)


def test_state_pads_confined_to_scratch_slot():
    """Ladder pad rows and bucket tails must not perturb live recurrent
    state: a session decoded alone inside a padded rung matches the
    same session decoded in a pad-free configuration bit-for-bit."""
    cfg = get_smoke("mamba2-2.7b")
    params, _ = tr.init_params(cfg, KEY)
    rng = np.random.default_rng(7)
    toks = rng.integers(0, cfg.vocab_size, 6)
    outs = []
    for rungs in ((1,), (4,)):           # pad-free vs 3 pad rows per tick
        eng = Engine(cfg, params, EngineConfig(
            num_slots=4, max_len=64, token_buckets=(16, 32),
            decode_buckets=rungs))
        first = eng.step_mixed([(0, toks)], []).tokens[0]
        seq = [first]
        for _ in range(5):
            seq.append(eng.decode_batch([0], [seq[-1]])[0][0])
        outs.append((seq, _slot_state(eng, 0)))
    (seq_a, st_a), (seq_b, st_b) = outs
    assert seq_a == seq_b
    # 1e-5: the two configs compile different batch shapes, so XLA may
    # vectorize the state update differently at the ulp level — the
    # invariant under test is that pads never CORRUPT live state
    for c1, c2 in zip(st_a, st_b):
        np.testing.assert_allclose(c1["ssm"], c2["ssm"], **TOL)
        np.testing.assert_allclose(c1["conv"], c2["conv"], **TOL)


def test_fused_greedy_skips_logits_transfer():
    """Satellite: with keep_last_logits=False, all-greedy steps take
    their tokens from the executor's on-device argmax — zero full-vocab
    logits rows cross to host, and tokens match the shipping engine."""
    cfg = get_smoke("jamba-v0.1-52b")
    params, _ = tr.init_params(cfg, KEY)
    rng = np.random.default_rng(4)
    toks = rng.integers(0, cfg.vocab_size, 8)
    eng = Engine(cfg, params, EngineConfig(
        num_slots=4, max_len=64, token_buckets=(16, 32),
        decode_buckets=(1, 2), keep_last_logits=False))
    ref_eng = Engine(cfg, params, EngineConfig(
        num_slots=4, max_len=64, token_buckets=(16, 32),
        decode_buckets=(1, 2)))
    t1 = eng.step_mixed([(0, toks)], []).tokens[0]
    t2 = ref_eng.step_mixed([(0, toks)], []).tokens[0]
    assert t1 == t2
    seq1, seq2 = [t1], [t2]
    for _ in range(4):
        seq1.append(eng.decode_batch([0], [seq1[-1]])[0][0])
        seq2.append(ref_eng.decode_batch([0], [seq2[-1]])[0][0])
    assert seq1 == seq2
    st = eng.stats()
    assert st["logits_rows_shipped"] == 0
    assert st["fused_greedy_steps"] == 5          # 1 mixed + 4 decode
    assert 0 not in eng.last_logits               # nothing kept on host
    assert ref_eng.stats()["logits_rows_shipped"] > 0
    assert 0 in ref_eng.last_logits


def test_dense_cause_accounting_hybrid():
    """Satellite: stats() separates requested-baseline dense runs from
    capability/ladder-forced ones."""
    cfg = get_smoke("jamba-v0.1-52b")
    params, _ = tr.init_params(cfg, KEY)
    rng = np.random.default_rng(6)
    # forced: off-ladder total on a packed engine falls to dense
    # dense-cause accounting is a slot/dense-baseline concern: the paged
    # pool has no dense gather fallback, so pin the slot arena here
    eng = Engine(cfg, params, EngineConfig(num_slots=4, max_len=128,
                                           token_buckets=(16,),
                                           decode_buckets=(1, 2),
                                           paged_kv=False))
    eng.step_mixed([(0, rng.integers(0, cfg.vocab_size, 30))], [])
    causes = eng.stats()["dense_dispatches_by_cause"]
    assert causes["prefill"] == {"forced": 1}
    # arena decode can never overflow its ladder (the arena depth is
    # always the top rung), so decode never lands on the forced path
    eng.decode_batch([0], [1])
    assert "decode" not in eng.stats()["dense_dispatches_by_cause"]
    # requested: arena decode off → every decode tick is baseline-dense
    half = Engine(cfg, params, EngineConfig(num_slots=4, max_len=64,
                                            token_buckets=(16, 32),
                                            arena_decode=False,
                                            paged_kv=False))
    half.prefill_batch([0], [rng.integers(0, cfg.vocab_size, 4)])
    half.decode_batch([0], [1], steps=2)
    causes = half.stats()["dense_dispatches_by_cause"]
    assert causes["decode"] == {"requested": 2}
    # requested: pinned (L, B) bucket and packed=False engines
    base = Engine(cfg, params, EngineConfig(num_slots=4, max_len=64,
                                            packed=False,
                                            arena_decode=False,
                                            paged_kv=False))
    base.prefill_batch([0], [rng.integers(0, cfg.vocab_size, 6)])
    base.decode_batch([0], [1])
    causes = base.stats()["dense_dispatches_by_cause"]
    assert causes["prefill"] == {"requested": 1}
    assert causes["decode"] == {"requested": 1}
