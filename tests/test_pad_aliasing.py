"""Property test for the pad-slot aliasing hazard (hypothesis).

``_run_packed`` (and the dense grid's depth padding) fill dummy cache
rows with ``slots[0]`` — an ALIAS of a live slot.  The invariant that
makes this safe: padded segments and bucket-tail rows write only at the
park position S_max − 1 (the arena's designated scratch row), so for ANY
batch shape they never corrupt a live slot's cached KV.  Verified here
over random segment counts/lengths on both the arena-resident and the
gathered-cache packed paths, with a live out-of-batch victim session and
a history-bearing in-batch session as the canaries.
"""
import itertools

import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

import jax  # noqa: E402

from repro.configs import get_smoke  # noqa: E402
from repro.models import transformer as tr  # noqa: E402
from repro.serving import Engine, EngineConfig  # noqa: E402

KEY = jax.random.key(33)
_ids = itertools.count(100)


@pytest.fixture(scope="module")
def engines():
    """One engine per packed path, each with a live victim session 9
    (10 cached tokens) that no property example ever touches."""
    cfg = get_smoke("qwen3-4b")
    params, _ = tr.init_params(cfg, KEY)
    rng = np.random.default_rng(29)
    out = {}
    for arena in (True, False):
        eng = Engine(cfg, params, EngineConfig(
            num_slots=8, max_len=64, packed=True, arena_prefill=arena,
            token_buckets=(64, 128), paged_kv=False))
        eng.prefill_batch([9], [rng.integers(0, cfg.vocab_size, 10)])
        out["arena" if arena else "gather"] = (cfg, eng)
    return out


def snapshot_slot(eng, slot):
    return [
        {p: np.asarray(c[p][:, slot]) for p in ("k", "v")}
        for c in eng.arena.arena]


def changed_rows(before, after):
    rows = set()
    for cb, ca in zip(before, after):
        for part in ("k", "v"):
            diff = np.any(cb[part] != ca[part], axis=(0, 2, 3))
            rows.update(np.nonzero(diff)[0].tolist())
    return rows


@settings(deadline=None)
@given(lens=st.lists(st.integers(min_value=1, max_value=6),
                     min_size=1, max_size=3),
       seed=st.integers(min_value=0, max_value=2**31 - 1))
@pytest.mark.parametrize("path", ["arena", "gather"])
def test_pad_rows_never_corrupt_live_slots(engines, path, lens, seed):
    cfg, eng = engines[path]
    rng = np.random.default_rng(seed)
    sessions = [next(_ids) for _ in lens]
    toks = [rng.integers(0, cfg.vocab_size, l) for l in lens]
    vslot = eng.arena.slot_of(9)
    v_before = snapshot_slot(eng, vslot)
    eng.prefill_batch(sessions, toks)        # n < b_max → dummy rows
    # the out-of-batch victim is bit-identical, scratch row included
    assert changed_rows(v_before, snapshot_slot(eng, vslot)) == set()
    for s in sessions:
        eng.close_session(s)     # freed slots are reused by later examples


@settings(deadline=None, max_examples=10)
@given(l=st.integers(min_value=1, max_value=6),
       seed=st.integers(min_value=0, max_value=2**31 - 1))
@pytest.mark.parametrize("path", ["arena", "gather"])
def test_pad_rows_confined_to_scratch_row(engines, path, l, seed):
    """The aliased slots[0] itself: junk lands on row S_max − 1 only,
    beyond the new tokens the batch legitimately wrote."""
    cfg, eng = engines[path]
    rng = np.random.default_rng(seed)
    park = eng.arena.max_len - 1
    s = next(_ids)
    eng.open_session(s)
    slot = eng.arena.slot_of(s)
    before = snapshot_slot(eng, slot)
    eng.prefill_batch([s], [rng.integers(0, cfg.vocab_size, l)])
    after = snapshot_slot(eng, slot)
    assert changed_rows(before, after) <= set(range(l)) | {park}
    eng.close_session(s)
