"""§2.2 M/G/1 analytics validated against an independent event-driven
single-server queue."""
import numpy as np
import pytest
pytest.importorskip("hypothesis")
from hypothesis import given, strategies as st

from repro.core.queueing import (ServiceClass, hol_penalty, mixed_wait,
                                 mixture, normalized_latency, pk_wait,
                                 utilization)


def _simulate_mg1(rng, lam, sampler, n=40_000):
    """Event-driven M/G/1 FCFS: returns mean waiting time."""
    t = 0.0
    server_free = 0.0
    waits = []
    for _ in range(n):
        t += rng.exponential(1.0 / lam)
        start = max(t, server_free)
        waits.append(start - t)
        server_free = start + sampler()
    return float(np.mean(waits[n // 10:]))


def test_pk_matches_simulation_deterministic_service():
    rng = np.random.default_rng(0)
    lam, s = 8.0, 0.05                      # rho = 0.4
    w_sim = _simulate_mg1(rng, lam, lambda: s)
    w_pk = pk_wait(lam, s, s * s)
    assert w_sim == pytest.approx(w_pk, rel=0.08)


def test_pk_matches_simulation_two_class_mixture():
    rng = np.random.default_rng(1)
    lam, p = 6.0, 0.8
    s_short, s_long = 0.02, 0.30

    def sampler():
        return s_short if rng.random() < p else s_long

    w_sim = _simulate_mg1(rng, lam, sampler)
    classes = [ServiceClass(lam * p, s_short, s_short ** 2),
               ServiceClass(lam * (1 - p), s_long, s_long ** 2)]
    w_pk = mixed_wait(classes)
    assert w_sim == pytest.approx(w_pk, rel=0.12)


def test_hol_penalty_is_the_mixture_excess():
    """ΔW_HoL == W(mixture) − W(homogeneous with same mean)."""
    lam, p = 6.0, 0.8
    s_s, s_l = 0.02, 0.30
    classes = [ServiceClass(lam * p, s_s, s_s ** 2),
               ServiceClass(lam * (1 - p), s_l, s_l ** 2)]
    _, es, es2 = mixture(classes)
    rho = lam * es
    w_mixed = pk_wait(lam, es, es2)
    w_homog = pk_wait(lam, es, es * es)     # deterministic same-mean
    delta = hol_penalty(lam, p, s_l, s_s, rho)
    assert w_mixed - w_homog == pytest.approx(delta, rel=1e-9)


@given(p=st.floats(0.01, 0.99), lam=st.floats(0.1, 5.0),
       s_s=st.floats(0.001, 0.05), gap=st.floats(0.01, 0.5))
def test_hol_penalty_positive_and_grows_with_gap(p, lam, s_s, gap):
    s_l = s_s + gap
    es = p * s_s + (1 - p) * s_l
    rho = lam * es
    if rho >= 0.95:
        return
    d1 = hol_penalty(lam, p, s_l, s_s, rho)
    d2 = hol_penalty(lam, p, s_l + gap, s_s, rho)
    assert d1 > 0
    assert d2 > d1


def test_convoy_effect_hurts_short_jobs_more():
    """Same W ⇒ normalized latency is worse for shorter service (§2.2)."""
    w = 0.1
    assert normalized_latency(0.02, w) > normalized_latency(0.3, w)


def test_utilization():
    cs = [ServiceClass(2.0, 0.1, 0.01), ServiceClass(1.0, 0.3, 0.09)]
    assert utilization(cs) == pytest.approx(0.5)
