"""Per-architecture smoke tests (deliverable f).

Each assigned arch instantiates a REDUCED same-family config and runs
one forward + one train step on CPU, asserting output shapes and no
NaNs.  Full configs are exercised only via the dry-run.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ASSIGNED, get_config, get_smoke
from repro.models import transformer as tr
from repro.models.config import SHAPES, cell_supported
from repro.optim import AdamWConfig, adamw_init
from repro.training import make_train_step

KEY = jax.random.key(0)


@pytest.mark.parametrize("arch", ASSIGNED)
def test_forward_smoke(arch):
    cfg = get_smoke(arch)
    params, axes = tr.init_params(cfg, KEY)
    b, l = 2, 16
    if cfg.frontend or cfg.is_encoder_only:
        logits, _, _ = tr.forward(
            params, cfg, embeds=jax.random.normal(KEY, (b, l, cfg.d_model)))
    else:
        tok = jax.random.randint(KEY, (b, l), 0, cfg.vocab_size)
        logits, _, _ = tr.forward(params, cfg, tokens=tok)
    assert logits.shape == (b, l, cfg.padded_vocab)
    assert not jnp.isnan(logits).any()
    # padded vocab columns are masked out of argmax/softmax
    if cfg.padded_vocab > cfg.vocab_size:
        assert int(jnp.argmax(logits, -1).max()) < cfg.vocab_size


@pytest.mark.parametrize("arch", ASSIGNED)
def test_train_step_smoke(arch):
    cfg = get_smoke(arch)
    params, _ = tr.init_params(cfg, KEY)
    opt = adamw_init(params)
    emb = cfg.frontend is not None or cfg.is_encoder_only
    step = make_train_step(cfg, AdamWConfig(warmup_steps=1, total_steps=10),
                           accum=2, remat=True, with_embeds=emb)
    a, b, l = 2, 2, 16
    rng = np.random.default_rng(0)
    batch = {"labels": jnp.asarray(
        rng.integers(0, cfg.vocab_size, (a, b, l)), jnp.int32)}
    if emb:
        batch["embeds"] = jnp.asarray(
            rng.normal(size=(a, b, l, cfg.d_model)), cfg.np_dtype)
    else:
        batch["tokens"] = jnp.asarray(
            rng.integers(0, cfg.vocab_size, (a, b, l)), jnp.int32)
    params2, opt2, metrics = jax.jit(step)(params, opt, batch)
    assert jnp.isfinite(metrics["loss"])
    assert float(metrics["grad_norm"]) > 0
    # params actually moved
    moved = jax.tree.map(lambda x, y: float(jnp.max(jnp.abs(x - y))),
                         params, params2)
    assert max(jax.tree.leaves(moved)) > 0


@pytest.mark.parametrize("arch", ASSIGNED)
def test_full_config_matches_assignment(arch):
    cfg = get_config(arch)
    # spot-check the published numbers survived transcription
    expected = {
        "qwen3-4b": (36, 2560, 32, 8, 9728, 151_936),
        "stablelm-1.6b": (24, 2048, 32, 32, 5632, 100_352),
        "qwen2.5-14b": (48, 5120, 40, 8, 13_824, 152_064),
        "minitron-8b": (32, 4096, 32, 8, 16_384, 256_000),
        "mixtral-8x7b": (32, 4096, 32, 8, 14_336, 32_000),
        "qwen3-moe-30b-a3b": (48, 2048, 32, 4, 768, 151_936),
        "phi-3-vision-4.2b": (32, 3072, 32, 32, 8192, 32_064),
        "mamba2-2.7b": (64, 2560, 0, 0, 0, 50_280),
        "hubert-xlarge": (48, 1280, 16, 16, 5120, 504),
        "jamba-v0.1-52b": (32, 4096, 32, 8, 14_336, 65_536),
    }[arch]
    got = (cfg.num_layers, cfg.d_model, cfg.num_heads, cfg.num_kv_heads,
           cfg.d_ff, cfg.vocab_size)
    assert got == expected


def test_cell_matrix():
    """32 runnable cells + 8 principled skips (DESIGN.md §5)."""
    runnable = skipped = 0
    for arch in ASSIGNED:
        cfg = get_config(arch)
        for shape in SHAPES:
            ok, why = cell_supported(cfg, shape)
            if ok:
                runnable += 1
            else:
                skipped += 1
                assert why
    assert runnable == 32
    assert skipped == 8


def test_param_counts_plausible():
    # analytic parameter counts should be in the advertised ballpark
    approx = {"qwen3-4b": 4e9, "stablelm-1.6b": 1.6e9, "qwen2.5-14b": 14e9,
              "minitron-8b": 8e9, "mixtral-8x7b": 47e9,
              "qwen3-moe-30b-a3b": 30e9, "phi-3-vision-4.2b": 3.8e9,
              "mamba2-2.7b": 2.7e9, "jamba-v0.1-52b": 52e9}
    for arch, target in approx.items():
        n = get_config(arch).param_count()
        assert 0.5 * target < n < 1.7 * target, (arch, n, target)


def test_moe_active_params():
    cfg = get_config("mixtral-8x7b")
    active = cfg.active_param_count()
    total = cfg.param_count()
    assert active < total * 0.45          # top-2 of 8 experts + dense parts
    cfg2 = get_config("qwen3-moe-30b-a3b")
    assert cfg2.active_param_count() < cfg2.param_count() * 0.25
