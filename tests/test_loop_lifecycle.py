"""Serve-loop lifecycle regressions (PR 7 bugfix sweep).

Each test pins a bug that shipped in an earlier PR:

* ``submit`` read ``engine.history`` at enqueue time only — stale the
  moment a second turn of the same session was queued behind the first
  (wrong dual-queue class, wrong AWD billing, wrong write offset).
* ``SLOTracker.finished`` grew without bound — a long-lived loop held
  every Request ever served.
* ``close_session`` freed the engine slot but left the session's queued
  turns in the policy and its prompts in ``_tokens`` — a later tick
  dispatched a prefill into the freed (or reallocated) slot and
  ``_outstanding`` never drained.
* ``percentile`` used ``int(q * n)`` — one rank high; p99 of any sample
  smaller than 100 reported the maximum.
"""
import jax
import numpy as np
import pytest

from repro.configs import get_smoke
from repro.core import H200_QWEN32B, Variant, make_policy
from repro.core.request import Request
from repro.core.slo import SLOTracker, percentile
from repro.models import transformer as tr
from repro.serving import Engine, EngineConfig
from repro.serving.loop import ServeLoop

KEY = jax.random.key(11)


@pytest.fixture(scope="module")
def smoke():
    cfg = get_smoke("qwen3-4b")
    params, _ = tr.init_params(cfg, KEY)
    return cfg, params


def _loop(cfg, params, **ecfg_kw):
    ecfg_kw.setdefault("num_slots", 4)
    ecfg_kw.setdefault("max_len", 96)
    ecfg_kw.setdefault("chunk_tokens", 16)
    engine = Engine(cfg, params, EngineConfig(**ecfg_kw))
    policy = make_policy(Variant("pla_full"), H200_QWEN32B, threshold=24,
                         chunk_tokens=16)
    return ServeLoop(engine, policy, slo_ttft=30.0)


# --------------------------------------------------- stale history on submit
def test_back_to_back_submits_history(smoke):
    """Turn 2 queued before turn 1 dispatches: its enqueue-time history
    must count turn 1's queued tokens (the estimate), and its dispatch-time
    history must equal the true cache length.  The pre-fix code reported
    history 0 for turn 2 in both places."""
    cfg, params = smoke
    loop = _loop(cfg, params)
    rng = np.random.default_rng(1)
    t1 = rng.integers(0, cfg.vocab_size, 7)
    t2 = rng.integers(0, cfg.vocab_size, 5)
    r1 = loop.submit(0, t1)
    r2 = loop.submit(0, t2)          # queued behind turn 1
    assert r1.history_tokens == 0
    assert r2.history_tokens == 7    # estimate: turn 1's queued tokens
    loop.run_until_idle(max_wall=120.0)
    assert r1.history_tokens == 0
    assert r2.history_tokens == 7    # exact at dispatch: 7 cached tokens
    assert loop.engine.history(0) == 12
    # nothing leaks once served
    assert loop._outstanding == 0
    assert not loop._tokens
    assert not loop._session_pending


def test_pending_estimate_forgets_preempted_decode_budget(smoke):
    """A new turn preempts generation — including decode budgets of
    EARLIER turns still queued.  The pending-token estimate must forget
    those never-to-be-generated tokens or turn 3's history would be
    overcounted."""
    cfg, params = smoke
    loop = _loop(cfg, params)
    rng = np.random.default_rng(2)
    loop.submit(0, rng.integers(0, cfg.vocab_size, 6), decode_tokens=8)
    r2 = loop.submit(0, rng.integers(0, cfg.vocab_size, 4))
    # turn 1's 8-token budget was cancelled by turn 2's arrival
    assert r2.history_tokens == 6
    loop.run_until_idle(max_wall=120.0)
    assert r2.history_tokens == 6
    assert loop.engine.history(0) == 10
    assert not loop._session_pending


# --------------------------------------------------- close purges queued work
def test_close_session_purges_queued_turns(smoke):
    """close_session with turns still queued: the policy queue, the
    prompt store, and the outstanding count all drop — and the other
    session still completes.  Pre-fix, the purged session's prefill
    later dispatched into the freed slot."""
    cfg, params = smoke
    loop = _loop(cfg, params)
    rng = np.random.default_rng(3)
    loop.submit(0, rng.integers(0, cfg.vocab_size, 6), decode_tokens=4)
    loop.submit(0, rng.integers(0, cfg.vocab_size, 30))   # long, queued
    loop.submit(1, rng.integers(0, cfg.vocab_size, 5), decode_tokens=2)
    assert loop._outstanding == 3
    loop.close_session(0)
    assert loop._outstanding == 1
    assert all(p.req.session != 0 for p in loop._tokens.values())
    assert loop.policy.queue_len() == 1
    assert 0 not in loop._session_pending
    loop.run_until_idle(max_wall=120.0)
    assert loop._outstanding == 0 and not loop.active_decodes
    assert len(loop.generated[1]) == 3          # first + 2
    assert loop.tracker.report().n == 1         # only session 1 finished
    assert 0 not in loop.generated


def test_close_session_mid_decode(smoke):
    """Closing while a session is actively decoding drops its budget and
    frees the slot for reuse."""
    cfg, params = smoke
    loop = _loop(cfg, params)
    rng = np.random.default_rng(4)
    loop.submit(0, rng.integers(0, cfg.vocab_size, 6), decode_tokens=50)
    loop.tick()                                 # prefill dispatched
    assert 0 in loop.active_decodes
    loop.close_session(0)
    assert not loop.has_work
    assert 0 not in loop.active_decodes and 0 not in loop.generated
    # the slot is genuinely free: a fresh session can take it
    loop.submit(0, rng.integers(0, cfg.vocab_size, 5))
    loop.run_until_idle(max_wall=60.0)
    assert loop.engine.history(0) == 5


# ------------------------------------------------------ bounded SLO tracker
def _fake_request(i: int, ttft: float, slo: float = 0.4) -> Request:
    r = Request(new_tokens=8, arrival=float(i),
                deadline=float(i) + slo, session=i)
    r.dispatch_time = float(i) + ttft / 2
    r.finish_time = float(i) + ttft
    r.used_graph = (i % 3 == 0)
    return r


def test_slotracker_bounded_memory():
    """10k records through a max_finished=64 tracker hold at most 128
    Request objects, yet every streaming aggregate is exact."""
    tr_ = SLOTracker(0.4, max_finished=64)
    ttfts = [0.05 + 0.001 * (i % 500) for i in range(10_000)]
    for i, t in enumerate(ttfts):
        tr_.record(_fake_request(i, t))
    assert len(tr_.finished) <= 2 * tr_.max_finished
    rep = tr_.report()
    assert rep.n == 10_000
    assert rep.mean_ttft == pytest.approx(sum(ttfts) / len(ttfts))
    viol = sum(1 for t in ttfts if t > 0.4)
    assert rep.violation_rate == pytest.approx(viol / 10_000)
    assert rep.graph_hit_rate == pytest.approx(
        sum(1 for i in range(10_000) if i % 3 == 0) / 10_000)


def test_slotracker_exact_on_short_runs():
    """Runs shorter than max_finished keep every request: report() is
    bit-identical to the keep-it-all behaviour, percentiles included."""
    tr_ = SLOTracker(0.4)
    ttfts = [0.01 * (i + 1) for i in range(50)]
    for i, t in enumerate(ttfts):
        tr_.record(_fake_request(i, t))
    rep = tr_.report()
    assert len(tr_.finished) == 50
    assert rep.p50_ttft == pytest.approx(percentile(ttfts, 0.50))
    assert rep.p99_ttft == pytest.approx(percentile(ttfts, 0.99))
    assert rep.mean_ttft == pytest.approx(sum(ttfts) / 50)


def test_slotracker_merged_matches_single():
    """Cluster report = merged per-engine trackers: aggregates must equal
    one tracker fed the union."""
    a, b, one = SLOTracker(0.4), SLOTracker(0.4), SLOTracker(0.4)
    for i in range(40):
        r = _fake_request(i, 0.1 + 0.01 * i)
        (a if i % 2 else b).record(r)
        one.record(r)
    m = SLOTracker.merged([a, b]).report()
    s = one.report()
    assert (m.n, m.violation_rate) == (s.n, s.violation_rate)
    assert m.mean_ttft == pytest.approx(s.mean_ttft)
    assert m.mean_queue_wait == pytest.approx(s.mean_queue_wait)


# ----------------------------------------------------- nearest-rank percentile
def test_percentile_nearest_rank():
    vals = list(range(1, 11))            # 1..10
    assert percentile(vals, 0.50) == 5   # ceil(5)=5th smallest; old code: 6
    assert percentile(vals, 0.90) == 9   # old code returned the max (10)
    assert percentile(vals, 1.00) == 10
    assert percentile(vals, 0.01) == 1
    assert percentile([], 0.99) == 0.0
    assert percentile([7.0], 0.99) == 7.0
    # p99 over 200 samples: rank ceil(198)=198 → value 198, not the max
    assert percentile(list(range(1, 201)), 0.99) == 198


def test_percentile_unsorted_input():
    assert percentile([5.0, 1.0, 9.0, 3.0, 7.0], 0.5) == 5.0


# ------------------------------------------- multi-token TPOT accounting
def test_tpot_credits_one_interval_per_committed_token(smoke):
    """PR 8 regression: a speculative tick commits m tokens in ONE
    dispatch.  The pre-fix accounting appended a single tpot sample
    equal to the whole inter-dispatch gap — inflating reported TPOT by
    ~m x and poisoning the SLO percentiles.  A tick committing m tokens
    must credit m intervals of gap/m each."""
    cfg, params = smoke
    loop = _loop(cfg, params)
    loop._start_decoding(0, 5, budget=6, now=0.0)
    loop._record_decoded(0, [1, 2, 3], 3.0)     # 3 tokens over 3 s
    assert loop.tpot_samples == [1.0, 1.0, 1.0]
    loop._record_decoded(0, [4], 4.0)           # plain single-token tick
    loop._record_decoded(0, [7, 8], 6.0)        # 2 tokens over 2 s
    assert loop.tpot_samples == [1.0] * 6
    assert loop.generated[0] == [5, 1, 2, 3, 4, 7, 8]
    assert 0 not in loop.active_decodes         # budget of 6 drained


def test_loop_spec_stream_lossless_and_tpot_count(smoke):
    """End to end through the serve loop: arming speculation changes
    neither the generated streams (greedy acceptance is exact-match)
    nor the NUMBER of tpot samples — one interval per decoded token,
    however many tokens each verify dispatch commits.  The tracker's
    merged spec counters mirror the engine's."""
    from repro.serving.draft import NGramDraft

    cfg, params = smoke
    rng = np.random.default_rng(5)
    prompts = {0: rng.integers(1, cfg.vocab_size, 9),
               1: rng.integers(1, cfg.vocab_size, 12)}
    budget = 8

    def run(spec):
        loop = _loop(cfg, params)
        if spec:
            loop.engine.enable_spec(NGramDraft(n=3), k=4)
        for s, p in prompts.items():
            loop.submit(s, p, decode_tokens=budget)
        loop.run_until_idle(max_wall=240.0)
        return {s: list(loop.generated[s]) for s in prompts}, loop

    base, _ = run(False)
    spec, loop = run(True)
    assert spec == base
    assert len(loop.tpot_samples) == 2 * budget
    rep = loop.tracker.report()
    assert rep.spec_dispatches == loop.engine.spec_dispatches > 0
    assert rep.tokens_drafted == loop.engine.tokens_drafted
    assert rep.tokens_accepted == loop.engine.tokens_accepted
