"""Property test for the paged-arena share/fork/evict/write state
machine (DESIGN.md §8) — the §6 no-alias invariant at page granularity.

Drives a bookkeeping-only ``PagedKVArena`` (cfg=None) through random
interleavings of submit (with radix prefix adoption), decode-style
extends, COW forks, session frees, and allocation pressure (a tiny pool
forces LRU eviction of index-only pages), asserting after every step:

  * ``audit()`` — refcounts equal the counted holders, the free list is
    duplicate-free and exactly the rc==0 pages, and the reserved scratch
    page appears in no table and no index;
  * write-range exclusivity — every page returned by ``prepare_extend``
    that overlaps the write range [h, h+n) has refcount == 1, so no
    session's write can land in a page another session (or the radix
    index) still references;
  * shared-content agreement — any page shared between two sessions sits
    at the SAME logical position in both and their committed token ids
    agree over it (prefix sharing and COW forks never alias divergent
    content).

The machine runs under hypothesis (shrinking, CI) AND as a seeded
random replay (no extra deps, always on).
"""
import random

import pytest

from repro.serving.kvcache import PagedKVArena

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

NUM_PAGES = 10
PS = 4
MAX_LEN = 24          # 6 pages/session, usable history = MAX_LEN - 2


def check_shared_content(arena):
    sess = list(arena._pages)
    for ai in range(len(sess)):
        for bi in range(ai + 1, len(sess)):
            a, b = sess[ai], sess[bi]
            pa, pb = arena._pages[a], arena._pages[b]
            for p in set(pa) & set(pb):
                i, j = pa.index(p), pb.index(p)
                assert i == j, \
                    f"page {p} at logical {i} in {a} but {j} in {b}"
                lo = i * PS
                hi = min(arena.lengths[a], arena.lengths[b], lo + PS)
                assert arena._tokens[a][lo:hi] == arena._tokens[b][lo:hi]


def write(arena, session, toks):
    """prepare_extend + commit, asserting write-range exclusivity in
    between (the instant the kernel would scatter-write)."""
    h = arena.length(session)
    n = len(toks)
    ps = arena.page_size
    try:
        pages = arena.prepare_extend(session, n)
    except RuntimeError:
        return False        # pool exhausted / arena overflow: no write
    for p in pages[h // ps:(h + n - 1) // ps + 1]:
        assert arena._refcount[p] == 1, \
            f"write range of {session} overlaps shared page {p}"
    arena.commit(session, toks)
    return True


def drive(arena, draw_int, draw_choice, steps):
    """One machine run; draw_int(lo, hi) and draw_choice(seq) abstract
    over hypothesis draws vs random.Random."""
    next_sid = [0]

    def fresh():
        next_sid[0] += 1
        return next_sid[0]

    for _ in range(steps):
        live = sorted(arena._pages)
        ops = ["submit"] + (["extend", "fork", "free"] if live else [])
        op = draw_choice(ops)
        if op == "submit":
            # resubmitting a live conversation + suffix exercises the
            # radix hit path; fresh tokens exercise cold misses
            toks = (list(arena._tokens[draw_choice(live)])
                    if live and draw_int(0, 1) else [])
            toks += [draw_int(0, 3)            # tiny vocab → collisions
                     for _ in range(draw_int(1, 10))]
            toks = toks[:MAX_LEN - 2]
            s = fresh()
            matched = arena.match_prefix(s, toks)
            assert matched % PS == 0 and matched < len(toks)
            assert arena.length(s) == matched
            if not write(arena, s, toks[matched:]):
                arena.free(s)
        elif op == "extend":
            s = draw_choice(live)
            write(arena, s,
                  [draw_int(0, 3) for _ in range(draw_int(1, 3))])
        elif op == "fork":
            parent, child = draw_choice(live), fresh()
            arena.fork(parent, child)
            assert arena.pages_of(child) == arena.pages_of(parent)
            assert arena.length(child) == arena.length(parent)
        else:
            arena.free(draw_choice(live))
        arena.audit()
        check_shared_content(arena)
        assert arena.gather_calls == 0 and arena.scatter_calls == 0

    # drain: freeing every session must leave only index-held pages, and
    # evicting under full pressure must return the pool to empty
    for s in list(arena._pages):
        arena.free(s)
    arena.audit()
    arena._evict(NUM_PAGES)
    arena.audit()
    assert arena.free_pages == NUM_PAGES
    assert all(r == 0 for r in arena._refcount)


@pytest.mark.parametrize("seed", range(20))
def test_page_state_machine_seeded(seed):
    rng = random.Random(seed)
    drive(PagedKVArena(None, NUM_PAGES, PS, MAX_LEN),
          rng.randint, rng.choice, steps=40)


if HAVE_HYPOTHESIS:
    @settings(max_examples=60, deadline=None)
    @given(st.data())
    def test_page_state_machine_hypothesis(data):
        drive(PagedKVArena(None, NUM_PAGES, PS, MAX_LEN),
              lambda lo, hi: data.draw(st.integers(lo, hi)),
              lambda seq: data.draw(st.sampled_from(list(seq))),
              steps=data.draw(st.integers(5, 30), label="steps"))
else:
    @pytest.mark.skip(reason="hypothesis not installed")
    def test_page_state_machine_hypothesis():
        pass


def test_eviction_under_pressure_prefers_index_leaves():
    """A tiny pool oversubscribed by the radix index: allocation evicts
    LRU index-only leaves but never pages pinned by live sessions."""
    arena = PagedKVArena(None, num_pages=4, page_size=2, max_len=12)
    arena.open(1)
    assert write(arena, 1, [7, 7, 7, 7])      # 2 full pages → indexed
    pinned = list(arena.pages_of(1))
    arena.free(2)                              # no-op on unknown session
    arena.open(2)
    assert write(arena, 2, [5, 5, 5])          # 2 more pages: pool full
    arena.free(2)                              # page 1 partial → freed;
    arena.audit()                              # full page stays indexed
    arena.open(3)
    assert write(arena, 3, [6, 6, 6, 6])       # must evict index leaves
    assert arena.pages_evicted >= 1
    assert all(arena._refcount[p] >= 1 for p in pinned), \
        "eviction touched a session-pinned page"
    assert arena._tokens[1] == [7, 7, 7, 7]
    arena.audit()


def test_match_prefix_leaves_a_suffix():
    """Even an exact resubmission keeps ≥ 1 token to prefill (the next
    step needs a query row), and partial pages never match."""
    arena = PagedKVArena(None, NUM_PAGES, PS, MAX_LEN)
    arena.open(1)
    toks = [1, 2, 3, 4, 5, 6, 7, 8]
    assert write(arena, 1, toks)
    assert arena.probe_prefix(toks) == PS      # last full page excluded
    m = arena.match_prefix(2, toks)
    assert m == PS and arena.length(2) == PS
    assert arena.probe_prefix(toks[:PS - 1]) == 0
    arena.audit()
