"""Fault tolerance (DESIGN.md §11): deterministic chaos injection,
engine failover with re-prefill session recovery, SLO-aware admission
control, and the never-lose-a-request accounting invariants.

The hypothesis chaos machine drives a 3-engine paged cluster through
seed-random fault plans over seed-random request mixes and checks the
§11 acceptance criteria every time: arenas stay audit-green, every
submit is finished/rejected/abandoned (never silently lost), and greedy
transcripts are bit-identical to a fault-free replay.
"""
import jax
import numpy as np
import pytest

from repro.configs import get_smoke
from repro.core import H200_QWEN32B, Variant, make_policy
from repro.core.faults import (CRASH, DISPATCH, HANDOFF, STALL,
                               FaultEvent, FaultInjector, FaultPlan)
from repro.core.routing import LengthAwareRouter, RoundRobinRouter
from repro.core.scheduler import PoolPolicy
from repro.models import transformer as tr
from repro.serving import Engine, EngineConfig, ServeCluster
from repro.serving.loop import ServeLoop
from repro.sim import ClusterSim, SimConfig
from repro.sim.costmodel import H200_32B
from repro.sim.workload import WorkloadConfig, lmsys_like_requests

KEY = jax.random.key(31)


# ---------------------------------------------------------- plan/injector
def test_fault_plan_random_deterministic():
    a = FaultPlan.random(7, n_engines=3)
    b = FaultPlan.random(7, n_engines=3)
    assert a == b and a.seed == 7
    # a 1-engine cluster never gets a crash scripted (no survivor)
    solo = FaultPlan.random(7, n_engines=1)
    assert all(ev.kind != CRASH for ev in solo.events)


def test_injector_replay_identical():
    plan = FaultPlan.random(11, n_engines=4)
    answers = []
    for _ in range(2):
        inj = FaultInjector(plan)
        seq = [inj.crashes_due(t) for t in range(8)]
        seq += [inj.handoff_fails(e, 5.0) for e in range(4)]
        seq += [inj.dispatch_fails(e, 5.0) for e in range(4)]
        seq += [inj.submit_stall(i) for i in range(8)]
        answers.append((seq, dict(inj.injected)))
    assert answers[0] == answers[1]


def test_injector_consumes_counts_and_gates_on_at():
    plan = FaultPlan(events=(FaultEvent(HANDOFF, at=5.0, engine=-1,
                                        count=2),))
    inj = FaultInjector(plan)
    assert not inj.handoff_fails(0, 3.0)       # not matured yet
    assert inj.handoff_fails(0, 5.0)
    assert inj.handoff_fails(1, 9.0)           # wildcard engine
    assert not inj.handoff_fails(1, 9.0)       # count exhausted
    assert inj.injected[HANDOFF] == 2


def test_injector_engine_specific_dispatch():
    plan = FaultPlan(events=(FaultEvent(DISPATCH, at=0.0, engine=2,
                                        count=1),))
    inj = FaultInjector(plan)
    assert not inj.dispatch_fails(0, 1.0)      # wrong engine
    assert inj.dispatch_fails(2, 1.0)
    assert not inj.dispatch_fails(2, 1.0)


def test_crashes_fire_once():
    plan = FaultPlan(events=(FaultEvent(CRASH, at=3.0, engine=1),))
    inj = FaultInjector(plan)
    assert inj.crashes_due(2.0) == []
    assert inj.crashes_due(3.0) == [1]
    assert inj.crashes_due(4.0) == []          # already fired


def test_submit_stall_matches_ordinal():
    plan = FaultPlan(events=(FaultEvent(STALL, at=2.0, duration=3.0),))
    inj = FaultInjector(plan)
    assert inj.submit_stall(0) is None
    assert inj.submit_stall(2) == 3.0
    assert inj.submit_stall(2) is None         # consumed


# ------------------------------------------------------------- sim mirror
def _sim(n_inst, cfg_kw, n_req=300, rate=40.0, seed=29):
    wl = WorkloadConfig(slo_ttft=0.4)
    reqs = lmsys_like_requests(n_req, rate, wl, seed=seed)

    def factory(i):
        return make_policy(Variant("pla_full"), H200_QWEN32B,
                           threshold=256.0)
    sim = ClusterSim(n_inst, factory, H200_32B,
                     SimConfig(router="least_loaded", mode="mix",
                               **cfg_kw))
    sim.add_requests(reqs)
    return sim, reqs[-1].arrival


def test_sim_crash_recovery_never_loses_requests():
    """A mid-trace instance crash: every request still finishes exactly
    once (the in-flight ChunkWork used to be re-pushed TWICE — once from
    inst.current, once from the queue drain — and recorded twice), and
    in-flight decode sessions come back via priced re-prefill."""
    sim, horizon = _sim(3, {"decode_handoff": True})
    plan = FaultPlan(events=(FaultEvent(CRASH, at=2.0, engine=1),))
    sim.apply_faults(plan)
    tracker = sim.run(horizon + 300)
    rids = [r.rid for r in tracker.finished]
    assert len(rids) == 300 and len(set(rids)) == 300
    assert sim.recovered_sessions > 0
    assert tracker.report().recovered_sessions == sim.recovered_sessions


def test_sim_recovery_off_drops_sessions_quietly():
    sim, horizon = _sim(3, {"decode_handoff": True, "recovery": False})
    sim.inject_failure(2.0, 1)
    tracker = sim.run(horizon + 300)
    assert sim.recovered_sessions == 0
    assert tracker.report().recovered_sessions == 0


def test_sim_transient_handoff_retries():
    """Handoffs fire on the spatial split; the scripted transient
    failures retry with backoff (or keep the session home) and no
    request is lost to the flapping."""
    wl = WorkloadConfig(slo_ttft=0.4)
    reqs = lmsys_like_requests(300, 40.0, wl, seed=29)

    def factory(i):
        return PoolPolicy(H200_QWEN32B, pool="long" if i == 0 else "short",
                          threshold=256.0)
    sim = ClusterSim(3, factory, H200_32B,
                     SimConfig(mode="mix", decode_handoff=True),
                     router_obj=LengthAwareRouter(threshold=256.0),
                     roles=["prefill", "decode", "decode"])
    plan = FaultPlan(events=(FaultEvent(HANDOFF, at=0.0, engine=-1,
                                        count=5),))
    sim.apply_faults(plan)
    sim.add_requests(reqs)
    tracker = sim.run(reqs[-1].arrival + 300)
    assert sim.handoffs > 5                    # the split actually fired
    assert sim.handoff_retries == 5
    assert len(tracker.finished) == 300        # nothing lost to retries
    assert tracker.report().retried >= 5


def test_sim_admission_beats_accept_everything():
    """Overload: the §11 admission gate sheds doomed submits and the
    violation rate over ADMITTED requests drops strictly below the
    accept-everything arm's."""
    viol, rejected = {}, {}
    for adm in (False, True):
        sim, horizon = _sim(2, {"admission": adm}, n_req=400, rate=150.0,
                            seed=23)
        tracker = sim.run(horizon + 300)
        rep = tracker.report()
        viol[adm], rejected[adm] = rep.violation_rate, rep.rejected
    assert rejected[True] > 0 and rejected[False] == 0
    assert viol[True] < viol[False], (viol, rejected)


# ------------------------------------------------------ real-engine seams
@pytest.fixture(scope="module")
def smoke():
    cfg = get_smoke("qwen3-4b")
    params, _ = tr.init_params(cfg, KEY)
    return cfg, params


def _ecfg(paged=False):
    return EngineConfig(num_slots=4, max_len=96, chunk_tokens=16,
                        paged_kv=paged, page_size=8)


def _loop(cfg, params, paged=False, **loop_kw):
    eng = Engine(cfg, params, _ecfg(paged))
    pol = make_policy(Variant("pla_full"), H200_QWEN32B, threshold=24,
                      chunk_tokens=16)
    return ServeLoop(eng, pol, slo_ttft=30.0, **loop_kw)


def _cluster(cfg, params, n=2, paged=False, **kw):
    loops = [_loop(cfg, params, paged) for _ in range(n)]
    return ServeCluster(loops, RoundRobinRouter(), **kw)


def test_admission_rejects_doomed_submit(smoke):
    """A submit whose predicted TTFT already violates its deadline is
    shed BEFORE any side effect: nothing queued, no session opened."""
    cfg, params = smoke
    loop = _loop(cfg, params, admission=H200_32B)
    rng = np.random.default_rng(0)
    r = loop.submit(0, rng.integers(0, cfg.vocab_size, 8), deadline=0.0)
    assert r.rejected
    assert loop.policy.queue_len() == 0 and loop._outstanding == 0
    assert loop.engine.history(0) == 0
    assert loop.tracker.report().rejected == 1
    # a feasible deadline sails through and serves normally
    r2 = loop.submit(0, rng.integers(0, cfg.vocab_size, 8),
                     decode_tokens=2)
    assert not r2.rejected
    loop.run_until_idle(max_wall=60.0)
    assert len(loop.generated[0]) == 3


def test_bounded_queue_rejects_overflow(smoke):
    cfg, params = smoke
    loop = _loop(cfg, params, max_queue=1)
    rng = np.random.default_rng(1)
    r1 = loop.submit(0, rng.integers(0, cfg.vocab_size, 6))
    r2 = loop.submit(1, rng.integers(0, cfg.vocab_size, 6))
    assert not r1.rejected and r2.rejected
    assert loop.tracker.rejected == 1
    loop.run_until_idle(max_wall=60.0)
    assert loop.engine.history(1) == 0         # never touched the engine


def test_run_until_idle_abandons_on_wall_expiry(smoke):
    """max_wall expiry used to silently strand queued prefills — now they
    are drained, counted, and charged as SLO violations."""
    cfg, params = smoke
    loop = _loop(cfg, params)
    rng = np.random.default_rng(2)
    loop.submit(0, rng.integers(0, cfg.vocab_size, 6))
    loop.submit(1, rng.integers(0, cfg.vocab_size, 6))
    loop.run_until_idle(max_wall=0.0)
    rep = loop.tracker.report()
    assert rep.abandoned == 2 and rep.n == 0
    assert rep.violation_rate == 1.0           # deadlines died with them
    assert loop._outstanding == 0 and not loop.has_work


def test_migration_cost_benefit_gate(smoke):
    """The greedy always-migrate trigger is replaced by a handoff_time
    cost/benefit gate: tiny decode budgets stay home, big ones move, and
    migrate_decodes=True restores the old unconditional behaviour."""
    cfg, params = smoke

    def spatial(**kw):
        loops = [ServeLoop(Engine(cfg, params, _ecfg()),
                           PoolPolicy(H200_QWEN32B, pool=pool,
                                      threshold=24, chunk_tokens=16),
                           slo_ttft=30.0)
                 for pool in ("long", "short")]
        return ServeCluster(loops, LengthAwareRouter(threshold=24),
                            roles=["prefill", "decode"], **kw)

    rng = np.random.default_rng(3)
    prompt = rng.integers(0, cfg.vocab_size, 40)
    for kw, budget, migrated in (({}, 2, 0),            # below breakeven
                                 ({}, 8, 1),            # worth the copy
                                 ({"migrate_decodes": True}, 2, 1),
                                 ({"migrate_decodes": False}, 8, 0)):
        cluster = spatial(**kw)
        cluster.submit(0, prompt, decode_tokens=budget)
        cluster.run_until_idle(max_wall=120.0)
        assert cluster.migrated_sessions == migrated, (kw, budget)
        assert len(cluster.generated(0)) == budget + 1


def test_close_session_purges_deflectable(smoke):
    """close_session on a deflection candidate must drop its _deflectable
    entry immediately — the stale rid used to linger until a later sweep
    tripped over it."""
    cfg, params = smoke
    loops = [ServeLoop(Engine(cfg, params, _ecfg()),
                       PoolPolicy(H200_QWEN32B, pool=pool,
                                  threshold=24, chunk_tokens=16),
                       slo_ttft=30.0)
             for pool in ("long", "short")]
    cluster = ServeCluster(loops,
                           LengthAwareRouter(threshold=24, spill_tokens=0),
                           roles=["prefill", "decode"],
                           deflect_backlog_tokens=8)
    rng = np.random.default_rng(4)
    cluster.submit(1, rng.integers(0, cfg.vocab_size, 6))    # decode eng
    spilled = cluster.submit(2, rng.integers(0, cfg.vocab_size, 5))
    assert spilled.rid in cluster._deflectable
    cluster.close_session(2)
    assert spilled.rid not in cluster._deflectable
    cluster._maybe_deflect()                   # no KeyError on stale rid
    cluster.run_until_idle(max_wall=60.0)


def test_dispatch_fault_retries_work(smoke):
    cfg, params = smoke
    loop = _loop(cfg, params)
    loop.faults = FaultInjector(FaultPlan(events=(
        FaultEvent(DISPATCH, at=0.0, engine=0, count=2),)))
    rng = np.random.default_rng(5)
    loop.submit(0, rng.integers(0, cfg.vocab_size, 6), decode_tokens=2)
    loop.submit(1, rng.integers(0, cfg.vocab_size, 6), decode_tokens=2)
    loop.run_until_idle(max_wall=60.0)
    assert loop.dispatch_faults == 2
    assert loop.tracker.retried >= 2
    for s in (0, 1):
        assert len(loop.generated[s]) == 3     # both completed anyway


def test_transient_handoff_backoff_and_giveup(smoke):
    """Every handoff attempt from engine 0 fails: the cluster backs off,
    gives up after max_handoff_attempts, and the session finishes its
    decode AT HOME — flapping never loses tokens."""
    cfg, params = smoke
    loops = [ServeLoop(Engine(cfg, params, _ecfg()),
                       PoolPolicy(H200_QWEN32B, pool=pool,
                                  threshold=24, chunk_tokens=16),
                       slo_ttft=30.0)
             for pool in ("long", "short")]
    inj = FaultInjector(FaultPlan(events=(
        FaultEvent(HANDOFF, at=0.0, engine=0, count=99),)))
    cluster = ServeCluster(loops, LengthAwareRouter(threshold=24),
                           roles=["prefill", "decode"],
                           migrate_decodes=True, faults=inj,
                           max_handoff_attempts=3)
    rng = np.random.default_rng(6)
    # budget long enough that the decode outlives the backoff windows
    # (attempts at t, t+2, t+6) — the third attempt must mature
    cluster.submit(0, rng.integers(0, cfg.vocab_size, 40),
                   decode_tokens=20)
    cluster.run_until_idle(max_wall=120.0)
    st = cluster.stats()
    assert st["handoff_retries"] == 3 and st["handoff_giveups"] == 1
    assert st["migrated_sessions"] == 0
    assert cluster.engine_of(0) == 0           # stayed home
    assert len(cluster.generated(0)) == 21


def test_submit_stall_released_and_served(smoke):
    cfg, params = smoke
    inj = FaultInjector(FaultPlan(events=(
        FaultEvent(STALL, at=0.0, duration=2.0),)))
    cluster = _cluster(cfg, params, n=2, faults=inj)
    rng = np.random.default_rng(7)
    r = cluster.submit(0, rng.integers(0, cfg.vocab_size, 8),
                       decode_tokens=2)
    assert not r.rejected and len(cluster._stalled) == 1
    assert cluster.engine_of(0) is None        # not routed while held
    cluster.run_until_idle(max_wall=120.0)
    st = cluster.stats()
    assert st["stalled_requests"] == 1 and st["retried"] >= 1
    assert len(cluster.generated(0)) == 3


def test_dead_engine_refuses_dispatch(smoke):
    cfg, params = smoke
    eng = Engine(cfg, params, _ecfg())
    eng.mark_dead()
    with pytest.raises(RuntimeError, match="dead"):
        eng.export_session(0)


@pytest.mark.parametrize("paged", [False, True], ids=["slot", "paged"])
def test_kill_engine_recovers_bit_identical(smoke, paged):
    """Kill an engine while its sessions are mid-decode: queued requests
    re-route, in-flight sessions re-prefill-reconstruct on the survivor,
    and every greedy transcript matches the fault-free run bit for bit."""
    cfg, params = smoke
    rng = np.random.default_rng(8)
    subs = [(s, rng.integers(0, cfg.vocab_size,
                             36 if s % 2 == 0 else 7), 6)
            for s in range(4)]

    baseline = _cluster(cfg, params, n=2, paged=paged)
    for s, toks, d in subs:
        baseline.submit(s, toks, decode_tokens=d)
    baseline.run_until_idle(max_wall=120.0)
    want = {s: list(baseline.generated(s)) for s, _, _ in subs}

    cluster = _cluster(cfg, params, n=2, paged=paged)
    for s, toks, d in subs:
        cluster.submit(s, toks, decode_tokens=d)
    # drive until engine 0 is mid-decode, then pull the plug
    for _ in range(400):
        if cluster.loops[0].active_decodes:
            break
        cluster._tick += 1
        for lp in cluster.loops:
            if lp.has_work:
                lp.tick()
    assert cluster.loops[0].active_decodes, "never reached decode phase"
    cluster.kill_engine(0)
    cluster.run_until_idle(max_wall=120.0)

    st = cluster.stats()
    assert st["crashes"] == 1
    assert st["recovered_sessions"] >= 1
    assert st["health"] == ["dead", "healthy"]
    rep = cluster.report()
    assert rep.n == len(subs)                  # nothing lost, no dups
    assert rep.recovered_sessions == st["recovered_sessions"]
    for s, _, d in subs:
        assert cluster.generated(s) == want[s], s
        assert cluster.engine_of(s) == 1
    if paged:
        cluster.loops[1].engine.arena.audit()


# --------------------------------------------------------- chaos machine
def _chaos_case(cfg, params, seed):
    """One chaos example: a random request mix on a 3-engine paged
    cluster under a seed-random fault plan vs a fault-free replay."""
    rng = np.random.default_rng(seed)
    n_sessions = int(rng.integers(3, 6))
    subs = [(s, rng.integers(0, cfg.vocab_size, int(rng.integers(4, 40))),
             int(rng.integers(1, 7)))
            for s in range(n_sessions)]

    def run(faults):
        cluster = _cluster(cfg, params, n=3, paged=True, faults=faults)
        for s, toks, d in subs:
            cluster.submit(s, toks, decode_tokens=d)
        cluster.run_until_idle(max_wall=120.0)
        return cluster

    base = run(None)
    want = {s: list(base.generated(s)) for s, _, _ in subs}
    plan = FaultPlan.random(seed, n_engines=3, horizon=12.0)
    chaos = run(FaultInjector(plan))

    rep = chaos.report()
    # never lost: every turn completed, was rejected, or was abandoned
    assert rep.n + rep.rejected + rep.abandoned == n_sessions, \
        (plan, rep.n, rep.rejected, rep.abandoned)
    assert rep.abandoned == 0 and rep.rejected == 0   # wall was generous
    # greedy transcripts are bit-identical to the fault-free replay
    for s, _, d in subs:
        assert chaos.generated(s) == want[s], (s, plan)
        assert len(chaos.generated(s)) == d + 1
    # arenas of surviving engines stay audit-green
    for i in chaos.alive_engines():
        chaos.loops[i].engine.arena.audit()
    if any(ev.kind == CRASH for ev in plan.events):
        assert chaos.stats()["crashes"] >= 1


try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

if HAVE_HYPOTHESIS:
    @settings(max_examples=5, deadline=None)
    @given(seed=st.integers(min_value=0, max_value=2**31 - 1))
    def test_chaos_property(smoke, seed):
        cfg, params = smoke
        _chaos_case(cfg, params, seed)
else:
    @pytest.mark.parametrize("seed", [3, 1009, 77777])
    def test_chaos_property(smoke, seed):
        """Seeded fallback when hypothesis is absent (conftest raises in
        CI if so — the property suite must not silently skip there)."""
        cfg, params = smoke
        _chaos_case(cfg, params, seed)
