"""Real-engine integration: staged serving == pure forward, arena slot
management, executor capture stats, runtime boundary fitting."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke
from repro.models import transformer as tr
from repro.serving import Engine, EngineConfig

KEY = jax.random.key(3)


@pytest.fixture(scope="module")
def qwen():
    cfg = get_smoke("qwen3-4b")
    params, _ = tr.init_params(cfg, KEY)
    return cfg, params


@pytest.mark.parametrize("arch", ["qwen3-4b", "mamba2-2.7b",
                                  "jamba-v0.1-52b"])
def test_engine_matches_pure_forward(arch):
    rng = np.random.default_rng(0)
    cfg = get_smoke(arch)
    params, _ = tr.init_params(cfg, KEY)
    eng = Engine(cfg, params, EngineConfig(num_slots=4, max_len=64))
    t1 = rng.integers(0, cfg.vocab_size, 10)
    out = eng.prefill_batch([0, 1], [t1, rng.integers(0, cfg.vocab_size, 5)],
                            bucket=(16, 2))
    tok0 = out[0]
    dec = eng.decode_batch([0], [tok0], steps=3)
    t2 = rng.integers(0, cfg.vocab_size, 7)
    out2 = eng.prefill_batch([0], [t2])

    def greedy(seq):
        lo, _, _ = tr.forward(params, cfg,
                              tokens=jnp.asarray(seq, jnp.int32)[None])
        return int(jnp.argmax(lo[0, -1]))

    ctx = list(t1)
    assert greedy(ctx) == tok0
    ctx.append(tok0)
    for i in range(3):
        nxt = greedy(ctx)
        assert nxt == dec[0][i]
        ctx.append(nxt)
    ctx = ctx[:-1] + list(t2)
    assert greedy(ctx) == out2[0]


def test_arena_slots(qwen):
    cfg, params = qwen
    # slot-occupancy semantics are the slot-arena baseline's (§12)
    eng = Engine(cfg, params, EngineConfig(num_slots=2, max_len=32,
                                           paged_kv=False))
    eng.open_session(0)
    eng.open_session(1)
    assert eng.arena.free_slots == 0
    with pytest.raises(RuntimeError):
        eng.open_session(2)
    eng.close_session(0)
    eng.open_session(2)                   # slot recycled
    assert eng.arena.free_slots == 0


def test_session_overflow_guard(qwen):
    cfg, params = qwen
    eng = Engine(cfg, params, EngineConfig(num_slots=2, max_len=16))
    rng = np.random.default_rng(1)
    eng.prefill_batch([0], [rng.integers(0, cfg.vocab_size, 10)])
    with pytest.raises(RuntimeError):
        eng.prefill_batch([0], [rng.integers(0, cfg.vocab_size, 10)])


def test_executor_capture_and_reuse(qwen):
    cfg, params = qwen
    # dense (L, B) grid capture path — a slot/dense-baseline concern (§12)
    eng = Engine(cfg, params, EngineConfig(num_slots=4, max_len=64,
                                           paged_kv=False))
    rng = np.random.default_rng(2)
    for s in range(3):
        eng.prefill_batch([s], [rng.integers(0, cfg.vocab_size, 6)],
                          bucket=(8, 1))
    st = eng.stats()
    assert st["captured_shapes"] == 1      # one (8,1) shape compiled once
    assert eng.executor.hits == 2
    assert st["capture_seconds"] > 0


def test_decode_bucket_compile_cache(qwen):
    """Decode-only serving of N sessions compiles at most |decode_ladder|
    executables on the arena-resident path — vs one per live session
    count on the dense-gather baseline (the §3.1 shape blowup in its
    decode form)."""
    cfg, params = qwen
    eng = Engine(cfg, params, EngineConfig(num_slots=8, max_len=64,
                                           decode_buckets=(1, 2, 4, 8)))
    base = Engine(cfg, params, EngineConfig(num_slots=8, max_len=64,
                                            arena_decode=False,
                                            paged_kv=False))
    rng = np.random.default_rng(7)
    n = 5
    prompts = [rng.integers(0, cfg.vocab_size, 4) for _ in range(n)]
    f1 = eng.prefill_batch(list(range(n)), prompts)
    f2 = base.prefill_batch(list(range(n)), prompts)
    last1, last2 = dict(f1), dict(f2)
    active = list(range(n))
    while active:                      # drain through every session count
        d1 = eng.decode_batch(active, [last1[s] for s in active])
        d2 = base.decode_batch(active, [last2[s] for s in active])
        assert d1 == d2                # tokens agree at every count
        for s in active:
            last1[s], last2[s] = d1[s][0], d2[s][0]
        active.pop()
    dx = eng.decode_executor
    assert len(dx.compile_times) <= len(dx.decode_buckets)
    assert len(dx.compile_times) < n   # counts 5..1 collapse onto rungs
    assert eng.executor.shapes_by_kind().get("decode", 0) == 0
    # the dense baseline compiled one decode shape per session count
    assert base.executor.shapes_by_kind()["decode"] == n


def test_runtime_boundary_fit(qwen):
    cfg, params = qwen
    eng = Engine(cfg, params, EngineConfig(num_slots=8, max_len=128))
    rng = np.random.default_rng(3)
    for s in range(8):
        n = int(rng.integers(4, 60))
        eng.prefill_batch([s], [rng.integers(0, cfg.vocab_size, n)])
    fit = eng.fit_boundary()
    assert fit is not None
    assert 16.0 <= fit.boundary() <= 2048.0
    assert eng.classification_threshold() == fit.boundary()
