"""End-to-end system behaviour: the full LAPS stack (queues → AWD →
bucketized executor → KV arena → decode) serving real multi-turn traffic
on a reduced model, plus serving-state rebuild after failure."""
import jax
import numpy as np
import pytest

from repro.configs import get_smoke
from repro.core import H200_QWEN32B, Variant, make_policy
from repro.models import transformer as tr
from repro.serving import Engine, EngineConfig
from repro.serving.loop import ServeLoop

KEY = jax.random.key(9)


@pytest.fixture(scope="module")
def served():
    cfg = get_smoke("qwen3-4b")
    params, _ = tr.init_params(cfg, KEY)
    engine = Engine(cfg, params,
                    EngineConfig(num_slots=8, max_len=160, chunk_tokens=16))
    policy = make_policy(Variant("pla_full"), H200_QWEN32B, threshold=24,
                         chunk_tokens=16)
    loop = ServeLoop(engine, policy, slo_ttft=30.0)
    rng = np.random.default_rng(0)
    # two turns of mixed traffic over 4 sessions: includes one long
    for turn in range(2):
        for s in range(4):
            n = 40 if (s == 3 and turn == 0) else int(rng.integers(4, 16))
            loop.submit(s, rng.integers(0, cfg.vocab_size, n))
        loop.run_until_idle(max_wall=180.0)
    return cfg, params, engine, policy, loop


def test_all_requests_complete(served):
    *_, loop = served
    assert loop._outstanding == 0
    assert loop.tracker.report().n == 8


def test_long_request_went_to_long_queue(served):
    cfg, params, engine, policy, loop = served
    # the 40-token request exceeded threshold 24 → chunked long path
    longs = [r for r in loop.tracker.finished if r.new_tokens >= 24]
    assert longs and all(not r.used_graph for r in longs)


def test_short_requests_bucketized(served):
    *_, loop = served
    shorts = [r for r in loop.tracker.finished if r.new_tokens < 24]
    assert any(r.used_graph for r in shorts)


def test_decode_after_serving(served):
    cfg, params, engine, policy, loop = served
    toks = loop.decode(0, 3)
    assert len(toks) == 4
    assert all(0 <= t < cfg.vocab_size for t in toks)


def test_engine_measured_and_fit(served):
    cfg, params, engine, *_ = served
    assert engine.fit_boundary() is not None


def test_serving_state_rebuild_after_failure(served):
    """Fault tolerance: a replacement engine rebuilt by re-prefilling the
    session transcript produces identical decode continuations."""
    cfg, params, *_ = served
    rng = np.random.default_rng(42)
    transcript = rng.integers(0, cfg.vocab_size, 12)
    eng1 = Engine(cfg, params, EngineConfig(num_slots=2, max_len=64))
    eng1.prefill_batch([0], [transcript])
    d1 = eng1.decode_batch([0], [5], steps=3)
    # "node failure": rebuild from the durable transcript
    eng2 = Engine(cfg, params, EngineConfig(num_slots=2, max_len=64))
    eng2.prefill_batch([0], [transcript])
    d2 = eng2.decode_batch([0], [5], steps=3)
    assert d1 == d2
