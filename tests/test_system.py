"""End-to-end system behaviour: the full LAPS stack (queues → AWD →
bucketized executor → KV arena → decode) serving real multi-turn traffic
on a reduced model, plus serving-state rebuild after failure."""
import jax
import numpy as np
import pytest

from repro.configs import get_smoke
from repro.core import H200_QWEN32B, Variant, make_policy
from repro.models import transformer as tr
from repro.serving import Engine, EngineConfig
from repro.serving.loop import ServeLoop

KEY = jax.random.key(9)


@pytest.fixture(scope="module")
def served():
    cfg = get_smoke("qwen3-4b")
    params, _ = tr.init_params(cfg, KEY)
    engine = Engine(cfg, params,
                    EngineConfig(num_slots=8, max_len=160, chunk_tokens=16))
    policy = make_policy(Variant("pla_full"), H200_QWEN32B, threshold=24,
                         chunk_tokens=16)
    loop = ServeLoop(engine, policy, slo_ttft=30.0)
    rng = np.random.default_rng(0)
    # two turns of mixed traffic over 4 sessions: includes one long
    for turn in range(2):
        for s in range(4):
            n = 40 if (s == 3 and turn == 0) else int(rng.integers(4, 16))
            loop.submit(s, rng.integers(0, cfg.vocab_size, n))
        loop.run_until_idle(max_wall=180.0)
    return cfg, params, engine, policy, loop


def test_all_requests_complete(served):
    *_, loop = served
    assert loop._outstanding == 0
    assert loop.tracker.report().n == 8


def test_long_request_went_to_long_queue(served):
    cfg, params, engine, policy, loop = served
    # the 40-token request exceeded threshold 24 → chunked long path
    longs = [r for r in loop.tracker.finished if r.new_tokens >= 24]
    assert longs and all(not r.used_graph for r in longs)


def test_short_requests_bucketized(served):
    *_, loop = served
    shorts = [r for r in loop.tracker.finished if r.new_tokens < 24]
    assert any(r.used_graph for r in shorts)


def test_decode_after_serving(served):
    cfg, params, engine, policy, loop = served
    toks = loop.decode(0, 3)
    assert len(toks) == 4
    assert all(0 <= t < cfg.vocab_size for t in toks)


def test_engine_measured_and_fit(served):
    cfg, params, engine, *_ = served
    assert engine.fit_boundary() is not None


def test_unified_tick_continuous_batching():
    """run_until_idle drives the unified mixed tick: sessions submitted
    with decode budgets keep generating inside the SAME dispatches that
    serve new prefills (and long chunks), decode tokens actually fuse,
    and every transcript matches greedy decoding over the flat context."""
    import jax.numpy as jnp

    from repro.core.awd import AWDConfig

    cfg = get_smoke("qwen3-4b")
    params, _ = tr.init_params(cfg, KEY)
    engine = Engine(cfg, params, EngineConfig(
        num_slots=8, max_len=160, chunk_tokens=16, packed=True,
        token_buckets=(64, 128)))
    policy = make_policy(
        Variant("pla_full"), H200_QWEN32B, threshold=24, chunk_tokens=16,
        awd_cfg=AWDConfig(packed=True, token_buckets=(64, 128),
                          packed_max_seqs=8))
    loop = ServeLoop(engine, policy, slo_ttft=30.0)
    rng = np.random.default_rng(0)
    prompts = {}
    for s in range(4):
        n = 40 if s == 3 else int(rng.integers(4, 16))   # one long
        prompts[s] = rng.integers(0, cfg.vocab_size, n)
        loop.submit(s, prompts[s], decode_tokens=6)
    loop.run_until_idle(max_wall=180.0)

    assert loop._outstanding == 0 and not loop.active_decodes
    assert all(len(loop.generated[s]) == 7 for s in range(4))  # first + 6
    assert loop.tpot_samples, "no TPOT measured"
    st = engine.stats()
    assert st["decode_tokens_fused"] > 0, "nothing fused"
    assert st["mixed_steps"] > 0

    def greedy(seq):
        lo, _, _ = tr.forward(params, cfg,
                              tokens=jnp.asarray(seq, jnp.int32)[None])
        return int(jnp.argmax(lo[0, -1]))

    for s in range(4):
        ctx = list(prompts[s])
        for tok in loop.generated[s]:
            assert greedy(ctx) == tok, s
            ctx.append(tok)


def test_two_queued_turns_same_session_serialize():
    """Two turns of ONE session submitted back-to-back must never share
    a batch (the second depends on the first's KV writes): the batcher
    defers the later turn and both complete with a correct transcript."""
    import jax.numpy as jnp

    from repro.core.awd import AWDConfig

    cfg = get_smoke("qwen3-4b")
    params, _ = tr.init_params(cfg, KEY)
    engine = Engine(cfg, params, EngineConfig(
        num_slots=4, max_len=64, packed=True, token_buckets=(64, 128)))
    policy = make_policy(
        Variant("pla_full"), H200_QWEN32B, threshold=32,
        awd_cfg=AWDConfig(packed=True, token_buckets=(64, 128),
                          packed_max_seqs=4))
    loop = ServeLoop(engine, policy, slo_ttft=30.0)
    rng = np.random.default_rng(3)
    t1 = rng.integers(0, cfg.vocab_size, 9)
    t2 = rng.integers(0, cfg.vocab_size, 6)
    loop.submit(0, t1)
    loop.submit(0, t2)          # queued before turn 1 dispatches
    loop.run_until_idle(max_wall=120.0)
    assert loop._outstanding == 0
    assert engine.history(0) == 15

    def greedy(seq):
        lo, _, _ = tr.forward(params, cfg,
                              tokens=jnp.asarray(seq, jnp.int32)[None])
        return int(jnp.argmax(lo[0, -1]))

    assert loop.generated[0][-1] == greedy(list(t1) + list(t2))
    assert not loop._tokens    # served requests release their prompts


def test_serving_state_rebuild_after_failure(served):
    """Fault tolerance: a replacement engine rebuilt by re-prefilling the
    session transcript produces identical decode continuations."""
    cfg, params, *_ = served
    rng = np.random.default_rng(42)
    transcript = rng.integers(0, cfg.vocab_size, 12)
    eng1 = Engine(cfg, params, EngineConfig(num_slots=2, max_len=64))
    eng1.prefill_batch([0], [transcript])
    d1 = eng1.decode_batch([0], [5], steps=3)
    # "node failure": rebuild from the durable transcript
    eng2 = Engine(cfg, params, EngineConfig(num_slots=2, max_len=64))
    eng2.prefill_batch([0], [transcript])
    d2 = eng2.decode_batch([0], [5], steps=3)
    assert d1 == d2
