"""Fused on-device sampling kernel vs the host sampler (DESIGN.md §10).

``serving/sampling.py`` is the bit-level oracle: the kernel applies the
same bias → temperature → exact top-k → tie-inclusive top-p pipeline and
consumes the SAME host-drawn uniform, so for every row the kernel token
must equal ``sample_from_probs(filtered_probs(row, sp), u)`` (greedy
rows: the biased argmax), the draft probability must match
``filtered_probs(row, sp)[draft]``, and the alt token must match the
residual resample with the draft token zeroed out.  Runs the Pallas
kernel in interpret mode so parity holds on any backend.
"""
import numpy as np
import pytest

from repro.kernels.sampling import MAX_BIAS, fused_sample
from repro.serving.sampling import (SamplingParams, filtered_probs,
                                    sample_from_probs)

V = 128

CASES = [
    SamplingParams(),                                       # greedy
    SamplingParams(logit_bias={3: 5.0, 7: -4.0}),           # biased greedy
    SamplingParams(temperature=1.0),                        # no truncation
    SamplingParams(temperature=0.7, top_k=16),
    SamplingParams(temperature=1.3, top_p=0.9),
    SamplingParams(temperature=0.8, top_k=24, top_p=0.85,
                   logit_bias={11: 3.0, 40: 1.5, 90: -2.0}),
]


def _encode(sp: SamplingParams):
    """SamplingParams → the kernel's scalar encodings (top_k == 0 off,
    top_p >= 1.0 off, bias id == -1 empty slot)."""
    ids = -np.ones(MAX_BIAS, np.int32)
    vals = np.zeros(MAX_BIAS, np.float32)
    for j, (tok, b) in enumerate(sp.logit_bias or ()):
        ids[j], vals[j] = tok, b
    return (np.float32(max(sp.temperature, 0.0)),
            np.int32(sp.top_k or 0),
            np.float32(sp.top_p if sp.top_p is not None else 1.0),
            ids, vals)


def _host_expect(row, sp, u, draft):
    """(token, p_draft, alt) per the host oracle."""
    if sp.is_greedy:
        biased = np.asarray(row, np.float32).copy()
        for tok, b in sp.logit_bias or ():
            biased[int(tok)] += np.float32(b)
        g = int(np.argmax(biased))
        return g, float(g == draft), g
    probs = filtered_probs(row, sp)
    tok = sample_from_probs(probs, u)
    p_d = float(probs[draft])
    resid = probs.copy()
    resid[draft] = 0.0
    mass = resid.sum()
    alt = sample_from_probs(resid / mass, u) if mass > 0 else tok
    return tok, p_d, alt


@pytest.mark.parametrize("logits_seed", [0, 1, 2])
def test_fused_sample_matches_host(logits_seed):
    rng = np.random.default_rng(100 + logits_seed)
    n = len(CASES)
    logits = rng.normal(0.0, 3.0, (n, V)).astype(np.float32)
    u = rng.random(n).astype(np.float32)
    draft = rng.integers(0, V, n).astype(np.int32)

    temp = np.zeros(n, np.float32)
    top_k = np.zeros(n, np.int32)
    top_p = np.ones(n, np.float32)
    bids = -np.ones((n, MAX_BIAS), np.int32)
    bvals = np.zeros((n, MAX_BIAS), np.float32)
    for i, sp in enumerate(CASES):
        temp[i], top_k[i], top_p[i], bids[i], bvals[i] = _encode(sp)

    tok, p_d, alt = fused_sample(logits, temp, top_k, top_p, bids,
                                 bvals, u, draft, interpret=True)
    tok, p_d, alt = np.asarray(tok), np.asarray(p_d), np.asarray(alt)

    for i, sp in enumerate(CASES):
        want_tok, want_pd, want_alt = _host_expect(
            logits[i], sp, float(u[i]), int(draft[i]))
        assert int(tok[i]) == want_tok, (i, sp)
        assert int(alt[i]) == want_alt, (i, sp)
        assert np.isclose(float(p_d[i]), want_pd, atol=1e-5), (i, sp)


def test_fused_sample_draft_accept_semantics():
    """The speculative accept test reads p_draft: when the draft token
    IS the sampled/greedy token under a near-deterministic distribution,
    p_draft ~ 1; a truncated-out draft gets exactly 0."""
    rng = np.random.default_rng(7)
    logits = rng.normal(0.0, 1.0, (2, V)).astype(np.float32)
    logits[0, 5] = 40.0                  # near-point-mass on token 5
    temp = np.array([0.9, 0.9], np.float32)
    top_k = np.array([0, 4], np.int32)   # row 1: truncate to top-4
    top_p = np.ones(2, np.float32)
    bids = -np.ones((2, MAX_BIAS), np.int32)
    bvals = np.zeros((2, MAX_BIAS), np.float32)
    u = np.array([0.5, 0.5], np.float32)
    # row 0 drafts the point-mass token; row 1 drafts the smallest logit
    worst = int(np.argmin(logits[1]))
    draft = np.array([5, worst], np.int32)

    tok, p_d, alt = fused_sample(logits, temp, top_k, top_p, bids,
                                 bvals, u, draft, interpret=True)
    assert float(p_d[0]) > 0.999
    assert float(p_d[1]) == 0.0          # truncated out by top-k
    assert int(alt[0]) != 5              # residual excludes the draft
    sp = SamplingParams(temperature=0.9, top_k=4)
    assert int(tok[1]) == sample_from_probs(
        filtered_probs(logits[1], sp), 0.5)
