"""Truncate-then-extend round-trip properties (DESIGN.md §10).

Speculative decoding over-writes k + 1 rows per verify segment and rolls
the rejected tail back via ``arena.truncate``.  This machine drives both
arena layouts through random speculate/commit/rollback cycles and
asserts the §10 rollback invariants:

  * slot arena — truncate is pure length bookkeeping: any
    speculate-by-k / accept-c cycle lands at exactly h + c, and
    out-of-range truncates refuse;
  * paged arena — ``audit()`` holds after every cycle (refcounts equal
    counted holders, free list exactly the rc==0 pages); a reject-all
    cycle that triggered no COW restores the ENTIRE bookkeeping state
    (pages, tokens, refcounts, free list) bit-for-bit;
  * fork safety — a forked child's rollback (even to zero) never frees
    a page the parent still holds, and the parent's page table and
    cached ids survive verbatim.

Runs under hypothesis (shrinking, CI) AND as a seeded random replay
(no extra deps, always on) — the test_paged_pages pattern.
"""
import numpy as np
import pytest

from repro.serving.kvcache import KVArena, PagedKVArena

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

NUM_PAGES = 12
PS = 4
MAX_LEN = 34            # 8 pages per session, usable history = 32

_SLOT_ARENA = None


def _slot_arena() -> KVArena:
    """One real (device-backed) slot arena, shared across examples —
    truncate only touches bookkeeping, so examples reset via free()."""
    global _SLOT_ARENA
    if _SLOT_ARENA is None:
        from repro.configs import get_smoke
        _SLOT_ARENA = KVArena(get_smoke("qwen3-4b"), num_slots=2,
                              max_len=32)
    return _SLOT_ARENA


def _snapshot(ar: PagedKVArena):
    return (sorted(ar._free), list(ar._refcount),
            {s: list(p) for s, p in ar._pages.items()},
            {s: list(t) for s, t in ar._tokens.items()},
            dict(ar.lengths))


def _commit(ar: PagedKVArena, s: int, toks) -> None:
    if toks:
        ar.prepare_extend(s, len(toks))
    ar.commit(s, list(toks))


def _drive_slot(rng: np.random.Generator) -> None:
    ar = _slot_arena()
    ar.alloc(0)
    try:
        h = 0
        for _ in range(24):
            k = int(rng.integers(1, 6))
            if h + k > ar.max_len - 2:
                ar.truncate(0, 0)
                h = 0
                continue
            ar.set_length(0, h + k)          # the verify write
            c = int(rng.integers(0, k + 1))  # accepted prefix
            ar.truncate(0, h + c)            # reject the tail
            assert ar.length(0) == h + c
            h += c
        with pytest.raises(ValueError):
            ar.truncate(0, h + 1)            # beyond the valid length
        with pytest.raises(ValueError):
            ar.truncate(0, -1)
    finally:
        ar.free(0)


def _drive_paged(rng: np.random.Generator) -> None:
    ar = PagedKVArena(None, NUM_PAGES, PS, MAX_LEN)
    ar.open(0)
    _commit(ar, 0, [int(t) for t in rng.integers(1, 50,
                                                 int(rng.integers(1, 9)))])
    ar.audit()
    forked = False
    for _ in range(12):
        op = int(rng.integers(0, 3))
        s = 1 if forked and rng.integers(0, 2) else 0
        h = ar.length(s)
        if op == 0 and not forked and h >= PS:
            ar.fork(0, 1)
            forked = True
            ar.audit()
        elif op == 1:
            # speculative cycle: over-extend by k, accept c, roll back
            k = int(rng.integers(1, 6))
            if h + k > MAX_LEN - 2 or ar.free_pages < -(-k // PS) + 1:
                continue
            before = _snapshot(ar)
            cow_before = ar.pages_cow_forked
            ar.prepare_extend(s, k)          # the verify write
            c = int(rng.integers(0, k + 1))
            ar.commit(s, [int(t) for t in rng.integers(1, 50, c)])
            ar.truncate(s, h + c)            # reject the tail
            ar.audit()
            assert ar.length(s) == h + c
            if c == 0 and ar.pages_cow_forked == cow_before:
                # reject-all with no COW: a perfect bookkeeping no-op —
                # over-allocated pages returned, refcounts restored
                assert _snapshot(ar) == before
        else:
            if h + 1 <= MAX_LEN - 2 and ar.free_pages > 1:
                _commit(ar, s, [int(rng.integers(1, 50))])
                ar.audit()
    if forked:
        # fork safety: the child's full rollback must not free pages
        # the parent still holds, nor disturb the parent's table
        parent_pages = list(ar._pages[0])
        parent_toks = list(ar._tokens[0])
        ar.truncate(1, 0)
        ar.audit()
        for p in parent_pages:
            assert ar._refcount[p] >= 1, f"shared page {p} freed"
        assert ar._pages[0] == parent_pages
        assert ar._tokens[0] == parent_toks
        ar.free(1)
    ar.free(0)
    ar.audit()


# ------------------------------------------------------------ hypothesis
if HAVE_HYPOTHESIS:
    @given(seed=st.integers(0, 2 ** 32 - 1))
    @settings(max_examples=60, deadline=None)
    def test_slot_truncate_roundtrip_hypothesis(seed):
        _drive_slot(np.random.default_rng(seed))

    @given(seed=st.integers(0, 2 ** 32 - 1))
    @settings(max_examples=60, deadline=None)
    def test_paged_truncate_roundtrip_hypothesis(seed):
        _drive_paged(np.random.default_rng(seed))


# ------------------------------------------------------- seeded replay
def test_slot_truncate_roundtrip_replay():
    for seed in range(30):
        _drive_slot(np.random.default_rng(seed))


def test_paged_truncate_roundtrip_replay():
    for seed in range(40):
        _drive_paged(np.random.default_rng(seed))
