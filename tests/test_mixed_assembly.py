"""Property-based invariants of mixed-batch assembly (continuous
batching): for ANY request mix, the builder never exceeds the token
bucket, never splits a prefill segment, preserves per-session token
order, and emits consistent ``cu_seqlens``.  Runs under hypothesis —
CI installs it; locally the module skips when absent."""
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, strategies as st

from repro.core.awd import AWDConfig, AWDScheduler
from repro.core.buckets import BucketGrid, DecodeBucketLadder, TokenBucketLadder
from repro.core.request import Request
from repro.serving.packing import (SegmentSpec, assemble_mixed_stream,
                                   fit_decodes, pad_decode_rows)

LADDER = TokenBucketLadder((64, 128, 256, 512), max_seqs=16)
PARK = 127


# ---------------------------------------------------------- strategies

segment_lists = st.lists(
    st.tuples(st.integers(1, 40),          # segment length
              st.integers(0, 60),          # history offset
              st.sampled_from(["prefill", "chunk", "decode"])),
    min_size=1, max_size=12)


def to_segments(raw):
    segs = []
    for i, (l, h, kind) in enumerate(raw):
        if kind == "decode":
            l = 1
        toks = np.arange(1000 * i, 1000 * i + l, dtype=np.int32) % 251
        segs.append(SegmentSpec(session=i, tokens=toks, history=h,
                                kind=kind))
    return segs


# ------------------------------------------------------------ assembly


@given(raw=segment_lists)
def test_stream_invariants(raw):
    segs = to_segments(raw)
    total = sum(s.length for s in segs)
    bucket = LADDER.bucket_for(total)
    if bucket is None:
        return                              # off-ladder mixes never assemble
    b_max = LADDER.max_seqs
    stream = assemble_mixed_stream(segs, bucket, b_max, PARK)
    n = len(segs)
    cu = stream.cu_seqlens

    # bucket never exceeded; all arrays statically shaped on (bucket, b_max)
    assert stream.total_tokens == total <= bucket
    assert stream.tokens.shape == (bucket,)
    assert cu.shape == (b_max + 1,)

    # cu_seqlens: 0-based, strictly increasing over real segments,
    # cu[n] == T, constant (empty padding sequences) afterwards
    assert cu[0] == 0
    assert all(cu[i] < cu[i + 1] for i in range(n))
    assert cu[n] == total
    assert all(cu[i] == total for i in range(n, b_max + 1))

    for i, seg in enumerate(segs):
        lo, hi = cu[i], cu[i + 1]
        # segments are never split: contiguous rows, exact token order
        np.testing.assert_array_equal(stream.tokens[lo:hi], seg.tokens)
        np.testing.assert_array_equal(stream.seg_ids[lo:hi], i)
        # positions resume at the history offset (re-prefill / decode)
        np.testing.assert_array_equal(stream.positions[lo:hi],
                                      seg.history + np.arange(hi - lo))
        assert stream.q_offsets[i] == seg.history
        assert stream.kv_lengths[i] == seg.history + seg.length
        assert stream.last_idx[i] == hi - 1
    # bucket tail: parked positions, no live sequence id
    np.testing.assert_array_equal(stream.positions[total:], PARK)
    assert stream.decode_tokens == sum(1 for s in segs if s.kind == "decode")
    assert stream.prefill_tokens + stream.decode_tokens == total


@given(prefill=st.integers(0, 600), n_p=st.integers(0, 16),
       n_d=st.integers(0, 40))
def test_fit_decodes_bounds(prefill, n_p, n_d):
    n_fit, bucket = fit_decodes(prefill, n_p, n_d, LADDER)
    assert 0 <= n_fit <= n_d
    assert n_p + n_fit <= max(LADDER.max_seqs, n_p)
    if bucket is not None:
        assert prefill + n_fit <= bucket
        assert bucket in LADDER.buckets
    elif prefill + min(n_d, LADDER.max_seqs - n_p) > 0:
        # None only when even the un-fused total is off-ladder / roomless
        assert prefill > LADDER.max_tokens or prefill + n_fit == 0


# ----------------------------------------------------- AWD mixed emit


@given(lengths=st.lists(st.integers(1, 80), min_size=1, max_size=30),
       backlog=st.integers(0, 24))
def test_awd_mixed_batch_respects_bucket(lengths, backlog):
    """The emitted packed batch + its reserved decode rows always fit
    the token bucket and the cache-row budget."""
    awd = AWDScheduler(BucketGrid(), AWDConfig(
        packed=True, token_buckets=LADDER.buckets, packed_max_seqs=16))
    awd.note_decode_backlog(backlog)
    q = [Request(new_tokens=l, arrival=0.0) for l in lengths]
    batch, _ = awd.decide(list(q), now=10.0, force=True)
    if batch is None or not batch.is_packed:
        return
    assert batch.tokens + batch.decode_tokens <= batch.token_bucket
    assert len(batch.requests) + batch.decode_tokens <= LADDER.max_seqs
    assert batch.decode_tokens <= backlog
    # FCFS order preserved — a packed batch never reorders arrivals
    arr = [r.arrival for r in batch.requests]
    assert arr == sorted(arr)


# ------------------------------------------------- decode bucket rows


@given(rows=st.lists(st.tuples(st.integers(0, 15),     # arena slot
                               st.integers(0, 60),     # cached history
                               st.integers(0, 250)),   # last token
                     min_size=1, max_size=32),
       ladder_max=st.integers(1, 32))
def test_decode_bucket_never_drops_or_reorders(rows, ladder_max):
    """For ANY live session set and ladder, the decode-bucket choice
    keeps every session, in submission order, with its exact (slot,
    history, token) — padding only ever APPENDS rows, and pad rows park
    at the junk position with a 1-entry attention window."""
    ladder = DecodeBucketLadder((1, 2, 4, 8, 16, 32), max_seqs=ladder_max)
    n = len(rows)
    bucket = ladder.bucket_for(n)
    if bucket is None:
        assert n > ladder.max_seqs       # overflow is the ONLY None case
        return
    assert n <= bucket <= ladder.max_seqs
    slots = [s for s, _, _ in rows]
    hists = [h for _, h, _ in rows]
    toks = [t for _, _, t in rows]
    park = 63
    dr = pad_decode_rows(slots, hists, toks, bucket, park_position=park)
    # live rows: exact values, original order
    np.testing.assert_array_equal(dr.slot_map[:n], slots)
    np.testing.assert_array_equal(dr.write_pos[:n], hists)
    np.testing.assert_array_equal(dr.tokens[:n], toks)
    np.testing.assert_array_equal(dr.kv_lengths[:n],
                                  np.asarray(hists) + 1)
    # pad rows: park position, slot 0's row, single-entry window
    assert dr.pad_rows == bucket - n
    np.testing.assert_array_equal(dr.slot_map[n:], slots[0])
    np.testing.assert_array_equal(dr.write_pos[n:], park)
    np.testing.assert_array_equal(dr.kv_lengths[n:], 1)


@given(backlog=st.integers(0, 32))
def test_awd_window_shrinks_with_decode_backlog(backlog):
    awd = AWDScheduler(BucketGrid(), AWDConfig(
        packed=True, w_min=0.0, w_max=1.0))
    q = [Request(new_tokens=8, arrival=0.0, deadline=100.0)]
    base = awd.window(q, 0.0, 1)
    awd.note_decode_backlog(backlog)
    shrunk = awd.window(q, 0.0, 1)
    assert shrunk <= base
    if backlog:
        assert shrunk < base
