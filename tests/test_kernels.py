"""Per-kernel shape/dtype sweeps: Pallas (interpret) vs ref.py oracle."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref
from repro.kernels.decode_attn import decode_attn
from repro.kernels.flash_attn import flash_attn
from repro.kernels.ssd_scan import ssd_scan

KEY = jax.random.key(0)


def rand(key, shape, dtype):
    x = jax.random.normal(key, shape, jnp.float32)
    return x.astype(dtype)


TOL = {jnp.float32: 2e-5, jnp.bfloat16: 2e-2}


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("b,lq,s,hq,hkv,d,bq,bk", [
    (1, 16, 16, 4, 4, 16, 8, 8),       # MHA square
    (2, 48, 80, 8, 2, 32, 16, 16),     # GQA, cache longer than query
    (1, 33, 70, 4, 1, 64, 16, 32),     # ragged (padding paths)
    (2, 8, 128, 8, 4, 16, 8, 64),      # short query, long cache
])
def test_flash_attn_sweep(dtype, b, lq, s, hq, hkv, d, bq, bk):
    ks = jax.random.split(KEY, 4)
    q = rand(ks[0], (b, lq, hq, d), dtype)
    k = rand(ks[1], (b, s, hkv, d), dtype)
    v = rand(ks[2], (b, s, hkv, d), dtype)
    offs = jax.random.randint(ks[3], (b,), 0, s - lq + 1)
    out = flash_attn(q, k, v, offs, block_q=bq, block_k=bk)
    want = ref.ref_flash_attn(q, k, v, q_offsets=offs)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32),
                               atol=TOL[dtype], rtol=TOL[dtype])


@pytest.mark.parametrize("window", [8, 24, 64])
def test_flash_attn_sliding_window(window):
    ks = jax.random.split(KEY, 4)
    b, lq, s, hq, hkv, d = 2, 32, 64, 4, 2, 32
    q = rand(ks[0], (b, lq, hq, d), jnp.float32)
    k = rand(ks[1], (b, s, hkv, d), jnp.float32)
    v = rand(ks[2], (b, s, hkv, d), jnp.float32)
    offs = jnp.array([10, 30], jnp.int32)
    out = flash_attn(q, k, v, offs, window=window, block_q=16, block_k=16)
    want = ref.ref_flash_attn(q, k, v, q_offsets=offs, window=window)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), atol=2e-5)


def test_flash_attn_noncausal_kv_len():
    ks = jax.random.split(KEY, 4)
    b, lq, s, hq, hkv, d = 2, 16, 32, 4, 4, 16
    q = rand(ks[0], (b, lq, hq, d), jnp.float32)
    k = rand(ks[1], (b, s, hkv, d), jnp.float32)
    v = rand(ks[2], (b, s, hkv, d), jnp.float32)
    lens = jnp.array([20, 32], jnp.int32)
    out = flash_attn(q, k, v, None, lens, causal=False, block_q=8, block_k=8)
    want = ref.ref_flash_attn(q, k, v, kv_lengths=lens, causal=False)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), atol=2e-5)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("b,s,hq,hkv,d,bk", [
    (2, 64, 8, 2, 32, 16),
    (1, 100, 4, 4, 64, 32),    # ragged cache blocks
    (4, 32, 8, 1, 16, 32),     # MQA
])
def test_decode_attn_sweep(dtype, b, s, hq, hkv, d, bk):
    ks = jax.random.split(KEY, 4)
    q = rand(ks[0], (b, hq, d), dtype)
    k = rand(ks[1], (b, s, hkv, d), dtype)
    v = rand(ks[2], (b, s, hkv, d), dtype)
    lens = jax.random.randint(ks[3], (b,), 1, s + 1)
    out = decode_attn(q, k, v, lens, block_k=bk)
    want = ref.ref_decode_attn(q, k, v, lens)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32),
                               atol=TOL[dtype], rtol=TOL[dtype])


@pytest.mark.parametrize("b,l,nh,hd,ds,chunk", [
    (2, 40, 4, 8, 16, 16),     # padding path (40 % 16 != 0)
    (1, 64, 2, 16, 32, 32),
    (2, 16, 8, 8, 8, 16),      # single chunk
])
def test_ssd_scan_sweep(b, l, nh, hd, ds, chunk):
    ks = jax.random.split(KEY, 5)
    x = rand(ks[0], (b, l, nh, hd), jnp.float32)
    dt = jax.nn.softplus(rand(ks[1], (b, l, nh), jnp.float32))
    a = -jnp.exp(rand(ks[2], (nh,), jnp.float32) * 0.3)
    bm = rand(ks[3], (b, l, nh, ds), jnp.float32)
    cm = rand(ks[4], (b, l, nh, ds), jnp.float32)
    h0 = jnp.zeros((b, nh, hd, ds))
    y, hf = ssd_scan(x, dt, a, bm, cm, h0, chunk=chunk)
    ye, hfe = ref.ref_ssd_scan(x, dt, a, bm, cm)
    np.testing.assert_allclose(np.asarray(y), np.asarray(ye),
                               atol=5e-5, rtol=5e-4)
    np.testing.assert_allclose(np.asarray(hf), np.asarray(hfe),
                               atol=5e-5, rtol=5e-4)


def test_ssd_scan_carries_state():
    """Splitting a sequence across two scans == one scan (re-prefill)."""
    ks = jax.random.split(KEY, 5)
    b, l, nh, hd, ds = 1, 32, 2, 8, 16
    x = rand(ks[0], (b, l, nh, hd), jnp.float32)
    dt = jax.nn.softplus(rand(ks[1], (b, l, nh), jnp.float32))
    a = -jnp.exp(rand(ks[2], (nh,), jnp.float32) * 0.3)
    bm = rand(ks[3], (b, l, nh, ds), jnp.float32)
    cm = rand(ks[4], (b, l, nh, ds), jnp.float32)
    h0 = jnp.zeros((b, nh, hd, ds))
    y_all, h_all = ssd_scan(x, dt, a, bm, cm, h0, chunk=8)
    y1, h1 = ssd_scan(x[:, :20], dt[:, :20], a, bm[:, :20], cm[:, :20],
                      h0, chunk=8)
    y2, h2 = ssd_scan(x[:, 20:], dt[:, 20:], a, bm[:, 20:], cm[:, 20:],
                      h1, chunk=8)
    np.testing.assert_allclose(np.asarray(jnp.concatenate([y1, y2], 1)),
                               np.asarray(y_all), atol=5e-5, rtol=5e-4)
    np.testing.assert_allclose(np.asarray(h2), np.asarray(h_all),
                               atol=5e-5, rtol=5e-4)
