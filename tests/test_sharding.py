"""Logical sharding rules: divisibility guard, duplicate-axis guard,
tree shardings."""
import jax
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.distributed.sharding import (SERVE_RULES, TRAIN_RULES,
                                        ShardingRules, spec_for,
                                        tree_shardings, use_rules, constrain)


def make_mesh():
    # jax < 0.5 has no jax.sharding.AxisType (all axes are Auto there)
    if hasattr(jax.sharding, "AxisType"):
        return jax.make_mesh((1, 1), ("data", "model"),
                             axis_types=(jax.sharding.AxisType.Auto,) * 2)
    return jax.make_mesh((1, 1), ("data", "model"))


@pytest.fixture(scope="module")
def mesh():
    return make_mesh()


def rules(mesh, table=TRAIN_RULES):
    return ShardingRules(mesh=mesh, rules=dict(table))


def test_spec_basic(mesh):
    r = rules(mesh)
    s = spec_for((64, 128), ("embed", "mlp"), r)
    assert s == P("data", "model")


def test_divisibility_guard():
    big = make_mesh()
    # fake a 16-wide model axis via rules math: use axis_size directly
    r = ShardingRules(mesh=big, rules=dict(TRAIN_RULES))
    # with axis size 1 everything divides; emulate 16 by checking the
    # guard logic through a shape that can't divide a hypothetical axis
    s = spec_for((8,), ("kv_heads",), r)
    assert s == P(None) or s == P("model")   # axis size 1 → allowed


def test_duplicate_axis_dropped(mesh):
    r = rules(mesh)
    # both logical dims map to "model" — second must drop
    s = spec_for((64, 64), ("heads", "mlp"), r)
    flat = [a for a in s if a is not None]
    names = []
    for a in flat:
        names.extend(a if isinstance(a, tuple) else (a,))
    assert len(names) == len(set(names))


def test_constrain_noop_without_rules():
    x = jax.numpy.ones((4, 4))
    y = constrain(x, "batch", "seq")
    np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def test_tree_shardings(mesh):
    r = rules(mesh)
    params = {"w": jax.numpy.ones((8, 16))}
    axes = {"w": ("embed", "mlp")}
    sh = tree_shardings(params, axes, r)
    assert sh["w"].spec == P("data", "model")


def test_serve_rules_replicate_weights_over_data(mesh):
    r = rules(mesh, SERVE_RULES)
    s = spec_for((64, 128), ("embed", "mlp"), r)
    assert s == P(None, "model")


def test_use_rules_context(mesh):
    from repro.distributed.sharding import current_rules
    assert current_rules() is None
    with use_rules(rules(mesh)):
        assert current_rules() is not None
    assert current_rules() is None
