"""Continuous-batching parity: a mixed packed step (short prefills +
long-prefill chunk + fused decode segments in ONE dispatch) must produce
the same logits and KV caches as running prefill_batch / prefill_long /
decode_batch sequentially on the dense path — across GQA/MHA configs,
re-prefill history offsets, and both ragged-attention backends (XLA
oracle and the Pallas kernel in interpret mode)."""
import jax
import numpy as np
import pytest

from repro.configs import get_smoke
from repro.kernels import ops as kernel_ops
from repro.models import transformer as tr
from repro.serving import Engine, EngineConfig

KEY = jax.random.key(7)
TOL = dict(atol=1e-5, rtol=0)
TOL_INTERPRET = dict(atol=2e-5, rtol=0)

# GQA with qk_norm, GQA with qkv bias, and plain MHA
CONFIGS = {
    "qwen3-4b": lambda: get_smoke("qwen3-4b"),
    "qwen2.5-14b": lambda: get_smoke("qwen2.5-14b"),
    "mha": lambda: get_smoke("qwen3-4b").replace(name="mha-smoke",
                                                 num_kv_heads=4),
}


def build(cfg, packed: bool):
    params, _ = tr.init_params(cfg, KEY)
    return params, Engine(cfg, params, EngineConfig(
        num_slots=8, max_len=128, chunk_tokens=32, packed=packed,
        token_buckets=(64, 128, 256), paged_kv=False))


def pair(cfg):
    """(mixed engine, dense oracle engine) sharing one param set."""
    params, mixed = build(cfg, packed=True)
    oracle = Engine(cfg, params, EngineConfig(num_slots=8, max_len=128,
                                              chunk_tokens=32,
                                              paged_kv=False))
    return mixed, oracle


def assert_kv_parity(eng: Engine, ora: Engine, sessions, tol=TOL):
    """Each session's valid cache prefix must match across engines."""
    for s in sessions:
        n = eng.arena.length(s)
        assert n == ora.arena.length(s), (s, n, ora.arena.length(s))
        sm, so = eng.arena.slot_of(s), ora.arena.slot_of(s)
        for cm, co in zip(eng.arena.arena, ora.arena.arena):
            for part in ("k", "v"):
                np.testing.assert_allclose(
                    np.asarray(cm[part][:, sm, :n]),
                    np.asarray(co[part][:, so, :n]),
                    err_msg=f"session {s} cache {part}", **tol)


def stage_histories(engines, cfg, rng):
    """Give sessions 2/3/4 cached history + a sampled token (decode
    state) and session 5 its first long-prefill chunk — identically on
    every engine via the dense path."""
    hist_lens = {2: 9, 3: 5, 4: 14}
    seqs = [rng.integers(0, cfg.vocab_size, l) for l in hist_lens.values()]
    long_toks = rng.integers(0, cfg.vocab_size, 50)
    firsts = None
    for e in engines:
        firsts = e.prefill_batch(list(hist_lens), seqs)
        e.prefill_batch([5], [long_toks[:32]])
    return firsts, long_toks


@pytest.mark.parametrize("arch", list(CONFIGS))
def test_mixed_step_parity(arch):
    """2 prefills (one a re-prefill) + 3 decodes + 1 long chunk, fused
    into one packed dispatch, vs the sequential dense path."""
    cfg = CONFIGS[arch]()
    rng = np.random.default_rng(11)
    eng, ora = pair(cfg)
    firsts, long_toks = stage_histories((eng, ora), cfg, rng)
    # session 0 is a RE-prefill: 6 tokens of history before the step
    pre0 = rng.integers(0, cfg.vocab_size, 6)
    for e in (eng, ora):
        e.prefill_batch([0], [pre0])

    t_a = rng.integers(0, cfg.vocab_size, 7)
    t_b = rng.integers(0, cfg.vocab_size, 12)
    chunk2 = long_toks[32:]
    decodes = [(s, firsts[s]) for s in (2, 3, 4)]

    before = eng.packed_executor.dispatches
    res = eng.step_mixed([(0, t_a), (1, t_b), (5, chunk2)], decodes)
    assert res.fused and res.bucket == 64
    assert res.n_prefill == 3 and res.n_decode == 3
    assert eng.packed_executor.dispatches == before + 1   # ONE dispatch
    assert eng.packed_executor.decode_tokens_fused == 3

    expect = {}
    expect.update(ora.prefill_batch([0], [t_a]))
    expect.update(ora.prefill_batch([1], [t_b]))
    expect.update(ora.prefill_batch([5], [chunk2]))
    dec = ora.decode_batch([2, 3, 4], [firsts[s] for s in (2, 3, 4)])
    expect.update({s: t[0] for s, t in dec.items()})

    assert res.tokens == expect
    for s in range(6):
        np.testing.assert_allclose(eng.last_logits[s], ora.last_logits[s],
                                   err_msg=f"session {s} logits", **TOL)
    assert_kv_parity(eng, ora, range(6))


def test_mixed_step_parity_interpret_mode():
    """The same parity holds with the ragged Pallas kernel in interpret
    mode — decode-length-1 segments attend over offset + 1 keys."""
    cfg = CONFIGS["qwen3-4b"]()
    rng = np.random.default_rng(13)
    kernel_ops.set_backend("pallas")
    try:
        eng, ora = pair(cfg)
        firsts, long_toks = stage_histories((eng, ora), cfg, rng)
        t_a = rng.integers(0, cfg.vocab_size, 7)
        chunk2 = long_toks[32:]
        decodes = [(s, firsts[s]) for s in (2, 3, 4)]
        res = eng.step_mixed([(0, t_a), (5, chunk2)], decodes)
        assert res.fused and res.n_decode == 3

        expect = {}
        expect.update(ora.prefill_batch([0], [t_a]))
        expect.update(ora.prefill_batch([5], [chunk2]))
        dec = ora.decode_batch([2, 3, 4], [firsts[s] for s in (2, 3, 4)])
        expect.update({s: t[0] for s, t in dec.items()})
        assert res.tokens == expect
        for s in (0, 2, 3, 4, 5):
            np.testing.assert_allclose(eng.last_logits[s],
                                       ora.last_logits[s],
                                       err_msg=f"session {s} logits",
                                       **TOL_INTERPRET)
        assert_kv_parity(eng, ora, (0, 2, 3, 4, 5), tol=TOL_INTERPRET)
    finally:
        kernel_ops.set_backend(None)


def test_decode_only_mixed_step():
    """A tick with no prefill work still fuses the decode backlog into
    one packed dispatch, matching the dense decode step."""
    cfg = CONFIGS["qwen3-4b"]()
    rng = np.random.default_rng(17)
    eng, ora = pair(cfg)
    firsts, _ = stage_histories((eng, ora), cfg, rng)
    decodes = [(s, firsts[s]) for s in (2, 3, 4)]
    res = eng.step_mixed([], decodes)
    assert res.fused and res.n_prefill == 0 and res.n_decode == 3
    dec = ora.decode_batch([2, 3, 4], [firsts[s] for s in (2, 3, 4)])
    assert res.tokens == {s: t[0] for s, t in dec.items()}
    for s in (2, 3, 4):
        np.testing.assert_allclose(eng.last_logits[s], ora.last_logits[s],
                                   **TOL)
    assert_kv_parity(eng, ora, (2, 3, 4))


def test_mixed_step_fallback_paths():
    """Off-ladder totals and over-depth mixes fall back to the
    alternating dense path — same results, just more dispatches."""
    cfg = CONFIGS["qwen3-4b"]()
    rng = np.random.default_rng(19)
    params, eng = build(cfg, packed=True)
    ora = Engine(cfg, params, EngineConfig(num_slots=8, max_len=128,
                                           paged_kv=False))
    firsts, _ = stage_histories((eng, ora), cfg, rng)
    # 3 × 90 prefill tokens bust the (64, 128, 256) ladder
    bigs = [rng.integers(0, cfg.vocab_size, 90) for _ in range(3)]
    res = eng.step_mixed(list(zip((0, 1, 6), bigs)), [(2, firsts[2])],
                         token_bucket=None)
    assert not res.fused
    expect = dict(ora.prefill_batch([0, 1, 6], bigs))
    dec = ora.decode_batch([2], [firsts[2]])
    expect[2] = dec[2][0]
    assert res.tokens == expect
    assert_kv_parity(eng, ora, (0, 1, 6, 2))


def test_mixed_step_rejects_duplicate_session():
    cfg = CONFIGS["qwen3-4b"]()
    _, eng = build(cfg, packed=True)
    rng = np.random.default_rng(23)
    t = rng.integers(0, cfg.vocab_size, 5)
    eng.prefill_packed([0], [t])
    with pytest.raises(AssertionError):
        eng.step_mixed([(0, t)], [(0, 1)])


def test_long_chunks_ride_token_buckets():
    """prefill_long routes every C_l chunk through the packed stream:
    the packed executor (not the dense grid) serves the chunks."""
    cfg = CONFIGS["qwen3-4b"]()
    rng = np.random.default_rng(29)
    params, eng = build(cfg, packed=True)
    ora = Engine(cfg, params, EngineConfig(num_slots=8, max_len=128,
                                           chunk_tokens=32,
                                           paged_kv=False))
    long_toks = rng.integers(0, cfg.vocab_size, 80)
    tok = eng.prefill_long(0, long_toks)
    assert eng.packed_executor.dispatches == 3          # ceil(80 / 32)
    assert eng.executor.dispatches == 0                 # dense untouched
    assert tok == ora.prefill_long(0, long_toks)
    assert_kv_parity(eng, ora, (0,))
