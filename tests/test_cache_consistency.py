"""System invariant: staged serving (prefill → re-prefill → decode)
produces exactly the same logits as one full forward pass — for every
stateful architecture family, including the rolling SWA cache."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ASSIGNED, get_smoke
from repro.models import transformer as tr

KEY = jax.random.key(1)
STATEFUL = [a for a in ASSIGNED if get_smoke(a).causal]


@pytest.mark.parametrize("arch", STATEFUL)
def test_staged_equals_full(arch):
    cfg = get_smoke(arch)
    params, _ = tr.init_params(cfg, KEY)
    b, h, l, s = 2, 8, 5, 32
    tok = jax.random.randint(KEY, (b, h + l + 1), 0, cfg.vocab_size)
    kw = {"tokens": tok} if not cfg.frontend else \
        {"embeds": jax.random.normal(KEY, (b, h + l + 1, cfg.d_model))}
    full, _, _ = tr.forward(params, cfg, **kw)

    def sl(a, z):
        return {k: v[:, a:z] for k, v in kw.items()}

    caches = tr.init_cache(cfg, b, s)
    pos = jnp.broadcast_to(jnp.arange(h + l + 1)[None], (b, h + l + 1))
    lo1, caches, _ = tr.forward(params, cfg, **sl(0, h),
                                positions=pos[:, :h], caches=caches)
    lo2, caches, _ = tr.forward(params, cfg, **sl(h, h + l),
                                positions=pos[:, h:h + l], caches=caches)
    lo3, caches, _ = tr.forward(params, cfg, **sl(h + l, h + l + 1),
                                positions=pos[:, h + l:], caches=caches)
    np.testing.assert_allclose(np.asarray(lo1), np.asarray(full[:, :h]),
                               atol=2e-3, rtol=1e-3)
    np.testing.assert_allclose(np.asarray(lo2), np.asarray(full[:, h:h + l]),
                               atol=2e-3, rtol=1e-3)
    np.testing.assert_allclose(np.asarray(lo3), np.asarray(full[:, h + l:]),
                               atol=2e-3, rtol=1e-3)


def test_rolling_swa_cache_matches_full():
    cfg = get_smoke("mixtral-8x7b")           # sliding_window = 32
    params, _ = tr.init_params(cfg, KEY)
    b, t = 1, 40                              # longer than the window
    tok = jax.random.randint(KEY, (b, t), 0, cfg.vocab_size)
    full, _, _ = tr.forward(params, cfg, tokens=tok)
    w = cfg.sliding_window
    caches = tr.init_cache(cfg, b, w)
    pos = jnp.broadcast_to(jnp.arange(t)[None], (b, t))
    worst = 0.0
    for i in range(t):
        lo, caches, _ = tr.forward(params, cfg, tokens=tok[:, i:i + 1],
                                   positions=pos[:, i:i + 1], caches=caches,
                                   rolling=True)
        worst = max(worst, float(jnp.max(jnp.abs(lo[:, 0] - full[:, i]))))
    assert worst < 2e-3, worst


def test_ragged_batch_positions():
    """Requests with different history lengths share one batch safely."""
    cfg = get_smoke("qwen3-4b")
    params, _ = tr.init_params(cfg, KEY)
    s = 32
    tok = jax.random.randint(KEY, (2, 12), 0, cfg.vocab_size)
    # row 0 has 6 tokens of history, row 1 has 0
    caches = tr.init_cache(cfg, 2, s)
    pos0 = jnp.broadcast_to(jnp.arange(6)[None], (2, 6))
    _, caches, _ = tr.forward(params, cfg, tokens=tok[:, :6],
                              positions=pos0, caches=caches)
    # re-prefill 4 tokens: row 0 continues at 6, row 1 restarts at 0
    new = tok[:, 6:10]
    positions = jnp.stack([6 + jnp.arange(4), jnp.arange(4)])
    lo, caches, _ = tr.forward(params, cfg, tokens=new,
                               positions=positions, caches=caches)
    # row 1's logits must equal a fresh 4-token forward (history invisible)
    ref, _, _ = tr.forward(params, cfg, tokens=new[1:2])
    np.testing.assert_allclose(np.asarray(lo[1]), np.asarray(ref[0]),
                               atol=2e-3, rtol=1e-3)
