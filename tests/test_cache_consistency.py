"""System invariant: staged serving (prefill → re-prefill → decode)
produces exactly the same logits as one full forward pass — for every
stateful architecture family, including the rolling SWA cache — and the
same invariant under INTERLEAVED continuous-batching schedules (decode
→ mid-conversation re-prefill → decode, all in mixed packed steps)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ASSIGNED, get_smoke
from repro.models import transformer as tr
from repro.serving import Engine, EngineConfig

KEY = jax.random.key(1)
STATEFUL = [a for a in ASSIGNED if get_smoke(a).causal]


@pytest.mark.parametrize("arch", STATEFUL)
def test_staged_equals_full(arch):
    cfg = get_smoke(arch)
    params, _ = tr.init_params(cfg, KEY)
    b, h, l, s = 2, 8, 5, 32
    tok = jax.random.randint(KEY, (b, h + l + 1), 0, cfg.vocab_size)
    kw = {"tokens": tok} if not cfg.frontend else \
        {"embeds": jax.random.normal(KEY, (b, h + l + 1, cfg.d_model))}
    full, _, _ = tr.forward(params, cfg, **kw)

    def sl(a, z):
        return {k: v[:, a:z] for k, v in kw.items()}

    caches = tr.init_cache(cfg, b, s)
    pos = jnp.broadcast_to(jnp.arange(h + l + 1)[None], (b, h + l + 1))
    lo1, caches, _ = tr.forward(params, cfg, **sl(0, h),
                                positions=pos[:, :h], caches=caches)
    lo2, caches, _ = tr.forward(params, cfg, **sl(h, h + l),
                                positions=pos[:, h:h + l], caches=caches)
    lo3, caches, _ = tr.forward(params, cfg, **sl(h + l, h + l + 1),
                                positions=pos[:, h + l:], caches=caches)
    np.testing.assert_allclose(np.asarray(lo1), np.asarray(full[:, :h]),
                               atol=2e-3, rtol=1e-3)
    np.testing.assert_allclose(np.asarray(lo2), np.asarray(full[:, h:h + l]),
                               atol=2e-3, rtol=1e-3)
    np.testing.assert_allclose(np.asarray(lo3), np.asarray(full[:, h + l:]),
                               atol=2e-3, rtol=1e-3)


def test_rolling_swa_cache_matches_full():
    cfg = get_smoke("mixtral-8x7b")           # sliding_window = 32
    params, _ = tr.init_params(cfg, KEY)
    b, t = 1, 40                              # longer than the window
    tok = jax.random.randint(KEY, (b, t), 0, cfg.vocab_size)
    full, _, _ = tr.forward(params, cfg, tokens=tok)
    w = cfg.sliding_window
    caches = tr.init_cache(cfg, b, w)
    pos = jnp.broadcast_to(jnp.arange(t)[None], (b, t))
    worst = 0.0
    for i in range(t):
        lo, caches, _ = tr.forward(params, cfg, tokens=tok[:, i:i + 1],
                                   positions=pos[:, i:i + 1], caches=caches,
                                   rolling=True)
        worst = max(worst, float(jnp.max(jnp.abs(lo[:, 0] - full[:, i]))))
    assert worst < 2e-3, worst


def test_ragged_batch_positions():
    """Requests with different history lengths share one batch safely."""
    cfg = get_smoke("qwen3-4b")
    params, _ = tr.init_params(cfg, KEY)
    s = 32
    tok = jax.random.randint(KEY, (2, 12), 0, cfg.vocab_size)
    # row 0 has 6 tokens of history, row 1 has 0
    caches = tr.init_cache(cfg, 2, s)
    pos0 = jnp.broadcast_to(jnp.arange(6)[None], (2, 6))
    _, caches, _ = tr.forward(params, cfg, tokens=tok[:, :6],
                              positions=pos0, caches=caches)
    # re-prefill 4 tokens: row 0 continues at 6, row 1 restarts at 0
    new = tok[:, 6:10]
    positions = jnp.stack([6 + jnp.arange(4), jnp.arange(4)])
    lo, caches, _ = tr.forward(params, cfg, tokens=new,
                               positions=positions, caches=caches)
    # row 1's logits must equal a fresh 4-token forward (history invisible)
    ref, _, _ = tr.forward(params, cfg, tokens=new[1:2])
    np.testing.assert_allclose(np.asarray(lo[1]), np.asarray(ref[0]),
                               atol=2e-3, rtol=1e-3)


def test_interleaved_mixed_steps_match_dense_oracle():
    """Cache consistency under interleaved continuous batching: a
    session that decodes, gets RE-prefilled mid-conversation (next user
    turn), and decodes again — every step a mixed packed step sharing
    the stream with other sessions' work — must reproduce the dense
    oracle's transcript and logits token for token."""
    cfg = get_smoke("qwen3-4b")
    params, _ = tr.init_params(cfg, KEY)
    rng = np.random.default_rng(31)
    eng = Engine(cfg, params, EngineConfig(num_slots=8, max_len=128,
                                           packed=True, paged_kv=False,
                                           token_buckets=(64, 128, 256)))
    ora = Engine(cfg, params, EngineConfig(num_slots=8, max_len=128,
                                           paged_kv=False))

    turn1 = rng.integers(0, cfg.vocab_size, 11)
    turn2 = rng.integers(0, cfg.vocab_size, 8)
    noise = [rng.integers(0, cfg.vocab_size, l) for l in (7, 23, 5, 9, 12)]

    # --- mixed engine: session 0 interleaved with sessions 1.. traffic
    transcript = []
    r = eng.step_mixed([(0, turn1), (1, noise[0])], [])
    cur = r.tokens[0]
    transcript.append(cur)
    for i in (1, 2):                                   # decode phase 1
        r = eng.step_mixed([(1 + i, noise[i])], [(0, cur)])
        cur = r.tokens[0]
        transcript.append(cur)
    # mid-conversation re-prefill (turn 2) fused with a decode of s3
    r = eng.step_mixed([(0, turn2)], [(3, r.tokens[3])])
    cur = r.tokens[0]
    transcript.append(cur)
    for i in (3, 4):                                   # decode phase 2
        r = eng.step_mixed([(4 + i - 3, noise[i])], [(0, cur)])
        cur = r.tokens[0]
        transcript.append(cur)

    # --- dense oracle: same schedule for session 0, sequential path
    expect = []
    tok = ora.prefill_batch([0], [turn1])[0]
    expect.append(tok)
    for _ in range(2):
        tok = ora.decode_batch([0], [tok])[0][0]
        expect.append(tok)
    tok = ora.prefill_batch([0], [turn2])[0]
    expect.append(tok)
    for _ in range(2):
        tok = ora.decode_batch([0], [tok])[0][0]
        expect.append(tok)

    assert transcript == expect
    np.testing.assert_allclose(eng.last_logits[0], ora.last_logits[0],
                               atol=1e-5, rtol=0)
    # full-context greedy agreement: the mixed-path transcript equals
    # greedy decoding over the flat concatenated conversation
    ctx = list(turn1)
    for i, t in enumerate(transcript):
        lo, _, _ = tr.forward(params, cfg,
                              tokens=jnp.asarray(ctx, jnp.int32)[None])
        assert int(jnp.argmax(lo[0, -1])) == t, i
        ctx.append(t)
        if i == 2:                   # turn 2 lands after the 3rd token
            ctx.extend(turn2)
            ctx.pop(-len(turn2) - 1)  # last decode token replaced by turn
    n = eng.arena.length(0)
    assert n == ora.arena.length(0)
    sm, so = eng.arena.slot_of(0), ora.arena.slot_of(0)
    for cm, co in zip(eng.arena.arena, ora.arena.arena):
        for part in ("k", "v"):
            np.testing.assert_allclose(np.asarray(cm[part][:, sm, :n]),
                                       np.asarray(co[part][:, so, :n]),
                                       atol=1e-5, rtol=0)
