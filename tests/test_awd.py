"""Algorithm 1 (AWD) invariants — property-based."""
import numpy as np
import pytest
pytest.importorskip("hypothesis")
from hypothesis import given, strategies as st

from repro.core.awd import AWDConfig, AWDScheduler
from repro.core.buckets import BucketGrid
from repro.core.request import Request


def mk_sched(**kw):
    grid = BucketGrid((8, 16, 32, 64, 128, 256), (1, 2, 4, 8, 16, 32, 64),
                      mem_budget_tokens=kw.pop("budget", 4096))
    return AWDScheduler(grid, AWDConfig(**kw))


def mk_queue(lengths, now=0.0, ddl=0.4):
    return [Request(new_tokens=l, arrival=now,
                    deadline=now + ddl) for l in lengths]


@given(lengths=st.lists(st.integers(1, 256), min_size=1, max_size=80))
def test_never_exceeds_budget_or_grid_depth(lengths):
    s = mk_sched(budget=2048)
    q = mk_queue(lengths)
    batch, _ = s.decide(list(q), now=10.0)   # far past windows → dispatch
    if batch is not None:
        padded = sum(s.grid.nearest_length(r.new_tokens) or r.new_tokens
                     for r in batch.requests)
        assert padded <= 2048 or len(batch.requests) == 1
        assert len(batch.requests) <= s.grid.depths[-1]


@given(lengths=st.lists(st.integers(1, 256), min_size=1, max_size=40))
def test_graph_bucket_covers_batch(lengths):
    s = mk_sched()
    q = mk_queue(lengths)
    batch, _ = s.decide(list(q), now=10.0)
    if batch is not None and batch.uses_graph:
        assert batch.bucket_len >= max(r.new_tokens for r in batch.requests)
        assert batch.bucket_depth >= len(batch.requests)
        # profitability guard: padding bounded
        real = sum(r.new_tokens for r in batch.requests)
        assert batch.bucket_len * len(batch.requests) <= 1.5 * real + 1


@given(lengths=st.lists(st.integers(1, 256), min_size=1, max_size=60))
def test_no_starvation(lengths):
    """Repeatedly polling drains the whole queue in bounded rounds."""
    s = mk_sched()
    q = mk_queue(lengths)
    now, rounds = 0.0, 0
    while q and rounds < 3 * len(lengths) + 10:
        batch, wake = s.decide(list(q), now)
        if batch is not None:
            for r in batch.requests:
                q.remove(r)
        now = (wake if wake is not None else now) + 0.05
        rounds += 1
    assert not q


def test_window_respects_bounds():
    s = mk_sched(w_min=0.002, w_max=0.04)
    q = mk_queue([8] * 4, now=0.0, ddl=10.0)
    w = s.window(q, 0.0, 2)
    assert 0.002 <= w <= 0.04


def test_sla_window_tightens_with_deadline():
    s = mk_sched(w_min=0.0, w_max=1.0, service_estimate=0.01)
    tight = mk_queue([8], now=0.0, ddl=0.02)
    loose = mk_queue([8], now=0.0, ddl=5.0)
    assert s.w_sla(tight, 0.0) < s.w_sla(loose, 0.0)


def test_urgent_flush_is_deadline_ordered():
    s = mk_sched(sigma=1.0, service_estimate=0.01)  # everything urgent
    q = [Request(new_tokens=8, arrival=0.0, deadline=d)
         for d in (0.9, 0.1, 0.5)]
    batch, _ = s.decide(list(q), now=0.0)
    assert batch is not None
    ddls = [r.deadline for r in batch.requests]
    assert ddls == sorted(ddls)


def test_deadline_free_token_max():
    s = mk_sched(deadline_free=True, min_fill_tokens=128, budget=4096)
    small = [Request(new_tokens=8, arrival=0.0, deadline=None)]
    batch, wake = s.decide(list(small), now=0.0)
    assert batch is None and wake is not None  # waits for fill w/ flush timer
    # residue flushes once the queue is stagnant
    batch, _ = s.decide(list(small), now=wake)
    assert batch is not None and len(batch.requests) == 1
    many = [Request(new_tokens=8, arrival=0.0, deadline=None)
            for _ in range(40)]
    batch, _ = s.decide(list(many), now=0.0)
    assert batch is not None
    assert sum(r.new_tokens for r in batch.requests) >= 128


def test_depth_adaptation_no_spiral():
    """SLA flushes must not collapse the target depth (regression: the
    D←d shrink on urgent singleton flushes starved throughput)."""
    s = mk_sched(sigma=10.0)                  # everything urgent
    d0 = s.d_target
    for _ in range(20):
        q = mk_queue([8], now=100.0, ddl=0.0)
        s.decide(q, now=100.0)
    assert s.d_target == d0


def test_rate_estimator_bounded_under_simultaneous_arrivals():
    s = mk_sched()
    for _ in range(100):
        s.on_arrival(1.0)                     # identical timestamps
    assert s.r_hat <= 1e4
